//! Quickstart: load a compiled Macformer artifact, initialize state on
//! the device, run a few training steps, and evaluate — the minimal
//! end-to-end tour of the public API.
//!
//! Run with: `cargo run --release --example quickstart`
//! (after `make artifacts`).

use anyhow::Result;
use macformer::config::RunConfig;
use macformer::coordinator::Trainer;
use macformer::runtime::{client, DeviceState, Executable, Registry};

fn main() -> Result<()> {
    macformer::util::logging::init();
    println!("backend: {}", client::describe()?);

    // 1. Open the artifact registry (the python AOT pipeline's output).
    let reg = Registry::open_default()?;
    println!("artifacts: {} modules", reg.modules.len());

    // 2. Pick the smallest family and inspect its manifest row.
    let family = "translation.softmax.ppsbn";
    let info = reg.get(&format!("{family}.train"))?;
    println!(
        "{family}: batch {} x seq {}, {} param buffers + {} opt buffers",
        info.batch, info.seq_len, info.n_params, info.n_opt
    );

    // 3. Compile the init module and create device-resident state.
    let init = Executable::compile_file(
        "init",
        &reg.hlo_path(reg.get(&format!("{family}.init"))?),
    )?;
    println!("init compiled in {:.1}s", init.compile_seconds);
    let state = DeviceState::init(&init, info, 42)?;
    println!("device state: {} buffers", state.state.len());
    drop(state);

    // 4. Or do all of the above + data synthesis in one call and train.
    let cfg = RunConfig {
        task: "translation".into(),
        variant: "softmax".into(),
        suffix: ".ppsbn".into(),
        steps: 5,
        train_examples: 64,
        eval_examples: 32,
        log_every: 1,
        ..RunConfig::default()
    };
    let mut trainer = Trainer::build(cfg, &reg)?;
    let report = trainer.run()?;
    println!(
        "trained {} steps: loss {:.4}, eval loss {:.4}, BLEU {:.2}",
        report.steps, report.final_loss, report.eval_loss, report.quality
    );
    Ok(())
}
