//! End-to-end driver (DESIGN.md E6): train Macformer_exp on the synthetic
//! LRA-Text workload for a few hundred steps and log the loss curve,
//! proving all three layers compose: Pallas RMF kernels (L1) lowered into
//! the JAX model (L2), driven by the Rust coordinator over PJRT (L3).
//!
//! Run with: `cargo run --release --example lra_text_e2e -- [steps]`
//! Results are recorded in EXPERIMENTS.md §E2E.

use anyhow::Result;
use macformer::config::RunConfig;
use macformer::coordinator::Trainer;
use macformer::runtime::Registry;

fn main() -> Result<()> {
    macformer::util::logging::init();
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    let cfg = RunConfig {
        task: "lra_text".into(),
        variant: "mac_exp".into(),
        seed: 42,
        train_examples: 512,
        eval_examples: 128,
        steps,
        eval_every: 50,
        log_every: 10,
        ..RunConfig::default()
    };
    let reg = Registry::open(std::path::Path::new(&cfg.artifacts_dir))?;
    let mut trainer = Trainer::build(cfg, &reg)?;
    let report = trainer.run()?;

    println!("\n== loss curve (step, train loss) ==");
    for (s, l) in &report.loss_curve {
        let bar = "#".repeat(((l / 0.02) as usize).min(60));
        println!("{s:>6} {l:>8.4} {bar}");
    }
    println!("\n== eval curve (step, eval loss, accuracy %) ==");
    for (s, l, a) in &report.eval_curve {
        println!("{s:>6} {l:>8.4} {a:>7.2}");
    }
    println!(
        "\nfinal: train loss {:.4}, eval loss {:.4}, accuracy {:.2}% \
         ({} steps in {:.1}s, {:.3}s/step, peak rss {})",
        report.final_loss,
        report.eval_loss,
        report.quality,
        report.steps,
        report.train_seconds,
        report.step_seconds_mean,
        macformer::util::human_bytes(report.peak_rss_bytes),
    );
    // the run must actually learn: random chance is 50%
    if report.quality <= 55.0 {
        eprintln!("WARNING: accuracy {:.1}% barely above chance", report.quality);
    }
    Ok(())
}
