//! Kernel explorer: inspect the Table-1 dot-product kernels, their
//! Maclaurin expansions, and the RMF approximation quality — all in pure
//! Rust (no PJRT) through the typed `attn::Kernel` API, mirroring the
//! paper's Definition 3 construction.
//!
//! Run with: `cargo run --release --example kernel_explorer -- [D] [t]`

use macformer::attn::{degree_distribution, Kernel};
use macformer::reference::rmf;
use macformer::util::rng::Rng;

fn main() {
    let mut args = std::env::args().skip(1);
    let feat: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(128);
    let t_probe: f64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(0.4);

    // Table 1: coefficients
    println!("Table 1 — Maclaurin coefficients a_N (paper-order kernels)\n");
    print!("{:>8}", "N");
    for k in Kernel::MACLAURIN {
        print!("{k:>12}");
    }
    println!();
    for n in 0..=8 {
        print!("{n:>8}");
        for k in Kernel::MACLAURIN {
            print!("{:>12.6}", k.coefficient(n).expect("Table-1 kernel"));
        }
        println!();
    }

    // closed form vs truncated expansion at the probe point
    println!("\nK(t) at t = {t_probe}: closed form vs degree-8 truncation\n");
    for k in Kernel::MACLAURIN {
        let exact = k.value(t_probe).expect("Table-1 kernel");
        let trunc = k.truncated_value(t_probe, 8).expect("Table-1 kernel");
        println!(
            "  {k:<6} exact {exact:>10.6}  series {trunc:>10.6}  |err| {:.2e}",
            (exact - trunc).abs()
        );
    }

    // RMF Monte-Carlo estimate (Definition 3 / Theorem 1)
    println!("\nRMF estimate of K(x.y) with D = {feat} (500 draws)\n");
    let mut rng = Rng::new(7);
    let d = 8;
    let x: Vec<f32> = (0..d).map(|_| rng.normal() * 0.25).collect();
    let y: Vec<f32> = (0..d).map(|_| rng.normal() * 0.25).collect();
    let t: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
    println!("  x.y = {t:.4}");
    for k in Kernel::MACLAURIN {
        let est = rmf::mc_kernel_estimate(&mut rng, k, &x, &y, feat, 2.0, 8, 500);
        let exact = k.truncated_value(t as f64, 8).expect("Table-1 kernel");
        println!(
            "  {k:<6} E[phi(x).phi(y)] = {est:>9.5}  target {exact:>9.5}  rel err {:+.3}%",
            100.0 * (est - exact) / exact
        );
    }

    // degree distribution
    println!("\nDegree law P[N = n] (p = 2, truncated at 8):\n");
    for (n, p) in degree_distribution(2.0, 8).iter().enumerate() {
        println!("  N={n}: {:.4} {}", p, "*".repeat((p * 120.0) as usize));
    }
}
