//! Fig-3 example: the ppSBN ablation on the synthetic translation task.
//! Trains the base Transformer and the ppSBN-wrapped Transformer with
//! identical seeds/data and prints the per-epoch loss / perplexity / BLEU
//! comparison (the three panels of the paper\'s Figure 3).
//!
//! Run with: `cargo run --release --example translation_ppsbn -- [epochs] [steps-per-epoch]`

use anyhow::Result;
use macformer::config::RunConfig;
use macformer::coordinator::fig3;
use macformer::runtime::Registry;

fn main() -> Result<()> {
    macformer::util::logging::init();
    let mut args = std::env::args().skip(1);
    let epochs: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(6);
    let spe: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(40);

    let cfg = RunConfig {
        train_examples: (spe * 32).max(512),
        eval_examples: 128,
        seed: 42,
        ..RunConfig::default()
    };
    let reg = Registry::open(std::path::Path::new(&cfg.artifacts_dir))?;
    let result = fig3::run(&reg, &cfg, epochs, spe)?;
    println!("{}", fig3::render(&result));

    // Paper claim: ppSBN outperforms the base model on loss and BLEU.
    let last_b = result.base.last().unwrap();
    let last_p = result.ppsbn.last().unwrap();
    println!(
        "final: base loss {:.4} vs ppSBN {:.4} | base BLEU {:.2} vs ppSBN {:.2}",
        last_b.loss, last_p.loss, last_b.bleu, last_p.bleu
    );
    Ok(())
}
