//! Cache-aware single-problem attention kernels.
//!
//! Same math as `reference::attention` (which stays the oracle), but:
//! score matrices come from the runtime-dispatched `matmul_nt_into` GEMM
//! (AVX2+FMA on capable hosts, register-blocked scalar otherwise)
//! instead of per-row scalar dots, rows are processed in blocks so the
//! logits working set stays L1/L2-resident, and every inner loop walks
//! contiguous memory through the `fastpath::simd` primitives (row
//! weighting, normalize, running `(S, z)` updates). Transcendentals
//! (`exp` and the Table-1 kernel weights) stay scalar on both arms.
//!
//! All functions also exist as `_into` variants over raw slices so the
//! parallel driver can shard one batched tensor into per-problem
//! sub-slices without copies.
//!
//! The causal linear path is **chunkwise-parallel**
//! ([`causal_prefill_fold_into`]): instead of a strictly sequential
//! token-by-token `(S, z)` fold, the sequence is processed
//! `MACFORMER_CHUNK` tokens at a time with the inter-chunk
//! contribution, the intra-chunk causal correction, and the state
//! advance all expressed as dispatched GEMMs plus the
//! [`simd::tril_accum`] masked fold. Chunk width 1 reproduces the
//! original sequential fold exactly; the fold halves themselves
//! ([`causal_fold_key`] / [`causal_fold_query`]) are shared with the
//! streaming decode state in `crate::attn`, so no causal path drifts.
//!
//! # Scratch discipline
//!
//! The logits / score blocks and the linear-attention `(S, z)`
//! accumulators live in a grow-only, thread-local workspace instead
//! of per-call `vec![0.0; ..]`s. The persistent worker pool keeps its
//! threads (and therefore their workspaces) alive across calls, so
//! steady-state attention makes **zero heap allocations** — enforced by
//! `tests/alloc_free.rs`. Every buffer's used prefix is fully
//! overwritten (or explicitly zero-filled) before being read, so no
//! state bleeds between calls of different shapes.

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::attn::Kernel;
use crate::tensor::{matmul_nt_into, matmul_tn_accum_into, matmul_tn_into, Tensor};

use super::{grow, simd};

/// Rows of the score matrix materialized at a time: 32 rows x n=4096
/// cols of f32 is 512 KiB, comfortably L2-resident.
const ROW_BLOCK: usize = 32;

/// Default causal chunk width: 64 tokens keeps the intra-chunk score
/// block (64 x 64 f32 = 16 KiB) L1-resident while amortizing the
/// per-chunk state transpose to `feat * dv / 64` copies per token.
pub const DEFAULT_CHUNK: usize = 64;

/// Chunk-width cache: 0 = unresolved (read `MACFORMER_CHUNK` on first
/// use), otherwise the width in effect (>= 1; 1 = sequential fold).
static CHUNK: AtomicUsize = AtomicUsize::new(0);

/// Outcome of validating a raw `MACFORMER_CHUNK` value — mirrors
/// `parallel::ThreadOverride` so every env knob follows the same
/// warn-and-clamp policy. Pure, so the policy is unit-testable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkOverride {
    /// A usable chunk width (>= 1).
    Count(usize),
    /// `0` was requested: a zero-token chunk cannot make progress, so
    /// the caller warns and clamps to 1 (the sequential fold).
    ClampedToOne,
    /// Not a number at all (empty, negative, fractional, or beyond
    /// `usize`): the caller warns and uses [`DEFAULT_CHUNK`].
    Malformed,
}

/// Validate a raw `MACFORMER_CHUNK` value. See [`ChunkOverride`].
pub fn parse_chunk_override(raw: &str) -> ChunkOverride {
    match raw.trim().parse::<usize>() {
        Ok(0) => ChunkOverride::ClampedToOne,
        Ok(c) => ChunkOverride::Count(c),
        Err(_) => ChunkOverride::Malformed,
    }
}

/// The causal chunk width in effect. Resolved once per process from
/// `MACFORMER_CHUNK` (default [`DEFAULT_CHUNK`]; `1` pins the
/// token-by-token sequential fold). Flipping the env var mid-process
/// has no effect — use [`set_causal_chunk`] for in-process sweeps
/// (benches, chunk-size tests).
pub fn causal_chunk() -> usize {
    match CHUNK.load(Ordering::Relaxed) {
        0 => {
            let c = match std::env::var("MACFORMER_CHUNK") {
                Ok(raw) => match parse_chunk_override(&raw) {
                    ChunkOverride::Count(c) => c,
                    ChunkOverride::ClampedToOne => {
                        log::warn!(
                            "MACFORMER_CHUNK={raw:?} requests a zero-token \
                             chunk; clamping to 1 (the sequential fold)"
                        );
                        1
                    }
                    ChunkOverride::Malformed => {
                        log::warn!(
                            "MACFORMER_CHUNK={raw:?} is not a chunk width; \
                             using the default of {DEFAULT_CHUNK}"
                        );
                        DEFAULT_CHUNK
                    }
                },
                Err(_) => DEFAULT_CHUNK,
            };
            CHUNK.store(c, Ordering::Relaxed);
            c
        }
        c => c,
    }
}

/// Force the causal chunk width for this process (clamped to >= 1).
/// Returns the width in effect. Global: do not call concurrently with
/// compute whose chunking must be deterministic.
pub fn set_causal_chunk(chunk: usize) -> usize {
    let c = chunk.max(1);
    CHUNK.store(c, Ordering::Relaxed);
    c
}

/// Drop any cached/forced chunk width; the next [`causal_chunk`] call
/// re-resolves from `MACFORMER_CHUNK`.
pub fn reset_causal_chunk() {
    CHUNK.store(0, Ordering::Relaxed);
}

/// Grow-only per-thread scratch for the attention kernels.
struct Workspace {
    /// ROW_BLOCK x m score/logits block.
    logits: Vec<f32>,
    /// feat x dv linear-attention accumulator.
    s: Vec<f32>,
    /// feat linear-attention normalizer.
    z: Vec<f32>,
}

thread_local! {
    static WORKSPACE: RefCell<Workspace> = RefCell::new(Workspace {
        logits: Vec::new(),
        s: Vec::new(),
        z: Vec::new(),
    });
}

/// Grow-only per-thread scratch for the chunked causal kernel — a
/// separate thread-local from [`WORKSPACE`] because the chunked kernel
/// runs while `linear_attention_into` still holds the main workspace
/// borrow (its `(S, z)` state lives there).
struct ChunkWorkspace {
    /// dv x feat transposed state staged for the inter-chunk GEMM.
    st: Vec<f32>,
    /// chunk x chunk intra-chunk score block.
    scores: Vec<f32>,
    /// chunk per-row denominators.
    den: Vec<f32>,
}

thread_local! {
    static CHUNK_WS: RefCell<ChunkWorkspace> = RefCell::new(ChunkWorkspace {
        st: Vec::new(),
        scores: Vec::new(),
        den: Vec::new(),
    });
}

/// Key half of the streaming causal `(S, z)` update: fold `phi(k')`
/// and `v` into the running accumulators (`S += phi_k v^T`, `z +=
/// phi_k`). Shared verbatim by `attn::CausalState` (single-stream
/// decode and the serve scheduler's micro-batched fold) and the
/// sequential arm of [`causal_prefill_fold_into`], so no causal path
/// can drift from another.
pub fn causal_fold_key(phi_k: &[f32], v: &[f32], z: &mut [f32], s: &mut [f32], dv: usize) {
    for (f, &pkf) in phi_k.iter().enumerate() {
        z[f] += pkf;
        if pkf == 0.0 {
            continue;
        }
        simd::axpy(pkf, v, &mut s[f * dv..(f + 1) * dv]);
    }
}

/// Query half: contract `phi(q')` against the running `(S, z)` state
/// into one normalized `dv`-length output row. See [`causal_fold_key`].
/// Returns the raw (pre-`eps`) denominator `phi_q . z` so callers can
/// run a health check on the fold (a non-finite denominator means phi
/// overflowed and the output row is garbage).
pub fn causal_fold_query(
    phi_q: &[f32],
    z: &[f32],
    s: &[f32],
    dv: usize,
    eps: f32,
    out: &mut [f32],
) -> f32 {
    let mut den = 0.0f32;
    out.fill(0.0);
    for (f, &pqf) in phi_q.iter().enumerate() {
        den += pqf * z[f];
        if pqf == 0.0 {
            continue;
        }
        simd::axpy(pqf, &s[f * dv..(f + 1) * dv], out);
    }
    simd::div_assign(out, den + eps);
    den
}

/// Chunkwise-parallel causal linear attention with a caller-owned
/// running state — the GEMM-dominated prefill kernel.
///
/// Folds `n` tokens of `(phi_q, phi_k, v)` rows into the running
/// `(s, z)` prefix state (`s` is `feat x dv` row-major, `z` is `feat`)
/// and writes every position's normalized attention output. Sequence
/// positions are processed `chunk` tokens at a time:
///
/// 1. **inter-chunk** — `out_chunk = phi_q_chunk · S_prev` and
///    `den = phi_q_chunk · z_prev` via the dispatched `matmul_nt`
///    (the state is staged transposed once per chunk);
/// 2. **intra-chunk** — the raw `chunk x chunk` score block
///    `phi_q_chunk · phi_k_chunk^T` via `matmul_nt`, masked and folded
///    by [`simd::tril_accum`] (position `i` sees keys `<= i` only);
/// 3. **state advance** — `z += colsum(phi_k_chunk)` and
///    `S += phi_k_chunk^T · V_chunk` via the accumulating
///    `matmul_tn_accum`, both applied token-ordered.
///
/// `chunk <= 1` runs the token-by-token sequential fold
/// ([`causal_fold_key`] / [`causal_fold_query`]) — exactly the
/// streaming decode path. For `chunk > 1` the **state advance is
/// bit-identical to the sequential fold on the same dispatch arm**
/// (token-ordered rank-1 updates and column adds, see
/// `matmul_tn_accum_into` / [`simd::colsum`]), so prefill-then-decode
/// continues bit-compatibly from decode-from-scratch; the prefill
/// *outputs* regroup their reductions per chunk and carry the usual
/// `1e-5` equivalence contract against the sequential fold.
#[allow(clippy::too_many_arguments)]
pub fn causal_prefill_fold_into(
    phi_q: &[f32],
    phi_k: &[f32],
    v: &[f32],
    n: usize,
    feat: usize,
    dv: usize,
    chunk: usize,
    eps: f32,
    s: &mut [f32],
    z: &mut [f32],
    out: &mut [f32],
) {
    assert_eq!(phi_q.len(), n * feat, "causal prefill: phi_q len");
    assert_eq!(phi_k.len(), n * feat, "causal prefill: phi_k len");
    assert_eq!(v.len(), n * dv, "causal prefill: v len");
    assert_eq!(out.len(), n * dv, "causal prefill: out len");
    assert_eq!(s.len(), feat * dv, "causal prefill: s len");
    assert_eq!(z.len(), feat, "causal prefill: z len");
    if n == 0 {
        return;
    }
    if chunk <= 1 {
        for i in 0..n {
            causal_fold_key(&phi_k[i * feat..(i + 1) * feat], &v[i * dv..(i + 1) * dv], z, s, dv);
            causal_fold_query(
                &phi_q[i * feat..(i + 1) * feat],
                z,
                s,
                dv,
                eps,
                &mut out[i * dv..(i + 1) * dv],
            );
        }
        return;
    }
    // An oversized width degenerates to "one chunk = the whole
    // sequence"; clamp before sizing the scratch so MACFORMER_CHUNK
    // values far beyond n cannot balloon the chunk*chunk score block.
    let chunk = chunk.min(n);
    CHUNK_WS.with(|ws| {
        let ws = &mut *ws.borrow_mut();
        grow(&mut ws.st, feat * dv);
        grow(&mut ws.scores, chunk * chunk);
        grow(&mut ws.den, chunk);
        let st = &mut ws.st[..feat * dv];
        let mut t0 = 0;
        while t0 < n {
            let c = chunk.min(n - t0);
            let pq = &phi_q[t0 * feat..(t0 + c) * feat];
            let pk = &phi_k[t0 * feat..(t0 + c) * feat];
            let vc = &v[t0 * dv..(t0 + c) * dv];
            let oc = &mut out[t0 * dv..(t0 + c) * dv];
            let scores = &mut ws.scores[..c * c];
            let den = &mut ws.den[..c];
            // Stage S_prev transposed (dv x feat) so the inter-chunk
            // contraction is one matmul_nt; a feat*dv copy per chunk,
            // amortized to feat*dv/chunk per token.
            for f in 0..feat {
                for (x, &sv) in s[f * dv..(f + 1) * dv].iter().enumerate() {
                    st[x * feat + f] = sv;
                }
            }
            // inter-chunk: every element of oc / den is overwritten
            matmul_nt_into(pq, c, feat, st, dv, oc);
            matmul_nt_into(pq, c, feat, z, 1, den);
            // intra-chunk: raw score block, then the masked fold (the
            // strictly-upper triangle is computed but never read)
            matmul_nt_into(pq, c, feat, pk, c, scores);
            simd::tril_accum(scores, c, vc, dv, oc, den);
            for (ii, &d) in den.iter().enumerate() {
                simd::div_assign(&mut oc[ii * dv..(ii + 1) * dv], d + eps);
            }
            // state advance, token-ordered — bit-compatible with the
            // sequential fold on the same dispatch arm
            simd::colsum(pk, c, z);
            matmul_tn_accum_into(pk, c, feat, vc, dv, s);
            t0 += c;
        }
    });
}

/// Exact softmax attention, blocked: out = softmax(q k^T / sqrt(d)) v.
pub fn softmax_attention(q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Tensor {
    let (n, d) = (q.shape[0], q.shape[1]);
    let m = k.shape[0];
    assert_eq!(k.shape[1], d);
    assert_eq!(v.shape[0], m);
    let dv = v.shape[1];
    let mut out = Tensor::zeros(&[n, dv]);
    softmax_attention_into(&q.data, &k.data, &v.data, n, m, d, dv, causal, &mut out.data);
    out
}

/// Slice-level exact softmax attention; `out` is (n x dv) row-major.
#[allow(clippy::too_many_arguments)]
pub fn softmax_attention_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    causal: bool,
    out: &mut [f32],
) {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), m * d);
    assert_eq!(v.len(), m * dv);
    assert_eq!(out.len(), n * dv);
    if causal {
        // same contract as the reference oracle (which indexes keys up
        // to row i and has no defined causal semantics for n != m)
        assert_eq!(n, m, "causal softmax attention needs n == m");
    }
    let scale = 1.0 / (d as f32).sqrt();
    WORKSPACE.with(|ws| {
        let ws = &mut *ws.borrow_mut();
        grow(&mut ws.logits, ROW_BLOCK * m);
        let logits = &mut ws.logits;
        let mut i0 = 0;
        while i0 < n {
            let ib = ROW_BLOCK.min(n - i0);
            // score block = Q[i0..i0+ib] · K[..cols]^T, one GEMM. Under a
            // causal mask only keys j <= i are ever read, so cap the GEMM at
            // the block's widest row instead of computing the full triangle.
            let cols = if causal { (i0 + ib).min(m) } else { m };
            matmul_nt_into(
                &q[i0 * d..(i0 + ib) * d],
                ib,
                d,
                &k[..cols * d],
                cols,
                &mut logits[..ib * cols],
            );
            for ii in 0..ib {
                let i = i0 + ii;
                let limit = if causal { (i + 1).min(m) } else { m };
                let row = &mut logits[ii * cols..ii * cols + limit];
                let maxl = simd::scale_max(row, scale);
                let mut z = 0.0f32;
                for l in row.iter_mut() {
                    *l = (*l - maxl).exp();
                    z += *l;
                }
                let orow = &mut out[i * dv..(i + 1) * dv];
                orow.fill(0.0);
                for (j, &w) in row.iter().enumerate() {
                    simd::axpy(w, &v[j * dv..(j + 1) * dv], orow);
                }
                simd::div_assign(orow, z);
            }
            i0 += ib;
        }
    });
}

/// Kernelized attention (Definition 2), blocked, any Table-1 kernel.
/// Panics on [`Kernel::Softmax`] (no pointwise kernel weight) — the
/// `attn` session API rejects that combination with a clean error.
pub fn kernelized_attention(
    kernel: Kernel,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (n, d) = (q.shape[0], q.shape[1]);
    let m = k.shape[0];
    assert_eq!(k.shape[1], d);
    assert_eq!(v.shape[0], m);
    let dv = v.shape[1];
    let mut out = Tensor::zeros(&[n, dv]);
    kernelized_attention_into(
        kernel, &q.data, &k.data, &v.data, n, m, d, dv, causal, eps, &mut out.data,
    );
    out
}

/// Slice-level kernelized attention; `out` is (n x dv) row-major.
#[allow(clippy::too_many_arguments)]
pub fn kernelized_attention_into(
    kernel: Kernel,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    causal: bool,
    eps: f32,
    out: &mut [f32],
) {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), m * d);
    assert_eq!(v.len(), m * dv);
    assert_eq!(out.len(), n * dv);
    if causal {
        assert_eq!(n, m, "causal kernelized attention needs n == m");
    }
    let scale = 1.0 / (d as f32).sqrt();
    // resolve the kernel once — not per score element in the hot loop
    let kf = kernel
        .value_fn()
        .expect("kernelized attention requires a Table-1 Maclaurin kernel");
    WORKSPACE.with(|ws| {
        let ws = &mut *ws.borrow_mut();
        grow(&mut ws.logits, ROW_BLOCK * m);
        let scores = &mut ws.logits;
        let mut i0 = 0;
        while i0 < n {
            let ib = ROW_BLOCK.min(n - i0);
            // see softmax_attention_into: cap the GEMM at the causal width
            let cols = if causal { (i0 + ib).min(m) } else { m };
            matmul_nt_into(
                &q[i0 * d..(i0 + ib) * d],
                ib,
                d,
                &k[..cols * d],
                cols,
                &mut scores[..ib * cols],
            );
            for ii in 0..ib {
                let i = i0 + ii;
                let limit = if causal { (i + 1).min(m) } else { m };
                let row = &scores[ii * cols..ii * cols + limit];
                let mut den = 0.0f32;
                let orow = &mut out[i * dv..(i + 1) * dv];
                orow.fill(0.0);
                for (j, &t) in row.iter().enumerate() {
                    let w = kf((t * scale) as f64) as f32;
                    den += w;
                    simd::axpy(w, &v[j * dv..(j + 1) * dv], orow);
                }
                simd::div_assign(orow, den + eps);
            }
            i0 += ib;
        }
    });
}

/// Factored linear contraction: out_i = phi_q_i S / (phi_q_i z + eps).
pub fn linear_attention(
    phi_q: &Tensor,
    phi_k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (n, feat) = (phi_q.shape[0], phi_q.shape[1]);
    let m = phi_k.shape[0];
    assert_eq!(phi_k.shape[1], feat);
    assert_eq!(v.shape[0], m);
    let dv = v.shape[1];
    let mut out = Tensor::zeros(&[n, dv]);
    linear_attention_into(
        &phi_q.data, &phi_k.data, &v.data, n, m, feat, dv, causal, eps, &mut out.data,
    );
    out
}

/// Slice-level linear attention; `out` is (n x dv) row-major. The causal
/// variant requires n == m (one running prefix state).
#[allow(clippy::too_many_arguments)]
pub fn linear_attention_into(
    phi_q: &[f32],
    phi_k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    feat: usize,
    dv: usize,
    causal: bool,
    eps: f32,
    out: &mut [f32],
) {
    assert_eq!(phi_q.len(), n * feat);
    assert_eq!(phi_k.len(), m * feat);
    assert_eq!(v.len(), m * dv);
    assert_eq!(out.len(), n * dv);
    if causal {
        assert_eq!(n, m, "causal linear attention needs n == m");
    }
    WORKSPACE.with(|ws| {
        let ws = &mut *ws.borrow_mut();
        grow(&mut ws.s, feat * dv);
        grow(&mut ws.z, feat);
        let s = &mut ws.s[..feat * dv];
        let z = &mut ws.z[..feat];
        if causal {
            // Chunkwise-parallel prefill over a zeroed local state; the
            // chunk width comes from MACFORMER_CHUNK (1 = the original
            // token-by-token fold, reproduced exactly).
            s.fill(0.0);
            z.fill(0.0);
            causal_prefill_fold_into(
                phi_q,
                phi_k,
                v,
                n,
                feat,
                dv,
                causal_chunk(),
                eps,
                s,
                z,
                out,
            );
        } else {
            // S = phi_k^T v (feat x dv) via the dispatched rank-1-update
            // GEMM and z = colsum(phi_k) — one column-sum primitive,
            // same accumulation order over keys as the fused reference
            // loop (and as the m-sequential-axpy loop it replaced).
            matmul_tn_into(phi_k, m, feat, v, dv, s);
            z.fill(0.0);
            simd::colsum(phi_k, m, z);
            for i in 0..n {
                let pq = &phi_q[i * feat..(i + 1) * feat];
                let den = simd::dot(pq, z);
                let orow = &mut out[i * dv..(i + 1) * dv];
                orow.fill(0.0);
                for (f, &pqf) in pq.iter().enumerate() {
                    if pqf == 0.0 {
                        continue;
                    }
                    simd::axpy(pqf, &s[f * dv..(f + 1) * dv], orow);
                }
                simd::div_assign(orow, den + eps);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reference::attention as oracle;
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
        Tensor::randn(rng, shape, scale)
    }

    #[test]
    fn softmax_matches_oracle_including_row_block_boundary() {
        let mut rng = Rng::new(21);
        // n = 70 crosses two ROW_BLOCK boundaries
        for causal in [false, true] {
            let q = randn(&mut rng, &[70, 8], 0.8);
            let k = randn(&mut rng, &[70, 8], 0.8);
            let v = randn(&mut rng, &[70, 5], 1.0);
            let a = oracle::softmax_attention(&q, &k, &v, causal);
            let b = softmax_attention(&q, &k, &v, causal);
            assert!(a.max_abs_diff(&b) < 1e-5, "causal={causal}: {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn kernelized_matches_oracle_all_kernels() {
        let mut rng = Rng::new(22);
        // n = 70 crosses two ROW_BLOCK boundaries, exercising the causal
        // cols-capped score stride
        for kernel in Kernel::MACLAURIN {
            for causal in [false, true] {
                let q = randn(&mut rng, &[70, 4], 0.4);
                let k = randn(&mut rng, &[70, 4], 0.4);
                let v = randn(&mut rng, &[70, 3], 1.0);
                let a = oracle::kernelized_attention(kernel, &q, &k, &v, causal, 1e-6);
                let b = kernelized_attention(kernel, &q, &k, &v, causal, 1e-6);
                assert!(
                    a.max_abs_diff(&b) < 1e-5,
                    "{kernel} causal={causal}: {}",
                    a.max_abs_diff(&b)
                );
            }
        }
    }

    #[test]
    fn linear_matches_oracle_nonsquare() {
        let mut rng = Rng::new(23);
        let phi_q = randn(&mut rng, &[7, 6], 1.0).map(f32::abs);
        let phi_k = randn(&mut rng, &[7, 6], 1.0).map(f32::abs);
        let v = randn(&mut rng, &[7, 2], 1.0);
        for causal in [false, true] {
            let a = oracle::linear_attention(&phi_q, &phi_k, &v, causal, 1e-6);
            let b = linear_attention(&phi_q, &phi_k, &v, causal, 1e-6);
            assert!(a.max_abs_diff(&b) < 1e-5, "causal={causal}: {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn chunk_override_parsing_policy() {
        use ChunkOverride::*;
        // malformed values are typed (causal_chunk warns + defaults)
        assert_eq!(parse_chunk_override("abc"), Malformed);
        assert_eq!(parse_chunk_override(""), Malformed);
        assert_eq!(parse_chunk_override("-3"), Malformed);
        assert_eq!(parse_chunk_override("2.5"), Malformed);
        // beyond usize is malformed, not wrapped
        assert_eq!(parse_chunk_override("184467440737095516160"), Malformed);
        // zero cannot chunk: typed clamp so causal_chunk warns about it
        assert_eq!(parse_chunk_override("0"), ClampedToOne);
        assert_eq!(parse_chunk_override(" 0 "), ClampedToOne);
        // honest values pass through, whitespace tolerated; huge-but-
        // representable widths are legal (the kernel clamps to n)
        assert_eq!(parse_chunk_override("1"), Count(1));
        assert_eq!(parse_chunk_override(" 64 "), Count(64));
        assert_eq!(parse_chunk_override(&usize::MAX.to_string()), Count(usize::MAX));
    }

    /// Chunked causal prefill vs the sequential fold: outputs within
    /// 1e-5 for every chunk width (including widths that don't divide
    /// n and widths larger than n), final `(S, z)` state bit-identical,
    /// and `chunk = 1` reproducing the fold's outputs bit for bit.
    #[test]
    fn chunked_causal_prefill_matches_sequential_fold() {
        let mut rng = Rng::new(25);
        let (n, feat, dv) = (70usize, 12usize, 5usize);
        let phi_q = randn(&mut rng, &[n, feat], 0.8).map(f32::abs);
        let phi_k = randn(&mut rng, &[n, feat], 0.8).map(f32::abs);
        let v = randn(&mut rng, &[n, dv], 1.0);
        let (pq, pk, vd) = (&phi_q.data[..], &phi_k.data[..], &v.data[..]);
        let mut s_seq = vec![0.0f32; feat * dv];
        let mut z_seq = vec![0.0f32; feat];
        let mut out_seq = vec![0.0f32; n * dv];
        causal_prefill_fold_into(
            pq, pk, vd, n, feat, dv, 1, 1e-6, &mut s_seq, &mut z_seq, &mut out_seq,
        );
        let oracle = crate::reference::attention::linear_attention(&phi_q, &phi_k, &v, true, 1e-6);
        for chunk in [1usize, 2, 3, 7, 16, 64, 70, 200] {
            let mut s = vec![0.0f32; feat * dv];
            let mut z = vec![0.0f32; feat];
            let mut out = vec![0.0f32; n * dv];
            causal_prefill_fold_into(
                pq, pk, vd, n, feat, dv, chunk, 1e-6, &mut s, &mut z, &mut out,
            );
            // the running state is bit-compatible with the fold's
            for (i, (a, b)) in s.iter().zip(&s_seq).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "chunk {chunk}: S elem {i}: {a} vs {b}");
            }
            for (i, (a, b)) in z.iter().zip(&z_seq).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "chunk {chunk}: z elem {i}: {a} vs {b}");
            }
            for (i, (a, b)) in out.iter().zip(&out_seq).enumerate() {
                if chunk <= 1 {
                    assert_eq!(a.to_bits(), b.to_bits(), "chunk 1 must BE the fold: elem {i}");
                } else {
                    assert!((a - b).abs() < 1e-5, "chunk {chunk} elem {i}: {a} vs {b}");
                }
                assert!(
                    (a - oracle.data[i]).abs() < 1e-5,
                    "chunk {chunk} elem {i} vs oracle: {a} vs {}",
                    oracle.data[i]
                );
            }
        }
    }

    /// A prefill split across two calls (carrying the state) equals one
    /// whole-stream prefill — the chunked state hand-off is seamless at
    /// arbitrary boundaries.
    #[test]
    fn chunked_prefill_state_carries_across_calls() {
        let mut rng = Rng::new(26);
        let (n, feat, dv, cut) = (41usize, 9usize, 4usize, 17usize);
        let phi_q = randn(&mut rng, &[n, feat], 0.8).map(f32::abs);
        let phi_k = randn(&mut rng, &[n, feat], 0.8).map(f32::abs);
        let v = randn(&mut rng, &[n, dv], 1.0);
        let (pq, pk, vd) = (&phi_q.data[..], &phi_k.data[..], &v.data[..]);
        for chunk in [1usize, 5, 16] {
            let mut s1 = vec![0.0f32; feat * dv];
            let mut z1 = vec![0.0f32; feat];
            let mut whole = vec![0.0f32; n * dv];
            causal_prefill_fold_into(
                pq, pk, vd, n, feat, dv, chunk, 1e-6, &mut s1, &mut z1, &mut whole,
            );
            let mut s2 = vec![0.0f32; feat * dv];
            let mut z2 = vec![0.0f32; feat];
            let mut split = vec![0.0f32; n * dv];
            causal_prefill_fold_into(
                &phi_q.data[..cut * feat],
                &phi_k.data[..cut * feat],
                &v.data[..cut * dv],
                cut,
                feat,
                dv,
                chunk,
                1e-6,
                &mut s2,
                &mut z2,
                &mut split[..cut * dv],
            );
            causal_prefill_fold_into(
                &phi_q.data[cut * feat..],
                &phi_k.data[cut * feat..],
                &v.data[cut * dv..],
                n - cut,
                feat,
                dv,
                chunk,
                1e-6,
                &mut s2,
                &mut z2,
                &mut split[cut * dv..],
            );
            assert_eq!(s1, s2, "chunk {chunk}: split S drifted");
            assert_eq!(z1, z2, "chunk {chunk}: split z drifted");
            // outputs may regroup at the cut (chunk boundaries shift):
            // within the chunked equivalence contract
            for (i, (a, b)) in split.iter().zip(&whole).enumerate() {
                assert!((a - b).abs() < 1e-5, "chunk {chunk} elem {i}: {a} vs {b}");
            }
        }
    }

    /// The workspace is shared across shapes within a thread: running a
    /// big problem, then a small one, then the big one again must give
    /// identical results (no stale-buffer bleed).
    #[test]
    fn workspace_reuse_across_shapes_is_stateless() {
        let mut rng = Rng::new(24);
        let qb = randn(&mut rng, &[40, 6], 0.6);
        let kb = randn(&mut rng, &[40, 6], 0.6);
        let vb = randn(&mut rng, &[40, 4], 1.0);
        let qs = randn(&mut rng, &[3, 2], 0.6);
        let ks = randn(&mut rng, &[3, 2], 0.6);
        let vs = randn(&mut rng, &[3, 7], 1.0);
        for causal in [false, true] {
            let first = softmax_attention(&qb, &kb, &vb, causal);
            let _ = softmax_attention(&qs, &ks, &vs, causal);
            let again = softmax_attention(&qb, &kb, &vb, causal);
            assert_eq!(first.data, again.data, "softmax causal={causal}");

            let pqb = qb.map(f32::abs);
            let pkb = kb.map(f32::abs);
            let first = linear_attention(&pqb, &pkb, &vb, causal, 1e-6);
            let _ = linear_attention(&qs.map(f32::abs), &ks.map(f32::abs), &vs, causal, 1e-6);
            let again = linear_attention(&pqb, &pkb, &vb, causal, 1e-6);
            assert_eq!(first.data, again.data, "linear causal={causal}");
        }
    }
}
