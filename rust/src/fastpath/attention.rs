//! Cache-aware single-problem attention kernels.
//!
//! Same math as `reference::attention` (which stays the oracle), but:
//! score matrices come from the runtime-dispatched `matmul_nt_into` GEMM
//! (AVX2+FMA on capable hosts, register-blocked scalar otherwise)
//! instead of per-row scalar dots, rows are processed in blocks so the
//! logits working set stays L1/L2-resident, and every inner loop walks
//! contiguous memory through the `fastpath::simd` primitives (row
//! weighting, normalize, running `(S, z)` updates). Transcendentals
//! (`exp` and the Table-1 kernel weights) stay scalar on both arms.
//!
//! All functions also exist as `_into` variants over raw slices so the
//! parallel driver can shard one batched tensor into per-problem
//! sub-slices without copies.
//!
//! # Scratch discipline
//!
//! The logits / score blocks and the linear-attention `(S, z)`
//! accumulators live in a grow-only, thread-local workspace instead
//! of per-call `vec![0.0; ..]`s. The persistent worker pool keeps its
//! threads (and therefore their workspaces) alive across calls, so
//! steady-state attention makes **zero heap allocations** — enforced by
//! `tests/alloc_free.rs`. Every buffer's used prefix is fully
//! overwritten (or explicitly zero-filled) before being read, so no
//! state bleeds between calls of different shapes.

use std::cell::RefCell;

use crate::attn::Kernel;
use crate::tensor::{matmul_nt_into, matmul_tn_into, Tensor};

use super::{grow, simd};

/// Rows of the score matrix materialized at a time: 32 rows x n=4096
/// cols of f32 is 512 KiB, comfortably L2-resident.
const ROW_BLOCK: usize = 32;

/// Grow-only per-thread scratch for the attention kernels.
struct Workspace {
    /// ROW_BLOCK x m score/logits block.
    logits: Vec<f32>,
    /// feat x dv linear-attention accumulator.
    s: Vec<f32>,
    /// feat linear-attention normalizer.
    z: Vec<f32>,
}

thread_local! {
    static WORKSPACE: RefCell<Workspace> = RefCell::new(Workspace {
        logits: Vec::new(),
        s: Vec::new(),
        z: Vec::new(),
    });
}

/// Exact softmax attention, blocked: out = softmax(q k^T / sqrt(d)) v.
pub fn softmax_attention(q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Tensor {
    let (n, d) = (q.shape[0], q.shape[1]);
    let m = k.shape[0];
    assert_eq!(k.shape[1], d);
    assert_eq!(v.shape[0], m);
    let dv = v.shape[1];
    let mut out = Tensor::zeros(&[n, dv]);
    softmax_attention_into(&q.data, &k.data, &v.data, n, m, d, dv, causal, &mut out.data);
    out
}

/// Slice-level exact softmax attention; `out` is (n x dv) row-major.
#[allow(clippy::too_many_arguments)]
pub fn softmax_attention_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    causal: bool,
    out: &mut [f32],
) {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), m * d);
    assert_eq!(v.len(), m * dv);
    assert_eq!(out.len(), n * dv);
    if causal {
        // same contract as the reference oracle (which indexes keys up
        // to row i and has no defined causal semantics for n != m)
        assert_eq!(n, m, "causal softmax attention needs n == m");
    }
    let scale = 1.0 / (d as f32).sqrt();
    WORKSPACE.with(|ws| {
        let ws = &mut *ws.borrow_mut();
        grow(&mut ws.logits, ROW_BLOCK * m);
        let logits = &mut ws.logits;
        let mut i0 = 0;
        while i0 < n {
            let ib = ROW_BLOCK.min(n - i0);
            // score block = Q[i0..i0+ib] · K[..cols]^T, one GEMM. Under a
            // causal mask only keys j <= i are ever read, so cap the GEMM at
            // the block's widest row instead of computing the full triangle.
            let cols = if causal { (i0 + ib).min(m) } else { m };
            matmul_nt_into(
                &q[i0 * d..(i0 + ib) * d],
                ib,
                d,
                &k[..cols * d],
                cols,
                &mut logits[..ib * cols],
            );
            for ii in 0..ib {
                let i = i0 + ii;
                let limit = if causal { (i + 1).min(m) } else { m };
                let row = &mut logits[ii * cols..ii * cols + limit];
                let maxl = simd::scale_max(row, scale);
                let mut z = 0.0f32;
                for l in row.iter_mut() {
                    *l = (*l - maxl).exp();
                    z += *l;
                }
                let orow = &mut out[i * dv..(i + 1) * dv];
                orow.fill(0.0);
                for (j, &w) in row.iter().enumerate() {
                    simd::axpy(w, &v[j * dv..(j + 1) * dv], orow);
                }
                simd::div_assign(orow, z);
            }
            i0 += ib;
        }
    });
}

/// Kernelized attention (Definition 2), blocked, any Table-1 kernel.
/// Panics on [`Kernel::Softmax`] (no pointwise kernel weight) — the
/// `attn` session API rejects that combination with a clean error.
pub fn kernelized_attention(
    kernel: Kernel,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (n, d) = (q.shape[0], q.shape[1]);
    let m = k.shape[0];
    assert_eq!(k.shape[1], d);
    assert_eq!(v.shape[0], m);
    let dv = v.shape[1];
    let mut out = Tensor::zeros(&[n, dv]);
    kernelized_attention_into(
        kernel, &q.data, &k.data, &v.data, n, m, d, dv, causal, eps, &mut out.data,
    );
    out
}

/// Slice-level kernelized attention; `out` is (n x dv) row-major.
#[allow(clippy::too_many_arguments)]
pub fn kernelized_attention_into(
    kernel: Kernel,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    causal: bool,
    eps: f32,
    out: &mut [f32],
) {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), m * d);
    assert_eq!(v.len(), m * dv);
    assert_eq!(out.len(), n * dv);
    if causal {
        assert_eq!(n, m, "causal kernelized attention needs n == m");
    }
    let scale = 1.0 / (d as f32).sqrt();
    // resolve the kernel once — not per score element in the hot loop
    let kf = kernel
        .value_fn()
        .expect("kernelized attention requires a Table-1 Maclaurin kernel");
    WORKSPACE.with(|ws| {
        let ws = &mut *ws.borrow_mut();
        grow(&mut ws.logits, ROW_BLOCK * m);
        let scores = &mut ws.logits;
        let mut i0 = 0;
        while i0 < n {
            let ib = ROW_BLOCK.min(n - i0);
            // see softmax_attention_into: cap the GEMM at the causal width
            let cols = if causal { (i0 + ib).min(m) } else { m };
            matmul_nt_into(
                &q[i0 * d..(i0 + ib) * d],
                ib,
                d,
                &k[..cols * d],
                cols,
                &mut scores[..ib * cols],
            );
            for ii in 0..ib {
                let i = i0 + ii;
                let limit = if causal { (i + 1).min(m) } else { m };
                let row = &scores[ii * cols..ii * cols + limit];
                let mut den = 0.0f32;
                let orow = &mut out[i * dv..(i + 1) * dv];
                orow.fill(0.0);
                for (j, &t) in row.iter().enumerate() {
                    let w = kf((t * scale) as f64) as f32;
                    den += w;
                    simd::axpy(w, &v[j * dv..(j + 1) * dv], orow);
                }
                simd::div_assign(orow, den + eps);
            }
            i0 += ib;
        }
    });
}

/// Factored linear contraction: out_i = phi_q_i S / (phi_q_i z + eps).
pub fn linear_attention(
    phi_q: &Tensor,
    phi_k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (n, feat) = (phi_q.shape[0], phi_q.shape[1]);
    let m = phi_k.shape[0];
    assert_eq!(phi_k.shape[1], feat);
    assert_eq!(v.shape[0], m);
    let dv = v.shape[1];
    let mut out = Tensor::zeros(&[n, dv]);
    linear_attention_into(
        &phi_q.data, &phi_k.data, &v.data, n, m, feat, dv, causal, eps, &mut out.data,
    );
    out
}

/// Slice-level linear attention; `out` is (n x dv) row-major. The causal
/// variant requires n == m (one running prefix state).
#[allow(clippy::too_many_arguments)]
pub fn linear_attention_into(
    phi_q: &[f32],
    phi_k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    feat: usize,
    dv: usize,
    causal: bool,
    eps: f32,
    out: &mut [f32],
) {
    assert_eq!(phi_q.len(), n * feat);
    assert_eq!(phi_k.len(), m * feat);
    assert_eq!(v.len(), m * dv);
    assert_eq!(out.len(), n * dv);
    if causal {
        assert_eq!(n, m, "causal linear attention needs n == m");
    }
    WORKSPACE.with(|ws| {
        let ws = &mut *ws.borrow_mut();
        grow(&mut ws.s, feat * dv);
        grow(&mut ws.z, feat);
        let s = &mut ws.s[..feat * dv];
        let z = &mut ws.z[..feat];
        if causal {
            s.fill(0.0);
            z.fill(0.0);
            for i in 0..n {
                let pk = &phi_k[i * feat..(i + 1) * feat];
                let vi = &v[i * dv..(i + 1) * dv];
                for (f, &pkf) in pk.iter().enumerate() {
                    z[f] += pkf;
                    if pkf == 0.0 {
                        continue;
                    }
                    simd::axpy(pkf, vi, &mut s[f * dv..(f + 1) * dv]);
                }
                let pq = &phi_q[i * feat..(i + 1) * feat];
                let mut den = 0.0f32;
                let orow = &mut out[i * dv..(i + 1) * dv];
                orow.fill(0.0);
                for (f, &pqf) in pq.iter().enumerate() {
                    den += pqf * z[f];
                    if pqf == 0.0 {
                        continue;
                    }
                    simd::axpy(pqf, &s[f * dv..(f + 1) * dv], orow);
                }
                simd::div_assign(orow, den + eps);
            }
        } else {
            // S = phi_k^T v (feat x dv) via the dispatched rank-1-update
            // GEMM and z = colsum(phi_k) — same accumulation order over
            // keys as the fused reference loop.
            matmul_tn_into(phi_k, m, feat, v, dv, s);
            z.fill(0.0);
            for j in 0..m {
                simd::axpy(1.0, &phi_k[j * feat..(j + 1) * feat], z);
            }
            for i in 0..n {
                let pq = &phi_q[i * feat..(i + 1) * feat];
                let den = simd::dot(pq, z);
                let orow = &mut out[i * dv..(i + 1) * dv];
                orow.fill(0.0);
                for (f, &pqf) in pq.iter().enumerate() {
                    if pqf == 0.0 {
                        continue;
                    }
                    simd::axpy(pqf, &s[f * dv..(f + 1) * dv], orow);
                }
                simd::div_assign(orow, den + eps);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reference::attention as oracle;
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
        Tensor::randn(rng, shape, scale)
    }

    #[test]
    fn softmax_matches_oracle_including_row_block_boundary() {
        let mut rng = Rng::new(21);
        // n = 70 crosses two ROW_BLOCK boundaries
        for causal in [false, true] {
            let q = randn(&mut rng, &[70, 8], 0.8);
            let k = randn(&mut rng, &[70, 8], 0.8);
            let v = randn(&mut rng, &[70, 5], 1.0);
            let a = oracle::softmax_attention(&q, &k, &v, causal);
            let b = softmax_attention(&q, &k, &v, causal);
            assert!(a.max_abs_diff(&b) < 1e-5, "causal={causal}: {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn kernelized_matches_oracle_all_kernels() {
        let mut rng = Rng::new(22);
        // n = 70 crosses two ROW_BLOCK boundaries, exercising the causal
        // cols-capped score stride
        for kernel in Kernel::MACLAURIN {
            for causal in [false, true] {
                let q = randn(&mut rng, &[70, 4], 0.4);
                let k = randn(&mut rng, &[70, 4], 0.4);
                let v = randn(&mut rng, &[70, 3], 1.0);
                let a = oracle::kernelized_attention(kernel, &q, &k, &v, causal, 1e-6);
                let b = kernelized_attention(kernel, &q, &k, &v, causal, 1e-6);
                assert!(
                    a.max_abs_diff(&b) < 1e-5,
                    "{kernel} causal={causal}: {}",
                    a.max_abs_diff(&b)
                );
            }
        }
    }

    #[test]
    fn linear_matches_oracle_nonsquare() {
        let mut rng = Rng::new(23);
        let phi_q = randn(&mut rng, &[7, 6], 1.0).map(f32::abs);
        let phi_k = randn(&mut rng, &[7, 6], 1.0).map(f32::abs);
        let v = randn(&mut rng, &[7, 2], 1.0);
        for causal in [false, true] {
            let a = oracle::linear_attention(&phi_q, &phi_k, &v, causal, 1e-6);
            let b = linear_attention(&phi_q, &phi_k, &v, causal, 1e-6);
            assert!(a.max_abs_diff(&b) < 1e-5, "causal={causal}: {}", a.max_abs_diff(&b));
        }
    }

    /// The workspace is shared across shapes within a thread: running a
    /// big problem, then a small one, then the big one again must give
    /// identical results (no stale-buffer bleed).
    #[test]
    fn workspace_reuse_across_shapes_is_stateless() {
        let mut rng = Rng::new(24);
        let qb = randn(&mut rng, &[40, 6], 0.6);
        let kb = randn(&mut rng, &[40, 6], 0.6);
        let vb = randn(&mut rng, &[40, 4], 1.0);
        let qs = randn(&mut rng, &[3, 2], 0.6);
        let ks = randn(&mut rng, &[3, 2], 0.6);
        let vs = randn(&mut rng, &[3, 7], 1.0);
        for causal in [false, true] {
            let first = softmax_attention(&qb, &kb, &vb, causal);
            let _ = softmax_attention(&qs, &ks, &vs, causal);
            let again = softmax_attention(&qb, &kb, &vb, causal);
            assert_eq!(first.data, again.data, "softmax causal={causal}");

            let pqb = qb.map(f32::abs);
            let pkb = kb.map(f32::abs);
            let first = linear_attention(&pqb, &pkb, &vb, causal, 1e-6);
            let _ = linear_attention(&qs.map(f32::abs), &ks.map(f32::abs), &vs, causal, 1e-6);
            let again = linear_attention(&pqb, &pkb, &vb, causal, 1e-6);
            assert_eq!(first.data, again.data, "linear causal={causal}");
        }
    }
}
