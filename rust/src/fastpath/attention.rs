//! Cache-aware single-problem attention kernels.
//!
//! Same math as `reference::attention` (which stays the oracle), but:
//! score matrices come from the register-blocked `matmul_nt_into` GEMM
//! instead of per-row scalar dots, rows are processed in blocks so the
//! logits working set stays L1/L2-resident, and every inner loop walks
//! contiguous memory. All functions also exist as `_into` variants over
//! raw slices so the parallel driver can shard one batched tensor into
//! per-problem sub-slices without copies.

use crate::attn::Kernel;
use crate::tensor::{matmul_nt_into, Tensor};

/// Rows of the score matrix materialized at a time: 32 rows x n=4096
/// cols of f32 is 512 KiB, comfortably L2-resident.
const ROW_BLOCK: usize = 32;

/// Exact softmax attention, blocked: out = softmax(q k^T / sqrt(d)) v.
pub fn softmax_attention(q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Tensor {
    let (n, d) = (q.shape[0], q.shape[1]);
    let m = k.shape[0];
    assert_eq!(k.shape[1], d);
    assert_eq!(v.shape[0], m);
    let dv = v.shape[1];
    let mut out = Tensor::zeros(&[n, dv]);
    softmax_attention_into(&q.data, &k.data, &v.data, n, m, d, dv, causal, &mut out.data);
    out
}

/// Slice-level exact softmax attention; `out` is (n x dv) row-major.
#[allow(clippy::too_many_arguments)]
pub fn softmax_attention_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    causal: bool,
    out: &mut [f32],
) {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), m * d);
    assert_eq!(v.len(), m * dv);
    assert_eq!(out.len(), n * dv);
    if causal {
        // same contract as the reference oracle (which indexes keys up
        // to row i and has no defined causal semantics for n != m)
        assert_eq!(n, m, "causal softmax attention needs n == m");
    }
    let scale = 1.0 / (d as f32).sqrt();
    let mut logits = vec![0.0f32; ROW_BLOCK * m];
    let mut i0 = 0;
    while i0 < n {
        let ib = ROW_BLOCK.min(n - i0);
        // score block = Q[i0..i0+ib] · K[..cols]^T, one GEMM. Under a
        // causal mask only keys j <= i are ever read, so cap the GEMM at
        // the block's widest row instead of computing the full triangle.
        let cols = if causal { (i0 + ib).min(m) } else { m };
        matmul_nt_into(
            &q[i0 * d..(i0 + ib) * d],
            ib,
            d,
            &k[..cols * d],
            cols,
            &mut logits[..ib * cols],
        );
        for ii in 0..ib {
            let i = i0 + ii;
            let limit = if causal { (i + 1).min(m) } else { m };
            let row = &mut logits[ii * cols..ii * cols + limit];
            let mut maxl = f32::NEG_INFINITY;
            for l in row.iter_mut() {
                *l *= scale;
                maxl = maxl.max(*l);
            }
            let mut z = 0.0f32;
            for l in row.iter_mut() {
                *l = (*l - maxl).exp();
                z += *l;
            }
            let orow = &mut out[i * dv..(i + 1) * dv];
            orow.fill(0.0);
            for (j, &w) in row.iter().enumerate() {
                let vj = &v[j * dv..(j + 1) * dv];
                for (o, x) in orow.iter_mut().zip(vj) {
                    *o += w * x;
                }
            }
            for o in orow.iter_mut() {
                *o /= z;
            }
        }
        i0 += ib;
    }
}

/// Kernelized attention (Definition 2), blocked, any Table-1 kernel.
/// Panics on [`Kernel::Softmax`] (no pointwise kernel weight) — the
/// `attn` session API rejects that combination with a clean error.
pub fn kernelized_attention(
    kernel: Kernel,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (n, d) = (q.shape[0], q.shape[1]);
    let m = k.shape[0];
    assert_eq!(k.shape[1], d);
    assert_eq!(v.shape[0], m);
    let dv = v.shape[1];
    let mut out = Tensor::zeros(&[n, dv]);
    kernelized_attention_into(
        kernel, &q.data, &k.data, &v.data, n, m, d, dv, causal, eps, &mut out.data,
    );
    out
}

/// Slice-level kernelized attention; `out` is (n x dv) row-major.
#[allow(clippy::too_many_arguments)]
pub fn kernelized_attention_into(
    kernel: Kernel,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    causal: bool,
    eps: f32,
    out: &mut [f32],
) {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), m * d);
    assert_eq!(v.len(), m * dv);
    assert_eq!(out.len(), n * dv);
    if causal {
        assert_eq!(n, m, "causal kernelized attention needs n == m");
    }
    let scale = 1.0 / (d as f32).sqrt();
    // resolve the kernel once — not per score element in the hot loop
    let kf = kernel
        .value_fn()
        .expect("kernelized attention requires a Table-1 Maclaurin kernel");
    let mut scores = vec![0.0f32; ROW_BLOCK * m];
    let mut i0 = 0;
    while i0 < n {
        let ib = ROW_BLOCK.min(n - i0);
        // see softmax_attention_into: cap the GEMM at the causal width
        let cols = if causal { (i0 + ib).min(m) } else { m };
        matmul_nt_into(
            &q[i0 * d..(i0 + ib) * d],
            ib,
            d,
            &k[..cols * d],
            cols,
            &mut scores[..ib * cols],
        );
        for ii in 0..ib {
            let i = i0 + ii;
            let limit = if causal { (i + 1).min(m) } else { m };
            let row = &scores[ii * cols..ii * cols + limit];
            let mut den = 0.0f32;
            let orow = &mut out[i * dv..(i + 1) * dv];
            orow.fill(0.0);
            for (j, &t) in row.iter().enumerate() {
                let w = kf((t * scale) as f64) as f32;
                den += w;
                let vj = &v[j * dv..(j + 1) * dv];
                for (o, x) in orow.iter_mut().zip(vj) {
                    *o += w * x;
                }
            }
            let denom = den + eps;
            for o in orow.iter_mut() {
                *o /= denom;
            }
        }
        i0 += ib;
    }
}

/// Factored linear contraction: out_i = phi_q_i S / (phi_q_i z + eps).
pub fn linear_attention(
    phi_q: &Tensor,
    phi_k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (n, feat) = (phi_q.shape[0], phi_q.shape[1]);
    let m = phi_k.shape[0];
    assert_eq!(phi_k.shape[1], feat);
    assert_eq!(v.shape[0], m);
    let dv = v.shape[1];
    let mut out = Tensor::zeros(&[n, dv]);
    linear_attention_into(
        &phi_q.data, &phi_k.data, &v.data, n, m, feat, dv, causal, eps, &mut out.data,
    );
    out
}

/// Slice-level linear attention; `out` is (n x dv) row-major. The causal
/// variant requires n == m (one running prefix state).
#[allow(clippy::too_many_arguments)]
pub fn linear_attention_into(
    phi_q: &[f32],
    phi_k: &[f32],
    v: &[f32],
    n: usize,
    m: usize,
    feat: usize,
    dv: usize,
    causal: bool,
    eps: f32,
    out: &mut [f32],
) {
    assert_eq!(phi_q.len(), n * feat);
    assert_eq!(phi_k.len(), m * feat);
    assert_eq!(v.len(), m * dv);
    assert_eq!(out.len(), n * dv);
    if causal {
        assert_eq!(n, m, "causal linear attention needs n == m");
        let mut s = vec![0.0f32; feat * dv];
        let mut z = vec![0.0f32; feat];
        for i in 0..n {
            let pk = &phi_k[i * feat..(i + 1) * feat];
            let vi = &v[i * dv..(i + 1) * dv];
            for (f, &pkf) in pk.iter().enumerate() {
                z[f] += pkf;
                if pkf == 0.0 {
                    continue;
                }
                let srow = &mut s[f * dv..(f + 1) * dv];
                for (acc, x) in srow.iter_mut().zip(vi) {
                    *acc += pkf * x;
                }
            }
            let pq = &phi_q[i * feat..(i + 1) * feat];
            let mut den = 0.0f32;
            let orow = &mut out[i * dv..(i + 1) * dv];
            orow.fill(0.0);
            for (f, &pqf) in pq.iter().enumerate() {
                den += pqf * z[f];
                if pqf == 0.0 {
                    continue;
                }
                let srow = &s[f * dv..(f + 1) * dv];
                for (o, x) in orow.iter_mut().zip(srow) {
                    *o += pqf * x;
                }
            }
            let denom = den + eps;
            for o in orow.iter_mut() {
                *o /= denom;
            }
        }
    } else {
        // S = phi_k^T v (feat x dv) and z = colsum(phi_k), one fused
        // pass of contiguous rank-1 updates.
        let mut s = vec![0.0f32; feat * dv];
        let mut z = vec![0.0f32; feat];
        for j in 0..m {
            let pk = &phi_k[j * feat..(j + 1) * feat];
            let vj = &v[j * dv..(j + 1) * dv];
            for (f, &pkf) in pk.iter().enumerate() {
                z[f] += pkf;
                if pkf == 0.0 {
                    continue;
                }
                let srow = &mut s[f * dv..(f + 1) * dv];
                for (acc, x) in srow.iter_mut().zip(vj) {
                    *acc += pkf * x;
                }
            }
        }
        for i in 0..n {
            let pq = &phi_q[i * feat..(i + 1) * feat];
            let den: f32 = pq.iter().zip(&z).map(|(a, b)| a * b).sum();
            let orow = &mut out[i * dv..(i + 1) * dv];
            orow.fill(0.0);
            for (f, &pqf) in pq.iter().enumerate() {
                if pqf == 0.0 {
                    continue;
                }
                let srow = &s[f * dv..(f + 1) * dv];
                for (o, x) in orow.iter_mut().zip(srow) {
                    *o += pqf * x;
                }
            }
            let denom = den + eps;
            for o in orow.iter_mut() {
                *o /= denom;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::reference::attention as oracle;
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
        Tensor::randn(rng, shape, scale)
    }

    #[test]
    fn softmax_matches_oracle_including_row_block_boundary() {
        let mut rng = Rng::new(21);
        // n = 70 crosses two ROW_BLOCK boundaries
        for causal in [false, true] {
            let q = randn(&mut rng, &[70, 8], 0.8);
            let k = randn(&mut rng, &[70, 8], 0.8);
            let v = randn(&mut rng, &[70, 5], 1.0);
            let a = oracle::softmax_attention(&q, &k, &v, causal);
            let b = softmax_attention(&q, &k, &v, causal);
            assert!(a.max_abs_diff(&b) < 1e-5, "causal={causal}: {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn kernelized_matches_oracle_all_kernels() {
        let mut rng = Rng::new(22);
        // n = 70 crosses two ROW_BLOCK boundaries, exercising the causal
        // cols-capped score stride
        for kernel in Kernel::MACLAURIN {
            for causal in [false, true] {
                let q = randn(&mut rng, &[70, 4], 0.4);
                let k = randn(&mut rng, &[70, 4], 0.4);
                let v = randn(&mut rng, &[70, 3], 1.0);
                let a = oracle::kernelized_attention(kernel, &q, &k, &v, causal, 1e-6);
                let b = kernelized_attention(kernel, &q, &k, &v, causal, 1e-6);
                assert!(
                    a.max_abs_diff(&b) < 1e-5,
                    "{kernel} causal={causal}: {}",
                    a.max_abs_diff(&b)
                );
            }
        }
    }

    #[test]
    fn linear_matches_oracle_nonsquare() {
        let mut rng = Rng::new(23);
        let phi_q = randn(&mut rng, &[7, 6], 1.0).map(f32::abs);
        let phi_k = randn(&mut rng, &[7, 6], 1.0).map(f32::abs);
        let v = randn(&mut rng, &[7, 2], 1.0);
        for causal in [false, true] {
            let a = oracle::linear_attention(&phi_q, &phi_k, &v, causal, 1e-6);
            let b = linear_attention(&phi_q, &phi_k, &v, causal, 1e-6);
            assert!(a.max_abs_diff(&b) < 1e-5, "causal={causal}: {}", a.max_abs_diff(&b));
        }
    }
}
