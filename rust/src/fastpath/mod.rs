//! The host fast compute path: cache-aware, data-parallel, SIMD-capable
//! versions of the three hot kernels (RMF feature map, softmax
//! attention, linear attention) behind the Fig-4 micro-benchmarks and
//! the hotpath bench.
//!
//! Tier structure (the contract every later backend follows):
//!
//! * **oracle tier** — `crate::reference`: scalar, single-problem,
//!   obviously-correct mirrors of the paper's math. It may receive
//!   memory-layout fixes (e.g. the non-causal `S` contraction walking
//!   rows instead of columns) but is never blocked, tiled, or threaded.
//! * **fast tier** — this module: same math, engineered for throughput,
//!   and *proved against the oracle* by the equivalence tests in
//!   `tests/fastpath_equiv.rs`. The fast tier itself has two
//!   runtime-dispatched arms (see [`simd`]): the **scalar arm**
//!   (`FlatRmfMap::apply` bit-for-bit, attention kernels within 1e-5)
//!   and the **AVX2+FMA arm** (everything within 1e-5; lane-parallel
//!   accumulation reassociates floating-point addition). Set
//!   `MACFORMER_NO_SIMD=1` to pin the scalar arm.
//!
//! Pieces:
//! * [`simd`] — the runtime feature detection + the 8-lane f32
//!   microkernels (GEMM tiles, row updates, normalize passes) with
//!   always-available scalar twins.
//! * [`flat_rmf::FlatRmfMap`] — degree-grouped feature map: phi(X) as a
//!   short sequence of GEMMs + running elementwise products.
//! * [`attention`] — blocked single-problem kernels over raw slices
//!   (GEMM score blocks, contiguous inner loops, thread-local grow-only
//!   scratch: steady-state calls never allocate).
//! * [`parallel`] — the persistent worker pool sharding batch x head
//!   problems over cores (created once per process, channel-free
//!   claim-based dispatch, no per-call allocation); batched entry
//!   points for all three kernels, over tensors and raw slices.
//!
//! This tier backs `attn::HostFastBackend`; new code should run
//! attention through `attn::AttentionSpec` rather than calling these
//! entry points directly.

pub mod attention;
pub mod flat_rmf;
pub mod parallel;
pub mod simd;

/// Grow `buf` to at least `len` without ever shrinking — the one
/// scratch-buffer idiom behind the zero-alloc steady-state contract
/// (capacity is retained across calls, so repeated use of the largest
/// shape seen never reallocates). Shared by the kernel workspaces and
/// the session scratch arena.
pub(crate) fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

pub use flat_rmf::FlatRmfMap;
pub use parallel::{
    apply_map_batched, kernelized_attention_batched, linear_attention_batched,
    softmax_attention_batched,
};
