//! The host fast compute path: cache-aware, data-parallel versions of
//! the three hot kernels (RMF feature map, softmax attention, linear
//! attention) behind the Fig-4 micro-benchmarks and the hotpath bench.
//!
//! Two-tier structure (the contract every later backend follows):
//!
//! * **oracle tier** — `crate::reference`: scalar, single-problem,
//!   obviously-correct mirrors of the paper's math. It may receive
//!   memory-layout fixes (e.g. the non-causal `S` contraction walking
//!   rows instead of columns) but is never blocked, tiled, or threaded.
//! * **fast tier** — this module: same math, engineered for throughput,
//!   and *proved against the oracle* by the equivalence tests in
//!   `tests/fastpath_equiv.rs` (`FlatRmfMap::apply` bit-for-bit,
//!   attention kernels within 1e-5).
//!
//! Pieces:
//! * [`flat_rmf::FlatRmfMap`] — degree-grouped feature map: phi(X) as a
//!   short sequence of GEMMs + running elementwise products.
//! * [`attention`] — blocked single-problem kernels over raw slices
//!   (GEMM score blocks, contiguous inner loops).
//! * [`parallel`] — `std::thread::scope` driver sharding batch x head
//!   problems over cores; batched entry points for all three kernels.
//!
//! This tier backs `attn::HostFastBackend`; new code should run
//! attention through `attn::AttentionSpec` rather than calling these
//! entry points directly.

pub mod attention;
pub mod flat_rmf;
pub mod parallel;

pub use flat_rmf::FlatRmfMap;
pub use parallel::{
    apply_map_batched, kernelized_attention_batched, linear_attention_batched,
    softmax_attention_batched,
};
