//! Scoped-thread parallel driver for batched (batch x head) problems.
//!
//! A batched attention workload is `g = batch * heads` independent
//! problems over one flat `(g, n, d)` tensor. The driver splits the
//! output buffer into per-problem chunks with `split_at_mut` (no
//! unsafe, no copies, no extra deps) and shards contiguous problem
//! ranges across `std::thread::scope` workers. Each problem is computed
//! by exactly the same single-thread kernel code, so parallel results
//! are identical to sequential ones.
//!
//! Thread count: `MACFORMER_THREADS` if set, else
//! `std::thread::available_parallelism()`.

use std::thread;

use crate::attn::Kernel;
use crate::tensor::Tensor;

use super::attention;
use super::flat_rmf::FlatRmfMap;

/// Worker count for the parallel driver.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("MACFORMER_THREADS") {
        if let Ok(x) = s.parse::<usize>() {
            if x >= 1 {
                return x;
            }
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(problem_index, out_chunk)` for each of `count` problems, where
/// `out` is `count * out_stride` long and chunk `i` is the sub-slice
/// `[i * out_stride, (i + 1) * out_stride)`. Problems are sharded as
/// contiguous ranges over scoped threads; with one worker (or one
/// problem) everything runs on the calling thread.
pub fn for_each_problem<F>(count: usize, out: &mut [f32], out_stride: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), count * out_stride, "for_each_problem: out len");
    if count == 0 {
        return;
    }
    if out_stride == 0 {
        for g in 0..count {
            f(g, &mut []);
        }
        return;
    }
    let threads = num_threads().min(count);
    if threads <= 1 {
        for (g, chunk) in out.chunks_mut(out_stride).enumerate() {
            f(g, chunk);
        }
        return;
    }
    thread::scope(|scope| {
        let mut rem: &mut [f32] = out;
        let mut start = 0usize;
        for t in 0..threads {
            // balanced contiguous split: remaining / remaining-threads
            let cnt = (count - start) / (threads - t);
            let (head, tail) = rem.split_at_mut(cnt * out_stride);
            rem = tail;
            let fref = &f;
            scope.spawn(move || {
                for (off, chunk) in head.chunks_mut(out_stride).enumerate() {
                    fref(start + off, chunk);
                }
            });
            start += cnt;
        }
    });
}

fn batched_dims(t: &Tensor, what: &str) -> (usize, usize, usize) {
    assert_eq!(t.rank(), 3, "{what}: expected (g, n, d) layout");
    (t.shape[0], t.shape[1], t.shape[2])
}

/// Exact softmax attention over `(g, n, d)` q/k and `(g, n, dv)` v.
pub fn softmax_attention_batched(q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Tensor {
    let (g, n, d) = batched_dims(q, "softmax_attention_batched q");
    let (gk, m, dk) = batched_dims(k, "softmax_attention_batched k");
    let (gv, mv, dv) = batched_dims(v, "softmax_attention_batched v");
    assert_eq!((g, d), (gk, dk), "q/k disagree");
    assert_eq!((g, m), (gv, mv), "k/v disagree");
    let mut out = Tensor::zeros(&[g, n, dv]);
    for_each_problem(g, &mut out.data, n * dv, |gi, chunk| {
        attention::softmax_attention_into(
            &q.data[gi * n * d..(gi + 1) * n * d],
            &k.data[gi * m * d..(gi + 1) * m * d],
            &v.data[gi * m * dv..(gi + 1) * m * dv],
            n,
            m,
            d,
            dv,
            causal,
            chunk,
        );
    });
    out
}

/// Kernelized attention over batched tensors (see [`softmax_attention_batched`]).
pub fn kernelized_attention_batched(
    kernel: Kernel,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (g, n, d) = batched_dims(q, "kernelized_attention_batched q");
    let (gk, m, dk) = batched_dims(k, "kernelized_attention_batched k");
    let (gv, mv, dv) = batched_dims(v, "kernelized_attention_batched v");
    assert_eq!((g, d), (gk, dk), "q/k disagree");
    assert_eq!((g, m), (gv, mv), "k/v disagree");
    let mut out = Tensor::zeros(&[g, n, dv]);
    for_each_problem(g, &mut out.data, n * dv, |gi, chunk| {
        attention::kernelized_attention_into(
            kernel,
            &q.data[gi * n * d..(gi + 1) * n * d],
            &k.data[gi * m * d..(gi + 1) * m * d],
            &v.data[gi * m * dv..(gi + 1) * m * dv],
            n,
            m,
            d,
            dv,
            causal,
            eps,
            chunk,
        );
    });
    out
}

/// Linear attention over `(g, n, D)` phi_q/phi_k and `(g, n, dv)` v.
pub fn linear_attention_batched(
    phi_q: &Tensor,
    phi_k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (g, n, feat) = batched_dims(phi_q, "linear_attention_batched phi_q");
    let (gk, m, fk) = batched_dims(phi_k, "linear_attention_batched phi_k");
    let (gv, mv, dv) = batched_dims(v, "linear_attention_batched v");
    assert_eq!((g, feat), (gk, fk), "phi_q/phi_k disagree");
    assert_eq!((g, m), (gv, mv), "phi_k/v disagree");
    let mut out = Tensor::zeros(&[g, n, dv]);
    for_each_problem(g, &mut out.data, n * dv, |gi, chunk| {
        attention::linear_attention_into(
            &phi_q.data[gi * n * feat..(gi + 1) * n * feat],
            &phi_k.data[gi * m * feat..(gi + 1) * m * feat],
            &v.data[gi * m * dv..(gi + 1) * m * dv],
            n,
            m,
            feat,
            dv,
            causal,
            eps,
            chunk,
        );
    });
    out
}

/// phi over a batched `(g, n, d)` tensor -> `(g, n, D)`, one problem per
/// shard (each problem is itself a short GEMM sequence).
pub fn apply_map_batched(map: &FlatRmfMap, x: &Tensor) -> Tensor {
    let (g, n, d) = batched_dims(x, "apply_map_batched x");
    assert_eq!(d, map.dim_in, "input dim vs map dim");
    let feat = map.num_features();
    let mut out = Tensor::zeros(&[g, n, feat]);
    for_each_problem(g, &mut out.data, n * feat, |gi, chunk| {
        map.apply_into(&x.data[gi * n * d..(gi + 1) * n * d], n, chunk);
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn3(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
        Tensor::randn(rng, shape, scale)
    }

    #[test]
    fn for_each_problem_covers_every_chunk_once() {
        let count = 13;
        let stride = 7;
        let mut out = vec![0.0f32; count * stride];
        for_each_problem(count, &mut out, stride, |g, chunk| {
            for (i, c) in chunk.iter_mut().enumerate() {
                *c = (g * stride + i) as f32;
            }
        });
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i as f32);
        }
    }

    #[test]
    fn for_each_problem_edge_cases() {
        // zero problems
        for_each_problem(0, &mut [], 5, |_, _| panic!("must not run"));
        // one problem
        let mut one = vec![0.0f32; 3];
        for_each_problem(1, &mut one, 3, |g, chunk| {
            assert_eq!(g, 0);
            chunk.fill(1.0);
        });
        assert_eq!(one, vec![1.0; 3]);
    }

    #[test]
    fn batched_equals_sequential_per_problem() {
        let mut rng = Rng::new(31);
        let (g, n, d, dv) = (5, 9, 4, 3);
        let q = randn3(&mut rng, &[g, n, d], 0.7);
        let k = randn3(&mut rng, &[g, n, d], 0.7);
        let v = randn3(&mut rng, &[g, n, dv], 1.0);
        let batched = softmax_attention_batched(&q, &k, &v, false);
        for gi in 0..g {
            let single =
                attention::softmax_attention(&q.problem2(gi), &k.problem2(gi), &v.problem2(gi), false);
            for (a, b) in batched.data[gi * n * dv..(gi + 1) * n * dv]
                .iter()
                .zip(&single.data)
            {
                assert_eq!(a, b, "problem {gi} differs between batched and single");
            }
        }
    }
}
