//! Persistent worker-pool parallel driver for batched (batch x head)
//! problems.
//!
//! A batched attention workload is `g = batch * heads` independent
//! problems over one flat `(g, n, d)` tensor. Earlier revisions spawned
//! a fresh `std::thread::scope` per call; this module keeps a process-
//! wide pool of resident workers instead (created once, on the first
//! parallel call) and feeds them through a claim-based task slot:
//!
//! * the caller publishes one type-erased task (a raw closure pointer)
//!   under the pool mutex and wakes the workers;
//! * workers (and the caller itself) repeatedly claim the next unclaimed
//!   index and run it — [`for_each_index`] is this primitive, and
//!   [`for_each_problem`] layers the disjoint-`out`-chunk contract on
//!   top (the serve scheduler uses the primitive directly to fold
//!   micro-batched decode streams);
//! * the caller blocks until every claimed index has finished before
//!   returning, which is what makes the borrowed-data-behind-raw-
//!   pointers scheme sound (the borrows strictly outlive every worker
//!   access).
//!
//! No boxing, no channels: publishing and claiming are plain mutex ops
//! over POD state, so steady-state batched calls make **zero heap
//! allocations** (enforced by `tests/alloc_free.rs`). Problems are
//! claimed one at a time, which also load-balances ragged problem
//! costs better than the old contiguous range split. Each problem runs
//! exactly the same single-thread kernel code, so parallel results are
//! identical to sequential ones.
//!
//! Thread count: `MACFORMER_THREADS` if set (validated by
//! [`parse_thread_override`]; malformed values warn and fall back, `0`
//! warns and clamps to 1), else `std::thread::available_parallelism()`.
//! The count is resolved once per process (see [`num_threads`]) and the
//! pool is sized from it on first use.
//!
//! Re-entrant / concurrent batched calls are safe: if the task slot is
//! already occupied (another thread mid-batch), the new call simply
//! runs sequentially on its own thread.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Condvar, Mutex, OnceLock};
use std::thread;

use crate::attn::Kernel;
use crate::tensor::Tensor;

use super::attention;
use super::flat_rmf::FlatRmfMap;

/// Outcome of parsing a `MACFORMER_THREADS` override value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ThreadOverride {
    /// A usable worker count (>= 1).
    Count(usize),
    /// `"0"`: zero workers cannot make progress — clamp to 1 (warned).
    ClampedToOne,
    /// Not a number at all — ignore with a warning, use the hardware
    /// default.
    Malformed,
}

/// Validate a raw `MACFORMER_THREADS` value. Pure (no env access, no
/// logging) so the policy is unit-testable; [`num_threads`] applies it
/// and emits the warnings.
pub fn parse_thread_override(raw: &str) -> ThreadOverride {
    match raw.trim().parse::<usize>() {
        Ok(0) => ThreadOverride::ClampedToOne,
        Ok(n) => ThreadOverride::Count(n),
        Err(_) => ThreadOverride::Malformed,
    }
}

/// Worker count for the parallel driver (>= 1, always). Resolved once
/// per process: the pool is sized once anyway, and re-reading the
/// environment (or `available_parallelism`, which probes cgroup files
/// on Linux) on every batched call would allocate inside the
/// steady-state hot path.
pub fn num_threads() -> usize {
    static RESOLVED: OnceLock<usize> = OnceLock::new();
    *RESOLVED.get_or_init(|| {
        let hardware = || thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        match std::env::var("MACFORMER_THREADS") {
            Ok(raw) => match parse_thread_override(&raw) {
                ThreadOverride::Count(n) => n,
                ThreadOverride::ClampedToOne => {
                    log::warn!("MACFORMER_THREADS={raw:?} requests zero workers; clamping to 1");
                    1
                }
                ThreadOverride::Malformed => {
                    let d = hardware();
                    log::warn!(
                        "MACFORMER_THREADS={raw:?} is not a thread count; \
                         using the hardware default of {d}"
                    );
                    d
                }
            },
            Err(_) => hardware(),
        }
    })
}

/// One published batch, type-erased. The pointer borrows the publishing
/// call's stack frame; soundness comes from `for_each_index` blocking
/// until `in_flight == 0` with every index claimed before it returns.
#[derive(Clone, Copy)]
struct Task {
    /// `&F` erased to a thin pointer.
    f: *const (),
    /// Monomorphized trampoline that re-types `f` and runs one index.
    call: unsafe fn(*const (), usize),
    count: usize,
}

// SAFETY: the raw pointer is only dereferenced between publication and
// completion of the owning `for_each_index` call, which outlives every
// worker access by construction (the caller waits on `done`).
unsafe impl Send for Task {}

/// Mutex-protected pool state. `next`/`in_flight` always describe the
/// task currently in `slot`; the slot is cleared by the publishing
/// caller only after `next >= count && in_flight == 0`.
struct PoolState {
    slot: Option<Task>,
    next: usize,
    in_flight: usize,
    /// First shard panic's payload; re-raised on the publishing caller
    /// via `resume_unwind` so the original message survives the pool.
    panic: Option<Box<dyn Any + Send + 'static>>,
}

struct Pool {
    state: Mutex<PoolState>,
    /// Wakes workers when a task is published.
    work: Condvar,
    /// Wakes the publishing caller when the last shard finishes.
    done: Condvar,
    workers: usize,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<&'static Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        // The caller participates in every batch, so resident workers
        // only need to cover the remaining parallelism.
        let workers = num_threads().saturating_sub(1);
        let pool: &'static Pool = Box::leak(Box::new(Pool {
            state: Mutex::new(PoolState {
                slot: None,
                next: 0,
                in_flight: 0,
                panic: None,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            workers,
        }));
        for i in 0..workers {
            thread::Builder::new()
                .name(format!("macformer-pool-{i}"))
                .spawn(move || worker_loop(pool))
                .expect("spawn fastpath pool worker");
        }
        pool
    })
}

/// Claim one problem of `task` (already counted into `in_flight` by the
/// claimant) and run it, catching panics so the pool survives.
fn run_claimed(pool: &Pool, task: Task, index: usize) {
    let result = catch_unwind(AssertUnwindSafe(|| {
        // SAFETY: `index < task.count` was checked under the pool lock
        // and the publishing caller keeps the closure alive until
        // `in_flight` drains.
        unsafe { (task.call)(task.f, index) }
    }));
    let mut st = pool.state.lock().unwrap();
    st.in_flight -= 1;
    if let Err(payload) = result {
        if st.panic.is_none() {
            st.panic = Some(payload);
        }
    }
    if st.in_flight == 0 && st.next >= task.count {
        pool.done.notify_all();
    }
}

fn worker_loop(pool: &'static Pool) {
    crate::serve::obs::register_thread();
    loop {
        let (task, index) = {
            let mut st = pool.state.lock().unwrap();
            loop {
                match st.slot {
                    Some(t) if st.next < t.count => {
                        let i = st.next;
                        st.next += 1;
                        st.in_flight += 1;
                        break (t, i);
                    }
                    _ => st = pool.work.wait(st).unwrap(),
                }
            }
        };
        run_claimed(pool, task, index);
    }
}

/// Run `f(index)` for each index in `0..count`, claiming indices one at
/// a time across the resident pool workers plus the calling thread.
/// This is the pool's primitive: [`for_each_problem`] layers the
/// disjoint-output-chunk contract on top, and the serve scheduler uses
/// it directly to fold a micro-batch of decode streams (each index
/// touching its own stream slot). With one worker (or one index, or a
/// pool already busy with another batch) everything runs sequentially
/// on the calling thread — so `f` must be correct, not merely tolerant,
/// when called from the publishing thread itself.
///
/// Panics in `f` are caught per index so the pool survives; the first
/// panic payload is re-raised on the calling thread after the batch
/// drains. Zero heap allocations: the closure is published by
/// reference, never boxed.
pub fn for_each_index<F>(count: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if count == 0 {
        return;
    }
    let threads = num_threads().min(count);
    let sequential = |f: &F| {
        for i in 0..count {
            f(i);
        }
    };
    if threads <= 1 {
        sequential(&f);
        return;
    }
    let pool = pool();
    if pool.workers == 0 {
        sequential(&f);
        return;
    }

    /// Re-type the erased closure pointer and run one index.
    unsafe fn trampoline<F: Fn(usize) + Sync>(f: *const (), index: usize) {
        let f = &*(f as *const F);
        f(index);
    }

    let task = Task {
        f: &f as *const F as *const (),
        call: trampoline::<F>,
        count,
    };

    // Publish — or fall back to sequential if another batch is mid-air.
    {
        let mut st = pool.state.lock().unwrap();
        if st.slot.is_some() {
            drop(st);
            sequential(&f);
            return;
        }
        debug_assert_eq!(st.in_flight, 0, "stale in_flight with an empty slot");
        st.slot = Some(task);
        st.next = 0;
        st.panic = None;
    }
    pool.work.notify_all();

    // The caller claims problems alongside the workers.
    loop {
        let claimed = {
            let mut st = pool.state.lock().unwrap();
            if st.next < count {
                let i = st.next;
                st.next += 1;
                st.in_flight += 1;
                Some(i)
            } else {
                None
            }
        };
        match claimed {
            Some(i) => run_claimed(pool, task, i),
            None => break,
        }
    }

    // Wait out the stragglers, then retire the task. This wait is what
    // keeps the raw pointers in `task` sound.
    let panic = {
        let mut st = pool.state.lock().unwrap();
        while st.in_flight > 0 {
            st = pool.done.wait(st).unwrap();
        }
        st.slot = None;
        st.panic.take()
    };
    if let Some(payload) = panic {
        // re-raise the first shard panic with its original payload
        resume_unwind(payload);
    }
}

/// A `*mut T` that may cross to the pool workers during a
/// [`for_each_index`] dispatch. Soundness is the caller's contract:
/// every index dereferences a disjoint region behind the pointer, and
/// the underlying exclusive borrow outlives the dispatch call. Used by
/// [`for_each_problem`] for output chunks and by the serve scheduler
/// for per-stream slots.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> SendPtr<T> {
        *self
    }
}

impl<T> Copy for SendPtr<T> {}

// SAFETY: see the struct docs — disjoint per-index access under a live
// exclusive borrow held by the publishing caller.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Run `f(problem_index, out_chunk)` for each of `count` problems, where
/// `out` is `count * out_stride` long and chunk `i` is the sub-slice
/// `[i * out_stride, (i + 1) * out_stride)`. Built on
/// [`for_each_index`]; see there for the claiming, fallback, and panic
/// semantics.
pub fn for_each_problem<F>(count: usize, out: &mut [f32], out_stride: usize, f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(out.len(), count * out_stride, "for_each_problem: out len");
    if out_stride == 0 {
        for g in 0..count {
            f(g, &mut []);
        }
        return;
    }
    let base = SendPtr(out.as_mut_ptr());
    for_each_index(count, |g| {
        // SAFETY: chunks of distinct indices are disjoint, each index is
        // claimed exactly once, and the exclusive borrow of `out` is
        // held across the whole for_each_index call.
        let chunk =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(g * out_stride), out_stride) };
        f(g, chunk);
    });
}

fn batched_dims(t: &Tensor, what: &str) -> (usize, usize, usize) {
    assert_eq!(t.rank(), 3, "{what}: expected (g, n, d) layout");
    (t.shape[0], t.shape[1], t.shape[2])
}

/// Slice-level batched exact softmax attention: `(g, n, d)` q, `(g, m,
/// d)` k, `(g, m, dv)` v, `(g, n, dv)` out, all row-major flat slices.
#[allow(clippy::too_many_arguments)]
pub fn softmax_attention_batched_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    g: usize,
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    causal: bool,
    out: &mut [f32],
) {
    assert_eq!(q.len(), g * n * d, "softmax batched: q len");
    assert_eq!(k.len(), g * m * d, "softmax batched: k len");
    assert_eq!(v.len(), g * m * dv, "softmax batched: v len");
    for_each_problem(g, out, n * dv, |gi, chunk| {
        attention::softmax_attention_into(
            &q[gi * n * d..(gi + 1) * n * d],
            &k[gi * m * d..(gi + 1) * m * d],
            &v[gi * m * dv..(gi + 1) * m * dv],
            n,
            m,
            d,
            dv,
            causal,
            chunk,
        );
    });
}

/// Exact softmax attention over `(g, n, d)` q/k and `(g, n, dv)` v.
pub fn softmax_attention_batched(q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Tensor {
    let (g, n, d) = batched_dims(q, "softmax_attention_batched q");
    let (gk, m, dk) = batched_dims(k, "softmax_attention_batched k");
    let (gv, mv, dv) = batched_dims(v, "softmax_attention_batched v");
    assert_eq!((g, d), (gk, dk), "q/k disagree");
    assert_eq!((g, m), (gv, mv), "k/v disagree");
    let mut out = Tensor::zeros(&[g, n, dv]);
    softmax_attention_batched_into(
        &q.data, &k.data, &v.data, g, n, m, d, dv, causal, &mut out.data,
    );
    out
}

/// Slice-level batched kernelized attention (see
/// [`softmax_attention_batched_into`] for the layout contract).
#[allow(clippy::too_many_arguments)]
pub fn kernelized_attention_batched_into(
    kernel: Kernel,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    g: usize,
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    causal: bool,
    eps: f32,
    out: &mut [f32],
) {
    assert_eq!(q.len(), g * n * d, "kernelized batched: q len");
    assert_eq!(k.len(), g * m * d, "kernelized batched: k len");
    assert_eq!(v.len(), g * m * dv, "kernelized batched: v len");
    for_each_problem(g, out, n * dv, |gi, chunk| {
        attention::kernelized_attention_into(
            kernel,
            &q[gi * n * d..(gi + 1) * n * d],
            &k[gi * m * d..(gi + 1) * m * d],
            &v[gi * m * dv..(gi + 1) * m * dv],
            n,
            m,
            d,
            dv,
            causal,
            eps,
            chunk,
        );
    });
}

/// Kernelized attention over batched tensors (see [`softmax_attention_batched`]).
pub fn kernelized_attention_batched(
    kernel: Kernel,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (g, n, d) = batched_dims(q, "kernelized_attention_batched q");
    let (gk, m, dk) = batched_dims(k, "kernelized_attention_batched k");
    let (gv, mv, dv) = batched_dims(v, "kernelized_attention_batched v");
    assert_eq!((g, d), (gk, dk), "q/k disagree");
    assert_eq!((g, m), (gv, mv), "k/v disagree");
    let mut out = Tensor::zeros(&[g, n, dv]);
    kernelized_attention_batched_into(
        kernel, &q.data, &k.data, &v.data, g, n, m, d, dv, causal, eps, &mut out.data,
    );
    out
}

/// Slice-level batched linear attention over `(g, n, feat)` phi maps and
/// `(g, m, dv)` values.
#[allow(clippy::too_many_arguments)]
pub fn linear_attention_batched_into(
    phi_q: &[f32],
    phi_k: &[f32],
    v: &[f32],
    g: usize,
    n: usize,
    m: usize,
    feat: usize,
    dv: usize,
    causal: bool,
    eps: f32,
    out: &mut [f32],
) {
    assert_eq!(phi_q.len(), g * n * feat, "linear batched: phi_q len");
    assert_eq!(phi_k.len(), g * m * feat, "linear batched: phi_k len");
    assert_eq!(v.len(), g * m * dv, "linear batched: v len");
    for_each_problem(g, out, n * dv, |gi, chunk| {
        attention::linear_attention_into(
            &phi_q[gi * n * feat..(gi + 1) * n * feat],
            &phi_k[gi * m * feat..(gi + 1) * m * feat],
            &v[gi * m * dv..(gi + 1) * m * dv],
            n,
            m,
            feat,
            dv,
            causal,
            eps,
            chunk,
        );
    });
}

/// Linear attention over `(g, n, D)` phi_q/phi_k and `(g, n, dv)` v.
pub fn linear_attention_batched(
    phi_q: &Tensor,
    phi_k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (g, n, feat) = batched_dims(phi_q, "linear_attention_batched phi_q");
    let (gk, m, fk) = batched_dims(phi_k, "linear_attention_batched phi_k");
    let (gv, mv, dv) = batched_dims(v, "linear_attention_batched v");
    assert_eq!((g, feat), (gk, fk), "phi_q/phi_k disagree");
    assert_eq!((g, m), (gv, mv), "phi_k/v disagree");
    let mut out = Tensor::zeros(&[g, n, dv]);
    linear_attention_batched_into(
        &phi_q.data, &phi_k.data, &v.data, g, n, m, feat, dv, causal, eps, &mut out.data,
    );
    out
}

/// Slice-level batched phi: `(g, n, d)` input, `(g, n, D)` output.
pub fn apply_map_batched_into(
    map: &FlatRmfMap,
    x: &[f32],
    g: usize,
    n: usize,
    d: usize,
    out: &mut [f32],
) {
    assert_eq!(d, map.dim_in, "input dim vs map dim");
    assert_eq!(x.len(), g * n * d, "apply_map batched: x len");
    let feat = map.num_features();
    for_each_problem(g, out, n * feat, |gi, chunk| {
        map.apply_into(&x[gi * n * d..(gi + 1) * n * d], n, chunk);
    });
}

/// Row-blocked phi over `rows` independent pre-scaled rows of ONE
/// problem: contiguous row blocks are sharded over the pool (block
/// width scaled to the worker count, capped at 64 rows so every shard
/// is a healthy GEMM instead of `rows` tiny one-row problems — the
/// chunked-prefill feature step). Row `i` of the output is
/// bit-identical to `map.apply_into` of that row alone (`FlatRmfMap`
/// rows are independent), so callers may mix this freely with per-row
/// phi — the prefill/decode bit-compat contract relies on that.
pub fn apply_map_rows_into(map: &FlatRmfMap, x: &[f32], rows: usize, d: usize, out: &mut [f32]) {
    assert_eq!(d, map.dim_in, "input dim vs map dim");
    assert_eq!(x.len(), rows * d, "apply_map rows: x len");
    let feat = map.num_features();
    assert_eq!(out.len(), rows * feat, "apply_map rows: out len");
    if rows == 0 {
        return;
    }
    let block = rows.div_ceil(num_threads()).clamp(1, 64);
    let blocks = rows.div_ceil(block);
    let base = SendPtr(out.as_mut_ptr());
    for_each_index(blocks, |b| {
        let r0 = b * block;
        let rb = block.min(rows - r0);
        // SAFETY: blocks of distinct indices cover disjoint out rows,
        // each index is claimed exactly once, and the exclusive borrow
        // of `out` is held across the whole for_each_index call.
        let chunk =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(r0 * feat), rb * feat) };
        map.apply_into(&x[r0 * d..(r0 + rb) * d], rb, chunk);
    });
}

/// phi over a batched `(g, n, d)` tensor -> `(g, n, D)`, one problem per
/// shard (each problem is itself a short GEMM sequence).
pub fn apply_map_batched(map: &FlatRmfMap, x: &Tensor) -> Tensor {
    let (g, n, d) = batched_dims(x, "apply_map_batched x");
    let feat = map.num_features();
    let mut out = Tensor::zeros(&[g, n, feat]);
    apply_map_batched_into(map, &x.data, g, n, d, &mut out.data);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn3(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
        Tensor::randn(rng, shape, scale)
    }

    #[test]
    fn thread_override_parsing_policy() {
        // malformed values are rejected (the driver falls back + warns)
        assert_eq!(parse_thread_override("abc"), ThreadOverride::Malformed);
        assert_eq!(parse_thread_override(""), ThreadOverride::Malformed);
        assert_eq!(parse_thread_override("-3"), ThreadOverride::Malformed);
        assert_eq!(parse_thread_override("2.5"), ThreadOverride::Malformed);
        // zero is clamped, not silently defaulted
        assert_eq!(parse_thread_override("0"), ThreadOverride::ClampedToOne);
        assert_eq!(parse_thread_override(" 0 "), ThreadOverride::ClampedToOne);
        // honest values pass through, whitespace tolerated
        assert_eq!(parse_thread_override("1"), ThreadOverride::Count(1));
        assert_eq!(parse_thread_override(" 8 "), ThreadOverride::Count(8));
    }

    #[test]
    fn num_threads_is_at_least_one() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn for_each_problem_covers_every_chunk_once() {
        let count = 13;
        let stride = 7;
        let mut out = vec![0.0f32; count * stride];
        for_each_problem(count, &mut out, stride, |g, chunk| {
            for (i, c) in chunk.iter_mut().enumerate() {
                *c = (g * stride + i) as f32;
            }
        });
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i as f32);
        }
    }

    #[test]
    fn for_each_index_claims_every_index_exactly_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let count = 37;
        let hits: Vec<AtomicUsize> = (0..count).map(|_| AtomicUsize::new(0)).collect();
        for_each_index(count, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
        // zero indices: the closure must never run
        for_each_index(0, |_| panic!("must not run"));
    }

    #[test]
    fn for_each_problem_edge_cases() {
        // zero problems
        for_each_problem(0, &mut [], 5, |_, _| panic!("must not run"));
        // one problem
        let mut one = vec![0.0f32; 3];
        for_each_problem(1, &mut one, 3, |g, chunk| {
            assert_eq!(g, 0);
            chunk.fill(1.0);
        });
        assert_eq!(one, vec![1.0; 3]);
    }

    #[test]
    fn pool_survives_repeated_batches() {
        // many small batches through the same resident pool: no worker
        // leaks, no deadlocks, every chunk written every time
        for round in 0..50usize {
            let count = 1 + round % 5;
            let stride = 3;
            let mut out = vec![-1.0f32; count * stride];
            for_each_problem(count, &mut out, stride, |g, chunk| {
                chunk.fill(g as f32 + round as f32);
            });
            for (i, &x) in out.iter().enumerate() {
                assert_eq!(x, (i / stride) as f32 + round as f32, "round {round}");
            }
        }
    }

    #[test]
    fn concurrent_batches_from_many_threads_stay_disjoint() {
        // the slot-busy path must degrade to sequential, never corrupt
        std::thread::scope(|scope| {
            for t in 0..4u32 {
                scope.spawn(move || {
                    for round in 0..20usize {
                        let count = 6;
                        let stride = 5;
                        let mut out = vec![0.0f32; count * stride];
                        for_each_problem(count, &mut out, stride, |g, chunk| {
                            chunk.fill((t as f32) * 1000.0 + g as f32 + round as f32);
                        });
                        for (i, &x) in out.iter().enumerate() {
                            assert_eq!(
                                x,
                                (t as f32) * 1000.0 + (i / stride) as f32 + round as f32,
                                "thread {t} round {round}"
                            );
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn worker_panic_propagates_and_pool_recovers() {
        let count = 8;
        let stride = 2;
        let mut out = vec![0.0f32; count * stride];
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for_each_problem(count, &mut out, stride, |g, chunk| {
                if g == 3 {
                    panic!("shard 3 exploded");
                }
                chunk.fill(g as f32);
            });
        }));
        let payload = r.expect_err("the shard panic must propagate to the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(
            msg.contains("shard 3 exploded"),
            "the original panic payload must be preserved, got {msg:?}"
        );
        // the pool must still serve later batches
        let mut out2 = vec![0.0f32; count * stride];
        for_each_problem(count, &mut out2, stride, |g, chunk| {
            chunk.fill(g as f32);
        });
        for (i, &x) in out2.iter().enumerate() {
            assert_eq!(x, (i / stride) as f32);
        }
    }

    #[test]
    fn row_blocked_phi_is_row_for_row_sequential() {
        use crate::reference::rmf::RmfMap;
        let mut rng = Rng::new(33);
        let map = RmfMap::sample(&mut rng, Kernel::Exp, 20, 5, 2.0, 8);
        let flat = FlatRmfMap::from(&map);
        let feat = flat.num_features();
        // rows crossing the 64-row block cap, plus tiny and empty sets
        for rows in [0usize, 1, 3, 64, 65, 150] {
            let x: Vec<f32> = (0..rows * 5).map(|_| rng.normal() * 0.5).collect();
            let mut blocked = vec![0.0f32; rows * feat];
            apply_map_rows_into(&flat, &x, rows, 5, &mut blocked);
            for r in 0..rows {
                let mut one = vec![0.0f32; feat];
                flat.apply_into(&x[r * 5..(r + 1) * 5], 1, &mut one);
                let row = &blocked[r * feat..(r + 1) * feat];
                for (j, (a, b)) in row.iter().zip(&one).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "rows={rows} row {r} feature {j}");
                }
            }
        }
    }

    #[test]
    fn batched_equals_sequential_per_problem() {
        let mut rng = Rng::new(31);
        let (g, n, d, dv) = (5, 9, 4, 3);
        let q = randn3(&mut rng, &[g, n, d], 0.7);
        let k = randn3(&mut rng, &[g, n, d], 0.7);
        let v = randn3(&mut rng, &[g, n, dv], 1.0);
        let batched = softmax_attention_batched(&q, &k, &v, false);
        for gi in 0..g {
            let single =
                attention::softmax_attention(&q.problem2(gi), &k.problem2(gi), &v.problem2(gi), false);
            for (a, b) in batched.data[gi * n * dv..(gi + 1) * n * dv]
                .iter()
                .zip(&single.data)
            {
                assert_eq!(a, b, "problem {gi} differs between batched and single");
            }
        }
    }
}
