//! Degree-grouped Random Maclaurin Feature map.
//!
//! `reference::rmf::RmfMap` stores each feature's Rademacher directions
//! as `Vec<Vec<Vec<f32>>>` and evaluates phi feature by feature — three
//! levels of pointer chasing per dot product. `FlatRmfMap` re-sorts the
//! sampled features by Maclaurin degree and packs each degree bucket's
//! directions into one contiguous row-major matrix, so `phi(X)` becomes
//! a short sequence of GEMMs (one per distinct degree, at most
//! `max_degree + 1` of them) followed by a running elementwise product
//! over each feature's `degree` contiguous dot products.
//!
//! The layout change is exact, not approximate — on the **scalar
//! dispatch arm**: the blocked GEMM accumulates every dot product in
//! the same order as the reference's `zip(..).sum()`, the degree
//! products multiply in the same direction, and the
//! `scale * prod * sqrt(1/D)` prefactor is the same expression — so
//! `FlatRmfMap::apply` is **bit-for-bit identical** to `RmfMap::apply`
//! there. On the AVX2+FMA arm the GEMM reassociates accumulation, so
//! the map carries the SIMD tier's `1e-5` contract instead (both arms
//! enforced by `tests/fastpath_equiv.rs`; the product pass itself
//! rounds identically on both arms).
//!
//! The per-row dot-product staging buffer is thread-local and
//! grow-only, so steady-state `apply_into` calls never allocate.

use std::cell::RefCell;
use std::collections::BTreeMap;

use crate::reference::rmf::RmfMap;
use crate::tensor::{matmul_nt_into, Tensor};

use super::{grow, simd};

thread_local! {
    /// Grow-only staging buffer for one problem's (n x s*g) dot block.
    static DOTS: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// One degree's worth of features, packed contiguously.
struct DegreeBucket {
    /// Maclaurin degree N shared by every feature in this bucket.
    degree: usize,
    /// Original feature indices (ascending), used to scatter outputs
    /// back into the reference feature order.
    features: Vec<usize>,
    /// `(features.len() * degree) x dim_in` row-major Rademacher
    /// directions, rows grouped feature-major; empty when degree == 0.
    omega: Vec<f32>,
    /// Per feature: `sqrt(a_N p^{N+1})`, in `features` order.
    scales: Vec<f32>,
}

/// Degree-grouped, GEMM-friendly RMF map (same math as [`RmfMap`]).
pub struct FlatRmfMap {
    pub dim_in: usize,
    num_features: usize,
    buckets: Vec<DegreeBucket>,
}

impl From<&RmfMap> for FlatRmfMap {
    fn from(map: &RmfMap) -> FlatRmfMap {
        let mut by_degree: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for (i, &deg) in map.degrees.iter().enumerate() {
            by_degree.entry(deg).or_default().push(i);
        }
        let buckets = by_degree
            .into_iter()
            .map(|(degree, features)| {
                let mut omega = Vec::with_capacity(features.len() * degree * map.dim_in);
                let mut scales = Vec::with_capacity(features.len());
                for &f in &features {
                    scales.push(map.scales[f]);
                    for dir in &map.omega[f] {
                        omega.extend_from_slice(dir);
                    }
                }
                DegreeBucket { degree, features, omega, scales }
            })
            .collect();
        FlatRmfMap {
            dim_in: map.dim_in,
            num_features: map.num_features(),
            buckets,
        }
    }
}

impl FlatRmfMap {
    pub fn num_features(&self) -> usize {
        self.num_features
    }

    /// Number of distinct degrees present (== number of GEMMs per apply).
    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Phi over an (n x dim_in) tensor -> (n x D); bit-for-bit equal to
    /// `RmfMap::apply` on the scalar dispatch arm, within `1e-5` on the
    /// AVX2+FMA arm (see the module docs).
    pub fn apply(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.shape[1], self.dim_in);
        let n = x.shape[0];
        let mut out = Tensor::zeros(&[n, self.num_features]);
        self.apply_into(&x.data, n, &mut out.data);
        out
    }

    /// Slice-level apply for the parallel driver: `x` is (n x dim_in)
    /// row-major, `out` is (n x D) row-major.
    pub fn apply_into(&self, x: &[f32], n: usize, out: &mut [f32]) {
        let feat = self.num_features;
        assert_eq!(x.len(), n * self.dim_in, "apply_into: input len");
        assert_eq!(out.len(), n * feat, "apply_into: output len");
        // Same prefactor expression as RmfMap::apply_row — kept textually
        // identical so the scalar arm stays bit-for-bit the same.
        let d = feat as f32;
        let inv = (1.0 / d).sqrt();
        DOTS.with(|cell| {
            let dots = &mut *cell.borrow_mut();
            for bucket in &self.buckets {
                let s = bucket.features.len();
                let g = bucket.degree;
                if g == 0 {
                    // Degree-0 features are input-independent constants.
                    for i in 0..n {
                        let row = &mut out[i * feat..(i + 1) * feat];
                        for (j, &f) in bucket.features.iter().enumerate() {
                            let prod = 1.0f32;
                            row[f] = bucket.scales[j] * prod * inv;
                        }
                    }
                    continue;
                }
                // One GEMM: (n x dim_in) · (s*g x dim_in)^T -> (n x s*g).
                // Feature j's g dot products land contiguously at columns
                // [j*g, (j+1)*g). Grow-only thread-local scratch:
                // matmul_nt_into writes every element, so no zero-fill
                // between buckets (or between calls).
                grow(dots, n * s * g);
                matmul_nt_into(x, n, self.dim_in, &bucket.omega, s * g, &mut dots[..n * s * g]);
                for i in 0..n {
                    let drow = &dots[i * s * g..(i + 1) * s * g];
                    let row = &mut out[i * feat..(i + 1) * feat];
                    simd::bucket_products(drow, g, &bucket.scales, inv, &bucket.features, row);
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::Kernel;
    use crate::util::rng::Rng;

    #[test]
    fn conversion_preserves_feature_count_and_groups_degrees() {
        let mut rng = Rng::new(11);
        let map = RmfMap::sample(&mut rng, Kernel::Exp, 64, 8, 2.0, 8);
        let flat = FlatRmfMap::from(&map);
        assert_eq!(flat.num_features(), 64);
        let distinct: std::collections::BTreeSet<usize> =
            map.degrees.iter().copied().collect();
        assert_eq!(flat.num_buckets(), distinct.len());
    }

    #[test]
    fn apply_matches_reference_smoke_both_arms() {
        let mut rng = Rng::new(12);
        for kernel in [Kernel::Exp, Kernel::Inv, Kernel::Sqrt] {
            let map = RmfMap::sample(&mut rng, kernel, 48, 6, 2.0, 8);
            let flat = FlatRmfMap::from(&map);
            let mut x = Tensor::zeros(&[5, 6]);
            for v in x.data.iter_mut() {
                *v = rng.normal() * 0.5;
            }
            let a = map.apply(&x);
            let b = flat.apply(&x);
            assert_eq!(a.shape, b.shape);
            // scalar arm: bit-for-bit; SIMD arm: the 1e-5 tier contract
            let simd_arm = crate::fastpath::simd::active();
            for (i, (p, q)) in a.data.iter().zip(&b.data).enumerate() {
                if simd_arm {
                    assert!(
                        (p - q).abs() < 1e-5 * p.abs().max(1.0),
                        "{kernel}: feature value {i} drifts: {p} vs {q}"
                    );
                } else {
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "{kernel}: feature value {i} differs: {p} vs {q}"
                    );
                }
            }
        }
    }
}
