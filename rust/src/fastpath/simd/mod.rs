//! The runtime-dispatched SIMD compute tier.
//!
//! Every primitive here exists twice:
//!
//! * a `_scalar` twin — the plain loop the rest of the crate used before
//!   this module existed, kept textually equivalent so the scalar arm of
//!   the fastpath stays **bit-for-bit** what it always was;
//! * an AVX2+FMA microkernel in [`x86`] (8-lane f32, `x86_64` only),
//!   selected at runtime via `is_x86_feature_detected!` — never at
//!   compile time, so one binary runs correctly on every host.
//!
//! The public entry points (`axpy`, `dot`, `scale_max`, …) dispatch
//! between the two. Dispatch is resolved once per process and cached:
//! the SIMD arm is taken iff the CPU reports AVX2 **and** FMA and
//! `MACFORMER_NO_SIMD` is unset (set it to force the scalar arm for
//! debugging — see PERF.md).
//!
//! # The two-arm equivalence contract
//!
//! SIMD reassociates floating-point accumulation (8 partial sums + a
//! horizontal reduce instead of one sequential chain), so the fastpath
//! equivalence contract splits:
//!
//! * **scalar arm** — `FlatRmfMap::apply` bit-for-bit equal to
//!   `RmfMap::apply`, attention kernels within `1e-5` of the oracle
//!   (unchanged from before this tier existed);
//! * **SIMD arm** — everything within `1e-5` of the scalar arm (and by
//!   the triangle inequality, of the oracle).
//!
//! Both arms are enforced by `tests/fastpath_equiv.rs`, and CI runs the
//! equivalence suite once per arm (`MACFORMER_NO_SIMD=1` and unset).

#[cfg(target_arch = "x86_64")]
pub mod x86;

use std::sync::atomic::{AtomicU8, Ordering};

/// Dispatch cache: 0 = unresolved, 1 = scalar, 2 = vector.
static STATE: AtomicU8 = AtomicU8::new(0);
const SCALAR: u8 = 1;
const VECTOR: u8 = 2;

/// True when the running CPU can execute the AVX2+FMA microkernels,
/// regardless of the `MACFORMER_NO_SIMD` override.
#[cfg(target_arch = "x86_64")]
pub fn supported() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

/// True when the running CPU can execute the AVX2+FMA microkernels
/// (never, on non-x86_64 targets).
#[cfg(not(target_arch = "x86_64"))]
pub fn supported() -> bool {
    false
}

/// Is the SIMD arm active? Resolved once per process on first use:
/// `supported()` and `MACFORMER_NO_SIMD` unset (or `"0"`/empty). The
/// result is cached, so flipping the env var mid-process has no effect —
/// use [`set_active`] for in-process arm switching (benches).
pub fn active() -> bool {
    match STATE.load(Ordering::Relaxed) {
        VECTOR => true,
        SCALAR => false,
        _ => {
            let on = supported() && !no_simd_env();
            STATE.store(if on { VECTOR } else { SCALAR }, Ordering::Relaxed);
            on
        }
    }
}

fn no_simd_env() -> bool {
    matches!(std::env::var("MACFORMER_NO_SIMD"), Ok(v) if !v.is_empty() && v != "0")
}

/// Force the dispatch arm for this process (benches time both arms in
/// one run; tests pin an arm). Forcing the vector arm on a host without
/// AVX2+FMA stays scalar. Returns the arm actually in effect
/// (`true` = vector). Global: do not call concurrently with compute.
pub fn set_active(on: bool) -> bool {
    let arm = if on && supported() { VECTOR } else { SCALAR };
    STATE.store(arm, Ordering::Relaxed);
    arm == VECTOR
}

/// Drop any cached/forced arm; the next [`active`] call re-resolves from
/// the CPU and `MACFORMER_NO_SIMD`.
pub fn reset() {
    STATE.store(0, Ordering::Relaxed);
}

/// `y += alpha * x` (lengths must match) — the row-update primitive
/// behind every value contraction in the fastpath.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: active() implies AVX2+FMA were detected on this CPU.
        unsafe { x86::axpy(alpha, x, y) };
        return;
    }
    axpy_scalar(alpha, x, y);
}

/// Scalar arm of [`axpy`] — the exact pre-SIMD loop.
pub fn axpy_scalar(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (o, xv) in y.iter_mut().zip(x) {
        *o += alpha * xv;
    }
}

/// Dot product of two equal-length rows.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len(), "dot: length mismatch");
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: active() implies AVX2+FMA were detected on this CPU.
        return unsafe { x86::dot(x, y) };
    }
    dot_scalar(x, y)
}

/// Scalar arm of [`dot`] — the exact pre-SIMD expression.
pub fn dot_scalar(x: &[f32], y: &[f32]) -> f32 {
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// `row *= scale` in place; returns the post-scale maximum (or
/// `f32::NEG_INFINITY` for an empty row) — the softmax pre-pass.
pub fn scale_max(row: &mut [f32], scale: f32) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: active() implies AVX2+FMA were detected on this CPU.
        return unsafe { x86::scale_max(row, scale) };
    }
    scale_max_scalar(row, scale)
}

/// Scalar arm of [`scale_max`] — the exact pre-SIMD loop.
pub fn scale_max_scalar(row: &mut [f32], scale: f32) -> f32 {
    let mut maxl = f32::NEG_INFINITY;
    for l in row.iter_mut() {
        *l *= scale;
        maxl = maxl.max(*l);
    }
    maxl
}

/// `row /= denom` in place — the attention normalize pass (real
/// division, not a reciprocal multiply, to preserve accuracy).
pub fn div_assign(row: &mut [f32], denom: f32) {
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: active() implies AVX2+FMA were detected on this CPU.
        unsafe { x86::div_assign(row, denom) };
        return;
    }
    div_assign_scalar(row, denom);
}

/// Scalar arm of [`div_assign`].
pub fn div_assign_scalar(row: &mut [f32], denom: f32) {
    for o in row.iter_mut() {
        *o /= denom;
    }
}

/// `dst = src * scale` elementwise (lengths must match) — the
/// score-scale input pass of the session forward path. Elementwise
/// multiply rounds identically in both arms, so this primitive is
/// bit-for-bit across dispatch.
pub fn scaled_copy(src: &[f32], scale: f32, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len(), "scaled_copy: length mismatch");
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: active() implies AVX2+FMA were detected on this CPU.
        unsafe { x86::scaled_copy(src, scale, dst) };
        return;
    }
    scaled_copy_scalar(src, scale, dst);
}

/// Scalar arm of [`scaled_copy`].
pub fn scaled_copy_scalar(src: &[f32], scale: f32, dst: &mut [f32]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d = s * scale;
    }
}

/// `out[f] += sum_p x[p * cols + f]` over `rows` row-major rows, where
/// `cols = out.len()` — the column-sum accumulate behind the linear-
/// attention normalizer `z = colsum(phi_k)`. Rows are folded in order
/// on both arms and each per-element add rounds identically, so this
/// primitive is **bit-for-bit** across dispatch (the chunked causal
/// prefill relies on that for its `z` state advance).
pub fn colsum(x: &[f32], rows: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * out.len(), "colsum: length mismatch");
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: active() implies AVX2+FMA were detected on this CPU.
        unsafe { x86::colsum(x, rows, out) };
        return;
    }
    colsum_scalar(x, rows, out);
}

/// Scalar arm of [`colsum`] — the exact accumulation the pre-SIMD
/// `m`-sequential-`axpy` loop performed (`1.0 * x` is exact).
pub fn colsum_scalar(x: &[f32], rows: usize, out: &mut [f32]) {
    let cols = out.len();
    for p in 0..rows {
        let row = &x[p * cols..(p + 1) * cols];
        for (o, xv) in out.iter_mut().zip(row) {
            *o += xv;
        }
    }
}

/// Lower-triangular masked accumulate — the intra-chunk causal
/// correction of the chunked prefill. `scores` is a `c x c` block of
/// raw phi-dot weights; for each row `ii` the weights `jj <= ii` are
/// folded into `den[ii]` and `out[ii * dv ..] += w * v[jj * dv ..]`.
/// The strictly-upper triangle of `scores` is never read (future
/// positions stay masked). `den` accumulates scalar adds in identical
/// order on both arms; the row updates are the dispatched [`axpy`]
/// loop, so the vector arm carries the usual `1e-5` contract.
pub fn tril_accum(
    scores: &[f32],
    c: usize,
    v: &[f32],
    dv: usize,
    out: &mut [f32],
    den: &mut [f32],
) {
    debug_assert_eq!(scores.len(), c * c, "tril_accum: scores length");
    debug_assert_eq!(v.len(), c * dv, "tril_accum: v length");
    debug_assert_eq!(out.len(), c * dv, "tril_accum: out length");
    debug_assert_eq!(den.len(), c, "tril_accum: den length");
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: active() implies AVX2+FMA were detected on this CPU.
        unsafe { x86::tril_accum(scores, c, v, dv, out, den) };
        return;
    }
    tril_accum_scalar(scores, c, v, dv, out, den);
}

/// Scalar arm of [`tril_accum`] — the masked weight-fold written as
/// plain loops.
pub fn tril_accum_scalar(
    scores: &[f32],
    c: usize,
    v: &[f32],
    dv: usize,
    out: &mut [f32],
    den: &mut [f32],
) {
    for ii in 0..c {
        let orow = &mut out[ii * dv..(ii + 1) * dv];
        for jj in 0..=ii {
            let w = scores[ii * c + jj];
            den[ii] += w;
            axpy_scalar(w, &v[jj * dv..(jj + 1) * dv], orow);
        }
    }
}

/// One row's degree-bucket pass of the RMF feature map: for each of the
/// bucket's `s = scales.len()` features (shared degree `g >= 1`),
/// multiply its `g` contiguous dot products out of `dots` (laid out
/// feature-major, `s * g` long) and scatter
/// `scales[j] * prod * inv` into `row[features[j]]`.
///
/// Given identical `dots`, both arms round identically (the product
/// chain multiplies in the same order); the arms only diverge through
/// the GEMM that produced `dots`.
pub fn bucket_products(
    dots: &[f32],
    g: usize,
    scales: &[f32],
    inv: f32,
    features: &[usize],
    row: &mut [f32],
) {
    debug_assert_eq!(dots.len(), scales.len() * g, "bucket_products: dots length");
    debug_assert_eq!(features.len(), scales.len(), "bucket_products: features length");
    #[cfg(target_arch = "x86_64")]
    if active() {
        // SAFETY: active() implies AVX2+FMA were detected on this CPU.
        unsafe { x86::bucket_products(dots, g, scales, inv, features, row) };
        return;
    }
    bucket_products_scalar(dots, g, scales, inv, features, row);
}

/// Scalar arm of [`bucket_products`] — the exact pre-SIMD loop.
pub fn bucket_products_scalar(
    dots: &[f32],
    g: usize,
    scales: &[f32],
    inv: f32,
    features: &[usize],
    row: &mut [f32],
) {
    for (j, &f) in features.iter().enumerate() {
        let mut prod = 1.0f32;
        for &d in &dots[j * g..(j + 1) * g] {
            prod *= d;
        }
        row[f] = scales[j] * prod * inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn scalar_arms_match_reference_semantics() {
        let mut rng = Rng::new(41);
        let x = fill(&mut rng, 13);
        let mut y = fill(&mut rng, 13);
        let mut expect = y.clone();
        for (o, xv) in expect.iter_mut().zip(&x) {
            *o += 0.37 * xv;
        }
        axpy_scalar(0.37, &x, &mut y);
        assert_eq!(y, expect);

        let d = dot_scalar(&x, &y);
        let dref: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        assert_eq!(d.to_bits(), dref.to_bits());

        let mut row = fill(&mut rng, 9);
        let mut row2 = row.clone();
        let m = scale_max_scalar(&mut row, 0.5);
        let mut mref = f32::NEG_INFINITY;
        for l in row2.iter_mut() {
            *l *= 0.5;
            mref = mref.max(*l);
        }
        assert_eq!(row, row2);
        assert_eq!(m, mref);
        assert_eq!(scale_max_scalar(&mut [], 2.0), f32::NEG_INFINITY);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn vector_arms_match_scalar_within_tolerance() {
        if !supported() {
            return; // nothing to compare on this host
        }
        let mut rng = Rng::new(42);
        // cross the 8-lane boundary and exercise the tails
        for n in [1usize, 3, 7, 8, 9, 16, 31, 70] {
            let x = fill(&mut rng, n);
            let y0 = fill(&mut rng, n);

            let mut ys = y0.clone();
            axpy_scalar(0.81, &x, &mut ys);
            let mut yv = y0.clone();
            // SAFETY: supported() checked above.
            unsafe { x86::axpy(0.81, &x, &mut yv) };
            for (a, b) in ys.iter().zip(&yv) {
                assert!((a - b).abs() < 1e-5, "axpy n={n}: {a} vs {b}");
            }

            let ds = dot_scalar(&x, &y0);
            // SAFETY: supported() checked above.
            let dv = unsafe { x86::dot(&x, &y0) };
            assert!((ds - dv).abs() < 1e-4 * ds.abs().max(1.0), "dot n={n}: {ds} vs {dv}");

            let mut rs = x.clone();
            let ms = scale_max_scalar(&mut rs, 0.25);
            let mut rv = x.clone();
            // SAFETY: supported() checked above.
            let mv = unsafe { x86::scale_max(&mut rv, 0.25) };
            assert_eq!(rs, rv, "scale n={n}");
            assert_eq!(ms, mv, "max n={n}");

            let mut qs = x.clone();
            div_assign_scalar(&mut qs, 1.7);
            let mut qv = x.clone();
            // SAFETY: supported() checked above.
            unsafe { x86::div_assign(&mut qv, 1.7) };
            assert_eq!(qs, qv, "div n={n}");

            let mut cs = vec![0.0f32; n];
            scaled_copy_scalar(&x, 0.3, &mut cs);
            let mut cv = vec![0.0f32; n];
            // SAFETY: supported() checked above.
            unsafe { x86::scaled_copy(&x, 0.3, &mut cv) };
            assert_eq!(cs, cv, "scaled_copy n={n}");
        }
    }

    #[test]
    fn colsum_scalar_matches_sequential_axpy_ones() {
        // satellite contract: the dedicated colsum reproduces the old
        // m-sequential-axpy(1.0, ..) accumulation bit for bit
        let mut rng = Rng::new(44);
        for (rows, cols) in [(1usize, 1usize), (3, 7), (5, 8), (4, 19)] {
            let x = fill(&mut rng, rows * cols);
            let mut expect = fill(&mut rng, cols);
            let mut got = expect.clone();
            for p in 0..rows {
                axpy_scalar(1.0, &x[p * cols..(p + 1) * cols], &mut expect);
            }
            colsum_scalar(&x, rows, &mut got);
            for (i, (a, b)) in got.iter().zip(&expect).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "({rows},{cols}) col {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn tril_accum_scalar_matches_explicit_masked_sums() {
        let mut rng = Rng::new(45);
        for (c, dv) in [(1usize, 1usize), (3, 4), (5, 9), (8, 3)] {
            let scores = fill(&mut rng, c * c);
            let v = fill(&mut rng, c * dv);
            let mut out = fill(&mut rng, c * dv);
            let mut den = fill(&mut rng, c);
            let (out0, den0) = (out.clone(), den.clone());
            tril_accum_scalar(&scores, c, &v, dv, &mut out, &mut den);
            for ii in 0..c {
                let mut dref = den0[ii];
                let mut oref = out0[ii * dv..(ii + 1) * dv].to_vec();
                for jj in 0..=ii {
                    let w = scores[ii * c + jj];
                    dref += w;
                    for (o, x) in oref.iter_mut().zip(&v[jj * dv..(jj + 1) * dv]) {
                        *o += w * x;
                    }
                }
                assert_eq!(den[ii].to_bits(), dref.to_bits(), "({c},{dv}) den {ii}");
                for (x, y) in out[ii * dv..(ii + 1) * dv].iter().zip(&oref) {
                    assert_eq!(x.to_bits(), y.to_bits(), "({c},{dv}) row {ii}");
                }
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn vector_colsum_and_tril_match_scalar() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(46);
        for (rows, cols) in [(1usize, 1usize), (3, 8), (5, 7), (6, 23)] {
            let x = fill(&mut rng, rows * cols);
            let base = fill(&mut rng, cols);
            let mut s = base.clone();
            colsum_scalar(&x, rows, &mut s);
            let mut vctr = base.clone();
            // SAFETY: supported() checked above.
            unsafe { x86::colsum(&x, rows, &mut vctr) };
            for (i, (a, b)) in s.iter().zip(&vctr).enumerate() {
                // lane adds round like scalar adds: bit-for-bit
                assert_eq!(a.to_bits(), b.to_bits(), "colsum ({rows},{cols}) col {i}");
            }
        }
        for (c, dv) in [(1usize, 1usize), (4, 8), (5, 11), (9, 16)] {
            let scores = fill(&mut rng, c * c);
            let v = fill(&mut rng, c * dv);
            let out0 = fill(&mut rng, c * dv);
            let den0 = fill(&mut rng, c);
            let (mut out_s, mut den_s) = (out0.clone(), den0.clone());
            tril_accum_scalar(&scores, c, &v, dv, &mut out_s, &mut den_s);
            let (mut out_v, mut den_v) = (out0.clone(), den0.clone());
            // SAFETY: supported() checked above.
            unsafe { x86::tril_accum(&scores, c, &v, dv, &mut out_v, &mut den_v) };
            for (i, (a, b)) in den_s.iter().zip(&den_v).enumerate() {
                // den accumulates in identical scalar order on both arms
                assert_eq!(a.to_bits(), b.to_bits(), "tril den ({c},{dv}) row {i}");
            }
            for (i, (a, b)) in out_s.iter().zip(&out_v).enumerate() {
                assert!((a - b).abs() < 1e-5, "tril out ({c},{dv}) elem {i}: {a} vs {b}");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn vector_bucket_products_match_scalar() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(43);
        for g in 1usize..5 {
            for s in [1usize, 2, 7, 8, 9, 17] {
                let dots = fill(&mut rng, s * g);
                let scales = fill(&mut rng, s);
                // scattered, strictly ascending feature slots
                let features: Vec<usize> = (0..s).map(|j| j * 2 + 1).collect();
                let width = 2 * s + 1;
                let mut row_s = vec![0.0f32; width];
                bucket_products_scalar(&dots, g, &scales, 0.5, &features, &mut row_s);
                let mut row_v = vec![0.0f32; width];
                // SAFETY: supported() checked above.
                unsafe { x86::bucket_products(&dots, g, &scales, 0.5, &features, &mut row_v) };
                for (i, (a, b)) in row_s.iter().zip(&row_v).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "g={g} s={s} slot {i}: {a} vs {b}");
                }
            }
        }
    }

    // NOTE: set_active / reset are process-global, so flipping them here
    // would race with sibling unit tests that read the dispatch state.
    // Their round-trip behavior is covered by `tests/simd_dispatch.rs`,
    // which owns its whole test binary.
}
