//! AVX2+FMA microkernels (`x86_64` only) — the vector arm of the
//! [`simd`](super) dispatch.
//!
//! Every function is an `unsafe fn` carrying
//! `#[target_feature(enable = "avx2,fma")]`: the compiler may emit VEX
//! instructions freely inside, and the caller promises (via
//! [`super::active`] / [`super::supported`]) that the running CPU
//! reports both features. Layout contracts (lengths, row-major strides)
//! are asserted eagerly so a bad caller fails loudly rather than reading
//! out of bounds.
//!
//! Accumulation strategy: 8 f32 lanes per register, FMA for every
//! multiply-add chain, scalar tails for the `len % 8` remainder. The
//! lane-parallel partial sums reassociate addition relative to the
//! scalar arm — that is exactly why the SIMD arm carries a `1e-5`
//! equivalence contract instead of bit-for-bit (see the module docs).

#![allow(clippy::missing_safety_doc)] // one shared contract, documented above

use std::arch::x86_64::*;

/// Horizontal sum of the 8 lanes.
#[inline]
#[target_feature(enable = "avx2,fma")]
unsafe fn hsum(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let s = _mm_add_ps(lo, hi);
    let shuf = _mm_movehdup_ps(s);
    let sums = _mm_add_ps(s, shuf);
    let shuf2 = _mm_movehl_ps(shuf, sums);
    _mm_cvtss_f32(_mm_add_ss(sums, shuf2))
}

/// Horizontal max of the 8 lanes.
#[inline]
#[target_feature(enable = "avx2,fma")]
unsafe fn hmax(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let m4 = _mm_max_ps(lo, hi);
    let m2 = _mm_max_ps(m4, _mm_movehl_ps(m4, m4));
    let m1 = _mm_max_ss(m2, _mm_shuffle_ps::<1>(m2, m2));
    _mm_cvtss_f32(m1)
}

/// `out = A · B^T`: A is (m x k), B is (n x k), out is (m x n), all
/// row-major. 1x4 register tile of dot products, each vectorized over k
/// with FMA; column and k tails run scalar.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn matmul_nt(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "simd matmul_nt: lhs len");
    assert_eq!(b.len(), n * k, "simd matmul_nt: rhs len");
    assert_eq!(out.len(), m * n, "simd matmul_nt: out len");
    let kv = k - k % 8;
    for i in 0..m {
        let arow = a.as_ptr().add(i * k);
        let orow = out.as_mut_ptr().add(i * n);
        let mut j = 0;
        while j + 4 <= n {
            let b0 = b.as_ptr().add(j * k);
            let b1 = b.as_ptr().add((j + 1) * k);
            let b2 = b.as_ptr().add((j + 2) * k);
            let b3 = b.as_ptr().add((j + 3) * k);
            let mut acc0 = _mm256_setzero_ps();
            let mut acc1 = _mm256_setzero_ps();
            let mut acc2 = _mm256_setzero_ps();
            let mut acc3 = _mm256_setzero_ps();
            let mut p = 0;
            while p < kv {
                let av = _mm256_loadu_ps(arow.add(p));
                acc0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b0.add(p)), acc0);
                acc1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b1.add(p)), acc1);
                acc2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b2.add(p)), acc2);
                acc3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(b3.add(p)), acc3);
                p += 8;
            }
            let mut d0 = hsum(acc0);
            let mut d1 = hsum(acc1);
            let mut d2 = hsum(acc2);
            let mut d3 = hsum(acc3);
            while p < k {
                let av = *arow.add(p);
                d0 += av * *b0.add(p);
                d1 += av * *b1.add(p);
                d2 += av * *b2.add(p);
                d3 += av * *b3.add(p);
                p += 1;
            }
            *orow.add(j) = d0;
            *orow.add(j + 1) = d1;
            *orow.add(j + 2) = d2;
            *orow.add(j + 3) = d3;
            j += 4;
        }
        while j < n {
            let brow = b.as_ptr().add(j * k);
            let mut acc = _mm256_setzero_ps();
            let mut p = 0;
            while p < kv {
                acc = _mm256_fmadd_ps(
                    _mm256_loadu_ps(arow.add(p)),
                    _mm256_loadu_ps(brow.add(p)),
                    acc,
                );
                p += 8;
            }
            let mut d = hsum(acc);
            while p < k {
                d += *arow.add(p) * *brow.add(p);
                p += 1;
            }
            *orow.add(j) = d;
            j += 1;
        }
    }
}

/// `out = A^T · B`: A is (r x m), B is (r x n), out is (m x n), all
/// row-major, accumulated rank-1 update by rank-1 update (every stream
/// contiguous); each update row is vectorized over n with FMA.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn matmul_tn(a: &[f32], r: usize, m: usize, b: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(out.len(), m * n, "simd matmul_tn: out len");
    out.fill(0.0);
    matmul_tn_accum(a, r, m, b, n, out);
}

/// Accumulating form of [`matmul_tn`] (`out += A^T · B`, no zero-fill).
/// Each rank-1 update row is exactly the [`axpy`] loop, applied in `r`
/// order — so accumulating a chunk of rows into a running state is
/// bit-identical to folding them one `axpy` at a time on this arm.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn matmul_tn_accum(a: &[f32], r: usize, m: usize, b: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), r * m, "simd matmul_tn: lhs len");
    assert_eq!(b.len(), r * n, "simd matmul_tn: rhs len");
    assert_eq!(out.len(), m * n, "simd matmul_tn: out len");
    let nv = n - n % 8;
    for p in 0..r {
        let arow = &a[p * m..(p + 1) * m];
        let brow = b.as_ptr().add(p * n);
        for (f, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let dst = out.as_mut_ptr().add(f * n);
            let avv = _mm256_set1_ps(av);
            let mut c = 0;
            while c < nv {
                let cur = _mm256_loadu_ps(dst.add(c));
                _mm256_storeu_ps(
                    dst.add(c),
                    _mm256_fmadd_ps(avv, _mm256_loadu_ps(brow.add(c)), cur),
                );
                c += 8;
            }
            while c < n {
                *dst.add(c) += av * *brow.add(c);
                c += 1;
            }
        }
    }
}

/// `y += alpha * x` (lengths must match).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "simd axpy: length mismatch");
    let n = x.len();
    let nv = n - n % 8;
    let av = _mm256_set1_ps(alpha);
    let xp = x.as_ptr();
    let yp = y.as_mut_ptr();
    let mut c = 0;
    while c < nv {
        let cur = _mm256_loadu_ps(yp.add(c));
        _mm256_storeu_ps(yp.add(c), _mm256_fmadd_ps(av, _mm256_loadu_ps(xp.add(c)), cur));
        c += 8;
    }
    while c < n {
        *yp.add(c) += alpha * *xp.add(c);
        c += 1;
    }
}

/// Dot product of two equal-length rows.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len(), "simd dot: length mismatch");
    let n = x.len();
    let nv = n - n % 8;
    let xp = x.as_ptr();
    let yp = y.as_ptr();
    let mut acc = _mm256_setzero_ps();
    let mut c = 0;
    while c < nv {
        acc = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(c)), _mm256_loadu_ps(yp.add(c)), acc);
        c += 8;
    }
    let mut d = hsum(acc);
    while c < n {
        d += *xp.add(c) * *yp.add(c);
        c += 1;
    }
    d
}

/// `row *= scale` in place; returns the post-scale maximum
/// (`f32::NEG_INFINITY` for an empty row).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn scale_max(row: &mut [f32], scale: f32) -> f32 {
    let n = row.len();
    let nv = n - n % 8;
    let sv = _mm256_set1_ps(scale);
    let mut mv = _mm256_set1_ps(f32::NEG_INFINITY);
    let p = row.as_mut_ptr();
    let mut c = 0;
    while c < nv {
        let v = _mm256_mul_ps(_mm256_loadu_ps(p.add(c)), sv);
        _mm256_storeu_ps(p.add(c), v);
        mv = _mm256_max_ps(mv, v);
        c += 8;
    }
    let mut maxl = hmax(mv);
    while c < n {
        let v = *p.add(c) * scale;
        *p.add(c) = v;
        maxl = maxl.max(v);
        c += 1;
    }
    maxl
}

/// `row /= denom` in place (real division, matching the scalar arm).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn div_assign(row: &mut [f32], denom: f32) {
    let n = row.len();
    let nv = n - n % 8;
    let dv = _mm256_set1_ps(denom);
    let p = row.as_mut_ptr();
    let mut c = 0;
    while c < nv {
        _mm256_storeu_ps(p.add(c), _mm256_div_ps(_mm256_loadu_ps(p.add(c)), dv));
        c += 8;
    }
    while c < n {
        *p.add(c) /= denom;
        c += 1;
    }
}

/// `dst = src * scale` elementwise (lengths must match).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn scaled_copy(src: &[f32], scale: f32, dst: &mut [f32]) {
    assert_eq!(src.len(), dst.len(), "simd scaled_copy: length mismatch");
    let n = src.len();
    let nv = n - n % 8;
    let sv = _mm256_set1_ps(scale);
    let sp = src.as_ptr();
    let dp = dst.as_mut_ptr();
    let mut c = 0;
    while c < nv {
        _mm256_storeu_ps(dp.add(c), _mm256_mul_ps(_mm256_loadu_ps(sp.add(c)), sv));
        c += 8;
    }
    while c < n {
        *dp.add(c) = *sp.add(c) * scale;
        c += 1;
    }
}

/// `out[f] += sum_p x[p * cols + f]` over `rows` row-major rows
/// (`cols = out.len()`) — the column-sum accumulate behind the linear-
/// attention `z` normalizer. Rows are folded in order and every lane
/// add rounds exactly like the scalar add, so this primitive is
/// **bit-for-bit** across dispatch arms (like `scaled_copy`).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn colsum(x: &[f32], rows: usize, out: &mut [f32]) {
    let cols = out.len();
    assert_eq!(x.len(), rows * cols, "simd colsum: input len");
    let cv = cols - cols % 8;
    let op = out.as_mut_ptr();
    for p in 0..rows {
        let row = x.as_ptr().add(p * cols);
        let mut c = 0;
        while c < cv {
            _mm256_storeu_ps(
                op.add(c),
                _mm256_add_ps(_mm256_loadu_ps(op.add(c)), _mm256_loadu_ps(row.add(c))),
            );
            c += 8;
        }
        while c < cols {
            *op.add(c) += *row.add(c);
            c += 1;
        }
    }
}

/// Lower-triangular masked accumulate (see [`super::tril_accum`]): for
/// each row `ii`, fold the weights `scores[ii * c + jj]` for `jj <= ii`
/// into `den[ii]` (scalar adds, same order as the scalar twin) and
/// `out[ii] += w * v[jj]` (each row update is the [`axpy`] loop,
/// vectorized over `dv` with FMA).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn tril_accum(
    scores: &[f32],
    c: usize,
    v: &[f32],
    dv: usize,
    out: &mut [f32],
    den: &mut [f32],
) {
    assert_eq!(scores.len(), c * c, "simd tril_accum: scores len");
    assert_eq!(v.len(), c * dv, "simd tril_accum: v len");
    assert_eq!(out.len(), c * dv, "simd tril_accum: out len");
    assert_eq!(den.len(), c, "simd tril_accum: den len");
    let nv = dv - dv % 8;
    for ii in 0..c {
        let orow = out.as_mut_ptr().add(ii * dv);
        for jj in 0..=ii {
            let w = scores[ii * c + jj];
            den[ii] += w;
            let vrow = v.as_ptr().add(jj * dv);
            let wv = _mm256_set1_ps(w);
            let mut x = 0;
            while x < nv {
                let cur = _mm256_loadu_ps(orow.add(x));
                _mm256_storeu_ps(
                    orow.add(x),
                    _mm256_fmadd_ps(wv, _mm256_loadu_ps(vrow.add(x)), cur),
                );
                x += 8;
            }
            while x < dv {
                *orow.add(x) += w * *vrow.add(x);
                x += 1;
            }
        }
    }
}

/// Degree-bucket running products (see [`super::bucket_products`]):
/// 8 features at a time, their strided first dots fetched with an AVX2
/// gather, the remaining `g - 1` dots folded in gather by gather. The
/// product chain multiplies in the same order as the scalar arm, so
/// given identical `dots` the results are bit-identical; only the GEMM
/// feeding `dots` differs between arms.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn bucket_products(
    dots: &[f32],
    g: usize,
    scales: &[f32],
    inv: f32,
    features: &[usize],
    row: &mut [f32],
) {
    let s = scales.len();
    assert!(g >= 1, "simd bucket_products: degree-0 buckets are handled by the caller");
    assert_eq!(dots.len(), s * g, "simd bucket_products: dots len");
    assert_eq!(features.len(), s, "simd bucket_products: features len");
    let gi = g as i32;
    let step = _mm256_setr_epi32(0, gi, 2 * gi, 3 * gi, 4 * gi, 5 * gi, 6 * gi, 7 * gi);
    let invv = _mm256_set1_ps(inv);
    let base = dots.as_ptr();
    let sv = s - s % 8;
    let mut tmp = [0.0f32; 8];
    let mut j = 0;
    while j < sv {
        let idx = _mm256_add_epi32(_mm256_set1_epi32((j * g) as i32), step);
        let mut prod = _mm256_i32gather_ps::<4>(base, idx);
        for t in 1..g {
            prod = _mm256_mul_ps(prod, _mm256_i32gather_ps::<4>(base.add(t), idx));
        }
        let res = _mm256_mul_ps(
            _mm256_mul_ps(_mm256_loadu_ps(scales.as_ptr().add(j)), prod),
            invv,
        );
        _mm256_storeu_ps(tmp.as_mut_ptr(), res);
        for (u, &val) in tmp.iter().enumerate() {
            row[features[j + u]] = val;
        }
        j += 8;
    }
    while j < s {
        let mut prod = 1.0f32;
        for &d in &dots[j * g..(j + 1) * g] {
            prod *= d;
        }
        row[features[j]] = scales[j] * prod * inv;
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::super::supported;
    use super::*;
    use crate::tensor::{matmul_nt_scalar_into, matmul_tn_scalar_into};
    use crate::util::rng::Rng;

    fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn matmul_nt_matches_scalar_kernel() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(51);
        for (m, k, n) in [(1, 1, 1), (3, 5, 4), (7, 9, 11), (4, 16, 8), (2, 70, 5)] {
            let a = fill(&mut rng, m * k);
            let b = fill(&mut rng, n * k);
            let mut scalar = vec![0.0f32; m * n];
            matmul_nt_scalar_into(&a, m, k, &b, n, &mut scalar);
            let mut vector = vec![f32::NAN; m * n];
            // SAFETY: supported() checked above.
            unsafe { matmul_nt(&a, m, k, &b, n, &mut vector) };
            for (i, (x, y)) in scalar.iter().zip(&vector).enumerate() {
                assert!(
                    (x - y).abs() < 1e-4 * x.abs().max(1.0),
                    "({m},{k},{n}) elem {i}: scalar {x} vs simd {y}"
                );
            }
        }
    }

    #[test]
    fn matmul_tn_matches_scalar_kernel() {
        if !supported() {
            return;
        }
        let mut rng = Rng::new(52);
        for (r, m, n) in [(1, 1, 1), (4, 3, 5), (9, 2, 17), (6, 6, 8), (13, 5, 70)] {
            let a = fill(&mut rng, r * m);
            let b = fill(&mut rng, r * n);
            let mut scalar = vec![0.0f32; m * n];
            matmul_tn_scalar_into(&a, r, m, &b, n, &mut scalar);
            let mut vector = vec![f32::NAN; m * n];
            // SAFETY: supported() checked above.
            unsafe { matmul_tn(&a, r, m, &b, n, &mut vector) };
            for (i, (x, y)) in scalar.iter().zip(&vector).enumerate() {
                assert!(
                    (x - y).abs() < 1e-4 * x.abs().max(1.0),
                    "({r},{m},{n}) elem {i}: scalar {x} vs simd {y}"
                );
            }
        }
    }
}
