//! # Macformer — Random Maclaurin Feature Attention, reproduced
//!
//! A three-layer reproduction of *"Macformer: Transformer with Random
//! Maclaurin Feature Attention"* (Guo, Ding, Yuan, Wang, 2024):
//!
//! * **L1** — Pallas kernels (RMF projection, linear-attention
//!   contraction, online-softmax baseline) under `python/compile/kernels/`.
//! * **L2** — the JAX Macformer/Transformer/RFA model family under
//!   `python/compile/`, AOT-lowered to HLO text artifacts.
//! * **L3** — this crate: the Rust coordinator that owns datasets,
//!   batching, the training loop over PJRT, metrics, checkpoints, the
//!   Table-2 sweep orchestrator and the Fig-3/Fig-4 benchmark harnesses.
//!
//! Python never runs at training/serving time; after `make artifacts`
//! the `macformer` binary is self-contained.
//!
//! Attention itself has one public API: the typed engine in [`attn`]
//! (a `Kernel` enum, an `AttentionSpec` builder, pluggable
//! `AttentionBackend` tiers, and streaming decode sessions). The
//! `reference` and `fastpath` modules are the tiers behind it, and
//! [`serve`] multiplexes many concurrent decode streams over them as
//! dynamic micro-batches (`macformer serve`, `benches/serve_load.rs`).
//!
//! Quickstart (see `examples/quickstart.rs`):
//! ```no_run
//! use macformer::runtime::{Executable, Registry, DeviceState};
//! let reg = Registry::open_default().unwrap();
//! let info = reg.get("lra_text.mac_exp.train").unwrap();
//! let init = Executable::compile_file(
//!     "init",
//!     &reg.hlo_path(reg.get("lra_text.mac_exp.init").unwrap()),
//! ).unwrap();
//! let state = DeviceState::init(&init, info, 42).unwrap();
//! assert_eq!(state.params().len(), info.n_params);
//! ```

pub mod attn;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fastpath;
pub mod metrics;
pub mod reference;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// The Table-2 attention variants, paper order.
pub const VARIANTS: [&str; 7] = [
    "softmax", "rfa", "mac_exp", "mac_inv", "mac_trigh", "mac_log", "mac_sqrt",
];

/// The three LRA tasks evaluated in Table 2.
pub const LRA_TASKS: [&str; 3] = ["lra_text", "lra_listops", "lra_retrieval"];
