//! Dataset synthesis for every workload the paper evaluates.
//!
//! The paper's corpora (IMDb bytes, LRA listops, AAN retrieval, Multi30K)
//! are not shippable; each generator here produces a synthetic stand-in
//! with the statistics that drive the respective benchmark — see
//! DESIGN.md §Substitutions for the per-task argument. Generators are
//! deterministic functions of a seed, so every experiment is exactly
//! reproducible and the train/eval split is a disjoint seed split.

pub mod batcher;
pub mod listops;
pub mod retrieval;
pub mod text_cls;
pub mod translation;
pub mod vocab;

use crate::util::rng::Rng;

/// A materialized classification-style dataset in batch-major buffers.
pub struct ClsDataset {
    pub tokens: Vec<Vec<i32>>,
    pub masks: Vec<Vec<i32>>,
    pub labels: Vec<i32>,
}

impl ClsDataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

/// A retrieval dataset (paired documents).
pub struct PairDataset {
    pub tokens1: Vec<Vec<i32>>,
    pub masks1: Vec<Vec<i32>>,
    pub tokens2: Vec<Vec<i32>>,
    pub masks2: Vec<Vec<i32>>,
    pub labels: Vec<i32>,
}

impl PairDataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

/// An LM dataset (translation rows).
pub struct LmDataset {
    pub tokens: Vec<Vec<i32>>,
    pub loss_masks: Vec<Vec<f32>>,
    pub srcs: Vec<Vec<i32>>,
    pub tgts: Vec<Vec<i32>>,
}

impl LmDataset {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Build the dataset for a classification task by name.
pub fn build_cls(task: &str, seed: u64, count: usize, n: usize) -> ClsDataset {
    let mut rng = Rng::new(seed);
    match task {
        "lra_text" => {
            let exs = text_cls::generate(&mut rng, count, n);
            ClsDataset {
                tokens: exs.iter().map(|e| e.tokens.clone()).collect(),
                masks: exs.iter().map(|e| e.mask.clone()).collect(),
                labels: exs.iter().map(|e| e.label).collect(),
            }
        }
        "lra_listops" => {
            let exs = listops::generate(&mut rng, count, n, 0.7);
            ClsDataset {
                tokens: exs.iter().map(|e| e.tokens.clone()).collect(),
                masks: exs.iter().map(|e| e.mask.clone()).collect(),
                labels: exs.iter().map(|e| e.label).collect(),
            }
        }
        other => panic!("unknown cls task {other:?}"),
    }
}

/// Build the retrieval dataset.
pub fn build_retrieval(seed: u64, count: usize, n: usize) -> PairDataset {
    let mut rng = Rng::new(seed);
    let exs = retrieval::generate(&mut rng, count, n);
    PairDataset {
        tokens1: exs.iter().map(|e| e.tokens1.clone()).collect(),
        masks1: exs.iter().map(|e| e.mask1.clone()).collect(),
        tokens2: exs.iter().map(|e| e.tokens2.clone()).collect(),
        masks2: exs.iter().map(|e| e.mask2.clone()).collect(),
        labels: exs.iter().map(|e| e.label).collect(),
    }
}

/// Build the translation dataset.
pub fn build_translation(seed: u64, count: usize, src_max: usize, seq: usize) -> LmDataset {
    let lex = translation::lexicon(0xBEEF);
    let mut rng = Rng::new(seed);
    let exs = translation::generate(&mut rng, &lex, count, src_max, seq);
    LmDataset {
        tokens: exs.iter().map(|e| e.tokens.clone()).collect(),
        loss_masks: exs.iter().map(|e| e.loss_mask.clone()).collect(),
        srcs: exs.iter().map(|e| e.src.clone()).collect(),
        tgts: exs.iter().map(|e| e.tgt.clone()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cls_builders_produce_consistent_shapes() {
        for task in ["lra_text", "lra_listops"] {
            let d = build_cls(task, 1, 8, 128);
            assert_eq!(d.len(), 8);
            for i in 0..8 {
                assert_eq!(d.tokens[i].len(), 128, "{task}");
                assert_eq!(d.masks[i].len(), 128);
            }
        }
    }

    #[test]
    fn seed_split_gives_disjoint_data() {
        let a = build_cls("lra_text", 1, 4, 128);
        let b = build_cls("lra_text", 2, 4, 128);
        assert_ne!(a.tokens[0], b.tokens[0]);
    }

    #[test]
    fn retrieval_and_translation_builders() {
        let r = build_retrieval(3, 6, 128);
        assert_eq!(r.len(), 6);
        let t = build_translation(4, 10, 24, 64);
        assert_eq!(t.len(), 10);
        assert_eq!(t.tokens[0].len(), 64);
    }
}
