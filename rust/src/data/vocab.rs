//! Token vocabularies shared by the dataset generators and the encoders.
//!
//! Byte-level vocabulary (LRA Text / Retrieval): ids 0..255 are raw bytes,
//! followed by the special tokens. Symbol vocabulary (Listops /
//! translation): dense ids assigned per registered symbol, specials first.

/// Special token ids for the byte-level tasks (match aot.py's vocab_size
/// 260 = 256 bytes + 4 specials).
pub const BYTE_PAD: i32 = 256;
pub const BYTE_CLS: i32 = 257;
pub const BYTE_SEP: i32 = 258;
pub const BYTE_UNK: i32 = 259;
pub const BYTE_VOCAB: usize = 260;

/// Encode a byte string, prepending CLS and padding/truncating to n.
/// Returns (tokens, mask) with mask = 1 on real positions.
pub fn encode_bytes(text: &[u8], n: usize) -> (Vec<i32>, Vec<i32>) {
    let mut toks = Vec::with_capacity(n);
    let mut mask = Vec::with_capacity(n);
    toks.push(BYTE_CLS);
    mask.push(1);
    for &b in text.iter().take(n - 1) {
        toks.push(b as i32);
        mask.push(1);
    }
    while toks.len() < n {
        toks.push(BYTE_PAD);
        mask.push(0);
    }
    (toks, mask)
}

/// Dense symbol vocabulary with reserved specials.
#[derive(Debug, Clone)]
pub struct SymbolVocab {
    symbols: Vec<String>,
}

pub const SYM_PAD: i32 = 0;
pub const SYM_BOS: i32 = 1;
pub const SYM_EOS: i32 = 2;
pub const SYM_SEP: i32 = 3;
pub const NUM_SPECIALS: usize = 4;

impl SymbolVocab {
    pub fn new(symbols: &[&str]) -> SymbolVocab {
        SymbolVocab { symbols: symbols.iter().map(|s| s.to_string()).collect() }
    }

    pub fn id(&self, sym: &str) -> i32 {
        self.symbols
            .iter()
            .position(|s| s == sym)
            .map(|i| (i + NUM_SPECIALS) as i32)
            .unwrap_or_else(|| panic!("unknown symbol {sym:?}"))
    }

    pub fn symbol(&self, id: i32) -> Option<&str> {
        let idx = id as usize;
        if idx < NUM_SPECIALS {
            return Some(["<pad>", "<bos>", "<eos>", "<sep>"][idx]);
        }
        self.symbols.get(idx - NUM_SPECIALS).map(|s| s.as_str())
    }

    pub fn size(&self) -> usize {
        self.symbols.len() + NUM_SPECIALS
    }

    pub fn encode(&self, syms: &[&str]) -> Vec<i32> {
        syms.iter().map(|s| self.id(s)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_encoding_pads_and_masks() {
        let (t, m) = encode_bytes(b"ab", 6);
        assert_eq!(t, vec![BYTE_CLS, 97, 98, BYTE_PAD, BYTE_PAD, BYTE_PAD]);
        assert_eq!(m, vec![1, 1, 1, 0, 0, 0]);
    }

    #[test]
    fn byte_encoding_truncates() {
        let (t, m) = encode_bytes(b"abcdef", 4);
        assert_eq!(t.len(), 4);
        assert_eq!(t[0], BYTE_CLS);
        assert_eq!(m, vec![1, 1, 1, 1]);
    }

    #[test]
    fn byte_tokens_in_vocab_range() {
        let (t, _) = encode_bytes("héllo😀".as_bytes(), 16);
        for tok in t {
            assert!((0..BYTE_VOCAB as i32).contains(&tok));
        }
    }

    #[test]
    fn symbol_vocab_round_trip() {
        let v = SymbolVocab::new(&["MAX", "MIN", "0", "1"]);
        assert_eq!(v.size(), 8);
        let id = v.id("MIN");
        assert_eq!(v.symbol(id), Some("MIN"));
        assert_eq!(v.symbol(SYM_PAD), Some("<pad>"));
    }

    #[test]
    #[should_panic(expected = "unknown symbol")]
    fn unknown_symbol_panics() {
        SymbolVocab::new(&["a"]).id("b");
    }
}
