//! LRA Listops generator: nested prefix expressions over single digits.
//!
//! This re-implements the Long Range Arena recipe (Tay et al. 2021;
//! originally Nangia & Bowman 2018): expressions like
//!
//!   [MAX 4 3 [MIN 2 3 ] 1 0 [MEDIAN 1 5 8 9 2 ] ]
//!
//! with operators MAX, MIN, MEDIAN (MED), SUM_MOD (SM, sum mod 10); the
//! label is the evaluated result in 0..=9. Depth and arity are sampled to
//! fill a target token budget so sequences genuinely exercise long-range
//! hierarchical structure.

use crate::util::rng::Rng;

use super::vocab::{SymbolVocab, SYM_PAD};

pub const OPS: [&str; 4] = ["MAX", "MIN", "MED", "SM"];

/// AST for a listops expression.
#[derive(Debug, Clone)]
pub enum Expr {
    Digit(u8),
    Op(usize, Vec<Expr>), // index into OPS
}

impl Expr {
    pub fn eval(&self) -> u8 {
        match self {
            Expr::Digit(d) => *d,
            Expr::Op(op, args) => {
                let vals: Vec<u8> = args.iter().map(Expr::eval).collect();
                match OPS[*op] {
                    "MAX" => *vals.iter().max().unwrap(),
                    "MIN" => *vals.iter().min().unwrap(),
                    "MED" => {
                        let mut v = vals.clone();
                        v.sort_unstable();
                        v[v.len() / 2]
                    }
                    "SM" => (vals.iter().map(|x| *x as u32).sum::<u32>() % 10) as u8,
                    _ => unreachable!(),
                }
            }
        }
    }

    /// Token count of the rendered form ("[OP", args..., "]").
    pub fn token_len(&self) -> usize {
        match self {
            Expr::Digit(_) => 1,
            Expr::Op(_, args) => 2 + args.iter().map(Expr::token_len).sum::<usize>(),
        }
    }

    pub fn render(&self, out: &mut Vec<String>) {
        match self {
            Expr::Digit(d) => out.push(d.to_string()),
            Expr::Op(op, args) => {
                out.push(format!("[{}", OPS[*op]));
                for a in args {
                    a.render(out);
                }
                out.push("]".to_string());
            }
        }
    }

    pub fn depth(&self) -> usize {
        match self {
            Expr::Digit(_) => 0,
            Expr::Op(_, args) => 1 + args.iter().map(Expr::depth).max().unwrap_or(0),
        }
    }
}

/// Sample an expression with at most `budget` tokens and depth <= max_depth.
pub fn sample_expr(rng: &mut Rng, budget: usize, max_depth: usize) -> Expr {
    if budget < 4 || max_depth == 0 {
        return Expr::Digit(rng.below(10) as u8);
    }
    let op = rng.below(OPS.len());
    // spend between 2 and 5 argument slots, recursing with split budgets
    let arity = rng.range(2, 5);
    let mut remaining = budget - 2; // brackets
    let mut args = Vec::with_capacity(arity);
    for i in 0..arity {
        let slots = arity - i;
        let share = (remaining / slots).max(1);
        let child_budget = if rng.bernoulli(0.45) { share } else { 1 };
        let child = sample_expr(rng, child_budget.min(remaining), max_depth - 1);
        remaining = remaining.saturating_sub(child.token_len());
        args.push(child);
        if remaining == 0 {
            break;
        }
    }
    if args.is_empty() {
        return Expr::Digit(rng.below(10) as u8);
    }
    Expr::Op(op, args)
}

/// The listops token vocabulary: digits, "[OP" markers, "]".
pub fn vocab() -> SymbolVocab {
    SymbolVocab::new(&[
        "0", "1", "2", "3", "4", "5", "6", "7", "8", "9",
        "[MAX", "[MIN", "[MED", "[SM", "]",
    ])
}

/// One labeled example: tokens (padded to n), mask, label in 0..=9.
pub struct ListopsExample {
    pub tokens: Vec<i32>,
    pub mask: Vec<i32>,
    pub label: i32,
}

/// Generate a dataset of `count` examples, each filling roughly
/// `fill_frac` of the n-token window.
pub fn generate(rng: &mut Rng, count: usize, n: usize, fill_frac: f64) -> Vec<ListopsExample> {
    let v = vocab();
    let budget = ((n as f64) * fill_frac) as usize;
    (0..count)
        .map(|_| {
            // resample until the expression fits (token_len <= n)
            let expr = loop {
                let e = sample_expr(rng, budget.max(8), 12);
                if e.token_len() <= n {
                    break e;
                }
            };
            let label = expr.eval() as i32;
            let mut syms = Vec::new();
            expr.render(&mut syms);
            let mut tokens: Vec<i32> = syms.iter().map(|s| v.id(s)).collect();
            let mut mask = vec![1; tokens.len()];
            while tokens.len() < n {
                tokens.push(SYM_PAD);
                mask.push(0);
            }
            ListopsExample { tokens, mask, label }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_known_expressions() {
        // [MAX 4 3 [MIN 2 3] 1] = 4
        let e = Expr::Op(
            0,
            vec![
                Expr::Digit(4),
                Expr::Digit(3),
                Expr::Op(1, vec![Expr::Digit(2), Expr::Digit(3)]),
                Expr::Digit(1),
            ],
        );
        assert_eq!(e.eval(), 4);
        // [SM 5 6 7] = 18 % 10 = 8
        let e = Expr::Op(3, vec![Expr::Digit(5), Expr::Digit(6), Expr::Digit(7)]);
        assert_eq!(e.eval(), 8);
        // [MED 1 5 8 9 2] = sorted [1,2,5,8,9][2] = 5
        let e = Expr::Op(
            2,
            vec![
                Expr::Digit(1),
                Expr::Digit(5),
                Expr::Digit(8),
                Expr::Digit(9),
                Expr::Digit(2),
            ],
        );
        assert_eq!(e.eval(), 5);
    }

    #[test]
    fn token_len_matches_render() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let e = sample_expr(&mut rng, 60, 6);
            let mut syms = Vec::new();
            e.render(&mut syms);
            assert_eq!(syms.len(), e.token_len());
        }
    }

    #[test]
    fn labels_in_digit_range() {
        let mut rng = Rng::new(2);
        for ex in generate(&mut rng, 50, 128, 0.6) {
            assert!((0..10).contains(&ex.label));
            assert_eq!(ex.tokens.len(), 128);
            assert_eq!(ex.mask.len(), 128);
        }
    }

    #[test]
    fn expressions_are_nontrivial() {
        let mut rng = Rng::new(3);
        let exs = generate(&mut rng, 100, 256, 0.7);
        let mean_len: f64 = exs
            .iter()
            .map(|e| e.mask.iter().sum::<i32>() as f64)
            .sum::<f64>()
            / exs.len() as f64;
        assert!(mean_len > 40.0, "sequences too short: {mean_len}");
        // label distribution not collapsed to a single value
        let mut seen = [false; 10];
        for e in &exs {
            seen[e.label as usize] = true;
        }
        assert!(seen.iter().filter(|s| **s).count() >= 5);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&mut Rng::new(7), 5, 64, 0.5);
        let b = generate(&mut Rng::new(7), 5, 64, 0.5);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.label, y.label);
        }
    }

    #[test]
    fn render_parses_back_visually() {
        let mut rng = Rng::new(4);
        let e = sample_expr(&mut rng, 30, 4);
        let mut syms = Vec::new();
        e.render(&mut syms);
        // bracket balance
        let opens = syms.iter().filter(|s| s.starts_with('[')).count();
        let closes = syms.iter().filter(|s| *s == "]").count();
        assert_eq!(opens, closes);
    }
}
