//! Synthetic byte-level sentiment corpus — the LRA Text substitution.
//!
//! The paper uses character-level IMDb; we cannot ship IMDb, so this
//! generator produces long "reviews" with the properties that matter for
//! the benchmark (DESIGN.md §Substitutions): byte-level input, long
//! documents, and a *compositional* sentiment signal — polarity words
//! carry the label, negators ("never", "hardly") flip the polarity of the
//! following clause, and the bulk of each document is neutral filler so
//! the model must aggregate sparse evidence across the full window.

use crate::util::rng::Rng;

use super::vocab::encode_bytes;

const POSITIVE: [&str; 12] = [
    "wonderful", "brilliant", "superb", "delightful", "masterful", "charming",
    "gripping", "stunning", "excellent", "heartfelt", "inspired", "luminous",
];

const NEGATIVE: [&str; 12] = [
    "dreadful", "tedious", "clumsy", "hollow", "grating", "lifeless",
    "muddled", "shallow", "plodding", "stilted", "forgettable", "incoherent",
];

const NEGATORS: [&str; 4] = ["never", "hardly", "scarcely", "barely"];

const FILLER: [&str; 24] = [
    "the", "film", "with", "plot", "scene", "actor", "camera", "story",
    "score", "while", "then", "about", "again", "during", "frame", "moment",
    "dialogue", "sequence", "character", "director", "screen", "cut",
    "light", "sound",
];

/// One labeled review: raw text plus encoded tokens/mask.
pub struct TextExample {
    pub text: String,
    pub tokens: Vec<i32>,
    pub mask: Vec<i32>,
    pub label: i32, // 1 = positive
}

/// Generate `count` reviews encoded into n-byte windows.
///
/// Each review contains `evidence` polarity clauses (possibly negated)
/// buried in filler; the label is the majority *effective* polarity, with
/// ties broken by regeneration so labels are unambiguous.
pub fn generate(rng: &mut Rng, count: usize, n: usize) -> Vec<TextExample> {
    (0..count)
        .map(|_| loop {
            let (text, score) = sample_review(rng, n);
            if score != 0 {
                let label = (score > 0) as i32;
                let (tokens, mask) = encode_bytes(text.as_bytes(), n);
                return TextExample { text, tokens, mask, label };
            }
        })
        .collect()
}

fn sample_review(rng: &mut Rng, n: usize) -> (String, i32) {
    // target byte length ~ 70-95% of the window
    let target = n * rng.range(70, 95) / 100;
    let evidence = rng.range(3, 9);
    let mut words: Vec<String> = Vec::new();
    let mut score = 0i32;
    let mut bytes = 0usize;
    let mut placed = 0usize;
    while bytes < target {
        let place_evidence = placed < evidence && rng.bernoulli(0.08);
        if place_evidence {
            let negate = rng.bernoulli(0.3);
            if negate {
                let w = rng.choose(&NEGATORS);
                bytes += w.len() + 1;
                words.push(w.to_string());
            }
            let positive = rng.bernoulli(0.5);
            let w = if positive { rng.choose(&POSITIVE) } else { rng.choose(&NEGATIVE) };
            let effective = positive != negate;
            score += if effective { 1 } else { -1 };
            bytes += w.len() + 1;
            words.push(w.to_string());
            placed += 1;
        } else {
            let w = rng.choose(&FILLER);
            bytes += w.len() + 1;
            words.push(w.to_string());
        }
    }
    (words.join(" "), score)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_binary_and_balanced() {
        let mut rng = Rng::new(1);
        let exs = generate(&mut rng, 200, 512);
        let pos = exs.iter().filter(|e| e.label == 1).count();
        assert!(pos > 50 && pos < 150, "positive count {pos}");
        for e in &exs {
            assert!(e.label == 0 || e.label == 1);
        }
    }

    #[test]
    fn documents_fill_the_window() {
        let mut rng = Rng::new(2);
        for e in generate(&mut rng, 20, 1024) {
            let real: i32 = e.mask.iter().sum();
            assert!(real as usize > 1024 / 2, "doc too short: {real}");
            assert_eq!(e.tokens.len(), 1024);
        }
    }

    #[test]
    fn label_agrees_with_effective_polarity() {
        // Count effective polarity from the text and compare to the label.
        let mut rng = Rng::new(3);
        for e in generate(&mut rng, 50, 512) {
            let words: Vec<&str> = e.text.split_whitespace().collect();
            let mut score = 0i32;
            let mut i = 0;
            while i < words.len() {
                let negated = NEGATORS.contains(&words[i]);
                let j = if negated { i + 1 } else { i };
                if j < words.len() {
                    if POSITIVE.contains(&words[j]) {
                        score += if negated { -1 } else { 1 };
                        i = j + 1;
                        continue;
                    }
                    if NEGATIVE.contains(&words[j]) {
                        score += if negated { 1 } else { -1 };
                        i = j + 1;
                        continue;
                    }
                }
                i += 1;
            }
            assert_eq!((score > 0) as i32, e.label, "text: {}", e.text);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(&mut Rng::new(9), 5, 256);
        let b = generate(&mut Rng::new(9), 5, 256);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.text, y.text);
        }
    }
}
