//! Synthetic citation-retrieval pairs — the LRA Retrieval (AAN)
//! substitution.
//!
//! Protocol identical to the benchmark: given two byte-level documents,
//! predict whether they are related (binary). Related pairs are papers
//! drawn from the same synthetic "topic" (shared technical lexicon +
//! shared citation keys); unrelated pairs come from different topics.
//! The signal is distributed across both documents, so the dual-encoder
//! must compress each into a pooled representation — same mechanism the
//! real task exercises.

use crate::util::rng::Rng;

use super::vocab::encode_bytes;

const TOPICS: [[&str; 8]; 6] = [
    ["parser", "grammar", "syntax", "treebank", "token", "corpus", "tagset", "lexicon"],
    ["neuron", "gradient", "backprop", "layer", "softmax", "dropout", "logits", "epoch"],
    ["kernel", "feature", "margin", "support", "convex", "dual", "slack", "hinge"],
    ["reward", "policy", "agent", "bandit", "rollout", "critic", "regret", "qvalue"],
    ["phoneme", "acoustic", "decoder", "lattice", "prosody", "speaker", "spectral", "voicing"],
    ["entity", "relation", "triple", "ontology", "linking", "mention", "schema", "graph"],
];

const GLUE: [&str; 16] = [
    "we", "show", "that", "the", "proposed", "method", "improves", "over",
    "baseline", "results", "on", "standard", "datasets", "using", "novel", "analysis",
];

/// One retrieval pair.
pub struct RetrievalExample {
    pub tokens1: Vec<i32>,
    pub mask1: Vec<i32>,
    pub tokens2: Vec<i32>,
    pub mask2: Vec<i32>,
    pub label: i32, // 1 = related (same topic)
}

fn sample_doc(rng: &mut Rng, topic: usize, cite_key: u32, n: usize) -> String {
    let target = n * rng.range(70, 95) / 100;
    let mut words: Vec<String> = Vec::new();
    let mut bytes = 0usize;
    // citation key appears a few times — the long-range anchor
    let key = format!("ref{cite_key:04}");
    let mut keys_left = rng.range(2, 4);
    while bytes < target {
        let w: String = if keys_left > 0 && rng.bernoulli(0.02) {
            keys_left -= 1;
            key.clone()
        } else if rng.bernoulli(0.25) {
            (*rng.choose(&TOPICS[topic])).to_string()
        } else {
            (*rng.choose(&GLUE)).to_string()
        };
        bytes += w.len() + 1;
        words.push(w);
    }
    words.join(" ")
}

/// Generate `count` balanced related/unrelated pairs over n-byte windows.
pub fn generate(rng: &mut Rng, count: usize, n: usize) -> Vec<RetrievalExample> {
    (0..count)
        .map(|i| {
            let related = i % 2 == 0;
            let t1 = rng.below(TOPICS.len());
            let t2 = if related {
                t1
            } else {
                // a different topic
                let mut t = rng.below(TOPICS.len());
                while t == t1 {
                    t = rng.below(TOPICS.len());
                }
                t
            };
            let key1 = rng.next_u32() % 10_000;
            let key2 = if related { key1 } else { rng.next_u32() % 10_000 };
            let d1 = sample_doc(rng, t1, key1, n);
            let d2 = sample_doc(rng, t2, key2, n);
            let (tokens1, mask1) = encode_bytes(d1.as_bytes(), n);
            let (tokens2, mask2) = encode_bytes(d2.as_bytes(), n);
            RetrievalExample { tokens1, mask1, tokens2, mask2, label: related as i32 }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pairs_are_balanced() {
        let mut rng = Rng::new(1);
        let exs = generate(&mut rng, 100, 256);
        let pos = exs.iter().filter(|e| e.label == 1).count();
        assert_eq!(pos, 50);
    }

    #[test]
    fn related_docs_share_lexicon() {
        let mut rng = Rng::new(2);
        let exs = generate(&mut rng, 40, 512);
        // measure byte-bigram cosine overlap: related > unrelated on average
        fn hist(tokens: &[i32]) -> Vec<f32> {
            let mut h = vec![0f32; 256];
            for t in tokens {
                if (0..256).contains(t) {
                    h[*t as usize] += 1.0;
                }
            }
            h
        }
        fn cos(a: &[f32], b: &[f32]) -> f32 {
            let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
            let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
            dot / (na * nb)
        }
        let (mut rel, mut unrel) = (0.0, 0.0);
        let (mut nrel, mut nunrel) = (0, 0);
        for e in &exs {
            let c = cos(&hist(&e.tokens1), &hist(&e.tokens2));
            if e.label == 1 {
                rel += c;
                nrel += 1;
            } else {
                unrel += c;
                nunrel += 1;
            }
        }
        assert!(
            rel / nrel as f32 > unrel / nunrel as f32,
            "related pairs must be lexically closer"
        );
    }

    #[test]
    fn shapes_are_consistent() {
        let mut rng = Rng::new(3);
        for e in generate(&mut rng, 10, 128) {
            assert_eq!(e.tokens1.len(), 128);
            assert_eq!(e.tokens2.len(), 128);
            assert_eq!(e.mask1.len(), 128);
            assert_eq!(e.mask2.len(), 128);
        }
    }
}
