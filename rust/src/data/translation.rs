//! Synthetic translation corpus — the Multi30K substitution for Fig 3.
//!
//! Deterministic toy language pair sized like Multi30K (29k train pairs,
//! ~1k eval) that exercises exactly what the Fig-3 experiment measures:
//! a seq2seq model trained with cross-entropy, evaluated by loss,
//! perplexity, and BLEU of greedy decodes. The mapping is learnable but
//! non-trivial:
//!
//!   * every source word has a fixed target translation (a seeded
//!     permutation of the target vocabulary),
//!   * adjective-noun phrases invert order in the target (local
//!     reordering, the classic de/en artifact),
//!   * plural-marked nouns emit an extra suffix token in the target
//!     (morphology), and
//!   * sentences end with a mapped punctuation token.
//!
//! Sequence layout (matches aot.py's translation module contract):
//!   [ src (padded to SRC_MAX) | SEP | tgt tokens | EOS | PAD... ]
//! with loss_mask = 1 exactly on the target span (incl. EOS).

use crate::util::rng::Rng;

use super::vocab::{SYM_EOS, SYM_PAD, SYM_SEP};

/// Vocabulary layout inside the model's 512-id space.
pub const NUM_WORDS: usize = 180; // per language
pub const SRC_BASE: i32 = 4;
pub const TGT_BASE: i32 = SRC_BASE + NUM_WORDS as i32;
pub const PLURAL_MARK: i32 = TGT_BASE + NUM_WORDS as i32; // tgt plural suffix
pub const SRC_PLURAL: i32 = PLURAL_MARK + 1; // src plural suffix

/// Word-class split of the source vocabulary (by id offset).
const NOUNS: std::ops::Range<usize> = 0..80;
const ADJS: std::ops::Range<usize> = 80..130;
const VERBS: std::ops::Range<usize> = 130..175;
const PUNCT: std::ops::Range<usize> = 175..180;

/// The fixed translation lexicon: src word offset -> tgt word offset.
pub fn lexicon(seed: u64) -> Vec<usize> {
    let mut perm: Vec<usize> = (0..NUM_WORDS).collect();
    let mut rng = Rng::new(seed ^ 0x7A61_7274);
    rng.shuffle(&mut perm);
    perm
}

/// One parallel sentence pair (unpadded token ids).
#[derive(Debug, Clone)]
pub struct Pair {
    pub src: Vec<i32>,
    pub tgt: Vec<i32>,
}

/// Sample a source sentence and derive its deterministic translation.
pub fn sample_pair(rng: &mut Rng, lex: &[usize]) -> Pair {
    let phrases = rng.range(2, 4);
    let mut src = Vec::new();
    let mut tgt = Vec::new();
    for _ in 0..phrases {
        match rng.below(3) {
            0 => {
                // adjective + noun (reordered in target)
                let a = rng.range(ADJS.start, ADJS.end - 1);
                let n = rng.range(NOUNS.start, NOUNS.end - 1);
                let plural = rng.bernoulli(0.3);
                src.push(SRC_BASE + a as i32);
                src.push(SRC_BASE + n as i32);
                if plural {
                    src.push(SRC_PLURAL);
                }
                tgt.push(TGT_BASE + lex[n] as i32);
                if plural {
                    tgt.push(PLURAL_MARK);
                }
                tgt.push(TGT_BASE + lex[a] as i32);
            }
            1 => {
                // bare noun
                let n = rng.range(NOUNS.start, NOUNS.end - 1);
                src.push(SRC_BASE + n as i32);
                tgt.push(TGT_BASE + lex[n] as i32);
            }
            _ => {
                // verb
                let v = rng.range(VERBS.start, VERBS.end - 1);
                src.push(SRC_BASE + v as i32);
                tgt.push(TGT_BASE + lex[v] as i32);
            }
        }
    }
    let p = rng.range(PUNCT.start, PUNCT.end - 1);
    src.push(SRC_BASE + p as i32);
    tgt.push(TGT_BASE + lex[p] as i32);
    Pair { src, tgt }
}

/// Reference translation of a source sentence (for BLEU scoring of
/// arbitrary model output). Mirrors sample_pair's derivation.
pub fn translate(src: &[i32], lex: &[usize]) -> Vec<i32> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < src.len() {
        let w = (src[i] - SRC_BASE) as usize;
        let is_adj = src[i] >= SRC_BASE && ADJS.contains(&w);
        if is_adj && i + 1 < src.len() {
            let n = (src[i + 1] - SRC_BASE) as usize;
            if src[i + 1] >= SRC_BASE && NOUNS.contains(&n) {
                let plural = i + 2 < src.len() && src[i + 2] == SRC_PLURAL;
                out.push(TGT_BASE + lex[n] as i32);
                if plural {
                    out.push(PLURAL_MARK);
                }
                out.push(TGT_BASE + lex[w] as i32);
                i += if plural { 3 } else { 2 };
                continue;
            }
        }
        if src[i] == SRC_PLURAL {
            i += 1;
            continue;
        }
        out.push(TGT_BASE + lex[w] as i32);
        i += 1;
    }
    out
}

/// A padded LM training/eval row.
pub struct TranslationExample {
    pub tokens: Vec<i32>,
    pub loss_mask: Vec<f32>,
    pub src: Vec<i32>,
    pub tgt: Vec<i32>,
}

/// Pack a pair into the [src | SEP | tgt | EOS | pad] layout.
pub fn pack(pair: &Pair, src_max: usize, seq: usize) -> TranslationExample {
    let mut tokens = vec![SYM_PAD; seq];
    let mut loss_mask = vec![0.0f32; seq];
    for (i, &t) in pair.src.iter().take(src_max).enumerate() {
        tokens[i] = t;
    }
    tokens[src_max] = SYM_SEP;
    let mut pos = src_max + 1;
    for &t in &pair.tgt {
        if pos >= seq - 1 {
            break;
        }
        tokens[pos] = t;
        loss_mask[pos] = 1.0;
        pos += 1;
    }
    tokens[pos] = SYM_EOS;
    loss_mask[pos] = 1.0;
    TranslationExample { tokens, loss_mask, src: pair.src.clone(), tgt: pair.tgt.clone() }
}

/// Generate a corpus of packed examples.
pub fn generate(
    rng: &mut Rng,
    lex: &[usize],
    count: usize,
    src_max: usize,
    seq: usize,
) -> Vec<TranslationExample> {
    (0..count)
        .map(|_| {
            // keep sampling until the pair fits the fixed layout
            let pair = loop {
                let p = sample_pair(rng, lex);
                if p.src.len() <= src_max && p.tgt.len() < seq - src_max - 2 {
                    break p;
                }
            };
            pack(&pair, src_max, seq)
        })
        .collect()
}

/// Extract the generated target span from a decoded row (stops at EOS).
pub fn decode_target(tokens: &[i32], src_max: usize) -> Vec<i32> {
    let mut out = Vec::new();
    for &t in &tokens[src_max + 1..] {
        if t == SYM_EOS || t == SYM_PAD {
            break;
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn translation_is_deterministic() {
        let lex = lexicon(1);
        let mut rng = Rng::new(2);
        let p = sample_pair(&mut rng, &lex);
        assert_eq!(translate(&p.src, &lex), p.tgt);
    }

    #[test]
    fn adjective_noun_reorders() {
        let lex = lexicon(1);
        // src: adj(80) noun(0) -> tgt: lex[0] lex[80]
        let src = vec![SRC_BASE + 80, SRC_BASE];
        let tgt = translate(&src, &lex);
        assert_eq!(tgt, vec![TGT_BASE + lex[0] as i32, TGT_BASE + lex[80] as i32]);
    }

    #[test]
    fn plural_emits_marker() {
        let lex = lexicon(1);
        let src = vec![SRC_BASE + 80, SRC_BASE, SRC_PLURAL];
        let tgt = translate(&src, &lex);
        assert_eq!(tgt[1], PLURAL_MARK);
        assert_eq!(tgt.len(), 3);
    }

    #[test]
    fn pack_layout_and_mask() {
        let lex = lexicon(1);
        let mut rng = Rng::new(3);
        let ex = generate(&mut rng, &lex, 1, 24, 64).pop().unwrap();
        assert_eq!(ex.tokens.len(), 64);
        assert_eq!(ex.tokens[24], SYM_SEP);
        // mask exactly covers the tgt span + EOS
        let mask_count = ex.loss_mask.iter().filter(|x| **x > 0.0).count();
        assert_eq!(mask_count, ex.tgt.len() + 1);
        // nothing before SEP is masked
        assert!(ex.loss_mask[..25].iter().all(|x| *x == 0.0));
    }

    #[test]
    fn decode_target_recovers_reference() {
        let lex = lexicon(1);
        let mut rng = Rng::new(4);
        let ex = generate(&mut rng, &lex, 1, 24, 64).pop().unwrap();
        assert_eq!(decode_target(&ex.tokens, 24), ex.tgt);
    }

    #[test]
    fn corpus_vocabulary_stays_in_range() {
        let lex = lexicon(5);
        let mut rng = Rng::new(6);
        for ex in generate(&mut rng, &lex, 100, 24, 64) {
            for &t in &ex.tokens {
                assert!((0..512).contains(&t), "token {t} out of range");
            }
        }
    }

    #[test]
    fn lexicon_is_a_permutation() {
        let lex = lexicon(9);
        let mut sorted = lex.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..NUM_WORDS).collect::<Vec<_>>());
    }

    #[test]
    fn perfect_translation_gets_bleu_100() {
        use crate::metrics::bleu::corpus_bleu;
        let lex = lexicon(1);
        let mut rng = Rng::new(7);
        let pairs: Vec<(Vec<u32>, Vec<u32>)> = (0..20)
            .map(|_| {
                let p = sample_pair(&mut rng, &lex);
                let hyp: Vec<u32> = translate(&p.src, &lex).iter().map(|x| *x as u32).collect();
                let r: Vec<u32> = p.tgt.iter().map(|x| *x as u32).collect();
                (hyp, r)
            })
            .collect();
        assert!((corpus_bleu(&pairs) - 100.0).abs() < 1e-6);
    }
}
