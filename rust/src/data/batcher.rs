//! Epoch batcher: shuffled, exhaustive, fixed-size batches.
//!
//! The AOT-compiled train modules have a *static* batch dimension, so the
//! scheduler always emits full batches; the epoch tail that doesn't fill a
//! batch is carried into the next epoch's shuffle (no silent drops across
//! the run — every sample is consumed with equal frequency in the limit).

use crate::util::rng::Rng;

/// Yields index batches over a dataset of `len` items.
#[derive(Debug)]
pub struct Batcher {
    len: usize,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub epoch: usize,
}

impl Batcher {
    pub fn new(len: usize, batch: usize, seed: u64) -> Batcher {
        assert!(batch > 0 && len >= batch, "dataset ({len}) smaller than batch ({batch})");
        let mut b = Batcher {
            len,
            batch,
            order: Vec::new(),
            cursor: 0,
            rng: Rng::new(seed),
            epoch: 0,
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        // carry the unconsumed tail to the front of the new epoch
        let tail: Vec<usize> = self.order[self.cursor..].to_vec();
        let mut fresh: Vec<usize> = (0..self.len).collect();
        self.rng.shuffle(&mut fresh);
        self.order = tail;
        self.order.extend(fresh);
        self.cursor = 0;
    }

    /// Next batch of indices (always exactly `batch` long).
    pub fn next_batch(&mut self) -> &[usize] {
        if self.cursor + self.batch > self.order.len() {
            self.epoch += 1;
            self.reshuffle();
        }
        let out = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        out
    }

    /// Number of full batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.len / self.batch
    }
}

/// Flatten per-example i32 rows into one contiguous batch buffer.
pub fn gather_i32(rows: &[Vec<i32>], idx: &[usize]) -> Vec<i32> {
    let width = rows[0].len();
    let mut out = Vec::with_capacity(idx.len() * width);
    for &i in idx {
        debug_assert_eq!(rows[i].len(), width);
        out.extend_from_slice(&rows[i]);
    }
    out
}

/// Flatten per-example f32 rows.
pub fn gather_f32(rows: &[Vec<f32>], idx: &[usize]) -> Vec<f32> {
    let width = rows[0].len();
    let mut out = Vec::with_capacity(idx.len() * width);
    for &i in idx {
        out.extend_from_slice(&rows[i]);
    }
    out
}

/// Gather scalars.
pub fn gather_scalar_i32(vals: &[i32], idx: &[usize]) -> Vec<i32> {
    idx.iter().map(|&i| vals[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn batches_have_fixed_size() {
        let mut b = Batcher::new(10, 4, 1);
        for _ in 0..20 {
            assert_eq!(b.next_batch().len(), 4);
        }
    }

    #[test]
    fn every_sample_seen_with_equal_frequency() {
        let mut b = Batcher::new(10, 4, 2);
        let mut counts: HashMap<usize, usize> = HashMap::new();
        // 10 epochs worth of samples = 100 draws = 25 batches
        for _ in 0..25 {
            for &i in b.next_batch() {
                *counts.entry(i).or_insert(0) += 1;
            }
        }
        // exhaustive coverage: each sample seen 10 +- 1 times
        for i in 0..10 {
            let c = counts.get(&i).copied().unwrap_or(0);
            assert!((9..=11).contains(&c), "sample {i} seen {c} times");
        }
    }

    #[test]
    fn indices_always_in_range() {
        let mut b = Batcher::new(7, 7, 3);
        for _ in 0..10 {
            for &i in b.next_batch() {
                assert!(i < 7);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Batcher::new(20, 5, 42);
        let mut b = Batcher::new(20, 5, 42);
        for _ in 0..12 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }

    #[test]
    fn gather_concatenates_rows() {
        let rows = vec![vec![1, 2], vec![3, 4], vec![5, 6]];
        assert_eq!(gather_i32(&rows, &[2, 0]), vec![5, 6, 1, 2]);
        assert_eq!(gather_scalar_i32(&[7, 8, 9], &[1, 1]), vec![8, 8]);
    }
}
