//! The unified typed attention engine — the single public API for every
//! way this crate runs attention.
//!
//! Macformer's core claim is one mechanism (RMFA + ppSBN) instantiated
//! over many dot-product kernels and backends. This module is that
//! claim as an API: a typed [`Kernel`] enum instead of stringly-typed
//! `"exp"`-style parameters, an [`AttentionSpec`] builder, an
//! [`AttentionBackend`] trait with three tiers, and an
//! [`AttentionSession`] that owns one feature-map draw and exposes both
//! batched `forward()` and O(1)-per-token streaming decode.
//!
//! # Tier contract
//!
//! | tier | type | job |
//! |------|------|-----|
//! | oracle | [`ReferenceBackend`] | scalar, single-thread mirrors of the paper's math (`crate::reference`); never optimized |
//! | fast | [`HostFastBackend`] | same math, engineered for throughput (`crate::fastpath`); proved against the oracle |
//! | device | [`DeviceBackend`] | PJRT execution; gates itself off with clean `Err`s on the stub build |
//!
//! [`Backend::Auto`] resolves to the best tier that can actually
//! execute (today: the host fast path). Every future backend (SIMD,
//! sharded, batching servers) implements [`AttentionBackend`] and plugs
//! into the same sessions. The serving layer ([`crate::serve`])
//! multiplexes many concurrent [`CausalState`] decode streams over one
//! session as dynamic micro-batches, via the batched single-token
//! entry point [`AttentionSession::phi_rows_into`].
//!
//! # Migration from the old free functions
//!
//! | old (stringly-typed, panics on typos) | new |
//! |---|---|
//! | `maclaurin::coefficient("exp", n)` | [`Kernel::Exp`]`.coefficient(n)?` |
//! | `maclaurin::kernel_value("inv", t)` | [`Kernel::Inv`]`.value(t)?` |
//! | `maclaurin::truncated_kernel_value(k, t, deg)` | `kernel.truncated_value(t, deg)?` |
//! | `maclaurin::feature_scale(k, n, p)` | `kernel.feature_scale(n, p)?` |
//! | `maclaurin::KERNELS` | [`Kernel::MACLAURIN`] |
//! | `maclaurin::degree_distribution(p, deg)` | [`degree_distribution`] |
//! | `RmfMap::sample(rng, "exp", ..)` | `RmfMap::sample(rng, Kernel::Exp, ..)` (or let a session own the draw) |
//! | `reference::attention::kernelized_attention("exp", ..)` | [`AttentionSpec::new`]`(Kernel::Exp).build()?.forward_exact(..)` |
//! | `fastpath::kernelized_attention_batched("exp", ..)` | session with [`Backend::HostFast`], `forward_exact(..)` |
//! | hand-rolled `phi_q`/`phi_k` + `linear_attention(..)` | `session.forward(..)` |
//! | (not expressible before) O(1)-per-token decode | [`AttentionSession::begin_decode`] + [`CausalState::append_token`] |
//! | (not expressible before) chunked prompt prefill | [`CausalState::prefill_into`] (whole prompt in `MACFORMER_CHUNK`-token GEMM chunks, then stream) |
//!
//! Kernel parsing never panics: `Kernel::from_str("bogus")` is a plain
//! `Err`, so CLI surfaces report bad `--kernel` values cleanly.
//!
//! # Batched forward
//!
//! ```
//! use macformer::attn::{AttentionSpec, Backend, Kernel};
//! use macformer::tensor::Tensor;
//! use macformer::util::rng::Rng;
//!
//! let mut rng = Rng::new(7);
//! // 2 problems (batch x heads), 6 tokens, head_dim 4
//! let q = Tensor::randn(&mut rng, &[2, 6, 4], 0.5);
//! let k = Tensor::randn(&mut rng, &[2, 6, 4], 0.5);
//! let v = Tensor::randn(&mut rng, &[2, 6, 4], 1.0);
//!
//! let session = AttentionSpec::new(Kernel::Inv)
//!     .head_dim(4)
//!     .num_features(32)
//!     .seed(42)
//!     .backend(Backend::HostFast)
//!     .build()
//!     .unwrap();
//! let out = session.forward(&q, &k, &v).unwrap();
//! assert_eq!(out.shape, vec![2, 6, 4]);
//! ```
//!
//! # Streaming decode
//!
//! ```
//! use macformer::attn::{AttentionSpec, Kernel};
//!
//! let session = AttentionSpec::new(Kernel::Exp)
//!     .head_dim(2)
//!     .num_features(16)
//!     .causal(true)
//!     .build()
//!     .unwrap();
//! let mut state = session.begin_decode(1).unwrap();
//! // one (q, k, v) row per generated token; O(1) work each
//! let o0 = state.append_token(&[0.1, -0.2], &[0.3, 0.0], &[1.0]).unwrap();
//! let o1 = state.append_token(&[0.0, 0.2], &[-0.1, 0.1], &[2.0]).unwrap();
//! assert_eq!((o0.len(), o1.len(), state.len()), (1, 1, 2));
//! // the first token can only attend to itself (up to the eps stabilizer)
//! assert!((o0[0] - 1.0).abs() < 1e-3);
//! ```

pub mod backend;
pub mod kernel;
pub mod session;
pub mod spec;

pub use backend::{
    select, AttentionBackend, DeviceBackend, HostFastBackend, ReferenceBackend,
};
pub use kernel::{
    degree_distribution, Kernel, NoMaclaurinSeries, ParseKernelError, DEFAULT_MAX_DEGREE,
};
pub use session::{AttentionSession, CausalState, FeatureMap};
pub use spec::{AttentionSpec, Backend, ParseBackendError};
