//! [`AttentionBackend`] — the compute-tier trait every attention
//! implementation plugs into, plus the three built-in tiers:
//!
//! * [`ReferenceBackend`] — the scalar oracle (`crate::reference`),
//!   single thread, per-problem loops. Never optimized; the ground
//!   truth the other tiers are proved against.
//! * [`HostFastBackend`] — the engineered host tier
//!   (`crate::fastpath`): degree-grouped GEMM feature maps and
//!   persistent-pool batched kernels, with a runtime-dispatched
//!   AVX2+FMA arm on capable hosts.
//! * [`DeviceBackend`] — PJRT execution. On the vendored stub (or when
//!   no per-shape artifacts are compiled) every op returns a clean
//!   `Err` instead of panicking, and [`select`] auto-falls back to the
//!   host fast path.
//!
//! All tensor arguments are batched `(g, n, d)` row-major; `g` is
//! batch x heads. Sharding across problems is a backend concern.

use anyhow::{anyhow, Result};

use crate::fastpath;
use crate::reference::attention as oracle;
use crate::tensor::Tensor;

use super::kernel::Kernel;
use super::session::FeatureMap;
use super::spec::Backend;

/// One compute tier. Object-safe so sessions can hold `Box<dyn ...>`;
/// future tiers (SIMD, sharded, remote) implement this same contract
/// and are proved against [`ReferenceBackend`].
pub trait AttentionBackend: Send + Sync {
    /// Stable identifier for logs and reports.
    fn name(&self) -> &'static str;

    /// Can this tier execute at all in the current build/environment?
    fn available(&self) -> bool;

    /// Exact softmax attention over `(g, n, d)` q/k and `(g, m, dv)` v.
    fn softmax(&self, q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Result<Tensor>;

    /// Quadratic kernelized attention (Definition 2) with a Table-1
    /// kernel; scores are scaled by `1/sqrt(d)` internally.
    fn kernelized(
        &self,
        kernel: Kernel,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        causal: bool,
        eps: f32,
    ) -> Result<Tensor>;

    /// phi over a batched `(g, n, d)` tensor -> `(g, n, D)`. Inputs are
    /// expected to be pre-scaled to score scale by the caller.
    fn features(&self, map: &FeatureMap, x: &Tensor) -> Result<Tensor>;

    /// Factored linear contraction over `(g, n, D)` phi maps.
    fn linear(
        &self,
        phi_q: &Tensor,
        phi_k: &Tensor,
        v: &Tensor,
        causal: bool,
        eps: f32,
    ) -> Result<Tensor>;

    /// phi of a single pre-scaled row — the O(1)-per-token building
    /// block of the streaming decode path.
    fn phi_row(&self, map: &FeatureMap, x_scaled: &[f32]) -> Result<Vec<f32>>;

    // ----- allocation-free slice entry points -------------------------
    //
    // The `_into` variants below power `AttentionSession::forward_into`:
    // they write into caller-owned buffers so steady-state forwards make
    // zero heap allocations. The default implementations wrap the slices
    // into tensors and delegate to the allocating methods (correct for
    // every tier); `HostFastBackend` overrides them with true zero-copy,
    // zero-alloc paths. All slices are flat row-major with the batched
    // `(g, n, d)` layout of the tensor methods.

    /// Exact softmax attention into a caller-owned `(g, n, dv)` buffer.
    #[allow(clippy::too_many_arguments)]
    fn softmax_into(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        g: usize,
        n: usize,
        m: usize,
        d: usize,
        dv: usize,
        causal: bool,
        out: &mut [f32],
    ) -> Result<()> {
        let qt = Tensor::from_vec(&[g, n, d], q.to_vec());
        let kt = Tensor::from_vec(&[g, m, d], k.to_vec());
        let vt = Tensor::from_vec(&[g, m, dv], v.to_vec());
        let r = self.softmax(&qt, &kt, &vt, causal)?;
        out.copy_from_slice(&r.data);
        Ok(())
    }

    /// phi over a `(g, n, d)` slice into a caller-owned `(g, n, D)`
    /// buffer. Inputs are expected to be pre-scaled to score scale.
    fn features_into(
        &self,
        map: &FeatureMap,
        x: &[f32],
        g: usize,
        n: usize,
        d: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let xt = Tensor::from_vec(&[g, n, d], x.to_vec());
        let r = self.features(map, &xt)?;
        out.copy_from_slice(&r.data);
        Ok(())
    }

    /// Factored linear contraction into a caller-owned `(g, n, dv)`
    /// buffer.
    #[allow(clippy::too_many_arguments)]
    fn linear_into(
        &self,
        phi_q: &[f32],
        phi_k: &[f32],
        v: &[f32],
        g: usize,
        n: usize,
        m: usize,
        feat: usize,
        dv: usize,
        causal: bool,
        eps: f32,
        out: &mut [f32],
    ) -> Result<()> {
        let pq = Tensor::from_vec(&[g, n, feat], phi_q.to_vec());
        let pk = Tensor::from_vec(&[g, m, feat], phi_k.to_vec());
        let vt = Tensor::from_vec(&[g, m, dv], v.to_vec());
        let r = self.linear(&pq, &pk, &vt, causal, eps)?;
        out.copy_from_slice(&r.data);
        Ok(())
    }

    /// phi of a single pre-scaled row into a caller-owned `D`-length
    /// buffer — the allocation-free decode building block.
    fn phi_row_into(&self, map: &FeatureMap, x_scaled: &[f32], out: &mut [f32]) -> Result<()> {
        let r = self.phi_row(map, x_scaled)?;
        out.copy_from_slice(&r);
        Ok(())
    }

    /// phi over `rows` pre-scaled `d`-length rows into a caller-owned
    /// `rows * D` buffer — the batched single-token step behind the
    /// serve scheduler's micro-batches and the prefill feature pass.
    /// Equivalent to `rows` independent [`phi_row_into`] (row-for-row
    /// bit-identical on both host tiers). The default dispatches one
    /// `(rows, 1, d)` batched feature call; the host tier overrides
    /// with row-blocked sharding over the persistent worker pool (zero
    /// steady-state allocations either way).
    ///
    /// [`phi_row_into`]: AttentionBackend::phi_row_into
    fn phi_rows_into(
        &self,
        map: &FeatureMap,
        x_scaled: &[f32],
        rows: usize,
        d: usize,
        out: &mut [f32],
    ) -> Result<()> {
        self.features_into(map, x_scaled, rows, 1, d, out)
    }

    /// Causal prefill fold over one problem's precomputed phi rows:
    /// advance the running `(s, z)` decode state (`s` is `feat x dv`
    /// row-major, `z` is `feat`) by `n` tokens and write every
    /// position's normalized output. Pure host math over
    /// already-computed features — infallible and allocation-free on
    /// every tier.
    ///
    /// `chunk` is the blocked-kernel width; the default folds token by
    /// token (the oracle semantics, exactly the streaming decode fold)
    /// and ignores it. The host tier overrides with the chunkwise GEMM
    /// kernel, whose **state advance stays bit-identical to this
    /// fold** on the same SIMD dispatch arm — so prefill-then-decode
    /// continues bit-compatibly regardless of tier or chunk width.
    #[allow(clippy::too_many_arguments)]
    fn prefill_fold_into(
        &self,
        phi_q: &[f32],
        phi_k: &[f32],
        v: &[f32],
        n: usize,
        feat: usize,
        dv: usize,
        chunk: usize,
        eps: f32,
        s: &mut [f32],
        z: &mut [f32],
        out: &mut [f32],
    ) {
        let _ = chunk;
        for i in 0..n {
            fastpath::attention::causal_fold_key(
                &phi_k[i * feat..(i + 1) * feat],
                &v[i * dv..(i + 1) * dv],
                z,
                s,
                dv,
            );
            fastpath::attention::causal_fold_query(
                &phi_q[i * feat..(i + 1) * feat],
                z,
                s,
                dv,
                eps,
                &mut out[i * dv..(i + 1) * dv],
            );
        }
    }
}

fn batched_dims(t: &Tensor, what: &str) -> Result<(usize, usize, usize)> {
    if t.rank() != 3 {
        return Err(anyhow!("{what}: expected a (g, n, d) tensor, got shape {:?}", t.shape));
    }
    Ok((t.shape[0], t.shape[1], t.shape[2]))
}

/// The scalar oracle tier: per-problem loops over `crate::reference`.
#[derive(Debug, Default, Clone, Copy)]
pub struct ReferenceBackend;

impl ReferenceBackend {
    /// Run a single-problem kernel over every problem of a batched set.
    fn per_problem(
        g: usize,
        out_shape: &[usize],
        mut f: impl FnMut(usize) -> Tensor,
    ) -> Tensor {
        let mut out = Tensor::zeros(out_shape);
        let stride = out_shape[1] * out_shape[2];
        for gi in 0..g {
            let one = f(gi);
            out.data[gi * stride..(gi + 1) * stride].copy_from_slice(&one.data);
        }
        out
    }
}

impl AttentionBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn available(&self) -> bool {
        true
    }

    fn softmax(&self, q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Result<Tensor> {
        let (g, n, _d) = batched_dims(q, "reference softmax q")?;
        let (_, _, dv) = batched_dims(v, "reference softmax v")?;
        Ok(Self::per_problem(g, &[g, n, dv], |gi| {
            oracle::softmax_attention(&q.problem2(gi), &k.problem2(gi), &v.problem2(gi), causal)
        }))
    }

    fn kernelized(
        &self,
        kernel: Kernel,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        causal: bool,
        eps: f32,
    ) -> Result<Tensor> {
        kernel.value_fn()?; // reject the exact baseline with a clean error
        let (g, n, _d) = batched_dims(q, "reference kernelized q")?;
        let (_, _, dv) = batched_dims(v, "reference kernelized v")?;
        Ok(Self::per_problem(g, &[g, n, dv], |gi| {
            oracle::kernelized_attention(
                kernel,
                &q.problem2(gi),
                &k.problem2(gi),
                &v.problem2(gi),
                causal,
                eps,
            )
        }))
    }

    fn features(&self, map: &FeatureMap, x: &Tensor) -> Result<Tensor> {
        let (g, n, _d) = batched_dims(x, "reference features x")?;
        let feat = map.reference.num_features();
        Ok(Self::per_problem(g, &[g, n, feat], |gi| {
            map.reference.apply(&x.problem2(gi))
        }))
    }

    fn linear(
        &self,
        phi_q: &Tensor,
        phi_k: &Tensor,
        v: &Tensor,
        causal: bool,
        eps: f32,
    ) -> Result<Tensor> {
        let (g, n, _feat) = batched_dims(phi_q, "reference linear phi_q")?;
        let (_, _, dv) = batched_dims(v, "reference linear v")?;
        Ok(Self::per_problem(g, &[g, n, dv], |gi| {
            oracle::linear_attention(
                &phi_q.problem2(gi),
                &phi_k.problem2(gi),
                &v.problem2(gi),
                causal,
                eps,
            )
        }))
    }

    fn phi_row(&self, map: &FeatureMap, x_scaled: &[f32]) -> Result<Vec<f32>> {
        Ok(map.reference.apply_row(x_scaled))
    }
}

/// The engineered host tier: `crate::fastpath` batched kernels over the
/// persistent worker pool, with the runtime-dispatched SIMD arm
/// (AVX2+FMA where available, scalar otherwise — `MACFORMER_NO_SIMD=1`
/// pins the scalar arm). The slice-level `_into` methods are true
/// zero-allocation paths.
#[derive(Debug, Default, Clone, Copy)]
pub struct HostFastBackend;

impl AttentionBackend for HostFastBackend {
    fn name(&self) -> &'static str {
        // matches Backend::HostFast's Display/FromStr token, so
        // backend_name() round-trips through Backend::from_str
        "host"
    }

    fn available(&self) -> bool {
        true
    }

    fn softmax(&self, q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Result<Tensor> {
        batched_dims(q, "host_fast softmax q")?;
        Ok(fastpath::softmax_attention_batched(q, k, v, causal))
    }

    fn kernelized(
        &self,
        kernel: Kernel,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        causal: bool,
        eps: f32,
    ) -> Result<Tensor> {
        kernel.value_fn()?; // reject the exact baseline with a clean error
        batched_dims(q, "host_fast kernelized q")?;
        Ok(fastpath::kernelized_attention_batched(kernel, q, k, v, causal, eps))
    }

    fn features(&self, map: &FeatureMap, x: &Tensor) -> Result<Tensor> {
        batched_dims(x, "host_fast features x")?;
        Ok(fastpath::apply_map_batched(&map.flat, x))
    }

    fn linear(
        &self,
        phi_q: &Tensor,
        phi_k: &Tensor,
        v: &Tensor,
        causal: bool,
        eps: f32,
    ) -> Result<Tensor> {
        batched_dims(phi_q, "host_fast linear phi_q")?;
        Ok(fastpath::linear_attention_batched(phi_q, phi_k, v, causal, eps))
    }

    fn phi_row(&self, map: &FeatureMap, x_scaled: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; map.flat.num_features()];
        map.flat.apply_into(x_scaled, 1, &mut out);
        Ok(out)
    }

    // Zero-alloc slice paths: straight into the fastpath batched
    // drivers, no tensor round-trips.

    #[allow(clippy::too_many_arguments)]
    fn softmax_into(
        &self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        g: usize,
        n: usize,
        m: usize,
        d: usize,
        dv: usize,
        causal: bool,
        out: &mut [f32],
    ) -> Result<()> {
        fastpath::parallel::softmax_attention_batched_into(q, k, v, g, n, m, d, dv, causal, out);
        Ok(())
    }

    fn features_into(
        &self,
        map: &FeatureMap,
        x: &[f32],
        g: usize,
        n: usize,
        d: usize,
        out: &mut [f32],
    ) -> Result<()> {
        fastpath::parallel::apply_map_batched_into(&map.flat, x, g, n, d, out);
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn linear_into(
        &self,
        phi_q: &[f32],
        phi_k: &[f32],
        v: &[f32],
        g: usize,
        n: usize,
        m: usize,
        feat: usize,
        dv: usize,
        causal: bool,
        eps: f32,
        out: &mut [f32],
    ) -> Result<()> {
        fastpath::parallel::linear_attention_batched_into(
            phi_q, phi_k, v, g, n, m, feat, dv, causal, eps, out,
        );
        Ok(())
    }

    fn phi_row_into(&self, map: &FeatureMap, x_scaled: &[f32], out: &mut [f32]) -> Result<()> {
        map.flat.apply_into(x_scaled, 1, out);
        Ok(())
    }

    fn phi_rows_into(
        &self,
        map: &FeatureMap,
        x_scaled: &[f32],
        rows: usize,
        d: usize,
        out: &mut [f32],
    ) -> Result<()> {
        // Row blocks over the pool instead of `rows` one-row problems:
        // small micro-batches behave as before (block width 1 at low
        // rows-per-thread), prompt-sized row sets become a handful of
        // healthy GEMM shards. Row-for-row bit-identical either way.
        fastpath::parallel::apply_map_rows_into(&map.flat, x_scaled, rows, d, out);
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn prefill_fold_into(
        &self,
        phi_q: &[f32],
        phi_k: &[f32],
        v: &[f32],
        n: usize,
        feat: usize,
        dv: usize,
        chunk: usize,
        eps: f32,
        s: &mut [f32],
        z: &mut [f32],
        out: &mut [f32],
    ) {
        fastpath::attention::causal_prefill_fold_into(
            phi_q, phi_k, v, n, feat, dv, chunk, eps, s, z, out,
        );
    }
}

/// PJRT device execution.
///
/// Today this tier serves only the precompiled per-shape microbench
/// modules (`macformer microbench --backend device`); generic-shape
/// execution needs an artifact story a later PR supplies. Every trait
/// op therefore returns a descriptive `Err` — on the vendored stub
/// because no runtime exists, and on a real PJRT build because no
/// artifact matches an arbitrary `(g, n, d)` problem. [`select`] never
/// auto-picks it.
#[derive(Debug, Default, Clone, Copy)]
pub struct DeviceBackend;

impl DeviceBackend {
    /// Why this tier cannot run the requested op right now.
    fn unavailable(&self, op: &str) -> anyhow::Error {
        match crate::runtime::client::describe() {
            Err(e) => anyhow!(
                "device backend cannot run {op}: PJRT runtime unavailable ({e}); \
                 use Backend::HostFast or Backend::Auto"
            ),
            Ok(desc) => anyhow!(
                "device backend cannot run {op}: PJRT present ({desc}) but generic-shape \
                 attention needs compiled artifacts — run `macformer microbench --backend \
                 device` for the precompiled grid, or use Backend::HostFast"
            ),
        }
    }

    /// Could the device tier execute arbitrary-shape sessions? Always
    /// false until a generic artifact/compile path lands.
    pub fn can_execute() -> bool {
        false
    }
}

impl AttentionBackend for DeviceBackend {
    fn name(&self) -> &'static str {
        "device"
    }

    fn available(&self) -> bool {
        crate::runtime::client::describe().is_ok()
    }

    fn softmax(&self, _q: &Tensor, _k: &Tensor, _v: &Tensor, _causal: bool) -> Result<Tensor> {
        Err(self.unavailable("softmax attention"))
    }

    fn kernelized(
        &self,
        _kernel: Kernel,
        _q: &Tensor,
        _k: &Tensor,
        _v: &Tensor,
        _causal: bool,
        _eps: f32,
    ) -> Result<Tensor> {
        Err(self.unavailable("kernelized attention"))
    }

    fn features(&self, _map: &FeatureMap, _x: &Tensor) -> Result<Tensor> {
        Err(self.unavailable("the RMF feature map"))
    }

    fn linear(
        &self,
        _phi_q: &Tensor,
        _phi_k: &Tensor,
        _v: &Tensor,
        _causal: bool,
        _eps: f32,
    ) -> Result<Tensor> {
        Err(self.unavailable("linear attention"))
    }

    fn phi_row(&self, _map: &FeatureMap, _x_scaled: &[f32]) -> Result<Vec<f32>> {
        Err(self.unavailable("streaming decode"))
    }
}

/// Resolve a backend preference to a concrete tier. `Auto` picks the
/// device tier only when it can actually execute generic shapes (never,
/// today) and otherwise the host fast path — so `Auto` is always safe.
pub fn select(choice: Backend) -> Box<dyn AttentionBackend> {
    match choice {
        Backend::Reference => Box::new(ReferenceBackend),
        Backend::HostFast => Box::new(HostFastBackend),
        Backend::Device => Box::new(DeviceBackend),
        Backend::Auto => {
            if DeviceBackend::can_execute() {
                Box::new(DeviceBackend)
            } else {
                Box::new(HostFastBackend)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_selects_a_usable_backend() {
        let b = select(Backend::Auto);
        assert!(b.available(), "auto must resolve to a usable tier");
        assert_eq!(b.name(), "host");
    }

    #[test]
    fn backend_names_round_trip_through_from_str() {
        use std::str::FromStr;
        for choice in [Backend::Reference, Backend::HostFast, Backend::Device] {
            let tier = select(choice);
            assert_eq!(Backend::from_str(tier.name()), Ok(choice), "{choice}");
        }
    }

    #[test]
    fn device_ops_error_cleanly() {
        let dev = DeviceBackend;
        let t = Tensor::zeros(&[1, 2, 3]);
        let err = dev.softmax(&t, &t, &t, false).unwrap_err();
        assert!(err.to_string().contains("device backend"), "{err}");
    }

    #[test]
    fn phi_rows_into_is_row_for_row_phi_row() {
        use crate::reference::rmf::RmfMap;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xBA7C);
        let reference = RmfMap::sample(&mut rng, Kernel::Exp, 20, 4, 2.0, 8);
        let flat = crate::fastpath::FlatRmfMap::from(&reference);
        let map = FeatureMap { reference, flat };
        let feat = map.reference.num_features();
        let rows = 6usize;
        let x: Vec<f32> = (0..rows * 4).map(|_| rng.normal() * 0.5).collect();
        let tiers: [&dyn AttentionBackend; 2] = [&ReferenceBackend, &HostFastBackend];
        for b in tiers {
            let mut batched = vec![0.0f32; rows * feat];
            b.phi_rows_into(&map, &x, rows, 4, &mut batched).unwrap();
            for r in 0..rows {
                let mut one = vec![0.0f32; feat];
                b.phi_row_into(&map, &x[r * 4..(r + 1) * 4], &mut one).unwrap();
                let rows_eq = batched[r * feat..(r + 1) * feat].iter().zip(&one);
                for (j, (a, e)) in rows_eq.enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        e.to_bits(),
                        "{}: row {r} feature {j}: {a} vs {e}",
                        b.name()
                    );
                }
            }
        }
    }

    #[test]
    fn prefill_fold_state_is_bit_compatible_across_tiers_and_chunks() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0xF01D);
        let (n, feat, dv) = (23usize, 7usize, 3usize);
        let phi_q: Vec<f32> = (0..n * feat).map(|_| rng.normal().abs()).collect();
        let phi_k: Vec<f32> = (0..n * feat).map(|_| rng.normal().abs()).collect();
        let v: Vec<f32> = (0..n * dv).map(|_| rng.normal()).collect();
        // the oracle fold (trait default on the reference tier)
        let mut s0 = vec![0.0f32; feat * dv];
        let mut z0 = vec![0.0f32; feat];
        let mut out0 = vec![0.0f32; n * dv];
        ReferenceBackend.prefill_fold_into(
            &phi_q, &phi_k, &v, n, feat, dv, 8, 1e-6, &mut s0, &mut z0, &mut out0,
        );
        for chunk in [1usize, 4, 9, 64] {
            let mut s = vec![0.0f32; feat * dv];
            let mut z = vec![0.0f32; feat];
            let mut out = vec![0.0f32; n * dv];
            HostFastBackend.prefill_fold_into(
                &phi_q, &phi_k, &v, n, feat, dv, chunk, 1e-6, &mut s, &mut z, &mut out,
            );
            assert_eq!(s, s0, "chunk {chunk}: S state drifted from the fold");
            assert_eq!(z, z0, "chunk {chunk}: z state drifted from the fold");
            for (i, (a, b)) in out.iter().zip(&out0).enumerate() {
                assert!((a - b).abs() < 1e-5, "chunk {chunk} elem {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn kernelized_rejects_softmax_kernel() {
        let t = Tensor::zeros(&[1, 2, 3]);
        let tiers: [&dyn AttentionBackend; 2] = [&ReferenceBackend, &HostFastBackend];
        for b in tiers {
            let err = b.kernelized(Kernel::Softmax, &t, &t, &t, false, 0.0).unwrap_err();
            assert!(err.to_string().contains("no Maclaurin expansion"), "{err}");
        }
    }
}
