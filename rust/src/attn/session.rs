//! [`AttentionSession`] — a built attention configuration that owns one
//! RMF feature-map draw across all its calls, plus [`CausalState`], the
//! O(1)-per-token streaming decode state.
//!
//! A session is the unit of determinism: the map is sampled exactly
//! once (from `spec.seed`) at build time, so repeated `forward()` calls
//! — and the streaming decode path — all see the same features. The
//! batched and streaming causal paths are proved equal by
//! `tests/attn_api.rs`.
//!
//! # Scratch arena
//!
//! Each session owns a grow-only scratch arena (behind a `Mutex`)
//! holding the scaled-input and phi staging buffers, and every kernel-
//! level buffer (logits blocks, `(S, z)` accumulators) lives in
//! thread-local scratch inside `crate::fastpath`. Together with the
//! persistent worker pool this makes steady-state
//! [`AttentionSession::forward_into`] calls **zero-allocation** after
//! the first (warmup) call — enforced by `tests/alloc_free.rs`.
//! Concurrent `forward` calls on one session are safe but serialize on
//! the arena; use one session per thread for parallel inference.

use std::borrow::Cow;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::fastpath::attention::{causal_chunk, causal_fold_key, causal_fold_query};
use crate::fastpath::{grow, simd, FlatRmfMap};
use crate::reference::rmf::RmfMap;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::backend::{select, AttentionBackend};
use super::kernel::Kernel;
use super::spec::AttentionSpec;

/// The session's single feature-map draw, in both layouts: the
/// reference `RmfMap` (scalar oracle) and the degree-grouped
/// `FlatRmfMap` (GEMM layout). The two are equivalent (bit-for-bit on
/// the scalar dispatch arm, within `1e-5` on the SIMD arm), so every
/// backend sees the same features.
pub struct FeatureMap {
    /// Scalar per-feature layout (`crate::reference::rmf`).
    pub reference: RmfMap,
    /// Degree-grouped GEMM layout (`crate::fastpath::flat_rmf`).
    pub flat: FlatRmfMap,
}

/// Grow-only session-owned staging buffers for the forward path. Every
/// used prefix is fully overwritten before being read, so nothing
/// bleeds between calls of different shapes.
#[derive(Default)]
struct Scratch {
    /// Score-scaled q, `g * n * d`.
    qs: Vec<f32>,
    /// Score-scaled k, `g * m * d`.
    ks: Vec<f32>,
    /// phi(q'), `g * n * D`.
    phi_q: Vec<f32>,
    /// phi(k'), `g * m * D`.
    phi_k: Vec<f32>,
}

/// Validated batched dimensions of one forward call.
struct Dims {
    g: usize,
    n: usize,
    m: usize,
    d: usize,
    dv: usize,
    was_2d: bool,
}

/// A built attention configuration: spec + resolved backend + (for
/// Table-1 kernels) the one feature-map draw it owns.
pub struct AttentionSession {
    spec: AttentionSpec,
    backend: Box<dyn AttentionBackend>,
    map: Option<FeatureMap>,
    scratch: Mutex<Scratch>,
}

impl AttentionSession {
    /// Build from a validated spec (called by [`AttentionSpec::build`]).
    pub(crate) fn build(spec: AttentionSpec) -> Result<AttentionSession> {
        let backend = select(spec.backend);
        let map = if spec.kernel.has_maclaurin() {
            let mut rng = Rng::new(spec.seed);
            let reference = RmfMap::sample(
                &mut rng,
                spec.kernel,
                spec.num_features,
                spec.head_dim,
                spec.p,
                spec.max_degree,
            );
            let flat = FlatRmfMap::from(&reference);
            Some(FeatureMap { reference, flat })
        } else {
            None
        };
        Ok(AttentionSession {
            spec,
            backend,
            map,
            scratch: Mutex::new(Scratch::default()),
        })
    }

    /// The spec this session was built from.
    pub fn spec(&self) -> &AttentionSpec {
        &self.spec
    }

    /// Name of the resolved backend tier (`Auto` is resolved at build).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The session's feature-map draw (`None` for `Kernel::Softmax`).
    pub fn feature_map(&self) -> Option<&FeatureMap> {
        self.map.as_ref()
    }

    /// `d^(-1/4)`: inputs are scaled by this before phi so that
    /// `phi(q') . phi(k')` estimates `K(q.k / sqrt(d))` — the kernel at
    /// attention-score scale.
    fn input_scale(&self, d: usize) -> f32 {
        1.0 / (d as f32).sqrt().sqrt()
    }

    /// Shape-check one forward call without copying anything: rank-2
    /// tensors are viewed as `g = 1`, rank-3 as `(g, n, d)`.
    fn checked_dims(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Result<Dims> {
        let view = |t: &Tensor, what: &str| -> Result<(usize, usize, usize)> {
            match t.rank() {
                3 => Ok((t.shape[0], t.shape[1], t.shape[2])),
                2 => Ok((1, t.shape[0], t.shape[1])),
                r => Err(anyhow!("{what}: expected rank 2 or 3, got rank {r} ({:?})", t.shape)),
            }
        };
        let was_2d = q.rank() == 2;
        let (g, n, d) = view(q, "forward q")?;
        let (gk, m, dk) = view(k, "forward k")?;
        let (gv, mv, dv) = view(v, "forward v")?;
        if (g, d) != (gk, dk) {
            bail!("forward: q {:?} and k {:?} disagree on (g, d)", q.shape, k.shape);
        }
        if (gk, m) != (gv, mv) {
            bail!("forward: k {:?} and v {:?} disagree on (g, m)", k.shape, v.shape);
        }
        if self.spec.causal && n != m {
            bail!(
                "forward: causal attention needs n == m (one prefix per position), \
                 got n = {n}, m = {m}"
            );
        }
        if self.spec.kernel.has_maclaurin() && d != self.spec.head_dim {
            bail!(
                "forward: this session's feature map was sampled for head_dim = {}, \
                 got inputs with d = {d}",
                self.spec.head_dim
            );
        }
        Ok(Dims { g, n, m, d, dv, was_2d })
    }

    fn checked_inputs<'t>(
        &self,
        q: &'t Tensor,
        k: &'t Tensor,
        v: &'t Tensor,
    ) -> Result<(Cow<'t, Tensor>, Cow<'t, Tensor>, Cow<'t, Tensor>, bool)> {
        let promote = |t: &'t Tensor, what: &str| -> Result<Cow<'t, Tensor>> {
            match t.rank() {
                3 => Ok(Cow::Borrowed(t)),
                2 => Ok(Cow::Owned(Tensor::from_vec(
                    &[1, t.shape[0], t.shape[1]],
                    t.data.clone(),
                ))),
                r => Err(anyhow!("{what}: expected rank 2 or 3, got rank {r} ({:?})", t.shape)),
            }
        };
        // shared validation, then the Cow promotion the quadratic
        // tensor-level paths still use
        self.checked_dims(q, k, v)?;
        let was_2d = q.rank() == 2;
        let q3 = promote(q, "forward q")?;
        let k3 = promote(k, "forward k")?;
        let v3 = promote(v, "forward v")?;
        Ok((q3, k3, v3, was_2d))
    }

    fn demote(out: Tensor, was_2d: bool) -> Tensor {
        if was_2d {
            let (n, dv) = (out.shape[1], out.shape[2]);
            Tensor::from_vec(&[n, dv], out.data)
        } else {
            out
        }
    }

    /// Run attention on `(g, n, d)` q/k and `(g, m, dv)` v (rank-2
    /// single-problem inputs are promoted to `g = 1` and the output
    /// demoted back).
    ///
    /// * `Kernel::Softmax` — exact attention.
    /// * Table-1 kernels — the linear RMFA path: inputs are scaled to
    ///   score scale, mapped through the session's phi draw, and
    ///   contracted via running `(S, z)` state (O(n) total).
    ///
    /// Allocates the output tensor; reuse one via [`forward_into`](Self::forward_into)
    /// for allocation-free steady state.
    pub fn forward(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Result<Tensor> {
        let mut out = Tensor { shape: Vec::new(), data: Vec::new() };
        self.forward_into(q, k, v, &mut out)?;
        Ok(out)
    }

    /// [`forward`](Self::forward) into a caller-owned output tensor,
    /// which is reshaped and resized as needed (grow-only data buffer).
    /// After a warmup call per shape, repeated calls make **zero heap
    /// allocations**: inputs are scaled and phi-mapped inside the
    /// session's scratch arena and the kernels run out of thread-local
    /// workspaces. On error the output's contents are unspecified.
    pub fn forward_into(
        &self,
        q: &Tensor,
        k: &Tensor,
        v: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        let Dims { g, n, m, d, dv, was_2d } = self.checked_dims(q, k, v)?;
        // reshape in place: clear + extend reuses the shape vec's capacity
        out.shape.clear();
        if was_2d {
            out.shape.extend_from_slice(&[n, dv]);
        } else {
            out.shape.extend_from_slice(&[g, n, dv]);
        }
        out.data.resize(g * n * dv, 0.0);
        match self.spec.kernel {
            Kernel::Softmax => self.backend.softmax_into(
                &q.data,
                &k.data,
                &v.data,
                g,
                n,
                m,
                d,
                dv,
                self.spec.causal,
                &mut out.data,
            ),
            _ => {
                let map = self.map.as_ref().expect("Maclaurin session always has a map");
                let feat = map.flat.num_features();
                let scale = self.input_scale(d);
                // A panicking kernel shard unwinds through this guard and
                // poisons the lock; the scratch holds no invariants (every
                // used prefix is overwritten before reads), so recover the
                // buffers instead of bricking the session forever.
                let mut scratch =
                    self.scratch.lock().unwrap_or_else(|poison| poison.into_inner());
                let sc = &mut *scratch;
                grow(&mut sc.qs, g * n * d);
                grow(&mut sc.ks, g * m * d);
                grow(&mut sc.phi_q, g * n * feat);
                grow(&mut sc.phi_k, g * m * feat);
                simd::scaled_copy(&q.data, scale, &mut sc.qs[..g * n * d]);
                simd::scaled_copy(&k.data, scale, &mut sc.ks[..g * m * d]);
                self.backend.features_into(
                    map,
                    &sc.qs[..g * n * d],
                    g,
                    n,
                    d,
                    &mut sc.phi_q[..g * n * feat],
                )?;
                self.backend.features_into(
                    map,
                    &sc.ks[..g * m * d],
                    g,
                    m,
                    d,
                    &mut sc.phi_k[..g * m * feat],
                )?;
                self.backend.linear_into(
                    &sc.phi_q[..g * n * feat],
                    &sc.phi_k[..g * m * feat],
                    &v.data,
                    g,
                    n,
                    m,
                    feat,
                    dv,
                    self.spec.causal,
                    self.spec.eps,
                    &mut out.data,
                )
            }
        }
    }

    /// Batched single-token phi: `rows` pre-scaled rows of length
    /// `head_dim` (one flat `rows * head_dim` slice) mapped to `rows *
    /// D` features. This is the serve scheduler's batched decode entry
    /// point — one `(g, 1, d)` feature step across a micro-batch of
    /// streams, dispatched through the backend (the host tier shards
    /// rows over the persistent worker pool with zero steady-state
    /// allocations). Row `i` of the output is bit-identical to what the
    /// single-stream decode path computes for the same input row.
    pub fn phi_rows_into(&self, x_scaled: &[f32], rows: usize, out: &mut [f32]) -> Result<()> {
        let map = self.map.as_ref().ok_or_else(|| {
            anyhow!("phi_rows_into: kernel {} has no feature map", self.spec.kernel)
        })?;
        let d = self.spec.head_dim;
        if x_scaled.len() != rows * d {
            bail!(
                "phi_rows_into: expected {rows} rows x head_dim {d} = {} inputs, got {}",
                rows * d,
                x_scaled.len()
            );
        }
        let feat = map.flat.num_features();
        if out.len() != rows * feat {
            bail!(
                "phi_rows_into: expected {rows} rows x {feat} features = {} outputs, got {}",
                rows * feat,
                out.len()
            );
        }
        self.backend.phi_rows_into(map, x_scaled, rows, d, out)
    }

    /// The pre-phi input scale for this session's `head_dim` —
    /// `d^(-1/4)`, applied to q/k rows before the feature map so the
    /// phi dot product estimates the kernel at attention-score scale.
    /// The serve scheduler scales its gathered micro-batch with this,
    /// matching [`CausalState::append_token_into`] bit for bit.
    pub(crate) fn decode_scale(&self) -> f32 {
        self.input_scale(self.spec.head_dim)
    }

    /// The quadratic oracle this session's `forward` approximates:
    /// exact softmax for `Kernel::Softmax`, otherwise Definition-2
    /// kernelized attention with the session's kernel (O(n^2)). Useful
    /// for NMSE measurement.
    pub fn forward_exact(&self, q: &Tensor, k: &Tensor, v: &Tensor) -> Result<Tensor> {
        let (q3, k3, v3, was_2d) = self.checked_inputs(q, k, v)?;
        let out = match self.spec.kernel {
            Kernel::Softmax => self.backend.softmax(&q3, &k3, &v3, self.spec.causal)?,
            kernel => {
                self.backend
                    .kernelized(kernel, &q3, &k3, &v3, self.spec.causal, self.spec.eps)?
            }
        };
        Ok(Self::demote(out, was_2d))
    }

    /// Start an O(1)-per-token streaming decode for one problem (one
    /// head) producing `dv`-dimensional outputs. Requires a causal
    /// session with a Table-1 kernel; matches the batched causal
    /// `forward()` token-for-token. The state owns its own scratch
    /// (running accumulators + a phi staging row), so decode and
    /// batched `forward` calls interleave freely on one session.
    pub fn begin_decode(&self, dv: usize) -> Result<CausalState<'_>> {
        if !self.spec.causal {
            bail!(
                "begin_decode: streaming decode is causal by construction; build the \
                 session with .causal(true) so batched and streaming outputs agree"
            );
        }
        if !self.spec.kernel.has_maclaurin() {
            bail!(
                "begin_decode: kernel {} has no feature map, so no O(1) running-state \
                 decode exists (exact softmax needs the full key/value history)",
                self.spec.kernel
            );
        }
        if dv == 0 {
            bail!("begin_decode: dv must be > 0");
        }
        // Surface device-tier unavailability at decode start, not on the
        // first token.
        let map = self.map.as_ref().expect("Maclaurin session always has a map");
        let probe = vec![0.0f32; self.spec.head_dim];
        self.backend.phi_row(map, &probe)?;
        let feat = map.reference.num_features();
        Ok(CausalState {
            session: self,
            dv,
            s: vec![0.0f32; feat * dv],
            z: vec![0.0f32; feat],
            q_scaled: vec![0.0f32; self.spec.head_dim],
            k_scaled: vec![0.0f32; self.spec.head_dim],
            phi: vec![0.0f32; feat],
            prefill_x: Vec::new(),
            prefill_phi_q: Vec::new(),
            prefill_phi_k: Vec::new(),
            len: 0,
        })
    }
}

/// Running `(S, z)` decode state: `S = sum_j phi(k_j) v_j^T` (feat x dv)
/// and `z = sum_j phi(k_j)`. Each [`CausalState::append_token`] folds
/// one `(q, k, v)` row in and emits that position's attention output in
/// O(D * dv) time and O(D * dv) memory — independent of the sequence
/// length, the linear-attention decoding story of Performer/RFA.
///
/// All per-token staging (scaled rows, the phi row) is owned by the
/// state and reused, so [`CausalState::append_token_into`] is
/// allocation-free after construction.
///
/// Whole prompts are ingested in one call by the chunkwise-parallel
/// [`CausalState::prefill_into`] — GEMM-dominated blocked compute over
/// `MACFORMER_CHUNK`-token chunks that leaves the state bit-identical
/// to having folded the prompt token by token, so streaming
/// `append_token` continues seamlessly.
pub struct CausalState<'s> {
    session: &'s AttentionSession,
    dv: usize,
    /// feat x dv running value accumulator.
    s: Vec<f32>,
    /// feat running normalizer accumulator.
    z: Vec<f32>,
    /// Reused per-token scratch for the score-scaled q/k rows.
    q_scaled: Vec<f32>,
    k_scaled: Vec<f32>,
    /// Reused per-token phi staging row (first phi(k'), then phi(q')).
    phi: Vec<f32>,
    /// Grow-only prefill staging: score-scaled prompt rows (n x d),
    /// reused for k then q. Empty until the first prefill.
    prefill_x: Vec<f32>,
    /// Grow-only prefill staging: phi(q') prompt rows (n x D).
    prefill_phi_q: Vec<f32>,
    /// Grow-only prefill staging: phi(k') prompt rows (n x D).
    prefill_phi_k: Vec<f32>,
    len: usize,
}

// The `(S, z)` fold halves live in `crate::fastpath::attention`
// ([`causal_fold_key`] / [`causal_fold_query`]) and are shared verbatim
// by the single-stream [`CausalState::append_token_into`] path, the
// serve scheduler's micro-batched [`CausalState::fold_token_into`]
// path, and the sequential arm of the chunked prefill kernel — so no
// causal path can drift from another.

impl CausalState<'_> {
    /// Tokens consumed so far.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True before the first token.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Output row length this state was started with.
    pub fn dv(&self) -> usize {
        self.dv
    }

    /// Rewind to the empty prefix: zero the `(S, z)` accumulators and
    /// the token count, keeping every buffer (so a serve slot can be
    /// retired and re-admitted without reallocating). Equivalent to a
    /// fresh [`AttentionSession::begin_decode`] on the same session.
    pub fn reset(&mut self) {
        self.s.fill(0.0);
        self.z.fill(0.0);
        self.len = 0;
    }

    /// Fold in one token and return its attention output (length `dv`).
    /// Allocates the output row; use
    /// [`append_token_into`](Self::append_token_into) for the
    /// allocation-free form.
    ///
    /// Serve-adjacent code must not call this: anything on a
    /// steady-state serving path goes through
    /// [`append_token_into`](Self::append_token_into) /
    /// [`prefill_into`](Self::prefill_into) so the zero-allocation
    /// contract (`tests/alloc_free.rs`) holds. This allocating form
    /// exists for exploratory and test code only.
    pub fn append_token(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.dv];
        self.append_token_into(q, k, v, &mut out)?;
        Ok(out)
    }

    /// Fold in one token, writing its attention output into a caller-
    /// owned `dv`-length row. Zero allocations in steady state.
    ///
    /// The key/value update happens before the query read — position i
    /// attends to positions `0..=i`, exactly like the batched causal
    /// path.
    pub fn append_token_into(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        let spec = self.session.spec();
        let d = spec.head_dim;
        if q.len() != d || k.len() != d {
            bail!(
                "append_token: q/k rows must have length head_dim = {d}, got {} and {}",
                q.len(),
                k.len()
            );
        }
        if v.len() != self.dv {
            bail!("append_token: v row must have length dv = {}, got {}", self.dv, v.len());
        }
        if out.len() != self.dv {
            bail!(
                "append_token: out row must have length dv = {}, got {}",
                self.dv,
                out.len()
            );
        }
        let map = self.session.feature_map().expect("decode state implies a map");
        let scale = self.session.input_scale(d);
        simd::scaled_copy(q, scale, &mut self.q_scaled);
        simd::scaled_copy(k, scale, &mut self.k_scaled);
        self.session.backend.phi_row_into(map, &self.k_scaled, &mut self.phi)?;
        causal_fold_key(&self.phi, v, &mut self.z, &mut self.s, self.dv);
        self.session.backend.phi_row_into(map, &self.q_scaled, &mut self.phi)?;
        causal_fold_query(&self.phi, &self.z, &self.s, self.dv, spec.eps, out);
        self.len += 1;
        Ok(())
    }

    /// Fold in one token whose phi rows were already computed (the
    /// serve scheduler's path: phi over the whole micro-batch in one
    /// `(g, 1, d)` backend step, then this per-stream fold). Runs the
    /// exact same [`causal_fold_key`]/[`causal_fold_query`] code as
    /// [`append_token_into`](Self::append_token_into), so batched and
    /// single-stream decode are bit-identical by construction.
    ///
    /// Lengths are the caller's contract (`debug_assert`ed): `phi_k`
    /// and `phi_q` are `D`-length feature rows of the *scaled* k/q
    /// rows, `v` and `out` are `dv`-length.
    /// Returns the raw fold denominator `phi_q . z` so the serve
    /// scheduler can run its denominator-health check.
    pub(crate) fn fold_token_into(
        &mut self,
        phi_k: &[f32],
        phi_q: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) -> f32 {
        debug_assert_eq!(phi_k.len(), self.z.len(), "fold_token_into: phi_k len");
        debug_assert_eq!(phi_q.len(), self.z.len(), "fold_token_into: phi_q len");
        debug_assert_eq!(v.len(), self.dv, "fold_token_into: v len");
        debug_assert_eq!(out.len(), self.dv, "fold_token_into: out len");
        causal_fold_key(phi_k, v, &mut self.z, &mut self.s, self.dv);
        let den =
            causal_fold_query(phi_q, &self.z, &self.s, self.dv, self.session.spec().eps, out);
        self.len += 1;
        den
    }

    /// Exact byte length of this stream's snapshot record:
    /// `D*dv + D` floats plus an O(1) header/checksum (see
    /// `tensor::io::state_record_len`).
    pub fn snapshot_len(&self) -> usize {
        crate::tensor::io::state_record_len(self.z.len(), self.dv)
    }

    /// Serialize the full decode state — `(S, z)` and the token count —
    /// into `buf` as a versioned, checksummed record (cleared first;
    /// capacity is reused across calls, so a warm hibernation arena
    /// makes no allocations). The record restores **bit-identically**:
    /// a stream that hibernates and resumes produces the same output
    /// bits as one that never left RAM.
    pub fn snapshot_into(&self, buf: &mut Vec<u8>) {
        crate::tensor::io::write_state_record(buf, self.len as u64, &self.s, &self.z);
    }

    /// Restore a snapshot taken by [`snapshot_into`](Self::snapshot_into)
    /// on a state with the same `(D, dv)` geometry (same session spec).
    /// The record is validated in full before anything is written, so a
    /// corrupt or mismatched record leaves the state untouched.
    pub fn restore_from(&mut self, bytes: &[u8]) -> Result<()> {
        let step = crate::tensor::io::read_state_record(bytes, &mut self.s, &mut self.z)
            .map_err(|e| anyhow!("restore_from: {e}"))?;
        self.len = step as usize;
        Ok(())
    }

    /// Ingest a whole prompt in chunks (the chunkwise-parallel prefill),
    /// leaving the state positioned for streaming
    /// [`append_token_into`](Self::append_token_into) of the
    /// continuation. Returns every prompt position's attention output
    /// (`n * dv`). Allocates the output; use
    /// [`prefill_into`](Self::prefill_into) for the steady-state
    /// allocation-free form.
    pub fn prefill(&mut self, q: &[f32], k: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let d = self.session.spec().head_dim;
        let n = q.len() / d.max(1);
        let mut out = vec![0.0f32; n * self.dv];
        self.prefill_into(q, k, v, &mut out)?;
        Ok(out)
    }

    /// [`prefill`](Self::prefill) into a caller-owned `n * dv` output
    /// buffer, with the chunk width from `MACFORMER_CHUNK` (see
    /// `fastpath::attention::causal_chunk`; width 1 degenerates to the
    /// sequential token-by-token fold).
    ///
    /// `q` and `k` are `n * head_dim` row-major prompt rows, `v` is
    /// `n * dv`. The prompt is scaled and phi-mapped in bulk (the host
    /// tier shards feature rows over the persistent worker pool), then
    /// folded chunkwise into the running `(S, z)` state. After a warmup
    /// call per prompt shape, repeated prefill makes **zero heap
    /// allocations** (grow-only staging owned by this state).
    ///
    /// The state this leaves behind is **bit-identical** to
    /// `append_token`-ing the same prompt row by row on the same
    /// backend and SIMD arm, so a prefixed stream's continuation
    /// decodes bit-compatibly with a decode-from-scratch stream. The
    /// prompt *outputs* carry the chunked kernel's `1e-5` equivalence
    /// contract instead (chunk width 1 reproduces the fold bit for
    /// bit). On error the state is unchanged.
    pub fn prefill_into(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) -> Result<()> {
        self.prefill_with_chunk_into(q, k, v, causal_chunk(), out)
    }

    /// [`prefill_into`](Self::prefill_into) with an explicit chunk
    /// width (clamped to >= 1) — the chunk-sweep entry point for tests
    /// and benches.
    pub fn prefill_with_chunk_into(
        &mut self,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        chunk: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let spec = self.session.spec();
        let d = spec.head_dim;
        if q.len() != k.len() || q.len() % d != 0 {
            bail!(
                "prefill: q/k must hold whole rows of head_dim = {d}, got lengths {} and {}",
                q.len(),
                k.len()
            );
        }
        let n = q.len() / d;
        if v.len() != n * self.dv {
            bail!(
                "prefill: v must hold {n} rows of dv = {}, got length {}",
                self.dv,
                v.len()
            );
        }
        if out.len() != n * self.dv {
            bail!(
                "prefill: out must hold {n} rows of dv = {}, got length {}",
                self.dv,
                out.len()
            );
        }
        if n == 0 {
            return Ok(());
        }
        let map = self.session.feature_map().expect("decode state implies a map");
        let feat = self.z.len();
        let scale = self.session.input_scale(d);
        grow(&mut self.prefill_x, n * d);
        grow(&mut self.prefill_phi_q, n * feat);
        grow(&mut self.prefill_phi_k, n * feat);
        // Both fallible phi passes complete before the state is
        // touched, so an error leaves the state exactly as it was.
        simd::scaled_copy(k, scale, &mut self.prefill_x[..n * d]);
        self.session.backend.phi_rows_into(
            map,
            &self.prefill_x[..n * d],
            n,
            d,
            &mut self.prefill_phi_k[..n * feat],
        )?;
        simd::scaled_copy(q, scale, &mut self.prefill_x[..n * d]);
        self.session.backend.phi_rows_into(
            map,
            &self.prefill_x[..n * d],
            n,
            d,
            &mut self.prefill_phi_q[..n * feat],
        )?;
        self.session.backend.prefill_fold_into(
            &self.prefill_phi_q[..n * feat],
            &self.prefill_phi_k[..n * feat],
            v,
            n,
            feat,
            self.dv,
            chunk.max(1),
            spec.eps,
            &mut self.s,
            &mut self.z,
            out,
        );
        self.len += n;
        Ok(())
    }

    /// Chunked prefill over already-computed phi rows (the serve
    /// scheduler's path: the prompt is scaled and phi-mapped in the
    /// scheduler's scratch, then folded here). Lengths are the caller's
    /// contract (`debug_assert`ed): `phi_q`/`phi_k` are `n * D`, `v`
    /// and `out` are `n * dv`.
    pub(crate) fn prefill_phi_into(
        &mut self,
        phi_q: &[f32],
        phi_k: &[f32],
        v: &[f32],
        n: usize,
        chunk: usize,
        out: &mut [f32],
    ) {
        let feat = self.z.len();
        debug_assert_eq!(phi_q.len(), n * feat, "prefill_phi_into: phi_q len");
        debug_assert_eq!(phi_k.len(), n * feat, "prefill_phi_into: phi_k len");
        debug_assert_eq!(v.len(), n * self.dv, "prefill_phi_into: v len");
        debug_assert_eq!(out.len(), n * self.dv, "prefill_phi_into: out len");
        self.session.backend.prefill_fold_into(
            phi_q,
            phi_k,
            v,
            n,
            feat,
            self.dv,
            chunk.max(1),
            self.session.spec().eps,
            &mut self.s,
            &mut self.z,
            out,
        );
        self.len += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::spec::Backend;

    fn randn(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
        Tensor::randn(rng, shape, scale)
    }

    #[test]
    fn session_owns_one_map_draw() {
        let a = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(8)
            .seed(3)
            .build()
            .unwrap();
        let b = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(8)
            .seed(3)
            .build()
            .unwrap();
        let (ma, mb) = (a.feature_map().unwrap(), b.feature_map().unwrap());
        assert_eq!(ma.reference.degrees, mb.reference.degrees);
        assert_eq!(ma.reference.scales, mb.reference.scales);
    }

    #[test]
    fn rank2_inputs_round_trip() {
        let mut rng = Rng::new(5);
        let q = randn(&mut rng, &[6, 4], 0.5);
        let k = randn(&mut rng, &[6, 4], 0.5);
        let v = randn(&mut rng, &[6, 3], 1.0);
        let sess = AttentionSpec::new(Kernel::Softmax)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let out = sess.forward(&q, &k, &v).unwrap();
        assert_eq!(out.shape, vec![6, 3]);
        let oracle = crate::reference::attention::softmax_attention(&q, &k, &v, false);
        assert!(out.max_abs_diff(&oracle) < 1e-5);
    }

    #[test]
    fn forward_into_reuses_the_output_tensor() {
        let mut rng = Rng::new(15);
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(16)
            .seed(2)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let mut out = Tensor { shape: Vec::new(), data: Vec::new() };
        // big, then small, then big again: shapes must track the inputs
        // and results must equal fresh forward() calls (no stale state)
        for n in [40usize, 3, 40] {
            let q = randn(&mut rng, &[2, n, 4], 0.5);
            let k = randn(&mut rng, &[2, n, 4], 0.5);
            let v = randn(&mut rng, &[2, n, 3], 1.0);
            sess.forward_into(&q, &k, &v, &mut out).unwrap();
            assert_eq!(out.shape, vec![2, n, 3]);
            let fresh = sess.forward(&q, &k, &v).unwrap();
            assert_eq!(out.data[..2 * n * 3], fresh.data[..], "n={n}");
        }
    }

    #[test]
    fn causal_shape_mismatch_is_an_error_not_a_panic() {
        let mut rng = Rng::new(6);
        let q = randn(&mut rng, &[1, 4, 4], 0.5);
        let k = randn(&mut rng, &[1, 6, 4], 0.5);
        let v = randn(&mut rng, &[1, 6, 3], 1.0);
        let sess = AttentionSpec::new(Kernel::Softmax)
            .causal(true)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let err = sess.forward(&q, &k, &v).unwrap_err();
        assert!(err.to_string().contains("causal"), "{err}");
    }

    #[test]
    fn decode_requires_causal_maclaurin_session() {
        let not_causal =
            AttentionSpec::new(Kernel::Exp).head_dim(4).num_features(8).build().unwrap();
        assert!(not_causal.begin_decode(3).is_err());
        let softmax = AttentionSpec::new(Kernel::Softmax).causal(true).build().unwrap();
        assert!(softmax.begin_decode(3).is_err());
        let ok = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(8)
            .causal(true)
            .build()
            .unwrap();
        let state = ok.begin_decode(3).unwrap();
        assert!(state.is_empty());
    }

    #[test]
    fn begin_decode_rejects_dv_zero() {
        // regression: a dv = 0 decode state would hold empty (S, z)
        // accumulators and emit zero-length "outputs" forever
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(8)
            .causal(true)
            .build()
            .unwrap();
        let err = sess.begin_decode(0).unwrap_err();
        assert!(err.to_string().contains("dv"), "{err}");
    }

    #[test]
    fn append_token_rejects_mismatched_v_len() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(2)
            .num_features(8)
            .causal(true)
            .build()
            .unwrap();
        let mut state = sess.begin_decode(3).unwrap();
        // v shorter and longer than the dv the state was started with
        for bad_v in [vec![1.0f32; 2], vec![1.0f32; 4]] {
            let err = state.append_token(&[0.1, 0.2], &[0.3, 0.4], &bad_v).unwrap_err();
            assert!(err.to_string().contains("dv"), "{err}");
            assert!(state.is_empty(), "a rejected token must not advance the state");
        }
    }

    #[test]
    fn append_token_and_append_token_into_cannot_drift() {
        // drift guard: the alloc path delegates to the no-alloc path, so
        // two states fed the same random stream must agree bit for bit
        let sess = AttentionSpec::new(Kernel::Inv)
            .head_dim(5)
            .num_features(24)
            .causal(true)
            .seed(21)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let (d, dv, n) = (5usize, 3usize, 40usize);
        let mut rng = Rng::new(0xD21F7);
        let q = randn(&mut rng, &[n, d], 0.5);
        let k = randn(&mut rng, &[n, d], 0.5);
        let v = randn(&mut rng, &[n, dv], 1.0);
        let mut a = sess.begin_decode(dv).unwrap();
        let mut b = sess.begin_decode(dv).unwrap();
        let mut row = vec![0.0f32; dv];
        for i in 0..n {
            let qr = &q.data[i * d..(i + 1) * d];
            let kr = &k.data[i * d..(i + 1) * d];
            let vr = &v.data[i * dv..(i + 1) * dv];
            let out_a = a.append_token(qr, kr, vr).unwrap();
            b.append_token_into(qr, kr, vr, &mut row).unwrap();
            for (j, (x, y)) in out_a.iter().zip(&row).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "token {i} element {j}: {x} vs {y}");
            }
        }
        assert_eq!((a.len(), b.len()), (n, n));
    }

    #[test]
    fn reset_rewinds_to_a_fresh_decode() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(3)
            .num_features(16)
            .causal(true)
            .seed(4)
            .build()
            .unwrap();
        let mut rng = Rng::new(77);
        let q = randn(&mut rng, &[6, 3], 0.5);
        let k = randn(&mut rng, &[6, 3], 0.5);
        let v = randn(&mut rng, &[6, 2], 1.0);
        let mut state = sess.begin_decode(2).unwrap();
        let feed = |state: &mut CausalState<'_>| -> Vec<Vec<f32>> {
            (0..6)
                .map(|i| {
                    let qr = &q.data[i * 3..(i + 1) * 3];
                    let kr = &k.data[i * 3..(i + 1) * 3];
                    let vr = &v.data[i * 2..(i + 1) * 2];
                    state.append_token(qr, kr, vr).unwrap()
                })
                .collect()
        };
        let first = feed(&mut state);
        state.reset();
        assert!(state.is_empty());
        let second = feed(&mut state);
        assert_eq!(first, second, "reset must reproduce the fresh-state outputs");
    }

    /// A mid-decode snapshot restored into a reset state continues
    /// bit-identically to the stream that never hibernated — including
    /// restoring into a state that decoded something else in between.
    #[test]
    fn snapshot_restore_continues_bit_identically() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(3)
            .num_features(16)
            .causal(true)
            .seed(4)
            .build()
            .unwrap();
        let mut rng = Rng::new(78);
        let q = randn(&mut rng, &[8, 3], 0.5);
        let k = randn(&mut rng, &[8, 3], 0.5);
        let v = randn(&mut rng, &[8, 2], 1.0);
        let tok = |i: usize| {
            (&q.data[i * 3..(i + 1) * 3], &k.data[i * 3..(i + 1) * 3], &v.data[i * 2..(i + 1) * 2])
        };
        let mut state = sess.begin_decode(2).unwrap();
        for i in 0..4 {
            let (qr, kr, vr) = tok(i);
            state.append_token(qr, kr, vr).unwrap();
        }
        let mut buf = Vec::new();
        state.snapshot_into(&mut buf);
        assert_eq!(buf.len(), state.snapshot_len());
        // never-hibernated continuation
        let baseline: Vec<Vec<f32>> = (4..8)
            .map(|i| {
                let (qr, kr, vr) = tok(i);
                state.append_token(qr, kr, vr).unwrap()
            })
            .collect();
        // poison the state with unrelated tokens, then restore
        state.reset();
        let (qr, kr, vr) = tok(7);
        state.append_token(qr, kr, vr).unwrap();
        state.restore_from(&buf).unwrap();
        assert_eq!(state.len(), 4);
        let resumed: Vec<Vec<f32>> = (4..8)
            .map(|i| {
                let (qr, kr, vr) = tok(i);
                state.append_token(qr, kr, vr).unwrap()
            })
            .collect();
        for (a, b) in baseline.iter().flatten().zip(resumed.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits(), "restored stream diverged: {a} vs {b}");
        }
        // a mismatched-geometry record fails closed
        let other = AttentionSpec::new(Kernel::Exp)
            .head_dim(3)
            .num_features(8)
            .causal(true)
            .seed(4)
            .build()
            .unwrap();
        let mut narrow = other.begin_decode(2).unwrap();
        assert!(narrow.restore_from(&buf).is_err());
    }

    #[test]
    fn phi_rows_into_matches_per_row_decode_phi() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(16)
            .causal(true)
            .seed(8)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let map = sess.feature_map().unwrap();
        let feat = map.flat.num_features();
        let mut rng = Rng::new(12);
        let rows = 5usize;
        let x = randn(&mut rng, &[rows, 4], 0.5);
        let mut batched = vec![0.0f32; rows * feat];
        sess.phi_rows_into(&x.data, rows, &mut batched).unwrap();
        for r in 0..rows {
            let one = map.reference.apply_row(&x.data[r * 4..(r + 1) * 4]);
            // host tier vs scalar reference: bit-for-bit on the scalar
            // dispatch arm, within the SIMD contract otherwise
            for (j, (a, b)) in batched[r * feat..(r + 1) * feat].iter().zip(&one).enumerate() {
                assert!(
                    (a - b).abs() < 1e-5 * b.abs().max(1.0),
                    "row {r} feature {j}: {a} vs {b}"
                );
            }
        }
        // shape errors are clean Errs, not panics
        assert!(sess.phi_rows_into(&x.data[..3], 1, &mut batched[..feat]).is_err());
        assert!(sess.phi_rows_into(&x.data[..4], 1, &mut batched[..feat - 1]).is_err());
        let softmax = AttentionSpec::new(Kernel::Softmax).build().unwrap();
        assert!(softmax.phi_rows_into(&[0.0; 4], 1, &mut [0.0; 4]).is_err());
    }

    #[test]
    fn append_token_into_rejects_bad_out_len() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(2)
            .num_features(8)
            .causal(true)
            .build()
            .unwrap();
        let mut state = sess.begin_decode(3).unwrap();
        let mut out = [0.0f32; 2];
        let err = state
            .append_token_into(&[0.1, 0.2], &[0.3, 0.4], &[1.0, 2.0, 3.0], &mut out)
            .unwrap_err();
        assert!(err.to_string().contains("out row"), "{err}");
        assert!(state.is_empty(), "a rejected token must not advance the state");
    }

    #[test]
    fn prefill_validates_row_shapes_without_touching_state() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(3)
            .num_features(8)
            .causal(true)
            .build()
            .unwrap();
        let mut state = sess.begin_decode(2).unwrap();
        let mut out = [0.0f32; 4];
        // ragged q, mismatched k, short v, short out — all clean Errs
        let err = state.prefill_into(&[0.0; 4], &[0.0; 4], &[0.0; 2], &mut out[..2]).unwrap_err();
        assert!(err.to_string().contains("head_dim"), "{err}");
        let err = state.prefill_into(&[0.0; 6], &[0.0; 3], &[0.0; 4], &mut out).unwrap_err();
        assert!(err.to_string().contains("head_dim"), "{err}");
        let err = state.prefill_into(&[0.0; 6], &[0.0; 6], &[0.0; 3], &mut out).unwrap_err();
        assert!(err.to_string().contains("v must"), "{err}");
        let err = state.prefill_into(&[0.0; 6], &[0.0; 6], &[0.0; 4], &mut out[..3]).unwrap_err();
        assert!(err.to_string().contains("out must"), "{err}");
        assert!(state.is_empty(), "a rejected prefill must not advance the state");
        // the empty prompt is a clean no-op
        state.prefill_into(&[], &[], &[], &mut []).unwrap();
        assert!(state.is_empty());
    }

    #[test]
    fn prefill_chunk_one_is_the_append_chain_bit_for_bit() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(24)
            .causal(true)
            .seed(13)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let (d, dv, n) = (4usize, 3usize, 11usize);
        let mut rng = Rng::new(0xC1);
        let q = randn(&mut rng, &[n, d], 0.5);
        let k = randn(&mut rng, &[n, d], 0.5);
        let v = randn(&mut rng, &[n, dv], 1.0);
        let mut pre = sess.begin_decode(dv).unwrap();
        let mut out = vec![0.0f32; n * dv];
        pre.prefill_with_chunk_into(&q.data, &k.data, &v.data, 1, &mut out).unwrap();
        assert_eq!(pre.len(), n);
        let mut seq = sess.begin_decode(dv).unwrap();
        let mut row = vec![0.0f32; dv];
        for i in 0..n {
            seq.append_token_into(
                &q.data[i * d..(i + 1) * d],
                &k.data[i * d..(i + 1) * d],
                &v.data[i * dv..(i + 1) * dv],
                &mut row,
            )
            .unwrap();
            for (j, (a, b)) in out[i * dv..(i + 1) * dv].iter().zip(&row).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "token {i} elem {j}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn exp_forward_tracks_softmax() {
        // RMFA_exp with a healthy D approximates exact softmax attention.
        let mut rng = Rng::new(9);
        let q = randn(&mut rng, &[2, 8, 4], 0.3);
        let k = randn(&mut rng, &[2, 8, 4], 0.3);
        let v = randn(&mut rng, &[2, 8, 3], 1.0);
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(256)
            .seed(11)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let approx = sess.forward(&q, &k, &v).unwrap();
        let exact = sess.forward_exact(&q, &k, &v).unwrap();
        let diff = approx.max_abs_diff(&exact);
        assert!(diff < 0.35, "RMFA_exp vs exact kernelized: {diff}");
    }
}
