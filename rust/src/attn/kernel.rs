//! The `Kernel` enum — the single typed home of the paper's Table-1
//! dot-product kernels (plus the exact-softmax baseline).
//!
//! This replaces the old stringly-typed `&str` kernel parameters that
//! threaded `"exp"`/`"inv"`/... through every attention entry point and
//! `panic!`ed on typos. Parsing is total (`FromStr` returns `Err`, never
//! panics) and the Maclaurin-series accessors return `Result` because
//! [`Kernel::Softmax`] — the exact-attention baseline — has no feature
//! expansion.
//!
//! ```
//! use std::str::FromStr;
//! use macformer::attn::Kernel;
//!
//! assert_eq!(Kernel::from_str("inv"), Ok(Kernel::Inv));
//! assert!(Kernel::from_str("bogus").is_err());
//! assert_eq!(Kernel::Exp.to_string(), "exp");
//! // Table 1: a_3 of exp is 1/3! = 1/6
//! assert_eq!(Kernel::Exp.coefficient(3).unwrap(), 1.0 / 6.0);
//! // the exact baseline has no Maclaurin series
//! assert!(Kernel::Softmax.coefficient(0).is_err());
//! ```

use std::fmt;
use std::str::FromStr;

/// Truncation degree used by the static AOT lowering (see python side).
pub const DEFAULT_MAX_DEGREE: usize = 8;

/// A dot-product kernel K(q.k / sqrt(d)): the five Maclaurin kernels of
/// Table 1 (paper order) plus the exact-softmax baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Kernel {
    /// exp(t) — the softmax numerator; RMFA_exp approximates softmax.
    Exp,
    /// 1 / (1 - t).
    Inv,
    /// 1 - ln(1 - t).
    Log,
    /// sinh(t) + cosh(t) (= exp(t), but with its own Table-1 row).
    Trigh,
    /// 2 - sqrt(1 - t).
    Sqrt,
    /// Exact softmax attention — the quadratic baseline, no feature map.
    Softmax,
}

/// A kernel operation needed a Maclaurin expansion that does not exist.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NoMaclaurinSeries(pub Kernel);

impl fmt::Display for NoMaclaurinSeries {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "kernel {:?} ({}) has no Maclaurin expansion — it is the exact \
             baseline, not a Table-1 feature kernel",
            self.0, self.0
        )
    }
}

impl std::error::Error for NoMaclaurinSeries {}

/// `Kernel::from_str` failed: the name is not a known kernel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseKernelError {
    got: String,
}

impl fmt::Display for ParseKernelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown kernel {:?}; expected one of: exp, inv, log, trigh, sqrt, softmax",
            self.got
        )
    }
}

impl std::error::Error for ParseKernelError {}

impl FromStr for Kernel {
    type Err = ParseKernelError;

    fn from_str(s: &str) -> Result<Kernel, ParseKernelError> {
        match s {
            "exp" => Ok(Kernel::Exp),
            "inv" => Ok(Kernel::Inv),
            "log" => Ok(Kernel::Log),
            "trigh" => Ok(Kernel::Trigh),
            "sqrt" => Ok(Kernel::Sqrt),
            "softmax" => Ok(Kernel::Softmax),
            other => Err(ParseKernelError { got: other.to_string() }),
        }
    }
}

impl fmt::Display for Kernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // pad() so width specifiers ({:<8}) align bench tables
        f.pad(self.name())
    }
}

fn factorial(n: usize) -> f64 {
    (1..=n).map(|i| i as f64).product()
}

fn double_factorial(n: i64) -> f64 {
    if n <= 0 {
        return 1.0;
    }
    let mut out = 1.0;
    let mut k = n;
    while k > 1 {
        out *= k as f64;
        k -= 2;
    }
    out
}

impl Kernel {
    /// The five Maclaurin kernels of Table 1, paper order.
    pub const MACLAURIN: [Kernel; 5] =
        [Kernel::Exp, Kernel::Inv, Kernel::Log, Kernel::Trigh, Kernel::Sqrt];

    /// Every kernel, Table-1 order then the exact baseline.
    pub const ALL: [Kernel; 6] = [
        Kernel::Exp,
        Kernel::Inv,
        Kernel::Log,
        Kernel::Trigh,
        Kernel::Sqrt,
        Kernel::Softmax,
    ];

    /// The canonical (parseable) name — inverse of `FromStr`.
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Exp => "exp",
            Kernel::Inv => "inv",
            Kernel::Log => "log",
            Kernel::Trigh => "trigh",
            Kernel::Sqrt => "sqrt",
            Kernel::Softmax => "softmax",
        }
    }

    /// Does this kernel have a Maclaurin feature expansion (Table 1)?
    pub fn has_maclaurin(self) -> bool {
        !matches!(self, Kernel::Softmax)
    }

    /// a_N: the N-th Maclaurin coefficient.
    ///
    /// Matches the paper's Table 1 with the two typos fixed (log:
    /// 1/max(1,N); sqrt: double factorial (2N-3)!!) — see
    /// `python/compile/maclaurin.py` for the derivation. `Err` for
    /// [`Kernel::Softmax`], which has no expansion.
    pub fn coefficient(self, n: usize) -> Result<f64, NoMaclaurinSeries> {
        match self {
            Kernel::Exp | Kernel::Trigh => Ok(1.0 / factorial(n)),
            Kernel::Inv => Ok(1.0),
            Kernel::Log => Ok(if n == 0 { 1.0 } else { 1.0 / n as f64 }),
            Kernel::Sqrt => Ok(if n == 0 {
                1.0
            } else {
                double_factorial(2 * n as i64 - 3) / (2f64.powi(n as i32) * factorial(n))
            }),
            Kernel::Softmax => Err(NoMaclaurinSeries(self)),
        }
    }

    /// Closed-form K as a plain function pointer, so hot loops resolve
    /// the kernel once instead of matching per score element. `Err` for
    /// [`Kernel::Softmax`] (exact attention does not go through a
    /// pointwise kernel weight).
    pub fn value_fn(self) -> Result<fn(f64) -> f64, NoMaclaurinSeries> {
        match self {
            Kernel::Exp | Kernel::Trigh => Ok(f64::exp),
            Kernel::Inv => Ok(|t| 1.0 / (1.0 - t)),
            Kernel::Log => Ok(|t| 1.0 - (1.0 - t).ln()),
            Kernel::Sqrt => Ok(|t| 2.0 - (1.0 - t).sqrt()),
            Kernel::Softmax => Err(NoMaclaurinSeries(self)),
        }
    }

    /// Closed-form K(t).
    pub fn value(self, t: f64) -> Result<f64, NoMaclaurinSeries> {
        Ok(self.value_fn()?(t))
    }

    /// sum_{N=0}^{max_degree} a_N t^N.
    pub fn truncated_value(self, t: f64, max_degree: usize) -> Result<f64, NoMaclaurinSeries> {
        let mut acc = 0.0;
        let mut tn = 1.0;
        for n in 0..=max_degree {
            acc += self.coefficient(n)? * tn;
            tn *= t;
        }
        Ok(acc)
    }

    /// sqrt(a_N * p^(N+1)): the phi_i prefactor from Definition 3.
    pub fn feature_scale(self, degree: usize, p: f64) -> Result<f64, NoMaclaurinSeries> {
        Ok((self.coefficient(degree)? * p.powi(degree as i32 + 1)).sqrt())
    }
}

/// P[N = eta] over the truncated window (renormalized geometric law) —
/// kernel-independent, shared by every RMF map.
pub fn degree_distribution(p: f64, max_degree: usize) -> Vec<f64> {
    assert!(p > 1.0, "p must be > 1");
    let raw: Vec<f64> = (0..=max_degree).map(|e| p.powi(-(e as i32 + 1))).collect();
    let z: f64 = raw.iter().sum();
    raw.into_iter().map(|x| x / z).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_str_round_trips_and_never_panics() {
        for k in Kernel::ALL {
            assert_eq!(Kernel::from_str(k.name()), Ok(k));
            assert_eq!(k.to_string(), k.name());
        }
        for bad in ["bogus", "", "EXP", "exp ", "soft-max"] {
            let e = Kernel::from_str(bad).unwrap_err();
            assert!(e.to_string().contains("unknown kernel"), "{e}");
        }
    }

    #[test]
    fn softmax_has_no_series() {
        assert!(Kernel::Softmax.coefficient(0).is_err());
        assert!(Kernel::Softmax.value(0.3).is_err());
        assert!(Kernel::Softmax.value_fn().is_err());
        assert!(Kernel::Softmax.feature_scale(2, 2.0).is_err());
        assert!(!Kernel::Softmax.has_maclaurin());
        for k in Kernel::MACLAURIN {
            assert!(k.has_maclaurin());
        }
    }

    #[test]
    fn exp_coefficients_are_inverse_factorials() {
        assert_eq!(Kernel::Exp.coefficient(0).unwrap(), 1.0);
        assert_eq!(Kernel::Exp.coefficient(3).unwrap(), 1.0 / 6.0);
        assert_eq!(Kernel::Trigh.coefficient(4).unwrap(), 1.0 / 24.0);
    }

    #[test]
    fn all_coefficients_nonnegative() {
        for k in Kernel::MACLAURIN {
            for n in 0..=12 {
                assert!(k.coefficient(n).unwrap() >= 0.0, "{k} a_{n}");
            }
        }
    }

    #[test]
    fn expansions_match_closed_forms() {
        // On |t| <= 0.5 a degree-16 truncation must be within 1e-3 of the
        // closed form for every kernel.
        for k in Kernel::MACLAURIN {
            for i in 0..=20 {
                let t = -0.5 + i as f64 * 0.05;
                let exact = k.value(t).unwrap();
                let series = k.truncated_value(t, 16).unwrap();
                assert!(
                    (exact - series).abs() < 1e-3 * exact.abs().max(1.0),
                    "{k}(t={t}): closed {exact} vs series {series}"
                );
            }
        }
    }

    #[test]
    fn sqrt_coefficient_uses_double_factorial() {
        // a_4 of 2-sqrt(1-t) is 5!!/2^4/4! = 15/384, NOT the paper's
        // max(1, 2N-3)/(2^N N!) = 5/384 — the series test above would fail
        // with the paper's literal formula.
        assert!((Kernel::Sqrt.coefficient(4).unwrap() - 15.0 / 384.0).abs() < 1e-12);
    }

    #[test]
    fn degree_distribution_sums_to_one() {
        for p in [1.5, 2.0, 4.0] {
            let d = degree_distribution(p, 8);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
            // monotone decreasing
            for w in d.windows(2) {
                assert!(w[0] > w[1]);
            }
        }
    }

    #[test]
    fn geometric_law_ratios() {
        let d = degree_distribution(2.0, 8);
        for w in d.windows(2) {
            assert!((w[0] / w[1] - 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn scale_squared_times_prob_recovers_coefficient() {
        // E[a_N p^{N+1} * P[N]] telescopes back to a_N (untruncated law):
        // scale^2 * p^-(N+1) == a_N.
        for k in Kernel::MACLAURIN {
            for n in 0..=6 {
                let s = k.feature_scale(n, 2.0).unwrap();
                let back = s * s * 2f64.powi(-(n as i32 + 1));
                assert!((back - k.coefficient(n).unwrap()).abs() < 1e-12);
            }
        }
    }
}
