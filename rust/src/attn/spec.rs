//! [`AttentionSpec`] — the builder that describes one attention
//! configuration (kernel, causality, feature-map hyper-parameters,
//! backend preference) and turns it into a ready
//! [`AttentionSession`](crate::attn::AttentionSession).

use std::fmt;
use std::str::FromStr;

use anyhow::{bail, Result};

use super::kernel::{Kernel, DEFAULT_MAX_DEGREE};
use super::session::AttentionSession;

/// Which compute tier a session should run on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pick the best available tier (device if it can execute, else the
    /// host fast path).
    Auto,
    /// The scalar oracle tier (`crate::reference`) — obviously correct,
    /// single thread, never optimized.
    Reference,
    /// The engineered host tier (`crate::fastpath`) — degree-grouped
    /// GEMM feature maps + persistent-pool batched kernels, with a
    /// runtime-dispatched AVX2+FMA arm on capable x86_64 hosts
    /// (`MACFORMER_NO_SIMD=1` pins the always-available scalar arm).
    HostFast,
    /// PJRT device execution. Gates itself off (every op returns `Err`)
    /// when the runtime is the vendored stub or no per-shape artifacts
    /// are compiled.
    Device,
}

impl fmt::Display for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(match self {
            Backend::Auto => "auto",
            Backend::Reference => "reference",
            Backend::HostFast => "host",
            Backend::Device => "device",
        })
    }
}

/// `Backend::from_str` failed: the name is not a known backend.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseBackendError {
    got: String,
}

impl fmt::Display for ParseBackendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown backend {:?}; expected one of: auto, reference, host, device",
            self.got
        )
    }
}

impl std::error::Error for ParseBackendError {}

impl FromStr for Backend {
    type Err = ParseBackendError;

    fn from_str(s: &str) -> Result<Backend, ParseBackendError> {
        match s {
            "auto" => Ok(Backend::Auto),
            "reference" => Ok(Backend::Reference),
            "host" => Ok(Backend::HostFast),
            "device" => Ok(Backend::Device),
            other => Err(ParseBackendError { got: other.to_string() }),
        }
    }
}

/// One attention configuration. Build with [`AttentionSpec::new`] and
/// the chained setters, then [`AttentionSpec::build`] to get a session
/// that owns a single RMF feature-map draw across all its calls.
///
/// ```
/// use macformer::attn::{AttentionSpec, Backend, Kernel};
///
/// let session = AttentionSpec::new(Kernel::Inv)
///     .head_dim(8)
///     .num_features(32)
///     .causal(true)
///     .seed(42)
///     .backend(Backend::HostFast)
///     .build()
///     .unwrap();
/// assert_eq!(session.spec().kernel, Kernel::Inv);
/// ```
#[derive(Debug, Clone)]
pub struct AttentionSpec {
    /// Score kernel (Table 1 or the exact-softmax baseline).
    pub kernel: Kernel,
    /// Causal (autoregressive) masking.
    pub causal: bool,
    /// Denominator stabilizer for the kernelized / linear paths.
    pub eps: f32,
    /// Feature count D of the RMF map (ignored for `Kernel::Softmax`).
    pub num_features: usize,
    /// Input (head) dimension d the feature map is sampled for.
    pub head_dim: usize,
    /// Geometric degree-law parameter p (> 1).
    pub p: f64,
    /// Maclaurin truncation degree of the sampled map.
    pub max_degree: usize,
    /// Seed for the one map draw the session owns.
    pub seed: u64,
    /// Compute-tier preference.
    pub backend: Backend,
}

impl AttentionSpec {
    /// Paper defaults: d = 64, D = 128, p = 2, degree 8, eps = 1e-6,
    /// non-causal, auto backend.
    pub fn new(kernel: Kernel) -> AttentionSpec {
        AttentionSpec {
            kernel,
            causal: false,
            eps: 1e-6,
            num_features: 128,
            head_dim: 64,
            p: 2.0,
            max_degree: DEFAULT_MAX_DEGREE,
            seed: 7,
            backend: Backend::Auto,
        }
    }

    /// Causal (autoregressive) masking; enables the streaming decode path.
    pub fn causal(mut self, yes: bool) -> Self {
        self.causal = yes;
        self
    }

    /// Denominator stabilizer eps.
    pub fn eps(mut self, eps: f32) -> Self {
        self.eps = eps;
        self
    }

    /// Feature count D of the RMF map.
    pub fn num_features(mut self, d: usize) -> Self {
        self.num_features = d;
        self
    }

    /// Input (head) dimension d.
    pub fn head_dim(mut self, d: usize) -> Self {
        self.head_dim = d;
        self
    }

    /// Geometric degree-law parameter p (> 1).
    pub fn p(mut self, p: f64) -> Self {
        self.p = p;
        self
    }

    /// Maclaurin truncation degree.
    pub fn max_degree(mut self, n: usize) -> Self {
        self.max_degree = n;
        self
    }

    /// Seed for the session's single feature-map draw.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Compute-tier preference.
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Validate the spec and build a session (samples the RMF map once).
    pub fn build(self) -> Result<AttentionSession> {
        self.validate()?;
        AttentionSession::build(self)
    }

    pub(crate) fn validate(&self) -> Result<()> {
        if self.eps.is_nan() || self.eps < 0.0 {
            bail!("AttentionSpec: eps must be >= 0, got {}", self.eps);
        }
        if self.kernel.has_maclaurin() {
            if self.num_features == 0 {
                bail!("AttentionSpec: num_features must be > 0 for kernel {}", self.kernel);
            }
            if self.head_dim == 0 {
                bail!("AttentionSpec: head_dim must be > 0 for kernel {}", self.kernel);
            }
            if self.p.is_nan() || self.p <= 1.0 {
                bail!("AttentionSpec: p must be > 1, got {}", self.p);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_round_trips() {
        for b in [Backend::Auto, Backend::Reference, Backend::HostFast, Backend::Device] {
            assert_eq!(Backend::from_str(&b.to_string()), Ok(b));
        }
        assert!(Backend::from_str("gpu").is_err());
    }

    #[test]
    fn invalid_specs_are_errors_not_panics() {
        assert!(AttentionSpec::new(Kernel::Exp).num_features(0).build().is_err());
        assert!(AttentionSpec::new(Kernel::Exp).head_dim(0).build().is_err());
        assert!(AttentionSpec::new(Kernel::Exp).p(1.0).build().is_err());
        assert!(AttentionSpec::new(Kernel::Exp).eps(-1.0).build().is_err());
        // the exact baseline needs no feature map, so D = 0 is fine there
        assert!(AttentionSpec::new(Kernel::Softmax).num_features(0).build().is_ok());
    }
}
