//! Checkpointing: persist the opaque device state + run metadata.
//!
//! Layout: `<path>` is a tensor bundle (tensor/io.rs format) whose entries
//! are "state_<i>" blobs in manifest order plus a "meta" tensor packing
//! [key0, key1, steps_done] as f32 bit-views of u32 (lossless for the
//! values involved: keys are arbitrary u32 -> stored via bit reinterpret).

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{DeviceState, ModuleInfo};
use crate::tensor::{read_bundle, write_bundle, Tensor};

pub fn save(path: &Path, state: &DeviceState, info: &ModuleInfo) -> Result<()> {
    let blobs = state.download()?;
    let key = state.download_key()?;
    let mut entries: Vec<(String, Tensor)> = Vec::with_capacity(blobs.len() + 1);
    let specs: Vec<_> = info.param_specs.iter().chain(info.opt_specs.iter()).collect();
    for (i, blob) in blobs.into_iter().enumerate() {
        let shape = specs
            .get(i)
            .map(|s| s.shape.clone())
            .unwrap_or_else(|| vec![blob.len()]);
        entries.push((format!("state_{i:04}"), Tensor::from_vec(&shape, blob)));
    }
    let meta = vec![
        f32::from_bits(key[0]),
        f32::from_bits(key[1]),
        state.steps_done as f32,
    ];
    entries.push(("meta".to_string(), Tensor::from_vec(&[3], meta)));
    write_bundle(path, &entries).map_err(|e| anyhow!("checkpoint write: {e}"))
}

pub fn load(path: &Path, info: &ModuleInfo) -> Result<DeviceState> {
    let entries = read_bundle(path).map_err(|e| anyhow!("checkpoint read: {e}"))?;
    let mut blobs: Vec<Vec<f32>> = Vec::new();
    let mut meta: Option<Vec<f32>> = None;
    for (name, t) in entries {
        if name == "meta" {
            meta = Some(t.data);
        } else {
            blobs.push(t.data);
        }
    }
    let meta = meta.ok_or_else(|| anyhow!("checkpoint missing meta entry"))?;
    if meta.len() != 3 {
        bail!("bad meta entry");
    }
    let key = [meta[0].to_bits(), meta[1].to_bits()];
    DeviceState::restore(info, &blobs, key, meta[2] as u64)
}
