//! The training loop: the L3 hot path.
//!
//! Owns the compiled init/train/eval(/generate) executables for one
//! (task, variant) cell, the synthetic train/eval splits, the epoch
//! batcher, and the device-resident state. Loss buffers are fetched to
//! the host only every `log_every` steps — between fetches the loop is a
//! pure device-buffer relay (see DESIGN.md §Perf).

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::data::batcher::Batcher;
use crate::data::translation;
use crate::metrics::{bleu, Accuracy, Ema, Perplexity, Timing};
use crate::runtime::{DeviceState, Executable, HostArg, ModuleInfo, Registry};
use crate::util::json::Value;

use super::task_data::TaskData;

/// Outcome of one run, consumed by the sweep orchestrator / EXPERIMENTS.md.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub family: String,
    pub steps: usize,
    pub train_seconds: f64,
    pub step_seconds_mean: f64,
    pub compile_seconds: f64,
    pub peak_rss_bytes: u64,
    pub final_loss: f64,
    pub eval_loss: f64,
    /// accuracy % for cls/retrieval; BLEU for lm
    pub quality: f64,
    /// perplexity for lm runs (NaN otherwise)
    pub perplexity: f64,
    pub loss_curve: Vec<(usize, f64)>,
    pub eval_curve: Vec<(usize, f64, f64)>, // (step, eval_loss, quality)
}

impl RunReport {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("family", Value::str(&self.family)),
            ("steps", Value::num(self.steps as f64)),
            ("train_seconds", Value::num(self.train_seconds)),
            ("step_seconds_mean", Value::num(self.step_seconds_mean)),
            ("compile_seconds", Value::num(self.compile_seconds)),
            ("peak_rss_bytes", Value::num(self.peak_rss_bytes as f64)),
            ("final_loss", Value::num(self.final_loss)),
            ("eval_loss", Value::num(self.eval_loss)),
            ("quality", Value::num(self.quality)),
            ("perplexity", Value::num(self.perplexity)),
            (
                "loss_curve",
                Value::Arr(
                    self.loss_curve
                        .iter()
                        .map(|(s, l)| Value::Arr(vec![Value::num(*s as f64), Value::num(*l)]))
                        .collect(),
                ),
            ),
            (
                "eval_curve",
                Value::Arr(
                    self.eval_curve
                        .iter()
                        .map(|(s, l, q)| {
                            Value::Arr(vec![
                                Value::num(*s as f64),
                                Value::num(*l),
                                Value::num(*q),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// One fully-wired training cell.
pub struct Trainer {
    pub cfg: RunConfig,
    pub info: ModuleInfo,
    init_exe: Executable,
    train_exe: Executable,
    eval_exe: Executable,
    gen_exe: Option<Executable>,
    pub state: DeviceState,
    train_data: TaskData,
    eval_data: TaskData,
    batcher: Batcher,
    src_max: usize,
    compile_seconds: f64,
}

impl Trainer {
    /// Compile the cell's modules, synthesize data, init device state.
    pub fn build(cfg: RunConfig, reg: &Registry) -> Result<Trainer> {
        let family = cfg.family();
        let info = reg.get(&format!("{family}.train"))?.clone();
        let t0 = Instant::now();
        let init_exe = Executable::compile_file(
            &format!("{family}.init"),
            &reg.hlo_path(reg.get(&format!("{family}.init"))?),
        )?;
        let train_exe = Executable::compile_file(
            &format!("{family}.train"),
            &reg.hlo_path(&info),
        )?;
        let eval_exe = Executable::compile_file(
            &format!("{family}.eval"),
            &reg.hlo_path(reg.get(&format!("{family}.eval"))?),
        )?;
        let gen_exe = match reg.get(&format!("{family}.generate")) {
            Ok(gi) => Some(Executable::compile_file(
                &format!("{family}.generate"),
                &reg.hlo_path(gi),
            )?),
            Err(_) => None,
        };
        let compile_seconds = t0.elapsed().as_secs_f64();

        let src_max = reg.translation_src_max;
        let train_data = TaskData::build(
            &cfg.task, cfg.seed, cfg.train_examples, info.seq_len, src_max,
        )?;
        let eval_data = TaskData::build(
            &cfg.task,
            cfg.seed ^ 0xEAE0_17AC,
            cfg.eval_examples,
            info.seq_len,
            src_max,
        )?;
        let batcher = Batcher::new(train_data.len(), info.batch, cfg.seed ^ 0xBA7C);
        let state = DeviceState::init(&init_exe, &info, cfg.seed as u32)?;
        log::info!(
            "{family}: compiled in {compile_seconds:.1}s, {} params, batch {}x{}",
            info.n_params,
            info.batch,
            info.seq_len
        );
        Ok(Trainer {
            cfg,
            info,
            init_exe,
            train_exe,
            eval_exe,
            gen_exe,
            state,
            train_data,
            eval_data,
            batcher,
            src_max,
            compile_seconds,
        })
    }

    /// Re-initialize parameters (fresh seed) without recompiling.
    pub fn reinit(&mut self, seed: u32) -> Result<()> {
        self.state = DeviceState::init(&self.init_exe, &self.info, seed)?;
        Ok(())
    }

    /// The compiled train executable (for external harnesses, e.g. the
    /// hotpath bench that times phases individually).
    pub fn train_exe(&self) -> &Executable {
        &self.train_exe
    }

    /// One train step over an externally staged batch (hotpath bench).
    pub fn step_with(&mut self, batch: &[HostArg]) -> Result<xla::PjRtBuffer> {
        self.state.train_step(&self.train_exe, batch)
    }

    /// One optimization step over the next scheduled batch; returns the
    /// loss *buffer* (host fetch deferred to the caller's logging cadence).
    pub fn step(&mut self) -> Result<xla::PjRtBuffer> {
        let idx = self.batcher.next_batch().to_vec();
        let batch = self.train_data.stage(&idx, self.info.seq_len);
        self.state.train_step(&self.train_exe, &batch)
    }

    /// Full evaluation sweep; returns (mean loss, quality, ppl).
    pub fn evaluate(&mut self) -> Result<(f64, f64, f64)> {
        let b = self.info.batch;
        let n = (self.eval_data.len() / b) * b;
        let mut loss_sum = 0.0;
        let mut batches = 0usize;
        let mut acc = Accuracy::default();
        let mut ppl = Perplexity::default();
        for start in (0..n).step_by(b) {
            let idx: Vec<usize> = (start..start + b).collect();
            let batch = self.eval_data.stage(&idx, self.info.seq_len);
            let (loss, metric) = self.state.eval_step(&self.eval_exe, &batch)?;
            loss_sum += loss as f64;
            batches += 1;
            if self.eval_data.is_lm() {
                // metric = target token count; loss = mean token nll
                ppl.update(loss as f64, metric as f64);
            } else {
                acc.update(metric as f64, b as f64);
            }
        }
        let mean_loss = loss_sum / batches.max(1) as f64;
        if self.eval_data.is_lm() {
            let bleu = self.bleu_eval(n.min(4 * b))?;
            Ok((mean_loss, bleu, ppl.value()))
        } else {
            Ok((mean_loss, acc.value(), f64::NAN))
        }
    }

    /// Greedy-decode BLEU over the first `count` eval rows (LM only).
    fn bleu_eval(&self, count: usize) -> Result<f64> {
        let gen = self
            .gen_exe
            .as_ref()
            .ok_or_else(|| anyhow!("no generate module for {}", self.cfg.family()))?;
        let b = self.info.batch;
        let mut pairs: Vec<(Vec<u32>, Vec<u32>)> = Vec::new();
        for start in (0..count).step_by(b) {
            let idx: Vec<usize> = (start..start + b).collect();
            let (prompts, refs) = self.eval_data.lm_prompts(&idx, self.src_max, self.info.seq_len);
            let out = self.state.generate(
                gen,
                &HostArg::I32(vec![b, self.info.seq_len], prompts),
                [0xB1E0u32, start as u32],
            )?;
            for (row, reference) in out.chunks(self.info.seq_len).zip(&refs) {
                let hyp = translation::decode_target(row, self.src_max);
                pairs.push((
                    hyp.iter().map(|x| *x as u32).collect(),
                    reference.iter().map(|x| *x as u32).collect(),
                ));
            }
        }
        Ok(bleu::corpus_bleu(&pairs))
    }

    /// The full run: train `cfg.steps` steps with periodic logging/eval.
    pub fn run(&mut self) -> Result<RunReport> {
        let mut loss_curve = Vec::new();
        let mut eval_curve = Vec::new();
        let mut ema = Ema::new(0.1);
        let mut timing = Timing::default();
        let steps = self.cfg.steps;
        let log_every = self.cfg.log_every.max(1);
        let eval_every = self.cfg.eval_every.max(1);
        let t_train = Instant::now();
        let mut last_loss = f64::NAN;
        for s in 1..=steps {
            let t0 = Instant::now();
            let loss_buf = self.step()?;
            // fetching the loss synchronizes; only do it on the log cadence
            if s % log_every == 0 || s == steps {
                let loss = DeviceState::loss_value(&loss_buf)? as f64;
                timing.push(t0.elapsed().as_secs_f64());
                last_loss = ema.update(loss);
                loss_curve.push((s, loss));
                log::info!(
                    "{} step {s}/{steps} loss {loss:.4} (ema {last_loss:.4})",
                    self.cfg.family()
                );
            }
            if s % eval_every == 0 && s != steps {
                let (el, q, _p) = self.evaluate()?;
                eval_curve.push((s, el, q));
                log::info!("{} eval @{s}: loss {el:.4} quality {q:.2}", self.cfg.family());
            }
        }
        let train_seconds = t_train.elapsed().as_secs_f64();
        let (eval_loss, quality, perplexity) = self.evaluate()?;
        eval_curve.push((steps, eval_loss, quality));
        Ok(RunReport {
            family: self.cfg.family(),
            steps,
            train_seconds,
            step_seconds_mean: timing.mean(),
            compile_seconds: self.compile_seconds,
            peak_rss_bytes: crate::util::peak_rss_bytes(),
            final_loss: last_loss,
            eval_loss,
            quality,
            perplexity,
            loss_curve,
            eval_curve,
        })
    }
}
