//! Fig-4 micro-benchmark harness: RMFA_exp vs exact softmax attention.
//!
//! For every (length n, feature dim D) cell of the paper's simulation
//! grid: generate random (q, k, v) with the paper's shape (batch 16 x
//! 8 heads x n x 64), run both compiled attention modules, and record
//!   * Fig 4a — log10 NMSE between RMFA output and exact attention, and
//!   * Fig 4b — log10 acceleration ratio t_softmax / t_rmfa.
//! Both modules apply identical in-graph preSBN (eps = 1e-12), matching
//! the paper's preprocessing.

use std::time::Instant;

use anyhow::Result;

use crate::metrics::{nmse, Timing};
use crate::runtime::{Executable, HostArg, Registry};
use crate::util::json::Value;
use crate::util::rng::Rng;

/// One (n, D) cell measurement.
#[derive(Debug, Clone)]
pub struct MicroCell {
    pub n: usize,
    pub feature_dim: usize,
    pub nmse: f64,
    pub softmax_seconds: f64,
    pub rmfa_seconds: f64,
}

impl MicroCell {
    pub fn log10_nmse(&self) -> f64 {
        self.nmse.log10()
    }
    /// log10(t_softmax / t_rmfa): positive = RMFA faster.
    pub fn log10_speedup(&self) -> f64 {
        (self.softmax_seconds / self.rmfa_seconds).log10()
    }
}

/// Run the grid. `repeats` controls timing stability (paper: 100; CPU
/// default lower). Returns cells in (n-major, D-minor) order.
pub fn run_grid(
    reg: &Registry,
    lengths: &[usize],
    features: &[usize],
    repeats: usize,
    seed: u64,
) -> Result<Vec<MicroCell>> {
    let g = 16 * 8; // paper: batch 16, 8 heads
    let d = 64;
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for &n in lengths {
        let sm_info = reg.get(&format!("micro.softmax.n{n}"))?;
        let sm = Executable::compile_file(&sm_info.name, &reg.hlo_path(sm_info))?;
        // Shared inputs per length (both paths see identical data).
        let numel = g * n * d;
        let mk = |rng: &mut Rng| -> Vec<f32> {
            (0..numel).map(|_| rng.normal() * 0.5).collect()
        };
        let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let dims = vec![g, n, d];
        let q_buf = Executable::upload(&HostArg::F32(dims.clone(), q))?;
        let k_buf = Executable::upload(&HostArg::F32(dims.clone(), k))?;
        let v_buf = Executable::upload(&HostArg::F32(dims.clone(), v))?;

        // exact softmax output + timing
        let mut sm_t = Timing::default();
        let mut exact = Vec::new();
        for r in 0..repeats.max(1) {
            let t0 = Instant::now();
            let outs = sm.run_buffers_ref(&[&q_buf, &k_buf, &v_buf])?;
            // fetch synchronizes: include device->host in both paths
            let data = Executable::fetch_f32(&outs[0])?;
            sm_t.push(t0.elapsed().as_secs_f64());
            if r == 0 {
                exact = data;
            }
        }

        for &feat in features {
            let rm_info = reg.get(&format!("micro.rmfa_exp.n{n}.D{feat}"))?;
            let rm = Executable::compile_file(&rm_info.name, &reg.hlo_path(rm_info))?;
            let mut rm_t = Timing::default();
            let mut err_sum = 0.0;
            let mut err_n = 0usize;
            for r in 0..repeats.max(1) {
                let key = Executable::upload(&HostArg::key([seed as u32, r as u32]))?;
                let t0 = Instant::now();
                let outs = rm.run_buffers_ref(&[&q_buf, &k_buf, &v_buf, &key])?;
                let approx = Executable::fetch_f32(&outs[0])?;
                rm_t.push(t0.elapsed().as_secs_f64());
                err_sum += nmse(&approx, &exact);
                err_n += 1;
            }
            let cell = MicroCell {
                n,
                feature_dim: feat,
                nmse: err_sum / err_n as f64,
                softmax_seconds: sm_t.min(),
                rmfa_seconds: rm_t.min(),
            };
            log::info!(
                "micro n={n} D={feat}: log10(nmse)={:.2} log10(speedup)={:+.2}",
                cell.log10_nmse(),
                cell.log10_speedup()
            );
            out.push(cell);
        }
    }
    Ok(out)
}

/// Render the two Fig-4 panels as ASCII heat tables.
pub fn render(cells: &[MicroCell]) -> String {
    let mut lengths: Vec<usize> = cells.iter().map(|c| c.n).collect();
    lengths.dedup();
    let mut features: Vec<usize> = cells.iter().map(|c| c.feature_dim).collect();
    features.sort_unstable();
    features.dedup();
    let lookup = |n: usize, f: usize| cells.iter().find(|c| c.n == n && c.feature_dim == f);
    let mut s = String::new();
    for (title, get) in [
        ("Fig 4a: log10 NMSE (RMFA_exp vs softmax attention)",
         Box::new(|c: &MicroCell| c.log10_nmse()) as Box<dyn Fn(&MicroCell) -> f64>),
        ("Fig 4b: log10 acceleration ratio (softmax / RMFA)",
         Box::new(|c: &MicroCell| c.log10_speedup())),
    ] {
        s.push_str(&format!("\n{title}\n{:>8}", "n \\ D"));
        for f in &features {
            s.push_str(&format!("{f:>9}"));
        }
        s.push('\n');
        for n in &lengths {
            s.push_str(&format!("{n:>8}"));
            for f in &features {
                match lookup(*n, *f) {
                    Some(c) => s.push_str(&format!("{:>9.2}", get(c))),
                    None => s.push_str(&format!("{:>9}", "-")),
                }
            }
            s.push('\n');
        }
    }
    s
}

pub fn to_json(cells: &[MicroCell]) -> Value {
    Value::Arr(
        cells
            .iter()
            .map(|c| {
                Value::obj(vec![
                    ("n", Value::num(c.n as f64)),
                    ("D", Value::num(c.feature_dim as f64)),
                    ("nmse", Value::num(c.nmse)),
                    ("softmax_seconds", Value::num(c.softmax_seconds)),
                    ("rmfa_seconds", Value::num(c.rmfa_seconds)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_math() {
        let c = MicroCell {
            n: 256,
            feature_dim: 64,
            nmse: 0.01,
            softmax_seconds: 1.0,
            rmfa_seconds: 0.1,
        };
        assert!((c.log10_nmse() + 2.0).abs() < 1e-9);
        assert!((c.log10_speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn render_includes_axes() {
        let c = MicroCell {
            n: 256,
            feature_dim: 64,
            nmse: 0.01,
            softmax_seconds: 1.0,
            rmfa_seconds: 0.1,
        };
        let s = render(&[c]);
        assert!(s.contains("256"));
        assert!(s.contains("64"));
        assert!(s.contains("Fig 4a"));
        assert!(s.contains("Fig 4b"));
    }
}
