//! Fig-4 micro-benchmark harness: RMFA_exp vs exact softmax attention.
//!
//! Two backends share this module:
//!
//! * **device** (`run_grid`) — the original path: compiled HLO modules
//!   over PJRT, identical in-graph preSBN (eps = 1e-12).
//! * **host** (`run_host_grid`) — typed `attn` sessions dispatched over
//!   the `AttentionBackend` trait: the requested tier (default
//!   `Backend::HostFast` — `FlatRmfMap` GEMM feature maps +
//!   persistent-pool batched kernels with the runtime-dispatched SIMD
//!   arm) and, per cell, the oracle tier (`Backend::Reference`, scalar
//!   per-problem, single thread) so the fast-vs-oracle speedup is
//!   tracked under one protocol. Any Table-1 kernel, not just exp.
//!
//! For every (length n, feature dim D) cell of the paper's simulation
//! grid: generate random (q, k, v) with the paper's shape (batch 16 x
//! 8 heads x n x 64) and record
//!   * Fig 4a — log10 NMSE between RMFA output and exact attention, and
//!   * Fig 4b — log10 acceleration ratio t_softmax / t_rmfa.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::attn::{AttentionSession, AttentionSpec, Backend, Kernel};
use crate::metrics::{nmse, Timing};
use crate::runtime::{Executable, HostArg, Registry};
use crate::tensor::Tensor;
use crate::util::json::Value;
use crate::util::rng::Rng;

/// One (n, D) cell measurement.
#[derive(Debug, Clone)]
pub struct MicroCell {
    pub n: usize,
    pub feature_dim: usize,
    pub nmse: f64,
    pub softmax_seconds: f64,
    pub rmfa_seconds: f64,
}

impl MicroCell {
    pub fn log10_nmse(&self) -> f64 {
        self.nmse.log10()
    }
    /// log10(t_softmax / t_rmfa): positive = RMFA faster.
    pub fn log10_speedup(&self) -> f64 {
        (self.softmax_seconds / self.rmfa_seconds).log10()
    }
}

/// Run the grid. `repeats` controls timing stability (paper: 100; CPU
/// default lower). Returns cells in (n-major, D-minor) order.
pub fn run_grid(
    reg: &Registry,
    lengths: &[usize],
    features: &[usize],
    repeats: usize,
    seed: u64,
) -> Result<Vec<MicroCell>> {
    let g = 16 * 8; // paper: batch 16, 8 heads
    let d = 64;
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for &n in lengths {
        let sm_info = reg.get(&format!("micro.softmax.n{n}"))?;
        let sm = Executable::compile_file(&sm_info.name, &reg.hlo_path(sm_info))?;
        // Shared inputs per length (both paths see identical data).
        let numel = g * n * d;
        let mk = |rng: &mut Rng| -> Vec<f32> {
            (0..numel).map(|_| rng.normal() * 0.5).collect()
        };
        let (q, k, v) = (mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let dims = vec![g, n, d];
        let q_buf = Executable::upload(&HostArg::F32(dims.clone(), q))?;
        let k_buf = Executable::upload(&HostArg::F32(dims.clone(), k))?;
        let v_buf = Executable::upload(&HostArg::F32(dims.clone(), v))?;

        // exact softmax output + timing
        let mut sm_t = Timing::default();
        let mut exact = Vec::new();
        for r in 0..repeats.max(1) {
            let t0 = Instant::now();
            let outs = sm.run_buffers_ref(&[&q_buf, &k_buf, &v_buf])?;
            // fetch synchronizes: include device->host in both paths
            let data = Executable::fetch_f32(&outs[0])?;
            sm_t.push(t0.elapsed().as_secs_f64());
            if r == 0 {
                exact = data;
            }
        }

        for &feat in features {
            let rm_info = reg.get(&format!("micro.rmfa_exp.n{n}.D{feat}"))?;
            let rm = Executable::compile_file(&rm_info.name, &reg.hlo_path(rm_info))?;
            let mut rm_t = Timing::default();
            let mut err_sum = 0.0;
            let mut err_n = 0usize;
            for r in 0..repeats.max(1) {
                let key = Executable::upload(&HostArg::key([seed as u32, r as u32]))?;
                let t0 = Instant::now();
                let outs = rm.run_buffers_ref(&[&q_buf, &k_buf, &v_buf, &key])?;
                let approx = Executable::fetch_f32(&outs[0])?;
                rm_t.push(t0.elapsed().as_secs_f64());
                err_sum += nmse(&approx, &exact);
                err_n += 1;
            }
            let cell = MicroCell {
                n,
                feature_dim: feat,
                nmse: err_sum / err_n as f64,
                softmax_seconds: sm_t.min(),
                rmfa_seconds: rm_t.min(),
            };
            log::info!(
                "micro n={n} D={feat}: log10(nmse)={:.2} log10(speedup)={:+.2}",
                cell.log10_nmse(),
                cell.log10_speedup()
            );
            out.push(cell);
        }
    }
    Ok(out)
}

/// Render the two Fig-4 panels as ASCII heat tables.
pub fn render(cells: &[MicroCell]) -> String {
    let mut lengths: Vec<usize> = cells.iter().map(|c| c.n).collect();
    lengths.dedup();
    let mut features: Vec<usize> = cells.iter().map(|c| c.feature_dim).collect();
    features.sort_unstable();
    features.dedup();
    let lookup = |n: usize, f: usize| cells.iter().find(|c| c.n == n && c.feature_dim == f);
    let mut s = String::new();
    for (title, get) in [
        ("Fig 4a: log10 NMSE (RMFA_exp vs softmax attention)",
         Box::new(|c: &MicroCell| c.log10_nmse()) as Box<dyn Fn(&MicroCell) -> f64>),
        ("Fig 4b: log10 acceleration ratio (softmax / RMFA)",
         Box::new(|c: &MicroCell| c.log10_speedup())),
    ] {
        s.push_str(&format!("\n{title}\n{:>8}", "n \\ D"));
        for f in &features {
            s.push_str(&format!("{f:>9}"));
        }
        s.push('\n');
        for n in &lengths {
            s.push_str(&format!("{n:>8}"));
            for f in &features {
                match lookup(*n, *f) {
                    Some(c) => s.push_str(&format!("{:>9.2}", get(c))),
                    None => s.push_str(&format!("{:>9}", "-")),
                }
            }
            s.push('\n');
        }
    }
    s
}

// ---------------------------------------------------------------------------
// host backend (fastpath, no PJRT)
// ---------------------------------------------------------------------------

/// One (n, D) cell of the host grid.
#[derive(Debug, Clone)]
pub struct HostCell {
    /// The Table-1 kernel the RMFA sessions ran.
    pub kernel: Kernel,
    /// Resolved name of the tier that produced `rmfa_seconds`
    /// (`Backend::Auto` is resolved before timing).
    pub backend: &'static str,
    pub n: usize,
    pub feature_dim: usize,
    pub nmse: f64,
    /// exact softmax attention through the host-fast backend, min seconds
    pub softmax_seconds: f64,
    /// RMFA session forward on the requested backend tier
    pub rmfa_seconds: f64,
    /// RMFA session forward on `Backend::Reference` (scalar, single thread)
    pub reference_seconds: f64,
}

impl HostCell {
    pub fn log10_nmse(&self) -> f64 {
        self.nmse.log10()
    }
    /// log10(t_softmax / t_rmfa): positive = RMFA faster (Fig 4b).
    pub fn log10_speedup(&self) -> f64 {
        (self.softmax_seconds / self.rmfa_seconds).log10()
    }
    /// t_reference / t_rmfa: the fast-vs-oracle speedup factor.
    pub fn speedup_vs_reference(&self) -> f64 {
        self.reference_seconds / self.rmfa_seconds
    }
}

/// Time `session.forward` over a batched problem set: returns (first
/// run's output, full timing over `repeats`). Shared by the host grid
/// and the hotpath bench so every tier is measured under the same
/// protocol (min over the same repeats, no warm-up bias).
pub fn time_forward(
    session: &AttentionSession,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    repeats: usize,
) -> Result<(Tensor, Timing)> {
    let mut t = Timing::default();
    let mut first: Option<Tensor> = None;
    for _ in 0..repeats.max(1) {
        let t0 = Instant::now();
        let out = session.forward(q, k, v)?;
        t.push(t0.elapsed().as_secs_f64());
        if first.is_none() {
            first = Some(out);
        }
    }
    Ok((first.expect("repeats >= 1"), t))
}

/// Run the Fig-4 grid entirely on the host, through the typed `attn`
/// session API. `groups` is batch x heads (paper: 16 x 8 = 128), `dim`
/// the head dimension (paper: 64). Per cell three sessions run: exact
/// softmax (host-fast tier), the RMFA session on the requested
/// `backend` tier (`Auto` resolves before timing; `Reference` times the
/// oracle tier itself, so the speedup column reads ~1x), and the same
/// spec on `Backend::Reference` — all timed min over the same
/// `repeats`, so no path gets a cold-start penalty the others amortize
/// away. NMSE is measured against exact softmax for the exp kernel
/// (Fig 4a) and against the quadratic Definition-2 oracle for every
/// other kernel.
#[allow(clippy::too_many_arguments)]
pub fn run_host_grid(
    kernel: Kernel,
    backend: Backend,
    lengths: &[usize],
    features: &[usize],
    repeats: usize,
    seed: u64,
    groups: usize,
    dim: usize,
) -> Result<Vec<HostCell>> {
    if !kernel.has_maclaurin() {
        bail!(
            "the host microbench measures an RMFA approximation; kernel {kernel} is the \
             exact baseline itself — pick one of: exp, inv, log, trigh, sqrt"
        );
    }
    if backend == Backend::Device {
        bail!(
            "the host grid cannot time the device tier (generic-shape artifacts are not \
             compiled); use the device grid via `microbench --backend device`"
        );
    }
    // Resolve Auto to a host tier explicitly: on a device-capable build
    // `select(Auto)` could pick the device tier, whose generic-shape ops
    // error — and the host grid only times host tiers (the bail above).
    let backend = if backend == Backend::Auto { Backend::HostFast } else { backend };
    let backend_name = crate::attn::select(backend).name();
    let eps = 1e-6f32;
    let softmax_session = AttentionSpec::new(Kernel::Softmax)
        .head_dim(dim)
        .backend(Backend::HostFast)
        .build()?;
    let mut out = Vec::new();
    for &n in lengths {
        let mut rng = Rng::new(seed ^ (n as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let q = Tensor::randn(&mut rng, &[groups, n, dim], 0.5);
        let k = Tensor::randn(&mut rng, &[groups, n, dim], 0.5);
        let v = Tensor::randn(&mut rng, &[groups, n, dim], 1.0);

        let (exact_softmax, sm_t) = time_forward(&softmax_session, &q, &k, &v, repeats)?;
        let softmax_seconds = sm_t.min();

        for &feat in features {
            let spec = AttentionSpec::new(kernel)
                .head_dim(dim)
                .num_features(feat)
                .eps(eps)
                .seed(seed ^ (feat as u64).wrapping_mul(0xD1B54A32D192ED03) ^ n as u64);
            let fast = spec.clone().backend(backend).build()?;
            let reference = spec.backend(Backend::Reference).build()?;

            let (approx, rmfa_t) = time_forward(&fast, &q, &k, &v, repeats)?;
            let (_, reference_t) = time_forward(&reference, &q, &k, &v, repeats)?;
            // the RMFA estimate's target: softmax for exp (Fig 4a), the
            // same-kernel quadratic oracle otherwise (not timed)
            let err = if kernel == Kernel::Exp {
                nmse(&approx.data, &exact_softmax.data)
            } else {
                let target = fast.forward_exact(&q, &k, &v)?;
                nmse(&approx.data, &target.data)
            };

            let cell = HostCell {
                kernel,
                backend: backend_name,
                n,
                feature_dim: feat,
                nmse: err,
                softmax_seconds,
                rmfa_seconds: rmfa_t.min(),
                reference_seconds: reference_t.min(),
            };
            log::info!(
                "host micro {kernel} [{backend_name}] n={n} D={feat}: log10(nmse)={:.2} log10(speedup)={:+.2} vs-reference x{:.1}",
                cell.log10_nmse(),
                cell.log10_speedup(),
                cell.speedup_vs_reference()
            );
            out.push(cell);
        }
    }
    Ok(out)
}

/// Render the host grid: the two Fig-4 panels plus the fast-vs-reference
/// speedup panel.
pub fn render_host(cells: &[HostCell]) -> String {
    let mut lengths: Vec<usize> = cells.iter().map(|c| c.n).collect();
    lengths.dedup();
    let mut features: Vec<usize> = cells.iter().map(|c| c.feature_dim).collect();
    features.sort_unstable();
    features.dedup();
    let lookup = |n: usize, f: usize| cells.iter().find(|c| c.n == n && c.feature_dim == f);
    let kernel = cells.first().map(|c| c.kernel).unwrap_or(Kernel::Exp);
    let nmse_target =
        if kernel == Kernel::Exp { "softmax attention" } else { "exact kernelized" };
    let mut s = String::new();
    let panels: [(String, Box<dyn Fn(&HostCell) -> f64>); 3] = [
        (
            format!("Fig 4a (host): log10 NMSE (RMFA_{kernel} vs {nmse_target})"),
            Box::new(|c: &HostCell| c.log10_nmse()),
        ),
        (
            format!("Fig 4b (host): log10 acceleration ratio (softmax / RMFA_{kernel})"),
            Box::new(|c: &HostCell| c.log10_speedup()),
        ),
        (
            "fastpath speedup over reference path (x)".to_string(),
            Box::new(|c: &HostCell| c.speedup_vs_reference()),
        ),
    ];
    for (title, get) in panels {
        s.push_str(&format!("\n{title}\n{:>8}", "n \\ D"));
        for f in &features {
            s.push_str(&format!("{f:>9}"));
        }
        s.push('\n');
        for n in &lengths {
            s.push_str(&format!("{n:>8}"));
            for f in &features {
                match lookup(*n, *f) {
                    Some(c) => s.push_str(&format!("{:>9.2}", get(c))),
                    None => s.push_str(&format!("{:>9}", "-")),
                }
            }
            s.push('\n');
        }
    }
    s
}

pub fn host_to_json(cells: &[HostCell]) -> Value {
    Value::Arr(
        cells
            .iter()
            .map(|c| {
                Value::obj(vec![
                    ("kernel", Value::str(c.kernel.name())),
                    ("backend", Value::str(c.backend)),
                    ("n", Value::num(c.n as f64)),
                    ("D", Value::num(c.feature_dim as f64)),
                    ("nmse", Value::num(c.nmse)),
                    ("softmax_seconds", Value::num(c.softmax_seconds)),
                    ("rmfa_seconds", Value::num(c.rmfa_seconds)),
                    ("reference_seconds", Value::num(c.reference_seconds)),
                    (
                        "speedup_vs_reference",
                        Value::num(c.speedup_vs_reference()),
                    ),
                ])
            })
            .collect(),
    )
}

pub fn to_json(cells: &[MicroCell]) -> Value {
    Value::Arr(
        cells
            .iter()
            .map(|c| {
                Value::obj(vec![
                    ("n", Value::num(c.n as f64)),
                    ("D", Value::num(c.feature_dim as f64)),
                    ("nmse", Value::num(c.nmse)),
                    ("softmax_seconds", Value::num(c.softmax_seconds)),
                    ("rmfa_seconds", Value::num(c.rmfa_seconds)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_math() {
        let c = MicroCell {
            n: 256,
            feature_dim: 64,
            nmse: 0.01,
            softmax_seconds: 1.0,
            rmfa_seconds: 0.1,
        };
        assert!((c.log10_nmse() + 2.0).abs() < 1e-9);
        assert!((c.log10_speedup() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn host_grid_smoke() {
        let cells =
            run_host_grid(Kernel::Exp, Backend::HostFast, &[8], &[4], 1, 3, 2, 4).unwrap();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.backend, "host");
        assert!(c.nmse.is_finite() && c.nmse >= 0.0, "nmse {}", c.nmse);
        assert!(c.rmfa_seconds >= 0.0 && c.reference_seconds >= 0.0);
        let s = render_host(&cells);
        assert!(s.contains("Fig 4a (host)"));
        assert!(s.contains("fastpath speedup"));
        let j = host_to_json(&cells).to_string();
        assert!(j.contains("speedup_vs_reference"), "{j}");
        assert!(j.contains("\"kernel\""), "{j}");
        assert!(j.contains("\"backend\""), "{j}");
    }

    #[test]
    fn host_grid_times_any_tier() {
        // --backend reference: the grid times the oracle tier itself
        let cells =
            run_host_grid(Kernel::Exp, Backend::Reference, &[6], &[4], 1, 3, 2, 4).unwrap();
        assert_eq!(cells[0].backend, "reference");
        // auto resolves to the host tier before timing
        let cells = run_host_grid(Kernel::Exp, Backend::Auto, &[6], &[4], 1, 3, 2, 4).unwrap();
        assert_eq!(cells[0].backend, "host");
        // the device tier has no generic-shape path to time
        let err =
            run_host_grid(Kernel::Exp, Backend::Device, &[6], &[4], 1, 3, 2, 4).unwrap_err();
        assert!(err.to_string().contains("device"), "{err}");
    }

    #[test]
    fn host_grid_non_exp_kernel_measures_against_kernelized_oracle() {
        let cells =
            run_host_grid(Kernel::Inv, Backend::HostFast, &[6], &[8], 1, 5, 2, 4).unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].kernel, Kernel::Inv);
        assert!(cells[0].nmse.is_finite(), "nmse {}", cells[0].nmse);
        let s = render_host(&cells);
        assert!(s.contains("RMFA_inv"), "{s}");
    }

    #[test]
    fn host_grid_rejects_softmax_kernel() {
        let err = run_host_grid(Kernel::Softmax, Backend::HostFast, &[4], &[4], 1, 1, 1, 4)
            .unwrap_err();
        assert!(err.to_string().contains("exact baseline"), "{err}");
    }

    #[test]
    fn render_includes_axes() {
        let c = MicroCell {
            n: 256,
            feature_dim: 64,
            nmse: 0.01,
            softmax_seconds: 1.0,
            rmfa_seconds: 0.1,
        };
        let s = render(&[c]);
        assert!(s.contains("256"));
        assert!(s.contains("64"));
        assert!(s.contains("Fig 4a"));
        assert!(s.contains("Fig 4b"));
    }
}
