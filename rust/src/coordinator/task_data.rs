//! Task-specific dataset + batch staging.
//!
//! Bridges the synthetic datasets (`data::*`) to the HLO modules' batch
//! argument lists (manifest `batch_specs` order): cls = (tokens, mask,
//! labels), retrieval = (tokens1, mask1, tokens2, mask2, labels),
//! lm = (tokens, loss_mask).

use anyhow::{bail, Result};

use crate::data::{self, batcher};
use crate::runtime::HostArg;

/// A materialized train-or-eval split for one task.
pub enum TaskData {
    Cls(data::ClsDataset),
    Pair(data::PairDataset),
    Lm(data::LmDataset),
}

impl TaskData {
    /// Synthesize the split. Train and eval use disjoint seed streams.
    pub fn build(task: &str, seed: u64, count: usize, seq_len: usize,
                 src_max: usize) -> Result<TaskData> {
        Ok(match task {
            "lra_text" | "lra_listops" => {
                TaskData::Cls(data::build_cls(task, seed, count, seq_len))
            }
            "lra_retrieval" => TaskData::Pair(data::build_retrieval(seed, count, seq_len)),
            "translation" => {
                TaskData::Lm(data::build_translation(seed, count, src_max, seq_len))
            }
            other => bail!("unknown task {other:?}"),
        })
    }

    pub fn len(&self) -> usize {
        match self {
            TaskData::Cls(d) => d.len(),
            TaskData::Pair(d) => d.len(),
            TaskData::Lm(d) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stage one index batch as module arguments (manifest order).
    pub fn stage(&self, idx: &[usize], seq_len: usize) -> Vec<HostArg> {
        let b = idx.len();
        match self {
            TaskData::Cls(d) => vec![
                HostArg::I32(vec![b, seq_len], batcher::gather_i32(&d.tokens, idx)),
                HostArg::I32(vec![b, seq_len], batcher::gather_i32(&d.masks, idx)),
                HostArg::I32(vec![b], batcher::gather_scalar_i32(&d.labels, idx)),
            ],
            TaskData::Pair(d) => vec![
                HostArg::I32(vec![b, seq_len], batcher::gather_i32(&d.tokens1, idx)),
                HostArg::I32(vec![b, seq_len], batcher::gather_i32(&d.masks1, idx)),
                HostArg::I32(vec![b, seq_len], batcher::gather_i32(&d.tokens2, idx)),
                HostArg::I32(vec![b, seq_len], batcher::gather_i32(&d.masks2, idx)),
                HostArg::I32(vec![b], batcher::gather_scalar_i32(&d.labels, idx)),
            ],
            TaskData::Lm(d) => vec![
                HostArg::I32(vec![b, seq_len], batcher::gather_i32(&d.tokens, idx)),
                HostArg::F32(vec![b, seq_len], batcher::gather_f32(&d.loss_masks, idx)),
            ],
        }
    }

    /// For LM eval: prompt rows (source only, targets blanked) and the
    /// reference targets, for greedy-decode BLEU.
    pub fn lm_prompts(&self, idx: &[usize], src_max: usize, seq_len: usize)
                      -> (Vec<i32>, Vec<Vec<i32>>) {
        let TaskData::Lm(d) = self else {
            panic!("lm_prompts on non-LM task");
        };
        let mut prompts = Vec::with_capacity(idx.len() * seq_len);
        let mut refs = Vec::with_capacity(idx.len());
        for &i in idx {
            let row = &d.tokens[i];
            // keep [src | SEP], blank the target span with PAD
            for (pos, &t) in row.iter().enumerate() {
                prompts.push(if pos <= src_max { t } else { crate::data::vocab::SYM_PAD });
            }
            refs.push(d.tgts[i].clone());
        }
        (prompts, refs)
    }

    /// Number of label-bearing units per batch row (for accuracy
    /// normalization): 1 for cls/retrieval; LM tracks tokens instead.
    pub fn is_lm(&self) -> bool {
        matches!(self, TaskData::Lm(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_shapes_per_task() {
        let d = TaskData::build("lra_listops", 1, 8, 64, 0).unwrap();
        let args = d.stage(&[0, 1, 2, 3], 64);
        assert_eq!(args.len(), 3);
        match &args[0] {
            HostArg::I32(dims, data) => {
                assert_eq!(dims, &vec![4, 64]);
                assert_eq!(data.len(), 256);
            }
            _ => panic!("expected i32 tokens"),
        }
    }

    #[test]
    fn retrieval_stages_five_args() {
        let d = TaskData::build("lra_retrieval", 1, 4, 64, 0).unwrap();
        assert_eq!(d.stage(&[0, 1], 64).len(), 5);
    }

    #[test]
    fn lm_prompts_blank_targets() {
        let d = TaskData::build("translation", 1, 4, 64, 24).unwrap();
        let (prompts, refs) = d.lm_prompts(&[0], 24, 64);
        assert_eq!(prompts.len(), 64);
        // target span blanked
        for &t in &prompts[25..] {
            assert_eq!(t, crate::data::vocab::SYM_PAD);
        }
        assert!(!refs[0].is_empty());
    }
}
