//! Layer-3 coordinator: everything that happens at run time.
//!
//! The paper's contribution is the attention approximation (L1/L2), so —
//! per the architecture rule — L3 is the *experiment system* around it:
//! dataset synthesis, batch scheduling, the device-resident training loop,
//! periodic evaluation (accuracy / perplexity / greedy-decode BLEU),
//! checkpoints, and the multi-process Table-2 sweep orchestrator.

pub mod checkpoint;
pub mod fig3;
pub mod microbench;
pub mod sweep;
pub mod task_data;
pub mod trainer;

pub use task_data::TaskData;
pub use trainer::{RunReport, Trainer};
