//! Fig-3 harness: Transformer with vs without ppSBN on the synthetic
//! translation task, tracking loss / perplexity / BLEU per epoch.
//!
//! Mirrors the paper's toy experiment: same base model (softmax
//! attention), ppSBN wrapped around the attention layer in one arm,
//! identical data/seeds in both arms.

use anyhow::Result;

use crate::config::RunConfig;
use crate::metrics::Perplexity;
use crate::runtime::Registry;
use crate::util::json::Value;

use super::trainer::Trainer;

/// Per-epoch curve point for one arm.
#[derive(Debug, Clone)]
pub struct EpochPoint {
    pub epoch: usize,
    pub loss: f64,
    pub perplexity: f64,
    pub bleu: f64,
}

/// Full Fig-3 result: two arms, aligned epochs.
#[derive(Debug, Clone)]
pub struct Fig3Result {
    pub base: Vec<EpochPoint>,
    pub ppsbn: Vec<EpochPoint>,
}

/// Train both arms for `epochs` x `steps_per_epoch` steps.
pub fn run(
    reg: &Registry,
    base_cfg: &RunConfig,
    epochs: usize,
    steps_per_epoch: usize,
) -> Result<Fig3Result> {
    let mut arms = Vec::new();
    for suffix in [".base", ".ppsbn"] {
        let mut cfg = base_cfg.clone();
        cfg.task = "translation".into();
        cfg.variant = "softmax".into();
        cfg.suffix = suffix.into();
        cfg.steps = epochs * steps_per_epoch;
        let mut tr = Trainer::build(cfg, reg)?;
        let mut curve = Vec::new();
        for e in 1..=epochs {
            let mut ppl_epoch = Perplexity::default();
            let mut loss_sum = 0.0;
            for _ in 0..steps_per_epoch {
                let buf = tr.step()?;
                let loss = crate::runtime::DeviceState::loss_value(&buf)? as f64;
                loss_sum += loss;
                ppl_epoch.update(loss, 1.0);
            }
            let (eval_loss, bleu, ppl) = tr.evaluate()?;
            let point = EpochPoint {
                epoch: e,
                loss: loss_sum / steps_per_epoch as f64,
                perplexity: if ppl.is_nan() { eval_loss.exp() } else { ppl },
                bleu,
            };
            log::info!(
                "fig3 {suffix} epoch {e}: loss {:.4} ppl {:.2} bleu {:.2}",
                point.loss,
                point.perplexity,
                point.bleu
            );
            curve.push(point);
        }
        arms.push(curve);
    }
    let ppsbn = arms.pop().unwrap();
    let base = arms.pop().unwrap();
    Ok(Fig3Result { base, ppsbn })
}

/// ASCII rendering of the three panels.
pub fn render(r: &Fig3Result) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "\nFig 3: Transformer +- ppSBN on synthetic Multi30K-scale translation\n{:>6} | {:>10} {:>10} | {:>10} {:>10} | {:>8} {:>8}\n",
        "epoch", "loss", "loss+sbn", "ppl", "ppl+sbn", "bleu", "bleu+sbn"
    ));
    for (b, p) in r.base.iter().zip(&r.ppsbn) {
        s.push_str(&format!(
            "{:>6} | {:>10.4} {:>10.4} | {:>10.2} {:>10.2} | {:>8.2} {:>8.2}\n",
            b.epoch, b.loss, p.loss, b.perplexity, p.perplexity, b.bleu, p.bleu
        ));
    }
    s
}

pub fn to_json(r: &Fig3Result) -> Value {
    let arm = |pts: &[EpochPoint]| {
        Value::Arr(
            pts.iter()
                .map(|p| {
                    Value::obj(vec![
                        ("epoch", Value::num(p.epoch as f64)),
                        ("loss", Value::num(p.loss)),
                        ("perplexity", Value::num(p.perplexity)),
                        ("bleu", Value::num(p.bleu)),
                    ])
                })
                .collect(),
        )
    };
    Value::obj(vec![("base", arm(&r.base)), ("ppsbn", arm(&r.ppsbn))])
}
