//! Table-2 sweep orchestrator: run every (task, variant) cell in an
//! isolated subprocess, normalize against the base Transformer, and emit
//! the paper-style table.
//!
//! Subprocess isolation matters for the *memory* column: peak RSS is a
//! process-lifetime high-water mark, so sharing a process across variants
//! would contaminate later cells with earlier peaks. The child is this
//! same binary invoked as `macformer train --out-json <tmp>`; the parent
//! reads the JSON report back. This mirrors (and improves on) the paper's
//! protocol of sequential per-model runs on one GPU.

use std::path::PathBuf;
use std::process::Command;

use anyhow::{anyhow, bail, Result};

use crate::config::RunConfig;
use crate::util::json::{self, Value};

/// One Table-2 cell result.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub task: String,
    pub variant: String,
    pub train_seconds: f64,
    pub step_seconds: f64,
    pub peak_rss_bytes: u64,
    pub accuracy: f64,
}

/// The normalized Table-2 row block for one task.
#[derive(Debug, Clone)]
pub struct TaskTable {
    pub task: String,
    pub cells: Vec<CellResult>,
}

impl TaskTable {
    /// Normalize time/memory to the first (base Transformer) row, like the
    /// paper. Uses steady-state step time (not compile time) for the time
    /// column — compile is a one-off, the paper's numbers are train time.
    pub fn normalized(&self) -> Vec<(String, f64, f64, f64)> {
        let base = &self.cells[0];
        self.cells
            .iter()
            .map(|c| {
                (
                    c.variant.clone(),
                    c.step_seconds / base.step_seconds.max(1e-12),
                    c.peak_rss_bytes as f64 / (base.peak_rss_bytes as f64).max(1.0),
                    c.accuracy,
                )
            })
            .collect()
    }
}

/// Run one cell in a child process; parse its JSON report.
pub fn run_cell_subprocess(cfg: &RunConfig) -> Result<CellResult> {
    let exe = std::env::current_exe()?;
    run_cell_with_binary(cfg, &exe)
}

/// Same, with an explicit launcher binary (used by the bench harnesses,
/// whose own executable is the bench, not the `macformer` CLI).
pub fn run_cell_with_binary(cfg: &RunConfig, exe: &std::path::Path) -> Result<CellResult> {
    let out: PathBuf = std::env::temp_dir().join(format!(
        "macformer_cell_{}_{}_{}.json",
        cfg.task,
        cfg.variant,
        std::process::id()
    ));
    let status = Command::new(exe)
        .args([
            "train",
            "--task", &cfg.task,
            "--variant", &cfg.variant,
            "--suffix", &cfg.suffix,
            "--steps", &cfg.steps.to_string(),
            "--train-examples", &cfg.train_examples.to_string(),
            "--eval-examples", &cfg.eval_examples.to_string(),
            "--seed", &cfg.seed.to_string(),
            "--eval-every", &(cfg.steps + 1).to_string(), // final eval only
            "--log-every", &cfg.log_every.to_string(),
            "--artifacts", &cfg.artifacts_dir,
            "--out-json", out.to_str().unwrap(),
        ])
        .status()
        .map_err(|e| anyhow!("spawning child: {e}"))?;
    if !status.success() {
        bail!("child for {}/{} failed: {status}", cfg.task, cfg.variant);
    }
    let text = std::fs::read_to_string(&out)?;
    std::fs::remove_file(&out).ok();
    let v = json::parse(&text).map_err(|e| anyhow!("child report: {e}"))?;
    Ok(CellResult {
        task: cfg.task.clone(),
        variant: format!("{}{}", cfg.variant, cfg.suffix),
        train_seconds: v.get("train_seconds").as_f64().unwrap_or(f64::NAN),
        step_seconds: v.get("step_seconds_mean").as_f64().unwrap_or(f64::NAN),
        peak_rss_bytes: v.get("peak_rss_bytes").as_f64().unwrap_or(0.0) as u64,
        accuracy: v.get("quality").as_f64().unwrap_or(f64::NAN),
    })
}

/// Run all variants on one task (sequentially, like the paper's protocol).
pub fn run_task(base_cfg: &RunConfig, task: &str, variants: &[&str]) -> Result<TaskTable> {
    let exe = std::env::current_exe()?;
    run_task_with_binary(base_cfg, task, variants, &exe)
}

/// Task sweep with an explicit launcher binary.
pub fn run_task_with_binary(
    base_cfg: &RunConfig,
    task: &str,
    variants: &[&str],
    exe: &std::path::Path,
) -> Result<TaskTable> {
    let mut cells = Vec::new();
    for v in variants {
        let mut cfg = base_cfg.clone();
        cfg.task = task.to_string();
        cfg.variant = v.to_string();
        log::info!("sweep: {task}/{v} ({} steps)", cfg.steps);
        cells.push(run_cell_with_binary(&cfg, exe)?);
    }
    Ok(TaskTable { task: task.to_string(), cells })
}

/// Render the paper-style table block.
pub fn render_table(tables: &[TaskTable]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<22}{}\n",
        "",
        tables
            .iter()
            .map(|t| format!("| {:<30}", t.task))
            .collect::<String>()
    ));
    out.push_str(&format!(
        "{:<22}{}\n",
        "Model",
        tables
            .iter()
            .map(|_| format!("| {:>8} {:>8} {:>10} ", "Time", "Memory", "Accuracy"))
            .collect::<String>()
    ));
    let n_rows = tables.first().map(|t| t.cells.len()).unwrap_or(0);
    for i in 0..n_rows {
        let name = &tables[0].cells[i].variant;
        out.push_str(&format!("{name:<22}"));
        for t in tables {
            let rows = t.normalized();
            let (_, time, mem, acc) = &rows[i];
            out.push_str(&format!("| {time:>8.3} {mem:>8.3} {acc:>10.3} "));
        }
        out.push('\n');
    }
    out
}

/// Serialize sweep results for EXPERIMENTS.md tooling.
pub fn to_json(tables: &[TaskTable]) -> Value {
    Value::Arr(
        tables
            .iter()
            .map(|t| {
                Value::obj(vec![
                    ("task", Value::str(&t.task)),
                    (
                        "cells",
                        Value::Arr(
                            t.cells
                                .iter()
                                .map(|c| {
                                    Value::obj(vec![
                                        ("variant", Value::str(&c.variant)),
                                        ("train_seconds", Value::num(c.train_seconds)),
                                        ("step_seconds", Value::num(c.step_seconds)),
                                        (
                                            "peak_rss_bytes",
                                            Value::num(c.peak_rss_bytes as f64),
                                        ),
                                        ("accuracy", Value::num(c.accuracy)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(v: &str, step: f64, rss: u64, acc: f64) -> CellResult {
        CellResult {
            task: "t".into(),
            variant: v.into(),
            train_seconds: step * 10.0,
            step_seconds: step,
            peak_rss_bytes: rss,
            accuracy: acc,
        }
    }

    #[test]
    fn normalization_against_first_row() {
        let t = TaskTable {
            task: "t".into(),
            cells: vec![cell("softmax", 2.0, 1000, 60.0), cell("mac_exp", 1.0, 1500, 61.0)],
        };
        let rows = t.normalized();
        assert_eq!(rows[0].1, 1.0);
        assert_eq!(rows[0].2, 1.0);
        assert_eq!(rows[1].1, 0.5);
        assert_eq!(rows[1].2, 1.5);
    }

    #[test]
    fn table_renders_all_rows() {
        let t = TaskTable {
            task: "lra_text".into(),
            cells: vec![cell("softmax", 2.0, 1000, 60.0), cell("mac_exp", 1.0, 1500, 61.0)],
        };
        let s = render_table(&[t]);
        assert!(s.contains("softmax"));
        assert!(s.contains("mac_exp"));
        assert!(s.contains("0.500"));
    }
}
