//! The lazy JSON wire layer: a borrowing scanner over the request
//! buffer plus an allocation-conscious response serializer.
//!
//! The frontend must stay off the compute hot path, so request bodies
//! are never parsed into a DOM (`util::json::Value` allocates a node
//! per number — a 4k-token prompt would be ~500k allocations). Instead
//! [`Scan`] walks the raw bytes once, in the spirit of squirrel-json's
//! sparse scanning: the caller names the fields it needs (`"q"`,
//! `"k"`, `"v"`, …), numbers are parsed straight into a reusable
//! `Vec<f32>`, and every other value is skipped structurally without
//! materializing anything.
//!
//! Robustness contract (enforced by `tests/serve_net.rs`): truncated
//! input, non-UTF8 bytes, deeply nested containers, and arbitrary
//! garbage are all typed [`WireError`]s — never a panic, never
//! unbounded recursion (the skipper is iterative with a hard depth
//! cap), never an out-of-bounds read.
//!
//! On the response side, floats are written with Rust's shortest
//! round-trip formatting, so an `f32` crossing the wire twice comes
//! back **bit-identical** — the property the socket load generator's
//! verification leans on ([`write_f32`], round-trip proved in the
//! tests below).

use std::fmt;

/// Hard nesting cap for skipped values. Deeper input is hostile (the
/// API's own payloads are depth 1) and is rejected before it can cost
/// anything.
const MAX_DEPTH: usize = 64;

/// Why a request body was rejected by the scanner. Every variant maps
/// to a 400-family response in the HTTP layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Malformed JSON at `pos` (byte offset into the body).
    Syntax { pos: usize, what: &'static str },
    /// Containers nested past [`MAX_DEPTH`].
    TooDeep,
    /// A required field is absent.
    Missing { field: &'static str },
    /// A field exists but has the wrong shape (e.g. `"q": "hi"`).
    BadField { field: &'static str },
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Syntax { pos, what } => write!(f, "bad JSON at byte {pos}: {what}"),
            WireError::TooDeep => write!(f, "JSON nested deeper than {MAX_DEPTH} levels"),
            WireError::Missing { field } => write!(f, "missing field {field:?}"),
            WireError::BadField { field } => write!(f, "field {field:?} has the wrong type"),
        }
    }
}

impl std::error::Error for WireError {}

/// A single-pass, borrowing scanner over one JSON object body.
pub struct Scan<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Scan<'a> {
    /// Start scanning `body`, which must hold exactly one top-level
    /// JSON object (the shape of every API request).
    pub fn object(body: &'a [u8]) -> Result<Scan<'a>, WireError> {
        let mut s = Scan { bytes: body, pos: 0 };
        s.skip_ws();
        s.expect(b'{', "expected '{'")?;
        Ok(s)
    }

    fn err(&self, what: &'static str) -> WireError {
        WireError::Syntax { pos: self.pos, what }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8, what: &'static str) -> Result<(), WireError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(what))
        }
    }

    /// Advance to the next key in the top-level object. Returns the
    /// raw key bytes (no unescaping — the API's field names are plain
    /// ASCII, so an escaped key simply matches nothing and its value
    /// is skipped), or `None` at the closing `}`.
    ///
    /// The caller must consume the value after a `Some` key — with
    /// [`Scan::f32_array_into`], [`Scan::str_value`],
    /// [`Scan::usize_value`], or [`Scan::skip_value`] — before calling
    /// `next_key` again.
    pub fn next_key(&mut self) -> Result<Option<&'a [u8]>, WireError> {
        self.skip_ws();
        match self.peek() {
            Some(b'}') => {
                self.pos += 1;
                return Ok(None);
            }
            Some(b',') => {
                self.pos += 1;
                self.skip_ws();
            }
            _ => {}
        }
        let key = self.raw_string()?;
        self.skip_ws();
        self.expect(b':', "expected ':' after key")?;
        self.skip_ws();
        Ok(Some(key))
    }

    /// The raw contents of a JSON string (between the quotes, escapes
    /// left as-is). Bounded by the body; never reads past it.
    fn raw_string(&mut self) -> Result<&'a [u8], WireError> {
        self.expect(b'"', "expected '\"'")?;
        let start = self.pos;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let s = &self.bytes[start..self.pos];
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    // skip the escape and the escaped byte (\uXXXX's
                    // hex digits are ordinary bytes to the skipper)
                    self.pos += 2;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated escape"));
                    }
                }
                Some(_) => self.pos += 1,
            }
        }
    }

    /// Consume a string value and return it as UTF-8. Escapes are
    /// rejected (the API's string fields are opaque handles like
    /// `"s-12"` which never need them).
    pub fn str_value(&mut self, field: &'static str) -> Result<&'a str, WireError> {
        let raw = self.raw_string().map_err(|_| WireError::BadField { field })?;
        if raw.contains(&b'\\') {
            return Err(WireError::BadField { field });
        }
        std::str::from_utf8(raw).map_err(|_| WireError::BadField { field })
    }

    /// Consume a non-negative integer value.
    pub fn usize_value(&mut self, field: &'static str) -> Result<usize, WireError> {
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(WireError::BadField { field });
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse().ok())
            .ok_or(WireError::BadField { field })
    }

    /// Consume a `[...]` of numbers, parsed straight into `out`
    /// (cleared first; grows only past its previous high-water mark).
    /// The JSON number grammar cannot spell NaN/inf, so the wire layer
    /// structurally never admits a non-finite float — the pool's
    /// `screen_inputs` stays on as defense in depth, not first line.
    pub fn f32_array_into(
        &mut self,
        field: &'static str,
        out: &mut Vec<f32>,
    ) -> Result<(), WireError> {
        out.clear();
        if self.peek() != Some(b'[') {
            return Err(WireError::BadField { field });
        }
        self.pos += 1;
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(());
        }
        loop {
            self.skip_ws();
            out.push(self.number(field)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    /// One JSON number, returned as f32. The token span is matched
    /// against the JSON grammar first, so `f32::from_str` never sees
    /// `inf`/`NaN` spellings.
    fn number(&mut self, field: &'static str) -> Result<f32, WireError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut saw_digit = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => {
                    saw_digit = true;
                    self.pos += 1;
                }
                b'.' | b'e' | b'E' | b'+' | b'-' => self.pos += 1,
                _ => break,
            }
        }
        if !saw_digit {
            return Err(WireError::BadField { field });
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f32>().ok())
            .filter(|x| x.is_finite())
            .ok_or(WireError::BadField { field })
    }

    /// Skip one value of any shape — iteratively, with a hard depth
    /// cap, so hostile nesting can neither overflow the stack nor loop
    /// forever.
    pub fn skip_value(&mut self) -> Result<(), WireError> {
        let mut depth = 0usize;
        loop {
            self.skip_ws();
            match self.peek() {
                None => return Err(self.err("truncated value")),
                Some(b'{') | Some(b'[') => {
                    depth += 1;
                    if depth > MAX_DEPTH {
                        return Err(WireError::TooDeep);
                    }
                    self.pos += 1;
                }
                Some(b'"') => {
                    self.raw_string()?;
                }
                Some(_) => {
                    // number / literal / garbage token: consume until a
                    // structural byte (validity doesn't matter — the
                    // field was not requested)
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if matches!(c, b',' | b']' | b'}' | b'{' | b'[' | b'"')
                            || c.is_ascii_whitespace()
                        {
                            break;
                        }
                        self.pos += 1;
                    }
                    if self.pos == start {
                        return Err(self.err("unexpected byte"));
                    }
                }
            }
            // unwind closers / separators until this value is done
            loop {
                self.skip_ws();
                match self.peek() {
                    Some(b']') | Some(b'}') if depth > 0 => {
                        depth -= 1;
                        self.pos += 1;
                    }
                    Some(b',') if depth > 0 => {
                        self.pos += 1;
                        break; // next element of the open container
                    }
                    _ => {
                        if depth == 0 {
                            return Ok(());
                        }
                        break; // first value of a just-opened container
                    }
                }
            }
            if depth == 0 {
                return Ok(());
            }
        }
    }
}

/// The `(q, k, v)` row sets every submit/prefill/decode request
/// carries, parsed into reusable buffers.
#[derive(Default)]
pub struct TokenBody {
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl TokenBody {
    /// Scan `body` for the `q`/`k`/`v` arrays (all required), skipping
    /// every other field. The buffers are reused across requests on
    /// the same connection.
    pub fn parse_into(&mut self, body: &[u8]) -> Result<(), WireError> {
        let mut scan = Scan::object(body)?;
        let (mut got_q, mut got_k, mut got_v) = (false, false, false);
        while let Some(key) = scan.next_key()? {
            match key {
                b"q" => {
                    scan.f32_array_into("q", &mut self.q)?;
                    got_q = true;
                }
                b"k" => {
                    scan.f32_array_into("k", &mut self.k)?;
                    got_k = true;
                }
                b"v" => {
                    scan.f32_array_into("v", &mut self.v)?;
                    got_v = true;
                }
                _ => scan.skip_value()?,
            }
        }
        for (field, got) in [("q", got_q), ("k", got_k), ("v", got_v)] {
            if !got {
                return Err(WireError::Missing { field });
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// response serialization
// ---------------------------------------------------------------------------

/// Append one f32 in shortest round-trip form. Rust's `{}` formatting
/// for `f32` prints the shortest decimal that parses back to exactly
/// the same bits, and [`Scan::number`] parses it back with
/// `f32::from_str` — so outputs cross the wire losslessly.
pub fn write_f32(buf: &mut String, x: f32) {
    use fmt::Write;
    if x.is_finite() {
        let _ = write!(buf, "{x}");
    } else {
        // JSON cannot spell non-finite values; the serve layer screens
        // them out long before here, but a serializer must still be
        // total
        buf.push_str("null");
    }
}

/// Append `[x0,x1,...]`.
pub fn write_f32_array(buf: &mut String, xs: &[f32]) {
    buf.push('[');
    for (i, &x) in xs.iter().enumerate() {
        if i > 0 {
            buf.push(',');
        }
        write_f32(buf, x);
    }
    buf.push(']');
}

/// Append a JSON string (the subset the API emits: handles and error
/// text; control characters and quotes escaped).
pub fn write_str(buf: &mut String, s: &str) {
    use fmt::Write;
    buf.push('"');
    for c in s.chars() {
        match c {
            '"' => buf.push_str("\\\""),
            '\\' => buf.push_str("\\\\"),
            '\n' => buf.push_str("\\n"),
            '\r' => buf.push_str("\\r"),
            '\t' => buf.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(buf, "\\u{:04x}", c as u32);
            }
            c => buf.push(c),
        }
    }
    buf.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn token_body_extracts_only_requested_fields() {
        let mut body = TokenBody::default();
        body.parse_into(
            br#"{"session":"s-3","q":[1,2.5,-3e-2],"ignored":{"a":[1,2]},"k":[0],"v":[],"flag":true}"#,
        )
        .unwrap();
        assert_eq!(body.q, vec![1.0, 2.5, -3e-2]);
        assert_eq!(body.k, vec![0.0]);
        assert!(body.v.is_empty());
    }

    #[test]
    fn missing_and_mistyped_fields_are_typed_errors() {
        let mut body = TokenBody::default();
        assert_eq!(
            body.parse_into(br#"{"q":[1],"k":[1]}"#),
            Err(WireError::Missing { field: "v" })
        );
        assert_eq!(
            body.parse_into(br#"{"q":"hi","k":[1],"v":[1]}"#),
            Err(WireError::BadField { field: "q" })
        );
        // NaN/inf are unrepresentable in the JSON number grammar
        assert_eq!(
            body.parse_into(br#"{"q":[NaN],"k":[1],"v":[1]}"#),
            Err(WireError::BadField { field: "q" })
        );
        // a finite-overflow literal (1e999 -> inf) is rejected too
        assert_eq!(
            body.parse_into(br#"{"q":[1e999],"k":[1],"v":[1]}"#),
            Err(WireError::BadField { field: "q" })
        );
    }

    #[test]
    fn truncated_and_garbage_bodies_never_panic() {
        let mut body = TokenBody::default();
        for bad in [
            &b""[..],
            b"{",
            b"{\"q\":[1,",
            b"{\"q\":[1]",
            b"not json at all",
            b"{\"q\":[1],\"k\":[1],\"v\":[1]",
            b"{\"x\": \"unterminated",
            b"{\"x\": \"trailing escape\\",
            b"\xff\xfe{\"q\":[1]}",
        ] {
            assert!(body.parse_into(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn hostile_nesting_hits_the_depth_cap_not_the_stack() {
        let mut evil = String::from("{\"x\":");
        for _ in 0..100_000 {
            evil.push('[');
        }
        let mut body = TokenBody::default();
        assert_eq!(body.parse_into(evil.as_bytes()), Err(WireError::TooDeep));
    }

    #[test]
    fn skipper_handles_nested_values_between_wanted_fields() {
        let mut body = TokenBody::default();
        body.parse_into(
            br#"{"a":{"b":[{"c":"}]"},null,-1.5e3],"d":{}},"q":[7],"e":[[],[[]]],"k":[8],"v":[9]}"#,
        )
        .unwrap();
        assert_eq!((body.q[0], body.k[0], body.v[0]), (7.0, 8.0, 9.0));
    }

    /// The load generator's bit-exact verification depends on this:
    /// f32 -> shortest decimal -> f32 is the identity, for any bits.
    #[test]
    fn f32_round_trips_bit_exactly_through_the_wire() {
        let mut rng = Rng::new(77);
        let mut buf = String::new();
        let mut vals = vec![0.0f32, -0.0, 1.0, f32::MIN_POSITIVE, f32::MAX, 1e-40];
        for _ in 0..2000 {
            let x = f32::from_bits(rng.next_u32());
            if x.is_finite() {
                vals.push(x);
            }
        }
        buf.push_str("{\"q\":");
        write_f32_array(&mut buf, &vals);
        buf.push_str(",\"k\":[],\"v\":[]}");
        let mut body = TokenBody::default();
        body.parse_into(buf.as_bytes()).unwrap();
        assert_eq!(body.q.len(), vals.len());
        for (a, b) in vals.iter().zip(&body.q) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} round-trip");
        }
    }

    #[test]
    fn string_writer_escapes_control_bytes() {
        let mut buf = String::new();
        write_str(&mut buf, "a\"b\\c\nd\u{1}");
        assert_eq!(buf, r#""a\"b\\c\nd\u0001""#);
    }
}
