//! Socket mode for the load generator: drive a running [`Server`]
//! over real TCP with one closed-loop client thread per stream, and
//! verify survivor outputs **bit-identical** to in-process decode.
//!
//! The clients generate exactly the same deterministic token/prompt
//! data as the in-process loadgen (same per-stream seeds), so the
//! verification replay is the same too: every output row that crossed
//! the wire — shortest round-trip f32 decimal both ways — must match
//! the single-stream [`CausalState`](crate::attn::CausalState) replay
//! bit for bit.
//!
//! Chaos over the wire reuses the seeded [`FaultPlan`], with two
//! differences from the in-process drive loop, both forced by the
//! protocol:
//!
//! * NaN injection is skipped — the JSON number grammar cannot spell
//!   non-finite values, so the wire layer structurally rejects them
//!   before the input screen ever runs (`tests/serve_net.rs` pins the
//!   400 instead).
//! * Planned fold panics and forced hibernations are driven through
//!   the explicit `arm_fault` / `hibernate` endpoints at the planned
//!   token positions, by splitting each stream's decode into segments
//!   around them. The casualty then lands mid-stream as an
//!   `event: error` frame on an already-committed 200 response —
//!   never a 5xx status — and the surviving prefix still verifies.
//!
//! [`Server`]: super::Server

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::attn::AttentionSpec;
use crate::serve::loadgen::{generate_prompts, generate_tokens, token_stride, LoadConfig};
use crate::util::json::Value;

use super::wire::{Scan, TokenBody};

/// Give up on a retryable status after this many attempts — keeps a
/// misbehaving server from hanging the generator. The wall-clock
/// budget below usually fires first.
const MAX_RETRIES: usize = 2048;

/// Ceiling for a single backoff sleep, in milliseconds.
const MAX_BACKOFF_MS: u64 = 50;

/// Default per-request retry wall-clock budget (see
/// [`set_retry_budget_ms`]).
pub const DEFAULT_RETRY_BUDGET_MS: u64 = 60_000;

/// Total wall-clock a single request may spend in its retry loop
/// before giving up, in milliseconds. The attempt cap alone bounds
/// the wait only indirectly (attempts x max backoff); behind a router
/// that keeps answering `503 migrating` for a lost stream, an
/// explicit time budget is the difference between a clean
/// [`RetryGaveUp`] and a client that looks hung. `0` disables the
/// wall-clock cap, leaving only [`MAX_RETRIES`]. Surfaced on the CLI
/// as `--retry-budget-ms`.
static RETRY_BUDGET_MS: AtomicU64 = AtomicU64::new(DEFAULT_RETRY_BUDGET_MS);

/// Set the per-request retry wall-clock budget in milliseconds
/// (`0` = attempt-capped only). Process-global: applies to every
/// loadgen client thread.
pub fn set_retry_budget_ms(ms: u64) {
    RETRY_BUDGET_MS.store(ms, Ordering::SeqCst);
}

/// Sleep before retry `attempt` if the wall-clock budget still covers
/// the wait; `false` means the budget is spent and the caller must
/// give up now (with the elapsed time in its [`RetryGaveUp`]).
fn retry_sleep(started: Instant, attempt: usize, retry_after: Option<u64>, salt: u64) -> bool {
    let wait = Duration::from_millis(backoff_ms(attempt, retry_after, salt));
    let budget = RETRY_BUDGET_MS.load(Ordering::SeqCst);
    if budget != 0 && started.elapsed() + wait > Duration::from_millis(budget) {
        return false;
    }
    std::thread::sleep(wait);
    true
}

/// A retryable request that exhausted its attempt budget or its
/// wall-clock budget. Typed (and surfaced through `anyhow`'s chain,
/// so `downcast_ref` works) to keep "the server kept saying come back
/// later" distinguishable from protocol failures.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetryGaveUp {
    pub method: String,
    pub path: String,
    pub attempts: usize,
    /// The last retryable status observed before giving up.
    pub last_status: u16,
    /// Wall-clock spent retrying when the client gave up — at most
    /// the configured [`set_retry_budget_ms`] budget (plus one
    /// backoff) when that cap fired.
    pub elapsed_ms: u64,
}

impl std::fmt::Display for RetryGaveUp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} {}: still {} after {} attempts ({} ms)",
            self.method, self.path, self.last_status, self.attempts, self.elapsed_ms
        )
    }
}

impl std::error::Error for RetryGaveUp {}

/// Retryable-status tallies for one client connection. `http_5xx`
/// only counts answers the client could *not* retry (no `Retry-After`
/// hint, i.e. the server says the condition is final) — retried 429s
/// and 503s land in their own buckets.
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct RetryCounts {
    pub(crate) http_429: u64,
    pub(crate) http_503: u64,
    pub(crate) http_5xx: u64,
}

/// Sleep before retry `attempt` (0-based): exponential from the
/// server's `Retry-After` hint (scheduler ticks, read as milliseconds,
/// default 1), doubled per attempt, plus deterministic jitter from
/// `salt` so a thundering herd of clients spreads out instead of
/// re-colliding, capped at [`MAX_BACKOFF_MS`].
fn backoff_ms(attempt: usize, retry_after: Option<u64>, salt: u64) -> u64 {
    let base = retry_after.unwrap_or(1).clamp(1, MAX_BACKOFF_MS);
    let exp = base.saturating_mul(1u64 << attempt.min(6)).min(MAX_BACKOFF_MS);
    // splitmix64-style avalanche over (salt, attempt)
    let mut x = salt ^ (attempt as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (exp + x % (exp / 2 + 1)).min(MAX_BACKOFF_MS)
}

// ---------------------------------------------------------------------------
// a minimal blocking HTTP/1.1 client (keep-alive, chunked-aware)
// ---------------------------------------------------------------------------

struct Http {
    stream: TcpStream,
    buf: Vec<u8>,
    pos: usize,
    out: String,
    /// Sent as `x-request-id` on every request when non-empty, so the
    /// server's stage spans (and an exported trace) carry the stream's
    /// identity end to end.
    req_id: String,
}

struct Head {
    status: u16,
    content_length: usize,
    chunked: bool,
    retry_after: Option<u64>,
}

impl Http {
    fn connect(addr: &str) -> Result<Http> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        stream.set_nodelay(true)?;
        Ok(Http {
            stream,
            buf: Vec::with_capacity(4096),
            pos: 0,
            out: String::new(),
            req_id: String::new(),
        })
    }

    fn send(&mut self, method: &str, path: &str, body: &str) -> Result<()> {
        use std::fmt::Write as _;
        self.out.clear();
        let _ = write!(
            self.out,
            "{method} {path} HTTP/1.1\r\nHost: macformer\r\nContent-Length: {}\r\n",
            body.len()
        );
        if !self.req_id.is_empty() {
            let _ = write!(self.out, "x-request-id: {}\r\n", self.req_id);
        }
        if !body.is_empty() {
            self.out.push_str("Content-Type: application/json\r\n");
        }
        self.out.push_str("\r\n");
        self.out.push_str(body);
        self.stream.write_all(self.out.as_bytes())?;
        Ok(())
    }

    fn fill(&mut self) -> Result<()> {
        // compact the consumed prefix before growing
        if self.pos > 0 && self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        }
        let mut chunk = [0u8; 4096];
        let n = self.stream.read(&mut chunk)?;
        if n == 0 {
            bail!("server closed the connection mid-response");
        }
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(())
    }

    /// One `\n`-terminated line (CR stripped), as an owned string.
    fn line(&mut self) -> Result<String> {
        loop {
            if let Some(off) = self.buf[self.pos..].iter().position(|&b| b == b'\n') {
                let line = &self.buf[self.pos..self.pos + off];
                let line = line.strip_suffix(b"\r").unwrap_or(line);
                let s = String::from_utf8(line.to_vec()).context("non-UTF8 response line")?;
                self.pos += off + 1;
                return Ok(s);
            }
            self.fill()?;
        }
    }

    /// Exactly `n` body bytes, owned.
    fn take(&mut self, n: usize) -> Result<Vec<u8>> {
        while self.buf.len() - self.pos < n {
            self.fill()?;
        }
        let bytes = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(bytes)
    }

    fn read_head(&mut self) -> Result<Head> {
        let status_line = self.line()?;
        let status: u16 = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad status line {status_line:?}"))?;
        let mut head = Head { status, content_length: 0, chunked: false, retry_after: None };
        loop {
            let line = self.line()?;
            if line.is_empty() {
                return Ok(head);
            }
            let Some((name, value)) = line.split_once(':') else { continue };
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                head.content_length = value.parse().context("bad Content-Length")?;
            } else if name.eq_ignore_ascii_case("transfer-encoding") {
                head.chunked = value.eq_ignore_ascii_case("chunked");
            } else if name.eq_ignore_ascii_case("retry-after") {
                head.retry_after = value.parse().ok();
            }
        }
    }

    /// The next chunk payload of a chunked response; `None` at the
    /// terminal chunk.
    fn read_chunk(&mut self) -> Result<Option<Vec<u8>>> {
        let size_line = self.line()?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .with_context(|| format!("bad chunk size {size_line:?}"))?;
        if size == 0 {
            let _ = self.line()?; // trailing CRLF
            return Ok(None);
        }
        let payload = self.take(size)?;
        let _ = self.line()?; // chunk-terminating CRLF
        Ok(Some(payload))
    }
}

// ---------------------------------------------------------------------------
// SSE frames (the server writes exactly one frame per chunk)
// ---------------------------------------------------------------------------

enum Frame {
    Token { t: usize, out: Vec<f32> },
    Done,
    Error { code: String, message: String },
}

fn parse_frame(payload: &[u8], dv: usize) -> Result<Frame> {
    let text = std::str::from_utf8(payload).context("non-UTF8 SSE frame")?;
    let mut event = "message";
    let mut data = "";
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("event: ") {
            event = rest.trim();
        } else if let Some(rest) = line.strip_prefix("data: ") {
            data = rest;
        }
    }
    match event {
        "done" => Ok(Frame::Done),
        "error" => {
            let mut scan = Scan::object(data.as_bytes()).map_err(|e| anyhow!("{e}"))?;
            let (mut code, mut message) = (String::new(), String::new());
            while let Some(key) = scan.next_key().map_err(|e| anyhow!("{e}"))? {
                match key {
                    b"error" => code = scan.str_value("error").map_err(|e| anyhow!("{e}"))?.into(),
                    b"message" => {
                        message = scan.str_value("message").map_err(|e| anyhow!("{e}"))?.into()
                    }
                    _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
                }
            }
            Ok(Frame::Error { code, message })
        }
        _ => {
            let mut scan = Scan::object(data.as_bytes()).map_err(|e| anyhow!("{e}"))?;
            let mut t = usize::MAX;
            let mut out = Vec::with_capacity(dv);
            while let Some(key) = scan.next_key().map_err(|e| anyhow!("{e}"))? {
                match key {
                    b"t" => t = scan.usize_value("t").map_err(|e| anyhow!("{e}"))?,
                    b"out" => scan.f32_array_into("out", &mut out).map_err(|e| anyhow!("{e}"))?,
                    _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
                }
            }
            if t == usize::MAX || out.len() != dv {
                bail!("malformed token frame {data:?}");
            }
            Ok(Frame::Token { t, out })
        }
    }
}

// ---------------------------------------------------------------------------
// the per-stream closed-loop client
// ---------------------------------------------------------------------------

/// What one stream's client thread brings home.
struct StreamOutcome {
    /// Decode output rows actually produced (prefix on a casualty).
    outs: Vec<f32>,
    produced: usize,
    /// Last prompt-position output from prefill (empty without prompt).
    prompt_last: Vec<f32>,
    /// The planned fold panic landed (as an in-stream error frame).
    faulted: bool,
    /// Unexpected failures (protocol errors, wrong error codes, ...).
    errors: u64,
    http: RetryCounts,
    /// Client-observed seconds between consecutive token frames.
    latencies: Vec<f64>,
}

/// Where a stream's decode must pause for an out-of-band action.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Action {
    ArmFault,
    Hibernate,
}

/// Decode segment cut points for stream `i` under the fault plan:
/// hibernate after its planned tokens, arm the fold panic right
/// before its planned token (everything after the panic is moot).
fn plan_cuts(cfg: &LoadConfig, i: usize) -> Vec<(usize, Action)> {
    let plan = &cfg.faults;
    let panic_at = (0..cfg.tokens)
        .find(|&t| plan.inject_panic(i as u64, t as u64, cfg.tokens as u64));
    let mut cuts = Vec::new();
    for t in 0..cfg.tokens {
        if plan.force_hibernate(i as u64, t as u64) {
            let cut = t + 1;
            if panic_at.is_none_or(|p| cut < p) && cut < cfg.tokens {
                cuts.push((cut, Action::Hibernate));
            }
        }
    }
    if let Some(p) = panic_at {
        cuts.push((p, Action::ArmFault));
    }
    cuts.sort_by_key(|&(c, _)| c);
    cuts
}

/// Issue `method path` with exponential-backoff retry on retryable
/// admission statuses: `429` (ingress/backpressure) and `503` carrying
/// a `Retry-After` hint (pool-full, draining). A `503` *without* the
/// hint is the server saying the condition is final (engine down) —
/// that one counts as a real 5xx and fails immediately. Exhausting the
/// attempt budget surfaces as a typed [`RetryGaveUp`] error.
fn request_with_retry(
    http: &mut Http,
    method: &str,
    path: &str,
    body: &str,
    counts: &mut RetryCounts,
    salt: u64,
) -> Result<(Head, Vec<u8>)> {
    let started = Instant::now();
    let mut last_status = 0u16;
    let mut tries = 0usize;
    for attempt in 0..MAX_RETRIES {
        http.send(method, path, body)?;
        let head = http.read_head()?;
        if head.chunked {
            // callers that expect a stream never come through here
            bail!("unexpected chunked response for {method} {path}");
        }
        let resp_body = http.take(head.content_length)?;
        match (head.status, head.retry_after) {
            (429, _) => counts.http_429 += 1,
            (503, Some(_)) => counts.http_503 += 1,
            (503, None) => {
                counts.http_5xx += 1;
                bail!("{method} {path}: non-retryable 503 (engine down)");
            }
            _ => return Ok((head, resp_body)),
        }
        last_status = head.status;
        tries = attempt + 1;
        if !retry_sleep(started, attempt, head.retry_after, salt) {
            break; // wall-clock retry budget spent
        }
    }
    Err(anyhow::Error::new(RetryGaveUp {
        method: method.into(),
        path: path.into(),
        attempts: tries,
        last_status,
        elapsed_ms: started.elapsed().as_millis() as u64,
    }))
}

fn body_for(tokens: &[f32], d: usize, dv: usize, range: std::ops::Range<usize>) -> String {
    let stride = 2 * d + dv;
    let mut q = Vec::with_capacity(range.len() * d);
    let mut k = Vec::with_capacity(range.len() * d);
    let mut v = Vec::with_capacity(range.len() * dv);
    for t in range {
        let row = &tokens[t * stride..(t + 1) * stride];
        q.extend_from_slice(&row[..d]);
        k.extend_from_slice(&row[d..2 * d]);
        v.extend_from_slice(&row[2 * d..]);
    }
    let mut body = String::with_capacity((q.len() + k.len() + v.len()) * 12);
    body.push_str("{\"q\":");
    super::wire::write_f32_array(&mut body, &q);
    body.push_str(",\"k\":");
    super::wire::write_f32_array(&mut body, &k);
    body.push_str(",\"v\":");
    super::wire::write_f32_array(&mut body, &v);
    body.push('}');
    body
}

/// Drive one stream end to end over its own connection.
fn drive_stream(
    addr: &str,
    cfg: &LoadConfig,
    i: usize,
    tokens: &[f32],
    prompt: &(Vec<f32>, Vec<f32>, Vec<f32>),
) -> Result<StreamOutcome> {
    let (d, dv) = (cfg.head_dim, cfg.dv);
    let mut outcome = StreamOutcome {
        outs: vec![0.0; cfg.tokens * dv],
        produced: 0,
        prompt_last: Vec::new(),
        faulted: false,
        errors: 0,
        http: RetryCounts::default(),
        latencies: Vec::new(),
    };
    let salt = i as u64;
    let mut http = Http::connect(addr)?;
    http.req_id = format!("s{i}");

    // open
    let (head, resp) =
        request_with_retry(&mut http, "POST", "/v1/streams", "{}", &mut outcome.http, salt)?;
    if head.status != 201 {
        bail!("open: expected 201, got {}", head.status);
    }
    let mut scan = Scan::object(&resp).map_err(|e| anyhow!("open body: {e}"))?;
    let mut sid = String::new();
    while let Some(key) = scan.next_key().map_err(|e| anyhow!("open body: {e}"))? {
        match key {
            b"stream" => sid = scan.str_value("stream").map_err(|e| anyhow!("{e}"))?.into(),
            _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
        }
    }
    if sid.is_empty() {
        bail!("open: no stream id in response");
    }

    // prefill
    if cfg.prompt > 0 {
        let (pq, pk, pv) = prompt;
        let mut body = String::new();
        body.push_str("{\"q\":");
        super::wire::write_f32_array(&mut body, pq);
        body.push_str(",\"k\":");
        super::wire::write_f32_array(&mut body, pk);
        body.push_str(",\"v\":");
        super::wire::write_f32_array(&mut body, pv);
        body.push('}');
        let path = format!("/v1/streams/{sid}/prefill");
        let (head, resp) =
            request_with_retry(&mut http, "POST", &path, &body, &mut outcome.http, salt)?;
        if head.status != 200 {
            bail!("prefill: expected 200, got {}", head.status);
        }
        let mut scan = Scan::object(&resp).map_err(|e| anyhow!("prefill body: {e}"))?;
        while let Some(key) = scan.next_key().map_err(|e| anyhow!("prefill body: {e}"))? {
            match key {
                b"out" => scan
                    .f32_array_into("out", &mut outcome.prompt_last)
                    .map_err(|e| anyhow!("{e}"))?,
                _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
            }
        }
        if outcome.prompt_last.len() != dv {
            bail!("prefill: expected {dv} output values, got {}", outcome.prompt_last.len());
        }
    }

    // decode, split into segments around planned chaos actions
    let cuts = plan_cuts(cfg, i);
    let mut expect_fault = false;
    let decode_path = format!("/v1/streams/{sid}/decode");
    let mut segments: Vec<(std::ops::Range<usize>, Option<Action>)> = Vec::new();
    let mut prev = 0usize;
    for &(cut, action) in &cuts {
        segments.push((prev..cut, Some(action)));
        prev = cut;
    }
    segments.push((prev..cfg.tokens, None));

    'segments: for (range, action) in segments {
        if !range.is_empty() {
            let body = body_for(tokens, d, dv, range.clone());
            // admission retry loop: a 429/503 answer means nothing
            // streamed yet, so the whole segment can be re-sent
            let started = Instant::now();
            let mut streamed = false;
            let mut last_status = 0u16;
            let mut tries = 0usize;
            for attempt in 0..MAX_RETRIES {
                http.send("POST", &decode_path, &body)?;
                let head = http.read_head()?;
                if !head.chunked {
                    let _resp = http.take(head.content_length)?;
                    match (head.status, head.retry_after) {
                        (429, _) => outcome.http.http_429 += 1,
                        (503, Some(_)) => outcome.http.http_503 += 1,
                        (503, None) => {
                            outcome.http.http_5xx += 1;
                            bail!("decode: non-retryable 503 (engine down)");
                        }
                        (s, _) => bail!("decode: unexpected status {s}"),
                    }
                    last_status = head.status;
                    tries = attempt + 1;
                    if !retry_sleep(started, attempt, head.retry_after, salt) {
                        break; // wall-clock retry budget spent
                    }
                    continue;
                }
                // committed stream: read frames until done/error
                let mut last = Instant::now();
                while let Some(payload) = http.read_chunk()? {
                    match parse_frame(&payload, dv)? {
                        Frame::Token { t, out } => {
                            let now = Instant::now();
                            outcome.latencies.push((now - last).as_secs_f64());
                            last = now;
                            let abs = range.start + t;
                            if abs >= cfg.tokens {
                                bail!("decode: token index {t} out of segment range");
                            }
                            outcome.outs[abs * dv..(abs + 1) * dv].copy_from_slice(&out);
                            outcome.produced = abs + 1;
                        }
                        Frame::Done => {}
                        Frame::Error { code, message } => {
                            if expect_fault && code == "faulted" {
                                outcome.faulted = true;
                            } else {
                                log::warn!(
                                    "socket loadgen: stream {i} unexpected error frame \
                                     {code}: {message}"
                                );
                                outcome.errors += 1;
                            }
                        }
                    }
                }
                streamed = true;
                break;
            }
            if !streamed {
                return Err(anyhow::Error::new(RetryGaveUp {
                    method: "POST".into(),
                    path: decode_path.clone(),
                    attempts: tries,
                    last_status,
                    elapsed_ms: started.elapsed().as_millis() as u64,
                }));
            }
            if outcome.faulted || outcome.errors > 0 {
                break 'segments;
            }
        }
        match action {
            None => {}
            Some(Action::Hibernate) => {
                let path = format!("/v1/streams/{sid}/hibernate");
                let (head, _) =
                    request_with_retry(&mut http, "POST", &path, "{}", &mut outcome.http, salt)?;
                if head.status != 200 {
                    log::warn!("socket loadgen: stream {i} hibernate got {}", head.status);
                    outcome.errors += 1;
                }
            }
            Some(Action::ArmFault) => {
                let path = format!("/v1/streams/{sid}/arm_fault");
                let (head, _) =
                    request_with_retry(&mut http, "POST", &path, "{}", &mut outcome.http, salt)?;
                if head.status != 200 {
                    log::warn!("socket loadgen: stream {i} arm_fault got {}", head.status);
                    outcome.errors += 1;
                }
                expect_fault = true;
            }
        }
    }

    // close works in any state, faulted included
    let path = format!("/v1/streams/{sid}");
    let (head, _) = request_with_retry(&mut http, "DELETE", &path, "", &mut outcome.http, salt)?;
    if head.status != 200 {
        log::warn!("socket loadgen: stream {i} close got {}", head.status);
        outcome.errors += 1;
    }
    Ok(outcome)
}

// ---------------------------------------------------------------------------
// the report
// ---------------------------------------------------------------------------

/// Outcome of one [`run_socket`] drive: like
/// [`LoadReport`](crate::serve::loadgen::LoadReport) but measured from
/// the client side of real TCP connections.
#[derive(Debug, Clone)]
pub struct NetLoadReport {
    pub streams: usize,
    pub tokens_per_stream: usize,
    pub prompt_tokens: usize,
    pub elapsed_s: f64,
    pub tokens_total: u64,
    pub tokens_per_sec: f64,
    /// Client-observed per-token latency percentiles (seconds).
    pub latency_p50: f64,
    pub latency_p99: f64,
    pub latency_max: f64,
    /// Backpressure/ingress rejects answered `429` (then retried).
    pub http_429: u64,
    /// Retryable `503`s (pool-full, draining — `Retry-After` present),
    /// absorbed by backoff. Not failures, so not counted in
    /// [`http_5xx`](NetLoadReport::http_5xx).
    pub http_503_retried: u64,
    /// Non-retryable `5xx` answers observed (zero on a clean run; the
    /// CI socket smoke greps this).
    pub http_5xx: u64,
    /// Unexpected failures across all streams (zero on any run whose
    /// chaos stayed contained).
    pub stream_errors: u64,
    /// Planned fold-panic casualties, surfaced as in-stream error
    /// frames.
    pub faulted_streams: u64,
    /// Streams whose wire outputs diverged from the single-stream
    /// replay.
    pub poisoned_streams: u64,
    pub verified: Option<bool>,
    pub max_abs_diff: f64,
    pub prefill_max_scaled_diff: f64,
}

impl NetLoadReport {
    pub fn render(&self) -> String {
        let verified = match self.verified {
            Some(true) => "bit-identical to in-process decode".to_string(),
            Some(false) => format!("MISMATCH (max |diff| {})", self.max_abs_diff),
            None => "skipped".to_string(),
        };
        format!(
            "serve/net: {} streams x {} tokens (+{} prompt) over TCP\n\
             {:>10.0} tokens/sec  ({} tokens in {:.3}s)\n\
             latency   p50 {:.6}s  p99 {:.6}s  max {:.6}s  (client-observed)\n\
             http      {} x 429 (retried), {} x 503 (retried), {} x 5xx, {} stream errors\n\
             resil     {} faulted (planned), {} poisoned\n\
             verify    {}",
            self.streams,
            self.tokens_per_stream,
            self.prompt_tokens,
            self.tokens_per_sec,
            self.tokens_total,
            self.elapsed_s,
            self.latency_p50,
            self.latency_p99,
            self.latency_max,
            self.http_429,
            self.http_503_retried,
            self.http_5xx,
            self.stream_errors,
            self.faulted_streams,
            self.poisoned_streams,
            verified,
        )
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("streams", Value::num(self.streams as f64)),
            ("tokens_per_stream", Value::num(self.tokens_per_stream as f64)),
            ("prompt_tokens", Value::num(self.prompt_tokens as f64)),
            ("elapsed_s", Value::num(self.elapsed_s)),
            ("tokens_total", Value::num(self.tokens_total as f64)),
            ("tokens_per_sec", Value::num(self.tokens_per_sec)),
            ("latency_p50_s", Value::num(self.latency_p50)),
            ("latency_p99_s", Value::num(self.latency_p99)),
            ("latency_max_s", Value::num(self.latency_max)),
            ("http_429", Value::num(self.http_429 as f64)),
            ("http_503_retried", Value::num(self.http_503_retried as f64)),
            ("http_5xx", Value::num(self.http_5xx as f64)),
            ("stream_errors", Value::num(self.stream_errors as f64)),
            ("faulted_streams", Value::num(self.faulted_streams as f64)),
            ("poisoned_streams", Value::num(self.poisoned_streams as f64)),
            (
                "verified",
                match self.verified {
                    Some(b) => Value::Bool(b),
                    None => Value::Null,
                },
            ),
            ("max_abs_diff", Value::num(self.max_abs_diff)),
            ("prefill_max_scaled_diff", Value::num(self.prefill_max_scaled_diff)),
        ])
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Drive a running server at `addr` with `cfg.streams` concurrent TCP
/// clients and verify survivors bit-identical to in-process decode.
///
/// The server must have been started with the same attention spec and
/// seed (`GET /v1/spec` is checked first, so a mismatch is a clear
/// error instead of a verification mystery).
pub fn run_socket(cfg: &LoadConfig, addr: &str) -> Result<NetLoadReport> {
    if cfg.streams == 0 || cfg.tokens == 0 {
        bail!("socket loadgen: streams and tokens must be > 0");
    }
    check_spec(cfg, addr)?;
    let tokens = generate_tokens(cfg);
    let prompts = generate_prompts(cfg);

    let t0 = Instant::now();
    let outcomes: Vec<Result<StreamOutcome>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.streams)
            .map(|i| {
                let tokens = &tokens[i];
                let prompt = &prompts[i];
                scope.spawn(move || drive_stream(addr, cfg, i, tokens, prompt))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("client thread panicked"))))
            .collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();

    let mut stream_errors = 0u64;
    let mut http_429 = 0u64;
    let mut http_503 = 0u64;
    let mut http_5xx = 0u64;
    let mut faulted_streams = 0u64;
    let mut failed = vec![false; cfg.streams];
    let mut produced = vec![0usize; cfg.streams];
    let mut outs: Vec<Vec<f32>> = vec![Vec::new(); cfg.streams];
    let mut prompt_last: Vec<Vec<f32>> = vec![Vec::new(); cfg.streams];
    let mut latencies: Vec<f64> = Vec::new();
    for (i, res) in outcomes.into_iter().enumerate() {
        match res {
            Ok(o) => {
                stream_errors += o.errors;
                http_429 += o.http.http_429;
                http_503 += o.http.http_503;
                http_5xx += o.http.http_5xx;
                if o.faulted {
                    faulted_streams += 1;
                }
                failed[i] = o.errors > 0;
                produced[i] = o.produced;
                outs[i] = o.outs;
                prompt_last[i] = o.prompt_last;
                latencies.extend(o.latencies);
            }
            Err(e) => {
                log::warn!("socket loadgen: stream {i} client failed: {e}");
                stream_errors += 1;
                failed[i] = true;
            }
        }
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));

    // replay every survivor through the single-stream path
    let (d, dv, stride) = (cfg.head_dim, cfg.dv, token_stride(cfg));
    let (verified, max_abs_diff, prefill_max_scaled_diff, poisoned_streams) = if cfg.verify {
        let session = AttentionSpec::new(cfg.kernel)
            .head_dim(d)
            .num_features(cfg.num_features)
            .causal(true)
            .seed(cfg.seed)
            .backend(cfg.backend)
            .build()
            .context("socket loadgen: building the verification session")?;
        let mut ok = stream_errors == 0;
        let mut max_diff = 0.0f64;
        let mut prefill_diff = 0.0f64;
        let mut poisoned = 0u64;
        let mut row = vec![0.0f32; dv];
        for i in 0..cfg.streams {
            if failed[i] {
                ok = false;
                continue;
            }
            let mut stream_poisoned = false;
            let mut state = session.begin_decode(dv)?;
            let (pq, pk, pv) = &prompts[i];
            for t in 0..cfg.prompt {
                state.append_token_into(
                    &pq[t * d..(t + 1) * d],
                    &pk[t * d..(t + 1) * d],
                    &pv[t * dv..(t + 1) * dv],
                    &mut row,
                )?;
            }
            if cfg.prompt > 0 {
                for (a, b) in prompt_last[i].iter().zip(&row) {
                    let diff = ((a - b).abs() / b.abs().max(1.0)) as f64;
                    prefill_diff = prefill_diff.max(diff);
                    if !diff.is_finite() || diff > 1e-5 {
                        ok = false;
                        stream_poisoned = true;
                    }
                }
            }
            for t in 0..produced[i] {
                let tok = &tokens[i][t * stride..(t + 1) * stride];
                state.append_token_into(&tok[..d], &tok[d..2 * d], &tok[2 * d..], &mut row)?;
                for (a, b) in outs[i][t * dv..(t + 1) * dv].iter().zip(&row) {
                    if a.to_bits() != b.to_bits() {
                        ok = false;
                        stream_poisoned = true;
                        max_diff = max_diff.max((a - b).abs() as f64);
                    }
                }
            }
            if stream_poisoned {
                poisoned += 1;
            }
        }
        (Some(ok), max_diff, prefill_diff, poisoned)
    } else {
        (None, 0.0, 0.0, failed.iter().filter(|&&f| f).count() as u64)
    };

    let tokens_total: u64 = produced.iter().map(|&p| p as u64).sum();
    Ok(NetLoadReport {
        streams: cfg.streams,
        tokens_per_stream: cfg.tokens,
        prompt_tokens: cfg.prompt,
        elapsed_s: elapsed,
        tokens_total,
        tokens_per_sec: if elapsed > 0.0 { tokens_total as f64 / elapsed } else { 0.0 },
        latency_p50: percentile(&latencies, 50.0),
        latency_p99: percentile(&latencies, 99.0),
        latency_max: latencies.last().copied().unwrap_or(0.0),
        http_429,
        http_503_retried: http_503,
        http_5xx,
        stream_errors,
        faulted_streams,
        poisoned_streams,
        verified,
        max_abs_diff,
        prefill_max_scaled_diff,
    })
}

/// Assert the server's `/v1/spec` matches the generator config, so
/// bit-exact verification is comparing like with like.
pub(crate) fn check_spec(cfg: &LoadConfig, addr: &str) -> Result<()> {
    let mut http = Http::connect(addr)?;
    http.send("GET", "/v1/spec", "")?;
    let head = http.read_head()?;
    if head.status != 200 {
        bail!("GET /v1/spec: status {}", head.status);
    }
    let body = http.take(head.content_length)?;
    let mut scan = Scan::object(&body).map_err(|e| anyhow!("spec body: {e}"))?;
    let mut fields: Vec<(String, String)> = Vec::new();
    while let Some(key) = scan.next_key().map_err(|e| anyhow!("spec body: {e}"))? {
        let name = String::from_utf8_lossy(key).into_owned();
        match key {
            b"kernel" | b"backend" => {
                let v = scan.str_value("spec").map_err(|e| anyhow!("{e}"))?;
                fields.push((name, v.to_string()));
            }
            b"head_dim" | b"dv" | b"num_features" | b"seed" => {
                let v = scan.usize_value("spec").map_err(|e| anyhow!("{e}"))?;
                fields.push((name, v.to_string()));
            }
            _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
        }
    }
    let expect = [
        ("kernel", cfg.kernel.name().to_string()),
        ("head_dim", cfg.head_dim.to_string()),
        ("dv", cfg.dv.to_string()),
        ("num_features", cfg.num_features.to_string()),
        ("seed", cfg.seed.to_string()),
    ];
    for (name, want) in expect {
        let got = fields.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str());
        if got != Some(want.as_str()) {
            bail!("spec mismatch: server {name}={got:?}, loadgen expects {want:?}");
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// kill-restart chaos: SIGKILL the serve process mid-load, restart it on
// the same data-dir, resume every stream, verify bit-identity
// ---------------------------------------------------------------------------

/// Outcome of one [`run_kill_restart`] drive — the crash-restart
/// counterpart of [`NetLoadReport`]. The CI kill-restart smoke greps
/// `verified` and `http_5xx` out of the JSON form.
#[derive(Debug, Clone)]
pub struct KillRestartReport {
    pub streams: usize,
    pub tokens_per_stream: usize,
    /// Seeded produced-token threshold at which the serve process took
    /// its SIGKILL.
    pub kill_at_tokens: u64,
    /// Tokens actually streamed back when the kill landed (can exceed
    /// the threshold by whatever was in flight).
    pub killed_at_tokens: u64,
    /// Streams whose open was acked before the kill (everything else
    /// is a true casualty with nothing durable to recover).
    pub admitted: usize,
    /// Admitted streams the restarted server recovered (resume probe
    /// answered 200).
    pub recovered: usize,
    /// Recovered streams that resumed decode to completion.
    pub resumed: usize,
    /// Journal-synced tokens the restarted server reported across all
    /// recovered streams (trails `killed_at_tokens` by at most the
    /// group-commit window).
    pub recovered_tokens: u64,
    pub http_429: u64,
    pub http_503_retried: u64,
    pub http_5xx: u64,
    pub stream_errors: u64,
    /// Every admitted stream recovered and resumed, and every wire
    /// output row — before the kill and after the restart — matched
    /// the single-stream replay bit for bit.
    pub verified: bool,
    pub elapsed_s: f64,
}

impl KillRestartReport {
    pub fn render(&self) -> String {
        format!(
            "serve/net kill-restart: {} streams x {} tokens, SIGKILL at {} produced tokens\n\
             phase 1   {} tokens streamed before the kill, {} / {} streams admitted\n\
             recover   {} / {} streams recovered ({} journal-synced tokens), {} resumed\n\
             http      {} x 429 (retried), {} x 503 (retried), {} x 5xx, {} stream errors\n\
             verify    {}",
            self.streams,
            self.tokens_per_stream,
            self.kill_at_tokens,
            self.killed_at_tokens,
            self.admitted,
            self.streams,
            self.recovered,
            self.admitted,
            self.recovered_tokens,
            self.resumed,
            self.http_429,
            self.http_503_retried,
            self.http_5xx,
            self.stream_errors,
            if self.verified {
                "bit-identical to a process that never died"
            } else {
                "FAILED (see warnings above)"
            },
        )
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("streams", Value::num(self.streams as f64)),
            ("tokens_per_stream", Value::num(self.tokens_per_stream as f64)),
            ("kill_at_tokens", Value::num(self.kill_at_tokens as f64)),
            ("killed_at_tokens", Value::num(self.killed_at_tokens as f64)),
            ("admitted", Value::num(self.admitted as f64)),
            ("recovered", Value::num(self.recovered as f64)),
            ("resumed", Value::num(self.resumed as f64)),
            ("recovered_tokens", Value::num(self.recovered_tokens as f64)),
            ("http_429", Value::num(self.http_429 as f64)),
            ("http_503_retried", Value::num(self.http_503_retried as f64)),
            ("http_5xx", Value::num(self.http_5xx as f64)),
            ("stream_errors", Value::num(self.stream_errors as f64)),
            ("verified", Value::Bool(self.verified)),
            ("elapsed_s", Value::num(self.elapsed_s)),
        ])
    }
}

/// The seeded kill point: a splitmix64 of the load seed mapped into
/// the middle half of the run, `[total/4, 3*total/4)` produced tokens
/// — late enough that streams have durable state, early enough that
/// every stream still has tokens left to resume.
pub(crate) fn kill_point(cfg: &LoadConfig) -> u64 {
    let total = (cfg.streams * cfg.tokens) as u64;
    let mut x = cfg.seed.wrapping_add(0x2545_F491_4F6C_DD1D);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    total / 4 + x % (total / 2).max(1)
}

/// Spawn `macformer serve --listen` as a child process on `data_dir`
/// and wait until `/healthz` answers ready. Stdout is discarded (the
/// parent prints its own report); stderr is inherited so a child-side
/// failure surfaces in CI logs.
fn spawn_serve(cfg: &LoadConfig, data_dir: &Path) -> Result<(Child, String)> {
    let exe = std::env::current_exe().context("resolving the serve binary")?;
    let port_file = data_dir.join("port.txt");
    let _ = std::fs::remove_file(&port_file);
    let mut child = Command::new(&exe)
        .arg("serve")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .arg("--port-file")
        .arg(&port_file)
        .arg("--data-dir")
        .arg(data_dir)
        .arg("--kernel")
        .arg(cfg.kernel.name())
        .arg("--backend")
        .arg(cfg.backend.to_string())
        .arg("--head-dim")
        .arg(cfg.head_dim.to_string())
        .arg("--dv")
        .arg(cfg.dv.to_string())
        .arg("--features")
        .arg(cfg.num_features.to_string())
        .arg("--seed")
        .arg(cfg.seed.to_string())
        .arg("--streams")
        .arg(cfg.streams.to_string())
        .arg("--min-batch")
        .arg(cfg.min_batch.to_string())
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .spawn()
        .with_context(|| format!("spawning {} serve", exe.display()))?;
    let deadline = Instant::now() + Duration::from_secs(60);
    let addr = loop {
        if let Some(status) = child.try_wait()? {
            bail!("serve child exited during startup: {status}");
        }
        if Instant::now() > deadline {
            let _ = child.kill();
            let _ = child.wait();
            bail!("serve child wrote no port file within 60s");
        }
        match std::fs::read_to_string(&port_file) {
            Ok(s) if !s.trim().is_empty() => break format!("127.0.0.1:{}", s.trim()),
            _ => std::thread::sleep(Duration::from_millis(5)),
        }
    };
    // the port file is written only once the gateway is ready, but a
    // healthz poll keeps this robust if that contract ever loosens
    loop {
        if Instant::now() > deadline {
            let _ = child.kill();
            let _ = child.wait();
            bail!("serve child on {addr} never answered /healthz ready");
        }
        if let Ok(mut http) = Http::connect(&addr) {
            if http.send("GET", "/healthz", "").is_ok() {
                if let Ok(head) = http.read_head() {
                    let _ = http.take(head.content_length);
                    if head.status == 200 {
                        break;
                    }
                }
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    Ok((child, addr))
}

/// What one stream's client holds when the kill lands.
pub(crate) struct KillPhase {
    /// Empty when the open was never acked (a true casualty).
    pub(crate) sid: String,
    pub(crate) outs: Vec<f32>,
    pub(crate) produced: usize,
    pub(crate) http: RetryCounts,
    /// A failure observed while the server was still alive — anything
    /// after the kill flag flips is an expected casualty, not an error.
    pub(crate) error: Option<String>,
}

/// What one stream's client brings home from the restarted server.
pub(crate) struct ResumePhase {
    /// Token count the resume probe reported (`None` = not probed:
    /// either a casualty skip or a probe failure, see `error`).
    pub(crate) probed: Option<u64>,
    pub(crate) outs: Vec<f32>,
    pub(crate) resumed_from: usize,
    pub(crate) produced: usize,
    pub(crate) http: RetryCounts,
    pub(crate) error: Option<String>,
}

fn sid_from_open(resp: &[u8]) -> Result<String> {
    let mut scan = Scan::object(resp).map_err(|e| anyhow!("open body: {e}"))?;
    let mut sid = String::new();
    while let Some(key) = scan.next_key().map_err(|e| anyhow!("open body: {e}"))? {
        match key {
            b"stream" => sid = scan.str_value("stream").map_err(|e| anyhow!("{e}"))?.into(),
            _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
        }
    }
    if sid.is_empty() {
        bail!("open: no stream id in response");
    }
    Ok(sid)
}

/// Stream `tokens[start..]` through one decode request, storing rows
/// at their absolute positions and bumping the shared produced-token
/// counter the killer thread watches.
#[allow(clippy::too_many_arguments)]
fn decode_into(
    http: &mut Http,
    cfg: &LoadConfig,
    sid: &str,
    tokens: &[f32],
    start: usize,
    outs: &mut [f32],
    produced: &mut usize,
    counter: &AtomicU64,
    counts: &mut RetryCounts,
    salt: u64,
) -> Result<()> {
    let (d, dv) = (cfg.head_dim, cfg.dv);
    if start >= cfg.tokens {
        return Ok(());
    }
    let path = format!("/v1/streams/{sid}/decode");
    let body = body_for(tokens, d, dv, start..cfg.tokens);
    let started = Instant::now();
    let mut last_status = 0u16;
    let mut tries = 0usize;
    for attempt in 0..MAX_RETRIES {
        http.send("POST", &path, &body)?;
        let head = http.read_head()?;
        if head.chunked {
            while let Some(payload) = http.read_chunk()? {
                match parse_frame(&payload, dv)? {
                    Frame::Token { t, out } => {
                        let abs = start + t;
                        if abs >= cfg.tokens {
                            bail!("decode: token index {t} out of range");
                        }
                        outs[abs * dv..(abs + 1) * dv].copy_from_slice(&out);
                        *produced = abs + 1;
                        counter.fetch_add(1, Ordering::SeqCst);
                    }
                    Frame::Done => {}
                    Frame::Error { code, message } => {
                        bail!("decode: unexpected error frame {code}: {message}")
                    }
                }
            }
            return Ok(());
        }
        let _ = http.take(head.content_length)?;
        match (head.status, head.retry_after) {
            (429, _) => counts.http_429 += 1,
            (503, Some(_)) => counts.http_503 += 1,
            (503, None) => {
                counts.http_5xx += 1;
                bail!("decode: non-retryable 503 (engine down)");
            }
            (s, _) => bail!("decode: unexpected status {s}"),
        }
        last_status = head.status;
        tries = attempt + 1;
        if !retry_sleep(started, attempt, head.retry_after, salt) {
            break; // wall-clock retry budget spent
        }
    }
    Err(anyhow::Error::new(RetryGaveUp {
        method: "POST".into(),
        path,
        attempts: tries,
        last_status,
        elapsed_ms: started.elapsed().as_millis() as u64,
    }))
}

pub(crate) fn drive_to_kill(
    addr: &str,
    cfg: &LoadConfig,
    i: usize,
    tokens: &[f32],
    counter: &AtomicU64,
    killed: &AtomicBool,
    done: &AtomicUsize,
) -> KillPhase {
    let mut out = KillPhase {
        sid: String::new(),
        outs: vec![0.0; cfg.tokens * cfg.dv],
        produced: 0,
        http: RetryCounts::default(),
        error: None,
    };
    let result = (|| -> Result<()> {
        let mut http = Http::connect(addr)?;
        http.req_id = format!("s{i}");
        let (head, resp) =
            request_with_retry(&mut http, "POST", "/v1/streams", "{}", &mut out.http, i as u64)?;
        if head.status != 201 {
            bail!("open: expected 201, got {}", head.status);
        }
        out.sid = sid_from_open(&resp)?;
        // no close afterwards: streams stay open so phase 2 can probe
        // and resume every one of them
        decode_into(
            &mut http,
            cfg,
            &out.sid,
            tokens,
            0,
            &mut out.outs,
            &mut out.produced,
            counter,
            &mut out.http,
            i as u64,
        )
    })();
    if let Err(e) = result {
        if killed.load(Ordering::SeqCst) {
            // cut off by the SIGKILL: the received prefix is the point
            log::debug!("kill-restart: stream {i} cut off by the kill: {e:#}");
        } else {
            out.error = Some(format!("{e:#}"));
        }
    }
    done.fetch_add(1, Ordering::SeqCst);
    out
}

pub(crate) fn resume_stream(
    addr: &str,
    cfg: &LoadConfig,
    i: usize,
    sid: &str,
    tokens: &[f32],
) -> ResumePhase {
    let mut out = ResumePhase {
        probed: None,
        outs: vec![0.0; cfg.tokens * cfg.dv],
        resumed_from: 0,
        produced: 0,
        http: RetryCounts::default(),
        error: None,
    };
    let counter = AtomicU64::new(0); // nobody watches phase-2 progress
    let result = (|| -> Result<()> {
        let mut http = Http::connect(addr)?;
        http.req_id = format!("r{i}");
        let path = format!("/v1/streams/{sid}");
        let (head, resp) =
            request_with_retry(&mut http, "GET", &path, "", &mut out.http, i as u64)?;
        if head.status != 200 {
            bail!("resume probe: expected 200 for {sid}, got {}", head.status);
        }
        let mut scan = Scan::object(&resp).map_err(|e| anyhow!("probe body: {e}"))?;
        let mut status = String::new();
        let mut recovered = u64::MAX;
        while let Some(key) = scan.next_key().map_err(|e| anyhow!("probe body: {e}"))? {
            match key {
                b"status" => status = scan.str_value("status").map_err(|e| anyhow!("{e}"))?.into(),
                b"tokens" => {
                    recovered = scan.usize_value("tokens").map_err(|e| anyhow!("{e}"))? as u64
                }
                _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
            }
        }
        if recovered == u64::MAX {
            bail!("resume probe: no token count for {sid}");
        }
        if status != "active" && status != "hibernated" {
            bail!("resume probe: {sid} recovered as {status:?}");
        }
        if recovered > cfg.tokens as u64 {
            bail!("resume probe: {sid} reports {recovered} tokens, expected <= {}", cfg.tokens);
        }
        out.probed = Some(recovered);
        out.resumed_from = recovered as usize;
        out.produced = out.resumed_from;
        decode_into(
            &mut http,
            cfg,
            sid,
            tokens,
            out.resumed_from,
            &mut out.outs,
            &mut out.produced,
            &counter,
            &mut out.http,
            i as u64,
        )?;
        if out.produced != cfg.tokens {
            bail!("resume: {sid} stopped at {} of {} tokens", out.produced, cfg.tokens);
        }
        let (head, _) =
            request_with_retry(&mut http, "DELETE", &path, "", &mut out.http, i as u64)?;
        if head.status != 200 {
            bail!("close: expected 200 for {sid}, got {}", head.status);
        }
        Ok(())
    })();
    if let Err(e) = result {
        out.error = Some(format!("{e:#}"));
    }
    out
}

/// Kill-restart chaos: spawn the serve gateway as a child process on
/// `data_dir`, drive `cfg.streams` concurrent clients, SIGKILL the
/// child at a seeded produced-token threshold, restart it on the same
/// data-dir, resume every admitted stream from the journal-recovered
/// length, and verify every output row — from before the kill and
/// after the restart — bit-identical to the single-stream replay.
///
/// Existing durable state under `data_dir` (checkpoint + journals) is
/// cleared first so "recovered" can only mean "recovered from *this*
/// run's crash".
pub fn run_kill_restart(cfg: &LoadConfig, data_dir: &Path) -> Result<KillRestartReport> {
    if cfg.streams == 0 || cfg.tokens < 2 {
        bail!("kill-restart: needs streams > 0 and at least 2 tokens per stream");
    }
    if cfg.prompt != 0 {
        bail!("kill-restart: --prompt is not supported here (decode-only recovery drill)");
    }
    if cfg.faults.is_active() {
        bail!("kill-restart: runs its own chaos; drop the --fault-* flags");
    }
    std::fs::create_dir_all(data_dir)
        .with_context(|| format!("creating data dir {}", data_dir.display()))?;
    for entry in std::fs::read_dir(data_dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name == "checkpoint.macc"
            || name == "checkpoint.tmp"
            || name == "port.txt"
            || (name.starts_with("journal.") && name.ends_with(".macj"))
        {
            std::fs::remove_file(entry.path()).with_context(|| format!("clearing stale {name}"))?;
        }
    }

    let tokens = generate_tokens(cfg);
    let kill_at = kill_point(cfg);
    let t0 = Instant::now();

    // phase 1: serve, load, SIGKILL at the seeded threshold
    log::info!(
        "kill-restart: phase 1 — serving on {}, SIGKILL at {kill_at} produced tokens",
        data_dir.display()
    );
    let (mut child, addr) = spawn_serve(cfg, data_dir)?;
    check_spec(cfg, &addr)?;
    let counter = AtomicU64::new(0);
    let killed = AtomicBool::new(false);
    let done = AtomicUsize::new(0);
    let phase1: Vec<KillPhase> = std::thread::scope(|scope| {
        let addr = addr.as_str();
        let handles: Vec<_> = (0..cfg.streams)
            .map(|i| {
                let tokens = &tokens[i];
                let (counter, killed, done) = (&counter, &killed, &done);
                scope.spawn(move || drive_to_kill(addr, cfg, i, tokens, counter, killed, done))
            })
            .collect();
        // the killer: flag first, then SIGKILL, so clients can tell an
        // expected cut-off from a real failure
        loop {
            if counter.load(Ordering::SeqCst) >= kill_at {
                killed.store(true, Ordering::SeqCst);
                let _ = child.kill();
                break;
            }
            if done.load(Ordering::SeqCst) == cfg.streams {
                break; // every client ended early — no kill happened
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| KillPhase {
                    sid: String::new(),
                    outs: Vec::new(),
                    produced: 0,
                    http: RetryCounts::default(),
                    error: Some("client thread panicked".into()),
                })
            })
            .collect()
    });
    let _ = child.wait();
    if !killed.load(Ordering::SeqCst) {
        let first = phase1.iter().find_map(|p| p.error.clone()).unwrap_or_default();
        bail!(
            "kill-restart: clients finished before the {kill_at}-token kill threshold \
             ({} produced); first error: {first:?}",
            counter.load(Ordering::SeqCst)
        );
    }
    let killed_at = counter.load(Ordering::SeqCst);

    // phase 2: restart on the same data-dir, probe + resume + close
    log::info!("kill-restart: phase 2 — restarting on the same data-dir");
    let (mut child2, addr2) = spawn_serve(cfg, data_dir)?;
    let phase2: Vec<ResumePhase> = std::thread::scope(|scope| {
        let addr2 = addr2.as_str();
        let handles: Vec<_> = (0..cfg.streams)
            .map(|i| {
                let tokens = &tokens[i];
                let sid = phase1[i].sid.as_str();
                scope.spawn(move || {
                    if sid.is_empty() {
                        // open never acked: nothing durable to recover
                        return ResumePhase {
                            probed: None,
                            outs: Vec::new(),
                            resumed_from: 0,
                            produced: 0,
                            http: RetryCounts::default(),
                            error: None,
                        };
                    }
                    resume_stream(addr2, cfg, i, sid, tokens)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| ResumePhase {
                    probed: None,
                    outs: Vec::new(),
                    resumed_from: 0,
                    produced: 0,
                    http: RetryCounts::default(),
                    error: Some("client thread panicked".into()),
                })
            })
            .collect()
    });
    let _ = child2.kill();
    let _ = child2.wait();

    // verify: one deterministic replay covers both phases
    let (d, dv, stride) = (cfg.head_dim, cfg.dv, token_stride(cfg));
    let session = AttentionSpec::new(cfg.kernel)
        .head_dim(d)
        .num_features(cfg.num_features)
        .causal(true)
        .seed(cfg.seed)
        .backend(cfg.backend)
        .build()
        .context("kill-restart: building the verification session")?;
    let mut stream_errors = 0u64;
    let mut admitted = 0usize;
    let mut recovered = 0usize;
    let mut resumed = 0usize;
    let mut recovered_tokens = 0u64;
    let mut outputs_ok = true;
    let mut row = vec![0.0f32; dv];
    for i in 0..cfg.streams {
        let (p1, p2) = (&phase1[i], &phase2[i]);
        if let Some(e) = &p1.error {
            log::warn!("kill-restart: stream {i} failed before the kill: {e}");
            stream_errors += 1;
            continue;
        }
        if p1.sid.is_empty() {
            continue; // casualty: the kill beat the open ack
        }
        admitted += 1;
        if let Some(e) = &p2.error {
            log::warn!("kill-restart: stream {i} ({}) failed to resume: {e}", p1.sid);
            stream_errors += 1;
            continue;
        }
        let Some(probe) = p2.probed else { continue };
        recovered += 1;
        recovered_tokens += probe;
        resumed += 1;
        let mut state = session.begin_decode(dv)?;
        let mut mismatched = false;
        for t in 0..cfg.tokens {
            let tok = &tokens[i][t * stride..(t + 1) * stride];
            state.append_token_into(&tok[..d], &tok[d..2 * d], &tok[2 * d..], &mut row)?;
            if t < p1.produced {
                for (a, b) in p1.outs[t * dv..(t + 1) * dv].iter().zip(&row) {
                    if a.to_bits() != b.to_bits() {
                        mismatched = true;
                    }
                }
            }
            if t >= p2.resumed_from {
                for (a, b) in p2.outs[t * dv..(t + 1) * dv].iter().zip(&row) {
                    if a.to_bits() != b.to_bits() {
                        mismatched = true;
                    }
                }
            }
        }
        if mismatched {
            log::warn!("kill-restart: stream {i} ({}) diverged from the replay", p1.sid);
            outputs_ok = false;
        }
    }
    let http_429: u64 = phase1.iter().map(|p| p.http.http_429).sum::<u64>()
        + phase2.iter().map(|p| p.http.http_429).sum::<u64>();
    let http_503: u64 = phase1.iter().map(|p| p.http.http_503).sum::<u64>()
        + phase2.iter().map(|p| p.http.http_503).sum::<u64>();
    let http_5xx: u64 = phase1.iter().map(|p| p.http.http_5xx).sum::<u64>()
        + phase2.iter().map(|p| p.http.http_5xx).sum::<u64>();

    let verified = outputs_ok && stream_errors == 0 && recovered == admitted && resumed == admitted;
    Ok(KillRestartReport {
        streams: cfg.streams,
        tokens_per_stream: cfg.tokens,
        kill_at_tokens: kill_at,
        killed_at_tokens: killed_at,
        admitted,
        recovered,
        resumed,
        recovered_tokens,
        http_429,
        http_503_retried: http_503,
        http_5xx,
        stream_errors,
        verified,
        elapsed_s: t0.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_jitters_and_caps() {
        // deterministic: same inputs, same sleep
        assert_eq!(backoff_ms(3, Some(2), 7), backoff_ms(3, Some(2), 7));
        // attempt 0 starts from the server's hint (plus bounded jitter)
        let first = backoff_ms(0, Some(4), 1);
        assert!((4..=6).contains(&first), "got {first}");
        // growth: by attempt 6 a 1ms base saturates the 50ms cap zone
        let late = backoff_ms(6, Some(1), 1);
        assert!(late >= 32, "got {late}");
        // hard cap regardless of hint or attempt
        for attempt in 0..20 {
            for hint in [None, Some(1), Some(7), Some(10_000)] {
                assert!(backoff_ms(attempt, hint, 42) <= MAX_BACKOFF_MS);
                assert!(backoff_ms(attempt, hint, 42) >= 1);
            }
        }
        // different salts actually spread (some pair must differ)
        let spread: Vec<u64> = (0..16).map(|s| backoff_ms(2, Some(8), s)).collect();
        assert!(spread.iter().any(|&v| v != spread[0]), "jitter is a no-op");
    }

    #[test]
    fn kill_point_lands_mid_run() {
        for seed in 0..64 {
            let cfg = LoadConfig { streams: 8, tokens: 16, seed, ..LoadConfig::default() };
            let total = (cfg.streams * cfg.tokens) as u64;
            let at = kill_point(&cfg);
            assert!(at >= total / 4 && at < total, "seed {seed}: kill at {at} of {total}");
        }
    }

    #[test]
    fn gave_up_error_downcasts_through_anyhow() {
        let err = anyhow::Error::new(RetryGaveUp {
            method: "POST".into(),
            path: "/v1/streams".into(),
            attempts: 3,
            last_status: 503,
            elapsed_ms: 12,
        });
        let typed = err.downcast_ref::<RetryGaveUp>().expect("typed give-up");
        assert_eq!(typed.attempts, 3);
        assert_eq!(typed.last_status, 503);
        assert!(err.to_string().contains("after 3 attempts"));
    }

    #[test]
    fn retry_sleep_refuses_once_budget_is_spent() {
        // A request whose retry loop started longer ago than the whole
        // default budget must be told to give up without sleeping.
        let long_ago = Instant::now()
            .checked_sub(Duration::from_millis(DEFAULT_RETRY_BUDGET_MS + 1_000))
            .expect("clock supports backdating");
        let t0 = Instant::now();
        assert!(!retry_sleep(long_ago, 0, Some(1), 42));
        assert!(t0.elapsed() < Duration::from_millis(200), "gave up without sleeping");
        // A fresh request with the same hint is still allowed to wait.
        assert!(retry_sleep(Instant::now(), 0, Some(0), 42));
    }
}
