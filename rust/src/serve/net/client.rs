//! Socket mode for the load generator: drive a running [`Server`]
//! over real TCP with one closed-loop client thread per stream, and
//! verify survivor outputs **bit-identical** to in-process decode.
//!
//! The clients generate exactly the same deterministic token/prompt
//! data as the in-process loadgen (same per-stream seeds), so the
//! verification replay is the same too: every output row that crossed
//! the wire — shortest round-trip f32 decimal both ways — must match
//! the single-stream [`CausalState`](crate::attn::CausalState) replay
//! bit for bit.
//!
//! Chaos over the wire reuses the seeded [`FaultPlan`], with two
//! differences from the in-process drive loop, both forced by the
//! protocol:
//!
//! * NaN injection is skipped — the JSON number grammar cannot spell
//!   non-finite values, so the wire layer structurally rejects them
//!   before the input screen ever runs (`tests/serve_net.rs` pins the
//!   400 instead).
//! * Planned fold panics and forced hibernations are driven through
//!   the explicit `arm_fault` / `hibernate` endpoints at the planned
//!   token positions, by splitting each stream's decode into segments
//!   around them. The casualty then lands mid-stream as an
//!   `event: error` frame on an already-committed 200 response —
//!   never a 5xx status — and the surviving prefix still verifies.
//!
//! [`Server`]: super::Server

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::attn::AttentionSpec;
use crate::serve::loadgen::{generate_prompts, generate_tokens, token_stride, LoadConfig};
use crate::util::json::Value;

use super::wire::{Scan, TokenBody};

/// Give up on a retryable status after this many attempts — keeps a
/// misbehaving server from hanging the generator.
const MAX_RETRIES: usize = 100_000;

// ---------------------------------------------------------------------------
// a minimal blocking HTTP/1.1 client (keep-alive, chunked-aware)
// ---------------------------------------------------------------------------

struct Http {
    stream: TcpStream,
    buf: Vec<u8>,
    pos: usize,
    out: String,
}

struct Head {
    status: u16,
    content_length: usize,
    chunked: bool,
    retry_after: Option<u64>,
}

impl Http {
    fn connect(addr: &str) -> Result<Http> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        stream.set_nodelay(true)?;
        Ok(Http { stream, buf: Vec::with_capacity(4096), pos: 0, out: String::new() })
    }

    fn send(&mut self, method: &str, path: &str, body: &str) -> Result<()> {
        use std::fmt::Write as _;
        self.out.clear();
        let _ = write!(
            self.out,
            "{method} {path} HTTP/1.1\r\nHost: macformer\r\nContent-Length: {}\r\n",
            body.len()
        );
        if !body.is_empty() {
            self.out.push_str("Content-Type: application/json\r\n");
        }
        self.out.push_str("\r\n");
        self.out.push_str(body);
        self.stream.write_all(self.out.as_bytes())?;
        Ok(())
    }

    fn fill(&mut self) -> Result<()> {
        // compact the consumed prefix before growing
        if self.pos > 0 && self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        }
        let mut chunk = [0u8; 4096];
        let n = self.stream.read(&mut chunk)?;
        if n == 0 {
            bail!("server closed the connection mid-response");
        }
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(())
    }

    /// One `\n`-terminated line (CR stripped), as an owned string.
    fn line(&mut self) -> Result<String> {
        loop {
            if let Some(off) = self.buf[self.pos..].iter().position(|&b| b == b'\n') {
                let line = &self.buf[self.pos..self.pos + off];
                let line = line.strip_suffix(b"\r").unwrap_or(line);
                let s = String::from_utf8(line.to_vec()).context("non-UTF8 response line")?;
                self.pos += off + 1;
                return Ok(s);
            }
            self.fill()?;
        }
    }

    /// Exactly `n` body bytes, owned.
    fn take(&mut self, n: usize) -> Result<Vec<u8>> {
        while self.buf.len() - self.pos < n {
            self.fill()?;
        }
        let bytes = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(bytes)
    }

    fn read_head(&mut self) -> Result<Head> {
        let status_line = self.line()?;
        let status: u16 = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad status line {status_line:?}"))?;
        let mut head = Head { status, content_length: 0, chunked: false, retry_after: None };
        loop {
            let line = self.line()?;
            if line.is_empty() {
                return Ok(head);
            }
            let Some((name, value)) = line.split_once(':') else { continue };
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                head.content_length = value.parse().context("bad Content-Length")?;
            } else if name.eq_ignore_ascii_case("transfer-encoding") {
                head.chunked = value.eq_ignore_ascii_case("chunked");
            } else if name.eq_ignore_ascii_case("retry-after") {
                head.retry_after = value.parse().ok();
            }
        }
    }

    /// The next chunk payload of a chunked response; `None` at the
    /// terminal chunk.
    fn read_chunk(&mut self) -> Result<Option<Vec<u8>>> {
        let size_line = self.line()?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .with_context(|| format!("bad chunk size {size_line:?}"))?;
        if size == 0 {
            let _ = self.line()?; // trailing CRLF
            return Ok(None);
        }
        let payload = self.take(size)?;
        let _ = self.line()?; // chunk-terminating CRLF
        Ok(Some(payload))
    }
}

// ---------------------------------------------------------------------------
// SSE frames (the server writes exactly one frame per chunk)
// ---------------------------------------------------------------------------

enum Frame {
    Token { t: usize, out: Vec<f32> },
    Done,
    Error { code: String, message: String },
}

fn parse_frame(payload: &[u8], dv: usize) -> Result<Frame> {
    let text = std::str::from_utf8(payload).context("non-UTF8 SSE frame")?;
    let mut event = "message";
    let mut data = "";
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("event: ") {
            event = rest.trim();
        } else if let Some(rest) = line.strip_prefix("data: ") {
            data = rest;
        }
    }
    match event {
        "done" => Ok(Frame::Done),
        "error" => {
            let mut scan = Scan::object(data.as_bytes()).map_err(|e| anyhow!("{e}"))?;
            let (mut code, mut message) = (String::new(), String::new());
            while let Some(key) = scan.next_key().map_err(|e| anyhow!("{e}"))? {
                match key {
                    b"error" => code = scan.str_value("error").map_err(|e| anyhow!("{e}"))?.into(),
                    b"message" => {
                        message = scan.str_value("message").map_err(|e| anyhow!("{e}"))?.into()
                    }
                    _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
                }
            }
            Ok(Frame::Error { code, message })
        }
        _ => {
            let mut scan = Scan::object(data.as_bytes()).map_err(|e| anyhow!("{e}"))?;
            let mut t = usize::MAX;
            let mut out = Vec::with_capacity(dv);
            while let Some(key) = scan.next_key().map_err(|e| anyhow!("{e}"))? {
                match key {
                    b"t" => t = scan.usize_value("t").map_err(|e| anyhow!("{e}"))?,
                    b"out" => scan.f32_array_into("out", &mut out).map_err(|e| anyhow!("{e}"))?,
                    _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
                }
            }
            if t == usize::MAX || out.len() != dv {
                bail!("malformed token frame {data:?}");
            }
            Ok(Frame::Token { t, out })
        }
    }
}

// ---------------------------------------------------------------------------
// the per-stream closed-loop client
// ---------------------------------------------------------------------------

/// What one stream's client thread brings home.
struct StreamOutcome {
    /// Decode output rows actually produced (prefix on a casualty).
    outs: Vec<f32>,
    produced: usize,
    /// Last prompt-position output from prefill (empty without prompt).
    prompt_last: Vec<f32>,
    /// The planned fold panic landed (as an in-stream error frame).
    faulted: bool,
    /// Unexpected failures (protocol errors, wrong error codes, ...).
    errors: u64,
    http_429: u64,
    http_5xx: u64,
    /// Client-observed seconds between consecutive token frames.
    latencies: Vec<f64>,
}

/// Where a stream's decode must pause for an out-of-band action.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Action {
    ArmFault,
    Hibernate,
}

/// Decode segment cut points for stream `i` under the fault plan:
/// hibernate after its planned tokens, arm the fold panic right
/// before its planned token (everything after the panic is moot).
fn plan_cuts(cfg: &LoadConfig, i: usize) -> Vec<(usize, Action)> {
    let plan = &cfg.faults;
    let panic_at = (0..cfg.tokens)
        .find(|&t| plan.inject_panic(i as u64, t as u64, cfg.tokens as u64));
    let mut cuts = Vec::new();
    for t in 0..cfg.tokens {
        if plan.force_hibernate(i as u64, t as u64) {
            let cut = t + 1;
            if panic_at.is_none_or(|p| cut < p) && cut < cfg.tokens {
                cuts.push((cut, Action::Hibernate));
            }
        }
    }
    if let Some(p) = panic_at {
        cuts.push((p, Action::ArmFault));
    }
    cuts.sort_by_key(|&(c, _)| c);
    cuts
}

/// Issue `method path` with retry on retryable admission statuses
/// (429 ingress/backpressure, 503 pool-full). Returns the final head
/// + body for the caller to interpret.
fn request_with_retry(
    http: &mut Http,
    method: &str,
    path: &str,
    body: &str,
    outcome: &mut StreamOutcome,
) -> Result<(Head, Vec<u8>)> {
    for _ in 0..MAX_RETRIES {
        http.send(method, path, body)?;
        let head = http.read_head()?;
        if head.chunked {
            // callers that expect a stream never come through here
            bail!("unexpected chunked response for {method} {path}");
        }
        let resp_body = http.take(head.content_length)?;
        match head.status {
            429 => outcome.http_429 += 1,
            503 => outcome.http_5xx += 1,
            _ => return Ok((head, resp_body)),
        }
        let ticks = head.retry_after.unwrap_or(1).max(1);
        std::thread::sleep(Duration::from_millis(ticks.min(50)));
    }
    bail!("{method} {path}: still rejected after {MAX_RETRIES} retries")
}

fn body_for(tokens: &[f32], d: usize, dv: usize, range: std::ops::Range<usize>) -> String {
    let stride = 2 * d + dv;
    let mut q = Vec::with_capacity(range.len() * d);
    let mut k = Vec::with_capacity(range.len() * d);
    let mut v = Vec::with_capacity(range.len() * dv);
    for t in range {
        let row = &tokens[t * stride..(t + 1) * stride];
        q.extend_from_slice(&row[..d]);
        k.extend_from_slice(&row[d..2 * d]);
        v.extend_from_slice(&row[2 * d..]);
    }
    let mut body = String::with_capacity((q.len() + k.len() + v.len()) * 12);
    body.push_str("{\"q\":");
    super::wire::write_f32_array(&mut body, &q);
    body.push_str(",\"k\":");
    super::wire::write_f32_array(&mut body, &k);
    body.push_str(",\"v\":");
    super::wire::write_f32_array(&mut body, &v);
    body.push('}');
    body
}

/// Drive one stream end to end over its own connection.
fn drive_stream(
    addr: &str,
    cfg: &LoadConfig,
    i: usize,
    tokens: &[f32],
    prompt: &(Vec<f32>, Vec<f32>, Vec<f32>),
) -> Result<StreamOutcome> {
    let (d, dv) = (cfg.head_dim, cfg.dv);
    let mut outcome = StreamOutcome {
        outs: vec![0.0; cfg.tokens * dv],
        produced: 0,
        prompt_last: Vec::new(),
        faulted: false,
        errors: 0,
        http_429: 0,
        http_5xx: 0,
        latencies: Vec::new(),
    };
    let mut http = Http::connect(addr)?;

    // open
    let (head, resp) = request_with_retry(&mut http, "POST", "/v1/streams", "{}", &mut outcome)?;
    if head.status != 201 {
        bail!("open: expected 201, got {}", head.status);
    }
    let mut scan = Scan::object(&resp).map_err(|e| anyhow!("open body: {e}"))?;
    let mut sid = String::new();
    while let Some(key) = scan.next_key().map_err(|e| anyhow!("open body: {e}"))? {
        match key {
            b"stream" => sid = scan.str_value("stream").map_err(|e| anyhow!("{e}"))?.into(),
            _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
        }
    }
    if sid.is_empty() {
        bail!("open: no stream id in response");
    }

    // prefill
    if cfg.prompt > 0 {
        let (pq, pk, pv) = prompt;
        let mut body = String::new();
        body.push_str("{\"q\":");
        super::wire::write_f32_array(&mut body, pq);
        body.push_str(",\"k\":");
        super::wire::write_f32_array(&mut body, pk);
        body.push_str(",\"v\":");
        super::wire::write_f32_array(&mut body, pv);
        body.push('}');
        let path = format!("/v1/streams/{sid}/prefill");
        let (head, resp) = request_with_retry(&mut http, "POST", &path, &body, &mut outcome)?;
        if head.status != 200 {
            bail!("prefill: expected 200, got {}", head.status);
        }
        let mut scan = Scan::object(&resp).map_err(|e| anyhow!("prefill body: {e}"))?;
        while let Some(key) = scan.next_key().map_err(|e| anyhow!("prefill body: {e}"))? {
            match key {
                b"out" => scan
                    .f32_array_into("out", &mut outcome.prompt_last)
                    .map_err(|e| anyhow!("{e}"))?,
                _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
            }
        }
        if outcome.prompt_last.len() != dv {
            bail!("prefill: expected {dv} output values, got {}", outcome.prompt_last.len());
        }
    }

    // decode, split into segments around planned chaos actions
    let cuts = plan_cuts(cfg, i);
    let mut expect_fault = false;
    let decode_path = format!("/v1/streams/{sid}/decode");
    let mut segments: Vec<(std::ops::Range<usize>, Option<Action>)> = Vec::new();
    let mut prev = 0usize;
    for &(cut, action) in &cuts {
        segments.push((prev..cut, Some(action)));
        prev = cut;
    }
    segments.push((prev..cfg.tokens, None));

    'segments: for (range, action) in segments {
        if !range.is_empty() {
            let body = body_for(tokens, d, dv, range.clone());
            // admission retry loop: a 429/503 answer means nothing
            // streamed yet, so the whole segment can be re-sent
            let mut streamed = false;
            for _ in 0..MAX_RETRIES {
                http.send("POST", &decode_path, &body)?;
                let head = http.read_head()?;
                if !head.chunked {
                    let _resp = http.take(head.content_length)?;
                    match head.status {
                        429 => outcome.http_429 += 1,
                        503 => outcome.http_5xx += 1,
                        s => bail!("decode: unexpected status {s}"),
                    }
                    let ticks = head.retry_after.unwrap_or(1).max(1);
                    std::thread::sleep(Duration::from_millis(ticks.min(50)));
                    continue;
                }
                // committed stream: read frames until done/error
                let mut last = Instant::now();
                while let Some(payload) = http.read_chunk()? {
                    match parse_frame(&payload, dv)? {
                        Frame::Token { t, out } => {
                            let now = Instant::now();
                            outcome.latencies.push((now - last).as_secs_f64());
                            last = now;
                            let abs = range.start + t;
                            if abs >= cfg.tokens {
                                bail!("decode: token index {t} out of segment range");
                            }
                            outcome.outs[abs * dv..(abs + 1) * dv].copy_from_slice(&out);
                            outcome.produced = abs + 1;
                        }
                        Frame::Done => {}
                        Frame::Error { code, message } => {
                            if expect_fault && code == "faulted" {
                                outcome.faulted = true;
                            } else {
                                log::warn!(
                                    "socket loadgen: stream {i} unexpected error frame \
                                     {code}: {message}"
                                );
                                outcome.errors += 1;
                            }
                        }
                    }
                }
                streamed = true;
                break;
            }
            if !streamed {
                bail!("decode: still rejected after {MAX_RETRIES} retries");
            }
            if outcome.faulted || outcome.errors > 0 {
                break 'segments;
            }
        }
        match action {
            None => {}
            Some(Action::Hibernate) => {
                let path = format!("/v1/streams/{sid}/hibernate");
                let (head, _) = request_with_retry(&mut http, "POST", &path, "{}", &mut outcome)?;
                if head.status != 200 {
                    log::warn!("socket loadgen: stream {i} hibernate got {}", head.status);
                    outcome.errors += 1;
                }
            }
            Some(Action::ArmFault) => {
                let path = format!("/v1/streams/{sid}/arm_fault");
                let (head, _) = request_with_retry(&mut http, "POST", &path, "{}", &mut outcome)?;
                if head.status != 200 {
                    log::warn!("socket loadgen: stream {i} arm_fault got {}", head.status);
                    outcome.errors += 1;
                }
                expect_fault = true;
            }
        }
    }

    // close works in any state, faulted included
    let path = format!("/v1/streams/{sid}");
    let (head, _) = request_with_retry(&mut http, "DELETE", &path, "", &mut outcome)?;
    if head.status != 200 {
        log::warn!("socket loadgen: stream {i} close got {}", head.status);
        outcome.errors += 1;
    }
    Ok(outcome)
}

// ---------------------------------------------------------------------------
// the report
// ---------------------------------------------------------------------------

/// Outcome of one [`run_socket`] drive: like
/// [`LoadReport`](crate::serve::loadgen::LoadReport) but measured from
/// the client side of real TCP connections.
#[derive(Debug, Clone)]
pub struct NetLoadReport {
    pub streams: usize,
    pub tokens_per_stream: usize,
    pub prompt_tokens: usize,
    pub elapsed_s: f64,
    pub tokens_total: u64,
    pub tokens_per_sec: f64,
    /// Client-observed per-token latency percentiles (seconds).
    pub latency_p50: f64,
    pub latency_p99: f64,
    pub latency_max: f64,
    /// Backpressure/ingress rejects answered `429` (then retried).
    pub http_429: u64,
    /// `5xx` answers observed (zero on a clean run; the CI socket
    /// smoke greps this).
    pub http_5xx: u64,
    /// Unexpected failures across all streams (zero on any run whose
    /// chaos stayed contained).
    pub stream_errors: u64,
    /// Planned fold-panic casualties, surfaced as in-stream error
    /// frames.
    pub faulted_streams: u64,
    /// Streams whose wire outputs diverged from the single-stream
    /// replay.
    pub poisoned_streams: u64,
    pub verified: Option<bool>,
    pub max_abs_diff: f64,
    pub prefill_max_scaled_diff: f64,
}

impl NetLoadReport {
    pub fn render(&self) -> String {
        let verified = match self.verified {
            Some(true) => "bit-identical to in-process decode".to_string(),
            Some(false) => format!("MISMATCH (max |diff| {})", self.max_abs_diff),
            None => "skipped".to_string(),
        };
        format!(
            "serve/net: {} streams x {} tokens (+{} prompt) over TCP\n\
             {:>10.0} tokens/sec  ({} tokens in {:.3}s)\n\
             latency   p50 {:.6}s  p99 {:.6}s  max {:.6}s  (client-observed)\n\
             http      {} x 429 (retried), {} x 5xx, {} stream errors\n\
             resil     {} faulted (planned), {} poisoned\n\
             verify    {}",
            self.streams,
            self.tokens_per_stream,
            self.prompt_tokens,
            self.tokens_per_sec,
            self.tokens_total,
            self.elapsed_s,
            self.latency_p50,
            self.latency_p99,
            self.latency_max,
            self.http_429,
            self.http_5xx,
            self.stream_errors,
            self.faulted_streams,
            self.poisoned_streams,
            verified,
        )
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("streams", Value::num(self.streams as f64)),
            ("tokens_per_stream", Value::num(self.tokens_per_stream as f64)),
            ("prompt_tokens", Value::num(self.prompt_tokens as f64)),
            ("elapsed_s", Value::num(self.elapsed_s)),
            ("tokens_total", Value::num(self.tokens_total as f64)),
            ("tokens_per_sec", Value::num(self.tokens_per_sec)),
            ("latency_p50_s", Value::num(self.latency_p50)),
            ("latency_p99_s", Value::num(self.latency_p99)),
            ("latency_max_s", Value::num(self.latency_max)),
            ("http_429", Value::num(self.http_429 as f64)),
            ("http_5xx", Value::num(self.http_5xx as f64)),
            ("stream_errors", Value::num(self.stream_errors as f64)),
            ("faulted_streams", Value::num(self.faulted_streams as f64)),
            ("poisoned_streams", Value::num(self.poisoned_streams as f64)),
            (
                "verified",
                match self.verified {
                    Some(b) => Value::Bool(b),
                    None => Value::Null,
                },
            ),
            ("max_abs_diff", Value::num(self.max_abs_diff)),
            ("prefill_max_scaled_diff", Value::num(self.prefill_max_scaled_diff)),
        ])
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Drive a running server at `addr` with `cfg.streams` concurrent TCP
/// clients and verify survivors bit-identical to in-process decode.
///
/// The server must have been started with the same attention spec and
/// seed (`GET /v1/spec` is checked first, so a mismatch is a clear
/// error instead of a verification mystery).
pub fn run_socket(cfg: &LoadConfig, addr: &str) -> Result<NetLoadReport> {
    if cfg.streams == 0 || cfg.tokens == 0 {
        bail!("socket loadgen: streams and tokens must be > 0");
    }
    check_spec(cfg, addr)?;
    let tokens = generate_tokens(cfg);
    let prompts = generate_prompts(cfg);

    let t0 = Instant::now();
    let outcomes: Vec<Result<StreamOutcome>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.streams)
            .map(|i| {
                let tokens = &tokens[i];
                let prompt = &prompts[i];
                scope.spawn(move || drive_stream(addr, cfg, i, tokens, prompt))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("client thread panicked"))))
            .collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();

    let mut stream_errors = 0u64;
    let mut http_429 = 0u64;
    let mut http_5xx = 0u64;
    let mut faulted_streams = 0u64;
    let mut failed = vec![false; cfg.streams];
    let mut produced = vec![0usize; cfg.streams];
    let mut outs: Vec<Vec<f32>> = vec![Vec::new(); cfg.streams];
    let mut prompt_last: Vec<Vec<f32>> = vec![Vec::new(); cfg.streams];
    let mut latencies: Vec<f64> = Vec::new();
    for (i, res) in outcomes.into_iter().enumerate() {
        match res {
            Ok(o) => {
                stream_errors += o.errors;
                http_429 += o.http_429;
                http_5xx += o.http_5xx;
                if o.faulted {
                    faulted_streams += 1;
                }
                failed[i] = o.errors > 0;
                produced[i] = o.produced;
                outs[i] = o.outs;
                prompt_last[i] = o.prompt_last;
                latencies.extend(o.latencies);
            }
            Err(e) => {
                log::warn!("socket loadgen: stream {i} client failed: {e}");
                stream_errors += 1;
                failed[i] = true;
            }
        }
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));

    // replay every survivor through the single-stream path
    let (d, dv, stride) = (cfg.head_dim, cfg.dv, token_stride(cfg));
    let (verified, max_abs_diff, prefill_max_scaled_diff, poisoned_streams) = if cfg.verify {
        let session = AttentionSpec::new(cfg.kernel)
            .head_dim(d)
            .num_features(cfg.num_features)
            .causal(true)
            .seed(cfg.seed)
            .backend(cfg.backend)
            .build()
            .context("socket loadgen: building the verification session")?;
        let mut ok = stream_errors == 0;
        let mut max_diff = 0.0f64;
        let mut prefill_diff = 0.0f64;
        let mut poisoned = 0u64;
        let mut row = vec![0.0f32; dv];
        for i in 0..cfg.streams {
            if failed[i] {
                ok = false;
                continue;
            }
            let mut stream_poisoned = false;
            let mut state = session.begin_decode(dv)?;
            let (pq, pk, pv) = &prompts[i];
            for t in 0..cfg.prompt {
                state.append_token_into(
                    &pq[t * d..(t + 1) * d],
                    &pk[t * d..(t + 1) * d],
                    &pv[t * dv..(t + 1) * dv],
                    &mut row,
                )?;
            }
            if cfg.prompt > 0 {
                for (a, b) in prompt_last[i].iter().zip(&row) {
                    let diff = ((a - b).abs() / b.abs().max(1.0)) as f64;
                    prefill_diff = prefill_diff.max(diff);
                    if !diff.is_finite() || diff > 1e-5 {
                        ok = false;
                        stream_poisoned = true;
                    }
                }
            }
            for t in 0..produced[i] {
                let tok = &tokens[i][t * stride..(t + 1) * stride];
                state.append_token_into(&tok[..d], &tok[d..2 * d], &tok[2 * d..], &mut row)?;
                for (a, b) in outs[i][t * dv..(t + 1) * dv].iter().zip(&row) {
                    if a.to_bits() != b.to_bits() {
                        ok = false;
                        stream_poisoned = true;
                        max_diff = max_diff.max((a - b).abs() as f64);
                    }
                }
            }
            if stream_poisoned {
                poisoned += 1;
            }
        }
        (Some(ok), max_diff, prefill_diff, poisoned)
    } else {
        (None, 0.0, 0.0, failed.iter().filter(|&&f| f).count() as u64)
    };

    let tokens_total: u64 = produced.iter().map(|&p| p as u64).sum();
    Ok(NetLoadReport {
        streams: cfg.streams,
        tokens_per_stream: cfg.tokens,
        prompt_tokens: cfg.prompt,
        elapsed_s: elapsed,
        tokens_total,
        tokens_per_sec: if elapsed > 0.0 { tokens_total as f64 / elapsed } else { 0.0 },
        latency_p50: percentile(&latencies, 50.0),
        latency_p99: percentile(&latencies, 99.0),
        latency_max: latencies.last().copied().unwrap_or(0.0),
        http_429,
        http_5xx,
        stream_errors,
        faulted_streams,
        poisoned_streams,
        verified,
        max_abs_diff,
        prefill_max_scaled_diff,
    })
}

/// Assert the server's `/v1/spec` matches the generator config, so
/// bit-exact verification is comparing like with like.
fn check_spec(cfg: &LoadConfig, addr: &str) -> Result<()> {
    let mut http = Http::connect(addr)?;
    http.send("GET", "/v1/spec", "")?;
    let head = http.read_head()?;
    if head.status != 200 {
        bail!("GET /v1/spec: status {}", head.status);
    }
    let body = http.take(head.content_length)?;
    let mut scan = Scan::object(&body).map_err(|e| anyhow!("spec body: {e}"))?;
    let mut fields: Vec<(String, String)> = Vec::new();
    while let Some(key) = scan.next_key().map_err(|e| anyhow!("spec body: {e}"))? {
        let name = String::from_utf8_lossy(key).into_owned();
        match key {
            b"kernel" | b"backend" => {
                let v = scan.str_value("spec").map_err(|e| anyhow!("{e}"))?;
                fields.push((name, v.to_string()));
            }
            b"head_dim" | b"dv" | b"num_features" | b"seed" => {
                let v = scan.usize_value("spec").map_err(|e| anyhow!("{e}"))?;
                fields.push((name, v.to_string()));
            }
            _ => scan.skip_value().map_err(|e| anyhow!("{e}"))?,
        }
    }
    let expect = [
        ("kernel", cfg.kernel.name().to_string()),
        ("head_dim", cfg.head_dim.to_string()),
        ("dv", cfg.dv.to_string()),
        ("num_features", cfg.num_features.to_string()),
        ("seed", cfg.seed.to_string()),
    ];
    for (name, want) in expect {
        let got = fields.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str());
        if got != Some(want.as_str()) {
            bail!("spec mismatch: server {name}={got:?}, loadgen expects {want:?}");
        }
    }
    Ok(())
}
