//! The engine thread: owns the [`AttentionSession`] + [`Supervisor`]
//! and runs the scheduler tick loop, fed by a **bounded** ingress
//! queue of [`Cmd`]s from the connection workers.
//!
//! The compute side is single-threaded by design — the scheduler's
//! micro-batch tick already spreads the fold across the fastpath
//! worker pool — so the network frontend's only job is to get typed
//! commands onto this thread cheaply and stream results back. The
//! ingress queue is a `sync_channel`: when it fills, workers answer
//! `429 ingress_full` instead of queueing unbounded memory.
//!
//! Decode requests become [`Job`]s driven closed-loop (one token in
//! flight per job, exactly like the in-process loadgen): submit →
//! tick → collect → next token, with one tick serving every job's
//! pending token as a micro-batch. Error policy, which the e2e tests
//! pin down:
//!
//! * **Before the first token** ships, any submit error — including
//!   retryable backpressure — is reported as a typed
//!   [`Event::Reject`], so the worker can answer a real HTTP status
//!   (`429` + `Retry-After`, `409`, ...) and the client decides when
//!   to retry.
//! * **After streaming starts** the response is committed (`200`
//!   chunked), so retryable errors are retried here transparently,
//!   and terminal errors become an in-stream [`Event::Error`].

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::time::Duration;

use crate::attn::{AttentionSession, AttentionSpec};
use crate::serve::durability::{
    self, CheckpointImage, CheckpointStream, DurabilityConfig, JournalOp, Recovery, Store,
};
use crate::serve::obs::{self, Stage};
use crate::serve::resilience::{ResilienceConfig, SessionId, StreamStatus, Supervisor};
use crate::serve::{ServeConfig, ServeError, Telemetry};

/// Everything the engine needs to build its session: the attention
/// spec fields the wire protocol exposes via `GET /v1/spec`.
#[derive(Debug, Clone)]
pub struct EngineSpec {
    pub kernel: crate::attn::Kernel,
    pub backend: crate::attn::Backend,
    pub head_dim: usize,
    pub dv: usize,
    pub num_features: usize,
    pub seed: u64,
}

/// A command from a connection worker. Every variant carries its own
/// reply channel; the engine never blocks on a worker.
pub enum Cmd {
    Open { reply: Sender<Result<u64, ServeError>> },
    Close { sid: u64, reply: Sender<Result<(), ServeError>> },
    Prefill {
        sid: u64,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        reply: Sender<Result<(usize, Vec<f32>), ServeError>>,
        /// Hashed `x-request-id` (0 = none) — spans the engine records
        /// for this request carry it into `--trace-out`.
        req: u64,
        /// [`obs::now_ns`] at enqueue (0 = obs disabled); the engine
        /// records the `ingress_wait` span from it at pickup.
        enq_ns: u64,
    },
    Decode {
        sid: u64,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        events: Sender<Event>,
        /// Hashed `x-request-id` (0 = none); same role as on `Prefill`.
        req: u64,
        /// Enqueue timestamp; same role as on `Prefill`.
        enq_ns: u64,
    },
    ArmFault { sid: u64, reply: Sender<Result<(), ServeError>> },
    Hibernate { sid: u64, reply: Sender<Result<(), ServeError>> },
    /// Move a stream out: snapshot its versioned MACS state record and
    /// close it here. The record restores bit-identically on any node
    /// (`GET /v1/streams/s-N/export`, the live-migration source side).
    Export { sid: u64, reply: Sender<Result<ExportedStream, ServeError>> },
    /// Adopt a stream under a fresh wire id (`POST /v1/streams/import`,
    /// the migration destination side).
    Import { source: ImportSource, reply: Sender<Result<u64, ServeError>> },
    Health { reply: Sender<Health> },
    /// Lifecycle + folded-token-count probe for `GET /v1/streams/s-N`
    /// — how a reconnecting client finds where to resume after a
    /// crash-restart.
    Status { sid: u64, reply: Sender<Result<(StreamStatus, u64), ServeError>> },
    /// Graceful drain: finish in-flight decode jobs, write a final
    /// checkpoint, then exit the engine loop. The worker side stops
    /// admitting new streams the moment drain is requested.
    Drain,
    /// Abrupt stop: no final checkpoint, no draining — exactly what a
    /// crash looks like to the durable store (and therefore what the
    /// recovery tests simulate in-process).
    Shutdown,
}

/// A stream's state moved out by [`Cmd::Export`]: the versioned MACS
/// record plus whether it sat in the spill arena (both travel over the
/// wire as-is; the record is the handoff format).
pub struct ExportedStream {
    pub record: Vec<u8>,
    pub hibernated: bool,
}

/// Where an imported stream's state comes from.
pub enum ImportSource {
    /// A versioned MACS state record shipped over the wire (live
    /// migration from a healthy source node).
    Record { record: Vec<u8>, hibernated: bool },
    /// Adopt one stream straight from a (dead) node's durable store on
    /// shared storage: checkpoint record + journal-tail replay through
    /// the normal fold path.
    Store { dir: PathBuf, sid: u64 },
}

/// One streamed decode event (one SSE frame).
pub enum Event {
    /// The request failed before any token was produced; the worker
    /// still owns the HTTP status line.
    Reject(ServeError),
    /// Output row for relative token `t` of this request.
    Token { t: usize, out: Vec<f32> },
    /// All requested tokens produced.
    Done,
    /// Terminal mid-stream failure; the stream stays open for
    /// `DELETE` but will not produce further tokens.
    Error(ServeError),
}

/// Snapshot answered to `GET /healthz`.
pub struct Health {
    pub tick_no: u64,
    pub active_streams: usize,
    pub hibernated_streams: usize,
    pub jobs: usize,
    pub telemetry: Telemetry,
}

/// One in-flight decode request (closed loop: at most one token
/// pending per job).
struct Job {
    sid: u64,
    id: SessionId,
    /// Hashed `x-request-id` (0 = none) — tags the engine-side spans
    /// (journal append) this job generates.
    req: u64,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Tokens in this request.
    n: usize,
    /// Next token to submit / collect.
    t: usize,
    in_flight: bool,
    /// At least one token has shipped — the HTTP response is
    /// committed, so errors are now in-stream events.
    started: bool,
    events: Sender<Event>,
    dead: bool,
}

/// The engine thread's whole mutable state: supervisor, the wire-id
/// map, the in-flight decode jobs, and the durable store.
struct Engine<'s> {
    sup: Supervisor<'s>,
    /// wire id -> supervised session; u64 keys keep SessionId private
    sessions: HashMap<u64, SessionId>,
    next_sid: u64,
    /// one decode job per stream at a time (closed-loop per session)
    busy: HashSet<u64>,
    jobs: Vec<Job>,
    d: usize,
    dv: usize,
    /// Write-ahead journal + checkpoints. `None` when the server runs
    /// without `--data-dir`, or after a disk error degraded durability
    /// mid-run (logged loudly; serving continues).
    store: Option<Store>,
    /// [`Cmd::Drain`] was received: finish in-flight jobs, write a
    /// final checkpoint, exit 0.
    draining: bool,
}

/// Run the engine loop until [`Cmd::Shutdown`], [`Cmd::Drain`]
/// completes, or every sender hangs up. `ready` reports session
/// construction and crash-restart recovery (the fallible setup) back
/// to [`Server::start`](super::Server::start) — recovery happens
/// *before* ready, so a listener that accepts connections is always
/// fully recovered.
pub(super) fn run(
    spec: EngineSpec,
    serve: ServeConfig,
    resilience: ResilienceConfig,
    durability: Option<DurabilityConfig>,
    ingress: Receiver<Cmd>,
    ready: Sender<Result<(), String>>,
) {
    obs::register_thread();
    if let Err(e) = serve.validate() {
        let _ = ready.send(Err(e.to_string()));
        return;
    }
    let session: AttentionSession = match AttentionSpec::new(spec.kernel)
        .head_dim(spec.head_dim)
        .num_features(spec.num_features)
        .causal(true)
        .seed(spec.seed)
        .backend(spec.backend)
        .build()
    {
        Ok(s) => s,
        Err(e) => {
            let _ = ready.send(Err(format!("building the attention session: {e}")));
            return;
        }
    };
    let sup = match Supervisor::new(&session, serve, resilience) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready.send(Err(format!("building the supervisor: {e}")));
            return;
        }
    };
    let (store, recovery) = match durability.map(Store::open).transpose() {
        Ok(opened) => match opened {
            Some((s, r)) => (Some(s), Some(r)),
            None => (None, None),
        },
        Err(e) => {
            let _ = ready.send(Err(format!("opening the durable store: {e}")));
            return;
        }
    };

    let mut eng = Engine {
        sup,
        sessions: HashMap::new(),
        next_sid: 1,
        busy: HashSet::new(),
        jobs: Vec::new(),
        d: spec.head_dim,
        dv: spec.dv,
        store,
        draining: false,
    };

    if let Some(rec) = recovery {
        if let Err(e) = eng.recover(rec) {
            let _ = ready.send(Err(format!("recovering from the durable store: {e}")));
            return;
        }
    }
    let _ = ready.send(Ok(()));

    loop {
        // --- drain: in-flight jobs finished, state checkpointed, out ---
        if eng.draining && eng.jobs.is_empty() {
            eng.final_checkpoint();
            return;
        }

        // --- ingest: block when idle, drain without blocking otherwise ---
        if eng.jobs.is_empty() {
            // going idle: flush any group-commit buffer first, so a
            // crash during the quiet period loses nothing
            eng.sync_store();
            match ingress.recv() {
                Ok(cmd) => {
                    if eng.handle_cmd(cmd) {
                        return;
                    }
                }
                Err(_) => return, // every worker is gone
            }
        }
        while let Ok(cmd) = ingress.try_recv() {
            if eng.handle_cmd(cmd) {
                return;
            }
        }
        if eng.draining && eng.jobs.is_empty() {
            eng.final_checkpoint();
            return;
        }

        let submitted = eng.submit_phase();
        if submitted {
            eng.tick_or_fail_all();
        } else if !eng.jobs.iter().all(|j| j.dead) {
            // jobs exist but none could submit (backpressure/shed with
            // no queue drain pending): tick to advance deadlines, and
            // breathe so the retry loop is not a hot spin
            let _ = eng.sup.tick();
            match ingress.recv_timeout(Duration::from_micros(200)) {
                Ok(cmd) => {
                    if eng.handle_cmd(cmd) {
                        return;
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }

        eng.collect_phase();
        eng.reap();
        eng.pump_durability();
    }
}

impl Engine<'_> {
    /// Stage each live job's next token. Returns whether anything was
    /// submitted (i.e. the tick has work to do).
    fn submit_phase(&mut self) -> bool {
        let (d, dv) = (self.d, self.dv);
        let mut submitted = false;
        for job in self.jobs.iter_mut() {
            if job.dead || job.in_flight || job.t >= job.n {
                continue;
            }
            let t = job.t;
            let q = &job.q[t * d..(t + 1) * d];
            let k = &job.k[t * d..(t + 1) * d];
            let v = &job.v[t * dv..(t + 1) * dv];
            match self.sup.submit(job.id, q, k, v) {
                Ok(()) => {
                    // journal the accepted token (group-committed by
                    // pump_durability at the end of the loop turn)
                    if let Some(store) = self.store.as_mut() {
                        obs::set_request_id(job.req);
                        store.record_token(job.sid, q, k, v);
                        obs::set_request_id(0);
                    }
                    job.in_flight = true;
                    submitted = true;
                }
                Err(e) if !job.started => {
                    // no bytes shipped yet: the worker can still answer
                    // a real status line (429/409/...)
                    let _ = job.events.send(Event::Reject(e));
                    job.dead = true;
                }
                Err(e) if e.is_retryable() => {
                    // mid-stream backpressure: retry next iteration
                }
                Err(e) => {
                    let _ = job.events.send(Event::Error(e));
                    job.dead = true;
                }
            }
        }
        submitted
    }

    /// Run one scheduler tick; a tick-level failure (not a per-stream
    /// fault — those are isolated inside the tick) fails every job.
    fn tick_or_fail_all(&mut self) {
        if self.sup.tick().is_ok() {
            return;
        }
        for job in self.jobs.iter_mut().filter(|j| !j.dead) {
            let e = ServeError::Session("scheduler tick failed".into());
            let _ = job.events.send(Event::Error(e));
            job.dead = true;
        }
    }

    /// Stream out every token the tick served.
    fn collect_phase(&mut self) {
        let dv = self.dv;
        for job in self.jobs.iter_mut() {
            if job.dead || !job.in_flight {
                continue;
            }
            let mut out = vec![0.0f32; dv];
            match self.sup.take_output(job.id, &mut out) {
                Ok(()) => {
                    job.in_flight = false;
                    let t = job.t;
                    job.t += 1;
                    job.started = true;
                    if job.events.send(Event::Token { t, out }).is_err() {
                        // client hung up mid-stream: abandon the job
                        job.dead = true;
                        continue;
                    }
                    if job.t >= job.n {
                        let _ = job.events.send(Event::Done);
                        job.dead = true;
                    }
                }
                Err(e) if e.is_retryable() => {
                    // a delayed/hibernating tick path: collect later
                }
                Err(e) => {
                    // fold-time failure (isolated fault, fired deadline):
                    // the submit was accepted, so this is an in-stream
                    // event even on the first token — the worker opens
                    // the committed 200 stream and reports it there,
                    // never a 5xx status line
                    let _ = job.events.send(Event::Error(e));
                    job.dead = true;
                }
            }
        }
    }

    /// Drop finished/abandoned jobs and release their busy marks.
    fn reap(&mut self) {
        for job in self.jobs.iter().filter(|j| j.dead) {
            self.busy.remove(&job.sid);
        }
        self.jobs.retain(|j| !j.dead);
    }

    /// Apply one control command. Returns `true` on shutdown.
    ///
    /// State-changing commands (open / prefill / close) journal and
    /// **sync before replying**: any ack a client holds survives a
    /// crash, so a recovered server never answers `unknown_stream` for
    /// a stream it admitted or forgets a prompt it confirmed.
    fn handle_cmd(&mut self, cmd: Cmd) -> bool {
        match cmd {
            Cmd::Shutdown => return true,
            Cmd::Drain => self.draining = true,
            Cmd::Open { reply } => {
                let res = self.sup.open().map(|id| {
                    let sid = self.next_sid;
                    self.next_sid += 1;
                    self.sessions.insert(sid, id);
                    sid
                });
                if let Ok(sid) = res {
                    if let Some(store) = self.store.as_mut() {
                        store.record_open(sid);
                    }
                    self.sync_store();
                }
                let _ = reply.send(res);
            }
            Cmd::Close { sid, reply } => {
                let res = match self.sessions.remove(&sid) {
                    None => Err(ServeError::UnknownStream),
                    Some(id) => {
                        // a close abandons any in-flight decode job
                        for job in self.jobs.iter_mut().filter(|j| j.sid == sid) {
                            let _ = job.events.send(Event::Error(ServeError::UnknownStream));
                            job.dead = true;
                        }
                        self.busy.remove(&sid);
                        self.sup.close(id)
                    }
                };
                if res.is_ok() {
                    if let Some(store) = self.store.as_mut() {
                        store.record_close(sid);
                    }
                    self.sync_store();
                }
                let _ = reply.send(res);
            }
            Cmd::Prefill { sid, q, k, v, reply, req, enq_ns } => {
                record_ingress_wait(enq_ns, req);
                // prefill computes on this thread, so its GEMM/fold and
                // journal spans can all carry the request id
                obs::set_request_id(req);
                let res = match self.sessions.get(&sid) {
                    None => Err(ServeError::UnknownStream),
                    Some(_) if self.busy.contains(&sid) => Err(ServeError::StreamBusy),
                    Some(&id) => self.sup.prefill(id, &q, &k, &v).and_then(|n| {
                        let mut last = vec![0.0f32; self.dv];
                        self.sup.take_output(id, &mut last)?;
                        Ok((n, last))
                    }),
                };
                if res.is_ok() {
                    if let Some(store) = self.store.as_mut() {
                        store.record_prefill(sid, &q, &k, &v);
                    }
                    self.sync_store();
                }
                obs::set_request_id(0);
                let _ = reply.send(res);
            }
            Cmd::Decode { sid, q, k, v, events, req, enq_ns } => {
                record_ingress_wait(enq_ns, req);
                self.start_decode(sid, q, k, v, events, req)
            }
            Cmd::ArmFault { sid, reply } => {
                let res = match self.sessions.get(&sid) {
                    None => Err(ServeError::UnknownStream),
                    Some(&id) => self.sup.arm_fault(id),
                };
                let _ = reply.send(res);
            }
            Cmd::Hibernate { sid, reply } => {
                let res = match self.sessions.get(&sid) {
                    None => Err(ServeError::UnknownStream),
                    Some(&id) => self.sup.hibernate(id),
                };
                let _ = reply.send(res);
            }
            Cmd::Export { sid, reply } => {
                let res = self.export_stream(sid);
                if res.is_ok() {
                    // the export is a move: journal the close so a
                    // restart of *this* node does not resurrect a
                    // stream that now lives elsewhere
                    if let Some(store) = self.store.as_mut() {
                        store.record_close(sid);
                    }
                    self.sync_store();
                }
                let _ = reply.send(res);
            }
            Cmd::Import { source, reply } => {
                let res = self.import_stream(source);
                if res.is_ok() {
                    // no journal op spells "restore this record", so an
                    // adopted stream becomes durable via an immediate
                    // compacting checkpoint
                    self.write_checkpoint();
                }
                let _ = reply.send(res);
            }
            Cmd::Health { reply } => {
                let _ = reply.send(Health {
                    tick_no: self.sup.tick_no(),
                    active_streams: self.sup.active_streams(),
                    hibernated_streams: self.sup.hibernated_streams(),
                    jobs: self.jobs.iter().filter(|j| !j.dead).count(),
                    telemetry: self.sup.telemetry().clone(),
                });
            }
            Cmd::Status { sid, reply } => {
                let res = match self.sessions.get(&sid) {
                    None => Err(ServeError::UnknownStream),
                    Some(&id) => self.sup.status(id).map(|st| {
                        // terminal streams hold no state: report len 0
                        let len = self.sup.stream_len(id).unwrap_or(0);
                        (st, len)
                    }),
                };
                let _ = reply.send(res);
            }
        }
        false
    }

    /// Validate a decode request's shape and queue it as a [`Job`].
    fn start_decode(
        &mut self,
        sid: u64,
        q: Vec<f32>,
        k: Vec<f32>,
        v: Vec<f32>,
        events: Sender<Event>,
        req: u64,
    ) {
        let Some(&id) = self.sessions.get(&sid) else {
            let _ = events.send(Event::Reject(ServeError::UnknownStream));
            return;
        };
        if self.busy.contains(&sid) {
            let _ = events.send(Event::Reject(ServeError::StreamBusy));
            return;
        }
        // shape check up front: one consistent token count
        let (d, dv) = (self.d, self.dv);
        let n = q.len() / d.max(1);
        let shape_err = if d == 0 || q.len() % d != 0 {
            Some(ServeError::BadRow { what: "q", expected: d.max(1), got: q.len() })
        } else if k.len() != n * d {
            Some(ServeError::BadRow { what: "k", expected: n * d, got: k.len() })
        } else if v.len() != n * dv {
            Some(ServeError::BadRow { what: "v", expected: n * dv, got: v.len() })
        } else if n == 0 {
            Some(ServeError::BadRow { what: "q", expected: d, got: 0 })
        } else {
            None
        };
        if let Some(e) = shape_err {
            let _ = events.send(Event::Reject(e));
            return;
        }
        self.busy.insert(sid);
        self.jobs.push(Job {
            sid,
            id,
            req,
            q,
            k,
            v,
            n,
            t: 0,
            in_flight: false,
            started: false,
            events,
            dead: false,
        });
    }

    /// [`Cmd::Export`]: snapshot the stream's state record, then close
    /// it here — the caller now owns the only copy. A stream with an
    /// in-flight decode job or a staged-but-unfolded token answers
    /// `StreamBusy` (retryable once the job drains).
    fn export_stream(&mut self, sid: u64) -> Result<ExportedStream, ServeError> {
        let Some(&id) = self.sessions.get(&sid) else {
            return Err(ServeError::UnknownStream);
        };
        if self.busy.contains(&sid) {
            return Err(ServeError::StreamBusy);
        }
        let snap = self.sup.snapshot_stream(id)?;
        if snap.pending.is_some() {
            return Err(ServeError::StreamBusy);
        }
        self.sup.close(id)?;
        self.sessions.remove(&sid);
        Ok(ExportedStream { record: snap.record, hibernated: snap.hibernated })
    }

    /// [`Cmd::Import`]: restore a stream under a fresh wire id, then
    /// replay any staged token and journal tail through the normal
    /// fold path (deterministic — the adopted stream is bit-identical
    /// to the one that left its old node). Failure rolls the stream
    /// back out so a half-imported state never serves.
    fn import_stream(&mut self, source: ImportSource) -> Result<u64, ServeError> {
        let (record, hibernated, pending, ops) = match source {
            ImportSource::Record { record, hibernated } => {
                (Some(record), hibernated, None, Vec::new())
            }
            ImportSource::Store { dir, sid } => {
                let rec = durability::recover_stream(&dir, sid)
                    .map_err(|e| ServeError::Session(format!("reading {dir:?}: {e}")))?
                    .ok_or(ServeError::UnknownStream)?;
                (rec.record, rec.hibernated, rec.pending, rec.ops)
            }
        };
        let id = match record {
            Some(rec) => self.sup.restore_stream(&rec, hibernated)?,
            // opened after the source's last checkpoint: fresh state,
            // rebuilt entirely by the journal-tail replay below
            None => self.sup.open()?,
        };
        let sid = self.next_sid;
        self.next_sid += 1;
        self.sessions.insert(sid, id);
        let mut replay = || -> Result<(), ServeError> {
            if let Some((q, k, v)) = &pending {
                self.replay_token(id, q, k, v)?;
            }
            for op in &ops {
                match op {
                    JournalOp::Prefill { q, k, v, .. } => {
                        self.sup.prefill(id, q, k, v)?;
                        let mut out = vec![0.0f32; self.dv];
                        self.sup.take_output(id, &mut out)?;
                    }
                    JournalOp::Token { q, k, v, .. } => self.replay_token(id, q, k, v)?,
                    // recover_stream folds Open/Close into the record
                    JournalOp::Open { .. } | JournalOp::Close { .. } => {}
                }
            }
            Ok(())
        };
        if let Err(e) = replay() {
            let _ = self.sup.close(id);
            self.sessions.remove(&sid);
            return Err(e);
        }
        Ok(sid)
    }

    // --- durability: journal pumping, checkpoints, recovery ---

    /// Fsync every buffered journal frame now. A disk error here (and
    /// in the other store paths) degrades to non-durable serving with
    /// one loud log line — the engine never fails live traffic because
    /// the journal disk went bad.
    fn sync_store(&mut self) {
        if let Some(store) = self.store.as_mut() {
            if let Err(e) = store.sync(self.sup.tick_no()) {
                log::error!("durable journal sync failed ({e}); continuing without durability");
                self.store = None;
            }
        }
    }

    /// Once per loop turn: group-commit the token journal, and write a
    /// compacting checkpoint when the cadence comes due.
    fn pump_durability(&mut self) {
        let tick = self.sup.tick_no();
        if self.store.as_ref().is_some_and(|s| s.checkpoint_due(tick)) {
            self.write_checkpoint();
        } else if let Some(store) = self.store.as_mut() {
            if let Err(e) = store.maybe_sync(tick) {
                log::error!("durable journal sync failed ({e}); continuing without durability");
                self.store = None;
            }
        }
    }

    /// The drain-path checkpoint: capture whatever state remains so a
    /// restart resumes exactly where the drained process stopped.
    fn final_checkpoint(&mut self) {
        self.write_checkpoint();
    }

    /// Write the Supervisor's full state as the new last-good
    /// checkpoint and rotate the journal epoch.
    fn write_checkpoint(&mut self) {
        let Some(epoch) = self.store.as_ref().map(|s| s.epoch() + 1) else {
            return;
        };
        let image = self.build_image(epoch);
        let tick = self.sup.tick_no();
        if let Some(store) = self.store.as_mut() {
            if let Err(e) = store.write_checkpoint(&image, tick) {
                log::error!("durable checkpoint failed ({e}); continuing without durability");
                self.store = None;
            }
        }
    }

    /// Snapshot every live stream (active or hibernated; terminal
    /// streams hold nothing worth persisting) into a checkpoint image.
    /// Streams are ordered by wire id so the same state always encodes
    /// to the same bytes.
    fn build_image(&self, epoch: u64) -> CheckpointImage {
        let mut streams: Vec<CheckpointStream> = self
            .sessions
            .iter()
            .filter_map(|(&sid, &id)| {
                let snap = self.sup.snapshot_stream(id).ok()?;
                Some(CheckpointStream {
                    sid,
                    hibernated: snap.hibernated,
                    record: snap.record,
                    pending: snap.pending,
                })
            })
            .collect();
        streams.sort_by_key(|s| s.sid);
        CheckpointImage {
            epoch,
            next_sid: self.next_sid,
            tick_no: self.sup.tick_no(),
            counters: self.sup.telemetry().export_counters(),
            streams,
        }
    }

    /// Crash-restart recovery: restore the checkpoint image, then
    /// replay the journal tail **through the normal fold path** — the
    /// deterministic fold makes the recovered streams bit-identical to
    /// a process that never died. Any failure here is a typed startup
    /// error: serving from a half-recovered state would silently break
    /// that contract.
    fn recover(&mut self, rec: Recovery) -> Result<(), String> {
        if rec.is_empty() {
            return Ok(());
        }
        if rec.truncated_bytes > 0 {
            log::warn!(
                "durable journal: dropped a {}-byte torn tail (crash mid-write); \
                 clients re-derive the lost rows bit-identically on resubmit",
                rec.truncated_bytes
            );
        }
        if let Some(img) = &rec.checkpoint {
            for s in &img.streams {
                let id = self
                    .sup
                    .restore_stream(&s.record, s.hibernated)
                    .map_err(|e| format!("checkpointed stream s-{}: {e}", s.sid))?;
                self.sessions.insert(s.sid, id);
            }
            self.next_sid = img.next_sid;
            // overwrite the restore churn with the checkpointed
            // aggregates, and re-anchor every deadline to the
            // checkpointed clock before any replay tick runs
            self.sup.import_telemetry(&img.counters);
            self.sup.restore_clock(img.tick_no);
            for s in &img.streams {
                if let Some((q, k, v)) = &s.pending {
                    let id = self.sessions[&s.sid];
                    self.replay_token(id, q, k, v)
                        .map_err(|e| format!("staged token for s-{}: {e}", s.sid))?;
                }
            }
        }
        let replayed = rec.ops.len();
        for op in &rec.ops {
            self.apply_op(op).map_err(|e| format!("journal replay for s-{}: {e}", op.sid()))?;
        }
        obs::record_recovery(replayed as u64, rec.truncated_bytes as u64);
        // a recovered wire id must never be handed out twice
        if let Some(&max) = self.sessions.keys().max() {
            self.next_sid = self.next_sid.max(max + 1);
        }
        log::info!(
            "recovered {} stream(s) from the durable store ({} journal op(s) replayed)",
            self.sessions.len(),
            replayed
        );
        Ok(())
    }

    /// Replay one journaled op through the same supervisor calls the
    /// live path uses.
    fn apply_op(&mut self, op: &JournalOp) -> Result<(), ServeError> {
        match op {
            JournalOp::Open { sid } => {
                let id = self.sup.open()?;
                self.sessions.insert(*sid, id);
                Ok(())
            }
            JournalOp::Prefill { sid, q, k, v } => {
                let id = *self.sessions.get(sid).ok_or(ServeError::UnknownStream)?;
                self.sup.prefill(id, q, k, v)?;
                let mut out = vec![0.0f32; self.dv];
                self.sup.take_output(id, &mut out)
            }
            JournalOp::Token { sid, q, k, v } => {
                let id = *self.sessions.get(sid).ok_or(ServeError::UnknownStream)?;
                self.replay_token(id, q, k, v)
            }
            JournalOp::Close { sid } => {
                let id = self.sessions.remove(sid).ok_or(ServeError::UnknownStream)?;
                self.sup.close(id)
            }
        }
    }

    /// Fold one replayed token: submit → tick → take, exactly the live
    /// closed loop (batching never changes a stream's fold, so
    /// one-token ticks replay bit-identically to batched serving).
    fn replay_token(
        &mut self,
        id: SessionId,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<(), ServeError> {
        self.sup.submit(id, q, k, v)?;
        self.sup
            .tick()
            .map_err(|e| ServeError::Session(format!("replay tick failed: {e:#}")))?;
        let mut out = vec![0.0f32; self.dv];
        self.sup.take_output(id, &mut out)
    }
}

/// Record how long a command sat in the bounded ingress queue between
/// the worker's enqueue and the engine picking it up. `enq_ns == 0`
/// means the worker enqueued with obs disabled — record nothing.
#[inline]
fn record_ingress_wait(enq_ns: u64, req: u64) {
    if enq_ns != 0 {
        obs::record_span(Stage::IngressWait, enq_ns, obs::now_ns(), req);
    }
}

/// Try to enqueue a command; a full ingress queue is typed admission
/// control for the worker (`429 ingress_full`), not a block.
pub(super) fn try_enqueue(ingress: &SyncSender<Cmd>, cmd: Cmd) -> Result<(), IngressError> {
    match ingress.try_send(cmd) {
        Ok(()) => Ok(()),
        Err(TrySendError::Full(_)) => Err(IngressError::Full),
        Err(TrySendError::Disconnected(_)) => Err(IngressError::Down),
    }
}

/// Why a command could not be enqueued.
pub(super) enum IngressError {
    /// Bounded queue at capacity → `429` + `Retry-After`.
    Full,
    /// Engine thread gone → `503`.
    Down,
}
