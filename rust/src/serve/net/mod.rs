//! The network serving frontend: a dependency-free HTTP/1.1 gateway
//! over the [`serve`](crate::serve) subsystem.
//!
//! Architecture (one process, plain `std::net` blocking I/O):
//!
//! ```text
//!             TcpListener (shared, SO_REUSE via try_clone)
//!   ┌───────────┬───────────┬───────────┐
//!   │ worker 0  │ worker 1  │ worker N  │   blocking accept + HTTP/1.1
//!   └─────┬─────┴─────┬─────┴─────┬─────┘   parse ([`http`]) + lazy
//!         │           │           │          JSON scan ([`wire`])
//!         └────── bounded ingress queue ─────────┐ (sync_channel;
//!                                                │  full → 429)
//!                                       ┌────────▼────────┐
//!                                       │  engine thread  │ Supervisor
//!                                       │ ([`engine`])    │ + Scheduler
//!                                       └─────────────────┘ tick loop
//! ```
//!
//! Connection workers never touch the pool: they parse requests,
//! enqueue typed [`engine::Cmd`]s, and stream replies back over
//! per-request channels. The engine thread owns the session +
//! [`Supervisor`](crate::serve::Supervisor) and runs the micro-batch
//! tick loop; hibernation, deadlines, and fault isolation all apply to
//! socket clients exactly as to in-process callers.
//!
//! # Wire protocol
//!
//! | Route | Body | Answer |
//! |---|---|---|
//! | `GET /healthz` | — | `200 {"status":"ready",...}` — or `503` with `"starting"` / `"draining"` |
//! | `GET /metrics` | — | `200` Prometheus text exposition ([`crate::serve::obs::prom`]) |
//! | `GET /v1/spec` | — | `200` kernel/dims/seed (clients verify against it) |
//! | `POST /v1/streams` | `{}` | `201 {"stream":"s-1"}` — `503 draining` + `Retry-After` mid-drain |
//! | `GET /v1/streams/{id}` | — | `200 {"stream":..,"status":..,"tokens":n}` (crash-recovery resume probe) |
//! | `POST /v1/streams/{id}/prefill` | `{"q":[..],"k":[..],"v":[..]}` | `200 {"tokens":n,"out":[..]}` |
//! | `POST /v1/streams/{id}/decode` | `{"q":[..],"k":[..],"v":[..]}` | `200` chunked SSE, one `data:` frame per token |
//! | `POST /v1/streams/{id}/arm_fault` | `{}` | `200` (chaos hook: next fold panics) |
//! | `POST /v1/streams/{id}/hibernate` | `{}` | `200` (snapshot to the spill arena) |
//! | `POST /admin/drain` | `{}` | `200` — flips the gateway to draining (see [`Server::drain`]) |
//! | `DELETE /v1/streams/{id}` | — | `200` (any state) |
//! | `GET /v1/streams/{id}/export` | — | `200` binary MACS state record (**moves** the stream out) |
//! | `POST /v1/streams/import` | record bytes, or `{"dir":..,"stream":..}` | `201 {"stream":"s-K"}` |
//!
//! Export/import are the live-migration pair a router tier drives:
//! `export` snapshots the stream's versioned state record and closes
//! it here (the caller owns the only copy), `import` restores a record
//! — or, in the JSON form, adopts one stream straight from a dead
//! node's durable store on shared storage — under a fresh id and
//! answers like an open. Every response carries `x-macformer-node`
//! (the node's seeded stable id, also in `/healthz`) so callers can
//! tell backends apart through a proxy.
//!
//! `q`/`k`/`v` are row-major flattened `n x d` / `n x d` / `n x dv`
//! token rows. Decode responses are `text/event-stream` frames:
//! `data: {"t":0,"out":[..]}`, then `event: done` — or `event: error`
//! with the typed error body if the stream dies mid-response (the
//! status line is already committed by then; error *before* the first
//! token is a real HTTP status). Every [`ServeError`] maps to a stable
//! `(status, code)` pair via [`http_status`] + [`ServeError::code`] —
//! pinned exhaustively by `tests/serve_net.rs` — and backpressure
//! carries its `retry_after_ticks` hint as a `Retry-After` header.
//! Floats cross the wire in shortest round-trip decimal, so decode
//! outputs are **bit-identical** to in-process decode (the socket
//! loadgen's verification is exact, not approximate).

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::mpsc::{channel, sync_channel, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::serve::obs::{self, Stage};
use crate::serve::resilience::StreamStatus;
use crate::serve::{DurabilityConfig, ResilienceConfig, ServeConfig, ServeError};
use crate::util::json::Value;

pub mod client;
pub mod engine;
pub mod http;
pub mod wire;

pub use client::{
    run_kill_restart, run_socket, set_retry_budget_ms, KillRestartReport, NetLoadReport,
    RetryGaveUp, DEFAULT_RETRY_BUDGET_MS,
};
pub use engine::EngineSpec;
use engine::{Cmd, Event, IngressError};
use http::{Conn, HttpConfig, HttpError, Method, Request};
use wire::TokenBody;

/// Frontend knobs (the compute config lives in [`EngineSpec`] /
/// [`ServeConfig`]).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address, e.g. `127.0.0.1:0` (0 = kernel-assigned port).
    pub addr: String,
    /// Blocking connection workers sharing the listener.
    pub workers: usize,
    /// Bound on queued engine commands; a full queue answers
    /// `429 ingress_full` instead of growing.
    pub queue_depth: usize,
    /// Per-connection HTTP limits.
    pub http: HttpConfig,
    /// Stable node id stamped on every response as
    /// `x-macformer-node` and reported by `/healthz`. `None` derives
    /// one from the engine seed + data dir (or bind address), so a
    /// restarted node keeps its identity.
    pub node_id: Option<String>,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            addr: "127.0.0.1:0".into(),
            workers: 4,
            queue_depth: 128,
            http: HttpConfig::default(),
            node_id: None,
        }
    }
}

/// Derive a stable node id from the engine seed and a location salt
/// (data dir, or the configured bind address): FNV-1a over the salt,
/// xor-folded with the seed, splitmix-finalized — short, stable across
/// restarts, and distinct per node in a `--spawn N` fleet.
pub fn derive_node_id(seed: u64, salt: &str) -> String {
    let mut h = 0xcbf29ce484222325u64;
    for b in salt.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    let mut x = h ^ seed.wrapping_mul(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^= x >> 31;
    format!("n-{:012x}", x & 0xffff_ffff_ffff)
}

/// The HTTP status (code + reason) for every typed [`ServeError`].
/// Exhaustive by construction — adding a variant without deciding its
/// wire mapping is a compile error, and `tests/serve_net.rs` pins
/// each pair so it cannot drift silently.
pub fn http_status(e: &ServeError) -> (u16, &'static str) {
    match e {
        ServeError::InvalidConfig { .. } => (500, "Internal Server Error"),
        ServeError::PoolFull { .. } => (503, "Service Unavailable"),
        ServeError::Backpressure { .. } => (429, "Too Many Requests"),
        ServeError::UnknownStream => (404, "Not Found"),
        ServeError::StreamBusy => (409, "Conflict"),
        ServeError::NoOutput => (409, "Conflict"),
        ServeError::BadRow { .. } => (400, "Bad Request"),
        ServeError::NonFinite { .. } => (422, "Unprocessable Entity"),
        ServeError::Expired => (410, "Gone"),
        ServeError::Faulted => (500, "Internal Server Error"),
        ServeError::Session(_) => (500, "Internal Server Error"),
    }
}

/// The `Retry-After` value (in scheduler ticks; documented in the
/// module docs) for errors that are worth retrying on a timer.
pub fn retry_after_ticks(e: &ServeError) -> Option<u64> {
    match e {
        ServeError::Backpressure { retry_after_ticks, .. } => Some((*retry_after_ticks).max(1)),
        ServeError::PoolFull { .. } => Some(1),
        _ => None,
    }
}

/// Serialize the machine-readable error body shared by plain error
/// responses, in-stream `event: error` frames, and the router's own
/// error answers (`serve::router` reuses this so clients see one
/// error shape fleet-wide).
pub(crate) fn error_json(
    buf: &mut String,
    code: &str,
    message: &str,
    retryable: bool,
    retry: Option<u64>,
) {
    use std::fmt::Write as _;
    buf.clear();
    buf.push_str("{\"error\":");
    wire::write_str(buf, code);
    buf.push_str(",\"message\":");
    wire::write_str(buf, message);
    let _ = write!(buf, ",\"retryable\":{retryable}");
    if let Some(t) = retry {
        let _ = write!(buf, ",\"retry_after_ticks\":{t}");
    }
    buf.push('}');
}

/// Gateway readiness, reported by `GET /healthz` and stored as one
/// atomic byte in [`Shared`].
const READY_STARTING: u8 = 0;
const READY_READY: u8 = 1;
const READY_DRAINING: u8 = 2;

struct Shared {
    ingress: SyncSender<Cmd>,
    spec: EngineSpec,
    serve: ServeConfig,
    /// Stable node identity (see [`derive_node_id`]).
    node_id: String,
    stop: AtomicBool,
    /// `starting` → `ready` → `draining`: workers consult this before
    /// touching the engine, so `healthz` answers during recovery and
    /// stream opens are refused the moment a drain begins.
    readiness: AtomicU8,
    /// `POST /admin/drain` was received; the process supervisor (the
    /// CLI's signal loop) polls [`Server::drain_requested`] and calls
    /// [`Server::drain`].
    drain_requested: AtomicBool,
}

impl Shared {
    fn readiness(&self) -> u8 {
        self.readiness.load(Ordering::SeqCst)
    }
}

/// A running gateway: engine thread + worker pool, shut down
/// explicitly (or on drop).
pub struct Server {
    addr: SocketAddr,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    engine: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind, start the engine thread (building the attention session
    /// on it, and — with `durability` — recovering from the data dir),
    /// and start the worker pool. Fails fast on a bad address, an
    /// invalid [`ServeConfig`], a session the backend rejects, or a
    /// durable store that cannot be trusted (structural corruption is
    /// a startup error, never a partial recovery).
    ///
    /// Workers accept connections while the engine is still
    /// recovering: `healthz` answers `503 starting` during that
    /// window, and flips to `200 ready` only once recovery completes
    /// — so when `start` returns, the listener is accepting and the
    /// engine is fully recovered.
    pub fn start(
        net: NetConfig,
        spec: EngineSpec,
        serve: ServeConfig,
        resilience: ResilienceConfig,
        durability: Option<DurabilityConfig>,
    ) -> Result<Server> {
        serve.validate().map_err(|e| anyhow!(e))?;
        let node_id = net.node_id.clone().unwrap_or_else(|| {
            let salt = durability
                .as_ref()
                .map(|d| d.dir.to_string_lossy().into_owned())
                .unwrap_or_else(|| net.addr.clone());
            derive_node_id(spec.seed, &salt)
        });
        let listener =
            TcpListener::bind(&net.addr).with_context(|| format!("binding {}", net.addr))?;
        let addr = listener.local_addr()?;
        let (ingress, rx) = sync_channel(net.queue_depth.max(1));
        let (ready_tx, ready_rx) = channel();
        let engine_spec = spec.clone();
        let engine = std::thread::Builder::new()
            .name("serve-engine".into())
            .spawn(move || engine::run(engine_spec, serve, resilience, durability, rx, ready_tx))?;
        let shared = Arc::new(Shared {
            ingress,
            spec,
            serve,
            node_id,
            stop: AtomicBool::new(false),
            readiness: AtomicU8::new(READY_STARTING),
            drain_requested: AtomicBool::new(false),
        });
        let mut workers = Vec::with_capacity(net.workers.max(1));
        for w in 0..net.workers.max(1) {
            let listener = listener.try_clone()?;
            let shared = Arc::clone(&shared);
            let http = net.http;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("serve-worker-{w}"))
                    .spawn(move || worker_loop(listener, shared, http))?,
            );
        }
        let mut server = Server { addr, shared, workers, engine: Some(engine) };
        let startup = match ready_rx.recv() {
            Ok(Ok(())) => Ok(()),
            Ok(Err(msg)) => Err(anyhow!("serve engine failed to start: {msg}")),
            Err(_) => Err(anyhow!("serve engine died during startup")),
        };
        if let Err(e) = startup {
            server.stop_all();
            return Err(e);
        }
        server.shared.readiness.store(READY_READY, Ordering::SeqCst);
        Ok(server)
    }

    /// The bound address (resolves `:0` to the kernel-assigned port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Whether a client asked for a drain via `POST /admin/drain`.
    /// The process supervisor polls this and calls [`Server::drain`].
    pub fn drain_requested(&self) -> bool {
        self.shared.drain_requested.load(Ordering::SeqCst)
    }

    /// Flip the gateway to draining without stopping anything yet: new
    /// stream opens answer `503 draining` + `Retry-After`, `healthz`
    /// reports `draining`, in-flight work keeps running. Idempotent;
    /// [`Server::drain`] calls this first.
    pub fn begin_drain(&self) {
        self.shared.readiness.store(READY_DRAINING, Ordering::SeqCst);
    }

    /// Graceful drain: refuse new streams, let in-flight decodes
    /// finish, checkpoint the remaining state to the data dir (when
    /// durability is on), then stop workers and return. The caller
    /// exits 0 afterwards.
    pub fn drain(mut self) {
        self.begin_drain();
        let _ = self.shared.ingress.send(Cmd::Drain);
        if let Some(engine) = self.engine.take() {
            let _ = engine.join();
        }
        self.stop_all();
    }

    /// Stop accepting, drain the workers, and stop the engine.
    pub fn shutdown(mut self) {
        self.stop_all();
    }

    fn stop_all(&mut self) {
        if self.shared.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // wake every accept-blocked worker with a throwaway connect
        for _ in 0..self.workers.len() {
            let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(250));
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let _ = self.shared.ingress.send(Cmd::Shutdown);
        if let Some(engine) = self.engine.take() {
            let _ = engine.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop_all();
    }
}

/// One worker: accept connections and serve keep-alive request loops
/// until the stop flag flips.
fn worker_loop(listener: TcpListener, shared: Arc<Shared>, http: HttpConfig) {
    obs::register_thread();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        // span from accept *returning* to the connection being ready —
        // wrapping the blocking accept itself would record idle time
        let obs_on = obs::enabled();
        let t_accept = if obs_on { obs::now_ns() } else { 0 };
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let _ = stream.set_nodelay(true);
        let mut conn = Conn::new(stream, http);
        conn.set_node_id(&shared.node_id);
        if obs_on {
            obs::record_span(Stage::Accept, t_accept, obs::now_ns(), 0);
        }
        serve_connection(conn, &shared);
    }
}

/// The keep-alive request loop for one connection. Any request-read
/// error answers its status (when it has one) and closes.
fn serve_connection(mut conn: Conn, shared: &Shared) {
    let mut body = TokenBody::default();
    let mut scratch = String::new();
    loop {
        let req = match conn.read_request() {
            Ok(req) => req,
            Err(e) => {
                if let Some((status, reason, code)) = e.status() {
                    error_json(&mut scratch, code, &e.detail(), false, None);
                    let _ = conn.write_response(status, reason, "application/json", &scratch, &[]);
                }
                return;
            }
        };
        let keep_alive = req.keep_alive;
        // tag this worker thread's spans (SSE writes, etc.) with the
        // request id until the next request replaces it
        obs::set_request_id(conn.request_id_hash());
        let served = dispatch(&mut conn, &req, shared, &mut body, &mut scratch);
        obs::set_request_id(0);
        if served.is_err() || !keep_alive {
            return;
        }
    }
}

/// What `/v1/streams/...` names: the stream plus an optional action.
enum Route {
    Health,
    Metrics,
    Spec,
    Streams,
    Drain,
    /// `POST /v1/streams/import` — migration destination side.
    Import,
    Stream { sid: u64, action: Option<StreamAction> },
    NotFound,
}

enum StreamAction {
    Prefill,
    Decode,
    ArmFault,
    Hibernate,
    Export,
}

fn parse_route(path: &str) -> Route {
    match path {
        "/healthz" => return Route::Health,
        "/metrics" => return Route::Metrics,
        "/v1/spec" => return Route::Spec,
        "/v1/streams" => return Route::Streams,
        "/admin/drain" => return Route::Drain,
        _ => {}
    }
    let Some(rest) = path.strip_prefix("/v1/streams/") else {
        return Route::NotFound;
    };
    if rest == "import" {
        return Route::Import;
    }
    let (id_part, action_part) = match rest.split_once('/') {
        Some((id, action)) => (id, Some(action)),
        None => (rest, None),
    };
    let Some(sid) = id_part.strip_prefix("s-").and_then(|s| s.parse::<u64>().ok()) else {
        return Route::NotFound;
    };
    let action = match action_part {
        None => None,
        Some("prefill") => Some(StreamAction::Prefill),
        Some("decode") => Some(StreamAction::Decode),
        Some("arm_fault") => Some(StreamAction::ArmFault),
        Some("hibernate") => Some(StreamAction::Hibernate),
        Some("export") => Some(StreamAction::Export),
        Some(_) => return Route::NotFound,
    };
    Route::Stream { sid, action }
}

/// Answer one request. `Err` means the transport broke (the
/// connection closes); protocol-level failures are proper responses.
fn dispatch(
    conn: &mut Conn,
    req: &Request,
    shared: &Shared,
    body: &mut TokenBody,
    scratch: &mut String,
) -> Result<(), HttpError> {
    let route = parse_route(conn.path(req));
    match (req.method, route) {
        (Method::Get, Route::Health) => health(conn, shared, scratch),
        (Method::Get, Route::Metrics) => metrics(conn, shared, scratch),
        (Method::Get, Route::Spec) => spec(conn, shared),
        (Method::Post, Route::Streams) => open_stream(conn, shared, scratch),
        (Method::Post, Route::Drain) => admin_drain(conn, shared),
        (Method::Get, Route::Stream { sid, action: None }) => {
            stream_status(conn, shared, sid, scratch)
        }
        (Method::Post, Route::Stream { sid, action: Some(StreamAction::Prefill) }) => {
            prefill(conn, req, shared, sid, body, scratch)
        }
        (Method::Post, Route::Stream { sid, action: Some(StreamAction::Decode) }) => {
            decode(conn, req, shared, sid, body, scratch)
        }
        (Method::Post, Route::Stream { sid, action: Some(StreamAction::ArmFault) }) => {
            simple_cmd(conn, shared, scratch, |reply| Cmd::ArmFault { sid, reply })
        }
        (Method::Post, Route::Stream { sid, action: Some(StreamAction::Hibernate) }) => {
            simple_cmd(conn, shared, scratch, |reply| Cmd::Hibernate { sid, reply })
        }
        (Method::Delete, Route::Stream { sid, action: None }) => {
            simple_cmd(conn, shared, scratch, |reply| Cmd::Close { sid, reply })
        }
        (Method::Get, Route::Stream { sid, action: Some(StreamAction::Export) }) => {
            export_stream(conn, shared, sid, scratch)
        }
        (Method::Post, Route::Import) => import_stream(conn, req, shared, scratch),
        _ => {
            error_json(scratch, "not_found", "no such route", false, None);
            conn.write_response(404, "Not Found", "application/json", scratch, &[])
        }
    }
}

/// Answer an enqueue failure (bounded queue full / engine gone).
fn ingress_error(conn: &mut Conn, e: IngressError, scratch: &mut String) -> Result<(), HttpError> {
    match e {
        IngressError::Full => {
            error_json(scratch, "ingress_full", "engine ingress queue is full", true, Some(1));
            conn.write_response(
                429,
                "Too Many Requests",
                "application/json",
                scratch,
                &[("Retry-After", "1")],
            )
        }
        IngressError::Down => {
            error_json(scratch, "engine_down", "engine thread is not running", false, None);
            conn.write_response(503, "Service Unavailable", "application/json", scratch, &[])
        }
    }
}

/// Answer a typed [`ServeError`] as its mapped status + error body.
fn serve_error(conn: &mut Conn, e: &ServeError, scratch: &mut String) -> Result<(), HttpError> {
    let (status, reason) = http_status(e);
    let retry = retry_after_ticks(e);
    error_json(scratch, e.code(), &e.to_string(), e.is_retryable(), retry);
    let ticks;
    let mut extra: Vec<(&str, &str)> = Vec::new();
    if let Some(t) = retry {
        ticks = t.to_string();
        extra.push(("Retry-After", &ticks));
    }
    conn.write_response(status, reason, "application/json", scratch, &extra)
}

fn engine_gone(conn: &mut Conn, scratch: &mut String) -> Result<(), HttpError> {
    error_json(scratch, "engine_down", "engine thread is not running", false, None);
    conn.write_response(503, "Service Unavailable", "application/json", scratch, &[])
}

/// The readiness state machine behind `GET /healthz`: `starting`
/// (engine still constructing/recovering) and `draining` answer `503`
/// immediately — no engine round trip, so health stays observable even
/// while the engine replays a long journal — and `ready` answers `200`
/// with the live engine/telemetry snapshot.
fn health(conn: &mut Conn, shared: &Shared, scratch: &mut String) -> Result<(), HttpError> {
    match shared.readiness() {
        READY_STARTING => {
            conn.write_response(
                503,
                "Service Unavailable",
                "application/json",
                "{\"status\":\"starting\"}",
                &[("Retry-After", "1")],
            )
        }
        READY_DRAINING => conn.write_response(
            503,
            "Service Unavailable",
            "application/json",
            "{\"status\":\"draining\"}",
            &[],
        ),
        _ => {
            let (reply, rx) = channel();
            if let Err(e) = engine::try_enqueue(&shared.ingress, Cmd::Health { reply }) {
                return ingress_error(conn, e, scratch);
            }
            match rx.recv() {
                Err(_) => engine_gone(conn, scratch),
                Ok(h) => {
                    let doc = Value::obj(vec![
                        ("status", Value::str("ready")),
                        ("node_id", Value::str(shared.node_id.clone())),
                        ("tick_no", Value::num(h.tick_no as f64)),
                        ("active_streams", Value::num(h.active_streams as f64)),
                        ("hibernated_streams", Value::num(h.hibernated_streams as f64)),
                        ("decode_jobs", Value::num(h.jobs as f64)),
                        ("telemetry", h.telemetry.to_json()),
                    ]);
                    conn.write_response(200, "OK", "application/json", &doc.to_string(), &[])
                }
            }
        }
    }
}

/// `GET /metrics`: Prometheus text exposition ([`obs::prom`]) — every
/// [`Telemetry`](crate::serve::Telemetry) counter, the per-stage
/// duration histograms, durability counters, and HTTP response
/// classes, plus live engine gauges from the same health snapshot the
/// `/healthz` handler uses. Answers `503` while the engine is still
/// starting (recovering), like `/healthz`.
fn metrics(conn: &mut Conn, shared: &Shared, scratch: &mut String) -> Result<(), HttpError> {
    if shared.readiness() == READY_STARTING {
        return conn.write_response(
            503,
            "Service Unavailable",
            "application/json",
            "{\"status\":\"starting\"}",
            &[("Retry-After", "1")],
        );
    }
    let (reply, rx) = channel();
    if let Err(e) = engine::try_enqueue(&shared.ingress, Cmd::Health { reply }) {
        return ingress_error(conn, e, scratch);
    }
    match rx.recv() {
        Err(_) => engine_gone(conn, scratch),
        Ok(h) => {
            let body = obs::prom::render(
                &h.telemetry,
                &[
                    (
                        "macformer_active_streams",
                        "Streams currently holding a pool slot.",
                        h.active_streams as f64,
                    ),
                    (
                        "macformer_hibernated_streams",
                        "Streams hibernated to the spill arena.",
                        h.hibernated_streams as f64,
                    ),
                    (
                        "macformer_decode_jobs",
                        "Decode jobs in flight on the engine.",
                        h.jobs as f64,
                    ),
                    ("macformer_tick_no", "Engine tick counter.", h.tick_no as f64),
                ],
            );
            conn.write_response(200, "OK", obs::prom::CONTENT_TYPE, &body, &[])
        }
    }
}

/// `POST /admin/drain`: flip to draining and flag the process
/// supervisor. The actual teardown (finish jobs, final checkpoint,
/// exit 0) runs on the CLI thread via [`Server::drain`]; this handler
/// only makes the intent durable in [`Shared`] so new opens start
/// refusing immediately.
fn admin_drain(conn: &mut Conn, shared: &Shared) -> Result<(), HttpError> {
    shared.readiness.store(READY_DRAINING, Ordering::SeqCst);
    shared.drain_requested.store(true, Ordering::SeqCst);
    conn.write_response(200, "OK", "application/json", "{\"draining\":true}", &[])
}

/// `GET /v1/streams/s-N`: lifecycle + folded-token count — how a
/// reconnecting client finds where to resume after a crash-restart.
fn stream_status(
    conn: &mut Conn,
    shared: &Shared,
    sid: u64,
    scratch: &mut String,
) -> Result<(), HttpError> {
    let (reply, rx) = channel();
    if let Err(e) = engine::try_enqueue(&shared.ingress, Cmd::Status { sid, reply }) {
        return ingress_error(conn, e, scratch);
    }
    match rx.recv() {
        Err(_) => engine_gone(conn, scratch),
        Ok(Err(e)) => serve_error(conn, &e, scratch),
        Ok(Ok((status, tokens))) => {
            use std::fmt::Write as _;
            let name = match status {
                StreamStatus::Active => "active",
                StreamStatus::Hibernated => "hibernated",
                StreamStatus::Faulted => "faulted",
                StreamStatus::Expired => "expired",
            };
            scratch.clear();
            let _ = write!(
                scratch,
                "{{\"stream\":\"s-{sid}\",\"status\":\"{name}\",\"tokens\":{tokens}}}"
            );
            conn.write_response(200, "OK", "application/json", scratch, &[])
        }
    }
}

fn spec(conn: &mut Conn, shared: &Shared) -> Result<(), HttpError> {
    let doc = Value::obj(vec![
        ("kernel", Value::str(shared.spec.kernel.name())),
        ("backend", Value::str(shared.spec.backend.to_string())),
        ("head_dim", Value::num(shared.spec.head_dim as f64)),
        ("dv", Value::num(shared.spec.dv as f64)),
        ("num_features", Value::num(shared.spec.num_features as f64)),
        ("seed", Value::num(shared.spec.seed as f64)),
        ("max_streams", Value::num(shared.serve.max_streams as f64)),
        ("max_pending", Value::num(shared.serve.pending_bound() as f64)),
    ]);
    conn.write_response(200, "OK", "application/json", &doc.to_string(), &[])
}

fn open_stream(conn: &mut Conn, shared: &Shared, scratch: &mut String) -> Result<(), HttpError> {
    if shared.readiness() == READY_DRAINING {
        // retryable by design: the client backs off and lands on the
        // replacement instance (or this one after a restart)
        error_json(scratch, "draining", "server is draining; retry later", true, Some(1));
        return conn.write_response(
            503,
            "Service Unavailable",
            "application/json",
            scratch,
            &[("Retry-After", "1")],
        );
    }
    let (reply, rx) = channel();
    if let Err(e) = engine::try_enqueue(&shared.ingress, Cmd::Open { reply }) {
        return ingress_error(conn, e, scratch);
    }
    match rx.recv() {
        Err(_) => engine_gone(conn, scratch),
        Ok(Err(e)) => serve_error(conn, &e, scratch),
        Ok(Ok(sid)) => {
            scratch.clear();
            scratch.push_str("{\"stream\":\"s-");
            scratch.push_str(&sid.to_string());
            scratch.push_str("\"}");
            conn.write_response(201, "Created", "application/json", scratch, &[])
        }
    }
}

/// Content type of an exported MACS state record.
pub const STATE_CONTENT_TYPE: &str = "application/x-macformer-state";

/// `GET /v1/streams/s-N/export`: snapshot the stream's versioned state
/// record and close it here — a **move**, the live-migration source
/// side. Busy streams (in-flight decode, staged token) answer `409`
/// (retryable once the job drains).
fn export_stream(
    conn: &mut Conn,
    shared: &Shared,
    sid: u64,
    scratch: &mut String,
) -> Result<(), HttpError> {
    let (reply, rx) = channel();
    if let Err(e) = engine::try_enqueue(&shared.ingress, Cmd::Export { sid, reply }) {
        return ingress_error(conn, e, scratch);
    }
    match rx.recv() {
        Err(_) => engine_gone(conn, scratch),
        Ok(Err(e)) => serve_error(conn, &e, scratch),
        Ok(Ok(exp)) => conn.write_response_bytes(
            200,
            "OK",
            STATE_CONTENT_TYPE,
            &exp.record,
            &[("x-macformer-hibernated", if exp.hibernated { "1" } else { "0" })],
        ),
    }
}

/// `POST /v1/streams/import`: adopt a stream under a fresh wire id —
/// the migration destination side. Two body forms: raw MACS record
/// bytes (live migration), or JSON `{"dir":"...","stream":"s-N"}`
/// to recover one stream from a dead node's durable store on shared
/// storage (checkpoint record + journal-tail replay through the
/// normal fold path). Refused while draining, like an open.
fn import_stream(
    conn: &mut Conn,
    req: &Request,
    shared: &Shared,
    scratch: &mut String,
) -> Result<(), HttpError> {
    if shared.readiness() == READY_DRAINING {
        error_json(scratch, "draining", "server is draining; retry later", true, Some(1));
        return conn.write_response(
            503,
            "Service Unavailable",
            "application/json",
            scratch,
            &[("Retry-After", "1")],
        );
    }
    let body = conn.body(req);
    let source = if body.first() == Some(&b'{') {
        match parse_import_json(body) {
            Ok(src) => src,
            Err(msg) => {
                error_json(scratch, "bad_body", msg, false, None);
                return conn.write_response(400, "Bad Request", "application/json", scratch, &[]);
            }
        }
    } else if body.is_empty() {
        error_json(scratch, "bad_body", "empty import body", false, None);
        return conn.write_response(400, "Bad Request", "application/json", scratch, &[]);
    } else {
        engine::ImportSource::Record { record: body.to_vec(), hibernated: false }
    };
    let (reply, rx) = channel();
    if let Err(e) = engine::try_enqueue(&shared.ingress, Cmd::Import { source, reply }) {
        return ingress_error(conn, e, scratch);
    }
    match rx.recv() {
        Err(_) => engine_gone(conn, scratch),
        Ok(Err(e)) => serve_error(conn, &e, scratch),
        Ok(Ok(sid)) => {
            scratch.clear();
            scratch.push_str("{\"stream\":\"s-");
            scratch.push_str(&sid.to_string());
            scratch.push_str("\"}");
            conn.write_response(201, "Created", "application/json", scratch, &[])
        }
    }
}

/// Parse the JSON (dead-store) import form.
fn parse_import_json(body: &[u8]) -> Result<engine::ImportSource, &'static str> {
    let mut scan = wire::Scan::object(body).map_err(|_| "malformed JSON")?;
    let mut dir: Option<String> = None;
    let mut sid: Option<u64> = None;
    while let Some(key) = scan.next_key().map_err(|_| "malformed JSON")? {
        match key {
            b"dir" => {
                dir = Some(scan.str_value("dir").map_err(|_| "bad \"dir\"")?.to_string());
            }
            b"stream" => {
                let s = scan.str_value("stream").map_err(|_| "bad \"stream\"")?;
                sid = s.strip_prefix("s-").and_then(|n| n.parse().ok());
                if sid.is_none() {
                    return Err("\"stream\" must be \"s-N\"");
                }
            }
            _ => scan.skip_value().map_err(|_| "malformed JSON")?,
        }
    }
    match (dir, sid) {
        (Some(dir), Some(sid)) => {
            Ok(engine::ImportSource::Store { dir: std::path::PathBuf::from(dir), sid })
        }
        _ => Err("import JSON needs \"dir\" and \"stream\""),
    }
}

/// Route a one-shot stream command (arm_fault / hibernate / close).
fn simple_cmd(
    conn: &mut Conn,
    shared: &Shared,
    scratch: &mut String,
    make: impl FnOnce(std::sync::mpsc::Sender<Result<(), ServeError>>) -> Cmd,
) -> Result<(), HttpError> {
    let (reply, rx) = channel();
    if let Err(e) = engine::try_enqueue(&shared.ingress, make(reply)) {
        return ingress_error(conn, e, scratch);
    }
    match rx.recv() {
        Err(_) => engine_gone(conn, scratch),
        Ok(Err(e)) => serve_error(conn, &e, scratch),
        Ok(Ok(())) => {
            conn.write_response(200, "OK", "application/json", "{\"ok\":true}", &[])
        }
    }
}

fn prefill(
    conn: &mut Conn,
    req: &Request,
    shared: &Shared,
    sid: u64,
    body: &mut TokenBody,
    scratch: &mut String,
) -> Result<(), HttpError> {
    if let Err(e) = body.parse_into(conn.body(req)) {
        error_json(scratch, "bad_body", &e.to_string(), false, None);
        return conn.write_response(400, "Bad Request", "application/json", scratch, &[]);
    }
    let (reply, rx) = channel();
    let cmd = Cmd::Prefill {
        sid,
        q: std::mem::take(&mut body.q),
        k: std::mem::take(&mut body.k),
        v: std::mem::take(&mut body.v),
        reply,
        req: conn.request_id_hash(),
        enq_ns: if obs::enabled() { obs::now_ns() } else { 0 },
    };
    if let Err(e) = engine::try_enqueue(&shared.ingress, cmd) {
        return ingress_error(conn, e, scratch);
    }
    match rx.recv() {
        Err(_) => engine_gone(conn, scratch),
        Ok(Err(e)) => serve_error(conn, &e, scratch),
        Ok(Ok((n, last))) => {
            use std::fmt::Write as _;
            scratch.clear();
            let _ = write!(scratch, "{{\"tokens\":{n},\"out\":");
            wire::write_f32_array(scratch, &last);
            scratch.push('}');
            conn.write_response(200, "OK", "application/json", scratch, &[])
        }
    }
}

fn decode(
    conn: &mut Conn,
    req: &Request,
    shared: &Shared,
    sid: u64,
    body: &mut TokenBody,
    scratch: &mut String,
) -> Result<(), HttpError> {
    if let Err(e) = body.parse_into(conn.body(req)) {
        error_json(scratch, "bad_body", &e.to_string(), false, None);
        return conn.write_response(400, "Bad Request", "application/json", scratch, &[]);
    }
    let (events, rx) = channel();
    let cmd = Cmd::Decode {
        sid,
        q: std::mem::take(&mut body.q),
        k: std::mem::take(&mut body.k),
        v: std::mem::take(&mut body.v),
        events,
        req: conn.request_id_hash(),
        enq_ns: if obs::enabled() { obs::now_ns() } else { 0 },
    };
    if let Err(e) = engine::try_enqueue(&shared.ingress, cmd) {
        return ingress_error(conn, e, scratch);
    }
    // first event decides the status line
    let first = match rx.recv() {
        Err(_) => return engine_gone(conn, scratch),
        Ok(Event::Reject(e)) => return serve_error(conn, &e, scratch),
        Ok(ev) => ev,
    };
    conn.begin_chunked("text/event-stream")?;
    let mut frame = String::new();
    let mut ev = Some(first);
    let mut served = 0usize;
    loop {
        let event = match ev.take() {
            Some(ev) => ev,
            None => match rx.recv() {
                Ok(ev) => ev,
                Err(_) => {
                    error_json(scratch, "engine_down", "engine stopped mid-stream", false, None);
                    frame.clear();
                    frame.push_str("event: error\ndata: ");
                    frame.push_str(scratch);
                    frame.push_str("\n\n");
                    conn.write_chunk(&frame)?;
                    break;
                }
            },
        };
        match event {
            Event::Token { t, out } => {
                use std::fmt::Write as _;
                frame.clear();
                let _ = write!(frame, "data: {{\"t\":{t},\"out\":");
                wire::write_f32_array(&mut frame, &out);
                frame.push_str("}\n\n");
                conn.write_chunk(&frame)?;
                served += 1;
            }
            Event::Done => {
                use std::fmt::Write as _;
                frame.clear();
                let _ = write!(frame, "event: done\ndata: {{\"tokens\":{served}}}\n\n");
                conn.write_chunk(&frame)?;
                break;
            }
            Event::Error(e) | Event::Reject(e) => {
                error_json(scratch, e.code(), &e.to_string(), e.is_retryable(), None);
                frame.clear();
                frame.push_str("event: error\ndata: ");
                frame.push_str(scratch);
                frame.push_str("\n\n");
                conn.write_chunk(&frame)?;
                break;
            }
        }
    }
    conn.end_chunked()
}
