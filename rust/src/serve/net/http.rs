//! Minimal HTTP/1.1 connection handling: incremental request reading
//! with hard limits, and plain + chunked (SSE) response writing.
//!
//! This is deliberately not a general HTTP implementation — it is the
//! exact subset the serve API needs, hardened against the classic
//! abuse shapes:
//!
//! * **Header limit** — a request head larger than
//!   [`HttpConfig::max_head`] is `431` and the connection closes.
//! * **Body limit** — a `Content-Length` past
//!   [`HttpConfig::max_body`] is `413` *before* any body byte is read.
//! * **Read deadline** — one wall-clock budget
//!   ([`HttpConfig::read_timeout`]) covers the whole request
//!   (head + body), so a slow-loris drip cannot hold a worker past it:
//!   the socket read timeout is re-armed with the *remaining* budget
//!   each iteration. A connection that goes quiet *between* requests
//!   is simply closed (keep-alive idle-out), not errored.
//!
//! All failures are typed [`HttpError`]s that map to a 4xx close-delta
//! response in the dispatch layer — never a panic, never an unbounded
//! buffer.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::serve::obs::{self, Stage};

/// Longest `x-request-id` value the server retains (longer values are
/// truncated; the bound keeps the per-connection buffer fixed-size).
pub const MAX_REQUEST_ID: usize = 64;

/// Connection-level limits. Defaults are generous for the API's real
/// payloads and tight against abuse.
#[derive(Debug, Clone, Copy)]
pub struct HttpConfig {
    /// Maximum request-head bytes (request line + headers).
    pub max_head: usize,
    /// Maximum request-body bytes (declared or actual).
    pub max_body: usize,
    /// Wall-clock budget for reading one full request.
    pub read_timeout: Duration,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            max_head: 8 * 1024,
            max_body: 4 * 1024 * 1024,
            read_timeout: Duration::from_secs(5),
        }
    }
}

/// Why a request could not be read. Each variant carries its HTTP
/// answer; `Closed` means the peer hung up cleanly between requests.
#[derive(Debug)]
pub enum HttpError {
    /// Head grew past [`HttpConfig::max_head`] → 431.
    HeadTooLarge,
    /// Declared body length past [`HttpConfig::max_body`] → 413.
    BodyTooLarge { limit: usize },
    /// A body-bearing method without `Content-Length` (or with
    /// `Transfer-Encoding`, which this server does not accept on
    /// requests) → 411.
    LengthRequired,
    /// Malformed request line / headers / truncated body → 400.
    BadRequest(&'static str),
    /// The read deadline elapsed mid-request (slow loris) → 408.
    Timeout,
    /// Clean disconnect with no request bytes pending.
    Closed,
    /// Transport error; the connection is dropped silently.
    Io(std::io::Error),
}

impl HttpError {
    /// `(status, reason, machine code)` for the variants that get an
    /// HTTP answer; `None` for the ones that just drop the connection.
    pub fn status(&self) -> Option<(u16, &'static str, &'static str)> {
        match self {
            HttpError::HeadTooLarge => {
                Some((431, "Request Header Fields Too Large", "head_too_large"))
            }
            HttpError::BodyTooLarge { .. } => Some((413, "Payload Too Large", "body_too_large")),
            HttpError::LengthRequired => Some((411, "Length Required", "length_required")),
            HttpError::BadRequest(_) => Some((400, "Bad Request", "bad_request")),
            HttpError::Timeout => Some((408, "Request Timeout", "timeout")),
            HttpError::Closed | HttpError::Io(_) => None,
        }
    }

    /// Human detail for the error body.
    pub fn detail(&self) -> String {
        match self {
            HttpError::HeadTooLarge => "request head exceeds the configured limit".into(),
            HttpError::BodyTooLarge { limit } => {
                format!("request body exceeds the {limit}-byte limit")
            }
            HttpError::LengthRequired => {
                "a body-bearing request needs Content-Length (chunked requests not accepted)"
                    .into()
            }
            HttpError::BadRequest(what) => (*what).into(),
            HttpError::Timeout => "request not completed within the read deadline".into(),
            HttpError::Closed => "connection closed".into(),
            HttpError::Io(e) => e.to_string(),
        }
    }
}

/// Request method — only what the API routes on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Get,
    Post,
    Delete,
    Other,
}

/// One parsed request: method + borrowed ranges into the connection
/// buffer (the head and body are never copied out).
pub struct Request {
    pub method: Method,
    path: (usize, usize),
    body: (usize, usize),
    pub keep_alive: bool,
}

/// One client connection: the socket plus reusable read/write buffers
/// (steady-state request handling re-reads into the same allocations).
pub struct Conn {
    stream: TcpStream,
    cfg: HttpConfig,
    buf: Vec<u8>,
    out: String,
    /// Sanitized `x-request-id` bytes of the request being served
    /// (printable ASCII only; fixed buffer, no allocation per request).
    req_id: [u8; MAX_REQUEST_ID],
    req_id_len: usize,
    /// FNV hash of the id ([`obs::hash_request_id`]; 0 = none).
    req_hash: u64,
    /// This node's stable id, stamped as `x-macformer-node` on every
    /// response (empty = header suppressed) so multi-node clients can
    /// tell backends apart through a router.
    node_id: String,
}

impl Conn {
    pub fn new(stream: TcpStream, cfg: HttpConfig) -> Conn {
        Conn {
            stream,
            cfg,
            buf: Vec::with_capacity(4096),
            out: String::with_capacity(1024),
            req_id: [0; MAX_REQUEST_ID],
            req_id_len: 0,
            req_hash: 0,
            node_id: String::new(),
        }
    }

    /// Stamp every response from this connection with
    /// `x-macformer-node: <id>` (empty clears the header).
    pub fn set_node_id(&mut self, id: &str) {
        self.node_id.clear();
        self.node_id.push_str(id);
    }

    /// The sanitized `x-request-id` of the current request (empty when
    /// the client sent none).
    pub fn request_id(&self) -> &[u8] {
        &self.req_id[..self.req_id_len]
    }

    /// Hashed request id ([`obs::hash_request_id`]; 0 = none) —
    /// threaded through the engine so spans across threads correlate.
    pub fn request_id_hash(&self) -> u64 {
        self.req_hash
    }

    /// The request path for `req` (ASCII; enforced during parse).
    pub fn path<'a>(&'a self, req: &Request) -> &'a str {
        std::str::from_utf8(&self.buf[req.path.0..req.path.1]).unwrap_or("")
    }

    /// The request body for `req`.
    pub fn body<'a>(&'a self, req: &Request) -> &'a [u8] {
        &self.buf[req.body.0..req.body.1]
    }

    /// Read one full request (head + body) within the deadline.
    pub fn read_request(&mut self) -> Result<Request, HttpError> {
        self.buf.clear();
        self.req_id_len = 0;
        self.req_hash = 0;
        let start = Instant::now();
        let obs_on = obs::enabled();
        // The head span opens at the first byte, not at function entry:
        // a keep-alive connection sits idle here between requests, and
        // that wait is not parse time.
        let mut head_t0 = 0u64;

        // --- head: read until \r\n\r\n, bounded by max_head ---
        let head_end = loop {
            if let Some(pos) = find_head_end(&self.buf) {
                // enforce the limit even when the whole head landed in
                // one read, so it cannot be dodged by fast delivery
                if pos > self.cfg.max_head {
                    return Err(HttpError::HeadTooLarge);
                }
                break pos;
            }
            if self.buf.len() > self.cfg.max_head {
                return Err(HttpError::HeadTooLarge);
            }
            self.fill(start, self.buf.is_empty())?;
            if obs_on && head_t0 == 0 && !self.buf.is_empty() {
                head_t0 = obs::now_ns();
            }
        };

        // --- parse request line + the headers we honor ---
        let head = &self.buf[..head_end];
        let mut lines = head.split(|&b| b == b'\n').map(|l| l.strip_suffix(b"\r").unwrap_or(l));
        let req_line = lines.next().ok_or(HttpError::BadRequest("empty request"))?;
        let req_line =
            std::str::from_utf8(req_line).map_err(|_| HttpError::BadRequest("non-ASCII head"))?;
        let mut parts = req_line.split(' ');
        let method = match parts.next() {
            Some("GET") => Method::Get,
            Some("POST") => Method::Post,
            Some("DELETE") => Method::Delete,
            Some(m) if !m.is_empty() && m.chars().all(|c| c.is_ascii_uppercase()) => Method::Other,
            _ => return Err(HttpError::BadRequest("malformed request line")),
        };
        let path = parts.next().ok_or(HttpError::BadRequest("missing request path"))?;
        let version = parts.next().ok_or(HttpError::BadRequest("missing HTTP version"))?;
        if !version.starts_with("HTTP/1.") || parts.next().is_some() {
            return Err(HttpError::BadRequest("malformed request line"));
        }
        let path_start = req_line.find(' ').expect("split found a space") + 1;
        let path_range = (path_start, path_start + path.len());

        let mut content_length: Option<usize> = None;
        let mut keep_alive = version == "HTTP/1.1";
        let mut expect_continue = false;
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let Ok(line) = std::str::from_utf8(line) else {
                return Err(HttpError::BadRequest("non-ASCII header"));
            };
            let Some((name, value)) = line.split_once(':') else {
                return Err(HttpError::BadRequest("malformed header"));
            };
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length =
                    Some(value.parse().map_err(|_| HttpError::BadRequest("bad Content-Length"))?);
            } else if name.eq_ignore_ascii_case("transfer-encoding") {
                // requests must be Content-Length framed
                return Err(HttpError::LengthRequired);
            } else if name.eq_ignore_ascii_case("connection") {
                if value.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if value.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            } else if name.eq_ignore_ascii_case("expect")
                && value.eq_ignore_ascii_case("100-continue")
            {
                expect_continue = true;
            } else if name.eq_ignore_ascii_case("x-request-id") {
                // keep printable ASCII only (the value is echoed back
                // verbatim in response headers), bounded by the buffer
                let mut n = 0;
                for &b in value.as_bytes() {
                    if n == MAX_REQUEST_ID {
                        break;
                    }
                    if (0x21..=0x7e).contains(&b) {
                        self.req_id[n] = b;
                        n += 1;
                    }
                }
                self.req_id_len = n;
                self.req_hash = obs::hash_request_id(&self.req_id[..n]);
            }
        }
        if obs_on {
            obs::record_span(Stage::HeadParse, head_t0, obs::now_ns(), self.req_hash);
        }

        // --- body: bounded by max_body, within the same deadline ---
        let body_len = match content_length {
            Some(n) => n,
            None if method == Method::Post => return Err(HttpError::LengthRequired),
            None => 0,
        };
        if body_len > self.cfg.max_body {
            return Err(HttpError::BodyTooLarge { limit: self.cfg.max_body });
        }
        if expect_continue && body_len > 0 {
            obs::record_http_response(100);
            self.stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n").map_err(HttpError::Io)?;
        }
        let body_start = head_end + 4;
        let body_t0 = if obs_on { obs::now_ns() } else { 0 };
        while self.buf.len() < body_start + body_len {
            self.fill(start, false)?;
        }
        if self.buf.len() > body_start + body_len {
            // pipelined extra bytes: this server answers one request
            // per read, so trailing bytes are a protocol error
            return Err(HttpError::BadRequest("unexpected bytes after body"));
        }
        if obs_on {
            obs::record_span(Stage::BodyParse, body_t0, obs::now_ns(), self.req_hash);
        }
        let body = (body_start, body_start + body_len);
        Ok(Request { method, path: path_range, body, keep_alive })
    }

    /// One bounded read into `buf`, re-arming the socket timeout with
    /// the remaining deadline budget. `idle` marks the gap between
    /// keep-alive requests, where silence is a clean close rather than
    /// a timeout.
    fn fill(&mut self, start: Instant, idle: bool) -> Result<(), HttpError> {
        let remaining = self
            .cfg
            .read_timeout
            .checked_sub(start.elapsed())
            .filter(|d| !d.is_zero())
            .ok_or(HttpError::Timeout)?;
        self.stream.set_read_timeout(Some(remaining)).map_err(HttpError::Io)?;
        let mut chunk = [0u8; 4096];
        match self.stream.read(&mut chunk) {
            Ok(0) if idle => Err(HttpError::Closed),
            Ok(0) => Err(HttpError::BadRequest("truncated request")),
            Ok(n) => {
                self.buf.extend_from_slice(&chunk[..n]);
                Ok(())
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if idle {
                    // keep-alive connection idled out quietly
                    Err(HttpError::Closed)
                } else {
                    Err(HttpError::Timeout)
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => Ok(()),
            Err(e) => Err(HttpError::Io(e)),
        }
    }

    /// Write one fixed-length response. `extra` headers are appended
    /// verbatim (e.g. `("Retry-After", "1")`).
    pub fn write_response(
        &mut self,
        status: u16,
        reason: &str,
        content_type: &str,
        body: &str,
        extra: &[(&str, &str)],
    ) -> Result<(), HttpError> {
        self.write_head(status, reason, content_type, body.len(), extra);
        self.out.push_str(body);
        obs::record_http_response(status);
        self.stream.write_all(self.out.as_bytes()).map_err(HttpError::Io)
    }

    /// Write one fixed-length response with a **binary** body (the
    /// state-record export path — MACS records are not UTF-8).
    pub fn write_response_bytes(
        &mut self,
        status: u16,
        reason: &str,
        content_type: &str,
        body: &[u8],
        extra: &[(&str, &str)],
    ) -> Result<(), HttpError> {
        self.write_head(status, reason, content_type, body.len(), extra);
        obs::record_http_response(status);
        self.stream.write_all(self.out.as_bytes()).map_err(HttpError::Io)?;
        self.stream.write_all(body).map_err(HttpError::Io)
    }

    /// Assemble status line + standard headers + `extra` into `out`.
    fn write_head(
        &mut self,
        status: u16,
        reason: &str,
        content_type: &str,
        body_len: usize,
        extra: &[(&str, &str)],
    ) {
        use std::fmt::Write as _;
        self.out.clear();
        let _ = write!(
            self.out,
            "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {body_len}\r\n",
        );
        self.echo_request_id();
        for (name, value) in extra {
            let _ = write!(self.out, "{name}: {value}\r\n");
        }
        self.out.push_str("\r\n");
    }

    /// Echo the client's `x-request-id` (sanitized) and this node's id
    /// onto the response being assembled in `out`.
    fn echo_request_id(&mut self) {
        if self.req_id_len > 0 {
            self.out.push_str("x-request-id: ");
            // printable ASCII by construction, so always valid UTF-8
            self.out.push_str(std::str::from_utf8(&self.req_id[..self.req_id_len]).unwrap_or(""));
            self.out.push_str("\r\n");
        }
        if !self.node_id.is_empty() {
            self.out.push_str("x-macformer-node: ");
            self.out.push_str(&self.node_id);
            self.out.push_str("\r\n");
        }
    }

    /// Start a chunked `200` response (the SSE token stream).
    pub fn begin_chunked(&mut self, content_type: &str) -> Result<(), HttpError> {
        use std::fmt::Write as _;
        self.out.clear();
        let _ = write!(
            self.out,
            "HTTP/1.1 200 OK\r\nContent-Type: {content_type}\r\nTransfer-Encoding: chunked\r\nCache-Control: no-store\r\n"
        );
        self.echo_request_id();
        self.out.push_str("\r\n");
        obs::record_http_response(200);
        self.stream.write_all(self.out.as_bytes()).map_err(HttpError::Io)
    }

    /// Write one chunk (one SSE event).
    pub fn write_chunk(&mut self, payload: &str) -> Result<(), HttpError> {
        use std::fmt::Write as _;
        let _span = obs::span(Stage::SseWrite);
        self.out.clear();
        let _ = write!(self.out, "{:x}\r\n{payload}\r\n", payload.len());
        self.stream.write_all(self.out.as_bytes()).map_err(HttpError::Io)
    }

    /// Terminate the chunked response.
    pub fn end_chunked(&mut self) -> Result<(), HttpError> {
        self.stream.write_all(b"0\r\n\r\n").map_err(HttpError::Io)
    }
}

/// Byte offset of the `\r\n\r\n` head terminator, if complete.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}
