//! Durable serve: write-ahead journal + compacting checkpoints.
//!
//! The serve engine survives a process kill by writing every
//! state-changing stream event ahead of (or batched just behind) the
//! work itself, and periodically compacting the whole Supervisor state
//! into one checkpoint file:
//!
//! ```text
//! data-dir/
//!   checkpoint.macc      last-good full image (atomic tmp+rename)
//!   journal.{E}.macj     append-only op log for epoch E
//! ```
//!
//! Both files are sequences of framed, checksummed MACJ records (see
//! [`crate::tensor::io::append_journal_record`]). Recovery loads the
//! checkpoint, restores every stream bit-identically from its MACS
//! state record, then replays the journal tail through the *normal*
//! fold path — the RMFA decode state is deterministic in the admitted
//! token sequence, so a recovered stream is byte-for-byte the stream
//! that never died, on either SIMD arm.
//!
//! Write-ahead discipline (what a crash can and cannot lose):
//!
//! - **Control ops** (open / prefill / close) are journaled and
//!   fsynced *before* the reply leaves the engine: any stream id or
//!   prompt ack a client holds is durable.
//! - **Decode tokens** are journaled at submit-accept and fsynced by
//!   group commit (every [`DurabilityConfig::sync_every_ticks`]
//!   ticks). A crash may lose the tail of *delivered* decode rows —
//!   but never bit-identity: the reconnecting client resubmits from
//!   the server's recovered length and the deterministic fold
//!   reproduces the lost rows exactly.
//! - **Checkpoints** subsume everything before them: the image is
//!   written to `checkpoint.tmp`, fsynced, renamed over the old
//!   checkpoint, and only then is the previous journal epoch deleted.
//!   A crash anywhere in that window recovers from whichever
//!   checkpoint the rename left in place.
//!
//! A torn journal tail (truncated or checksum-failed final record) is
//! silently truncated to the last good record on recovery. Structural
//! corruption — wrong magic, stale version, absurd length header, a
//! checkpoint that fails validation — is a typed error that refuses
//! startup: serving from a half-trusted log would break the
//! bit-identity contract.

mod checkpoint;
mod journal;

pub use checkpoint::{CheckpointImage, CheckpointStream};
pub use journal::JournalOp;

use std::fs::{File, OpenOptions};
use std::io::{Result, Write};
use std::path::{Path, PathBuf};

use crate::serve::obs::{self, Stage};
use journal::OpRef;

/// Configuration for the durable store. `Default` is tuned for the
/// serve bench shapes; only `dir` has no default.
#[derive(Debug, Clone)]
pub struct DurabilityConfig {
    /// Directory holding the checkpoint and journal files (created on
    /// open).
    pub dir: PathBuf,
    /// Group-commit window for decode-token records: the journal is
    /// fsynced at least every this many engine ticks (control ops
    /// always sync immediately). 0 syncs every tick.
    pub sync_every_ticks: u64,
    /// Write a compacting checkpoint (and rotate the journal) every
    /// this many ticks.
    pub checkpoint_every_ticks: u64,
}

impl DurabilityConfig {
    /// Durability rooted at `dir` with default cadences.
    pub fn new(dir: impl Into<PathBuf>) -> DurabilityConfig {
        DurabilityConfig { dir: dir.into(), sync_every_ticks: 32, checkpoint_every_ticks: 1024 }
    }
}

/// What [`Store::open`] recovered from disk: the last good checkpoint
/// (if any) plus every journaled op after it, ready to replay.
pub struct Recovery {
    pub checkpoint: Option<CheckpointImage>,
    pub ops: Vec<JournalOp>,
    /// Bytes of torn tail truncated from the journal (0 on a clean
    /// shutdown) — surfaced so recovery can log what a crash cost.
    pub truncated_bytes: u64,
}

impl Recovery {
    /// True when there was nothing on disk — a fresh data dir.
    pub fn is_empty(&self) -> bool {
        self.checkpoint.is_none() && self.ops.is_empty()
    }
}

/// One stream's durable state extracted from a store — the cross-node
/// failover payload. `record` is `None` when the stream was opened
/// after the last checkpoint (it starts from an empty fold state);
/// `ops` are the stream's journaled prefills/tokens after the
/// checkpoint, in order, to replay on top through the normal fold
/// path.
#[derive(Debug, Clone)]
pub struct StreamRecovery {
    /// The MACS state record from the last checkpoint, if the stream
    /// existed then.
    pub record: Option<Vec<u8>>,
    /// Whether the checkpointed stream sat in the spill arena.
    pub hibernated: bool,
    /// A token staged at checkpoint time but not yet folded; replay it
    /// through the normal submit path before the journal tail.
    pub pending: Option<(Vec<f32>, Vec<f32>, Vec<f32>)>,
    /// This stream's journal tail (Prefill/Token ops only).
    pub ops: Vec<JournalOp>,
}

/// Read a store's recovery state **without taking ownership**: no
/// torn-tail truncation, no stale-journal removal, no file creation.
/// Safe to point at a *dead* node's data dir while its files sit
/// untouched — the failover path another node uses to adopt streams.
pub fn read_store(dir: &Path) -> Result<Recovery> {
    let checkpoint = match std::fs::read(Store::checkpoint_path(dir)) {
        Ok(bytes) => Some(CheckpointImage::decode(&bytes)?),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
        Err(e) => return Err(e),
    };
    let epoch = checkpoint.as_ref().map(|c| c.epoch).unwrap_or(0);
    let (ops, truncated_bytes) = match std::fs::read(Store::journal_path(dir, epoch)) {
        Ok(bytes) => {
            let scan = journal::scan_journal(&bytes)?;
            (scan.ops, (bytes.len() - scan.good_len) as u64)
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => (Vec::new(), 0),
        Err(e) => return Err(e),
    };
    Ok(Recovery { checkpoint, ops, truncated_bytes })
}

/// Single-stream recovery from (another node's) store at `dir`:
/// read-only, see [`read_store`]. Returns `Ok(None)` when the stream
/// is unknown to the store or its journal tail closed it.
pub fn recover_stream(dir: &Path, sid: u64) -> Result<Option<StreamRecovery>> {
    let rec = read_store(dir)?;
    let mut out: Option<StreamRecovery> = None;
    if let Some(ckpt) = &rec.checkpoint {
        if let Some(s) = ckpt.streams.iter().find(|s| s.sid == sid) {
            out = Some(StreamRecovery {
                record: Some(s.record.clone()),
                hibernated: s.hibernated,
                pending: s.pending.clone(),
                ops: Vec::new(),
            });
        }
    }
    for op in rec.ops.into_iter().filter(|op| op.sid() == sid) {
        match op {
            JournalOp::Open { .. } => {
                out = Some(StreamRecovery {
                    record: None,
                    hibernated: false,
                    pending: None,
                    ops: Vec::new(),
                });
            }
            JournalOp::Close { .. } => out = None,
            op => {
                // a Prefill/Token for a stream the store never opened
                // would be structural corruption; recovery is lenient
                // and drops it (the op subsumes nothing)
                if let Some(sr) = out.as_mut() {
                    sr.ops.push(op);
                }
            }
        }
    }
    Ok(out)
}

/// The durable store: one open journal file plus the checkpoint
/// machinery. Owned by the serve engine thread; every method is
/// synchronous and returns typed I/O errors (the engine degrades to
/// non-durable serving, loudly, if the disk goes bad mid-run).
pub struct Store {
    cfg: DurabilityConfig,
    file: File,
    epoch: u64,
    /// Frames appended since the last sync (group commit buffer).
    buf: Vec<u8>,
    scratch: Vec<u8>,
    last_sync_tick: u64,
    last_ckpt_tick: u64,
}

impl Store {
    fn journal_path(dir: &Path, epoch: u64) -> PathBuf {
        dir.join(format!("journal.{epoch}.macj"))
    }

    fn checkpoint_path(dir: &Path) -> PathBuf {
        dir.join("checkpoint.macc")
    }

    /// Open (or create) the store at `cfg.dir` and load whatever a
    /// previous process left behind. The journal tail past the last
    /// good record is truncated; stale journal epochs from interrupted
    /// rotations are deleted.
    pub fn open(cfg: DurabilityConfig) -> Result<(Store, Recovery)> {
        std::fs::create_dir_all(&cfg.dir)?;
        let ckpt_path = Self::checkpoint_path(&cfg.dir);
        let checkpoint = match std::fs::read(&ckpt_path) {
            Ok(bytes) => Some(CheckpointImage::decode(&bytes)?),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
            Err(e) => return Err(e),
        };
        let epoch = checkpoint.as_ref().map(|c| c.epoch).unwrap_or(0);

        let path = Self::journal_path(&cfg.dir, epoch);
        let (ops, truncated_bytes) = match std::fs::read(&path) {
            Ok(bytes) => {
                let scan = journal::scan_journal(&bytes)?;
                if scan.torn {
                    // drop the torn tail so the reopened file appends
                    // at a record boundary
                    let f = OpenOptions::new().write(true).open(&path)?;
                    f.set_len(scan.good_len as u64)?;
                    f.sync_data()?;
                }
                (scan.ops, (bytes.len() - scan.good_len) as u64)
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => (Vec::new(), 0),
            Err(e) => return Err(e),
        };

        // interrupted rotations can leave older epochs behind; they are
        // fully subsumed by the checkpoint, so clear them out
        Self::remove_stale_journals(&cfg.dir, epoch);

        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        let store = Store {
            cfg,
            file,
            epoch,
            buf: Vec::new(),
            scratch: Vec::new(),
            last_sync_tick: 0,
            last_ckpt_tick: 0,
        };
        Ok((store, Recovery { checkpoint, ops, truncated_bytes }))
    }

    fn remove_stale_journals(dir: &Path, keep_epoch: u64) {
        let Ok(entries) = std::fs::read_dir(dir) else { return };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let stale = name
                .strip_prefix("journal.")
                .and_then(|rest| rest.strip_suffix(".macj"))
                .and_then(|e| e.parse::<u64>().ok())
                .is_some_and(|e| e != keep_epoch);
            if stale {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }

    /// The current journal epoch (bumped by every checkpoint).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Journal a stream open. Call [`Store::sync`] before replying.
    pub fn record_open(&mut self, sid: u64) {
        self.append(OpRef::Open { sid });
    }

    /// Journal a prompt prefill. Call [`Store::sync`] before replying.
    pub fn record_prefill(&mut self, sid: u64, q: &[f32], k: &[f32], v: &[f32]) {
        self.append(OpRef::Prefill { sid, q, k, v });
    }

    /// Journal one accepted decode token (group-committed by
    /// [`Store::maybe_sync`]).
    pub fn record_token(&mut self, sid: u64, q: &[f32], k: &[f32], v: &[f32]) {
        self.append(OpRef::Token { sid, q, k, v });
    }

    /// Journal a stream close. Call [`Store::sync`] before replying.
    pub fn record_close(&mut self, sid: u64) {
        self.append(OpRef::Close { sid });
    }

    /// Encode one op into the group-commit buffer, under a
    /// `journal_append` span, counting the appended bytes.
    fn append(&mut self, op: OpRef<'_>) {
        let _span = obs::span(Stage::JournalAppend);
        let before = self.buf.len();
        journal::append_op(&mut self.buf, &mut self.scratch, op);
        obs::add_journal_bytes((self.buf.len() - before) as u64);
    }

    /// Flush and fsync every buffered frame.
    pub fn sync(&mut self, tick_no: u64) -> Result<()> {
        if !self.buf.is_empty() {
            let _span = obs::span(Stage::Fsync);
            self.file.write_all(&self.buf)?;
            self.file.sync_data()?;
            self.buf.clear();
        }
        self.last_sync_tick = tick_no;
        Ok(())
    }

    /// Group commit: sync if the window since the last sync has passed
    /// and there is anything buffered.
    pub fn maybe_sync(&mut self, tick_no: u64) -> Result<()> {
        if !self.buf.is_empty()
            && tick_no.saturating_sub(self.last_sync_tick) >= self.cfg.sync_every_ticks
        {
            self.sync(tick_no)?;
        }
        Ok(())
    }

    /// True when the checkpoint cadence has elapsed.
    pub fn checkpoint_due(&self, tick_no: u64) -> bool {
        tick_no.saturating_sub(self.last_ckpt_tick) >= self.cfg.checkpoint_every_ticks
    }

    /// Write `image` as the new last-good checkpoint and rotate the
    /// journal to `image.epoch`. The caller builds the image *after*
    /// applying every op currently buffered, so the buffer is subsumed
    /// by the image and dropped instead of synced.
    pub fn write_checkpoint(&mut self, image: &CheckpointImage, tick_no: u64) -> Result<()> {
        let _span = obs::span(Stage::Checkpoint);
        let mut bytes = Vec::new();
        image.encode_into(&mut bytes, &mut self.scratch);

        let tmp = self.cfg.dir.join("checkpoint.tmp");
        let final_path = Self::checkpoint_path(&self.cfg.dir);
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_data()?;
        }
        std::fs::rename(&tmp, &final_path)?;
        // make the rename itself durable before retiring the old epoch
        if let Ok(d) = File::open(&self.cfg.dir) {
            let _ = d.sync_all();
        }

        let old_epoch = self.epoch;
        self.epoch = image.epoch;
        self.file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(Self::journal_path(&self.cfg.dir, self.epoch))?;
        let _ = std::fs::remove_file(Self::journal_path(&self.cfg.dir, old_epoch));
        // every buffered op predates the image; it is already durable
        // inside the checkpoint
        self.buf.clear();
        self.last_ckpt_tick = tick_no;
        self.last_sync_tick = tick_no;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::telemetry::Telemetry;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("macformer_durability_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn image(epoch: u64) -> CheckpointImage {
        let mut counters = [0u64; Telemetry::COUNTER_WORDS];
        counters[0] = 41;
        let mut record = Vec::new();
        crate::tensor::io::write_state_record(
            &mut record,
            3,
            &[1.0, 2.0, -0.0, f32::NAN],
            &[0.5, -0.5],
        );
        CheckpointImage {
            epoch,
            next_sid: 7,
            tick_no: 99,
            counters,
            streams: vec![
                CheckpointStream {
                    sid: 1,
                    hibernated: false,
                    record: record.clone(),
                    pending: None,
                },
                CheckpointStream {
                    sid: 4,
                    hibernated: true,
                    record,
                    pending: Some((vec![0.25, 0.5], vec![1.0, -1.0], vec![2.0])),
                },
            ],
        }
    }

    /// Journal ops written, synced, and read back across a simulated
    /// crash-restart: the reopened store replays exactly what was
    /// synced, and a torn tail is truncated to the last good record.
    #[test]
    fn journal_round_trips_and_truncates_torn_tail() {
        let dir = tmp_dir("journal");
        let cfg = DurabilityConfig::new(&dir);
        let (mut store, rec) = Store::open(cfg.clone()).unwrap();
        assert!(rec.is_empty());
        store.record_open(1);
        store.record_prefill(1, &[0.1, 0.2], &[0.3, 0.4], &[0.5]);
        store.record_token(1, &[1.0, 2.0], &[3.0, 4.0], &[5.0]);
        store.record_close(1);
        store.sync(1).unwrap();
        drop(store);

        // tear the tail: append half a record's worth of garbage and a
        // few bytes of a real-looking frame
        let path = Store::journal_path(&dir, 0);
        let mut bytes = std::fs::read(&path).unwrap();
        let good = bytes.len();
        let mut torn = Vec::new();
        crate::tensor::io::append_journal_record(&mut torn, 3, 9, &[0u8; 40]);
        bytes.extend_from_slice(&torn[..torn.len() - 7]);
        std::fs::write(&path, &bytes).unwrap();

        let (_store, rec) = Store::open(cfg).unwrap();
        assert_eq!(rec.truncated_bytes, (bytes.len() - good) as u64);
        assert_eq!(rec.ops.len(), 4);
        assert_eq!(rec.ops[0], JournalOp::Open { sid: 1 });
        assert_eq!(
            rec.ops[2],
            JournalOp::Token { sid: 1, q: vec![1.0, 2.0], k: vec![3.0, 4.0], v: vec![5.0] }
        );
        assert_eq!(rec.ops[3], JournalOp::Close { sid: 1 });
        assert_eq!(std::fs::read(&path).unwrap().len(), good, "torn tail truncated");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A checkpoint image round-trips bit-exactly (including NaN state
    /// payloads and a staged token), subsumes the journal, and rotates
    /// the epoch; the adversarial variants are typed errors.
    #[test]
    fn checkpoint_round_trips_rotates_and_rejects_corruption() {
        let dir = tmp_dir("ckpt");
        let cfg = DurabilityConfig::new(&dir);
        let (mut store, _) = Store::open(cfg.clone()).unwrap();
        store.record_open(1);
        store.sync(1).unwrap();
        store.record_token(1, &[1.0], &[2.0], &[3.0]);
        // the image is built after applying the buffered token, so the
        // checkpoint subsumes it
        let img = image(1);
        store.write_checkpoint(&img, 10).unwrap();
        assert_eq!(store.epoch(), 1);
        assert!(!Store::journal_path(&dir, 0).exists(), "old epoch retired");
        assert!(Store::journal_path(&dir, 1).exists(), "new epoch started");
        drop(store);

        let (_store, rec) = Store::open(cfg.clone()).unwrap();
        let back = rec.checkpoint.expect("checkpoint loaded");
        assert_eq!(back.epoch, 1);
        assert_eq!(back.next_sid, 7);
        assert_eq!(back.tick_no, 99);
        assert_eq!(back.counters[0], 41);
        assert_eq!(back.streams.len(), 2);
        assert!(back.streams[1].hibernated);
        assert_eq!(back.streams[1].pending, Some((vec![0.25, 0.5], vec![1.0, -1.0], vec![2.0])));
        // NaN payload bits survived the trip
        assert_eq!(back.streams[0].record, img.streams[0].record);
        assert!(rec.ops.is_empty(), "journal ops were subsumed by the checkpoint");

        // adversarial checkpoint files: bit-flip, truncation, stale
        // version, absurd length — all typed errors, never panics
        let path = Store::checkpoint_path(&dir);
        let pristine = std::fs::read(&path).unwrap();
        let cases: Vec<(&str, Vec<u8>)> = vec![
            ("bitflip", {
                let mut b = pristine.clone();
                b[40] ^= 0x08;
                b
            }),
            ("truncated", pristine[..pristine.len() - 9].to_vec()),
            ("stale version", {
                let mut b = pristine.clone();
                b[4] = 0xEE;
                b
            }),
            ("oversized length", {
                let mut b = pristine.clone();
                b[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
                b
            }),
        ];
        for (what, bytes) in cases {
            std::fs::write(&path, &bytes).unwrap();
            let err = Store::open(cfg.clone()).expect_err(what);
            assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{what}: {err}");
        }

        // the pristine checkpoint still opens
        std::fs::write(&path, &pristine).unwrap();
        assert!(Store::open(cfg).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Group commit: tokens buffer until the sync window elapses;
    /// control ops sync explicitly.
    #[test]
    fn group_commit_syncs_on_the_tick_window() {
        let dir = tmp_dir("sync");
        let cfg = DurabilityConfig { sync_every_ticks: 4, ..DurabilityConfig::new(&dir) };
        let (mut store, _) = Store::open(cfg.clone()).unwrap();
        let path = Store::journal_path(&dir, 0);
        store.record_token(2, &[1.0], &[1.0], &[1.0]);
        store.maybe_sync(2).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0, "inside the window: buffered");
        store.maybe_sync(4).unwrap();
        assert!(std::fs::metadata(&path).unwrap().len() > 0, "window elapsed: synced");
        drop(store);
        let (_s, rec) = Store::open(cfg).unwrap();
        assert_eq!(rec.ops.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
