//! Compacting checkpoints — the Supervisor's full durable state as a
//! sequence of framed MACJ records in one file.
//!
//! Layout: a `K_CKPT_META` frame (journal epoch, wire-id counter, tick
//! clock, telemetry counters, stream count), one `K_CKPT_STREAM` frame
//! per live stream (flags + the stream's MACS state record + any
//! staged-but-unfolded token), and a terminating `K_CKPT_END` frame.
//! The file is written to a temp name, fsynced, then atomically
//! renamed over the previous checkpoint — so the on-disk checkpoint is
//! always a complete last-good image, and any decode failure here is
//! real corruption answered with a typed error, never a panic.

use std::io::Result;

use crate::serve::telemetry::Telemetry;
use crate::tensor::io::{append_journal_record, read_journal_record, JournalFrame};

use super::journal::{push_blob, push_row, Cursor, K_CKPT_END, K_CKPT_META, K_CKPT_STREAM};

const FLAG_HIBERNATED: u8 = 1 << 0;
const FLAG_PENDING: u8 = 1 << 1;

/// One stream's entry in a checkpoint image.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointStream {
    /// Wire stream id (`s-{sid}`) — the handle clients hold across a
    /// restart.
    pub sid: u64,
    /// Restore straight into the spill arena instead of a pool slot.
    pub hibernated: bool,
    /// The versioned MACS state record.
    pub record: Vec<u8>,
    /// A token staged at checkpoint time but not yet folded; recovery
    /// replays it through the normal submit path.
    pub pending: Option<(Vec<f32>, Vec<f32>, Vec<f32>)>,
}

/// The Supervisor's full durable state at one checkpoint.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointImage {
    /// Journal epoch that starts after this checkpoint: recovery
    /// replays `journal.{epoch}.macj` on top of the image.
    pub epoch: u64,
    /// The engine's next unassigned wire stream id.
    pub next_sid: u64,
    /// The supervisor tick clock.
    pub tick_no: u64,
    /// Durable telemetry counters (see [`Telemetry::export_counters`]).
    pub counters: [u64; Telemetry::COUNTER_WORDS],
    pub streams: Vec<CheckpointStream>,
}

impl CheckpointImage {
    /// Serialize into `buf` (cleared first).
    pub(super) fn encode_into(&self, buf: &mut Vec<u8>, scratch: &mut Vec<u8>) {
        buf.clear();
        scratch.clear();
        scratch.extend_from_slice(&self.epoch.to_le_bytes());
        scratch.extend_from_slice(&self.next_sid.to_le_bytes());
        scratch.extend_from_slice(&self.tick_no.to_le_bytes());
        scratch.extend_from_slice(&(self.counters.len() as u32).to_le_bytes());
        for c in &self.counters {
            scratch.extend_from_slice(&c.to_le_bytes());
        }
        scratch.extend_from_slice(&(self.streams.len() as u32).to_le_bytes());
        append_journal_record(buf, K_CKPT_META, 0, scratch);
        for s in &self.streams {
            scratch.clear();
            let mut flags = 0u8;
            if s.hibernated {
                flags |= FLAG_HIBERNATED;
            }
            if s.pending.is_some() {
                flags |= FLAG_PENDING;
            }
            scratch.push(flags);
            push_blob(scratch, &s.record);
            if let Some((q, k, v)) = &s.pending {
                push_row(scratch, q);
                push_row(scratch, k);
                push_row(scratch, v);
            }
            append_journal_record(buf, K_CKPT_STREAM, s.sid, scratch);
        }
        scratch.clear();
        append_journal_record(buf, K_CKPT_END, 0, scratch);
    }

    /// Decode a checkpoint file. Everything is validated — frame
    /// checksums, the advertised stream count, the terminator — before
    /// the image is handed to recovery: a truncated or bit-flipped
    /// checkpoint is a typed error, not a partial restore.
    pub(super) fn decode(bytes: &[u8]) -> Result<CheckpointImage> {
        let mut at = 0;

        let (kind, _, payload) = next_frame(bytes, &mut at, "meta")?;
        if kind != K_CKPT_META {
            return Err(bad("checkpoint does not start with a meta record"));
        }
        let mut c = Cursor::new(payload);
        let epoch = c.u64()?;
        let next_sid = c.u64()?;
        let tick_no = c.u64()?;
        let n_counters = c.u32()? as usize;
        if n_counters != Telemetry::COUNTER_WORDS {
            return Err(bad("checkpoint counter set does not match this build"));
        }
        let mut counters = [0u64; Telemetry::COUNTER_WORDS];
        for w in counters.iter_mut() {
            *w = c.u64()?;
        }
        let n_streams = c.u32()? as usize;
        c.finish()?;
        if n_streams > 1 << 24 {
            return Err(bad("checkpoint stream count is absurd"));
        }

        let mut streams = Vec::with_capacity(n_streams);
        for _ in 0..n_streams {
            let (kind, sid, payload) = next_frame(bytes, &mut at, "stream")?;
            if kind != K_CKPT_STREAM {
                return Err(bad("checkpoint stream section out of order"));
            }
            let mut c = Cursor::new(payload);
            let flags = c.u8()?;
            let record = c.blob()?.to_vec();
            let pending = if flags & FLAG_PENDING != 0 {
                Some((c.row()?, c.row()?, c.row()?))
            } else {
                None
            };
            c.finish()?;
            streams.push(CheckpointStream {
                sid,
                hibernated: flags & FLAG_HIBERNATED != 0,
                record,
                pending,
            });
        }

        let (kind, _, _) = next_frame(bytes, &mut at, "terminator")?;
        if kind != K_CKPT_END {
            return Err(bad("checkpoint missing its terminator"));
        }
        Ok(CheckpointImage { epoch, next_sid, tick_no, counters, streams })
    }
}

/// Pull the next complete frame out of a checkpoint byte stream; a
/// torn or missing frame is a typed truncation error (the checkpoint
/// file is renamed into place atomically, so it is never legitimately
/// incomplete).
fn next_frame<'a>(bytes: &'a [u8], at: &mut usize, expect: &str) -> Result<(u32, u64, &'a [u8])> {
    match read_journal_record(&bytes[*at..])? {
        JournalFrame::Record { kind, sid, payload, consumed } => {
            *at += consumed;
            Ok((kind, sid, payload))
        }
        _ => Err(bad(&format!("checkpoint truncated (expected {expect} record)"))),
    }
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}
