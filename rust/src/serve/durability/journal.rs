//! Journal operations — the write-ahead record types and their
//! payload encoding.
//!
//! Each op is one framed MACJ record (see
//! [`crate::tensor::io::append_journal_record`]): the frame carries
//! `kind` + `sid` (the wire stream id, `s-{sid}`), the payload carries
//! the op's rows. Replaying the ops in order through the normal
//! supervisor path reproduces the engine's stream state bit-identically
//! — the fold is deterministic in the admitted token sequence, so the
//! journal is the only truth recovery needs beyond a checkpoint.

use std::io::Result;

use crate::tensor::io::{append_journal_record, read_journal_record, JournalFrame};

/// Frame kinds. `1..=4` are write-ahead ops; `16..=18` are checkpoint
/// sections (same framing, different file — see
/// [`super::checkpoint`]).
pub(super) const K_OPEN: u32 = 1;
pub(super) const K_PREFILL: u32 = 2;
pub(super) const K_TOKEN: u32 = 3;
pub(super) const K_CLOSE: u32 = 4;
pub(super) const K_CKPT_META: u32 = 16;
pub(super) const K_CKPT_STREAM: u32 = 17;
pub(super) const K_CKPT_END: u32 = 18;

/// One decoded write-ahead operation, keyed by wire stream id.
#[derive(Debug, Clone, PartialEq)]
pub enum JournalOp {
    /// Stream `s-{sid}` was opened.
    Open { sid: u64 },
    /// Stream `s-{sid}` ingested a whole prompt.
    Prefill { sid: u64, q: Vec<f32>, k: Vec<f32>, v: Vec<f32> },
    /// Stream `s-{sid}` folded one decode token.
    Token { sid: u64, q: Vec<f32>, k: Vec<f32>, v: Vec<f32> },
    /// Stream `s-{sid}` was closed.
    Close { sid: u64 },
}

impl JournalOp {
    /// The wire stream id this op belongs to.
    pub fn sid(&self) -> u64 {
        match self {
            JournalOp::Open { sid }
            | JournalOp::Prefill { sid, .. }
            | JournalOp::Token { sid, .. }
            | JournalOp::Close { sid } => *sid,
        }
    }
}

/// Byte-stream cursor with bounds-checked reads — every decode error
/// is a typed `InvalidData`, never a slice panic.
pub(super) struct Cursor<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    pub(super) fn new(bytes: &'a [u8]) -> Cursor<'a> {
        Cursor { bytes, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .at
            .checked_add(n)
            .filter(|&end| end <= self.bytes.len())
            .ok_or_else(|| bad("payload truncated"))?;
        let got = &self.bytes[self.at..end];
        self.at = end;
        Ok(got)
    }

    pub(super) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(super) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(super) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A length-prefixed f32 row (`u32 n | n f32s`), with the length
    /// validated against the remaining bytes before any allocation.
    pub(super) fn row(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        if n > self.bytes.len().saturating_sub(self.at) / 4 {
            return Err(bad("row length exceeds payload"));
        }
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Raw length-prefixed bytes (`u32 n | n bytes`).
    pub(super) fn blob(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()? as usize;
        if n > self.bytes.len().saturating_sub(self.at) {
            return Err(bad("blob length exceeds payload"));
        }
        self.take(n)
    }

    pub(super) fn finish(self) -> Result<()> {
        if self.at == self.bytes.len() {
            Ok(())
        } else {
            Err(bad("trailing bytes after payload"))
        }
    }
}

pub(super) fn push_row(buf: &mut Vec<u8>, row: &[f32]) {
    buf.extend_from_slice(&(row.len() as u32).to_le_bytes());
    for x in row {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

pub(super) fn push_blob(buf: &mut Vec<u8>, blob: &[u8]) {
    buf.extend_from_slice(&(blob.len() as u32).to_le_bytes());
    buf.extend_from_slice(blob);
}

/// Append `op` as one framed record to `buf`, using `scratch` for the
/// payload (both grow-only, reused across appends).
pub(super) fn append_op(buf: &mut Vec<u8>, scratch: &mut Vec<u8>, op: OpRef<'_>) {
    scratch.clear();
    let (kind, sid) = match op {
        OpRef::Open { sid } => (K_OPEN, sid),
        OpRef::Close { sid } => (K_CLOSE, sid),
        OpRef::Prefill { sid, q, k, v } => {
            push_row(scratch, q);
            push_row(scratch, k);
            push_row(scratch, v);
            (K_PREFILL, sid)
        }
        OpRef::Token { sid, q, k, v } => {
            push_row(scratch, q);
            push_row(scratch, k);
            push_row(scratch, v);
            (K_TOKEN, sid)
        }
    };
    append_journal_record(buf, kind, sid, scratch);
}

/// Borrowed form of [`JournalOp`] for the append path (the engine
/// journals rows it still owns; no clone until replay decode).
#[derive(Clone, Copy)]
pub(super) enum OpRef<'a> {
    Open { sid: u64 },
    Prefill { sid: u64, q: &'a [f32], k: &'a [f32], v: &'a [f32] },
    Token { sid: u64, q: &'a [f32], k: &'a [f32], v: &'a [f32] },
    Close { sid: u64 },
}

/// Result of scanning a journal byte stream.
pub(super) struct JournalScan {
    pub(super) ops: Vec<JournalOp>,
    /// Byte offset of the end of the last good record. Anything past
    /// it is a torn tail the writer should truncate before appending.
    pub(super) good_len: usize,
    pub(super) torn: bool,
}

/// Decode every good op from `bytes`, stopping at a torn tail
/// (truncated or checksum-failed record — recover to last good).
/// Structural corruption — wrong magic, stale version, absurd length,
/// or a malformed payload inside a checksum-clean frame — is a typed
/// error: the file cannot be trusted past that point and silently
/// dropping it would break the bit-identity contract.
pub(super) fn scan_journal(bytes: &[u8]) -> Result<JournalScan> {
    let mut ops = Vec::new();
    let mut at = 0;
    loop {
        match read_journal_record(&bytes[at..])? {
            JournalFrame::End => return Ok(JournalScan { ops, good_len: at, torn: false }),
            JournalFrame::Torn => return Ok(JournalScan { ops, good_len: at, torn: true }),
            JournalFrame::Record { kind, sid, payload, consumed } => {
                ops.push(decode_op(kind, sid, payload)?);
                at += consumed;
            }
        }
    }
}

fn decode_op(kind: u32, sid: u64, payload: &[u8]) -> Result<JournalOp> {
    let mut c = Cursor::new(payload);
    let op = match kind {
        K_OPEN => JournalOp::Open { sid },
        K_CLOSE => JournalOp::Close { sid },
        K_PREFILL => {
            JournalOp::Prefill { sid, q: c.row()?, k: c.row()?, v: c.row()? }
        }
        K_TOKEN => JournalOp::Token { sid, q: c.row()?, k: c.row()?, v: c.row()? },
        other => return Err(bad(&format!("unknown journal op kind {other}"))),
    };
    c.finish()?;
    Ok(op)
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg)
}
