//! [`Telemetry`] — fixed-footprint serving metrics.
//!
//! Everything here is counters and a log2-bucketed latency histogram:
//! no growth, no allocation, so the scheduler can record into it from
//! the steady-state tick without breaking the zero-alloc contract.
//! Percentiles are reconstructed from the histogram, clamped to the
//! exact maximum observed inside the bucket the rank lands in (a
//! bucket's raw upper bound would over-report by up to 2x); the
//! global max is tracked exactly.

use std::time::{Duration, Instant};

use crate::util::json::Value;

/// Latency buckets: bucket `b` covers `[2^b, 2^(b+1))` nanoseconds.
/// 48 buckets span 1 ns .. ~78 hours — everything a serving tick can
/// plausibly produce. Shared with the per-stage histograms in
/// [`obs`](super::obs), so `/metrics` exposes one consistent `le`
/// ladder.
pub const BUCKETS: usize = 48;

// Bucket upper bounds are computed as `1 << (idx + 1)`; keep the
// bucket count inside the u64 shift range.
const _: () = assert!(BUCKETS < 64);

#[derive(Debug, Clone)]
struct Histogram {
    buckets: [u64; BUCKETS],
    /// Exact maximum sample observed per bucket — what keeps the
    /// reported percentiles honest (never above a real sample).
    bucket_max: [u64; BUCKETS],
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            buckets: [0; BUCKETS],
            bucket_max: [0; BUCKETS],
            count: 0,
            sum_ns: 0,
            max_ns: 0,
        }
    }

    fn record(&mut self, ns: u64) {
        let idx = (63 - ns.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[idx] += 1;
        self.bucket_max[idx] = self.bucket_max[idx].max(ns);
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// The p-th percentile in seconds (0.0 with no samples), clamped
    /// to the exact maximum observed in the bucket the rank lands in.
    fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return self.bucket_max[idx].clamp(1, self.max_ns.max(1)) as f64 * 1e-9;
            }
        }
        self.max_ns as f64 * 1e-9
    }

    fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64 * 1e-9
        }
    }

    fn snapshot(&self) -> super::obs::HistSnapshot {
        super::obs::HistSnapshot {
            buckets: self.buckets,
            bucket_max: self.bucket_max,
            count: self.count,
            sum_ns: self.sum_ns,
            max_ns: self.max_ns,
        }
    }
}

/// Serving metrics for one [`StreamPool`](super::StreamPool): per-token
/// latency histogram, throughput, batch occupancy, queue depth, and
/// admission-control rejection counters.
#[derive(Debug, Clone)]
pub struct Telemetry {
    created: Instant,
    tokens: u64,
    ticks: u64,
    idle_ticks: u64,
    batched_ticks: u64,
    sequential_ticks: u64,
    batch_sum: u64,
    batch_max: usize,
    depth_sum: u64,
    depth_max: usize,
    admits: u64,
    rejected_admits: u64,
    rejected_submits: u64,
    prefills: u64,
    prefill_tokens: u64,
    // resilience counters (supervisor/scheduler events)
    hibernations: u64,
    restores: u64,
    evictions: u64,
    expirations: u64,
    shed: u64,
    faults: u64,
    quarantines: u64,
    nonfinite_rejects: u64,
    latency: Histogram,
}

impl Default for Telemetry {
    fn default() -> Telemetry {
        Telemetry::new()
    }
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry {
            created: Instant::now(),
            tokens: 0,
            ticks: 0,
            idle_ticks: 0,
            batched_ticks: 0,
            sequential_ticks: 0,
            batch_sum: 0,
            batch_max: 0,
            depth_sum: 0,
            depth_max: 0,
            admits: 0,
            rejected_admits: 0,
            rejected_submits: 0,
            prefills: 0,
            prefill_tokens: 0,
            hibernations: 0,
            restores: 0,
            evictions: 0,
            expirations: 0,
            shed: 0,
            faults: 0,
            quarantines: 0,
            nonfinite_rejects: 0,
            latency: Histogram::new(),
        }
    }

    pub(super) fn record_admit(&mut self) {
        self.admits += 1;
    }

    pub(super) fn record_admit_rejected(&mut self) {
        self.rejected_admits += 1;
    }

    pub(super) fn record_submit_rejected(&mut self) {
        self.rejected_submits += 1;
    }

    pub(super) fn record_tick(&mut self, batch: usize, queue_depth: usize, sequential: bool) {
        self.ticks += 1;
        self.depth_sum += queue_depth as u64;
        self.depth_max = self.depth_max.max(queue_depth);
        if batch == 0 {
            self.idle_ticks += 1;
            return;
        }
        if sequential {
            self.sequential_ticks += 1;
        } else {
            self.batched_ticks += 1;
        }
        self.batch_sum += batch as u64;
        self.batch_max = self.batch_max.max(batch);
        self.tokens += batch as u64;
    }

    pub(super) fn record_token_latency(&mut self, latency: Duration) {
        self.latency.record(latency.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub(super) fn record_prefill(&mut self, tokens: usize) {
        self.prefills += 1;
        self.prefill_tokens += tokens as u64;
    }

    pub(super) fn record_hibernation(&mut self) {
        self.hibernations += 1;
    }

    pub(super) fn record_restore(&mut self) {
        self.restores += 1;
    }

    pub(super) fn record_eviction(&mut self) {
        self.evictions += 1;
    }

    pub(super) fn record_expiration(&mut self) {
        self.expirations += 1;
    }

    pub(super) fn record_shed(&mut self) {
        self.shed += 1;
    }

    pub(super) fn record_fault(&mut self, quarantine: bool) {
        self.faults += 1;
        if quarantine {
            self.quarantines += 1;
        }
    }

    pub(super) fn record_nonfinite_reject(&mut self) {
        self.nonfinite_rejects += 1;
    }

    /// Tokens served (across all streams).
    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    /// Scheduler ticks observed (including idle ones).
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Ticks that served nothing.
    pub fn idle_ticks(&self) -> u64 {
        self.idle_ticks
    }

    /// Ticks that ran the gathered `(g, 1, d)` micro-batch step.
    pub fn batched_ticks(&self) -> u64 {
        self.batched_ticks
    }

    /// Ticks that fell back to the per-stream sequential path.
    pub fn sequential_ticks(&self) -> u64 {
        self.sequential_ticks
    }

    /// Streams admitted.
    pub fn admits(&self) -> u64 {
        self.admits
    }

    /// Admissions rejected with [`PoolFull`](super::ServeError::PoolFull).
    pub fn rejected_admits(&self) -> u64 {
        self.rejected_admits
    }

    /// Submissions rejected with
    /// [`Backpressure`](super::ServeError::Backpressure).
    pub fn rejected_submits(&self) -> u64 {
        self.rejected_submits
    }

    /// Prompt prefills performed (one per
    /// [`Scheduler::prefill`](super::Scheduler::prefill) call).
    pub fn prefills(&self) -> u64 {
        self.prefills
    }

    /// Prompt tokens ingested by chunked prefill (counted separately
    /// from [`tokens`](Self::tokens), which tracks per-tick decode).
    pub fn prefill_tokens(&self) -> u64 {
        self.prefill_tokens
    }

    /// Streams hibernated (idle-deadline sweeps, capacity evictions,
    /// and explicit/forced hibernations alike).
    pub fn hibernations(&self) -> u64 {
        self.hibernations
    }

    /// Hibernated streams restored on a later submit.
    pub fn restores(&self) -> u64 {
        self.restores
    }

    /// Hibernations forced by pool pressure (a subset of
    /// [`hibernations`](Self::hibernations)): an idle stream was
    /// evicted to make room for an admission/restore.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Streams expired by a deadline (untaken output, or hibernated
    /// past the hibernate-expire bound).
    pub fn expirations(&self) -> u64 {
        self.expirations
    }

    /// Submissions shed by the overload governor (reject-newest).
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// Streams retired by fault isolation (fold panics plus
    /// quarantines).
    pub fn faults(&self) -> u64 {
        self.faults
    }

    /// Streams quarantined by the denominator-health / phi screening
    /// checks (a subset of [`faults`](Self::faults)).
    pub fn quarantines(&self) -> u64 {
        self.quarantines
    }

    /// Tokens rejected at submit/prefill for non-finite q/k/v values
    /// (the stream survives these).
    pub fn nonfinite_rejects(&self) -> u64 {
        self.nonfinite_rejects
    }

    /// Mean streams per non-idle tick (batch occupancy).
    pub fn mean_batch(&self) -> f64 {
        let serving = self.batched_ticks + self.sequential_ticks;
        if serving == 0 {
            0.0
        } else {
            self.batch_sum as f64 / serving as f64
        }
    }

    /// Largest micro-batch served by one tick.
    pub fn max_batch(&self) -> usize {
        self.batch_max
    }

    /// Mean queue depth at tick start.
    pub fn mean_queue_depth(&self) -> f64 {
        if self.ticks == 0 {
            0.0
        } else {
            self.depth_sum as f64 / self.ticks as f64
        }
    }

    /// Deepest queue seen at a tick start.
    pub fn max_queue_depth(&self) -> usize {
        self.depth_max
    }

    /// Wall-clock seconds since this telemetry (i.e. its pool) was
    /// created.
    pub fn elapsed(&self) -> f64 {
        self.created.elapsed().as_secs_f64()
    }

    /// Served tokens per wall-clock second since pool creation.
    pub fn tokens_per_sec(&self) -> f64 {
        let dt = self.elapsed();
        if dt <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / dt
        }
    }

    /// Sum of micro-batch sizes over non-idle ticks (monotonic).
    pub fn batch_sum(&self) -> u64 {
        self.batch_sum
    }

    /// Sum of tick-start queue depths over all ticks (monotonic).
    pub fn queue_depth_sum(&self) -> u64 {
        self.depth_sum
    }

    /// p-th percentile of per-token latency (submit -> served), seconds.
    /// Bucketed: see the module docs for rounding semantics.
    pub fn latency_percentile(&self, p: f64) -> f64 {
        self.latency.percentile(p)
    }

    /// A point-in-time copy of the latency histogram, in the shared
    /// observability snapshot form (the `/metrics` exposition input).
    pub fn latency_snapshot(&self) -> super::obs::HistSnapshot {
        self.latency.snapshot()
    }

    /// Mean per-token latency in seconds (exact, not bucketed).
    pub fn latency_mean(&self) -> f64 {
        self.latency.mean()
    }

    /// Worst per-token latency in seconds (exact).
    pub fn latency_max(&self) -> f64 {
        self.latency.max_ns as f64 * 1e-9
    }

    /// Human-readable multi-line summary.
    pub fn render(&self) -> String {
        format!(
            "tokens {:>8}  |  {:>10.0} tok/s  |  latency p50 {:>9.6}s p99 {:>9.6}s max {:>9.6}s\n\
             ticks  {:>8}  (batched {}, sequential {}, idle {})\n\
             batch  mean {:>6.2} max {:>4}  |  queue mean {:>6.2} max {:>4}\n\
             admits {:>8}  rejected: admit {} submit {}  |  prefills {} ({} tokens)\n\
             resil  hibernations {} (evictions {}) restores {} expirations {} shed {}  |  \
             faults {} (quarantines {}) nonfinite {}",
            self.tokens,
            self.tokens_per_sec(),
            self.latency_percentile(50.0),
            self.latency_percentile(99.0),
            self.latency_max(),
            self.ticks,
            self.batched_ticks,
            self.sequential_ticks,
            self.idle_ticks,
            self.mean_batch(),
            self.batch_max,
            self.mean_queue_depth(),
            self.depth_max,
            self.admits,
            self.rejected_admits,
            self.rejected_submits,
            self.prefills,
            self.prefill_tokens,
            self.hibernations,
            self.evictions,
            self.restores,
            self.expirations,
            self.shed,
            self.faults,
            self.quarantines,
            self.nonfinite_rejects,
        )
    }

    /// Export every durable counter as one fixed-order word array (the
    /// serve-checkpoint payload). The latency histogram and the
    /// wall-clock origin stay behind: latencies are process-local
    /// timings that would be meaningless stitched across a restart.
    pub fn export_counters(&self) -> [u64; Telemetry::COUNTER_WORDS] {
        [
            self.tokens,
            self.ticks,
            self.idle_ticks,
            self.batched_ticks,
            self.sequential_ticks,
            self.batch_sum,
            self.batch_max as u64,
            self.depth_sum,
            self.depth_max as u64,
            self.admits,
            self.rejected_admits,
            self.rejected_submits,
            self.prefills,
            self.prefill_tokens,
            self.hibernations,
            self.restores,
            self.evictions,
            self.expirations,
            self.shed,
            self.faults,
            self.quarantines,
            self.nonfinite_rejects,
        ]
    }

    /// Overwrite the durable counters from an [`export_counters`]
    /// array (crash-restart recovery). The inverse of the export, in
    /// the same fixed order.
    ///
    /// [`export_counters`]: Telemetry::export_counters
    pub fn import_counters(&mut self, c: &[u64; Telemetry::COUNTER_WORDS]) {
        self.tokens = c[0];
        self.ticks = c[1];
        self.idle_ticks = c[2];
        self.batched_ticks = c[3];
        self.sequential_ticks = c[4];
        self.batch_sum = c[5];
        self.batch_max = c[6] as usize;
        self.depth_sum = c[7];
        self.depth_max = c[8] as usize;
        self.admits = c[9];
        self.rejected_admits = c[10];
        self.rejected_submits = c[11];
        self.prefills = c[12];
        self.prefill_tokens = c[13];
        self.hibernations = c[14];
        self.restores = c[15];
        self.evictions = c[16];
        self.expirations = c[17];
        self.shed = c[18];
        self.faults = c[19];
        self.quarantines = c[20];
        self.nonfinite_rejects = c[21];
    }

    /// Number of words in an [`export_counters`] array.
    ///
    /// [`export_counters`]: Telemetry::export_counters
    pub const COUNTER_WORDS: usize = 22;

    /// Machine-readable snapshot (the `telemetry` block of
    /// `BENCH_serve.json`). Deliberately time-independent — pure
    /// counters and the histogram, so a cloned `Telemetry` serializes
    /// the same no matter when. Rates need a measurement window only
    /// the caller knows (the load generator reports tokens/sec over
    /// its drive loop; [`Telemetry::tokens_per_sec`] measures since
    /// pool creation).
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("tokens", Value::num(self.tokens as f64)),
            ("ticks", Value::num(self.ticks as f64)),
            ("idle_ticks", Value::num(self.idle_ticks as f64)),
            ("batched_ticks", Value::num(self.batched_ticks as f64)),
            ("sequential_ticks", Value::num(self.sequential_ticks as f64)),
            ("batch_mean", Value::num(self.mean_batch())),
            ("batch_max", Value::num(self.batch_max as f64)),
            ("queue_depth_mean", Value::num(self.mean_queue_depth())),
            ("queue_depth_max", Value::num(self.depth_max as f64)),
            ("admits", Value::num(self.admits as f64)),
            ("rejected_admits", Value::num(self.rejected_admits as f64)),
            ("rejected_submits", Value::num(self.rejected_submits as f64)),
            ("prefills", Value::num(self.prefills as f64)),
            ("prefill_tokens", Value::num(self.prefill_tokens as f64)),
            ("hibernations", Value::num(self.hibernations as f64)),
            ("restores", Value::num(self.restores as f64)),
            ("evictions", Value::num(self.evictions as f64)),
            ("expirations", Value::num(self.expirations as f64)),
            ("shed", Value::num(self.shed as f64)),
            ("faults", Value::num(self.faults as f64)),
            ("quarantines", Value::num(self.quarantines as f64)),
            ("nonfinite_rejects", Value::num(self.nonfinite_rejects as f64)),
            (
                "latency_s",
                Value::obj(vec![
                    ("mean", Value::num(self.latency_mean())),
                    ("p50", Value::num(self.latency_percentile(50.0))),
                    ("p90", Value::num(self.latency_percentile(90.0))),
                    ("p99", Value::num(self.latency_percentile(99.0))),
                    ("p999", Value::num(self.latency_percentile(99.9))),
                    ("max", Value::num(self.latency_max())),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles_bracket_the_samples() {
        let mut h = Histogram::new();
        // 100 samples at ~1us, one at ~1ms
        for _ in 0..100 {
            h.record(1_000);
        }
        h.record(1_000_000);
        let p50 = h.percentile(50.0);
        assert!(p50 >= 1e-6 && p50 <= 4e-6, "p50 {p50}");
        let p100 = h.percentile(100.0);
        assert!((p100 - 1e-3).abs() < 2e-3, "p100 {p100}");
        assert_eq!(h.count, 101);
        // zero-duration samples land in the bottom bucket, no panic
        h.record(0);
        assert_eq!(h.count, 102);
    }

    /// Pins the percentile fix: with every sample exactly 1000ns, p50
    /// must report 1e-6 exactly — not the 1.024e-6 bucket upper bound
    /// the old implementation returned (up to 2x over-reporting).
    #[test]
    fn percentile_reports_observed_bucket_max_not_upper_bound() {
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.record(1_000);
        }
        // a single far-out sample keeps max_ns from masking the bug
        h.record(1_000_000);
        assert_eq!(h.percentile(50.0), 1e-6);
        assert_eq!(h.percentile(90.0), 1e-6);
        assert_eq!(h.percentile(100.0), 1e-3);
        // mixed values inside one bucket clamp to that bucket's max
        let mut h2 = Histogram::new();
        h2.record(600); // bucket [512, 1024)
        h2.record(900);
        h2.record(5_000);
        assert_eq!(h2.percentile(50.0), 900.0 * 1e-9);
    }

    #[test]
    fn p999_lands_in_the_healthz_snapshot() {
        let mut t = Telemetry::new();
        for _ in 0..999 {
            t.record_token_latency(Duration::from_nanos(1_000));
        }
        t.record_token_latency(Duration::from_nanos(1_000_000));
        let json = t.to_json();
        let lat = json.get("latency_s");
        assert_eq!(lat.get("p50").as_f64(), Some(1e-6));
        assert_eq!(lat.get("p999").as_f64(), Some(1e-3));
        assert_eq!(lat.get("max").as_f64(), Some(1e-3));
    }

    #[test]
    fn latency_snapshot_matches_the_histogram() {
        let mut t = Telemetry::new();
        t.record_token_latency(Duration::from_nanos(700));
        t.record_token_latency(Duration::from_nanos(3_000));
        let s = t.latency_snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.sum_ns, 3_700);
        assert_eq!(s.max_ns, 3_000);
        assert_eq!(s.buckets.iter().sum::<u64>(), 2);
    }

    #[test]
    fn tick_accounting_separates_idle_batched_sequential() {
        let mut t = Telemetry::new();
        t.record_tick(0, 0, false);
        t.record_tick(4, 4, false);
        t.record_tick(1, 3, true);
        assert_eq!(t.ticks(), 3);
        assert_eq!(t.idle_ticks(), 1);
        assert_eq!(t.batched_ticks(), 1);
        assert_eq!(t.sequential_ticks(), 1);
        assert_eq!(t.tokens(), 5);
        assert!((t.mean_batch() - 2.5).abs() < 1e-12);
        assert_eq!(t.max_batch(), 4);
        assert_eq!(t.max_queue_depth(), 4);
        t.record_token_latency(Duration::from_micros(3));
        let json = t.to_json();
        assert_eq!(json.get("tokens").as_usize(), Some(5));
        assert!(json.get("latency_s").get("max").as_f64().unwrap() > 0.0);
        assert!(t.render().contains("tokens"));
    }

    /// Export -> import round-trips every durable counter (the
    /// checkpoint path for crash-restart recovery).
    #[test]
    fn counter_export_import_round_trips() {
        let mut t = Telemetry::new();
        t.record_tick(4, 6, false);
        t.record_tick(1, 1, true);
        t.record_admit();
        t.record_admit_rejected();
        t.record_submit_rejected();
        t.record_prefill(9);
        t.record_hibernation();
        t.record_restore();
        t.record_eviction();
        t.record_expiration();
        t.record_shed();
        t.record_fault(true);
        t.record_nonfinite_reject();
        let exported = t.export_counters();
        let mut back = Telemetry::new();
        back.import_counters(&exported);
        assert_eq!(back.export_counters(), exported);
        assert_eq!(back.tokens(), t.tokens());
        assert_eq!(back.max_batch(), 4);
        assert_eq!(back.max_queue_depth(), 6);
        assert_eq!(back.quarantines(), 1);
    }
}
