//! Closed-loop load generator for the serving subsystem — the engine
//! behind the `macformer serve` subcommand and the `serve_load` bench.
//!
//! "Closed loop" means each stream keeps exactly one token in flight:
//! it submits, waits for the scheduler tick to serve it, takes the
//! output, and immediately submits the next token. Throughput is then
//! limited by the engine, not by an open-loop arrival process, which
//! makes `tokens/sec` comparable across runs. The [`Arrival`] pattern
//! controls *which* streams participate each tick:
//!
//! * [`Arrival::Closed`] — every stream is admitted up front and always
//!   has a token in flight: steady full-occupancy batches.
//! * [`Arrival::Staggered`] — one new stream is admitted per tick: the
//!   batch ramps 1, 2, 3, ... and exercises the degenerate-batch
//!   sequential fallback on the early ticks.
//! * [`Arrival::Bursty`] — streams alternate 4-ticks-on / 4-ticks-off
//!   phases (offset by stream index): ragged occupancy, the
//!   micro-batch size breathing tick to tick.
//!
//! The drive loop runs on the resilience [`Supervisor`], so a
//! [`LoadConfig::resilience`] config exercises hibernation/deadline
//! behavior under load, and a seeded [`LoadConfig::faults`] plan turns
//! the run into a **deterministic chaos test**: NaN tokens (must be
//! screened), forced fold panics (planned casualties, isolated from
//! the rest of the batch), forced hibernate/restore cycles (must be
//! bit-exact), and stalled clients (exercise idle deadlines).
//!
//! With [`LoadConfig::verify`] the run is re-decoded stream by stream
//! through the plain single-stream [`CausalState`] path and compared
//! **bit for bit** — including every surviving prefix of a chaos run.
//! The acceptance criterion: micro-batched serving, hibernation, and
//! fault isolation change throughput, never outputs.
//!
//! [`CausalState`]: crate::attn::CausalState

use std::fmt;
use std::str::FromStr;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::attn::{AttentionSpec, Backend, Kernel};
use crate::util::json::Value;
use crate::util::rng::Rng;

use super::resilience::{FaultPlan, ResilienceConfig, SessionId, Supervisor};
use super::telemetry::Telemetry;
use super::{ServeConfig, ServeError};

/// When streams enter (and pause) the closed loop. See the
/// [`crate::serve::loadgen`] module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    Closed,
    Staggered,
    Bursty,
}

impl Arrival {
    pub const ALL: [Arrival; 3] = [Arrival::Closed, Arrival::Staggered, Arrival::Bursty];

    pub fn name(&self) -> &'static str {
        match self {
            Arrival::Closed => "closed",
            Arrival::Staggered => "staggered",
            Arrival::Bursty => "bursty",
        }
    }
}

impl fmt::Display for Arrival {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.name())
    }
}

impl FromStr for Arrival {
    type Err = String;

    fn from_str(s: &str) -> Result<Arrival, String> {
        match s {
            "closed" => Ok(Arrival::Closed),
            "staggered" => Ok(Arrival::Staggered),
            "bursty" => Ok(Arrival::Bursty),
            other => {
                Err(format!(
                    "unknown arrival pattern {other:?}; expected one of: closed, staggered, bursty"
                ))
            }
        }
    }
}

/// One load scenario: how many streams, how much work per stream, the
/// attention config they share, the arrival pattern, and (for chaos
/// runs) the fault plan + resilience knobs.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    pub streams: usize,
    /// Sequence length each stream decodes (tokens per stream).
    pub tokens: usize,
    /// Prompt tokens chunk-prefilled at admission, before the decode
    /// loop (0 = no prompt). Prefill goes through
    /// [`Scheduler::prefill`](super::Scheduler::prefill) — chunkwise
    /// GEMM compute, not `n` single-token ticks — and with
    /// [`LoadConfig::verify`] the decode outputs after the prompt must
    /// still be **bit-identical** to a single-stream `append_token`
    /// replay of prompt + decode (the prefilled state is bit-compatible
    /// by construction); the prompt's own last output carries the
    /// chunked 1e-5 contract.
    pub prompt: usize,
    pub head_dim: usize,
    pub dv: usize,
    pub num_features: usize,
    pub kernel: Kernel,
    pub backend: Backend,
    pub arrival: Arrival,
    /// Batches below this run the sequential fallback (see
    /// [`ServeConfig::min_batch`]).
    pub min_batch: usize,
    pub seed: u64,
    /// Re-decode every stream through the single-stream path and
    /// require bit-identical outputs (surviving prefixes included).
    pub verify: bool,
    /// Deterministic chaos schedule ([`FaultPlan::none`] = clean run).
    pub faults: FaultPlan,
    /// Supervisor deadline/governor/spill knobs (default = all off).
    pub resilience: ResilienceConfig,
}

impl Default for LoadConfig {
    fn default() -> LoadConfig {
        LoadConfig {
            streams: 64,
            tokens: 64,
            prompt: 0,
            head_dim: 32,
            dv: 32,
            num_features: 64,
            kernel: Kernel::Exp,
            backend: Backend::HostFast,
            arrival: Arrival::Closed,
            min_batch: 2,
            seed: 7,
            verify: true,
            faults: FaultPlan::none(),
            resilience: ResilienceConfig::default(),
        }
    }
}

/// Outcome of one [`run`]: throughput/latency plus the engine's own
/// telemetry snapshot and the verification verdict.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub streams: usize,
    pub tokens_per_stream: usize,
    /// Prompt tokens chunk-prefilled per stream at admission.
    pub prompt_tokens: usize,
    pub arrival: Arrival,
    pub kernel: Kernel,
    /// Resolved backend tier name (`Auto` resolves at session build).
    pub backend: String,
    pub head_dim: usize,
    pub dv: usize,
    pub num_features: usize,
    pub min_batch: usize,
    /// Wall-clock seconds of the drive loop (excludes setup, data
    /// generation, and verification).
    pub elapsed_s: f64,
    pub tokens_total: u64,
    pub tokens_per_sec: f64,
    /// Streams that hit an unexpected `ServeError` mid-run (0 on any
    /// healthy run; the CI smoke gate asserts this).
    pub stream_errors: u64,
    /// Planned chaos casualties: streams killed by an injected fold
    /// panic, isolated by the supervisor. Their surviving output
    /// prefixes still verify bit-identically.
    pub faulted_streams: u64,
    /// Streams whose outputs diverged from the single-stream replay
    /// (or that failed unexpectedly): poison that escaped isolation.
    /// The chaos CI gate asserts 0.
    pub poisoned_streams: u64,
    /// `Some(true)` when every re-decoded output matched bit for bit;
    /// `None` when verification was not requested.
    pub verified: Option<bool>,
    /// Largest |serve - single-stream| over all outputs (0.0 when
    /// bit-identical).
    pub max_abs_diff: f64,
    /// Largest magnitude-scaled |prefill - single-stream| over the
    /// prompt's last output row — `|a - b| / max(1, |b|)`, the chunked
    /// kernel's 1e-5 contract (0.0 with no prompt).
    pub prefill_max_scaled_diff: f64,
    /// Engine telemetry, snapshotted at the end of the drive loop
    /// (before teardown and the verification replay).
    pub telemetry: Telemetry,
}

impl LoadReport {
    pub fn render(&self) -> String {
        let verified = match self.verified {
            Some(true) => "bit-identical to single-stream decode".to_string(),
            Some(false) => {
                format!("MISMATCH vs single-stream (max |diff| {})", self.max_abs_diff)
            }
            None => "skipped".to_string(),
        };
        format!(
            "serve: {} streams x {} tokens (+{} prompt, {} arrival, kernel {}, backend {}, d={} dv={} D={})\n\
             {:>10.0} tokens/sec  ({} tokens in {:.3}s, {} stream errors)\n\
             latency   p50 {:.6}s  p90 {:.6}s  p99 {:.6}s  max {:.6}s\n\
             occupancy mean {:.2} max {}  |  queue mean {:.2} max {}  |  ticks {} ({} seq, {} idle)\n\
             resil     {} faulted (planned), {} poisoned | hibernations {} restores {} shed {}\n\
             verify    {}",
            self.streams,
            self.tokens_per_stream,
            self.prompt_tokens,
            self.arrival,
            self.kernel,
            self.backend,
            self.head_dim,
            self.dv,
            self.num_features,
            self.tokens_per_sec,
            self.tokens_total,
            self.elapsed_s,
            self.stream_errors,
            self.telemetry.latency_percentile(50.0),
            self.telemetry.latency_percentile(90.0),
            self.telemetry.latency_percentile(99.0),
            self.telemetry.latency_max(),
            self.telemetry.mean_batch(),
            self.telemetry.max_batch(),
            self.telemetry.mean_queue_depth(),
            self.telemetry.max_queue_depth(),
            self.telemetry.ticks(),
            self.telemetry.sequential_ticks(),
            self.telemetry.idle_ticks(),
            self.faulted_streams,
            self.poisoned_streams,
            self.telemetry.hibernations(),
            self.telemetry.restores(),
            self.telemetry.shed(),
            verified,
        )
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("streams", Value::num(self.streams as f64)),
            ("tokens_per_stream", Value::num(self.tokens_per_stream as f64)),
            ("prompt_tokens", Value::num(self.prompt_tokens as f64)),
            ("arrival", Value::str(self.arrival.name())),
            ("kernel", Value::str(self.kernel.name())),
            ("backend", Value::str(self.backend.clone())),
            ("head_dim", Value::num(self.head_dim as f64)),
            ("dv", Value::num(self.dv as f64)),
            ("num_features", Value::num(self.num_features as f64)),
            ("min_batch", Value::num(self.min_batch as f64)),
            ("elapsed_s", Value::num(self.elapsed_s)),
            ("tokens_total", Value::num(self.tokens_total as f64)),
            ("tokens_per_sec", Value::num(self.tokens_per_sec)),
            ("stream_errors", Value::num(self.stream_errors as f64)),
            ("faulted_streams", Value::num(self.faulted_streams as f64)),
            ("poisoned_streams", Value::num(self.poisoned_streams as f64)),
            // duplicated from the nested telemetry block so the chaos
            // CI gate can grep them at the top level
            ("hibernations", Value::num(self.telemetry.hibernations() as f64)),
            ("restores", Value::num(self.telemetry.restores() as f64)),
            (
                "verified",
                match self.verified {
                    Some(b) => Value::Bool(b),
                    None => Value::Null,
                },
            ),
            ("max_abs_diff", Value::num(self.max_abs_diff)),
            ("prefill_max_scaled_diff", Value::num(self.prefill_max_scaled_diff)),
            ("telemetry", self.telemetry.to_json()),
        ])
    }
}

/// Row layout of one pre-generated token: `[q(d) | k(d) | v(dv)]`.
pub(crate) fn token_stride(cfg: &LoadConfig) -> usize {
    2 * cfg.head_dim + cfg.dv
}

/// Pre-generate every stream's token rows (deterministic per stream, so
/// verification replays the identical inputs).
pub(crate) fn generate_tokens(cfg: &LoadConfig) -> Vec<Vec<f32>> {
    (0..cfg.streams)
        .map(|i| {
            let mut rng = Rng::new(cfg.seed ^ (i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
            let mut data = Vec::with_capacity(cfg.tokens * token_stride(cfg));
            for _ in 0..cfg.tokens {
                for _ in 0..cfg.head_dim {
                    data.push(rng.normal() * 0.5);
                }
                for _ in 0..cfg.head_dim {
                    data.push(rng.normal() * 0.5);
                }
                for _ in 0..cfg.dv {
                    data.push(rng.normal());
                }
            }
            data
        })
        .collect()
}

/// Pre-generate every stream's prompt as contiguous `(q, k, v)` row
/// sets (the layout [`Scheduler::prefill`](super::Scheduler::prefill)
/// takes), deterministic per stream so verification replays the
/// identical prompt.
pub(crate) fn generate_prompts(cfg: &LoadConfig) -> Vec<(Vec<f32>, Vec<f32>, Vec<f32>)> {
    (0..cfg.streams)
        .map(|i| {
            let mut rng =
                Rng::new(cfg.seed ^ (i as u64 + 1).wrapping_mul(0xD1B54A32D192ED03));
            let fill = |rng: &mut Rng, len: usize, scale: f32| -> Vec<f32> {
                (0..len).map(|_| rng.normal() * scale).collect()
            };
            let q = fill(&mut rng, cfg.prompt * cfg.head_dim, 0.5);
            let k = fill(&mut rng, cfg.prompt * cfg.head_dim, 0.5);
            let v = fill(&mut rng, cfg.prompt * cfg.dv, 1.0);
            (q, k, v)
        })
        .collect()
}

/// May stream `i` submit at tick `tick_no` under this arrival pattern?
/// (Admission is separate: staggered streams are admitted one per tick.)
fn may_submit(arrival: Arrival, tick_no: usize, stream: usize) -> bool {
    match arrival {
        Arrival::Closed | Arrival::Staggered => true,
        Arrival::Bursty => ((tick_no + stream) / 4) % 2 == 0,
    }
}

/// Drive one closed-loop load scenario end to end and report.
pub fn run(cfg: &LoadConfig) -> Result<LoadReport> {
    if cfg.streams == 0 || cfg.tokens == 0 {
        bail!("loadgen: streams and tokens must be > 0");
    }
    let session = AttentionSpec::new(cfg.kernel)
        .head_dim(cfg.head_dim)
        .num_features(cfg.num_features)
        .causal(true)
        .seed(cfg.seed)
        .backend(cfg.backend)
        .build()
        .context("loadgen: building the shared session")?;
    let serve_cfg = ServeConfig {
        max_streams: cfg.streams,
        max_pending: 0,
        min_batch: cfg.min_batch,
        dv: cfg.dv,
        screen_inputs: true,
    };
    let mut sup = Supervisor::new(&session, serve_cfg, cfg.resilience.clone())?;

    let stride = token_stride(cfg);
    let (d, dv) = (cfg.head_dim, cfg.dv);
    let plan = cfg.faults;
    let tokens = generate_tokens(cfg);
    let prompts = generate_prompts(cfg);
    let mut outs: Vec<Vec<f32>> = (0..cfg.streams).map(|_| vec![0.0; cfg.tokens * dv]).collect();
    // last prompt position's output per stream (chunked prefill)
    let mut prompt_last: Vec<Vec<f32>> = (0..cfg.streams).map(|_| vec![0.0; dv]).collect();
    let mut ids: Vec<Option<SessionId>> = vec![None; cfg.streams];
    let mut produced = vec![0usize; cfg.streams];
    let mut in_flight = vec![false; cfg.streams];
    let mut failed = vec![false; cfg.streams];
    // planned chaos casualties (injected fold panics) — tracked apart
    // from `failed`, which is unexpected breakage
    let mut faulted = vec![false; cfg.streams];
    let mut expect_fault = vec![false; cfg.streams];
    // stalled-client injection: token index delayed, and until when
    let mut delayed_token: Vec<Option<usize>> = vec![None; cfg.streams];
    let mut delayed_until = vec![0u64; cfg.streams];
    let mut nan_q = vec![0.0f32; d];
    let mut stream_errors = 0u64;
    let mut done = 0usize;
    let target = cfg.streams * cfg.tokens;
    // generous livelock guard: bursty gaps are <= 4 ticks per token
    let mut max_ticks = 16 * (cfg.tokens + cfg.streams) + 1024;
    if plan.delay_every != 0 {
        // stalled-client injections push tokens past their usual tick
        max_ticks += 2 * cfg.tokens * plan.delay_ticks as usize + 64;
    }

    let t0 = Instant::now();
    let mut tick_no = 0usize;
    while done < target {
        if tick_no >= max_ticks {
            bail!("loadgen: no progress after {max_ticks} ticks ({done}/{target} tokens served)");
        }
        // admission (a SessionId is sticky: it survives hibernation)
        for i in 0..cfg.streams {
            if ids[i].is_some() || failed[i] {
                continue;
            }
            let due = match cfg.arrival {
                Arrival::Staggered => tick_no >= i,
                Arrival::Closed | Arrival::Bursty => true,
            };
            if !due {
                continue;
            }
            match sup.open() {
                Ok(id) => {
                    ids[i] = Some(id);
                    if cfg.prompt > 0 {
                        // chunked prompt admission: prefill, then take
                        // the prompt's last output so the closed loop
                        // can start submitting decode tokens
                        let (pq, pk, pv) = &prompts[i];
                        let ingested = sup.prefill(id, pq, pk, pv).and_then(|n| {
                            sup.take_output(id, &mut prompt_last[i]).map(|()| n)
                        });
                        if let Err(e) = ingested {
                            log::warn!("loadgen: stream {i} prefill failed: {e}");
                            stream_errors += 1;
                            failed[i] = true;
                            done += cfg.tokens - produced[i];
                        }
                    }
                }
                Err(e) => {
                    log::warn!("loadgen: stream {i} open failed: {e}");
                    stream_errors += 1;
                    failed[i] = true;
                    done += cfg.tokens - produced[i];
                }
            }
        }
        // submit phase (closed loop: at most one token in flight each)
        for i in 0..cfg.streams {
            let Some(id) = ids[i] else { continue };
            if failed[i] || faulted[i] || in_flight[i] || produced[i] >= cfg.tokens {
                continue;
            }
            if !may_submit(cfg.arrival, tick_no, i) {
                continue;
            }
            if (tick_no as u64) < delayed_until[i] {
                continue;
            }
            let t = produced[i];
            let delay = plan.submit_delay(i as u64, t as u64);
            if delay > 0 && delayed_token[i] != Some(t) {
                // stalled client: this token waits out its delay (each
                // token stalls at most once)
                delayed_token[i] = Some(t);
                delayed_until[i] = tick_no as u64 + delay;
                continue;
            }
            let row = &tokens[i][t * stride..(t + 1) * stride];
            if plan.inject_nan(i as u64, t as u64) {
                // poisoned copy first: the input screen must reject it
                // with the stream untouched; the real token follows
                nan_q.copy_from_slice(&row[..d]);
                nan_q[t % d] = f32::NAN;
                match sup.submit(id, &nan_q, &row[d..2 * d], &row[2 * d..]) {
                    Err(ServeError::NonFinite { .. }) => {}
                    // governor shed beat the screen: retry the whole
                    // token (poisoned copy first) next tick
                    Err(e) if e.is_retryable() => continue,
                    other => {
                        log::warn!(
                            "loadgen: stream {i} NaN injection was not screened: {other:?}"
                        );
                        stream_errors += 1;
                        failed[i] = true;
                        done += cfg.tokens - produced[i];
                        continue;
                    }
                }
            }
            match sup.submit(id, &row[..d], &row[d..2 * d], &row[2 * d..]) {
                Ok(()) => {
                    in_flight[i] = true;
                    if plan.inject_panic(i as u64, t as u64, cfg.tokens as u64) {
                        // planned casualty: the tick's guarded fold
                        // isolates this stream from the batch
                        sup.arm_fault(id).expect("stream is active after submit");
                        expect_fault[i] = true;
                    }
                }
                Err(e) if e.is_retryable() => {
                    // governor shed / backpressure: retry next tick
                }
                Err(e) => {
                    log::warn!("loadgen: stream {i} submit failed: {e}");
                    stream_errors += 1;
                    failed[i] = true;
                    done += cfg.tokens - produced[i];
                }
            }
        }
        sup.tick()?;
        // collect phase
        for i in 0..cfg.streams {
            if !in_flight[i] {
                continue;
            }
            let id = ids[i].expect("in-flight stream has an id");
            let t = produced[i];
            match sup.take_output(id, &mut outs[i][t * dv..(t + 1) * dv]) {
                Ok(()) => {
                    produced[i] = t + 1;
                    in_flight[i] = false;
                    done += 1;
                    if plan.force_hibernate(i as u64, t as u64) {
                        // forced spill: the next submit must restore
                        // this stream bit-identically
                        if let Err(e) = sup.hibernate(id) {
                            log::warn!("loadgen: stream {i} forced hibernate failed: {e}");
                            stream_errors += 1;
                        }
                    }
                }
                Err(ServeError::Faulted) if expect_fault[i] => {
                    // the planned casualty landed; its produced prefix
                    // is still verified below
                    faulted[i] = true;
                    in_flight[i] = false;
                    done += cfg.tokens - produced[i];
                }
                Err(e) => {
                    log::warn!("loadgen: stream {i} take_output failed: {e}");
                    stream_errors += 1;
                    failed[i] = true;
                    in_flight[i] = false;
                    done += cfg.tokens - produced[i];
                }
            }
        }
        tick_no += 1;
    }
    let elapsed = t0.elapsed().as_secs_f64();
    // Snapshot now, before teardown and the verification replay, so the
    // telemetry block reflects the drive loop only (the PERF.md
    // methodology); `Telemetry::to_json` is time-independent, so the
    // snapshot serializes identically whenever the report is written.
    let telemetry = sup.telemetry().clone();
    for (i, id) in ids.iter().enumerate() {
        if let Some(id) = id {
            if sup.close(*id).is_err() {
                log::warn!("loadgen: stream {i} close failed");
                stream_errors += 1;
            }
        }
    }

    let tokens_total: u64 = produced.iter().map(|&p| p as u64).sum();
    let faulted_streams = faulted.iter().filter(|&&f| f).count() as u64;
    let mut poisoned_streams = failed.iter().filter(|&&f| f).count() as u64;
    let (verified, max_abs_diff, prefill_max_scaled_diff) = if cfg.verify {
        let mut ok = stream_errors == 0;
        let mut max_diff = 0.0f64;
        let mut prefill_diff = 0.0f64;
        let mut row = vec![0.0f32; dv];
        for i in 0..cfg.streams {
            if failed[i] {
                ok = false;
                continue;
            }
            // Replay the whole stream — prompt, then decode — through
            // the plain single-stream append path. The prompt's last
            // output carries the chunked kernel's 1e-5 contract; every
            // decode output after it must be bit-identical (the
            // prefilled state is bit-compatible by construction). For
            // chaos casualties only the produced prefix exists, and it
            // must match exactly like any survivor's full run.
            let mut stream_poisoned = false;
            let mut state = session.begin_decode(dv)?;
            let (pq, pk, pv) = &prompts[i];
            for t in 0..cfg.prompt {
                state.append_token_into(
                    &pq[t * d..(t + 1) * d],
                    &pk[t * d..(t + 1) * d],
                    &pv[t * dv..(t + 1) * dv],
                    &mut row,
                )?;
            }
            if cfg.prompt > 0 {
                for (a, b) in prompt_last[i].iter().zip(&row) {
                    // magnitude-scaled like the chunked-kernel contract;
                    // the reported metric and the pass/fail gate use the
                    // same scaled quantity so a verified run never shows
                    // a diff above the documented 1e-5
                    let diff = ((a - b).abs() / b.abs().max(1.0)) as f64;
                    prefill_diff = prefill_diff.max(diff);
                    if !diff.is_finite() || diff > 1e-5 {
                        ok = false;
                        stream_poisoned = true;
                    }
                }
            }
            for t in 0..produced[i] {
                let tok = &tokens[i][t * stride..(t + 1) * stride];
                state.append_token_into(&tok[..d], &tok[d..2 * d], &tok[2 * d..], &mut row)?;
                for (a, b) in outs[i][t * dv..(t + 1) * dv].iter().zip(&row) {
                    if a.to_bits() != b.to_bits() {
                        ok = false;
                        stream_poisoned = true;
                        max_diff = max_diff.max((a - b).abs() as f64);
                    }
                }
            }
            if stream_poisoned {
                poisoned_streams += 1;
            }
        }
        (Some(ok), max_diff, prefill_diff)
    } else {
        (None, 0.0, 0.0)
    };

    Ok(LoadReport {
        streams: cfg.streams,
        tokens_per_stream: cfg.tokens,
        prompt_tokens: cfg.prompt,
        arrival: cfg.arrival,
        kernel: cfg.kernel,
        backend: session.backend_name().to_string(),
        head_dim: cfg.head_dim,
        dv: cfg.dv,
        num_features: cfg.num_features,
        min_batch: cfg.min_batch,
        elapsed_s: elapsed,
        tokens_total,
        tokens_per_sec: if elapsed > 0.0 { tokens_total as f64 / elapsed } else { 0.0 },
        stream_errors,
        faulted_streams,
        poisoned_streams,
        verified,
        max_abs_diff,
        prefill_max_scaled_diff,
        telemetry,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(arrival: Arrival) -> LoadConfig {
        LoadConfig {
            streams: 5,
            tokens: 6,
            head_dim: 4,
            dv: 3,
            num_features: 16,
            arrival,
            seed: 11,
            ..LoadConfig::default()
        }
    }

    #[test]
    fn arrival_parse_round_trips() {
        for a in Arrival::ALL {
            assert_eq!(Arrival::from_str(a.name()), Ok(a));
        }
        assert!(Arrival::from_str("poisson").is_err());
    }

    #[test]
    fn every_arrival_pattern_completes_and_verifies() {
        for arrival in Arrival::ALL {
            let report = run(&tiny(arrival)).unwrap();
            assert_eq!(report.tokens_total, 30, "{arrival}");
            assert_eq!(report.stream_errors, 0, "{arrival}");
            assert_eq!(report.verified, Some(true), "{arrival}");
            assert_eq!(report.max_abs_diff, 0.0, "{arrival}");
            assert_eq!(report.faulted_streams, 0, "{arrival}");
            assert_eq!(report.poisoned_streams, 0, "{arrival}");
            let json = report.to_json();
            assert_eq!(json.get("stream_errors").as_usize(), Some(0));
            assert_eq!(json.get("poisoned_streams").as_usize(), Some(0));
            assert!(report.render().contains("tokens/sec"));
        }
    }

    #[test]
    fn prompted_streams_prefill_then_decode_bit_compatibly() {
        for arrival in [Arrival::Closed, Arrival::Staggered] {
            let report = run(&LoadConfig { prompt: 7, ..tiny(arrival) }).unwrap();
            assert_eq!(report.tokens_total, 30, "{arrival}");
            assert_eq!(report.stream_errors, 0, "{arrival}");
            // decode tokens after the prefilled prompt stay bit-exact
            assert_eq!(report.verified, Some(true), "{arrival}");
            assert_eq!(report.max_abs_diff, 0.0, "{arrival}");
            // the prompt's own last output carries the 1e-5 contract
            assert!(report.prefill_max_scaled_diff < 1e-5, "{arrival}");
            assert_eq!(report.telemetry.prefills(), 5, "{arrival}");
            assert_eq!(report.telemetry.prefill_tokens(), 35, "{arrival}");
            let json = report.to_json();
            assert_eq!(json.get("prompt_tokens").as_usize(), Some(7));
        }
    }

    /// The full chaos gauntlet on one small run: NaN tokens screened,
    /// two planned panic casualties isolated, forced hibernate/restore
    /// cycles, stalled clients — and every surviving output prefix
    /// still bit-identical to a fault-free single-stream decode.
    #[test]
    fn chaos_run_keeps_survivors_bit_identical() {
        let faults = FaultPlan {
            seed: 42,
            nan_every: 2,
            panics: 2,
            hibernate_every: 2,
            delay_every: 4,
            delay_ticks: 3,
        };
        let report = run(&LoadConfig { faults, ..tiny(Arrival::Closed) }).unwrap();
        assert_eq!(report.stream_errors, 0);
        assert_eq!(report.faulted_streams, 2, "exactly the planned casualties");
        assert_eq!(report.poisoned_streams, 0, "no poison escaped isolation");
        assert_eq!(report.verified, Some(true));
        assert_eq!(report.max_abs_diff, 0.0);
        // the two killed streams produced partial prefixes
        assert!(report.tokens_total < 30, "{}", report.tokens_total);
        assert!(report.tokens_total > 0);
        assert_eq!(report.telemetry.faults(), 2);
        assert_eq!(report.telemetry.quarantines(), 0);
        assert!(report.telemetry.nonfinite_rejects() > 0, "NaN injections were screened");
        assert!(report.telemetry.hibernations() > 0);
        assert!(report.telemetry.restores() > 0);
        let json = report.to_json();
        assert_eq!(json.get("faulted_streams").as_usize(), Some(2));
        assert_eq!(json.get("poisoned_streams").as_usize(), Some(0));
        assert!(json.get("restores").as_usize().unwrap() > 0);
    }

    /// Chaos + resilience deadlines + governor together: stalled
    /// clients trip the idle-hibernate sweep, the governor sheds under
    /// the tightened queue bound, and the run still completes with
    /// bit-identical survivors.
    #[test]
    fn chaos_with_deadlines_and_governor_still_verifies() {
        let faults = FaultPlan {
            seed: 9,
            nan_every: 0,
            panics: 1,
            hibernate_every: 3,
            delay_every: 3,
            delay_ticks: 6,
        };
        let resilience = ResilienceConfig {
            idle_hibernate_ticks: 2,
            shed_pending: 4,
            ..ResilienceConfig::default()
        };
        let report =
            run(&LoadConfig { faults, resilience, ..tiny(Arrival::Closed) }).unwrap();
        assert_eq!(report.stream_errors, 0);
        assert_eq!(report.faulted_streams, 1);
        assert_eq!(report.poisoned_streams, 0);
        assert_eq!(report.verified, Some(true));
        assert!(report.telemetry.restores() > 0);
    }

    #[test]
    fn loadgen_rejects_empty_scenarios() {
        assert!(run(&LoadConfig { streams: 0, ..tiny(Arrival::Closed) }).is_err());
        assert!(run(&LoadConfig { tokens: 0, ..tiny(Arrival::Closed) }).is_err());
    }
}
