//! Backend health: a per-node failure-threshold state machine fed by
//! an active `/healthz` prober.
//!
//! ```text
//!            probe fails              fails reach threshold
//!  Healthy ──────────────▶ Suspect ──────────────────────▶ Down
//!     ▲                      │ probe ok                      │ probe ok
//!     │ oks reach threshold  ▼                               ▼
//!     └─────────────────── Recovering ◀──────────────────────┘
//!                            │ probe fails
//!                            └──────────▶ Down
//! ```
//!
//! `Healthy` and `Suspect` are *routable* (a single missed probe must
//! not trigger a migration storm); `Down` and `Recovering` are not.
//! The `Down` transition is the failover trigger: the router moves
//! every stream mapped to the node onto its ring successors. A node
//! that comes back must answer `recover_threshold` consecutive probes
//! before taking new opens again — it re-enters with no streams (its
//! old ones migrated away) and refills from the ring.

use std::io::{Read as _, Write as _};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// One backend's health as the router sees it.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum NodeState {
    Healthy,
    Suspect,
    Down,
    Recovering,
}

impl NodeState {
    pub fn name(self) -> &'static str {
        match self {
            NodeState::Healthy => "healthy",
            NodeState::Suspect => "suspect",
            NodeState::Down => "down",
            NodeState::Recovering => "recovering",
        }
    }

    /// May this node receive proxied traffic and new stream opens?
    pub fn routable(self) -> bool {
        matches!(self, NodeState::Healthy | NodeState::Suspect)
    }

    /// Stable gauge encoding for `/metrics`:
    /// `0` down, `1` recovering, `2` suspect, `3` healthy.
    pub fn gauge(self) -> u8 {
        match self {
            NodeState::Down => 0,
            NodeState::Recovering => 1,
            NodeState::Suspect => 2,
            NodeState::Healthy => 3,
        }
    }
}

/// The threshold state machine for one backend. Owned by the prober
/// thread; workers read the published [`NodeState`] through an atomic.
pub struct HealthMachine {
    state: NodeState,
    /// Consecutive probe failures since the last success.
    fails: u32,
    /// Consecutive probe successes while recovering.
    oks: u32,
    fail_threshold: u32,
    recover_threshold: u32,
}

impl HealthMachine {
    pub fn new(fail_threshold: u32, recover_threshold: u32) -> HealthMachine {
        HealthMachine {
            state: NodeState::Healthy,
            fails: 0,
            oks: 0,
            fail_threshold: fail_threshold.max(1),
            recover_threshold: recover_threshold.max(1),
        }
    }

    pub fn state(&self) -> NodeState {
        self.state
    }

    /// Feed one probe result; `Some((from, to))` when the state moved.
    pub fn observe(&mut self, ok: bool) -> Option<(NodeState, NodeState)> {
        let from = self.state;
        if ok {
            self.fails = 0;
            self.state = match self.state {
                NodeState::Healthy | NodeState::Suspect => NodeState::Healthy,
                NodeState::Down => {
                    self.oks = 1;
                    NodeState::Recovering
                }
                NodeState::Recovering => {
                    self.oks += 1;
                    if self.oks >= self.recover_threshold {
                        NodeState::Healthy
                    } else {
                        NodeState::Recovering
                    }
                }
            };
        } else {
            self.oks = 0;
            self.fails += 1;
            self.state = match self.state {
                NodeState::Healthy | NodeState::Suspect => {
                    if self.fails >= self.fail_threshold {
                        NodeState::Down
                    } else {
                        NodeState::Suspect
                    }
                }
                // one bad probe mid-recovery sends the node straight
                // back down: flapping must not reach the routable set
                NodeState::Recovering | NodeState::Down => NodeState::Down,
            };
        }
        (from != self.state).then_some((from, self.state))
    }
}

/// One active `/healthz` probe on its own short-deadline connection.
/// `Some(node_id)` on a `200` (the id comes from the gateway's
/// `x-macformer-node` response header); `None` on refusal, timeout,
/// or any non-200 (a draining gateway answers 503 and is treated as
/// going away — exactly what failover wants).
pub fn probe_once(addr: &str, timeout: Duration) -> Option<String> {
    let sock = addr.to_socket_addrs().ok()?.next()?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout).ok()?;
    stream.set_read_timeout(Some(timeout)).ok()?;
    stream.set_write_timeout(Some(timeout)).ok()?;
    let _ = stream.set_nodelay(true);
    stream
        .write_all(b"GET /healthz HTTP/1.1\r\nHost: router\r\nConnection: close\r\n\r\n")
        .ok()?;
    let mut buf = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    // `Connection: close` bounds the read; cap it anyway
    while buf.len() < 16 * 1024 {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(_) => break,
        }
    }
    let head_end = buf.windows(4).position(|w| w == b"\r\n\r\n")?;
    let head = std::str::from_utf8(&buf[..head_end]).ok()?;
    let mut lines = head.split("\r\n");
    let status: u16 = lines.next()?.split_whitespace().nth(1)?.parse().ok()?;
    if status != 200 {
        return None;
    }
    let node = lines
        .filter_map(|l| l.split_once(':'))
        .find(|(name, _)| name.eq_ignore_ascii_case("x-macformer-node"))
        .map(|(_, v)| v.trim().to_string())
        .unwrap_or_default();
    Some(node)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_node_goes_suspect_then_down_at_the_threshold() {
        let mut m = HealthMachine::new(3, 2);
        assert_eq!(m.state(), NodeState::Healthy);
        assert_eq!(m.observe(false), Some((NodeState::Healthy, NodeState::Suspect)));
        assert!(m.state().routable(), "one missed probe must not unroute a node");
        assert_eq!(m.observe(false), None, "still suspect below the threshold");
        assert_eq!(m.observe(false), Some((NodeState::Suspect, NodeState::Down)));
        assert!(!m.state().routable());
    }

    #[test]
    fn a_single_success_clears_suspicion() {
        let mut m = HealthMachine::new(3, 2);
        m.observe(false);
        assert_eq!(m.observe(true), Some((NodeState::Suspect, NodeState::Healthy)));
        // the failure counter reset: two more misses still only suspect
        m.observe(false);
        assert_eq!(m.state(), NodeState::Suspect);
        m.observe(false);
        assert_eq!(m.state(), NodeState::Suspect);
    }

    #[test]
    fn recovery_needs_consecutive_successes_and_flapping_restarts_it() {
        let mut m = HealthMachine::new(1, 3);
        assert_eq!(m.observe(false), Some((NodeState::Healthy, NodeState::Down)));
        assert_eq!(m.observe(true), Some((NodeState::Down, NodeState::Recovering)));
        assert!(!m.state().routable(), "recovering nodes take no traffic yet");
        assert_eq!(m.observe(true), None, "two of three successes: still recovering");
        // a flap mid-recovery goes straight back down...
        assert_eq!(m.observe(false), Some((NodeState::Recovering, NodeState::Down)));
        // ...and the success count starts over
        m.observe(true);
        m.observe(true);
        assert_eq!(m.state(), NodeState::Recovering);
        assert_eq!(m.observe(true), Some((NodeState::Recovering, NodeState::Healthy)));
    }

    #[test]
    fn probe_against_a_dead_port_is_none() {
        // a port from the dynamic range with nothing bound to it
        assert_eq!(probe_once("127.0.0.1:1", Duration::from_millis(100)), None);
    }
}
