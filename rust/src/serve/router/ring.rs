//! Seeded consistent-hash ring over the backend fleet.
//!
//! Each backend contributes `vnodes` points; a stream key walks the
//! ring clockwise to the first point whose backend passes the caller's
//! aliveness predicate. The ring itself is immutable — node health is
//! a *filter at lookup time*, so a backend coming back after a blip
//! reclaims exactly the arcs it owned before, and the death of one
//! node remaps only the keys that node owned (every other key keeps
//! hitting its old successor). All hashing is seeded and deterministic
//! so a restarted router rebuilds the identical ring.

/// `splitmix64` finalizer — the same mixer the serve stack uses for
/// jitter and node ids.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// FNV-1a over a byte string (backend addresses).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// The ring: `(point, backend index)` sorted by point.
pub struct Ring {
    points: Vec<(u64, usize)>,
}

impl Ring {
    /// Build the ring from the full backend list. `vnodes` points per
    /// backend; more points → smoother balance, linearly larger ring.
    pub fn build(seed: u64, backends: &[String], vnodes: usize) -> Ring {
        let mut points = Vec::with_capacity(backends.len() * vnodes);
        for (idx, addr) in backends.iter().enumerate() {
            let base = fnv1a(addr.as_bytes());
            for v in 0..vnodes as u64 {
                points.push((mix(seed ^ base ^ mix(v)), idx));
            }
        }
        points.sort_unstable();
        Ring { points }
    }

    /// The ring position of a public stream id.
    pub fn key(seed: u64, public_sid: u64) -> u64 {
        mix(seed ^ public_sid.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// First backend at or clockwise of `key` for which `alive[idx]`
    /// holds; `None` when no backend is routable.
    pub fn lookup(&self, key: u64, alive: &[bool]) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let start = self.points.partition_point(|&(h, _)| h < key);
        for off in 0..self.points.len() {
            let (_, idx) = self.points[(start + off) % self.points.len()];
            if alive.get(idx).copied().unwrap_or(false) {
                return Some(idx);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 9000 + i)).collect()
    }

    #[test]
    fn ring_is_deterministic_for_a_seed_and_differs_across_seeds() {
        let backends = addrs(3);
        let a = Ring::build(7, &backends, 64);
        let b = Ring::build(7, &backends, 64);
        let c = Ring::build(8, &backends, 64);
        let alive = vec![true; 3];
        let same = (0..256).all(|k| {
            a.lookup(Ring::key(7, k), &alive) == b.lookup(Ring::key(7, k), &alive)
        });
        assert!(same, "identical seeds must build identical rings");
        let moved = (0..256)
            .filter(|&k| a.lookup(Ring::key(7, k), &alive) != c.lookup(Ring::key(8, k), &alive))
            .count();
        assert!(moved > 0, "a different seed should shuffle at least some keys");
    }

    #[test]
    fn every_backend_owns_a_fair_share_of_keys() {
        let backends = addrs(4);
        let ring = Ring::build(42, &backends, 64);
        let alive = vec![true; 4];
        let mut counts = [0usize; 4];
        for k in 0..4000u64 {
            counts[ring.lookup(Ring::key(42, k), &alive).expect("routable")] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            // fair share is 1000; 64 vnodes keeps every node within ~2x
            assert!((400..=2200).contains(&c), "backend {i} owns {c} of 4000 keys");
        }
    }

    #[test]
    fn killing_one_node_remaps_only_its_own_keys() {
        let backends = addrs(5);
        let ring = Ring::build(3, &backends, 64);
        let alive = vec![true; 5];
        let before: Vec<usize> =
            (0..2000u64).map(|k| ring.lookup(Ring::key(3, k), &alive).unwrap()).collect();
        let mut degraded = alive.clone();
        degraded[2] = false;
        for (k, &owner) in before.iter().enumerate() {
            let after = ring.lookup(Ring::key(3, k as u64), &degraded).unwrap();
            if owner != 2 {
                assert_eq!(after, owner, "key {k} moved although its owner survived");
            } else {
                assert_ne!(after, 2, "key {k} still routed to the dead node");
            }
        }
    }

    #[test]
    fn lookup_with_no_routable_backend_is_none() {
        let backends = addrs(2);
        let ring = Ring::build(1, &backends, 8);
        assert_eq!(ring.lookup(Ring::key(1, 0), &[false, false]), None);
        let empty = Ring::build(1, &[], 8);
        assert_eq!(empty.lookup(Ring::key(1, 0), &[]), None);
    }
}
