//! Multi-node serve: a dependency-free HTTP/1.1 router that fronts N
//! serve gateways (`serve::net::Server` processes) behind one address.
//!
//! ```text
//!                        ┌──────────────────────────────┐
//!   clients ──────────▶  │ router: workers + hash ring  │
//!   POST /v1/streams     │  r-K ──ring──▶ backend, s-N  │
//!   POST .../decode      └──┬─────────┬─────────┬───────┘
//!                           │ proxy   │ proxy   │ /healthz prober
//!                        gateway 0 gateway 1 gateway 2   (+ failover)
//!   ```
//!
//! Responsibilities, in one place each:
//!
//! - **Placement** ([`ring`]): new stream opens consistent-hash onto a
//!   routable backend (seeded virtual nodes, so a restarted router
//!   rebuilds the identical ring and a dead node remaps only its own
//!   streams). The router mints public ids `r-K` and keeps the
//!   `r-K → (backend, s-N)` map; everything else about the wire
//!   protocol passes through byte-faithfully.
//! - **Proxying** ([`proxy`]): per-(worker, backend) keep-alive
//!   connections relay stream routes and the chunked SSE decode body.
//!   Status, reason, `code` body, and `Retry-After` are relayed
//!   unmodified; the backend's `x-macformer-node` id is echoed on
//!   every proxied response. Retryable backend answers (`429`, `503`
//!   with `Retry-After`) are retried on the same backend with the
//!   loadgen client's backoff discipline inside a small wall-clock
//!   budget, then passed through for the client to absorb.
//! - **Health** ([`health`]): an active `/healthz` prober drives a
//!   per-node `healthy → suspect → down → recovering` state machine.
//!   `down` triggers failover.
//! - **Migration** (here): live streams are exported from their
//!   backend (`GET /v1/streams/{sid}/export`) and imported on the
//!   ring successor (`POST /v1/streams/import`); streams on a *dead*
//!   backend are recovered by the successor straight from the dead
//!   node's durable store (the JSON import form). The public id is
//!   remapped in place — clients retrying on `503 migrating` resume
//!   against the successor without learning anything moved.
//! - **Chaos** ([`chaos`]): `run_kill_node` SIGKILLs one backend of a
//!   live fleet mid-load and requires survivors bit-identical to a
//!   never-died run, zero non-casualty 5xx, and every casualty stream
//!   migrated and resumed.
//!
//! Router-origin errors use the same JSON error shape as the gateway
//! (`{"error","message","retryable",...}`) with router-specific codes:
//! `no_backend` (no routable node), `backend_unreachable` (transport
//! failure towards the mapped node), `migrating` (the mapped node is
//! down and the stream has not landed on its successor yet) — all
//! retryable `503` + `Retry-After: 1`, so well-behaved clients absorb
//! failover with their existing backoff loop.

pub mod chaos;
pub mod health;
pub mod proxy;
pub mod ring;

pub use chaos::{run_kill_node, spawn_node, KillNodeReport};
pub use health::NodeState;

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::net::http::{Conn, HttpConfig, HttpError, Method, Request};
use super::net::{derive_node_id, error_json, wire};
use super::obs;
use health::HealthMachine;
use proxy::{BackendClient, RespHead};
use ring::Ring;

/// One backend gateway the router fronts.
#[derive(Clone, Debug)]
pub struct BackendSpec {
    /// `host:port` of the gateway.
    pub addr: String,
    /// The gateway's durable store, when the router is allowed to
    /// recover streams from it after the process dies. `None` means
    /// dead-node failover for this backend is impossible (its streams
    /// are lost if it dies without exporting).
    pub data_dir: Option<PathBuf>,
}

/// Router tuning. `Default` is sized for loopback fleets.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Listen address (`127.0.0.1:0` picks a free port).
    pub listen: String,
    pub workers: usize,
    /// Virtual nodes per backend on the hash ring.
    pub vnodes: usize,
    /// Seeds the ring, the public-id hash, and the router's node id.
    pub seed: u64,
    pub probe_interval: Duration,
    pub probe_timeout: Duration,
    /// Consecutive probe failures before a backend is `down`.
    pub fail_threshold: u32,
    /// Consecutive probe successes before a recovering backend is
    /// routable again.
    pub recover_threshold: u32,
    /// Wall-clock budget for router-side retries of retryable backend
    /// answers; once spent, the answer passes through for the client
    /// to handle.
    pub retry_budget: Duration,
    pub http: HttpConfig,
    pub backends: Vec<BackendSpec>,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            listen: "127.0.0.1:0".into(),
            workers: 4,
            vnodes: 64,
            seed: 7,
            probe_interval: Duration::from_millis(20),
            probe_timeout: Duration::from_millis(250),
            fail_threshold: 5,
            recover_threshold: 3,
            retry_budget: Duration::from_millis(500),
            http: HttpConfig::default(),
            backends: Vec::new(),
        }
    }
}

/// Where a public stream currently lives.
#[derive(Clone)]
struct StreamEntry {
    backend: usize,
    /// The backend-side wire id (`s-N`), distinct per node.
    sid: String,
}

/// Per-backend runtime state shared between workers and the prober.
struct BackendSlot {
    addr: String,
    data_dir: Option<PathBuf>,
    /// [`NodeState::gauge`] encoding, written by the prober.
    state: AtomicU8,
    /// The backend's self-reported node id, learned from probes.
    node_id: Mutex<String>,
}

impl BackendSlot {
    fn state(&self) -> NodeState {
        match self.state.load(Ordering::SeqCst) {
            0 => NodeState::Down,
            1 => NodeState::Recovering,
            2 => NodeState::Suspect,
            _ => NodeState::Healthy,
        }
    }

    fn set_state(&self, s: NodeState) {
        self.state.store(s.gauge(), Ordering::SeqCst);
    }

    fn node_id(&self) -> String {
        self.node_id.lock().unwrap().clone()
    }
}

struct RouterShared {
    seed: u64,
    retry_budget: Duration,
    backends: Vec<BackendSlot>,
    ring: Ring,
    streams: Mutex<HashMap<u64, StreamEntry>>,
    next_pub: AtomicU64,
    /// Serializes migrations (failover and `/admin/migrate`) so two
    /// movers never race on one stream.
    migrate_lock: Mutex<()>,
    node_id: String,
    stop: AtomicBool,
    draining: AtomicBool,
    drain_requested: AtomicBool,
}

impl RouterShared {
    /// Routable snapshot for ring lookups.
    fn routable(&self) -> Vec<bool> {
        self.backends.iter().map(|b| b.state().routable()).collect()
    }

    fn entry(&self, pub_sid: u64) -> Option<StreamEntry> {
        self.streams.lock().unwrap().get(&pub_sid).cloned()
    }
}

/// A running router: worker pool + health prober, shut down
/// explicitly (or on drop).
pub struct Router {
    addr: SocketAddr,
    shared: Arc<RouterShared>,
    workers: Vec<JoinHandle<()>>,
    prober: Option<JoinHandle<()>>,
}

impl Router {
    /// Bind, spawn workers and the prober. Backends are assumed
    /// healthy until the prober says otherwise, so the router serves
    /// from the first request.
    pub fn start(cfg: RouterConfig) -> Result<Router> {
        if cfg.backends.is_empty() {
            bail!("router needs at least one backend");
        }
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding router on {}", cfg.listen))?;
        let addr = listener.local_addr().context("router local addr")?;
        let addrs: Vec<String> = cfg.backends.iter().map(|b| b.addr.clone()).collect();
        let ring = Ring::build(cfg.seed, &addrs, cfg.vnodes.max(1));
        let backends = cfg
            .backends
            .iter()
            .map(|b| BackendSlot {
                addr: b.addr.clone(),
                data_dir: b.data_dir.clone(),
                state: AtomicU8::new(NodeState::Healthy.gauge()),
                node_id: Mutex::new(String::new()),
            })
            .collect();
        let shared = Arc::new(RouterShared {
            seed: cfg.seed,
            retry_budget: cfg.retry_budget,
            backends,
            ring,
            streams: Mutex::new(HashMap::new()),
            next_pub: AtomicU64::new(0),
            migrate_lock: Mutex::new(()),
            node_id: derive_node_id(cfg.seed, &format!("router:{addr}")),
            stop: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            drain_requested: AtomicBool::new(false),
        });
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            let listener = listener.try_clone().context("cloning router listener")?;
            let shared = Arc::clone(&shared);
            let http = cfg.http;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("router-worker-{i}"))
                    .spawn(move || worker_loop(listener, shared, http))
                    .context("spawning router worker")?,
            );
        }
        let prober = {
            let shared = Arc::clone(&shared);
            let (interval, timeout) = (cfg.probe_interval, cfg.probe_timeout);
            let (fail_t, rec_t) = (cfg.fail_threshold, cfg.recover_threshold);
            Some(
                std::thread::Builder::new()
                    .name("router-prober".into())
                    .spawn(move || prober_loop(shared, interval, timeout, fail_t, rec_t))
                    .context("spawning router prober")?,
            )
        };
        log::info!("router {} listening on {addr} over {} backends", shared.node_id, addrs.len());
        Ok(Router { addr, shared, workers, prober })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn node_id(&self) -> &str {
        &self.shared.node_id
    }

    /// Refuse new stream opens; keep proxying admitted streams.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Did a client ask for a drain via `POST /admin/drain`?
    pub fn drain_requested(&self) -> bool {
        self.shared.drain_requested.load(Ordering::SeqCst)
    }

    /// Snapshot of each backend: `(addr, state, node_id)`.
    pub fn backend_states(&self) -> Vec<(String, NodeState, String)> {
        self.shared
            .backends
            .iter()
            .map(|b| (b.addr.clone(), b.state(), b.node_id()))
            .collect()
    }

    /// Snapshot of the public-stream map: `(public id, backend idx)`.
    pub fn stream_map(&self) -> Vec<(u64, usize)> {
        self.shared.streams.lock().unwrap().iter().map(|(&k, e)| (k, e.backend)).collect()
    }

    /// Stop accepting and join every thread.
    pub fn shutdown(mut self) {
        self.stop_threads();
    }

    fn stop_threads(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        // wake accept-blocked workers with throwaway connects
        for _ in 0..self.workers.len() {
            let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(100));
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(p) = self.prober.take() {
            let _ = p.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

// ---------------------------------------------------------------------------
// worker: accept + dispatch
// ---------------------------------------------------------------------------

fn worker_loop(listener: TcpListener, shared: Arc<RouterShared>, http: HttpConfig) {
    let mut clients: Vec<BackendClient> =
        shared.backends.iter().map(|b| BackendClient::new(&b.addr)).collect();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(10));
                continue;
            }
        };
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let _ = stream.set_nodelay(true);
        let conn = Conn::new(stream, http);
        serve_connection(conn, &shared, &mut clients);
    }
}

fn serve_connection(mut conn: Conn, shared: &RouterShared, clients: &mut [BackendClient]) {
    let mut scratch = String::new();
    loop {
        let req = match conn.read_request() {
            Ok(req) => req,
            Err(e) => {
                if let Some((status, reason, code)) = e.status() {
                    error_json(&mut scratch, code, &e.detail(), false, None);
                    let _ = conn.write_response(status, reason, "application/json", &scratch, &[]);
                }
                return;
            }
        };
        let keep_alive = req.keep_alive;
        // router-origin answers carry the router's own node id; the
        // proxied paths overwrite it with the backend's before writing
        conn.set_node_id(&shared.node_id);
        let served = dispatch(&mut conn, &req, shared, clients, &mut scratch);
        if served.is_err() || !keep_alive {
            return;
        }
    }
}

/// What a router path names. Stream actions are kept as the raw
/// suffix to forward; only `decode` needs special (SSE) treatment.
enum Route {
    Health,
    Metrics,
    Spec,
    Streams,
    Drain,
    Migrate,
    Stream { pub_sid: u64, action: Option<&'static str> },
    NotFound,
}

fn parse_route(path: &str) -> Route {
    match path {
        "/healthz" => return Route::Health,
        "/metrics" => return Route::Metrics,
        "/v1/spec" => return Route::Spec,
        "/v1/streams" => return Route::Streams,
        "/admin/drain" => return Route::Drain,
        "/admin/migrate" => return Route::Migrate,
        _ => {}
    }
    let Some(rest) = path.strip_prefix("/v1/streams/") else {
        return Route::NotFound;
    };
    let (id_part, action_part) = match rest.split_once('/') {
        Some((id, action)) => (id, Some(action)),
        None => (rest, None),
    };
    let Some(pub_sid) = id_part.strip_prefix("r-").and_then(|s| s.parse::<u64>().ok()) else {
        return Route::NotFound;
    };
    let action = match action_part {
        None => None,
        Some("prefill") => Some("prefill"),
        Some("decode") => Some("decode"),
        Some("arm_fault") => Some("arm_fault"),
        Some("hibernate") => Some("hibernate"),
        Some("export") => Some("export"),
        Some(_) => return Route::NotFound,
    };
    Route::Stream { pub_sid, action }
}

fn dispatch(
    conn: &mut Conn,
    req: &Request,
    shared: &RouterShared,
    clients: &mut [BackendClient],
    scratch: &mut String,
) -> Result<(), HttpError> {
    let route = parse_route(conn.path(req));
    match (req.method, route) {
        (Method::Get, Route::Health) => health(conn, shared, scratch),
        (Method::Get, Route::Metrics) => metrics(conn, shared, scratch),
        (Method::Get, Route::Spec) => proxy_spec(conn, shared, clients, scratch),
        (Method::Post, Route::Streams) => open_stream(conn, req, shared, clients, scratch),
        (Method::Post, Route::Drain) => {
            shared.draining.store(true, Ordering::SeqCst);
            shared.drain_requested.store(true, Ordering::SeqCst);
            conn.write_response(200, "OK", "application/json", "{\"draining\":true}", &[])
        }
        (Method::Post, Route::Migrate) => admin_migrate(conn, req, shared, scratch),
        (Method::Get, Route::Stream { pub_sid, action: None }) => {
            stream_op(conn, req, shared, clients, pub_sid, None, scratch)
        }
        (Method::Get, Route::Stream { pub_sid, action: Some("export") }) => {
            stream_op(conn, req, shared, clients, pub_sid, Some("export"), scratch)
        }
        (Method::Post, Route::Stream { pub_sid, action: Some(a) }) if a != "export" => {
            stream_op(conn, req, shared, clients, pub_sid, Some(a), scratch)
        }
        (Method::Delete, Route::Stream { pub_sid, action: None }) => {
            stream_op(conn, req, shared, clients, pub_sid, None, scratch)
        }
        _ => {
            error_json(scratch, "not_found", "no such route", false, None);
            conn.write_response(404, "Not Found", "application/json", scratch, &[])
        }
    }
}

// ---------------------------------------------------------------------------
// router-origin answers
// ---------------------------------------------------------------------------

/// A retryable router-origin `503` (`Retry-After: 1`): the shape
/// clients already absorb in their backoff loop.
fn unavailable(
    conn: &mut Conn,
    scratch: &mut String,
    code: &str,
    msg: &str,
) -> Result<(), HttpError> {
    error_json(scratch, code, msg, true, Some(1));
    conn.write_response(
        503,
        "Service Unavailable",
        "application/json",
        scratch,
        &[("Retry-After", "1")],
    )
}

fn health(conn: &mut Conn, shared: &RouterShared, scratch: &mut String) -> Result<(), HttpError> {
    use std::fmt::Write as _;
    let draining = shared.draining.load(Ordering::SeqCst);
    scratch.clear();
    let _ = write!(
        scratch,
        "{{\"status\":\"{}\",\"node_id\":\"{}\",\"role\":\"router\",\"streams\":{}",
        if draining { "draining" } else { "ready" },
        shared.node_id,
        shared.streams.lock().unwrap().len()
    );
    scratch.push_str(",\"backends\":[");
    for (i, b) in shared.backends.iter().enumerate() {
        if i > 0 {
            scratch.push(',');
        }
        let _ = write!(
            scratch,
            "{{\"addr\":\"{}\",\"state\":\"{}\",\"node_id\":\"{}\"}}",
            b.addr,
            b.state().name(),
            b.node_id()
        );
    }
    scratch.push_str("]}");
    if draining {
        conn.write_response(503, "Service Unavailable", "application/json", scratch, &[])
    } else {
        conn.write_response(200, "OK", "application/json", scratch, &[])
    }
}

/// Hand-rolled Prometheus exposition: the router has no engine
/// telemetry, so it renders its own counters and per-backend health
/// gauges in the same text format the gateways use.
fn metrics(conn: &mut Conn, shared: &RouterShared, scratch: &mut String) -> Result<(), HttpError> {
    use std::fmt::Write as _;
    scratch.clear();
    let counters: [(&str, &str, u64); 5] = [
        (
            "macformer_router_migrations_total",
            "Streams moved between backends (failover or admin).",
            obs::router_migrations(),
        ),
        (
            "macformer_router_migration_failures_total",
            "Streams the router could not relocate.",
            obs::router_migration_failures(),
        ),
        (
            "macformer_router_proxied_requests_total",
            "Requests relayed to a backend.",
            obs::router_proxied_requests(),
        ),
        (
            "macformer_router_proxied_bytes_total",
            "Response-body bytes relayed from backends.",
            obs::router_proxied_bytes(),
        ),
        (
            "macformer_router_retries_total",
            "Retryable backend answers the router retried itself.",
            obs::router_retries(),
        ),
    ];
    for (name, help, value) in counters {
        let _ = write!(scratch, "# HELP {name} {help}\n# TYPE {name} counter\n{name} {value}\n");
    }
    scratch.push_str(
        "# HELP macformer_router_backend_health Backend state: 0 down, 1 recovering, 2 suspect, 3 healthy.\n\
         # TYPE macformer_router_backend_health gauge\n",
    );
    for b in &shared.backends {
        let _ = writeln!(
            scratch,
            "macformer_router_backend_health{{backend=\"{}\",node=\"{}\"}} {}",
            obs::prom::escape_label(&b.addr),
            obs::prom::escape_label(&b.node_id()),
            b.state().gauge()
        );
    }
    let _ = write!(
        scratch,
        "# HELP macformer_router_streams Public streams currently mapped.\n\
         # TYPE macformer_router_streams gauge\n\
         macformer_router_streams {}\n",
        shared.streams.lock().unwrap().len()
    );
    let classes = obs::http_responses();
    scratch.push_str(
        "# HELP macformer_http_responses_total Responses served by the router, by status class.\n\
         # TYPE macformer_http_responses_total counter\n",
    );
    for (i, label) in ["other", "1xx", "2xx", "3xx", "4xx", "5xx"].iter().enumerate() {
        let _ = writeln!(
            scratch,
            "macformer_http_responses_total{{class=\"{label}\"}} {}",
            classes[i]
        );
    }
    conn.write_response(200, "OK", obs::prom::CONTENT_TYPE, scratch, &[])
}

// ---------------------------------------------------------------------------
// proxying
// ---------------------------------------------------------------------------

/// Is this a backend answer the router should retry itself?
fn retryable(head: &RespHead) -> bool {
    head.status == 429 || (head.status == 503 && head.retry_after.is_some())
}

/// Budgeted retry pacing for one proxied request.
struct RetryClock {
    started: Instant,
    attempt: usize,
    budget: Duration,
}

impl RetryClock {
    fn new(budget: Duration) -> RetryClock {
        RetryClock { started: Instant::now(), attempt: 0, budget }
    }

    /// Sleep for the next backoff if it fits the budget; `false`
    /// means the budget is spent and the caller must answer now.
    fn try_again(&mut self, retry_after: Option<u64>, salt: u64) -> bool {
        let wait = Duration::from_millis(proxy::backoff_ms(self.attempt, retry_after, salt));
        if self.started.elapsed() + wait > self.budget {
            return false;
        }
        self.attempt += 1;
        std::thread::sleep(wait);
        true
    }
}

/// Relay a fixed-length backend response byte-faithfully: status,
/// reason, body, `Retry-After`, export's hibernation marker, and the
/// backend's node id.
fn relay_fixed(conn: &mut Conn, head: &RespHead, body: &[u8]) -> Result<(), HttpError> {
    conn.set_node_id(&head.node);
    let mut extra: Vec<(&str, &str)> = Vec::new();
    if let Some(ra) = head.retry_after.as_deref() {
        extra.push(("Retry-After", ra));
    }
    if let Some(h) = head.hibernated.as_deref() {
        extra.push(("x-macformer-hibernated", h));
    }
    let ct = if head.content_type.is_empty() { "application/json" } else { &head.content_type };
    obs::add_router_proxied(body.len() as u64);
    conn.write_response_bytes(head.status, &head.reason, ct, body, &extra)
}

/// One forwarded request with a fully-read fixed body. Chunked
/// answers are a protocol violation on these routes.
fn forward_fixed(
    client: &mut BackendClient,
    method: &str,
    path: &str,
    req_id: &[u8],
    body: &[u8],
) -> Result<(RespHead, Vec<u8>)> {
    let head = client.request(method, path, req_id, body)?;
    if head.chunked {
        client.disconnect();
        bail!("unexpected chunked response from backend on {path}");
    }
    let body = client.read_body(head.content_length)?;
    Ok((head, body))
}

/// `GET /v1/spec`: relayed from any routable backend (every node in a
/// fleet serves the same engine spec — the loadgen client checks it
/// against its own config before driving load).
fn proxy_spec(
    conn: &mut Conn,
    shared: &RouterShared,
    clients: &mut [BackendClient],
    scratch: &mut String,
) -> Result<(), HttpError> {
    let alive = shared.routable();
    let Some(target) = shared.ring.lookup(Ring::key(shared.seed, 0), &alive) else {
        return unavailable(conn, scratch, "no_backend", "no routable backend");
    };
    match forward_fixed(&mut clients[target], "GET", "/v1/spec", conn.request_id(), b"") {
        Ok((head, body)) => relay_fixed(conn, &head, &body),
        Err(e) => {
            log::debug!("router: spec relay to {} failed: {e:#}", shared.backends[target].addr);
            unavailable(conn, scratch, "backend_unreachable", "backend did not answer")
        }
    }
}

/// `POST /v1/streams`: place the new stream on the ring, open it on
/// the chosen backend, remember the mapping, answer the public id.
fn open_stream(
    conn: &mut Conn,
    req: &Request,
    shared: &RouterShared,
    clients: &mut [BackendClient],
    scratch: &mut String,
) -> Result<(), HttpError> {
    if shared.draining.load(Ordering::SeqCst) {
        error_json(scratch, "draining", "router is draining; retry elsewhere", true, Some(1));
        return conn.write_response(
            503,
            "Service Unavailable",
            "application/json",
            scratch,
            &[("Retry-After", "1")],
        );
    }
    let body = conn.body(req).to_vec();
    let pub_sid = shared.next_pub.fetch_add(1, Ordering::SeqCst);
    let key = Ring::key(shared.seed, pub_sid);
    let salt = conn.request_id_hash() ^ pub_sid;
    let mut clock = RetryClock::new(shared.retry_budget);
    loop {
        let alive = shared.routable();
        let Some(target) = shared.ring.lookup(key, &alive) else {
            return unavailable(conn, scratch, "no_backend", "no routable backend");
        };
        match forward_fixed(&mut clients[target], "POST", "/v1/streams", conn.request_id(), &body)
        {
            Err(e) => {
                log::debug!(
                    "router: open on {} failed: {e:#}",
                    shared.backends[target].addr
                );
                if clock.try_again(Some(1), salt) {
                    continue; // aliveness is re-read; the prober may reroute us
                }
                return unavailable(conn, scratch, "backend_unreachable", "backend did not answer");
            }
            Ok((head, resp)) => {
                if retryable(&head) && clock.try_again(head.retry_after_ticks(), salt) {
                    obs::add_router_retry();
                    continue;
                }
                if head.status != 201 {
                    return relay_fixed(conn, &head, &resp);
                }
                let Some(backend_sid) = sid_from_json(&resp) else {
                    log::warn!("router: open on {} answered 201 without a stream id",
                        shared.backends[target].addr);
                    return unavailable(conn, scratch, "backend_unreachable", "malformed open ack");
                };
                shared
                    .streams
                    .lock()
                    .unwrap()
                    .insert(pub_sid, StreamEntry { backend: target, sid: backend_sid });
                conn.set_node_id(&head.node);
                obs::add_router_proxied(resp.len() as u64);
                scratch.clear();
                use std::fmt::Write as _;
                let _ = write!(scratch, "{{\"stream\":\"r-{pub_sid}\"}}");
                return conn.write_response(201, "Created", "application/json", scratch, &[]);
            }
        }
    }
}

/// Any `/v1/streams/r-N[...]` request: resolve the mapping, rewrite
/// the path to the backend's own id, relay. `decode` streams SSE;
/// everything else is fixed-length.
#[allow(clippy::too_many_arguments)]
fn stream_op(
    conn: &mut Conn,
    req: &Request,
    shared: &RouterShared,
    clients: &mut [BackendClient],
    pub_sid: u64,
    action: Option<&'static str>,
    scratch: &mut String,
) -> Result<(), HttpError> {
    let method = match req.method {
        Method::Get => "GET",
        Method::Post => "POST",
        Method::Delete => "DELETE",
        Method::Other => {
            error_json(scratch, "not_found", "no such route", false, None);
            return conn.write_response(404, "Not Found", "application/json", scratch, &[]);
        }
    };
    let body = conn.body(req).to_vec();
    let salt = conn.request_id_hash() ^ pub_sid;
    let mut clock = RetryClock::new(shared.retry_budget);
    loop {
        // re-resolved every attempt: a migration may remap mid-retry
        let Some(entry) = shared.entry(pub_sid) else {
            error_json(scratch, "unknown_stream", "no such stream", false, None);
            return conn.write_response(404, "Not Found", "application/json", scratch, &[]);
        };
        let slot = &shared.backends[entry.backend];
        if !slot.state().routable() {
            if clock.try_again(Some(1), salt) {
                continue;
            }
            return unavailable(conn, scratch, "migrating", "stream is relocating; retry");
        }
        let path = match action {
            None => format!("/v1/streams/{}", entry.sid),
            Some(a) => format!("/v1/streams/{}/{a}", entry.sid),
        };
        if action == Some("decode") {
            match relay_decode(conn, &mut clients[entry.backend], &path, &body, &mut clock, salt) {
                DecodeRelay::Served(r) => return r,
                DecodeRelay::BackendFailed => {
                    if clock.try_again(Some(1), salt) {
                        continue;
                    }
                    return unavailable(
                        conn,
                        scratch,
                        "backend_unreachable",
                        "backend did not answer",
                    );
                }
            }
        }
        match forward_fixed(&mut clients[entry.backend], method, &path, conn.request_id(), &body) {
            Err(e) => {
                log::debug!("router: {method} {path} on {} failed: {e:#}", slot.addr);
                if clock.try_again(Some(1), salt) {
                    continue;
                }
                return unavailable(conn, scratch, "backend_unreachable", "backend did not answer");
            }
            Ok((head, resp)) => {
                if retryable(&head) && clock.try_again(head.retry_after_ticks(), salt) {
                    obs::add_router_retry();
                    continue;
                }
                if method == "DELETE" && matches!(head.status, 200 | 404) {
                    shared.streams.lock().unwrap().remove(&pub_sid);
                }
                return relay_fixed(conn, &head, &resp);
            }
        }
    }
}

/// What happened to one decode relay attempt.
enum DecodeRelay {
    /// An answer went to the client (SSE relayed, error passed
    /// through, or the client connection broke — in every case the
    /// request is over).
    Served(Result<(), HttpError>),
    /// The backend could not be reached / answered retryably and the
    /// clock still has budget; the caller re-resolves and retries.
    BackendFailed,
}

/// Relay one decode: chunked SSE pass-through on success, fixed error
/// pass-through otherwise. Once the `200` head is committed to the
/// client, a backend death can only be surfaced by cutting the client
/// connection — the client's own retry/resume discipline takes over.
fn relay_decode(
    conn: &mut Conn,
    client: &mut BackendClient,
    path: &str,
    body: &[u8],
    clock: &mut RetryClock,
    salt: u64,
) -> DecodeRelay {
    let req_id: Vec<u8> = conn.request_id().to_vec();
    let head = match client.request("POST", path, &req_id, body) {
        Ok(h) => h,
        Err(_) => return DecodeRelay::BackendFailed,
    };
    if !head.chunked {
        let resp = match client.read_body(head.content_length) {
            Ok(b) => b,
            Err(_) => return DecodeRelay::BackendFailed,
        };
        if retryable(&head) {
            // let the caller's loop decide: it owns the clock
            if clock.try_again(head.retry_after_ticks(), salt) {
                obs::add_router_retry();
                return DecodeRelay::BackendFailed;
            }
        }
        return DecodeRelay::Served(relay_fixed(conn, &head, &resp));
    }
    conn.set_node_id(&head.node);
    if let Err(e) = conn.begin_chunked(&head.content_type) {
        client.disconnect();
        return DecodeRelay::Served(Err(e));
    }
    let mut relayed = 0u64;
    loop {
        match client.read_chunk() {
            Ok(Some(payload)) => {
                let Ok(text) = std::str::from_utf8(&payload) else {
                    client.disconnect();
                    return DecodeRelay::Served(Err(HttpError::Closed));
                };
                relayed += payload.len() as u64;
                if let Err(e) = conn.write_chunk(text) {
                    client.disconnect();
                    return DecodeRelay::Served(Err(e));
                }
            }
            Ok(None) => break,
            // mid-stream backend death after the committed 200: cut
            // the client off so it sees a broken stream, not silence
            Err(_) => return DecodeRelay::Served(Err(HttpError::Closed)),
        }
    }
    obs::add_router_proxied(relayed);
    DecodeRelay::Served(conn.end_chunked())
}

fn sid_from_json(body: &[u8]) -> Option<String> {
    let mut scan = wire::Scan::object(body).ok()?;
    let mut sid = None;
    while let Some(key) = scan.next_key().ok()? {
        match key {
            b"stream" => sid = Some(scan.str_value("stream").ok()?.to_string()),
            _ => scan.skip_value().ok()?,
        }
    }
    sid
}

// ---------------------------------------------------------------------------
// migration
// ---------------------------------------------------------------------------

/// `POST /admin/migrate {"stream":"r-N"}`: move one stream off its
/// current backend onto its ring successor, live (export → import)
/// when the source is routable, from the durable store otherwise.
fn admin_migrate(
    conn: &mut Conn,
    req: &Request,
    shared: &RouterShared,
    scratch: &mut String,
) -> Result<(), HttpError> {
    let pub_sid = (|| {
        let mut scan = wire::Scan::object(conn.body(req)).ok()?;
        let mut sid = None;
        while let Some(key) = scan.next_key().ok()? {
            match key {
                b"stream" => {
                    let s = scan.str_value("stream").ok()?;
                    sid = s.strip_prefix("r-").and_then(|n| n.parse::<u64>().ok());
                    sid?;
                }
                _ => scan.skip_value().ok()?,
            }
        }
        sid
    })();
    let Some(pub_sid) = pub_sid else {
        error_json(scratch, "bad_body", "migrate JSON needs \"stream\":\"r-N\"", false, None);
        return conn.write_response(400, "Bad Request", "application/json", scratch, &[]);
    };
    match migrate_one(shared, pub_sid) {
        Ok(dest) => {
            use std::fmt::Write as _;
            scratch.clear();
            let _ = write!(
                scratch,
                "{{\"migrated\":\"r-{pub_sid}\",\"to\":\"{}\"}}",
                shared.backends[dest].addr
            );
            conn.write_response(200, "OK", "application/json", scratch, &[])
        }
        Err(MigrateError::UnknownStream) => {
            error_json(scratch, "unknown_stream", "no such stream", false, None);
            conn.write_response(404, "Not Found", "application/json", scratch, &[])
        }
        Err(MigrateError::Failed(msg)) => {
            error_json(scratch, "migration_failed", &msg, false, None);
            conn.write_response(502, "Bad Gateway", "application/json", scratch, &[])
        }
    }
}

enum MigrateError {
    UnknownStream,
    /// The stream could not be moved; the message says why. The
    /// failure is already counted and the mapping already dropped
    /// when the state is unrecoverable.
    Failed(String),
}

/// Move one public stream to its ring successor. Serialized under the
/// migrate lock; safe to call from the prober and workers alike (it
/// dials its own connections — migrations are rare).
fn migrate_one(shared: &RouterShared, pub_sid: u64) -> Result<usize, MigrateError> {
    let _guard = shared.migrate_lock.lock().unwrap();
    let Some(entry) = shared.entry(pub_sid) else {
        return Err(MigrateError::UnknownStream);
    };
    let source = entry.backend;
    let key = Ring::key(shared.seed, pub_sid);
    let mut alive = shared.routable();
    alive[source] = false;
    let Some(dest) = shared.ring.lookup(key, &alive) else {
        obs::add_router_migration_failure();
        return Err(MigrateError::Failed("no routable destination backend".into()));
    };
    let source_slot = &shared.backends[source];
    let result = if source_slot.state().routable() {
        migrate_live(shared, &entry, source, dest)
    } else {
        migrate_from_store(shared, &entry, source, dest)
    };
    match result {
        Ok(new_sid) => {
            let mut map = shared.streams.lock().unwrap();
            // the entry may only have been removed (DELETE) meanwhile;
            // remaps are serialized by the migrate lock
            if let Some(e) = map.get_mut(&pub_sid) {
                e.backend = dest;
                e.sid = new_sid;
            }
            drop(map);
            obs::add_router_migration();
            log::info!(
                "router: migrated r-{pub_sid} {} -> {}",
                source_slot.addr,
                shared.backends[dest].addr
            );
            Ok(dest)
        }
        Err(msg) => {
            obs::add_router_migration_failure();
            // the state is gone (live export consumed it, or the dead
            // store had nothing): a stale mapping would retry forever,
            // an honest 404 lets clients give up cleanly
            shared.streams.lock().unwrap().remove(&pub_sid);
            log::warn!("router: migration of r-{pub_sid} failed: {msg}");
            Err(MigrateError::Failed(msg))
        }
    }
}

/// Live migration: export the versioned state record from the source
/// (retrying `409 stream_busy` briefly — an in-flight decode batch
/// finishes within a tick or two), import it on the destination.
fn migrate_live(
    shared: &RouterShared,
    entry: &StreamEntry,
    source: usize,
    dest: usize,
) -> Result<String, String> {
    let mut src = BackendClient::new(&shared.backends[source].addr);
    let path = format!("/v1/streams/{}/export", entry.sid);
    let mut clock = RetryClock::new(shared.retry_budget);
    let (head, record) = loop {
        match forward_fixed(&mut src, "GET", &path, b"migrate", b"") {
            Err(e) => {
                if clock.try_again(Some(1), entry_salt(entry)) {
                    continue;
                }
                return Err(format!("export transport failed: {e:#}"));
            }
            Ok((head, body)) => {
                let busy = head.status == 409 || retryable(&head);
                if busy && clock.try_again(head.retry_after_ticks(), entry_salt(entry)) {
                    continue;
                }
                break (head, body);
            }
        }
    };
    if head.status != 200 {
        return Err(format!("export answered {}", head.status));
    }
    import_record(shared, dest, &record, entry)
}

/// Dead-node migration: the destination recovers the stream straight
/// from the dead backend's durable store (checkpoint + journal tail,
/// replayed through the normal fold path).
fn migrate_from_store(
    shared: &RouterShared,
    entry: &StreamEntry,
    source: usize,
    dest: usize,
) -> Result<String, String> {
    let Some(dir) = shared.backends[source].data_dir.as_ref() else {
        return Err(format!(
            "backend {} is down and has no known durable store",
            shared.backends[source].addr
        ));
    };
    use std::fmt::Write as _;
    let mut body = String::new();
    let _ = write!(body, "{{\"dir\":");
    let mut dirs = String::new();
    wire::write_str(&mut dirs, &dir.to_string_lossy());
    body.push_str(&dirs);
    let _ = write!(body, ",\"stream\":\"{}\"}}", entry.sid);
    import_record_body(shared, dest, body.as_bytes(), entry)
}

fn import_record(
    shared: &RouterShared,
    dest: usize,
    record: &[u8],
    entry: &StreamEntry,
) -> Result<String, String> {
    import_record_body(shared, dest, record, entry)
}

fn import_record_body(
    shared: &RouterShared,
    dest: usize,
    body: &[u8],
    entry: &StreamEntry,
) -> Result<String, String> {
    let mut dst = BackendClient::new(&shared.backends[dest].addr);
    let mut clock = RetryClock::new(shared.retry_budget);
    loop {
        match forward_fixed(&mut dst, "POST", "/v1/streams/import", b"migrate", body) {
            Err(e) => {
                if clock.try_again(Some(1), entry_salt(entry)) {
                    continue;
                }
                return Err(format!("import transport failed: {e:#}"));
            }
            Ok((head, resp)) => {
                if retryable(&head) && clock.try_again(head.retry_after_ticks(), entry_salt(entry))
                {
                    continue;
                }
                if head.status != 201 {
                    return Err(format!("import answered {}", head.status));
                }
                return sid_from_json(&resp)
                    .ok_or_else(|| "import ack carried no stream id".into());
            }
        }
    }
}

fn entry_salt(entry: &StreamEntry) -> u64 {
    entry.sid.bytes().fold(0xCBF2_9CE4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100_0000_01B3)
    })
}

// ---------------------------------------------------------------------------
// prober + failover
// ---------------------------------------------------------------------------

fn prober_loop(
    shared: Arc<RouterShared>,
    interval: Duration,
    timeout: Duration,
    fail_threshold: u32,
    recover_threshold: u32,
) {
    let mut machines: Vec<HealthMachine> = shared
        .backends
        .iter()
        .map(|_| HealthMachine::new(fail_threshold, recover_threshold))
        .collect();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        for (i, slot) in shared.backends.iter().enumerate() {
            if shared.stop.load(Ordering::SeqCst) {
                return;
            }
            let probe = health::probe_once(&slot.addr, timeout);
            if let Some(node) = &probe {
                if !node.is_empty() {
                    let mut id = slot.node_id.lock().unwrap();
                    if *id != *node {
                        *id = node.clone();
                    }
                }
            }
            if let Some((from, to)) = machines[i].observe(probe.is_some()) {
                slot.set_state(to);
                log::info!("router: backend {} {} -> {}", slot.addr, from.name(), to.name());
            }
        }
        // failover as a convergence sweep, not a one-shot on the Down
        // transition: a stream whose open was acked just before the
        // node died can land in the map *after* the transition fired,
        // and it still has to move
        for (i, slot) in shared.backends.iter().enumerate() {
            if shared.stop.load(Ordering::SeqCst) {
                return;
            }
            if slot.state() == NodeState::Down {
                failover_backend(&shared, i);
            }
        }
        std::thread::sleep(interval);
    }
}

/// Move every stream mapped to a now-dead backend onto its ring
/// successors. Failures are counted and logged; each stream is
/// independent.
fn failover_backend(shared: &RouterShared, dead: usize) {
    let victims: Vec<u64> = shared
        .streams
        .lock()
        .unwrap()
        .iter()
        .filter(|(_, e)| e.backend == dead)
        .map(|(&k, _)| k)
        .collect();
    if victims.is_empty() {
        return;
    }
    log::info!(
        "router: backend {} is down; migrating {} streams",
        shared.backends[dead].addr,
        victims.len()
    );
    for pub_sid in victims {
        match migrate_one(shared, pub_sid) {
            Ok(dest) => log::debug!(
                "router: failover moved r-{pub_sid} to {}",
                shared.backends[dest].addr
            ),
            Err(MigrateError::UnknownStream) => {} // closed meanwhile
            Err(MigrateError::Failed(msg)) => {
                log::warn!("router: failover of r-{pub_sid} failed: {msg}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_parse_and_reject_like_the_gateway() {
        assert!(matches!(parse_route("/healthz"), Route::Health));
        assert!(matches!(parse_route("/metrics"), Route::Metrics));
        assert!(matches!(parse_route("/admin/migrate"), Route::Migrate));
        assert!(matches!(parse_route("/v1/streams"), Route::Streams));
        assert!(matches!(
            parse_route("/v1/streams/r-12"),
            Route::Stream { pub_sid: 12, action: None }
        ));
        assert!(matches!(
            parse_route("/v1/streams/r-0/decode"),
            Route::Stream { pub_sid: 0, action: Some("decode") }
        ));
        assert!(matches!(
            parse_route("/v1/streams/r-3/export"),
            Route::Stream { pub_sid: 3, action: Some("export") }
        ));
        // backend-style ids and unknown actions don't resolve here
        assert!(matches!(parse_route("/v1/streams/s-1"), Route::NotFound));
        assert!(matches!(parse_route("/v1/streams/r-1/nope"), Route::NotFound));
        assert!(matches!(parse_route("/v1/streams/r-x"), Route::NotFound));
        assert!(matches!(parse_route("/nope"), Route::NotFound));
    }

    #[test]
    fn retry_clock_spends_its_budget_and_stops() {
        let mut clock = RetryClock::new(Duration::from_millis(30));
        let mut spins = 0;
        while clock.try_again(Some(1), 7) {
            spins += 1;
            assert!(spins < 100, "clock never gave up");
        }
        assert!(spins >= 1, "a 30ms budget admits at least one short retry");
        assert!(clock.started.elapsed() <= Duration::from_millis(300));
    }

    #[test]
    fn router_refuses_an_empty_fleet() {
        let err = Router::start(RouterConfig::default()).err().expect("must refuse");
        assert!(err.to_string().contains("at least one backend"), "{err:#}");
    }

    #[test]
    fn router_starts_stops_and_reports_health_over_the_wire() {
        use std::io::{Read as _, Write as _};
        let cfg = RouterConfig {
            workers: 2,
            // nothing listens there: the prober will mark it down,
            // which must not crash anything
            backends: vec![BackendSpec { addr: "127.0.0.1:9".into(), data_dir: None }],
            probe_interval: Duration::from_millis(5),
            probe_timeout: Duration::from_millis(50),
            fail_threshold: 1,
            ..RouterConfig::default()
        };
        let router = Router::start(cfg).expect("router start");
        let addr = router.local_addr();
        assert!(!router.node_id().is_empty());

        let mut s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(5))).expect("timeout");
        s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
            .expect("send");
        let mut text = String::new();
        let _ = s.read_to_string(&mut text);
        assert!(text.starts_with("HTTP/1.1 200"), "{text}");
        assert!(text.contains("\"role\":\"router\""), "{text}");
        assert!(text.contains("x-macformer-node:"), "router id missing: {text}");

        // the dead backend reaches `down` and health reports it
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let states = router.backend_states();
            if states[0].1 == NodeState::Down {
                break;
            }
            assert!(Instant::now() < deadline, "backend never marked down: {:?}", states[0].1);
            std::thread::sleep(Duration::from_millis(10));
        }
        router.shutdown();
    }
}
