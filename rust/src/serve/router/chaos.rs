//! Kill-node chaos: SIGKILL one backend of a live multi-node fleet
//! mid-load and prove the router's failover story end to end.
//!
//! The drill spawns `nodes` serve gateways as child processes (each on
//! its own durable data-dir), fronts them with an in-process
//! [`Router`], and drives `cfg.streams` concurrent clients through the
//! router exactly like the single-node kill-restart drill
//! ([`run_kill_restart`](crate::serve::net::run_kill_restart)). At a
//! seeded produced-token threshold it SIGKILLs the backend owning the
//! most streams. From there the router must do the rest on its own:
//! the prober marks the node down, every stream mapped to it is
//! recovered from the dead node's durable store onto its ring
//! successor, and the casualty clients — which saw their SSE cut
//! mid-decode — resume through the *same* router address and drain the
//! rest of their tokens from the successor.
//!
//! The verification bar is the same as every other chaos drill in this
//! repo: all wire outputs, before the kill and after the failover,
//! **bit-identical** to a single-stream replay that never saw a dead
//! node — on either SIMD arm. Non-casualty streams must never see a
//! 5xx they could not retry, and every casualty must be migrated
//! (`migrations >= casualties`, zero migration failures).

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::attn::AttentionSpec;
use crate::serve::loadgen::{generate_tokens, token_stride, LoadConfig};
use crate::serve::net::client::{
    check_spec, drive_to_kill, kill_point, resume_stream, KillPhase, ResumePhase, RetryCounts,
};
use crate::serve::obs;
use crate::util::json::Value;

use super::{health, BackendSpec, Router, RouterConfig};

/// Outcome of one [`run_kill_node`] drill. The CI router-smoke greps
/// `verified`, `non_casualty_5xx`, and `migrations` out of the JSON
/// form.
#[derive(Debug, Clone)]
pub struct KillNodeReport {
    pub nodes: usize,
    pub streams: usize,
    pub tokens_per_stream: usize,
    /// Seeded produced-token threshold at which the victim backend
    /// took its SIGKILL.
    pub kill_at_tokens: u64,
    /// Tokens actually streamed back when the kill landed.
    pub killed_at_tokens: u64,
    /// Address of the SIGKILL'd backend.
    pub killed_backend: String,
    /// Streams whose open was acked before the kill.
    pub admitted: usize,
    /// Streams mapped to the victim when the kill landed — the ones
    /// whose decode was cut and whose state had to migrate.
    pub casualties: usize,
    /// Admitted streams the fleet recovered (resume probe answered
    /// 200 — for casualties, through the ring successor).
    pub recovered: usize,
    /// Recovered streams that resumed decode to completion.
    pub resumed: usize,
    /// Token counts the resume probes reported, summed.
    pub recovered_tokens: u64,
    /// Streams the router moved off the dead node.
    pub migrations: u64,
    pub migration_failures: u64,
    pub http_429: u64,
    pub http_503_retried: u64,
    pub http_5xx: u64,
    /// Non-retryable 5xx seen by streams that were *not* mapped to the
    /// victim. The whole point of the router: this must be zero.
    pub non_casualty_5xx: u64,
    pub stream_errors: u64,
    /// Every admitted stream recovered, resumed, and matched the
    /// single-stream replay bit for bit; zero non-casualty 5xx; every
    /// casualty migrated.
    pub verified: bool,
    /// Wall-clock from the SIGKILL until no stream mapped to the dead
    /// node any more (detection + all migrations).
    pub recovery_ms: f64,
    pub elapsed_s: f64,
}

impl KillNodeReport {
    pub fn render(&self) -> String {
        format!(
            "serve/router kill-node: {} nodes, {} streams x {} tokens, SIGKILL at {} produced tokens\n\
             kill      backend {} died at {} streamed tokens holding {} of {} admitted streams\n\
             failover  {} migrations ({} failed), streams remapped in {:.0} ms\n\
             recover   {} / {} streams recovered ({} probed tokens), {} resumed\n\
             http      {} x 429 (retried), {} x 503 (retried), {} x 5xx ({} on non-casualty streams), {} stream errors\n\
             verify    {}",
            self.nodes,
            self.streams,
            self.tokens_per_stream,
            self.kill_at_tokens,
            self.killed_backend,
            self.killed_at_tokens,
            self.casualties,
            self.admitted,
            self.migrations,
            self.migration_failures,
            self.recovery_ms,
            self.recovered,
            self.admitted,
            self.recovered_tokens,
            self.resumed,
            self.http_429,
            self.http_503_retried,
            self.http_5xx,
            self.non_casualty_5xx,
            self.stream_errors,
            if self.verified {
                "bit-identical to a fleet where no node ever died"
            } else {
                "FAILED (see warnings above)"
            },
        )
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("nodes", Value::num(self.nodes as f64)),
            ("streams", Value::num(self.streams as f64)),
            ("tokens_per_stream", Value::num(self.tokens_per_stream as f64)),
            ("kill_at_tokens", Value::num(self.kill_at_tokens as f64)),
            ("killed_at_tokens", Value::num(self.killed_at_tokens as f64)),
            ("killed_backend", Value::str(&self.killed_backend)),
            ("admitted", Value::num(self.admitted as f64)),
            ("casualties", Value::num(self.casualties as f64)),
            ("recovered", Value::num(self.recovered as f64)),
            ("resumed", Value::num(self.resumed as f64)),
            ("recovered_tokens", Value::num(self.recovered_tokens as f64)),
            ("migrations", Value::num(self.migrations as f64)),
            ("migration_failures", Value::num(self.migration_failures as f64)),
            ("http_429", Value::num(self.http_429 as f64)),
            ("http_503_retried", Value::num(self.http_503_retried as f64)),
            ("http_5xx", Value::num(self.http_5xx as f64)),
            ("non_casualty_5xx", Value::num(self.non_casualty_5xx as f64)),
            ("stream_errors", Value::num(self.stream_errors as f64)),
            ("verified", Value::Bool(self.verified)),
            ("recovery_ms", Value::num(self.recovery_ms)),
            ("elapsed_s", Value::num(self.elapsed_s)),
        ])
    }
}

/// The child gateways, killable by index from the killer thread and
/// reaped unconditionally on drop (the victim is already dead by then;
/// killing it again is a no-op and the wait clears the zombie).
struct Fleet {
    children: Mutex<Vec<Child>>,
}

impl Fleet {
    fn kill_one(&self, idx: usize) {
        if let Some(child) = self.children.lock().unwrap().get_mut(idx) {
            let _ = child.kill();
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for child in self.children.get_mut().unwrap().iter_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}

/// Spawn one `macformer serve --listen` gateway on its own data-dir
/// and wait until `/healthz` answers ready. Unlike the kill-restart
/// spawn this passes `--workers` explicitly: gateway workers serve one
/// connection at a time, and behind a router every router worker may
/// pool a keep-alive connection to this node. Also the spawn path for
/// `macformer route --spawn N`.
pub fn spawn_node(cfg: &LoadConfig, data_dir: &Path, workers: usize) -> Result<(Child, String)> {
    std::fs::create_dir_all(data_dir)
        .with_context(|| format!("creating node dir {}", data_dir.display()))?;
    // clear stale durable state: "recovered" must mean this run's kill
    for entry in std::fs::read_dir(data_dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy().into_owned();
        if name == "checkpoint.macc"
            || name == "checkpoint.tmp"
            || name == "port.txt"
            || (name.starts_with("journal.") && name.ends_with(".macj"))
        {
            std::fs::remove_file(entry.path()).with_context(|| format!("clearing stale {name}"))?;
        }
    }
    let exe = std::env::current_exe().context("resolving the serve binary")?;
    let port_file = data_dir.join("port.txt");
    let mut child = Command::new(&exe)
        .arg("serve")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .arg("--port-file")
        .arg(&port_file)
        .arg("--data-dir")
        .arg(data_dir)
        .arg("--workers")
        .arg(workers.to_string())
        .arg("--kernel")
        .arg(cfg.kernel.name())
        .arg("--backend")
        .arg(cfg.backend.to_string())
        .arg("--head-dim")
        .arg(cfg.head_dim.to_string())
        .arg("--dv")
        .arg(cfg.dv.to_string())
        .arg("--features")
        .arg(cfg.num_features.to_string())
        .arg("--seed")
        .arg(cfg.seed.to_string())
        .arg("--streams")
        .arg(cfg.streams.to_string())
        .arg("--min-batch")
        .arg(cfg.min_batch.to_string())
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .spawn()
        .with_context(|| format!("spawning {} serve", exe.display()))?;
    let deadline = Instant::now() + Duration::from_secs(60);
    let addr = loop {
        if let Some(status) = child.try_wait()? {
            bail!("serve node exited during startup: {status}");
        }
        if Instant::now() > deadline {
            let _ = child.kill();
            let _ = child.wait();
            bail!("serve node wrote no port file within 60s");
        }
        match std::fs::read_to_string(&port_file) {
            Ok(s) if !s.trim().is_empty() => break format!("127.0.0.1:{}", s.trim()),
            _ => std::thread::sleep(Duration::from_millis(5)),
        }
    };
    loop {
        if Instant::now() > deadline {
            let _ = child.kill();
            let _ = child.wait();
            bail!("serve node on {addr} never answered /healthz ready");
        }
        if health::probe_once(&addr, Duration::from_millis(500)).is_some() {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    Ok((child, addr))
}

/// What the killer thread learned: which backend it shot, which public
/// streams were mapped there, how long the remap took, and whether the
/// map actually converged.
struct KillOutcome {
    victim: usize,
    casualties: Vec<u64>,
    killed_at: u64,
    recovery_ms: f64,
    remapped: bool,
}

/// Kill-node chaos over a router-fronted fleet; see the module docs
/// for the full choreography. `base_dir` gets one `node{i}` data-dir
/// per backend.
pub fn run_kill_node(cfg: &LoadConfig, base_dir: &Path, nodes: usize) -> Result<KillNodeReport> {
    if nodes < 2 {
        bail!("kill-node: needs at least 2 nodes (someone has to survive)");
    }
    if cfg.streams == 0 || cfg.tokens < 2 {
        bail!("kill-node: needs streams > 0 and at least 2 tokens per stream");
    }
    if cfg.prompt != 0 {
        bail!("kill-node: --prompt is not supported here (decode-only recovery drill)");
    }
    if cfg.faults.is_active() {
        bail!("kill-node: runs its own chaos; drop the --fault-* flags");
    }
    let tokens = generate_tokens(cfg);
    let kill_at = kill_point(cfg);
    let t0 = Instant::now();
    let mig0 = obs::router_migrations();
    let migf0 = obs::router_migration_failures();

    // the fleet: one gateway per node dir, each sized so the router's
    // whole worker pool plus the prober and a migration can connect
    log::info!(
        "kill-node: spawning {nodes} gateways under {}, SIGKILL at {kill_at} produced tokens",
        base_dir.display()
    );
    let node_workers = cfg.streams + 8;
    let mut children = Vec::with_capacity(nodes);
    let mut backends = Vec::with_capacity(nodes);
    for n in 0..nodes {
        let dir: PathBuf = base_dir.join(format!("node{n}"));
        match spawn_node(cfg, &dir, node_workers) {
            Ok((child, addr)) => {
                children.push(child);
                backends.push(BackendSpec { addr, data_dir: Some(dir) });
            }
            Err(e) => {
                // reap whatever came up before bailing
                drop(Fleet { children: Mutex::new(children) });
                return Err(e.context(format!("spawning node {n}")));
            }
        }
    }
    let fleet = Fleet { children: Mutex::new(children) };
    let addrs: Vec<String> = backends.iter().map(|b| b.addr.clone()).collect();

    let router = Router::start(RouterConfig {
        workers: cfg.streams + 4,
        seed: cfg.seed,
        probe_interval: Duration::from_millis(10),
        probe_timeout: Duration::from_millis(250),
        fail_threshold: 3,
        recover_threshold: 2,
        backends,
        ..RouterConfig::default()
    })?;
    let router_addr = router.local_addr().to_string();
    check_spec(cfg, &router_addr)?;

    // phase 1: drive all streams through the router; SIGKILL the
    // most-loaded backend at the seeded threshold, then watch the
    // stream map converge off the corpse
    let counter = AtomicU64::new(0);
    let killed = AtomicBool::new(false);
    let done = AtomicUsize::new(0);
    let (phase1, outcome) = std::thread::scope(|scope| {
        let addr = router_addr.as_str();
        let handles: Vec<_> = (0..cfg.streams)
            .map(|i| {
                let tokens = &tokens[i];
                let (counter, killed, done) = (&counter, &killed, &done);
                scope.spawn(move || drive_to_kill(addr, cfg, i, tokens, counter, killed, done))
            })
            .collect();
        let killer = scope.spawn(|| loop {
            if counter.load(Ordering::SeqCst) >= kill_at {
                let map = router.stream_map();
                let mut owned = vec![0usize; nodes];
                for &(_, b) in &map {
                    owned[b] += 1;
                }
                let victim = owned
                    .iter()
                    .enumerate()
                    .max_by_key(|&(_, c)| *c)
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                killed.store(true, Ordering::SeqCst);
                fleet.kill_one(victim);
                let struck = Instant::now();
                let casualties: Vec<u64> =
                    map.iter().filter(|&&(_, b)| b == victim).map(|&(s, _)| s).collect();
                // remap convergence: detection + every migration
                let deadline = struck + Duration::from_secs(30);
                let remapped = loop {
                    if !router.stream_map().iter().any(|&(_, b)| b == victim) {
                        break true;
                    }
                    if Instant::now() > deadline {
                        break false;
                    }
                    std::thread::sleep(Duration::from_millis(2));
                };
                return Some(KillOutcome {
                    victim,
                    casualties,
                    killed_at: counter.load(Ordering::SeqCst),
                    recovery_ms: struck.elapsed().as_secs_f64() * 1e3,
                    remapped,
                });
            }
            if done.load(Ordering::SeqCst) == cfg.streams {
                return None;
            }
            std::thread::sleep(Duration::from_micros(200));
        });
        let phase1: Vec<KillPhase> = handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| KillPhase {
                    sid: String::new(),
                    outs: Vec::new(),
                    produced: 0,
                    http: RetryCounts::default(),
                    error: Some("client thread panicked".into()),
                })
            })
            .collect();
        (phase1, killer.join().unwrap_or(None))
    });
    let Some(outcome) = outcome else {
        let first = phase1.iter().find_map(|p| p.error.clone()).unwrap_or_default();
        bail!(
            "kill-node: clients finished before the {kill_at}-token kill threshold \
             ({} produced); first error: {first:?}",
            counter.load(Ordering::SeqCst)
        );
    };
    if !outcome.remapped {
        log::warn!("kill-node: stream map never converged off the dead node within 30s");
    }

    // phase 2: resume every admitted stream through the SAME router —
    // casualties must land on the ring successor transparently
    log::info!(
        "kill-node: phase 2 — resuming {} streams after killing {}",
        cfg.streams,
        addrs[outcome.victim]
    );
    let phase2: Vec<ResumePhase> = std::thread::scope(|scope| {
        let addr = router_addr.as_str();
        let handles: Vec<_> = (0..cfg.streams)
            .map(|i| {
                let tokens = &tokens[i];
                let sid = phase1[i].sid.as_str();
                scope.spawn(move || {
                    if sid.is_empty() {
                        return ResumePhase {
                            probed: None,
                            outs: Vec::new(),
                            resumed_from: 0,
                            produced: 0,
                            http: RetryCounts::default(),
                            error: None,
                        };
                    }
                    resume_stream(addr, cfg, i, sid, tokens)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| ResumePhase {
                    probed: None,
                    outs: Vec::new(),
                    resumed_from: 0,
                    produced: 0,
                    http: RetryCounts::default(),
                    error: Some("client thread panicked".into()),
                })
            })
            .collect()
    });

    // verify: one deterministic replay covers both phases, same bar as
    // the single-node kill-restart drill
    let casualty_set: HashSet<u64> = outcome.casualties.iter().copied().collect();
    let is_casualty = |sid: &str| {
        sid.strip_prefix("r-")
            .and_then(|n| n.parse::<u64>().ok())
            .is_some_and(|n| casualty_set.contains(&n))
    };
    let (d, dv, stride) = (cfg.head_dim, cfg.dv, token_stride(cfg));
    let session = AttentionSpec::new(cfg.kernel)
        .head_dim(d)
        .num_features(cfg.num_features)
        .causal(true)
        .seed(cfg.seed)
        .backend(cfg.backend)
        .build()
        .context("kill-node: building the verification session")?;
    let mut stream_errors = 0u64;
    let mut admitted = 0usize;
    let mut recovered = 0usize;
    let mut resumed = 0usize;
    let mut recovered_tokens = 0u64;
    let mut non_casualty_5xx = 0u64;
    let mut outputs_ok = true;
    let mut row = vec![0.0f32; dv];
    for i in 0..cfg.streams {
        let (p1, p2) = (&phase1[i], &phase2[i]);
        if let Some(e) = &p1.error {
            log::warn!("kill-node: stream {i} failed before the kill: {e}");
            stream_errors += 1;
            continue;
        }
        if p1.sid.is_empty() {
            continue; // the kill beat the open ack: nothing to recover
        }
        admitted += 1;
        if !is_casualty(&p1.sid) {
            non_casualty_5xx += p1.http.http_5xx + p2.http.http_5xx;
        }
        if let Some(e) = &p2.error {
            log::warn!("kill-node: stream {i} ({}) failed to resume: {e}", p1.sid);
            stream_errors += 1;
            continue;
        }
        let Some(probe) = p2.probed else { continue };
        recovered += 1;
        recovered_tokens += probe;
        resumed += 1;
        let mut state = session.begin_decode(dv)?;
        let mut mismatched = false;
        for t in 0..cfg.tokens {
            let tok = &tokens[i][t * stride..(t + 1) * stride];
            state.append_token_into(&tok[..d], &tok[d..2 * d], &tok[2 * d..], &mut row)?;
            if t < p1.produced {
                for (a, b) in p1.outs[t * dv..(t + 1) * dv].iter().zip(&row) {
                    if a.to_bits() != b.to_bits() {
                        mismatched = true;
                    }
                }
            }
            if t >= p2.resumed_from {
                for (a, b) in p2.outs[t * dv..(t + 1) * dv].iter().zip(&row) {
                    if a.to_bits() != b.to_bits() {
                        mismatched = true;
                    }
                }
            }
        }
        if mismatched {
            log::warn!("kill-node: stream {i} ({}) diverged from the replay", p1.sid);
            outputs_ok = false;
        }
    }
    let http_429: u64 = phase1.iter().map(|p| p.http.http_429).sum::<u64>()
        + phase2.iter().map(|p| p.http.http_429).sum::<u64>();
    let http_503: u64 = phase1.iter().map(|p| p.http.http_503).sum::<u64>()
        + phase2.iter().map(|p| p.http.http_503).sum::<u64>();
    let http_5xx: u64 = phase1.iter().map(|p| p.http.http_5xx).sum::<u64>()
        + phase2.iter().map(|p| p.http.http_5xx).sum::<u64>();
    let migrations = obs::router_migrations().saturating_sub(mig0);
    let migration_failures = obs::router_migration_failures().saturating_sub(migf0);

    drop(router); // stop workers + prober before reaping the fleet
    drop(fleet);

    let verified = outputs_ok
        && stream_errors == 0
        && recovered == admitted
        && resumed == admitted
        && non_casualty_5xx == 0
        && migration_failures == 0
        && migrations >= outcome.casualties.len() as u64
        && outcome.remapped;
    Ok(KillNodeReport {
        nodes,
        streams: cfg.streams,
        tokens_per_stream: cfg.tokens,
        kill_at_tokens: kill_at,
        killed_at_tokens: outcome.killed_at,
        killed_backend: addrs[outcome.victim].clone(),
        admitted,
        casualties: outcome.casualties.len(),
        recovered,
        resumed,
        recovered_tokens,
        migrations,
        migration_failures,
        http_429,
        http_503_retried: http_503,
        http_5xx,
        non_casualty_5xx,
        stream_errors,
        verified,
        recovery_ms: outcome.recovery_ms,
        elapsed_s: t0.elapsed().as_secs_f64(),
    })
}
