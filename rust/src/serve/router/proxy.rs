//! The router's backend-side HTTP/1.1 client: one keep-alive
//! connection per (worker thread, backend), reused across proxied
//! requests so steady-state proxying adds no connection setup.
//!
//! Unlike the loadgen client this one is binary-clean (state-record
//! export bodies are not UTF-8), keeps the backend's exact status
//! *reason* and passthrough headers (`Retry-After`,
//! `x-macformer-node`, `x-macformer-hibernated`) so the router can
//! relay responses byte-faithfully, and exposes chunked reads for SSE
//! decode relay.
//!
//! Failure discipline: a pooled connection that dies on reuse is
//! retried **once** on a fresh connection (the backend may simply
//! have closed an idle keep-alive); a fresh connection that dies is a
//! real backend failure and surfaces as `Err` for the caller to map
//! to a retryable `503 backend_unreachable`.

use std::io::{Read as _, Write as _};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// How long a proxied backend read may stall before the router gives
/// up on the connection. Generous: decode SSE frames arrive far
/// faster than this on a live engine.
const READ_TIMEOUT: Duration = Duration::from_secs(30);
/// Loopback/LAN connect deadline; a backend that cannot accept within
/// this is treated as unreachable.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(1);

/// One parsed backend response head, with the raw header values the
/// router passes through verbatim.
pub struct RespHead {
    pub status: u16,
    pub reason: String,
    pub content_length: usize,
    pub chunked: bool,
    /// Raw `Retry-After` value, relayed unmodified.
    pub retry_after: Option<String>,
    pub content_type: String,
    /// The backend's `x-macformer-node` id (empty when absent).
    pub node: String,
    /// Raw `x-macformer-hibernated` value from an export response.
    pub hibernated: Option<String>,
}

impl RespHead {
    /// Parsed `Retry-After` ticks for the router's own backoff.
    pub fn retry_after_ticks(&self) -> Option<u64> {
        self.retry_after.as_deref().and_then(|v| v.trim().parse().ok())
    }
}

struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
    pos: usize,
}

impl Conn {
    fn connect(addr: &str) -> Result<Conn> {
        let sock = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving backend {addr}"))?
            .next()
            .with_context(|| format!("backend {addr} resolves to nothing"))?;
        let stream = TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT)
            .with_context(|| format!("connecting to backend {addr}"))?;
        stream.set_read_timeout(Some(READ_TIMEOUT))?;
        stream.set_write_timeout(Some(READ_TIMEOUT))?;
        let _ = stream.set_nodelay(true);
        Ok(Conn { stream, buf: Vec::with_capacity(4096), pos: 0 })
    }

    fn fill(&mut self) -> Result<()> {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        let mut chunk = [0u8; 4096];
        let n = self.stream.read(&mut chunk).context("reading from backend")?;
        if n == 0 {
            bail!("backend closed the connection mid-response");
        }
        self.buf.extend_from_slice(&chunk[..n]);
        Ok(())
    }

    /// One CRLF-terminated line (without the terminator).
    fn line(&mut self) -> Result<String> {
        loop {
            if let Some(off) = self.buf[self.pos..].windows(2).position(|w| w == b"\r\n") {
                let line =
                    String::from_utf8_lossy(&self.buf[self.pos..self.pos + off]).into_owned();
                self.pos += off + 2;
                return Ok(line);
            }
            self.fill()?;
        }
    }

    fn take(&mut self, n: usize) -> Result<Vec<u8>> {
        while self.buf.len() - self.pos < n {
            self.fill()?;
        }
        let out = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(out)
    }

    fn send(&mut self, method: &str, path: &str, req_id: &[u8], body: &[u8]) -> Result<()> {
        use std::fmt::Write as _;
        let mut head = String::with_capacity(256);
        let _ = write!(
            head,
            "{method} {path} HTTP/1.1\r\nHost: macformer-router\r\nContent-Length: {}\r\n",
            body.len()
        );
        if !req_id.is_empty() {
            // printable ASCII by the gateway's own sanitization
            head.push_str("x-request-id: ");
            head.push_str(std::str::from_utf8(req_id).unwrap_or(""));
            head.push_str("\r\n");
        }
        if !body.is_empty() {
            head.push_str("Content-Type: application/json\r\n");
        }
        head.push_str("\r\n");
        self.stream.write_all(head.as_bytes()).context("sending proxied request head")?;
        if !body.is_empty() {
            self.stream.write_all(body).context("sending proxied request body")?;
        }
        Ok(())
    }

    fn read_head(&mut self) -> Result<RespHead> {
        let status_line = self.line()?;
        let mut parts = status_line.splitn(3, ' ');
        let _version = parts.next();
        let status: u16 = parts
            .next()
            .and_then(|s| s.parse().ok())
            .with_context(|| format!("bad status line from backend: {status_line:?}"))?;
        let reason = parts.next().unwrap_or("").to_string();
        let mut head = RespHead {
            status,
            reason,
            content_length: 0,
            chunked: false,
            retry_after: None,
            content_type: String::new(),
            node: String::new(),
            hibernated: None,
        };
        loop {
            let line = self.line()?;
            if line.is_empty() {
                return Ok(head);
            }
            let Some((name, value)) = line.split_once(':') else { continue };
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                head.content_length =
                    value.parse().with_context(|| format!("bad Content-Length {value:?}"))?;
            } else if name.eq_ignore_ascii_case("transfer-encoding") {
                head.chunked = value.eq_ignore_ascii_case("chunked");
            } else if name.eq_ignore_ascii_case("retry-after") {
                head.retry_after = Some(value.to_string());
            } else if name.eq_ignore_ascii_case("content-type") {
                head.content_type = value.to_string();
            } else if name.eq_ignore_ascii_case("x-macformer-node") {
                head.node = value.to_string();
            } else if name.eq_ignore_ascii_case("x-macformer-hibernated") {
                head.hibernated = Some(value.to_string());
            }
        }
    }
}

/// The per-(worker, backend) client. Create once, reuse for the
/// worker's lifetime; it lazily (re)connects as needed.
pub struct BackendClient {
    addr: String,
    conn: Option<Conn>,
}

impl BackendClient {
    pub fn new(addr: &str) -> BackendClient {
        BackendClient { addr: addr.to_string(), conn: None }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Drop the pooled connection (after a transport error or a relay
    /// abandoned mid-body, when the stream position is unknown).
    pub fn disconnect(&mut self) {
        self.conn = None;
    }

    /// Send one request and read the response head. The caller *must*
    /// then consume the body — [`Self::read_body`] for fixed-length,
    /// [`Self::read_chunk`] to `None` for chunked — before the next
    /// request, or call [`Self::disconnect`].
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        req_id: &[u8],
        body: &[u8],
    ) -> Result<RespHead> {
        let pooled = self.conn.is_some();
        if self.conn.is_none() {
            self.conn = Some(Conn::connect(&self.addr)?);
        }
        let conn = self.conn.as_mut().expect("just connected");
        let first = conn.send(method, path, req_id, body).and_then(|()| conn.read_head());
        match first {
            Ok(head) => Ok(head),
            Err(e) if pooled => {
                // the backend closed an idle keep-alive under us;
                // one fresh-connection retry is safe because nothing
                // of the response was consumed
                log::debug!("router: pooled connection to {} died ({e:#}); redialing", self.addr);
                self.conn = None;
                self.conn = Some(Conn::connect(&self.addr)?);
                let conn = self.conn.as_mut().expect("just reconnected");
                conn.send(method, path, req_id, body)?;
                conn.read_head()
            }
            Err(e) => {
                self.conn = None;
                Err(e)
            }
        }
    }

    /// Read a fixed-length response body.
    pub fn read_body(&mut self, len: usize) -> Result<Vec<u8>> {
        let conn = self.conn.as_mut().context("read_body without a connection")?;
        match conn.take(len) {
            Ok(body) => Ok(body),
            Err(e) => {
                self.conn = None;
                Err(e)
            }
        }
    }

    /// Read one chunk of a chunked response; `None` is the final
    /// (empty) chunk — the response is complete and the connection
    /// stays reusable.
    pub fn read_chunk(&mut self) -> Result<Option<Vec<u8>>> {
        let conn = self.conn.as_mut().context("read_chunk without a connection")?;
        let r = (|| {
            let size_line = conn.line()?;
            let size = usize::from_str_radix(size_line.trim(), 16)
                .with_context(|| format!("bad chunk size {size_line:?}"))?;
            if size == 0 {
                let _trailer = conn.line()?;
                return Ok(None);
            }
            let payload = conn.take(size)?;
            let crlf = conn.take(2)?;
            if crlf != b"\r\n" {
                bail!("missing CRLF after chunk");
            }
            Ok(Some(payload))
        })();
        if r.is_err() {
            self.conn = None;
        }
        r
    }
}

/// Retry backoff for proxied retryable statuses: exponential from the
/// backend's `Retry-After` hint with deterministic splitmix jitter,
/// capped — the same discipline the loadgen client applies, so the
/// router never hammers a backpressured backend harder than a
/// well-behaved client would.
pub fn backoff_ms(attempt: usize, retry_after: Option<u64>, salt: u64) -> u64 {
    const CAP_MS: u64 = 50;
    let base = retry_after.unwrap_or(1).clamp(1, CAP_MS);
    let exp = base.saturating_mul(1u64 << attempt.min(6)).min(CAP_MS);
    let mut x = salt ^ (attempt as u64).wrapping_mul(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^= x >> 31;
    (exp + x % (exp / 2 + 1)).min(CAP_MS)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::TcpListener;

    #[test]
    fn backoff_grows_with_attempts_and_respects_the_cap() {
        let a0 = backoff_ms(0, Some(1), 7);
        let a6 = backoff_ms(6, Some(1), 7);
        assert!(a0 >= 1 && a0 <= 50, "{a0}");
        assert!(a6 <= 50, "{a6}");
        assert!(backoff_ms(0, Some(500), 7) <= 50, "hint must be clamped to the cap");
    }

    #[test]
    fn pooled_connection_death_is_retried_once_on_a_fresh_dial() {
        // a tiny server: answers the first request then slams the
        // connection, answers the second connection's request properly
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("addr").to_string();
        let server = std::thread::spawn(move || {
            // connection 1: one full response, then close (stale pool)
            let (mut s, _) = listener.accept().expect("accept 1");
            let mut sink = [0u8; 1024];
            let _ = std::io::Read::read(&mut s, &mut sink);
            s.write_all(b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\nok").expect("resp 1");
            drop(s);
            // connection 2: the redial after the pooled send fails
            let (mut s, _) = listener.accept().expect("accept 2");
            let _ = std::io::Read::read(&mut s, &mut sink);
            s.write_all(
                b"HTTP/1.1 503 Service Unavailable\r\nContent-Length: 5\r\nRetry-After: 3\r\nx-macformer-node: n-abc\r\n\r\nlater",
            )
            .expect("resp 2");
            // hold the socket open until the client has read it
            std::thread::sleep(Duration::from_millis(200));
        });

        let mut client = BackendClient::new(&addr);
        let head = client.request("GET", "/healthz", b"", b"").expect("first request");
        assert_eq!(head.status, 200);
        assert_eq!(client.read_body(head.content_length).expect("body"), b"ok");
        // the server closed; this pooled request must transparently redial
        let head = client.request("GET", "/healthz", b"rid-1", b"").expect("retried request");
        assert_eq!(head.status, 503);
        assert_eq!(head.reason, "Service Unavailable");
        assert_eq!(head.retry_after.as_deref(), Some("3"));
        assert_eq!(head.retry_after_ticks(), Some(3));
        assert_eq!(head.node, "n-abc");
        assert_eq!(client.read_body(head.content_length).expect("body"), b"later");
        server.join().expect("server thread");
    }

    #[test]
    fn fresh_connection_failure_is_an_error_not_a_loop() {
        let mut client = BackendClient::new("127.0.0.1:1");
        assert!(client.request("GET", "/healthz", b"", b"").is_err());
    }
}
