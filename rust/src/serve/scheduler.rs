//! [`Scheduler`] — the dynamic micro-batching tick.
//!
//! Each [`Scheduler::tick`] drains every pending submission in the pool
//! as one micro-batch:
//!
//! 1. **Gather** — the pending streams' staged q/k rows are scaled to
//!    score scale (`d^(-1/4)`, same as the single-stream path) into the
//!    scheduler's grow-only scratch, forming one `(g, 1, d)` problem
//!    set.
//! 2. **Feature step** — one
//!    [`AttentionSession::phi_rows_into`](crate::attn::AttentionSession::phi_rows_into)
//!    call per side (k, then q) maps the whole batch through the
//!    session's feature draw; on the host tier this shards rows over
//!    the persistent fastpath worker pool.
//! 3. **Fold** — each stream's `(S, z)` update + output row runs via
//!    [`for_each_index`](crate::fastpath::parallel::for_each_index)
//!    over the same pool, one stream per claimed index (disjoint slots,
//!    so the parallel fold is race-free and order-independent).
//!
//! Degenerate batches — fewer than
//! [`batch_threshold`](super::ServeConfig::batch_threshold) pending
//! streams — skip the gather/dispatch machinery and serve each stream
//! on the calling thread, with the same two-phase order per token
//! (both fallible phi rows first, then the infallible fold). Both
//! paths run the same per-row phi kernels and the same fold code as
//! [`append_token_into`](crate::attn::CausalState::append_token_into),
//! so serve outputs are **bit-identical** to lone single-stream
//! decodes (proved by `tests/serve_streams.rs` on both SIMD arms).
//!
//! Steady-state ticks make **zero heap allocations**: the scratch and
//! schedule vectors are grow-only, telemetry buckets are fixed-size,
//! and both dispatch layers are the allocation-free fastpath pool
//! (enforced by `tests/alloc_free.rs`).

use std::time::Instant;

use anyhow::Result;

use crate::fastpath::parallel::SendPtr;
use crate::fastpath::{grow, parallel, simd};

use super::pool::StreamPool;

/// What one [`Scheduler::tick`] did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TickStats {
    /// Streams served this tick (0 = idle tick).
    pub batch: usize,
    /// True when the degenerate-batch sequential path ran instead of
    /// the gathered `(g, 1, d)` step.
    pub sequential: bool,
}

/// The micro-batch scheduler. Owns only grow-only scratch, so one
/// scheduler can serve any number of pools (though one pool per
/// scheduler is the typical shape).
#[derive(Default)]
pub struct Scheduler {
    /// Slot indices scheduled this tick.
    scheduled: Vec<u32>,
    /// Scaled q rows, `g * d`.
    qs: Vec<f32>,
    /// Scaled k rows, `g * d`.
    ks: Vec<f32>,
    /// phi(q'), `g * D`.
    phi_q: Vec<f32>,
    /// phi(k'), `g * D`.
    phi_k: Vec<f32>,
}

impl Scheduler {
    pub fn new() -> Scheduler {
        Scheduler::default()
    }

    /// Serve every pending submission in `pool` as one micro-batch (see
    /// the [`crate::serve::scheduler`] module docs). Idle ticks (nothing
    /// pending) are cheap and recorded as such. On error (a backend
    /// refusing a step, e.g. the device tier losing its runtime) the
    /// un-served streams keep their pending submissions and the next
    /// tick retries them; no stream's state is ever advanced twice for
    /// one token — the batched path folds only after every phi row
    /// exists, and the sequential path marks each stream served as it
    /// folds.
    pub fn tick(&mut self, pool: &mut StreamPool<'_>) -> Result<TickStats> {
        let queue_depth = pool.pending;
        self.scheduled.clear();
        for (i, slot) in pool.slots.iter().enumerate() {
            if slot.active && slot.pending {
                self.scheduled.push(i as u32);
            }
        }
        let g = self.scheduled.len();
        debug_assert_eq!(g, pool.pending, "pending count out of sync with slots");
        if g == 0 {
            pool.tel.record_tick(0, queue_depth, false);
            return Ok(TickStats { batch: 0, sequential: false });
        }
        let sequential = g < pool.cfg.batch_threshold();
        let session = pool.session;
        let d = session.spec().head_dim;
        let map = session.feature_map().expect("streaming pool implies a Maclaurin session");
        let feat = map.flat.num_features();
        let scale = session.decode_scale();
        if sequential {
            // Degenerate batch: the gathered step would only add
            // dispatch overhead — serve each stream on the calling
            // thread. Same two-phase order per token as the batched
            // path (both fallible phi rows first, then the infallible
            // fold), and each stream is marked served as soon as its
            // token folds — so an error mid-loop leaves exactly the
            // un-served streams pending and no token is ever folded
            // twice.
            grow(&mut self.qs, d);
            grow(&mut self.ks, d);
            grow(&mut self.phi_q, feat);
            grow(&mut self.phi_k, feat);
            let mut served = 0usize;
            for &si in &self.scheduled {
                let slot = &mut pool.slots[si as usize];
                simd::scaled_copy(&slot.q, scale, &mut self.qs[..d]);
                simd::scaled_copy(&slot.k, scale, &mut self.ks[..d]);
                let mut phi = session.phi_rows_into(&self.ks[..d], 1, &mut self.phi_k[..feat]);
                if phi.is_ok() {
                    phi = session.phi_rows_into(&self.qs[..d], 1, &mut self.phi_q[..feat]);
                }
                if let Err(e) = phi {
                    // account for the streams this tick did serve
                    if served > 0 {
                        pool.tel.record_tick(served, queue_depth, sequential);
                    }
                    return Err(e);
                }
                let state = slot.state.as_mut().expect("active slot always has a state");
                state.fold_token_into(
                    &self.phi_k[..feat],
                    &self.phi_q[..feat],
                    &slot.v,
                    &mut slot.out,
                );
                slot.pending = false;
                slot.has_output = true;
                pool.pending -= 1;
                let latency = Instant::now().duration_since(slot.submitted_at);
                pool.tel.record_token_latency(latency);
                served += 1;
            }
            pool.tel.record_tick(g, queue_depth, sequential);
            return Ok(TickStats { batch: g, sequential });
        }
        {
            grow(&mut self.qs, g * d);
            grow(&mut self.ks, g * d);
            grow(&mut self.phi_q, g * feat);
            grow(&mut self.phi_k, g * feat);
            for (j, &si) in self.scheduled.iter().enumerate() {
                let slot = &pool.slots[si as usize];
                simd::scaled_copy(&slot.q, scale, &mut self.qs[j * d..(j + 1) * d]);
                simd::scaled_copy(&slot.k, scale, &mut self.ks[j * d..(j + 1) * d]);
            }
            // One (g, 1, d) feature step per side across the whole
            // micro-batch, sharded over the fastpath worker pool.
            session.phi_rows_into(&self.ks[..g * d], g, &mut self.phi_k[..g * feat])?;
            session.phi_rows_into(&self.qs[..g * d], g, &mut self.phi_q[..g * feat])?;
            // Parallel per-stream fold: index j owns slot scheduled[j].
            let slots = SendPtr(pool.slots.as_mut_ptr());
            let scheduled = &self.scheduled[..g];
            let phi_k = &self.phi_k[..g * feat];
            let phi_q = &self.phi_q[..g * feat];
            parallel::for_each_index(g, |j| {
                // SAFETY: `scheduled` holds distinct indices, each
                // claimed exactly once, and the exclusive borrow of
                // `pool` is held across this call (see SendPtr).
                let slot = unsafe { &mut *slots.0.add(scheduled[j] as usize) };
                let state = slot.state.as_mut().expect("active slot always has a state");
                state.fold_token_into(
                    &phi_k[j * feat..(j + 1) * feat],
                    &phi_q[j * feat..(j + 1) * feat],
                    &slot.v,
                    &mut slot.out,
                );
            });
        }
        // Hand outputs over and record per-token latency (queue wait +
        // compute, measured submit -> served).
        let served_at = Instant::now();
        for &si in &self.scheduled {
            let slot = &mut pool.slots[si as usize];
            slot.pending = false;
            slot.has_output = true;
            pool.tel.record_token_latency(served_at.duration_since(slot.submitted_at));
        }
        pool.pending -= g;
        pool.tel.record_tick(g, queue_depth, sequential);
        Ok(TickStats { batch: g, sequential })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::{AttentionSpec, Backend, Kernel};
    use crate::serve::ServeConfig;
    use crate::util::rng::Rng;

    #[test]
    fn tick_serves_all_pending_and_idles_cleanly() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(16)
            .causal(true)
            .seed(3)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let mut pool = StreamPool::new(&sess, ServeConfig::new(5, 2)).unwrap();
        let mut sched = Scheduler::new();
        // idle tick first
        let stats = sched.tick(&mut pool).unwrap();
        assert_eq!(stats, TickStats { batch: 0, sequential: false });
        let ids: Vec<_> = (0..5).map(|_| pool.admit().unwrap()).collect();
        let mut rng = Rng::new(9);
        for &id in &ids {
            let q: Vec<f32> = (0..4).map(|_| rng.normal() * 0.5).collect();
            let k: Vec<f32> = (0..4).map(|_| rng.normal() * 0.5).collect();
            let v: Vec<f32> = (0..2).map(|_| rng.normal()).collect();
            pool.submit(id, &q, &k, &v).unwrap();
        }
        let stats = sched.tick(&mut pool).unwrap();
        assert_eq!(stats, TickStats { batch: 5, sequential: false });
        assert_eq!(pool.pending_tokens(), 0);
        let mut out = [0.0f32; 2];
        for &id in &ids {
            pool.take_output(id, &mut out).unwrap();
            assert!(out.iter().all(|x| x.is_finite()));
            assert_eq!(pool.stream_len(id).unwrap(), 1);
        }
        assert_eq!(pool.telemetry().tokens(), 5);
    }

    #[test]
    fn degenerate_batch_falls_back_to_sequential() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(16)
            .causal(true)
            .seed(3)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let cfg = ServeConfig { min_batch: 3, ..ServeConfig::new(4, 2) };
        let mut pool = StreamPool::new(&sess, cfg).unwrap();
        let mut sched = Scheduler::new();
        let a = pool.admit().unwrap();
        pool.submit(a, &[0.1; 4], &[0.2; 4], &[1.0, 2.0]).unwrap();
        let stats = sched.tick(&mut pool).unwrap();
        assert_eq!(stats, TickStats { batch: 1, sequential: true });
        assert!(pool.has_output(a));
    }
}
