//! [`Scheduler`] — the dynamic micro-batching tick.
//!
//! Each [`Scheduler::tick`] drains every pending submission in the pool
//! as one micro-batch:
//!
//! 1. **Gather** — the pending streams' staged q/k rows are scaled to
//!    score scale (`d^(-1/4)`, same as the single-stream path) into the
//!    scheduler's grow-only scratch, forming one `(g, 1, d)` problem
//!    set.
//! 2. **Feature step** — one
//!    [`AttentionSession::phi_rows_into`](crate::attn::AttentionSession::phi_rows_into)
//!    call per side (k, then q) maps the whole batch through the
//!    session's feature draw; on the host tier this shards rows over
//!    the persistent fastpath worker pool.
//! 3. **Fold** — each stream's `(S, z)` update + output row runs via
//!    [`for_each_index`](crate::fastpath::parallel::for_each_index)
//!    over the same pool, one stream per claimed index (disjoint slots,
//!    so the parallel fold is race-free and order-independent).
//!
//! Degenerate batches — fewer than
//! [`batch_threshold`](super::ServeConfig::batch_threshold) pending
//! streams — skip the gather/dispatch machinery and serve each stream
//! on the calling thread, with the same two-phase order per token
//! (both fallible phi rows first, then the infallible fold). Both
//! paths run the same per-row phi kernels and the same fold code as
//! [`append_token_into`](crate::attn::CausalState::append_token_into),
//! so serve outputs are **bit-identical** to lone single-stream
//! decodes (proved by `tests/serve_streams.rs` on both SIMD arms).
//!
//! Steady-state ticks make **zero heap allocations**: the scratch and
//! schedule vectors are grow-only, telemetry buckets are fixed-size,
//! and both dispatch layers are the allocation-free fastpath pool
//! (enforced by `tests/alloc_free.rs`).
//!
//! Prompts enter through [`Scheduler::prefill`] instead of `n`
//! single-token ticks: one bulk phi pass plus the chunkwise-parallel
//! `(S, z)` fold (`MACFORMER_CHUNK` tokens per chunk, GEMM-dominated),
//! leaving the stream's state bit-identical to token-by-token
//! submission and its output slot holding the prompt's last position.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use anyhow::Result;

use crate::fastpath::attention::causal_chunk;
use crate::fastpath::parallel::SendPtr;
use crate::fastpath::{grow, parallel, simd};

use super::obs::{self, Stage};
use super::pool::{all_finite, FaultKind, Slot, StreamId, StreamPool};
use super::ServeError;

/// What one [`Scheduler::tick`] did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TickStats {
    /// Streams served this tick (0 = idle tick).
    pub batch: usize,
    /// True when the degenerate-batch sequential path ran instead of
    /// the gathered `(g, 1, d)` step.
    pub sequential: bool,
    /// Streams whose fold was isolated this tick (panic or quarantine)
    /// and whose slots were retired — no output, handle dead.
    pub faulted: usize,
}

/// One stream's guarded `(S, z)` fold: screen the phi rows for
/// non-finite values *before* the key fold can poison the state, run
/// the fold under `catch_unwind` so a panic in one stream cannot take
/// down the tick (or the worker pool — the payload never crosses this
/// frame), and check the fold denominator's health afterwards.
/// `Some(kind)` means the fold was isolated and the slot must be
/// retired; `None` means `slot.out` holds the served row.
///
/// On the non-panic path `catch_unwind` costs nothing (no allocation,
/// no unwinding machinery engaged), so this guard is free at steady
/// state.
fn guarded_fold(slot: &mut Slot<'_>, phi_k: &[f32], phi_q: &[f32], eps: f32) -> Option<FaultKind> {
    if !all_finite(phi_k) || !all_finite(phi_q) {
        // phi overflowed on screened-finite inputs (huge magnitudes
        // through a high-degree feature): quarantine before the key
        // fold touches (S, z)
        return Some(FaultKind::Quarantine);
    }
    let armed = slot.fault_armed;
    let state = slot.state.as_mut().expect("active slot always has a state");
    let v = &slot.v;
    let out = &mut slot.out;
    let folded = catch_unwind(AssertUnwindSafe(|| {
        if armed {
            panic!("injected slot fault (fault_armed)");
        }
        state.fold_token_into(phi_k, phi_q, v, out)
    }));
    match folded {
        Err(_payload) => Some(FaultKind::Panic),
        // a non-finite denominator means the key fold overflowed the
        // accumulators: the state is poisoned, retire it before the
        // next token reads it (finite-but-small denominators are
        // legitimate — Maclaurin features carry signs)
        Ok(den) if !(den + eps).is_finite() => Some(FaultKind::Quarantine),
        Ok(_) => None,
    }
}

/// The micro-batch scheduler. Owns only grow-only scratch, so one
/// scheduler can serve any number of pools (though one pool per
/// scheduler is the typical shape).
#[derive(Default)]
pub struct Scheduler {
    /// Slot indices scheduled this tick.
    scheduled: Vec<u32>,
    /// Scaled q rows, `g * d`.
    qs: Vec<f32>,
    /// Scaled k rows, `g * d`.
    ks: Vec<f32>,
    /// phi(q'), `g * D`.
    phi_q: Vec<f32>,
    /// phi(k'), `g * D`.
    phi_k: Vec<f32>,
    /// Per-position prefill outputs, `n * dv` (only the last row is
    /// handed to the stream's output slot).
    prefill_out: Vec<f32>,
}

impl Scheduler {
    pub fn new() -> Scheduler {
        Scheduler::default()
    }

    /// Ingest a whole prompt for one admitted stream — the
    /// prompt-admission path. Instead of queueing `n` single-token
    /// ticks, the prompt is scaled and phi-mapped in bulk in this
    /// scheduler's grow-only scratch (feature rows sharded over the
    /// fastpath worker pool), then folded chunkwise
    /// (`MACFORMER_CHUNK` tokens at a time) into the stream's `(S, z)`
    /// state. The last prompt position's attention output lands in the
    /// stream's output slot, taken with
    /// [`take_output`](StreamPool::take_output) like any served token.
    ///
    /// `q`/`k` are `n * head_dim` row-major prompt rows, `v` is
    /// `n * dv`; returns the number of prompt tokens ingested. The
    /// state after prefill is **bit-identical** to having submitted
    /// the prompt token by token through ticks, so subsequent decode
    /// continues bit-compatibly. Closed-loop: a stream with a pending
    /// token or an untaken output cannot prefill
    /// ([`ServeError::StreamBusy`]). On error no state is advanced.
    ///
    /// Unlike the session-level `CausalState::prefill_into` (where an
    /// empty prompt is a no-op), an empty or ragged prompt is a
    /// [`ServeError::BadRow`] here — a prompt admission must leave an
    /// output to take. For prompt rows, `BadRow` reports
    /// `expected` = the row quantum (`head_dim`, or `n * dv` for `v`)
    /// and `got` = the whole buffer's length.
    pub fn prefill(
        &mut self,
        pool: &mut StreamPool<'_>,
        id: StreamId,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<usize, ServeError> {
        let si = pool.resolve(id)?;
        if pool.slots[si].pending || pool.slots[si].has_output {
            return Err(ServeError::StreamBusy);
        }
        let session = pool.session;
        let d = session.spec().head_dim;
        if q.len() != k.len() || q.len() % d != 0 || q.is_empty() {
            return Err(ServeError::BadRow { what: "prompt q", expected: d, got: q.len() });
        }
        let n = q.len() / d;
        let dv = pool.cfg.dv;
        if v.len() != n * dv {
            return Err(ServeError::BadRow { what: "prompt v", expected: n * dv, got: v.len() });
        }
        let map = session.feature_map().expect("streaming pool implies a Maclaurin session");
        let feat = map.flat.num_features();
        let scale = session.decode_scale();
        {
            let _gather = obs::span(Stage::TickGather);
            grow(&mut self.qs, n * d);
            grow(&mut self.ks, n * d);
            grow(&mut self.phi_q, n * feat);
            grow(&mut self.phi_k, n * feat);
            grow(&mut self.prefill_out, n * dv);
            simd::scaled_copy(q, scale, &mut self.qs[..n * d]);
            simd::scaled_copy(k, scale, &mut self.ks[..n * d]);
        }
        // both fallible phi passes complete before any state is touched
        let phi = {
            let _gemm = obs::span(Stage::PhiGemm);
            let mut phi = session.phi_rows_into(&self.ks[..n * d], n, &mut self.phi_k[..n * feat]);
            if phi.is_ok() {
                phi = session.phi_rows_into(&self.qs[..n * d], n, &mut self.phi_q[..n * feat]);
            }
            phi
        };
        if let Err(e) = phi {
            return Err(ServeError::Session(format!("{e:#}")));
        }
        let slot = &mut pool.slots[si];
        let state = slot.state.as_mut().expect("active slot always has a state");
        {
            let _fold = obs::span(Stage::StateFold);
            state.prefill_phi_into(
                &self.phi_q[..n * feat],
                &self.phi_k[..n * feat],
                v,
                n,
                causal_chunk(),
                &mut self.prefill_out[..n * dv],
            );
        }
        slot.out.copy_from_slice(&self.prefill_out[(n - 1) * dv..n * dv]);
        slot.has_output = true;
        pool.tel.record_prefill(n);
        Ok(n)
    }

    /// Serve every pending submission in `pool` as one micro-batch (see
    /// the [`crate::serve::scheduler`] module docs). Idle ticks (nothing
    /// pending) are cheap and recorded as such. On error (a backend
    /// refusing a step, e.g. the device tier losing its runtime) the
    /// un-served streams keep their pending submissions and the next
    /// tick retries them; no stream's state is ever advanced twice for
    /// one token — the batched path folds only after every phi row
    /// exists, and the sequential path marks each stream served as it
    /// folds.
    pub fn tick(&mut self, pool: &mut StreamPool<'_>) -> Result<TickStats> {
        let queue_depth = pool.pending;
        self.scheduled.clear();
        for (i, slot) in pool.slots.iter().enumerate() {
            if slot.active && slot.pending {
                self.scheduled.push(i as u32);
            }
        }
        let g = self.scheduled.len();
        debug_assert_eq!(g, pool.pending, "pending count out of sync with slots");
        if g == 0 {
            pool.tel.record_tick(0, queue_depth, false);
            return Ok(TickStats { batch: 0, sequential: false, faulted: 0 });
        }
        let sequential = g < pool.cfg.batch_threshold();
        let session = pool.session;
        let d = session.spec().head_dim;
        let eps = session.spec().eps;
        let map = session.feature_map().expect("streaming pool implies a Maclaurin session");
        let feat = map.flat.num_features();
        let scale = session.decode_scale();
        if sequential {
            // Degenerate batch: the gathered step would only add
            // dispatch overhead — serve each stream on the calling
            // thread. Same two-phase order per token as the batched
            // path (both fallible phi rows first, then the infallible
            // fold), and each stream is marked served as soon as its
            // token folds — so an error mid-loop leaves exactly the
            // un-served streams pending and no token is ever folded
            // twice.
            grow(&mut self.qs, d);
            grow(&mut self.ks, d);
            grow(&mut self.phi_q, feat);
            grow(&mut self.phi_k, feat);
            let mut served = 0usize;
            let mut faulted = 0usize;
            for &si in &self.scheduled {
                let slot = &mut pool.slots[si as usize];
                {
                    let _gather = obs::span(Stage::TickGather);
                    simd::scaled_copy(&slot.q, scale, &mut self.qs[..d]);
                    simd::scaled_copy(&slot.k, scale, &mut self.ks[..d]);
                }
                let phi = {
                    let _gemm = obs::span(Stage::PhiGemm);
                    let mut phi = session.phi_rows_into(&self.ks[..d], 1, &mut self.phi_k[..feat]);
                    if phi.is_ok() {
                        phi = session.phi_rows_into(&self.qs[..d], 1, &mut self.phi_q[..feat]);
                    }
                    phi
                };
                if let Err(e) = phi {
                    // account for the streams this tick did serve
                    if served > 0 {
                        pool.tel.record_tick(served, queue_depth, sequential);
                    }
                    return Err(e);
                }
                let fold = {
                    let _fold = obs::span(Stage::StateFold);
                    guarded_fold(slot, &self.phi_k[..feat], &self.phi_q[..feat], eps)
                };
                if let Some(kind) = fold {
                    // isolate immediately: the token is dropped with
                    // its stream, never re-scheduled
                    pool.retire_faulted(si as usize, kind);
                    faulted += 1;
                    continue;
                }
                let slot = &mut pool.slots[si as usize];
                slot.pending = false;
                slot.has_output = true;
                pool.pending -= 1;
                let latency = Instant::now().duration_since(slot.submitted_at);
                pool.tel.record_token_latency(latency);
                served += 1;
            }
            pool.tel.record_tick(served, queue_depth, sequential);
            return Ok(TickStats { batch: served, sequential, faulted });
        }
        {
            {
                let _gather = obs::span(Stage::TickGather);
                grow(&mut self.qs, g * d);
                grow(&mut self.ks, g * d);
                grow(&mut self.phi_q, g * feat);
                grow(&mut self.phi_k, g * feat);
                for (j, &si) in self.scheduled.iter().enumerate() {
                    let slot = &pool.slots[si as usize];
                    simd::scaled_copy(&slot.q, scale, &mut self.qs[j * d..(j + 1) * d]);
                    simd::scaled_copy(&slot.k, scale, &mut self.ks[j * d..(j + 1) * d]);
                }
            }
            // One (g, 1, d) feature step per side across the whole
            // micro-batch, sharded over the fastpath worker pool.
            {
                let _gemm = obs::span(Stage::PhiGemm);
                session.phi_rows_into(&self.ks[..g * d], g, &mut self.phi_k[..g * feat])?;
                session.phi_rows_into(&self.qs[..g * d], g, &mut self.phi_q[..g * feat])?;
            }
            // Parallel per-stream fold: index j owns slot scheduled[j].
            // Each fold is individually guarded (phi screen, panic
            // catch, denominator health); a fault is recorded on the
            // slot — disjoint writes, so still race-free — and the
            // hand-over loop below retires it.
            let _fold = obs::span(Stage::StateFold);
            let slots = SendPtr(pool.slots.as_mut_ptr());
            let scheduled = &self.scheduled[..g];
            let phi_k = &self.phi_k[..g * feat];
            let phi_q = &self.phi_q[..g * feat];
            parallel::for_each_index(g, |j| {
                // SAFETY: `scheduled` holds distinct indices, each
                // claimed exactly once, and the exclusive borrow of
                // `pool` is held across this call (see SendPtr).
                let slot = unsafe { &mut *slots.0.add(scheduled[j] as usize) };
                slot.fault = guarded_fold(
                    slot,
                    &phi_k[j * feat..(j + 1) * feat],
                    &phi_q[j * feat..(j + 1) * feat],
                    eps,
                );
            });
        }
        // Hand outputs over, retire isolated folds, and record
        // per-token latency (queue wait + compute, submit -> served).
        let served_at = Instant::now();
        let mut served = 0usize;
        let mut faulted = 0usize;
        for &si in &self.scheduled {
            let si = si as usize;
            if let Some(kind) = pool.slots[si].fault {
                // retire_faulted balances the queue bookkeeping (the
                // slot's pending flag is still set)
                pool.retire_faulted(si, kind);
                faulted += 1;
                continue;
            }
            let slot = &mut pool.slots[si];
            slot.pending = false;
            slot.has_output = true;
            pool.tel.record_token_latency(served_at.duration_since(slot.submitted_at));
            served += 1;
        }
        pool.pending -= served;
        pool.tel.record_tick(served, queue_depth, sequential);
        Ok(TickStats { batch: served, sequential, faulted })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::{AttentionSpec, Backend, Kernel};
    use crate::serve::ServeConfig;
    use crate::util::rng::Rng;

    #[test]
    fn tick_serves_all_pending_and_idles_cleanly() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(16)
            .causal(true)
            .seed(3)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let mut pool = StreamPool::new(&sess, ServeConfig::new(5, 2)).unwrap();
        let mut sched = Scheduler::new();
        // idle tick first
        let stats = sched.tick(&mut pool).unwrap();
        assert_eq!(stats, TickStats { batch: 0, sequential: false, faulted: 0 });
        let ids: Vec<_> = (0..5).map(|_| pool.admit().unwrap()).collect();
        let mut rng = Rng::new(9);
        for &id in &ids {
            let q: Vec<f32> = (0..4).map(|_| rng.normal() * 0.5).collect();
            let k: Vec<f32> = (0..4).map(|_| rng.normal() * 0.5).collect();
            let v: Vec<f32> = (0..2).map(|_| rng.normal()).collect();
            pool.submit(id, &q, &k, &v).unwrap();
        }
        let stats = sched.tick(&mut pool).unwrap();
        assert_eq!(stats, TickStats { batch: 5, sequential: false, faulted: 0 });
        assert_eq!(pool.pending_tokens(), 0);
        let mut out = [0.0f32; 2];
        for &id in &ids {
            pool.take_output(id, &mut out).unwrap();
            assert!(out.iter().all(|x| x.is_finite()));
            assert_eq!(pool.stream_len(id).unwrap(), 1);
        }
        assert_eq!(pool.telemetry().tokens(), 5);
    }

    #[test]
    fn prefill_ingests_a_prompt_and_leaves_decode_ready() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(16)
            .causal(true)
            .seed(5)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let mut pool = StreamPool::new(&sess, ServeConfig::new(2, 2)).unwrap();
        let mut sched = Scheduler::new();
        let id = pool.admit().unwrap();
        let mut rng = Rng::new(17);
        let n = 9usize;
        let q: Vec<f32> = (0..n * 4).map(|_| rng.normal() * 0.5).collect();
        let k: Vec<f32> = (0..n * 4).map(|_| rng.normal() * 0.5).collect();
        let v: Vec<f32> = (0..n * 2).map(|_| rng.normal()).collect();
        assert_eq!(sched.prefill(&mut pool, id, &q, &k, &v).unwrap(), n);
        assert_eq!(pool.stream_len(id).unwrap(), n);
        assert!(pool.has_output(id));
        assert_eq!(pool.telemetry().prefills(), 1);
        assert_eq!(pool.telemetry().prefill_tokens(), n as u64);
        // the untaken prompt output blocks both submit and re-prefill
        assert_eq!(
            pool.submit(id, &[0.0; 4], &[0.0; 4], &[0.0; 2]).unwrap_err(),
            crate::serve::ServeError::StreamBusy
        );
        assert_eq!(
            sched.prefill(&mut pool, id, &q, &k, &v).unwrap_err(),
            crate::serve::ServeError::StreamBusy
        );
        let mut out = [0.0f32; 2];
        pool.take_output(id, &mut out).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
        // decode continues normally after the prompt
        pool.submit(id, &[0.1; 4], &[0.2; 4], &[1.0, -1.0]).unwrap();
        sched.tick(&mut pool).unwrap();
        pool.take_output(id, &mut out).unwrap();
        assert_eq!(pool.stream_len(id).unwrap(), n + 1);
        // ragged prompt rows are clean typed errors
        assert!(matches!(
            sched.prefill(&mut pool, id, &q[..5], &k[..5], &v).unwrap_err(),
            crate::serve::ServeError::BadRow { what: "prompt q", .. }
        ));
        assert!(matches!(
            sched.prefill(&mut pool, id, &q, &k, &v[..3]).unwrap_err(),
            crate::serve::ServeError::BadRow { what: "prompt v", .. }
        ));
    }

    #[test]
    fn degenerate_batch_falls_back_to_sequential() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(16)
            .causal(true)
            .seed(3)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let cfg = ServeConfig { min_batch: 3, ..ServeConfig::new(4, 2) };
        let mut pool = StreamPool::new(&sess, cfg).unwrap();
        let mut sched = Scheduler::new();
        let a = pool.admit().unwrap();
        pool.submit(a, &[0.1; 4], &[0.2; 4], &[1.0, 2.0]).unwrap();
        let stats = sched.tick(&mut pool).unwrap();
        assert_eq!(stats, TickStats { batch: 1, sequential: true, faulted: 0 });
        assert!(pool.has_output(a));
    }

    /// An injected fold panic in one stream is isolated: that slot is
    /// retired, every other stream in the same micro-batch is served
    /// normally, and the scheduler (and its worker pool) survive for
    /// the next tick — on both the batched and sequential paths.
    #[test]
    fn fold_panic_is_isolated_to_its_stream() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(16)
            .causal(true)
            .seed(3)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        for min_batch in [1usize, 8] {
            let cfg = ServeConfig { min_batch, ..ServeConfig::new(4, 2) };
            let mut pool = StreamPool::new(&sess, cfg).unwrap();
            let mut sched = Scheduler::new();
            let ids: Vec<_> = (0..3).map(|_| pool.admit().unwrap()).collect();
            for &id in &ids {
                pool.submit(id, &[0.1; 4], &[0.2; 4], &[1.0, -1.0]).unwrap();
            }
            pool.arm_fault(ids[1]).unwrap();
            let stats = sched.tick(&mut pool).unwrap();
            assert_eq!(stats.batch, 2, "min_batch {min_batch}");
            assert_eq!(stats.faulted, 1, "min_batch {min_batch}");
            assert_eq!(pool.pending_tokens(), 0);
            // the faulted stream's handle is dead, its slot reclaimed
            assert_eq!(
                pool.take_output(ids[1], &mut [0.0; 2]).unwrap_err(),
                crate::serve::ServeError::UnknownStream
            );
            assert_eq!(pool.active_streams(), 2);
            assert_eq!(pool.telemetry().faults(), 1);
            assert_eq!(pool.telemetry().quarantines(), 0);
            // survivors are served this tick and keep ticking
            let mut out = [0.0f32; 2];
            for &id in [ids[0], ids[2]].iter() {
                pool.take_output(id, &mut out).unwrap();
                assert!(out.iter().all(|x| x.is_finite()));
                pool.submit(id, &[0.1; 4], &[0.2; 4], &[1.0, -1.0]).unwrap();
            }
            let stats = sched.tick(&mut pool).unwrap();
            assert_eq!(stats.faulted, 0);
            assert_eq!(stats.batch, 2);
        }
    }

    /// Finite-but-huge inputs that overflow phi (or the fold
    /// denominator) quarantine the stream instead of serving NaN — and
    /// instead of poisoning the tick for everyone else.
    #[test]
    fn overflowing_phi_quarantines_the_stream() {
        let sess = AttentionSpec::new(Kernel::Exp)
            .head_dim(4)
            .num_features(24)
            .causal(true)
            .seed(7)
            .backend(Backend::HostFast)
            .build()
            .unwrap();
        let cfg = ServeConfig { min_batch: 1, ..ServeConfig::new(4, 2) };
        let mut pool = StreamPool::new(&sess, cfg).unwrap();
        let mut sched = Scheduler::new();
        let good = pool.admit().unwrap();
        let bad = pool.admit().unwrap();
        pool.submit(good, &[0.1; 4], &[0.2; 4], &[1.0, -1.0]).unwrap();
        // finite values (they pass the submit screen) whose huge
        // magnitudes overflow f32 in phi (degree>=2 features) or in the
        // fold denominator; non-uniform so no Rademacher +/- draw can
        // cancel w.x to zero
        let huge = [1e25f32, 1.3e25, 1.7e25, 2.9e25];
        pool.submit(bad, &huge, &huge, &[1.0, -1.0]).unwrap();
        let stats = sched.tick(&mut pool).unwrap();
        assert_eq!(stats.faulted, 1, "{stats:?}");
        assert_eq!(stats.batch, 1);
        assert_eq!(pool.telemetry().quarantines(), 1);
        assert_eq!(
            pool.take_output(bad, &mut [0.0; 2]).unwrap_err(),
            crate::serve::ServeError::UnknownStream
        );
        // the survivor's output is clean
        let mut out = [0.0f32; 2];
        pool.take_output(good, &mut out).unwrap();
        assert!(out.iter().all(|x| x.is_finite()), "{out:?}");
    }
}
