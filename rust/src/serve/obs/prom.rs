//! Hand-rolled Prometheus text exposition (format version 0.0.4) —
//! dependency-free, in the same spirit as `serve/net/wire.rs`.
//!
//! [`render`] produces the whole `GET /metrics` body: every
//! [`Telemetry`] counter/gauge, the token-latency histogram, the
//! per-stage duration histograms from [`super`], the durability
//! counters (journal bytes, recovery replay), and the HTTP
//! response-class counters. Structural correctness is by
//! construction:
//!
//! * each metric family is emitted exactly once (`# HELP`/`# TYPE`
//!   cannot duplicate because families are written by one call each;
//!   labelled series share one family header),
//! * histogram `le` buckets are cumulative and monotone (a running
//!   sum over the log2 buckets), and the `+Inf` bucket is written
//!   from the same `count` that becomes `_count`,
//! * label values pass through [`escape_label`].
//!
//! `tests/serve_obs.rs` re-checks all of the above on a live
//! `/metrics` response after a deterministic load.

use std::fmt::Write as _;

use crate::serve::telemetry::Telemetry;

use super::{HistSnapshot, Stage, BUCKETS};

/// The content type `/metrics` answers with.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Escape a label value per the exposition format: backslash, double
/// quote, and newline.
pub fn escape_label(value: &str) -> String {
    let mut out = String::with_capacity(value.len());
    for c in value.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

struct Prom {
    out: String,
}

impl Prom {
    fn header(&mut self, name: &str, help: &str, kind: &str) {
        let _ = writeln!(self.out, "# HELP {name} {help}");
        let _ = writeln!(self.out, "# TYPE {name} {kind}");
    }

    fn counter(&mut self, name: &str, help: &str, value: u64) {
        self.header(name, help, "counter");
        let _ = writeln!(self.out, "{name} {value}");
    }

    fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "gauge");
        let _ = writeln!(self.out, "{name} {}", fmt_f64(value));
    }

    /// One counter family with a single label dimension — one
    /// `# HELP`/`# TYPE` header shared by every labelled series.
    fn counter_family(&mut self, name: &str, help: &str, label: &str, series: &[(&str, u64)]) {
        self.header(name, help, "counter");
        for (value, count) in series {
            let _ = writeln!(self.out, "{name}{{{label}=\"{}\"}} {count}", escape_label(value));
        }
    }

    /// One histogram family; `series` carries one snapshot per label
    /// value (`label = None` for an unlabelled single histogram).
    /// Buckets are emitted cumulatively over the shared log2 ladder,
    /// `le` in seconds, closing with `+Inf` equal to `_count`.
    fn histogram(
        &mut self,
        name: &str,
        help: &str,
        label: Option<&str>,
        series: &[(&str, HistSnapshot)],
    ) {
        self.header(name, help, "histogram");
        for (value, snap) in series {
            let tag = match label {
                Some(key) => format!("{key}=\"{}\",", escape_label(value)),
                None => String::new(),
            };
            let mut cum = 0u64;
            for (b, &c) in snap.buckets.iter().enumerate() {
                cum += c;
                let le = (1u64 << (b + 1)) as f64 * 1e-9;
                let _ =
                    writeln!(self.out, "{name}_bucket{{{tag}le=\"{}\"}} {cum}", fmt_f64(le));
            }
            let _ = writeln!(self.out, "{name}_bucket{{{tag}le=\"+Inf\"}} {}", snap.count);
            let close = match label {
                Some(key) => format!("{{{key}=\"{}\"}}", escape_label(value)),
                None => String::new(),
            };
            let _ =
                writeln!(self.out, "{name}_sum{close} {}", fmt_f64(snap.sum_ns as f64 * 1e-9));
            let _ = writeln!(self.out, "{name}_count{close} {}", snap.count);
        }
    }
}

/// Prometheus floats: plain decimal via Rust's shortest round-trip
/// `Display`; non-finite values spelled the exposition way.
fn fmt_f64(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x.is_infinite() {
        if x > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else {
        format!("{x}")
    }
}

/// Render the full `/metrics` body. `extra_gauges` carries the
/// engine-snapshot gauges only the caller holds (active streams,
/// queued jobs, tick number): `(metric_name, help, value)` triples.
pub fn render(tel: &Telemetry, extra_gauges: &[(&str, &str, f64)]) -> String {
    let mut p = Prom { out: String::with_capacity(32 * 1024) };

    // --- Telemetry counters ---
    for (name, help, value) in [
        ("macformer_tokens_total", "Decode tokens served across all streams.", tel.tokens()),
        ("macformer_ticks_total", "Scheduler ticks observed (including idle).", tel.ticks()),
        ("macformer_idle_ticks_total", "Ticks that served nothing.", tel.idle_ticks()),
        (
            "macformer_batched_ticks_total",
            "Ticks that ran the gathered micro-batch step.",
            tel.batched_ticks(),
        ),
        (
            "macformer_sequential_ticks_total",
            "Ticks that fell back to the per-stream sequential path.",
            tel.sequential_ticks(),
        ),
        (
            "macformer_batch_size_sum_total",
            "Sum of micro-batch sizes over non-idle ticks.",
            tel.batch_sum(),
        ),
        (
            "macformer_queue_depth_sum_total",
            "Sum of tick-start queue depths over all ticks.",
            tel.queue_depth_sum(),
        ),
        ("macformer_admits_total", "Streams admitted.", tel.admits()),
        (
            "macformer_rejected_admits_total",
            "Admissions rejected (pool full).",
            tel.rejected_admits(),
        ),
        (
            "macformer_rejected_submits_total",
            "Submissions rejected (backpressure).",
            tel.rejected_submits(),
        ),
        ("macformer_prefills_total", "Prompt prefills performed.", tel.prefills()),
        (
            "macformer_prefill_tokens_total",
            "Prompt tokens ingested by chunked prefill.",
            tel.prefill_tokens(),
        ),
        ("macformer_hibernations_total", "Streams hibernated.", tel.hibernations()),
        ("macformer_restores_total", "Hibernated streams restored.", tel.restores()),
        (
            "macformer_evictions_total",
            "Hibernations forced by pool pressure.",
            tel.evictions(),
        ),
        ("macformer_expirations_total", "Streams expired by a deadline.", tel.expirations()),
        (
            "macformer_shed_total",
            "Submissions shed by the overload governor.",
            tel.shed(),
        ),
        ("macformer_faults_total", "Streams retired by fault isolation.", tel.faults()),
        (
            "macformer_quarantines_total",
            "Streams quarantined by health screening.",
            tel.quarantines(),
        ),
        (
            "macformer_nonfinite_rejects_total",
            "Tokens rejected for non-finite q/k/v values.",
            tel.nonfinite_rejects(),
        ),
    ] {
        p.counter(name, help, value);
    }

    // --- Telemetry gauges (high-water marks) ---
    p.gauge(
        "macformer_batch_max",
        "Largest micro-batch served by one tick.",
        tel.max_batch() as f64,
    );
    p.gauge(
        "macformer_queue_depth_max",
        "Deepest queue seen at a tick start.",
        tel.max_queue_depth() as f64,
    );
    for (name, help, value) in extra_gauges {
        p.gauge(name, help, *value);
    }

    // --- token latency + per-stage histograms ---
    p.histogram(
        "macformer_token_latency_seconds",
        "Per-token latency, submit to served.",
        None,
        &[("", tel.latency_snapshot())],
    );
    let stages: Vec<(&str, HistSnapshot)> =
        Stage::ALL.iter().map(|s| (s.name(), super::snapshot(*s))).collect();
    p.histogram(
        "macformer_stage_duration_seconds",
        "Per-stage request-path durations (see the obs stage taxonomy).",
        Some("stage"),
        &stages,
    );

    // --- durability counters ---
    p.counter(
        "macformer_journal_bytes_total",
        "Bytes appended to the write-ahead journal.",
        super::journal_bytes(),
    );
    p.counter(
        "macformer_recoveries_total",
        "Crash-restart recoveries performed at startup.",
        super::recoveries(),
    );
    p.counter(
        "macformer_recovery_replayed_ops_total",
        "Journal ops replayed through the fold path during recovery.",
        super::recovery_replayed_ops(),
    );
    p.counter(
        "macformer_recovery_truncated_bytes_total",
        "Torn journal-tail bytes truncated during recovery.",
        super::recovery_truncated_bytes(),
    );

    // --- HTTP response classes ---
    let classes = super::http_responses();
    p.counter_family(
        "macformer_http_responses_total",
        "HTTP responses served, by status class.",
        "class",
        &[
            ("1xx", classes[1]),
            ("2xx", classes[2]),
            ("3xx", classes[3]),
            ("4xx", classes[4]),
            ("5xx", classes[5]),
            ("other", classes[0]),
        ],
    );

    p.out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::time::Duration;

    fn sample_body() -> String {
        let mut tel = Telemetry::new();
        tel.record_tick(3, 4, false);
        tel.record_tick(1, 1, true);
        tel.record_token_latency(Duration::from_micros(3));
        tel.record_token_latency(Duration::from_micros(700));
        super::super::record_span(Stage::StateFold, 0, 12_000, 0);
        render(&tel, &[("macformer_active_streams", "Active streams.", 3.0)])
    }

    #[test]
    fn no_duplicate_help_or_type_lines() {
        let body = sample_body();
        let mut seen = HashSet::new();
        for line in body.lines() {
            if line.starts_with("# HELP") || line.starts_with("# TYPE") {
                let key: Vec<&str> = line.split_whitespace().take(3).collect();
                assert!(seen.insert(key.join(" ")), "duplicate header: {line}");
            }
        }
    }

    #[test]
    fn every_series_belongs_to_a_declared_family() {
        let body = sample_body();
        let mut declared = HashSet::new();
        for line in body.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let name = it.next().unwrap();
                let kind = it.next().unwrap();
                declared.insert(name.to_string());
                if kind == "histogram" {
                    declared.insert(format!("{name}_bucket"));
                    declared.insert(format!("{name}_sum"));
                    declared.insert(format!("{name}_count"));
                }
            }
        }
        for line in body.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let name = line.split(['{', ' ']).next().unwrap();
            assert!(declared.contains(name), "undeclared series {name}");
        }
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_inf_matches_count() {
        let body = sample_body();
        // the unlabelled token-latency histogram
        let mut last = 0u64;
        let mut inf = None;
        let mut count = None;
        for line in body.lines() {
            if let Some(rest) = line.strip_prefix("macformer_token_latency_seconds_bucket{le=") {
                let v: u64 = rest.split('}').nth(1).unwrap().trim().parse().unwrap();
                if rest.starts_with("\"+Inf\"") {
                    inf = Some(v);
                } else {
                    assert!(v >= last, "bucket series not monotone: {line}");
                    last = v;
                }
            } else if let Some(rest) = line.strip_prefix("macformer_token_latency_seconds_count ")
            {
                count = Some(rest.trim().parse::<u64>().unwrap());
            }
        }
        let (inf, count) = (inf.expect("+Inf bucket"), count.expect("_count"));
        assert_eq!(inf, count, "+Inf bucket must equal _count");
        assert!(inf >= last, "+Inf below the last finite bucket");
        assert_eq!(count, 2, "two recorded latencies");
    }

    #[test]
    fn stage_family_carries_every_stage_label_once() {
        let body = sample_body();
        for stage in Stage::ALL {
            let needle = format!("macformer_stage_duration_seconds_count{{stage=\"{}\"}}", stage.name());
            assert_eq!(
                body.lines().filter(|l| l.starts_with(needle.as_str())).count(),
                1,
                "stage {} missing or duplicated",
                stage.name()
            );
        }
    }

    #[test]
    fn label_values_are_escaped() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b"), "a\\\"b");
        assert_eq!(escape_label("a\\b"), "a\\\\b");
        assert_eq!(escape_label("a\nb"), "a\\nb");
    }

    #[test]
    fn floats_render_prometheus_style() {
        assert_eq!(fmt_f64(1.5), "1.5");
        assert_eq!(fmt_f64(f64::INFINITY), "+Inf");
        assert_eq!(fmt_f64(f64::NEG_INFINITY), "-Inf");
        assert_eq!(fmt_f64(f64::NAN), "NaN");
    }
}
