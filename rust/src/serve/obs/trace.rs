//! Chrome-trace export of the per-thread span rings.
//!
//! [`chrome_trace_json`] serializes every registered ring as
//! `chrome://tracing` / Perfetto "Trace Event Format" JSON: one pid
//! per worker thread (named via a `process_name` metadata event), one
//! complete `"X"` duration event per recorded span, timestamps in
//! microseconds relative to the process obs epoch. Spans that carried
//! a request id (from the `x-request-id` HTTP header) expose it as
//! `args.req`, so one slow request can be walked visually across the
//! accept, parse, journal, compute, and SSE-write threads.
//!
//! Wired to `--trace-out FILE` in `main.rs`; the file is written once
//! at shutdown (after drain) so the rings hold the tail of the run.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Value;

use super::{rings_snapshot, SpanRecord, Stage};

fn event(pid: u64, rec: &SpanRecord) -> Value {
    let name = Stage::ALL
        .get(rec.stage as usize)
        .map(|s| s.name())
        .unwrap_or("unknown");
    let mut fields = vec![
        ("ph", Value::str("X")),
        ("name", Value::str(name)),
        ("cat", Value::str("serve")),
        ("pid", Value::num(pid as f64)),
        ("tid", Value::num(0.0)),
        ("ts", Value::num(rec.start_ns as f64 / 1000.0)),
        ("dur", Value::num(rec.dur_ns as f64 / 1000.0)),
    ];
    if rec.req != 0 {
        fields.push(("args", Value::obj(vec![("req", Value::str(format!("{:016x}", rec.req)))])));
    }
    Value::obj(fields)
}

fn process_name(pid: u64, name: &str) -> Value {
    Value::obj(vec![
        ("ph", Value::str("M")),
        ("name", Value::str("process_name")),
        ("pid", Value::num(pid as f64)),
        ("args", Value::obj(vec![("name", Value::str(name))])),
    ])
}

/// Render every registered span ring as a Trace Event Format
/// document: `{"traceEvents": [...], "displayTimeUnit": "ms"}`.
pub fn chrome_trace_json() -> String {
    let mut events = Vec::new();
    for (pid0, (thread, spans)) in rings_snapshot().into_iter().enumerate() {
        let pid = pid0 as u64 + 1;
        events.push(process_name(pid, &thread));
        for rec in &spans {
            events.push(event(pid, rec));
        }
    }
    Value::obj(vec![
        ("traceEvents", Value::Arr(events)),
        ("displayTimeUnit", Value::str("ms")),
    ])
    .to_string()
}

/// Write the trace document to `path`.
pub fn write(path: &Path) -> Result<()> {
    std::fs::write(path, chrome_trace_json())
        .with_context(|| format!("writing trace to {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn trace_round_trips_as_strict_json_with_request_ids() {
        let _serial = super::super::ENABLE_TEST_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        super::super::set_enabled(true);
        super::super::register_thread();
        let t0 = super::super::now_ns();
        super::super::record_span(Stage::PhiGemm, t0, t0 + 5_000, 0xabcd);
        super::super::record_span(Stage::StateFold, t0 + 5_000, t0 + 6_000, 0);
        let text = chrome_trace_json();
        let doc = json::parse(&text).expect("trace must be strict JSON");
        let Value::Obj(top) = doc else { panic!("top level must be an object") };
        let Some(Value::Arr(events)) = top.get("traceEvents") else {
            panic!("traceEvents array missing")
        };
        assert!(!events.is_empty());
        let mut saw_meta = false;
        let mut saw_req = false;
        for ev in events {
            let Value::Obj(fields) = ev else { panic!("event must be an object") };
            match fields.get("ph") {
                Some(Value::Str(ph)) if ph == "M" => saw_meta = true,
                Some(Value::Str(ph)) if ph == "X" => {
                    assert!(matches!(fields.get("ts"), Some(Value::Num(_))));
                    assert!(matches!(fields.get("dur"), Some(Value::Num(_))));
                    if let Some(Value::Obj(args)) = fields.get("args") {
                        if let Some(Value::Str(req)) = args.get("req") {
                            saw_req |= req == "000000000000abcd";
                        }
                    }
                }
                other => panic!("unexpected ph: {other:?}"),
            }
        }
        assert!(saw_meta, "process_name metadata event missing");
        assert!(saw_req, "request id did not survive into trace args");
    }
}
