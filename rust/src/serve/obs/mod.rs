//! Observability for the serve stack: per-stage spans, lock-free
//! stage histograms, and the global counters behind `GET /metrics`
//! and `--trace-out`.
//!
//! The design constraint is the same one the scheduler lives under:
//! the hot path makes **zero heap allocations** in steady state
//! (`tests/alloc_free.rs` enforces it with a counting global
//! allocator). Recording a span therefore touches only
//!
//! * a fixed set of global [`AtomicHist`]s (relaxed atomics — the
//!   HTTP workers, the engine thread, and the fastpath pool all
//!   record concurrently, and `Telemetry` is `&mut`-owned by the
//!   pool, so the stage histograms cannot live there), and
//! * a per-thread fixed-capacity span ring behind a `thread_local`
//!   `Arc` — registered (one bounded allocation) the first time a
//!   thread records, then overwritten in place forever after.
//!
//! Stage taxonomy (one [`Stage`] per request-path phase):
//!
//! | stage | where it is recorded |
//! |---|---|
//! | `accept` | gateway worker: accepted socket → connection ready |
//! | `head_parse` | HTTP head read + parse (`net/http.rs`) |
//! | `body_parse` | HTTP body read (`net/http.rs`) |
//! | `ingress_wait` | command enqueue (worker) → engine pickup |
//! | `journal_append` | durability: op encoded into the journal buffer |
//! | `fsync` | durability: journal write + `sync_data` |
//! | `tick_gather` | scheduler: gather/scale rows into scratch |
//! | `phi_gemm` | scheduler: the two `phi_rows_into` feature steps |
//! | `state_fold` | scheduler: the parallel `(S, z)` fold |
//! | `sse_write` | gateway worker: one SSE frame onto the socket |
//! | `checkpoint` | durability: full checkpoint write + rotate |
//!
//! Request IDs: the gateway hashes the `x-request-id` header into a
//! `u64` ([`hash_request_id`]) and threads it through the ingress
//! queue, so engine-side spans (ingress wait, journal append) carry
//! the same id as the HTTP worker's spans — `--trace-out` then shows
//! one request crossing threads. [`set_request_id`] installs the id
//! in a thread-local; [`span`] picks it up implicitly.
//!
//! Everything here is dependency-free, like the rest of the serve
//! stack. The Prometheus encoder lives in [`prom`], the Chrome-trace
//! exporter in [`trace`].

pub mod prom;
pub mod trace;

use std::cell::{Cell, OnceCell};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Value;

pub use super::telemetry::BUCKETS;

/// The fixed stage taxonomy. Discriminants index the global histogram
/// table, so they must stay dense from zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    Accept = 0,
    HeadParse = 1,
    BodyParse = 2,
    IngressWait = 3,
    JournalAppend = 4,
    Fsync = 5,
    TickGather = 6,
    PhiGemm = 7,
    StateFold = 8,
    SseWrite = 9,
    Checkpoint = 10,
}

/// Number of stages (the size of the global histogram table).
pub const STAGES: usize = 11;

impl Stage {
    pub const ALL: [Stage; STAGES] = [
        Stage::Accept,
        Stage::HeadParse,
        Stage::BodyParse,
        Stage::IngressWait,
        Stage::JournalAppend,
        Stage::Fsync,
        Stage::TickGather,
        Stage::PhiGemm,
        Stage::StateFold,
        Stage::SseWrite,
        Stage::Checkpoint,
    ];

    /// Stable label value for metrics and traces.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Accept => "accept",
            Stage::HeadParse => "head_parse",
            Stage::BodyParse => "body_parse",
            Stage::IngressWait => "ingress_wait",
            Stage::JournalAppend => "journal_append",
            Stage::Fsync => "fsync",
            Stage::TickGather => "tick_gather",
            Stage::PhiGemm => "phi_gemm",
            Stage::StateFold => "state_fold",
            Stage::SseWrite => "sse_write",
            Stage::Checkpoint => "checkpoint",
        }
    }
}

// ---------------------------------------------------------------------------
// the monotonic clock
// ---------------------------------------------------------------------------

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds since the process-wide observability epoch (the first
/// call wins the race to define t=0). Allocation-free.
#[inline]
pub fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

// ---------------------------------------------------------------------------
// per-stage histograms (lock-free, log2 buckets shared with Telemetry)
// ---------------------------------------------------------------------------

/// A log2 latency histogram every thread can record into concurrently.
/// Bucket `b` covers `[2^b, 2^(b+1))` ns — the same bucketing as
/// `Telemetry`'s latency histogram, so `/metrics` exposes one
/// consistent `le` ladder. `bucket_max` tracks the exact observed
/// maximum per bucket, which is what keeps reported percentiles
/// honest (never above a value that actually occurred).
struct AtomicHist {
    buckets: [AtomicU64; BUCKETS],
    bucket_max: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)]
const ATOMIC_ZERO: AtomicU64 = AtomicU64::new(0);

impl AtomicHist {
    const fn new() -> AtomicHist {
        AtomicHist {
            buckets: [ATOMIC_ZERO; BUCKETS],
            bucket_max: [ATOMIC_ZERO; BUCKETS],
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    #[inline]
    fn record(&self, ns: u64) {
        let idx = (63 - ns.max(1).leading_zeros() as usize).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.bucket_max[idx].fetch_max(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        for b in &self.bucket_max {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
        self.max_ns.store(0, Ordering::Relaxed);
    }

    fn snapshot(&self) -> HistSnapshot {
        let mut s = HistSnapshot {
            buckets: [0; BUCKETS],
            bucket_max: [0; BUCKETS],
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
        };
        for i in 0..BUCKETS {
            s.buckets[i] = self.buckets[i].load(Ordering::Relaxed);
            s.bucket_max[i] = self.bucket_max[i].load(Ordering::Relaxed);
        }
        s
    }
}

static HISTS: [AtomicHist; STAGES] = {
    #[allow(clippy::declare_interior_mutable_const)]
    const H: AtomicHist = AtomicHist::new();
    [H; STAGES]
};

/// A point-in-time copy of one histogram, safe to read at leisure.
#[derive(Debug, Clone)]
pub struct HistSnapshot {
    pub buckets: [u64; BUCKETS],
    pub bucket_max: [u64; BUCKETS],
    pub count: u64,
    pub sum_ns: u64,
    pub max_ns: u64,
}

impl HistSnapshot {
    /// The `p`-th percentile in seconds, clamped to the exact maximum
    /// observed inside the bucket the rank lands in — never the bucket
    /// upper bound (which over-reports by up to 2x).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return self.bucket_max[idx].clamp(1, self.max_ns.max(1)) as f64 * 1e-9;
            }
        }
        self.max_ns as f64 * 1e-9
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 * 1e-9 / self.count as f64
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("count", Value::num(self.count as f64)),
            ("sum_s", Value::num(self.sum_ns as f64 * 1e-9)),
            ("mean_s", Value::num(self.mean())),
            ("p50_s", Value::num(self.percentile(50.0))),
            ("p90_s", Value::num(self.percentile(90.0))),
            ("p99_s", Value::num(self.percentile(99.0))),
            ("max_s", Value::num(self.max_ns as f64 * 1e-9)),
        ])
    }
}

/// Snapshot one stage's histogram.
pub fn snapshot(stage: Stage) -> HistSnapshot {
    HISTS[stage as usize].snapshot()
}

/// The per-stage latency breakdown as one JSON object — the section
/// `serve_load`/`serve_net`/`serve_obs` bench reports embed so a
/// throughput regression can be localized to a stage.
pub fn stage_breakdown_json() -> Value {
    Value::Obj(
        Stage::ALL.iter().map(|s| (s.name().to_string(), snapshot(*s).to_json())).collect(),
    )
}

// ---------------------------------------------------------------------------
// recording
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Is span recording on? (The `serve_obs` bench times the off arm.)
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Globally enable/disable span recording. Counters such as journal
/// bytes and HTTP response classes keep counting either way — only
/// the timestamp/histogram/ring work is gated.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

thread_local! {
    static CURRENT_REQ: Cell<u64> = const { Cell::new(0) };
}

/// Install the current request id on this thread (0 = none). Spans
/// recorded afterwards carry it into the trace rings.
#[inline]
pub fn set_request_id(req: u64) {
    CURRENT_REQ.with(|c| c.set(req));
}

/// The request id installed on this thread (0 = none).
#[inline]
pub fn request_id() -> u64 {
    CURRENT_REQ.with(|c| c.get())
}

/// FNV-1a hash of an `x-request-id` header value into the `u64` form
/// threaded through the engine. Empty input hashes to 0 ("no id").
pub fn hash_request_id(bytes: &[u8]) -> u64 {
    if bytes.is_empty() {
        return 0;
    }
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h.max(1)
}

/// Record one completed span: histogram + this thread's trace ring.
/// Allocation-free except the first time a thread records (its ring
/// is registered once and reused forever).
#[inline]
pub fn record_span(stage: Stage, start_ns: u64, end_ns: u64, req: u64) {
    if !enabled() {
        return;
    }
    let dur_ns = end_ns.saturating_sub(start_ns);
    HISTS[stage as usize].record(dur_ns);
    with_local_ring(|ring| ring.push(SpanRecord { stage: stage as u8, start_ns, dur_ns, req }));
}

/// An in-flight span; records on drop. Use [`span`] to start one.
pub struct Span {
    stage: Stage,
    start_ns: u64,
    req: u64,
    armed: bool,
}

/// Start a span for `stage`, tagged with this thread's current
/// request id. When recording is disabled the guard is inert (no
/// clock read, nothing recorded on drop).
#[inline]
pub fn span(stage: Stage) -> Span {
    if !enabled() {
        return Span { stage, start_ns: 0, req: 0, armed: false };
    }
    Span { stage, start_ns: now_ns(), req: request_id(), armed: true }
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if self.armed {
            record_span(self.stage, self.start_ns, now_ns(), self.req);
        }
    }
}

// ---------------------------------------------------------------------------
// per-thread span rings
// ---------------------------------------------------------------------------

/// Capacity of one thread's span ring. At ~10 spans per request this
/// keeps the last few hundred requests per thread visible in a trace
/// dump while bounding memory at `40 KiB` per recording thread.
pub const RING_CAP: usize = 4096;

/// One recorded span, as stored in the rings and dumped by
/// [`trace::chrome_trace_json`].
#[derive(Debug, Clone, Copy)]
pub struct SpanRecord {
    /// `Stage` discriminant (kept as `u8` to keep the record 32 bytes).
    pub stage: u8,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Hashed `x-request-id` (0 = none).
    pub req: u64,
}

struct RingInner {
    spans: Vec<SpanRecord>,
    next: usize,
}

pub(crate) struct Ring {
    name: String,
    inner: Mutex<RingInner>,
}

impl Ring {
    #[inline]
    fn push(&self, rec: SpanRecord) {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if inner.spans.len() < RING_CAP {
            inner.spans.push(rec);
        } else {
            let at = inner.next;
            inner.spans[at] = rec;
        }
        inner.next = (inner.next + 1) % RING_CAP;
    }

    /// Chronological copy of the ring's contents.
    fn drain_ordered(&self) -> Vec<SpanRecord> {
        let inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if inner.spans.len() < RING_CAP {
            inner.spans.clone()
        } else {
            let mut out = Vec::with_capacity(RING_CAP);
            out.extend_from_slice(&inner.spans[inner.next..]);
            out.extend_from_slice(&inner.spans[..inner.next]);
            out
        }
    }
}

static RINGS: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());

thread_local! {
    static LOCAL_RING: OnceCell<Arc<Ring>> = const { OnceCell::new() };
}

fn register_current_thread() -> Arc<Ring> {
    let mut rings = RINGS.lock().unwrap_or_else(|e| e.into_inner());
    let name = std::thread::current()
        .name()
        .map(str::to_string)
        .unwrap_or_else(|| format!("thread-{}", rings.len()));
    let ring = Arc::new(Ring {
        name,
        inner: Mutex::new(RingInner { spans: Vec::with_capacity(RING_CAP), next: 0 }),
    });
    rings.push(Arc::clone(&ring));
    ring
}

#[inline]
fn with_local_ring(f: impl FnOnce(&Ring)) {
    LOCAL_RING.with(|cell| f(cell.get_or_init(register_current_thread)));
}

/// Pre-register this thread's span ring (named after the thread), so
/// the one-time registration allocation happens at thread start
/// instead of inside the first recorded span. Long-lived threads
/// (gateway workers, the engine, the fastpath pool) call this on
/// spawn; it also guarantees the thread shows up in `--trace-out`
/// even before it records anything.
pub fn register_thread() {
    with_local_ring(|_| {});
}

/// Every registered ring's name + chronological span copy (the trace
/// exporter's input).
pub(crate) fn rings_snapshot() -> Vec<(String, Vec<SpanRecord>)> {
    let rings = RINGS.lock().unwrap_or_else(|e| e.into_inner());
    rings.iter().map(|r| (r.name.clone(), r.drain_ordered())).collect()
}

// ---------------------------------------------------------------------------
// durability + HTTP counters
// ---------------------------------------------------------------------------

static JOURNAL_BYTES: AtomicU64 = AtomicU64::new(0);
static RECOVERIES: AtomicU64 = AtomicU64::new(0);
static RECOVERY_REPLAYED_OPS: AtomicU64 = AtomicU64::new(0);
static RECOVERY_TRUNCATED_BYTES: AtomicU64 = AtomicU64::new(0);
/// Responses by status class: index 1..=5 for 1xx..5xx, 0 for other.
static HTTP_RESPONSES: [AtomicU64; 6] = [ATOMIC_ZERO; 6];

/// Count bytes appended to the write-ahead journal.
#[inline]
pub fn add_journal_bytes(n: u64) {
    JOURNAL_BYTES.fetch_add(n, Ordering::Relaxed);
}

pub fn journal_bytes() -> u64 {
    JOURNAL_BYTES.load(Ordering::Relaxed)
}

/// Count one startup recovery: how many journal ops were replayed
/// through the fold path and how many torn-tail bytes were truncated.
pub fn record_recovery(replayed_ops: u64, truncated_bytes: u64) {
    RECOVERIES.fetch_add(1, Ordering::Relaxed);
    RECOVERY_REPLAYED_OPS.fetch_add(replayed_ops, Ordering::Relaxed);
    RECOVERY_TRUNCATED_BYTES.fetch_add(truncated_bytes, Ordering::Relaxed);
}

pub fn recoveries() -> u64 {
    RECOVERIES.load(Ordering::Relaxed)
}

pub fn recovery_replayed_ops() -> u64 {
    RECOVERY_REPLAYED_OPS.load(Ordering::Relaxed)
}

pub fn recovery_truncated_bytes() -> u64 {
    RECOVERY_TRUNCATED_BYTES.load(Ordering::Relaxed)
}

// --- router counters (the multi-node tier; same pattern as above) ---

static ROUTER_MIGRATIONS: AtomicU64 = AtomicU64::new(0);
static ROUTER_MIGRATION_FAILURES: AtomicU64 = AtomicU64::new(0);
static ROUTER_PROXIED_REQUESTS: AtomicU64 = AtomicU64::new(0);
static ROUTER_PROXIED_BYTES: AtomicU64 = AtomicU64::new(0);
static ROUTER_RETRIES: AtomicU64 = AtomicU64::new(0);

/// Count one completed stream migration (failover or `/admin/migrate`).
#[inline]
pub fn add_router_migration() {
    ROUTER_MIGRATIONS.fetch_add(1, Ordering::Relaxed);
}

pub fn router_migrations() -> u64 {
    ROUTER_MIGRATIONS.load(Ordering::Relaxed)
}

/// Count one stream the router could not move (state unrecoverable).
#[inline]
pub fn add_router_migration_failure() {
    ROUTER_MIGRATION_FAILURES.fetch_add(1, Ordering::Relaxed);
}

pub fn router_migration_failures() -> u64 {
    ROUTER_MIGRATION_FAILURES.load(Ordering::Relaxed)
}

/// Count one proxied request and the response-body bytes relayed.
#[inline]
pub fn add_router_proxied(body_bytes: u64) {
    ROUTER_PROXIED_REQUESTS.fetch_add(1, Ordering::Relaxed);
    ROUTER_PROXIED_BYTES.fetch_add(body_bytes, Ordering::Relaxed);
}

pub fn router_proxied_requests() -> u64 {
    ROUTER_PROXIED_REQUESTS.load(Ordering::Relaxed)
}

pub fn router_proxied_bytes() -> u64 {
    ROUTER_PROXIED_BYTES.load(Ordering::Relaxed)
}

/// Count one retry the router performed against a backend (retryable
/// 429/503 re-sent after backoff).
#[inline]
pub fn add_router_retry() {
    ROUTER_RETRIES.fetch_add(1, Ordering::Relaxed);
}

pub fn router_retries() -> u64 {
    ROUTER_RETRIES.load(Ordering::Relaxed)
}

/// Count one HTTP response by status class (`429` → the 4xx bucket).
#[inline]
pub fn record_http_response(status: u16) {
    let class = (status / 100) as usize;
    HTTP_RESPONSES[if (1..=5).contains(&class) { class } else { 0 }]
        .fetch_add(1, Ordering::Relaxed);
}

/// Responses served by class: `[other, 1xx, 2xx, 3xx, 4xx, 5xx]`.
pub fn http_responses() -> [u64; 6] {
    let mut out = [0u64; 6];
    for (o, c) in out.iter_mut().zip(&HTTP_RESPONSES) {
        *o = c.load(Ordering::Relaxed);
    }
    out
}

/// Zero every histogram, counter, and span ring (rings stay
/// registered). The bench uses this to isolate its obs-on/obs-off
/// arms; production never calls it.
pub fn reset() {
    for h in &HISTS {
        h.reset();
    }
    JOURNAL_BYTES.store(0, Ordering::Relaxed);
    RECOVERIES.store(0, Ordering::Relaxed);
    RECOVERY_REPLAYED_OPS.store(0, Ordering::Relaxed);
    RECOVERY_TRUNCATED_BYTES.store(0, Ordering::Relaxed);
    ROUTER_MIGRATIONS.store(0, Ordering::Relaxed);
    ROUTER_MIGRATION_FAILURES.store(0, Ordering::Relaxed);
    ROUTER_PROXIED_REQUESTS.store(0, Ordering::Relaxed);
    ROUTER_PROXIED_BYTES.store(0, Ordering::Relaxed);
    ROUTER_RETRIES.store(0, Ordering::Relaxed);
    for c in &HTTP_RESPONSES {
        c.store(0, Ordering::Relaxed);
    }
    let rings = RINGS.lock().unwrap_or_else(|e| e.into_inner());
    for ring in rings.iter() {
        let mut inner = ring.inner.lock().unwrap_or_else(|e| e.into_inner());
        inner.spans.clear();
        inner.next = 0;
    }
}

/// Tests (here and in the submodules) that toggle the process-global
/// `ENABLED` flag or assert exact recording deltas serialize on this
/// lock so the test harness's thread pool cannot interleave them.
#[cfg(test)]
static ENABLE_TEST_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    // Global state is shared across the test binary; these tests only
    // assert relative deltas or properties that survive interleaving.

    #[test]
    fn stage_names_are_unique_and_snake_case() {
        let mut seen = std::collections::HashSet::new();
        for s in Stage::ALL {
            assert!(seen.insert(s.name()), "duplicate stage name {}", s.name());
            assert!(
                s.name().bytes().all(|b| b.is_ascii_lowercase() || b == b'_'),
                "{} is not snake_case",
                s.name()
            );
        }
        assert_eq!(Stage::ALL.len(), STAGES);
    }

    #[test]
    fn span_recording_lands_in_the_stage_histogram() {
        let _serial = ENABLE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        let before = snapshot(Stage::Checkpoint).count;
        record_span(Stage::Checkpoint, 1_000, 2_500, 7);
        let after = snapshot(Stage::Checkpoint);
        assert_eq!(after.count, before + 1);
        assert!(after.max_ns >= 1_500);
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _serial = ENABLE_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        let before = snapshot(Stage::Accept).count;
        {
            let _s = span(Stage::Accept);
        }
        record_span(Stage::Accept, 0, 10_000, 0);
        assert_eq!(snapshot(Stage::Accept).count, before);
        set_enabled(true);
    }

    #[test]
    fn percentile_clamps_to_observed_bucket_max() {
        let h = AtomicHist::new();
        // 100 samples at exactly 1000ns land in bucket [512, 1024);
        // the naive upper bound would report 1024ns.
        for _ in 0..100 {
            h.record(1_000);
        }
        h.record(10_000); // pull max_ns far above the p50 bucket
        let s = h.snapshot();
        assert_eq!(s.percentile(50.0), 1e-6);
        assert_eq!(s.max_ns, 10_000);
    }

    #[test]
    fn request_id_is_thread_local_and_hash_is_stable() {
        set_request_id(42);
        assert_eq!(request_id(), 42);
        set_request_id(0);
        assert_eq!(hash_request_id(b""), 0);
        assert_eq!(hash_request_id(b"req-1"), hash_request_id(b"req-1"));
        assert_ne!(hash_request_id(b"req-1"), hash_request_id(b"req-2"));
        assert_ne!(hash_request_id(b"req-1"), 0);
    }

    #[test]
    fn ring_overwrites_oldest_and_stays_bounded() {
        let ring = Ring {
            name: "test".into(),
            inner: Mutex::new(RingInner { spans: Vec::with_capacity(RING_CAP), next: 0 }),
        };
        for i in 0..(RING_CAP as u64 + 10) {
            ring.push(SpanRecord { stage: 0, start_ns: i, dur_ns: 1, req: 0 });
        }
        let spans = ring.drain_ordered();
        assert_eq!(spans.len(), RING_CAP);
        assert_eq!(spans[0].start_ns, 10, "oldest 10 overwritten");
        assert_eq!(spans[RING_CAP - 1].start_ns, RING_CAP as u64 + 9);
    }

    #[test]
    fn http_response_classes_bucket_correctly() {
        let before = http_responses();
        record_http_response(200);
        record_http_response(201);
        record_http_response(404);
        record_http_response(77); // nonsense status → "other"
        let after = http_responses();
        assert_eq!(after[2] - before[2], 2);
        assert_eq!(after[4] - before[4], 1);
        assert_eq!(after[0] - before[0], 1);
    }
}
