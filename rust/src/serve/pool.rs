//! [`StreamPool`] — slot-based admission, per-stream staging, and
//! output hand-off for the serving subsystem.
//!
//! The pool owns `max_streams` pre-allocated slots. Each live slot
//! holds one [`CausalState`] (sharing the pool's single
//! [`AttentionSession`] feature-map draw), fixed-size staging rows for
//! the one in-flight `(q, k, v)` submission, and the served output row.
//! Slots are reused across retire/admit cycles — the decode state is
//! [`reset`](CausalState::reset) instead of rebuilt — so a long-running
//! pool stops allocating once every slot has been warmed.
//!
//! Handles are generation-checked: [`StreamId`] is `(slot, generation)`
//! and retiring a stream bumps the slot's generation, so a stale handle
//! from a retired stream is a clean [`ServeError::UnknownStream`], not
//! silent cross-talk with whoever reuses the slot.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::attn::{AttentionSession, CausalState};

use super::telemetry::Telemetry;
use super::{ServeConfig, ServeError};

/// Opaque handle to one admitted stream: slot index + generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StreamId {
    pub(super) slot: u32,
    pub(super) gen: u32,
}

/// How a stream's fold went bad — recorded by the scheduler's fault
/// isolation, consumed when the slot is retired.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum FaultKind {
    /// The fold panicked (caught; the payload never crossed the tick).
    Panic,
    /// The denominator-health / phi screening check tripped before the
    /// bad values could enter (or after they produced a non-finite
    /// denominator in) the `(S, z)` state.
    Quarantine,
}

/// One stream slot. Staging buffers are sized once at pool build
/// (`head_dim` / `dv` rows) and never reallocated.
pub(super) struct Slot<'s> {
    pub(super) gen: u32,
    pub(super) active: bool,
    /// Present from the slot's first admission onward (kept across
    /// retire for reuse).
    pub(super) state: Option<CausalState<'s>>,
    /// A submitted token is waiting for the next tick.
    pub(super) pending: bool,
    /// `out` holds a served row the caller has not taken yet.
    pub(super) has_output: bool,
    pub(super) q: Vec<f32>,
    pub(super) k: Vec<f32>,
    pub(super) v: Vec<f32>,
    pub(super) out: Vec<f32>,
    pub(super) submitted_at: Instant,
    /// Chaos hook: the next fold for this slot panics deliberately
    /// (exercises the scheduler's panic isolation deterministically).
    pub(super) fault_armed: bool,
    /// Set by the tick's fold phase when this stream's fold was
    /// isolated; the tick retires the slot before returning.
    pub(super) fault: Option<FaultKind>,
}

/// The pool of decode streams behind one shared [`AttentionSession`].
/// See [`crate::serve`] for the lifecycle.
pub struct StreamPool<'s> {
    pub(super) session: &'s AttentionSession,
    pub(super) cfg: ServeConfig,
    pub(super) slots: Vec<Slot<'s>>,
    /// Free slot indices (stack).
    pub(super) free: Vec<u32>,
    pub(super) active: usize,
    /// Tokens currently staged for the next tick, across all streams.
    pub(super) pending: usize,
    pub(super) tel: Telemetry,
}

impl<'s> StreamPool<'s> {
    /// Build a pool over `session` (which must be causal with a
    /// Table-1 kernel — the same contract as
    /// [`AttentionSession::begin_decode`], surfaced here at build time
    /// rather than on the first admit).
    pub fn new(session: &'s AttentionSession, cfg: ServeConfig) -> Result<StreamPool<'s>> {
        // Typed as ServeError::InvalidConfig at the source; callers that
        // need the structured form use `ServeConfig::validate` directly
        // (the network frontend does, before binding a socket).
        cfg.validate()?;
        if cfg.max_streams > u32::MAX as usize {
            bail!("StreamPool: max_streams {} exceeds the slot index range", cfg.max_streams);
        }
        // Validates causal + kernel + dv + backend phi availability once,
        // with begin_decode's own error messages.
        session
            .begin_decode(cfg.dv)
            .context("StreamPool: session cannot stream-decode")?;
        let d = session.spec().head_dim;
        let now = Instant::now();
        let slots = (0..cfg.max_streams)
            .map(|_| Slot {
                gen: 0,
                active: false,
                state: None,
                pending: false,
                has_output: false,
                q: vec![0.0; d],
                k: vec![0.0; d],
                v: vec![0.0; cfg.dv],
                out: vec![0.0; cfg.dv],
                submitted_at: now,
                fault_armed: false,
                fault: None,
            })
            .collect();
        let free = (0..cfg.max_streams as u32).rev().collect();
        Ok(StreamPool {
            session,
            cfg,
            slots,
            free,
            active: 0,
            pending: 0,
            tel: Telemetry::new(),
        })
    }

    /// The shared session every stream decodes through.
    pub fn session(&self) -> &'s AttentionSession {
        self.session
    }

    /// The pool's config (normalized accessors: see
    /// [`ServeConfig::pending_bound`] / [`ServeConfig::batch_threshold`]).
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Currently admitted streams.
    pub fn active_streams(&self) -> usize {
        self.active
    }

    /// Tokens staged for the next tick.
    pub fn pending_tokens(&self) -> usize {
        self.pending
    }

    /// The pool's telemetry (latency histogram, throughput, occupancy,
    /// rejection counters). The network frontend exports every field
    /// here as Prometheus text on `GET /metrics` (see
    /// [`super::obs::prom`]).
    pub fn telemetry(&self) -> &Telemetry {
        &self.tel
    }

    pub(super) fn resolve(&self, id: StreamId) -> Result<usize, ServeError> {
        let si = id.slot as usize;
        match self.slots.get(si) {
            Some(slot) if slot.active && slot.gen == id.gen => Ok(si),
            _ => Err(ServeError::UnknownStream),
        }
    }

    /// Admit one stream. Fails with [`ServeError::PoolFull`] when every
    /// slot is live, and [`ServeError::Session`] if the shared session
    /// refuses a fresh decode state (validated at pool build, so this
    /// is unreachable in practice).
    pub fn admit(&mut self) -> Result<StreamId, ServeError> {
        let Some(si) = self.free.pop() else {
            self.tel.record_admit_rejected();
            return Err(ServeError::PoolFull { capacity: self.cfg.max_streams });
        };
        let slot = &mut self.slots[si as usize];
        match slot.state.as_mut() {
            Some(state) => state.reset(),
            None => match self.session.begin_decode(self.cfg.dv) {
                Ok(state) => slot.state = Some(state),
                Err(e) => {
                    self.free.push(si);
                    self.tel.record_admit_rejected();
                    return Err(ServeError::Session(format!("{e:#}")));
                }
            },
        }
        slot.active = true;
        slot.pending = false;
        slot.has_output = false;
        slot.fault_armed = false;
        slot.fault = None;
        // a reused slot must not inherit the previous stream's submit
        // timestamp into latency accounting (also cleared on retire)
        slot.submitted_at = Instant::now();
        self.active += 1;
        self.tel.record_admit();
        Ok(StreamId { slot: si, gen: slot.gen })
    }

    /// Retire a stream, freeing its slot (any pending token or untaken
    /// output is dropped). The handle is dead afterwards.
    pub fn retire(&mut self, id: StreamId) -> Result<(), ServeError> {
        let si = self.resolve(id)?;
        self.release_slot(si);
        Ok(())
    }

    /// Shared retire bookkeeping: drop pending/output, kill the handle
    /// generation, clear latency/fault residue, free the slot.
    fn release_slot(&mut self, si: usize) {
        let slot = &mut self.slots[si];
        if slot.pending {
            self.pending -= 1;
        }
        slot.active = false;
        slot.pending = false;
        slot.has_output = false;
        slot.fault_armed = false;
        slot.fault = None;
        slot.submitted_at = Instant::now();
        slot.gen = slot.gen.wrapping_add(1);
        self.active -= 1;
        self.free.push(si as u32);
    }

    /// Retire a slot whose fold was isolated this tick (see
    /// [`Slot::fault`]): fault counters, then the normal release path.
    /// The caller (the scheduler's fault reconciliation) has already
    /// left `slot.pending` set, so the queue bookkeeping balances here.
    pub(super) fn retire_faulted(&mut self, si: usize, kind: FaultKind) {
        self.tel.record_fault(kind == FaultKind::Quarantine);
        self.release_slot(si);
    }

    /// Arm the chaos hook: the next fold for `id` panics deliberately
    /// inside the tick, exercising the scheduler's panic isolation.
    /// Deterministic fault injection only — never fires on its own.
    pub fn arm_fault(&mut self, id: StreamId) -> Result<(), ServeError> {
        let si = self.resolve(id)?;
        self.slots[si].fault_armed = true;
        Ok(())
    }

    /// Stage one `(q, k, v)` token for `id`, to be served by the next
    /// [`Scheduler::tick`](super::Scheduler::tick). Closed-loop: each
    /// stream has at most one token in flight ([`ServeError::StreamBusy`]
    /// until the previous output is taken), and the pool-wide queue is
    /// bounded ([`ServeError::Backpressure`]).
    pub fn submit(
        &mut self,
        id: StreamId,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<(), ServeError> {
        let si = self.resolve(id)?;
        if self.slots[si].pending || self.slots[si].has_output {
            return Err(ServeError::StreamBusy);
        }
        if self.pending >= self.cfg.pending_bound() {
            self.tel.record_submit_rejected();
            // the queue drains every tick, so one tick is the honest hint
            return Err(ServeError::Backpressure {
                max_pending: self.cfg.pending_bound(),
                retry_after_ticks: 1,
            });
        }
        let d = self.session.spec().head_dim;
        let check = |what: &'static str, got: usize, expected: usize| {
            if got == expected {
                Ok(())
            } else {
                Err(ServeError::BadRow { what, expected, got })
            }
        };
        check("q", q.len(), d)?;
        check("k", k.len(), d)?;
        check("v", v.len(), self.cfg.dv)?;
        if self.cfg.screen_inputs {
            // reject-before-fold: a NaN/inf anywhere in the token would
            // poison the (S, z) accumulators irreversibly (ppSBN needs
            // finite inputs); the stream stays healthy after this error
            for (what, row) in [("q", q), ("k", k), ("v", v)] {
                if !all_finite(row) {
                    self.tel.record_nonfinite_reject();
                    return Err(ServeError::NonFinite { what });
                }
            }
        }
        let slot = &mut self.slots[si];
        slot.q.copy_from_slice(q);
        slot.k.copy_from_slice(k);
        slot.v.copy_from_slice(v);
        slot.submitted_at = Instant::now();
        slot.pending = true;
        self.pending += 1;
        Ok(())
    }

    /// True when a served output row is waiting to be taken.
    pub fn has_output(&self, id: StreamId) -> bool {
        self.resolve(id).map(|si| self.slots[si].has_output).unwrap_or(false)
    }

    /// Tokens this stream has decoded so far.
    pub fn stream_len(&self, id: StreamId) -> Result<usize, ServeError> {
        let si = self.resolve(id)?;
        Ok(self.slots[si].state.as_ref().map(|s| s.len()).unwrap_or(0))
    }

    /// Copy the served output row into `out` (length `dv`) and clear
    /// the slot for the stream's next submission.
    pub fn take_output(&mut self, id: StreamId, out: &mut [f32]) -> Result<(), ServeError> {
        let si = self.resolve(id)?;
        if !self.slots[si].has_output {
            return Err(ServeError::NoOutput);
        }
        if out.len() != self.cfg.dv {
            return Err(ServeError::BadRow { what: "out", expected: self.cfg.dv, got: out.len() });
        }
        let slot = &mut self.slots[si];
        out.copy_from_slice(&slot.out);
        slot.has_output = false;
        Ok(())
    }
}

/// True iff every value is finite (no NaN/inf). Shared by the submit
/// and prefill screens and the scheduler's phi-row quarantine check.
pub(super) fn all_finite(xs: &[f32]) -> bool {
    xs.iter().all(|x| x.is_finite())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::{AttentionSpec, Backend, Kernel};

    fn session() -> AttentionSession {
        AttentionSpec::new(Kernel::Exp)
            .head_dim(3)
            .num_features(8)
            .causal(true)
            .seed(2)
            .backend(Backend::HostFast)
            .build()
            .unwrap()
    }

    #[test]
    fn pool_rejects_non_streaming_sessions() {
        let not_causal = AttentionSpec::new(Kernel::Exp)
            .head_dim(3)
            .num_features(8)
            .build()
            .unwrap();
        assert!(StreamPool::new(&not_causal, ServeConfig::new(2, 1)).is_err());
        let sess = session();
        // dv = 0 and max_streams = 0 are typed InvalidConfig rejections
        // at construction; through the anyhow boundary the stable
        // Display phrase is the contract.
        let err = StreamPool::new(&sess, ServeConfig::new(2, 0)).unwrap_err();
        assert_eq!(err.to_string(), "invalid serve config: dv must be > 0");
        let zero_capacity = ServeConfig { max_streams: 0, ..ServeConfig::new(2, 1) };
        let err = StreamPool::new(&sess, zero_capacity).unwrap_err();
        assert_eq!(err.to_string(), "invalid serve config: max_streams must be > 0");
    }

    #[test]
    fn admission_is_bounded_with_reasoned_rejection() {
        let sess = session();
        let mut pool = StreamPool::new(&sess, ServeConfig::new(2, 1)).unwrap();
        let a = pool.admit().unwrap();
        let _b = pool.admit().unwrap();
        assert_eq!(pool.active_streams(), 2);
        assert_eq!(pool.admit().unwrap_err(), ServeError::PoolFull { capacity: 2 });
        // retiring frees the slot for a new admission
        pool.retire(a).unwrap();
        let c = pool.admit().unwrap();
        assert_eq!(pool.active_streams(), 2);
        // the retired handle is dead even though its slot was reused
        assert_eq!(pool.retire(a).unwrap_err(), ServeError::UnknownStream);
        assert_eq!(pool.stream_len(c).unwrap(), 0);
        assert_eq!(pool.telemetry().rejected_admits(), 1);
    }

    #[test]
    fn submit_validates_rows_and_closed_loop() {
        let sess = session();
        let mut pool = StreamPool::new(&sess, ServeConfig::new(2, 1)).unwrap();
        let a = pool.admit().unwrap();
        assert_eq!(
            pool.submit(a, &[0.0; 2], &[0.0; 3], &[0.0]).unwrap_err(),
            ServeError::BadRow { what: "q", expected: 3, got: 2 }
        );
        assert_eq!(
            pool.submit(a, &[0.0; 3], &[0.0; 3], &[0.0; 2]).unwrap_err(),
            ServeError::BadRow { what: "v", expected: 1, got: 2 }
        );
        pool.submit(a, &[0.0; 3], &[0.0; 3], &[0.5]).unwrap();
        assert_eq!(pool.pending_tokens(), 1);
        // one token in flight per stream
        assert_eq!(
            pool.submit(a, &[0.0; 3], &[0.0; 3], &[0.5]).unwrap_err(),
            ServeError::StreamBusy
        );
        // nothing served yet
        assert_eq!(pool.take_output(a, &mut [0.0]).unwrap_err(), ServeError::NoOutput);
    }

    #[test]
    fn submit_queue_is_bounded() {
        let sess = session();
        let cfg = ServeConfig { max_pending: 2, ..ServeConfig::new(3, 1) };
        let mut pool = StreamPool::new(&sess, cfg).unwrap();
        let ids: Vec<_> = (0..3).map(|_| pool.admit().unwrap()).collect();
        pool.submit(ids[0], &[0.0; 3], &[0.0; 3], &[0.5]).unwrap();
        pool.submit(ids[1], &[0.0; 3], &[0.0; 3], &[0.5]).unwrap();
        assert_eq!(
            pool.submit(ids[2], &[0.0; 3], &[0.0; 3], &[0.5]).unwrap_err(),
            ServeError::Backpressure { max_pending: 2, retry_after_ticks: 1 }
        );
        assert_eq!(pool.telemetry().rejected_submits(), 1);
    }

    #[test]
    fn non_finite_tokens_are_rejected_before_the_fold() {
        let sess = session();
        let mut pool = StreamPool::new(&sess, ServeConfig::new(2, 1)).unwrap();
        let a = pool.admit().unwrap();
        for (what, q, k, v) in [
            ("q", [f32::NAN, 0.0, 0.0], [0.0; 3], [0.5]),
            ("k", [0.0; 3], [0.0, f32::INFINITY, 0.0], [0.5]),
            ("v", [0.0; 3], [0.0; 3], [f32::NEG_INFINITY]),
        ] {
            assert_eq!(
                pool.submit(a, &q, &k, &v).unwrap_err(),
                ServeError::NonFinite { what },
                "{what}"
            );
        }
        assert_eq!(pool.telemetry().nonfinite_rejects(), 3);
        // the stream is intact: nothing pending, nothing folded, and a
        // finite token still goes through
        assert_eq!(pool.pending_tokens(), 0);
        assert_eq!(pool.stream_len(a).unwrap(), 0);
        pool.submit(a, &[0.1; 3], &[0.1; 3], &[0.5]).unwrap();
    }
}
