//! [`Supervisor`] — the resilience layer over pool + scheduler.
//!
//! The supervisor owns a [`StreamPool`], a [`Scheduler`], and a
//! [`Hibernator`], and tracks every client stream as a [`SessionId`]
//! entry that outlives its pool slot. Pool capacity bounds *active*
//! streams; total supervised streams are bounded only by the spill
//! arena. See the state machine in the [`crate::serve`] module docs.
//!
//! Everything here is tick-granular and deterministic: deadlines are
//! counted in [`Supervisor::tick`] calls (never wall clock), eviction
//! picks the coldest idle entry by tick age with index order as the
//! tie-break, and the steady-state deadline sweep makes zero heap
//! allocations (it walks the fixed entry table; enforced by
//! `tests/alloc_free.rs`).

use anyhow::Result;

use crate::attn::AttentionSession;

use super::super::pool::{StreamId, StreamPool};
use super::super::scheduler::{Scheduler, TickStats};
use super::super::telemetry::Telemetry;
use super::super::{ServeConfig, ServeError};
use super::hibernate::{Hibernator, Ticket};
use super::ResilienceConfig;

/// Opaque handle to one supervised stream: entry index + generation.
/// Unlike a raw [`StreamId`], it stays valid across hibernate/restore
/// cycles — the pool slot underneath may change or disappear entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionId {
    idx: u32,
    gen: u32,
}

/// Where a supervised stream currently is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamStatus {
    /// Holds a pool slot; submits and ticks flow normally.
    Active,
    /// State spilled to the arena; the next submit restores it.
    Hibernated,
    /// A poisoned fold was isolated (or a spill record went corrupt);
    /// terminal until [`Supervisor::close`].
    Faulted,
    /// A deadline fired and the state was reclaimed; terminal until
    /// [`Supervisor::close`].
    Expired,
}

#[derive(Clone, Copy)]
enum EntryState {
    Vacant,
    Active(StreamId),
    Hibernated(Ticket),
    Faulted,
    Expired,
}

struct Entry {
    gen: u32,
    state: EntryState,
    /// Tick of the last lifecycle event (open / submit / take /
    /// restore / hibernate) — the basis for every deadline.
    last_event_tick: u64,
}

/// One supervised stream's durable image, captured by
/// [`Supervisor::snapshot_stream`] for a serve checkpoint. An untaken
/// output row is deliberately not part of the image: the `(S, z)`
/// state already includes that token's fold, and a recovered client
/// re-derives the row by resubmitting from the recovered length.
pub struct StreamSnapshot {
    /// The versioned, checksummed MACS state record (see
    /// [`crate::tensor::io::write_state_record`]).
    pub record: Vec<u8>,
    /// The stream sat in the spill arena at snapshot time.
    pub hibernated: bool,
    /// A staged-but-unfolded `(q, k, v)` token, if one was pending.
    pub pending: Option<(Vec<f32>, Vec<f32>, Vec<f32>)>,
}

/// The resilience supervisor. One per served model; wraps the whole
/// pool + scheduler pair, so callers interact only with [`SessionId`]s.
pub struct Supervisor<'s> {
    pool: StreamPool<'s>,
    scheduler: Scheduler,
    hibernator: Hibernator,
    cfg: ResilienceConfig,
    entries: Vec<Entry>,
    /// Free entry indices (stack).
    free: Vec<u32>,
    tick_no: u64,
}

impl<'s> Supervisor<'s> {
    /// Build a supervisor over `session` (same contract as
    /// [`StreamPool::new`]).
    pub fn new(
        session: &'s AttentionSession,
        serve: ServeConfig,
        cfg: ResilienceConfig,
    ) -> Result<Supervisor<'s>> {
        let pool = StreamPool::new(session, serve)?;
        let hibernator = Hibernator::new(cfg.spill.clone());
        Ok(Supervisor {
            pool,
            scheduler: Scheduler::new(),
            hibernator,
            cfg,
            entries: Vec::new(),
            free: Vec::new(),
            tick_no: 0,
        })
    }

    /// Ticks elapsed (one per [`Supervisor::tick`] call).
    pub fn tick_no(&self) -> u64 {
        self.tick_no
    }

    /// The underlying serve config.
    pub fn config(&self) -> &ServeConfig {
        self.pool.config()
    }

    /// The resilience config this supervisor enforces.
    pub fn resilience_config(&self) -> &ResilienceConfig {
        &self.cfg
    }

    /// Shared telemetry (pool counters + resilience counters).
    pub fn telemetry(&self) -> &Telemetry {
        self.pool.telemetry()
    }

    /// Streams currently holding a pool slot.
    pub fn active_streams(&self) -> usize {
        self.pool.active_streams()
    }

    /// Streams currently hibernated in the spill arena.
    pub fn hibernated_streams(&self) -> usize {
        self.hibernator.stored()
    }

    fn resolve_entry(&self, id: SessionId) -> Result<usize, ServeError> {
        let ei = id.idx as usize;
        match self.entries.get(ei) {
            Some(e) if e.gen == id.gen && !matches!(e.state, EntryState::Vacant) => Ok(ei),
            _ => Err(ServeError::UnknownStream),
        }
    }

    /// Open a supervised stream. When the pool is full, the coldest
    /// idle active stream is evicted to the arena first; only if no
    /// stream is evictable does this surface [`ServeError::PoolFull`].
    pub fn open(&mut self) -> Result<SessionId, ServeError> {
        let sid = self.admit_or_evict()?;
        let ei = match self.free.pop() {
            Some(i) => i as usize,
            None => {
                self.entries.push(Entry {
                    gen: 0,
                    state: EntryState::Vacant,
                    last_event_tick: 0,
                });
                self.entries.len() - 1
            }
        };
        let tick_no = self.tick_no;
        let e = &mut self.entries[ei];
        e.state = EntryState::Active(sid);
        e.last_event_tick = tick_no;
        Ok(SessionId { idx: ei as u32, gen: e.gen })
    }

    /// Where `id` currently is in its lifecycle.
    pub fn status(&self, id: SessionId) -> Result<StreamStatus, ServeError> {
        let ei = self.resolve_entry(id)?;
        Ok(match self.entries[ei].state {
            EntryState::Active(_) => StreamStatus::Active,
            EntryState::Hibernated(_) => StreamStatus::Hibernated,
            EntryState::Faulted => StreamStatus::Faulted,
            EntryState::Expired => StreamStatus::Expired,
            EntryState::Vacant => unreachable!("resolve_entry rejects vacant entries"),
        })
    }

    /// Stage one `(q, k, v)` token. A hibernated stream is restored
    /// transparently first (bit-identically); a faulted/expired stream
    /// answers its terminal error; the overload governor sheds newest
    /// work with a typed retry hint when the queue is past
    /// [`ResilienceConfig::shed_pending`].
    pub fn submit(
        &mut self,
        id: SessionId,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<(), ServeError> {
        let ei = self.resolve_entry(id)?;
        let sid = match self.entries[ei].state {
            EntryState::Faulted => return Err(ServeError::Faulted),
            EntryState::Expired => return Err(ServeError::Expired),
            EntryState::Active(sid) => {
                self.shed_check()?;
                sid
            }
            EntryState::Hibernated(ticket) => {
                self.shed_check()?;
                self.thaw(ei, ticket)?
            }
            EntryState::Vacant => unreachable!("resolve_entry rejects vacant entries"),
        };
        self.pool.submit(sid, q, k, v)?;
        self.entries[ei].last_event_tick = self.tick_no;
        Ok(())
    }

    /// Ingest a whole prompt (see [`Scheduler::prefill`]). Restores a
    /// hibernated stream first, like [`Supervisor::submit`].
    pub fn prefill(
        &mut self,
        id: SessionId,
        q: &[f32],
        k: &[f32],
        v: &[f32],
    ) -> Result<usize, ServeError> {
        let ei = self.resolve_entry(id)?;
        let sid = match self.entries[ei].state {
            EntryState::Faulted => return Err(ServeError::Faulted),
            EntryState::Expired => return Err(ServeError::Expired),
            EntryState::Active(sid) => sid,
            EntryState::Hibernated(ticket) => self.thaw(ei, ticket)?,
            EntryState::Vacant => unreachable!("resolve_entry rejects vacant entries"),
        };
        let n = self.scheduler.prefill(&mut self.pool, sid, q, k, v)?;
        self.entries[ei].last_event_tick = self.tick_no;
        Ok(n)
    }

    /// Copy a served output row out (see [`StreamPool::take_output`]).
    pub fn take_output(&mut self, id: SessionId, out: &mut [f32]) -> Result<(), ServeError> {
        let ei = self.resolve_entry(id)?;
        match self.entries[ei].state {
            EntryState::Faulted => Err(ServeError::Faulted),
            EntryState::Expired => Err(ServeError::Expired),
            // a hibernated stream is idle by construction
            EntryState::Hibernated(_) => Err(ServeError::NoOutput),
            EntryState::Active(sid) => {
                self.pool.take_output(sid, out)?;
                self.entries[ei].last_event_tick = self.tick_no;
                Ok(())
            }
            EntryState::Vacant => unreachable!("resolve_entry rejects vacant entries"),
        }
    }

    /// Explicitly hibernate an idle active stream (snapshot to the
    /// arena, free the pool slot). Idempotent for already-hibernated
    /// streams; a stream with a pending token or an untaken output is
    /// [`ServeError::StreamBusy`].
    pub fn hibernate(&mut self, id: SessionId) -> Result<(), ServeError> {
        let ei = self.resolve_entry(id)?;
        match self.entries[ei].state {
            EntryState::Faulted => Err(ServeError::Faulted),
            EntryState::Expired => Err(ServeError::Expired),
            EntryState::Hibernated(_) => Ok(()),
            EntryState::Active(_) => self.hibernate_entry(ei),
            EntryState::Vacant => unreachable!("resolve_entry rejects vacant entries"),
        }
    }

    /// Arm the deterministic chaos hook: the stream's next fold panics
    /// inside the tick (must be active — arm after the submit that
    /// should die).
    pub fn arm_fault(&mut self, id: SessionId) -> Result<(), ServeError> {
        let ei = self.resolve_entry(id)?;
        match self.entries[ei].state {
            EntryState::Active(sid) => self.pool.arm_fault(sid),
            EntryState::Faulted => Err(ServeError::Faulted),
            EntryState::Expired => Err(ServeError::Expired),
            EntryState::Hibernated(_) => Err(ServeError::NoOutput),
            EntryState::Vacant => unreachable!("resolve_entry rejects vacant entries"),
        }
    }

    /// Close a supervised stream in any state, reclaiming whatever it
    /// still holds (pool slot, arena record, or nothing).
    pub fn close(&mut self, id: SessionId) -> Result<(), ServeError> {
        let ei = self.resolve_entry(id)?;
        match self.entries[ei].state {
            EntryState::Active(sid) => {
                let _ = self.pool.retire(sid);
            }
            EntryState::Hibernated(ticket) => self.hibernator.discard(ticket),
            EntryState::Faulted | EntryState::Expired => {}
            EntryState::Vacant => unreachable!("resolve_entry rejects vacant entries"),
        }
        let e = &mut self.entries[ei];
        e.state = EntryState::Vacant;
        e.gen = e.gen.wrapping_add(1);
        self.free.push(ei as u32);
        Ok(())
    }

    /// One supervised tick: run the deadline sweep (idle hibernation,
    /// output expiry, hibernation expiry — all tick-count based, so
    /// deterministic), then the scheduler's micro-batch tick, then
    /// reconcile entries whose stream was fault-isolated inside the
    /// tick. Steady state (no deadline fires, no faults) allocates
    /// nothing beyond the scheduler's own guarantee.
    pub fn tick(&mut self) -> Result<TickStats> {
        self.sweep_deadlines();
        let stats = self.scheduler.tick(&mut self.pool)?;
        if stats.faulted > 0 {
            // the scheduler retired the faulted slots; find the
            // entries whose handles just died and mark them terminal
            for ei in 0..self.entries.len() {
                if let EntryState::Active(sid) = self.entries[ei].state {
                    if self.pool.resolve(sid).is_err() {
                        self.entries[ei].state = EntryState::Faulted;
                    }
                }
            }
        }
        self.tick_no += 1;
        Ok(stats)
    }

    /// The tick-boundary deadline sweep. Walks the entry table once;
    /// nothing fires in steady state, and the walk itself is
    /// allocation-free.
    fn sweep_deadlines(&mut self) {
        for ei in 0..self.entries.len() {
            let age = self.tick_no.saturating_sub(self.entries[ei].last_event_tick);
            match self.entries[ei].state {
                EntryState::Active(sid) => {
                    let Ok(si) = self.pool.resolve(sid) else { continue };
                    let idle = !self.pool.slots[si].pending;
                    let has_output = self.pool.slots[si].has_output;
                    if self.cfg.output_deadline_ticks != 0
                        && has_output
                        && age >= self.cfg.output_deadline_ticks
                    {
                        // the client never took its output: reclaim
                        let _ = self.pool.retire(sid);
                        self.entries[ei].state = EntryState::Expired;
                        self.pool.tel.record_expiration();
                    } else if self.cfg.idle_hibernate_ticks != 0
                        && idle
                        && !has_output
                        && age >= self.cfg.idle_hibernate_ticks
                    {
                        // cold stream: spill it so the slot can serve
                        // someone who is actually decoding
                        if self.hibernate_entry(ei).is_ok() {
                            self.pool.tel.record_eviction();
                        }
                    }
                }
                EntryState::Hibernated(ticket) => {
                    if self.cfg.hibernate_expire_ticks != 0
                        && age >= self.cfg.hibernate_expire_ticks
                    {
                        self.hibernator.discard(ticket);
                        self.entries[ei].state = EntryState::Expired;
                        self.pool.tel.record_expiration();
                    }
                }
                EntryState::Vacant | EntryState::Faulted | EntryState::Expired => {}
            }
        }
    }

    /// Overload governor: reject-newest once the tick queue is past
    /// the shed threshold. The queue drains every tick, so one tick is
    /// the honest retry hint.
    fn shed_check(&mut self) -> Result<(), ServeError> {
        if self.cfg.shed_pending != 0 && self.pool.pending_tokens() >= self.cfg.shed_pending {
            self.pool.tel.record_shed();
            return Err(ServeError::Backpressure {
                max_pending: self.cfg.shed_pending,
                retry_after_ticks: 1,
            });
        }
        Ok(())
    }

    /// Admit a pool stream, evicting the coldest idle entry to the
    /// arena if the pool is full.
    fn admit_or_evict(&mut self) -> Result<StreamId, ServeError> {
        match self.pool.admit() {
            Ok(sid) => Ok(sid),
            Err(ServeError::PoolFull { capacity }) => {
                let Some(victim) = self.coldest_idle_entry() else {
                    return Err(ServeError::PoolFull { capacity });
                };
                self.hibernate_entry(victim)?;
                self.pool.tel.record_eviction();
                self.pool.admit()
            }
            Err(e) => Err(e),
        }
    }

    /// The active entry that has gone longest without a lifecycle
    /// event and is idle (no pending token, no untaken output) —
    /// deterministic: age-descending, entry index as tie-break.
    fn coldest_idle_entry(&self) -> Option<usize> {
        let mut best: Option<(usize, u64)> = None;
        for (ei, e) in self.entries.iter().enumerate() {
            let EntryState::Active(sid) = e.state else { continue };
            let Ok(si) = self.pool.resolve(sid) else { continue };
            let slot = &self.pool.slots[si];
            if slot.pending || slot.has_output {
                continue;
            }
            let age = self.tick_no.saturating_sub(e.last_event_tick);
            let better = match best {
                None => true,
                Some((_, best_age)) => age > best_age,
            };
            if better {
                best = Some((ei, age));
            }
        }
        best.map(|(ei, _)| ei)
    }

    /// Snapshot an active entry's state into the arena and release its
    /// pool slot.
    fn hibernate_entry(&mut self, ei: usize) -> Result<(), ServeError> {
        let EntryState::Active(sid) = self.entries[ei].state else {
            return Err(ServeError::UnknownStream);
        };
        let si = self.pool.resolve(sid)?;
        let slot = &self.pool.slots[si];
        if slot.pending || slot.has_output {
            return Err(ServeError::StreamBusy);
        }
        let state = slot.state.as_ref().expect("active slot always has a state");
        let ticket = self.hibernator.store(state)?;
        self.pool.retire(sid).expect("resolved stream retires");
        let tick_no = self.tick_no;
        let e = &mut self.entries[ei];
        e.state = EntryState::Hibernated(ticket);
        e.last_event_tick = tick_no;
        self.pool.tel.record_hibernation();
        Ok(())
    }

    /// Restore a hibernated entry into a (possibly evicted-for) fresh
    /// pool slot, bit-identically. A corrupt record faults the entry
    /// instead of half-restoring it.
    fn thaw(&mut self, ei: usize, ticket: Ticket) -> Result<StreamId, ServeError> {
        let sid = self.admit_or_evict()?;
        let si = self.pool.resolve(sid).expect("fresh admit resolves");
        let state = self.pool.slots[si].state.as_mut().expect("admitted slot has a state");
        match self.hibernator.restore(ticket, state) {
            Ok(()) => {
                let tick_no = self.tick_no;
                let e = &mut self.entries[ei];
                e.state = EntryState::Active(sid);
                e.last_event_tick = tick_no;
                self.pool.tel.record_restore();
                Ok(sid)
            }
            Err(e) => {
                let _ = self.pool.retire(sid);
                self.pool.tel.record_fault(false);
                self.entries[ei].state = EntryState::Faulted;
                Err(e)
            }
        }
    }

    // --- durability hooks (serve checkpoints + crash-restart recovery) ---

    /// Capture `id`'s durable image for a checkpoint. Terminal streams
    /// (faulted/expired) answer their terminal error — they hold no
    /// state worth persisting, and a recovered process re-derives
    /// nothing from them.
    pub fn snapshot_stream(&self, id: SessionId) -> Result<StreamSnapshot, ServeError> {
        let ei = self.resolve_entry(id)?;
        match self.entries[ei].state {
            EntryState::Faulted => Err(ServeError::Faulted),
            EntryState::Expired => Err(ServeError::Expired),
            EntryState::Hibernated(ticket) => {
                let mut record = Vec::new();
                self.hibernator.peek(ticket, &mut record)?;
                Ok(StreamSnapshot { record, hibernated: true, pending: None })
            }
            EntryState::Active(sid) => {
                let si = self.pool.resolve(sid)?;
                let slot = &self.pool.slots[si];
                let state = slot.state.as_ref().expect("active slot always has a state");
                let mut record = Vec::new();
                state.snapshot_into(&mut record);
                let pending = slot
                    .pending
                    .then(|| (slot.q.clone(), slot.k.clone(), slot.v.clone()));
                Ok(StreamSnapshot { record, hibernated: false, pending })
            }
            EntryState::Vacant => unreachable!("resolve_entry rejects vacant entries"),
        }
    }

    /// Recreate one stream from a checkpointed state record: open a
    /// fresh supervised entry, restore the record bit-identically into
    /// its pool slot, and (when the checkpoint says so) put it straight
    /// back into the spill arena. A corrupt record closes the entry
    /// again and surfaces a typed error — recovery never half-restores.
    pub fn restore_stream(
        &mut self,
        record: &[u8],
        hibernated: bool,
    ) -> Result<SessionId, ServeError> {
        let id = self.open()?;
        let ei = self.resolve_entry(id).expect("freshly opened entry resolves");
        let EntryState::Active(sid) = self.entries[ei].state else {
            unreachable!("open always yields an active entry");
        };
        let si = self.pool.resolve(sid).expect("fresh admit resolves");
        let state = self.pool.slots[si].state.as_mut().expect("admitted slot has a state");
        if let Err(e) = state.restore_from(record) {
            let _ = self.close(id);
            return Err(ServeError::Session(format!("checkpoint record corrupt: {e:#}")));
        }
        if hibernated {
            self.hibernate_entry(ei)?;
        }
        Ok(id)
    }

    /// Tokens `id` has folded so far (prefill + decode), in any
    /// non-terminal state — the recovery probe a reconnecting client
    /// uses to find where to resume.
    pub fn stream_len(&self, id: SessionId) -> Result<u64, ServeError> {
        let ei = self.resolve_entry(id)?;
        match self.entries[ei].state {
            EntryState::Faulted => Err(ServeError::Faulted),
            EntryState::Expired => Err(ServeError::Expired),
            EntryState::Active(sid) => Ok(self.pool.stream_len(sid)? as u64),
            EntryState::Hibernated(ticket) => {
                let mut record = Vec::new();
                self.hibernator.peek(ticket, &mut record)?;
                crate::tensor::io::state_record_step(&record)
                    .map_err(|e| ServeError::Session(format!("hibernated record corrupt: {e}")))
            }
            EntryState::Vacant => unreachable!("resolve_entry rejects vacant entries"),
        }
    }

    /// Jump the tick clock to a checkpointed value (recovery only).
    /// Every entry's deadline basis is re-anchored to the new clock, so
    /// the first post-recovery sweep cannot see a bogus multi-thousand-
    /// tick idle age and hibernate or expire freshly restored streams.
    pub fn restore_clock(&mut self, tick_no: u64) {
        self.tick_no = tick_no;
        for e in &mut self.entries {
            e.last_event_tick = tick_no;
        }
    }

    /// Overwrite the telemetry counters from a checkpoint (recovery
    /// only; see [`Telemetry::import_counters`]). Called after the
    /// streams are restored so the restore churn does not pollute the
    /// recovered aggregates.
    pub fn import_telemetry(&mut self, counters: &[u64; Telemetry::COUNTER_WORDS]) {
        self.pool.tel.import_counters(counters);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::{AttentionSession, AttentionSpec, Backend, Kernel};
    use crate::serve::SpillMode;

    fn session(seed: u64) -> AttentionSession {
        AttentionSpec::new(Kernel::Exp)
            .head_dim(3)
            .num_features(8)
            .causal(true)
            .seed(seed)
            .backend(Backend::HostFast)
            .build()
            .unwrap()
    }

    fn token(t: usize) -> ([f32; 3], [f32; 2]) {
        let x = [0.3 * t as f32 - 0.4, 0.1 * t as f32, -0.2];
        let v = [1.0 + t as f32, -0.5 * t as f32];
        (x, v)
    }

    /// One stream hibernates (and restores) mid-decode, the other
    /// never does; identical token sequences must produce bit-identical
    /// outputs at every step.
    #[test]
    fn hibernate_restore_is_bit_identical_mid_decode() {
        let sess = session(13);
        let mut sup =
            Supervisor::new(&sess, ServeConfig::new(2, 2), ResilienceConfig::default()).unwrap();
        let control = sup.open().unwrap();
        let roaming = sup.open().unwrap();
        let mut a = [0.0f32; 2];
        let mut b = [0.0f32; 2];
        for t in 0..8 {
            if t == 3 || t == 6 {
                sup.hibernate(roaming).unwrap();
                assert_eq!(sup.status(roaming).unwrap(), StreamStatus::Hibernated);
            }
            let (x, v) = token(t);
            sup.submit(control, &x, &x, &v).unwrap();
            // restores transparently on submit
            sup.submit(roaming, &x, &x, &v).unwrap();
            sup.tick().unwrap();
            sup.take_output(control, &mut a).unwrap();
            sup.take_output(roaming, &mut b).unwrap();
            assert_eq!(a.map(f32::to_bits), b.map(f32::to_bits), "token {t}");
        }
        assert_eq!(sup.telemetry().hibernations(), 2);
        assert_eq!(sup.telemetry().restores(), 2);
        sup.close(control).unwrap();
        sup.close(roaming).unwrap();
        assert!(matches!(sup.status(control), Err(ServeError::UnknownStream)));
    }

    /// More supervised streams than pool slots: opens and submits evict
    /// the coldest idle stream automatically, and every stream still
    /// decodes correctly through the churn.
    #[test]
    fn eviction_lets_streams_outnumber_slots() {
        let sess = session(5);
        let serve = ServeConfig { min_batch: 1, ..ServeConfig::new(2, 2) };
        let mut sup = Supervisor::new(&sess, serve, ResilienceConfig::default()).unwrap();
        let ids: Vec<SessionId> = (0..5).map(|_| sup.open().unwrap()).collect();
        assert_eq!(sup.active_streams(), 2);
        assert_eq!(sup.hibernated_streams(), 3);
        let mut out = [0.0f32; 2];
        for t in 0..4 {
            for &id in &ids {
                let (x, v) = token(t);
                sup.submit(id, &x, &x, &v).unwrap();
                sup.tick().unwrap();
                sup.take_output(id, &mut out).unwrap();
                assert!(out.iter().all(|x| x.is_finite()));
            }
        }
        assert!(sup.telemetry().evictions() > 0);
        assert!(sup.telemetry().restores() > 0);
        for &id in &ids {
            sup.close(id).unwrap();
        }
    }

    /// Tick-count deadlines: an untaken output expires its stream, an
    /// idle stream hibernates, and a hibernated stream expires — all
    /// surfaced as typed terminal errors.
    #[test]
    fn deadlines_fire_at_tick_boundaries() {
        let sess = session(9);
        let cfg = ResilienceConfig {
            idle_hibernate_ticks: 2,
            hibernate_expire_ticks: 3,
            output_deadline_ticks: 4,
            ..ResilienceConfig::default()
        };
        let mut sup = Supervisor::new(&sess, ServeConfig::new(4, 2), cfg).unwrap();

        // idle -> hibernated -> expired
        let idle = sup.open().unwrap();
        for _ in 0..3 {
            sup.tick().unwrap();
        }
        assert_eq!(sup.status(idle).unwrap(), StreamStatus::Hibernated);
        for _ in 0..4 {
            sup.tick().unwrap();
        }
        assert_eq!(sup.status(idle).unwrap(), StreamStatus::Expired);
        let (x, v) = token(0);
        assert_eq!(sup.submit(idle, &x, &x, &v).unwrap_err(), ServeError::Expired);
        sup.close(idle).unwrap();

        // untaken output -> expired
        let slow = sup.open().unwrap();
        sup.submit(slow, &x, &x, &v).unwrap();
        for _ in 0..6 {
            sup.tick().unwrap();
        }
        assert_eq!(sup.status(slow).unwrap(), StreamStatus::Expired);
        assert_eq!(sup.take_output(slow, &mut [0.0; 2]).unwrap_err(), ServeError::Expired);
        assert_eq!(sup.telemetry().expirations(), 2);
    }

    /// The governor sheds newest-first with a retry hint; a fold fault
    /// surfaces as a terminal typed error on the supervised handle.
    #[test]
    fn governor_sheds_and_faults_are_terminal() {
        let sess = session(3);
        let serve = ServeConfig { min_batch: 1, ..ServeConfig::new(4, 2) };
        let cfg = ResilienceConfig { shed_pending: 1, ..ResilienceConfig::default() };
        let mut sup = Supervisor::new(&sess, serve, cfg).unwrap();
        let a = sup.open().unwrap();
        let b = sup.open().unwrap();
        let (x, v) = token(1);
        sup.submit(a, &x, &x, &v).unwrap();
        let shed = sup.submit(b, &x, &x, &v).unwrap_err();
        assert_eq!(shed, ServeError::Backpressure { max_pending: 1, retry_after_ticks: 1 });
        assert!(shed.is_retryable());
        assert_eq!(sup.telemetry().shed(), 1);

        // kill a's next fold; the supervised handle goes terminal
        sup.arm_fault(a).unwrap();
        sup.tick().unwrap();
        assert_eq!(sup.status(a).unwrap(), StreamStatus::Faulted);
        assert_eq!(sup.submit(a, &x, &x, &v).unwrap_err(), ServeError::Faulted);
        assert!(!ServeError::Faulted.is_retryable());
        // b is unharmed
        sup.submit(b, &x, &x, &v).unwrap();
        sup.tick().unwrap();
        sup.take_output(b, &mut [0.0; 2]).unwrap();
        sup.close(a).unwrap();
        sup.close(b).unwrap();
    }

    /// The durability hooks: snapshot/restore round-trips active and
    /// hibernated streams bit-identically into a second supervisor,
    /// carries a staged-but-unfolded token, and `stream_len` probes
    /// both states without disturbing them.
    #[test]
    fn snapshot_restore_hooks_round_trip_bit_identically() {
        let sess = session(17);
        let serve = ServeConfig { min_batch: 1, ..ServeConfig::new(2, 2) };
        let mut sup = Supervisor::new(&sess, serve, ResilienceConfig::default()).unwrap();
        let awake = sup.open().unwrap();
        let asleep = sup.open().unwrap();
        let mut out = [0.0f32; 2];
        for t in 0..4 {
            let (x, v) = token(t);
            sup.submit(awake, &x, &x, &v).unwrap();
            sup.submit(asleep, &x, &x, &v).unwrap();
            sup.tick().unwrap();
            sup.take_output(awake, &mut out).unwrap();
            sup.take_output(asleep, &mut out).unwrap();
        }
        sup.hibernate(asleep).unwrap();
        // stage a token on the active stream but do not fold it yet
        let (px, pv) = token(4);
        sup.submit(awake, &px, &px, &pv).unwrap();

        let snap_awake = sup.snapshot_stream(awake).unwrap();
        let snap_asleep = sup.snapshot_stream(asleep).unwrap();
        assert!(!snap_awake.hibernated);
        assert!(snap_asleep.hibernated);
        assert!(snap_asleep.pending.is_none());
        let (pq, pk, pvv) = snap_awake.pending.clone().expect("staged token captured");
        assert_eq!(pq, px.to_vec());
        assert_eq!(sup.stream_len(awake).unwrap(), 4, "pending token not folded yet");
        assert_eq!(sup.stream_len(asleep).unwrap(), 4);

        // rebuild a fresh supervisor from the snapshots (the recovery path)
        let mut back = Supervisor::new(&sess, serve, ResilienceConfig::default()).unwrap();
        let r_awake = back.restore_stream(&snap_awake.record, false).unwrap();
        let r_asleep = back.restore_stream(&snap_asleep.record, true).unwrap();
        assert_eq!(back.status(r_asleep).unwrap(), StreamStatus::Hibernated);
        assert_eq!(back.stream_len(r_awake).unwrap(), 4);
        assert_eq!(back.stream_len(r_asleep).unwrap(), 4);

        // replay the carried token, then both arms continue identically
        back.submit(r_awake, &pq, &pk, &pvv).unwrap();
        let mut a = [0.0f32; 2];
        let mut b = [0.0f32; 2];
        for t in 4..8 {
            sup.tick().unwrap();
            back.tick().unwrap();
            sup.take_output(awake, &mut a).unwrap();
            back.take_output(r_awake, &mut b).unwrap();
            assert_eq!(a.map(f32::to_bits), b.map(f32::to_bits), "token {t}");
            let (x, v) = token(t + 1);
            sup.submit(awake, &x, &x, &v).unwrap();
            back.submit(r_awake, &x, &x, &v).unwrap();
            sup.submit(asleep, &x, &x, &v).unwrap();
            back.submit(r_asleep, &x, &x, &v).unwrap();
            sup.tick().unwrap();
            back.tick().unwrap();
            sup.take_output(asleep, &mut a).unwrap();
            back.take_output(r_asleep, &mut b).unwrap();
            assert_eq!(a.map(f32::to_bits), b.map(f32::to_bits), "hibernated arm, token {t}");
        }

        // a corrupt record is a typed error, and nothing half-restores
        // (the failed open may have evicted an idle stream to the
        // arena first, so the invariant is the total live count)
        let mut corrupt = snap_awake.record.clone();
        corrupt[28] ^= 0x10;
        let live_before = back.active_streams() + back.hibernated_streams();
        assert!(matches!(back.restore_stream(&corrupt, false), Err(ServeError::Session(_))));
        assert_eq!(back.active_streams() + back.hibernated_streams(), live_before);
    }

    /// Disk spill: hibernated state survives as a file and restores
    /// bit-identically from it.
    #[test]
    fn disk_spill_round_trips_through_the_supervisor() {
        let dir = std::env::temp_dir().join(format!("macformer_sup_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let sess = session(13);
        let cfg = ResilienceConfig {
            spill: SpillMode::Disk(dir.clone()),
            ..ResilienceConfig::default()
        };
        let mut sup = Supervisor::new(&sess, ServeConfig::new(2, 2), cfg).unwrap();
        let control = sup.open().unwrap();
        let roaming = sup.open().unwrap();
        let mut a = [0.0f32; 2];
        let mut b = [0.0f32; 2];
        for t in 0..5 {
            if t == 2 {
                sup.hibernate(roaming).unwrap();
                let files = std::fs::read_dir(&dir).unwrap().count();
                assert_eq!(files, 1, "hibernated record spilled to disk");
            }
            let (x, v) = token(t);
            sup.submit(control, &x, &x, &v).unwrap();
            sup.submit(roaming, &x, &x, &v).unwrap();
            sup.tick().unwrap();
            sup.take_output(control, &mut a).unwrap();
            sup.take_output(roaming, &mut b).unwrap();
            assert_eq!(a.map(f32::to_bits), b.map(f32::to_bits), "token {t}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
