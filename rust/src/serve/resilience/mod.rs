//! Serve resilience: hibernation & snapshot/restore, tick deadlines,
//! poison-state isolation, and deterministic fault injection.
//!
//! The linear-attention serving story makes resilience unusually
//! cheap: a stream's entire decode history is one constant-size
//! `(S, z, step)` summary, so snapshotting a stream is `O(D * dv)`
//! bytes (not `O(n)` KV cache), restoring is bit-exact, and a
//! poisoned state is caught by screening one denominator rather than
//! auditing a growing cache. This module turns those properties into
//! the [`Supervisor`] layer:
//!
//! * **Hibernation** ([`SpillMode`], the arena in `hibernate.rs`):
//!   idle streams are snapshotted through the versioned, checksummed
//!   `tensor::io` state record into RAM or a spill directory, freeing
//!   their pool slot; the next submit restores them transparently and
//!   **bit-identically**, so pool capacity bounds active streams, not
//!   total clients.
//! * **Deadlines & degradation** ([`ResilienceConfig`]): idle-
//!   hibernate, hibernate-expire, and untaken-output deadlines —
//!   counted in ticks, never wall clock, so chaos runs replay
//!   deterministically — plus a reject-newest overload governor with
//!   a typed [`ServeError::Backpressure`](super::ServeError)
//!   retry hint.
//! * **Poison isolation**: non-finite inputs are rejected at submit
//!   (the pool's screen), non-finite phi rows and fold denominators
//!   quarantine their stream before the `(S, z)` state can spread the
//!   poison, and a panicking fold is caught and retired without
//!   taking down the tick (the scheduler's `guarded_fold`). The
//!   supervised handle reports a terminal
//!   [`ServeError::Faulted`](super::ServeError).
//! * **Fault injection** ([`FaultPlan`]): a seeded, pure-function
//!   chaos schedule (NaN tokens, forced fold panics, forced
//!   hibernations, stalled clients) threaded through the load
//!   generator, so CI replays identical chaos runs and asserts that
//!   survivors are bit-identical to the fault-free run.

mod fault;
mod hibernate;
mod supervisor;

pub use fault::{parse_fault_knob, FaultKnob, FaultPlan};
pub use hibernate::SpillMode;
pub use supervisor::{SessionId, StreamStatus, Supervisor};

/// Deadline, governor, and spill knobs for one [`Supervisor`]. Every
/// deadline is a tick count (deterministic under replay); `0` disables
/// that mechanism. The default is everything off with RAM spill — the
/// supervisor then behaves exactly like the bare pool + scheduler,
/// plus fault isolation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResilienceConfig {
    /// Hibernate an active stream after this many ticks without a
    /// lifecycle event while idle (no pending token, no untaken
    /// output). 0 = never.
    pub idle_hibernate_ticks: u64,
    /// Expire a hibernated stream after this many ticks in the arena
    /// (its record is discarded; the handle answers
    /// [`ServeError::Expired`](super::ServeError)). 0 = never.
    pub hibernate_expire_ticks: u64,
    /// Expire a stream whose served output sits untaken for this many
    /// ticks (a vanished client must not pin a slot). 0 = never.
    pub output_deadline_ticks: u64,
    /// Overload governor: shed (reject-newest) submissions once the
    /// tick queue holds this many tokens, with a typed retry hint.
    /// 0 = off (the pool's own backpressure bound still applies).
    pub shed_pending: usize,
    /// Where hibernated state records live.
    pub spill: SpillMode,
}

impl Default for ResilienceConfig {
    fn default() -> ResilienceConfig {
        ResilienceConfig {
            idle_hibernate_ticks: 0,
            hibernate_expire_ticks: 0,
            output_deadline_ticks: 0,
            shed_pending: 0,
            spill: SpillMode::Memory,
        }
    }
}
