//! [`FaultPlan`] — seeded, deterministic fault injection.
//!
//! Every decision is a pure function of `(seed, stream, token)`: no
//! RNG state, no wall clock. Two runs with the same plan inject the
//! same faults at the same points, so CI can replay a chaos run and
//! assert that every surviving stream's output is **bit-identical** to
//! the fault-free run (injected-NaN tokens are rejected before any
//! fold, injected panics kill their stream before it produces the
//! token, and hibernate/restore cycles are bit-exact — none of them
//! may perturb a survivor).

/// The chaos schedule threaded through the load generator (env- or
/// CLI-driven; see [`FaultPlan::from_env`] and the `serve` subcommand's
/// `--fault-*` flags). All-zero = no faults.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed mixed into every decision.
    pub seed: u64,
    /// Inject a NaN-corrupted copy of roughly one in `nan_every`
    /// submitted tokens (rejected by the input screen; the real token
    /// follows). 0 = off.
    pub nan_every: u64,
    /// Kill this many streams with a forced fold panic, one mid-stream
    /// token each (streams `0..panics`). 0 = off.
    pub panics: u64,
    /// Force-hibernate a stream after roughly one in `hibernate_every`
    /// collected tokens (restored transparently on its next submit).
    /// 0 = off.
    pub hibernate_every: u64,
    /// Delay roughly one in `delay_every` submissions by
    /// [`delay_ticks`](FaultPlan::delay_ticks) ticks (a stalled
    /// client; lets idle-deadline sweeps fire naturally). 0 = off.
    pub delay_every: u64,
    /// How many ticks a delayed submission stalls.
    pub delay_ticks: u64,
}

/// Outcome of validating one raw `MACFORMER_FAULT_*` value — mirrors
/// `parallel::ThreadOverride` and `attention::ChunkOverride` so every
/// env knob in the crate follows the same warn-and-fall-back contract
/// (and stays unit-testable without touching the process environment).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKnob {
    /// A well-formed count (`0` keeps that fault class off).
    Count(u64),
    /// Not a `u64` — warn and stay 0; chaos must be opted into
    /// exactly, never guessed from a typo.
    Malformed,
}

/// Validate one raw `MACFORMER_FAULT_*` value. See [`FaultKnob`].
pub fn parse_fault_knob(raw: &str) -> FaultKnob {
    match raw.trim().parse::<u64>() {
        Ok(v) => FaultKnob::Count(v),
        Err(_) => FaultKnob::Malformed,
    }
}

impl FaultPlan {
    /// No faults at all (the default).
    pub fn none() -> FaultPlan {
        FaultPlan {
            seed: 0,
            nan_every: 0,
            panics: 0,
            hibernate_every: 0,
            delay_every: 0,
            delay_ticks: 0,
        }
    }

    /// True when any fault class is enabled.
    pub fn is_active(&self) -> bool {
        self.nan_every != 0
            || self.panics != 0
            || self.hibernate_every != 0
            || self.delay_every != 0
    }

    /// Read a plan from `MACFORMER_FAULT_{SEED, NAN_EVERY, PANICS,
    /// HIBERNATE_EVERY, DELAY_EVERY, DELAY_TICKS}` (each optional,
    /// default 0; malformed values warn and stay 0 — chaos must be
    /// opted into exactly, never guessed).
    pub fn from_env() -> FaultPlan {
        let read = |name: &str| -> u64 {
            match std::env::var(name) {
                Ok(raw) => match parse_fault_knob(&raw) {
                    FaultKnob::Count(v) => v,
                    FaultKnob::Malformed => {
                        log::warn!("{name}={raw:?} is not a count; ignoring");
                        0
                    }
                },
                Err(_) => 0,
            }
        };
        FaultPlan {
            seed: read("MACFORMER_FAULT_SEED"),
            nan_every: read("MACFORMER_FAULT_NAN_EVERY"),
            panics: read("MACFORMER_FAULT_PANICS"),
            hibernate_every: read("MACFORMER_FAULT_HIBERNATE_EVERY"),
            delay_every: read("MACFORMER_FAULT_DELAY_EVERY"),
            delay_ticks: read("MACFORMER_FAULT_DELAY_TICKS"),
        }
    }

    /// splitmix64-style avalanche over `(seed, salt, stream, token)` —
    /// decisions for nearby streams/tokens are uncorrelated.
    fn mix(&self, salt: u64, stream: u64, token: u64) -> u64 {
        let mut x = self
            .seed
            .wrapping_add(salt.wrapping_mul(0x2545F4914F6CDD1D))
            ^ stream.wrapping_mul(0x9E3779B97F4A7C15)
            ^ token.wrapping_mul(0xD1B54A32D192ED03);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58476D1CE4E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D049BB133111EB);
        x ^= x >> 31;
        x
    }

    /// Should a NaN-corrupted copy precede this token's submission?
    pub fn inject_nan(&self, stream: u64, token: u64) -> bool {
        self.nan_every != 0 && self.mix(1, stream, token) % self.nan_every == 0
    }

    /// Should this stream's fold panic at this token? Exactly the
    /// first [`panics`](FaultPlan::panics) streams die, each at a
    /// seed-chosen mid-stream token (never token 0, so a killed stream
    /// still has a surviving output prefix to verify).
    pub fn inject_panic(&self, stream: u64, token: u64, tokens_per_stream: u64) -> bool {
        if stream >= self.panics || tokens_per_stream == 0 {
            return false;
        }
        let at = 1 + self.mix(2, stream, 0) % tokens_per_stream.max(2).saturating_sub(1);
        token == at.min(tokens_per_stream - 1)
    }

    /// Should this stream force-hibernate after collecting this token?
    pub fn force_hibernate(&self, stream: u64, token: u64) -> bool {
        self.hibernate_every != 0 && self.mix(3, stream, token) % self.hibernate_every == 0
    }

    /// Ticks this submission stalls (0 = no delay).
    pub fn submit_delay(&self, stream: u64, token: u64) -> u64 {
        if self.delay_every != 0 && self.mix(4, stream, token) % self.delay_every == 0 {
            self.delay_ticks
        } else {
            0
        }
    }
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_and_seed_sensitive() {
        let a = FaultPlan { seed: 7, nan_every: 3, ..FaultPlan::none() };
        let b = FaultPlan { seed: 7, nan_every: 3, ..FaultPlan::none() };
        let c = FaultPlan { seed: 8, nan_every: 3, ..FaultPlan::none() };
        let hits = |p: &FaultPlan| -> Vec<(u64, u64)> {
            let mut v = Vec::new();
            for s in 0..8u64 {
                for t in 0..32u64 {
                    if p.inject_nan(s, t) {
                        v.push((s, t));
                    }
                }
            }
            v
        };
        assert_eq!(hits(&a), hits(&b), "same plan, same decisions");
        assert_ne!(hits(&a), hits(&c), "a different seed moves the faults");
        assert!(!hits(&a).is_empty(), "nan_every=3 over 256 points must fire");
    }

    #[test]
    fn panic_budget_kills_exactly_the_first_streams_once() {
        let p = FaultPlan { seed: 11, panics: 2, ..FaultPlan::none() };
        let tokens = 10u64;
        for s in 0..6u64 {
            let kill_tokens: Vec<u64> =
                (0..tokens).filter(|&t| p.inject_panic(s, t, tokens)).collect();
            if s < 2 {
                assert_eq!(kill_tokens.len(), 1, "stream {s} dies exactly once");
                assert!(kill_tokens[0] >= 1, "never the first token");
                assert!(kill_tokens[0] < tokens);
            } else {
                assert!(kill_tokens.is_empty(), "stream {s} survives");
            }
        }
    }

    #[test]
    fn fault_knobs_parse_like_the_other_env_overrides() {
        assert_eq!(parse_fault_knob("0"), FaultKnob::Count(0));
        assert_eq!(parse_fault_knob("42"), FaultKnob::Count(42));
        assert_eq!(parse_fault_knob(" 12 "), FaultKnob::Count(12), "whitespace is trimmed");
        assert_eq!(parse_fault_knob(""), FaultKnob::Malformed);
        assert_eq!(parse_fault_knob("-1"), FaultKnob::Malformed, "no negative counts");
        assert_eq!(parse_fault_knob("3.5"), FaultKnob::Malformed, "no fractional counts");
        assert_eq!(parse_fault_knob("lots"), FaultKnob::Malformed);
        assert_eq!(parse_fault_knob("0x10"), FaultKnob::Malformed, "decimal only");
    }

    #[test]
    fn inactive_plan_injects_nothing() {
        let p = FaultPlan::none();
        assert!(!p.is_active());
        for s in 0..4u64 {
            for t in 0..16u64 {
                assert!(!p.inject_nan(s, t));
                assert!(!p.inject_panic(s, t, 16));
                assert!(!p.force_hibernate(s, t));
                assert_eq!(p.submit_delay(s, t), 0);
            }
        }
    }
}
