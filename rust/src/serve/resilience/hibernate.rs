//! [`Hibernator`] — spill arena for evicted stream state.
//!
//! A hibernated stream's entire decode history collapses to one
//! versioned, checksummed state record (the `(S, z)` summary plus the
//! step counter — see [`crate::tensor::io::write_state_record`]), so
//! "spilling" a stream costs `4·(D·dv + D) + O(1)` bytes no matter how
//! many tokens it has decoded. The arena hands out generation-tagged
//! [`Ticket`]s: a stale ticket (slot reused after discard) can never
//! resurrect the wrong stream.
//!
//! Two spill targets, chosen by [`SpillMode`]:
//!
//! - [`SpillMode::Memory`]: records live in grow-only byte buffers
//!   that are reused across hibernate cycles (steady-state hibernation
//!   of same-geometry streams stops allocating once each arena slot
//!   has grown to one record's length).
//! - [`SpillMode::Disk`]: records are written to
//!   `dir/stream_{idx}_{gen}.macz` and deleted on restore/discard —
//!   state survives in files, RAM holds only scratch.

use std::path::PathBuf;

use crate::attn::CausalState;

use super::super::ServeError;

/// Where hibernated state records are spilled.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SpillMode {
    /// Keep records in an in-RAM arena (default).
    Memory,
    /// Write each record to a file under this directory. The
    /// directory is created on first spill if missing.
    Disk(PathBuf),
}

/// Handle to one hibernated state record. Single-use: redeemed (or
/// discarded) exactly once; the generation tag invalidates copies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) struct Ticket {
    idx: u32,
    gen: u32,
}

struct ArenaSlot {
    gen: u32,
    /// Record bytes (Memory mode) or read-back scratch (Disk mode,
    /// empty between uses so RAM stays bounded).
    buf: Vec<u8>,
    occupied: bool,
}

/// The spill arena. One per [`super::Supervisor`].
pub(super) struct Hibernator {
    mode: SpillMode,
    slots: Vec<ArenaSlot>,
    free: Vec<u32>,
    stored: usize,
}

impl Hibernator {
    pub(super) fn new(mode: SpillMode) -> Hibernator {
        Hibernator { mode, slots: Vec::new(), free: Vec::new(), stored: 0 }
    }

    /// Number of records currently hibernated.
    pub(super) fn stored(&self) -> usize {
        self.stored
    }

    fn path_for(dir: &std::path::Path, t: Ticket) -> PathBuf {
        dir.join(format!("stream_{}_{}.macz", t.idx, t.gen))
    }

    /// Snapshot `state` into the arena and return the ticket for it.
    pub(super) fn store(&mut self, state: &CausalState<'_>) -> Result<Ticket, ServeError> {
        let idx = match self.free.pop() {
            Some(i) => i as usize,
            None => {
                self.slots.push(ArenaSlot { gen: 0, buf: Vec::new(), occupied: false });
                self.slots.len() - 1
            }
        };
        let slot = &mut self.slots[idx];
        debug_assert!(!slot.occupied, "free list handed out an occupied slot");
        let ticket = Ticket { idx: idx as u32, gen: slot.gen };
        state.snapshot_into(&mut slot.buf);
        if let SpillMode::Disk(dir) = &self.mode {
            let write = || -> std::io::Result<()> {
                std::fs::create_dir_all(dir)?;
                std::fs::write(Self::path_for(dir, ticket), &self.slots[idx].buf)
            };
            if let Err(e) = write() {
                // The slot was never marked occupied; put it back.
                self.slots[idx].buf.clear();
                self.free.push(idx as u32);
                return Err(ServeError::Session(format!("hibernate spill failed: {e}")));
            }
            self.slots[idx].buf.clear(); // RAM holds nothing in disk mode
        }
        self.slots[idx].occupied = true;
        self.stored += 1;
        Ok(ticket)
    }

    /// Redeem `ticket`: restore its record into `state` and release
    /// the arena slot. The record is fully validated (magic, version,
    /// geometry, checksum) before a single float lands in `state`.
    pub(super) fn restore(
        &mut self,
        ticket: Ticket,
        state: &mut CausalState<'_>,
    ) -> Result<(), ServeError> {
        let slot = self
            .slots
            .get_mut(ticket.idx as usize)
            .filter(|s| s.occupied && s.gen == ticket.gen)
            .ok_or_else(|| ServeError::Session("stale hibernation ticket".into()))?;
        if let SpillMode::Disk(dir) = &self.mode {
            let path = Self::path_for(dir, ticket);
            slot.buf = std::fs::read(&path).map_err(|e| {
                ServeError::Session(format!(
                    "hibernated record {} unreadable: {e}",
                    path.display()
                ))
            })?;
            let _ = std::fs::remove_file(&path);
        }
        let restored = state
            .restore_from(&self.slots[ticket.idx as usize].buf)
            .map_err(|e| ServeError::Session(format!("hibernated record corrupt: {e}")));
        // The slot is released either way: a corrupt record is not
        // going to get better, and the caller faults the stream.
        self.release(ticket.idx as usize);
        restored
    }

    /// Copy `ticket`'s record bytes into `out` (cleared first) without
    /// redeeming the ticket — the serve-checkpoint path, which must
    /// capture hibernated state while leaving it hibernated.
    pub(super) fn peek(&self, ticket: Ticket, out: &mut Vec<u8>) -> Result<(), ServeError> {
        let slot = self
            .slots
            .get(ticket.idx as usize)
            .filter(|s| s.occupied && s.gen == ticket.gen)
            .ok_or_else(|| ServeError::Session("stale hibernation ticket".into()))?;
        out.clear();
        if let SpillMode::Disk(dir) = &self.mode {
            let path = Self::path_for(dir, ticket);
            let bytes = std::fs::read(&path).map_err(|e| {
                ServeError::Session(format!(
                    "hibernated record {} unreadable: {e}",
                    path.display()
                ))
            })?;
            out.extend_from_slice(&bytes);
        } else {
            out.extend_from_slice(&slot.buf);
        }
        Ok(())
    }

    /// Drop a record without restoring it (expiry, close).
    pub(super) fn discard(&mut self, ticket: Ticket) {
        let valid = self
            .slots
            .get(ticket.idx as usize)
            .is_some_and(|s| s.occupied && s.gen == ticket.gen);
        if valid {
            if let SpillMode::Disk(dir) = &self.mode {
                let _ = std::fs::remove_file(Self::path_for(dir, ticket));
            }
            self.release(ticket.idx as usize);
        }
    }

    fn release(&mut self, idx: usize) {
        let slot = &mut self.slots[idx];
        slot.occupied = false;
        slot.gen = slot.gen.wrapping_add(1);
        if matches!(self.mode, SpillMode::Disk(_)) {
            self.slots[idx].buf = Vec::new(); // drop any read-back allocation
        } else {
            self.slots[idx].buf.clear(); // keep capacity for the next cycle
        }
        self.free.push(idx as u32);
        self.stored -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::{AttentionSession, AttentionSpec, Backend, CausalState, Kernel};

    fn session() -> AttentionSession {
        AttentionSpec::new(Kernel::Exp)
            .head_dim(3)
            .num_features(8)
            .causal(true)
            .seed(21)
            .backend(Backend::HostFast)
            .build()
            .unwrap()
    }

    fn folded_state(session: &AttentionSession, tokens: usize) -> CausalState<'_> {
        let mut st = session.begin_decode(2).unwrap();
        for t in 0..tokens {
            let x = [t as f32 * 0.3 - 0.5, 0.25 * t as f32, -0.1];
            let v = [1.0 + t as f32, -0.5 * t as f32];
            st.append_token(&x, &x, &v).unwrap();
        }
        st
    }

    #[test]
    fn memory_arena_round_trips_and_reuses_slots() {
        let sess = session();
        let mut hib = Hibernator::new(SpillMode::Memory);

        let mut orig = folded_state(&sess, 5);
        let t1 = hib.store(&orig).unwrap();
        assert_eq!(hib.stored(), 1);

        let mut back = sess.begin_decode(2).unwrap();
        hib.restore(t1, &mut back).unwrap();
        assert_eq!(hib.stored(), 0);
        assert_eq!(back.len(), orig.len());

        // Both continue identically after the round trip.
        let x = [0.4f32, 0.1, 0.9];
        let v = [2.0f32, 3.0];
        let a = orig.append_token(&x, &x, &v).unwrap();
        let b = back.append_token(&x, &x, &v).unwrap();
        assert_eq!(a[0].to_bits(), b[0].to_bits());
        assert_eq!(a[1].to_bits(), b[1].to_bits());

        // A stale ticket must not resurrect anything.
        assert!(hib.restore(t1, &mut back).is_err());

        // The released slot is reused, not grown.
        let t2 = hib.store(&back).unwrap();
        hib.discard(t2);
        assert_eq!(hib.slots.len(), 1, "arena reuses released slots");
    }

    #[test]
    fn disk_arena_spills_files_and_cleans_up() {
        let dir = std::env::temp_dir().join(format!("macformer_hib_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let sess = session();
        let mut hib = Hibernator::new(SpillMode::Disk(dir.clone()));

        let st = folded_state(&sess, 7);
        let t = hib.store(&st).unwrap();
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert_eq!(files.len(), 1, "one record file per hibernated stream");

        let mut back = sess.begin_decode(2).unwrap();
        hib.restore(t, &mut back).unwrap();
        assert_eq!(back.len(), 7);
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(files.is_empty(), "restore deletes the spill file");

        let _ = std::fs::remove_dir_all(&dir);
    }
}
