//! The serving subsystem: many concurrent decode streams, scheduled as
//! dynamic micro-batches over the existing attention tiers.
//!
//! RMFA's per-token decode state `(S, z)` is constant-size (the
//! recurrent-state view of linear attention from RFA/Performer), which
//! is exactly what a high-throughput server wants: admitting one more
//! stream costs `O(D * dv)` memory, not `O(n)`. This module turns that
//! property into a subsystem:
//!
//! * [`StreamPool`] — admits/retires decode streams, each holding one
//!   [`CausalState`](crate::attn::CausalState). Every stream shares the
//!   pool's single [`AttentionSession`](crate::attn::AttentionSession)
//!   (one feature-map draw per kernel config), so admitting a stream
//!   never resamples features. Admission control is explicit: a full
//!   pool or a full submit queue is a typed [`ServeError`] carrying the
//!   reason — never a panic.
//! * [`Scheduler`] — every [`Scheduler::tick`], gathers the pending
//!   `append_token` submissions across streams into one batched
//!   `(g, 1, d)` feature step dispatched through the fastpath worker
//!   pool, then folds each stream's `(S, z)` update in parallel via
//!   [`for_each_index`](crate::fastpath::parallel::for_each_index).
//!   Degenerate batches (fewer than [`ServeConfig::min_batch`] pending
//!   streams) fall back to the per-stream sequential decode path. Both
//!   paths produce bit-identical outputs to a lone single-stream decode
//!   — they run the same fold code — and the steady-state tick makes
//!   zero heap allocations (enforced by `tests/alloc_free.rs`).
//!   Prompts are ingested through [`Scheduler::prefill`]: chunkwise-
//!   parallel GEMM compute over `MACFORMER_CHUNK`-token chunks instead
//!   of `n` single-token ticks, leaving the stream's `(S, z)` state
//!   bit-identical to token-by-token submission.
//! * [`resilience`] — the [`Supervisor`] wraps the pool + scheduler
//!   with stream hibernation (snapshot/restore of the `(S, z)` state
//!   through the versioned `tensor::io` record, to RAM or a spill
//!   directory), tick-deadline enforcement, an overload governor, and
//!   a seeded deterministic [`FaultPlan`] for chaos testing.
//! * [`Telemetry`] — per-token latency histogram (log2 buckets),
//!   tokens/sec, batch occupancy, queue depth, rejection counters, and
//!   the resilience counters (hibernations, restores, evictions,
//!   expirations, shed, faults, quarantines), owned by the pool and
//!   updated by the scheduler/supervisor.
//! * [`loadgen`] — the closed-loop load generator behind the
//!   `macformer serve` subcommand and the `serve_load` bench
//!   (`BENCH_serve.json`): configurable stream count, tokens per
//!   stream, arrival pattern, kernel, backend, and fault plan, with
//!   optional bit-exact verification against independent single-stream
//!   decodes.
//! * [`net`] — the dependency-free HTTP/1.1 frontend: a blocking
//!   [`net::Server`] that parses requests incrementally, maps every
//!   [`ServeError`] to an HTTP status + machine-readable body, and
//!   streams decode tokens as Server-Sent Events over chunked
//!   transfer encoding. `loadgen`'s socket mode
//!   ([`net::run_socket`]) replays the same closed-loop workload over
//!   real TCP connections and verifies survivors bit-identical to
//!   in-process decode.
//! * [`durability`] — write-ahead journal + compacting checkpoints
//!   under `--data-dir`: every acked open/prefill/close is fsynced
//!   before its reply, decode tokens are group-committed, and a
//!   restarted process replays the log through the normal fold path,
//!   so recovered streams are bit-identical to a process that never
//!   died — on either SIMD arm.
//! * [`obs`] — zero-alloc, dependency-free observability threaded
//!   through the whole request path: per-stage spans (accept, parse,
//!   ingress wait, journal append, fsync, tick gather, phi GEMM,
//!   state fold, SSE write, checkpoint) recorded into per-thread ring
//!   buffers + lock-free log2 histograms, a hand-rolled Prometheus
//!   `GET /metrics` endpoint ([`obs::prom`]), and Chrome-trace export
//!   ([`obs::trace`]) with request IDs threaded from the
//!   `x-request-id` HTTP header through the scheduler to the
//!   response. `benches/serve_obs.rs` gates the overhead at 5%.
//!
//! # Quickstart over the wire
//!
//! Start a server (`--port-file` writes the resolved port when using
//! port 0):
//!
//! ```text
//! macformer serve --listen 127.0.0.1:8077 --streams 8
//! ```
//!
//! then drive it with curl:
//!
//! ```text
//! # liveness + engine counters
//! curl -s http://127.0.0.1:8077/healthz
//!
//! # the model spec the server was built with (kernel, d, dv, seed...)
//! curl -s http://127.0.0.1:8077/v1/spec
//!
//! # open a stream -> {"stream":"s-0"}
//! curl -s -X POST http://127.0.0.1:8077/v1/streams
//!
//! # prefill a 2-token prompt (d = 4, dv = 2 here); returns the last
//! # prompt row's attention output
//! curl -s -X POST http://127.0.0.1:8077/v1/streams/s-0/prefill \
//!   -d '{"q":[0.1,0,0,0, 0,0.1,0,0],"k":[0.2,0,0,0, 0,0.2,0,0],"v":[1,0, 0,1]}'
//!
//! # decode 1 token; the response is an SSE stream of
//! #   data: {"t":0,"out":[...]}
//! # frames followed by "event: done"
//! curl -sN -X POST http://127.0.0.1:8077/v1/streams/s-0/decode \
//!   -d '{"q":[0.3,0,0,0],"k":[0.1,0,0,0],"v":[0.5,0.5]}'
//!
//! # close the stream
//! curl -s -X DELETE http://127.0.0.1:8077/v1/streams/s-0
//! ```
//!
//! # Observability quickstart
//!
//! Scrape Prometheus text exposition (every [`Telemetry`] counter,
//! per-stage latency histograms, durability + HTTP-class counters):
//!
//! ```text
//! curl -s http://127.0.0.1:8077/metrics
//! # macformer_tokens_total 4096
//! # macformer_stage_duration_seconds_bucket{stage="state_fold",le="0.000002048"} 129
//! # macformer_http_responses_total{class="5xx"} 0
//! # ...
//! ```
//!
//! Requests may carry an `x-request-id` header; the server echoes it
//! on the response and threads it through every stage span it covers.
//! Start the server with `--trace-out FILE` and the span rings are
//! dumped at drain as Chrome-trace JSON — load the file in
//! `chrome://tracing` (or Perfetto) to walk one slow request across
//! the worker, engine, and compute threads:
//!
//! ```text
//! macformer serve --listen 127.0.0.1:8077 --trace-out trace.json
//! curl -s -X POST -H 'x-request-id: req-42' \
//!   http://127.0.0.1:8077/v1/streams
//! kill -TERM %1   # drain; trace.json now holds the span rings
//! ```
//!
//! Errors are JSON with the stable [`ServeError::code`] token, e.g.
//! `{"error":"backpressure","message":"...","retryable":true,
//! "retry_after_ticks":1}` with HTTP status 429 and a `Retry-After`
//! header.
//!
//! # Stream lifecycle state machine
//!
//! A supervised stream moves through these states (tracked per
//! [`SessionId`](resilience::SessionId); the plain pool knows only
//! "admitted or not"):
//!
//! ```text
//!               open()                    idle deadline / hibernate()
//!   (vacant) ──────────► Active ───────────────────────► Hibernated
//!               ▲          │ ▲                                │
//!     restore on│submit ───┘ └────────────────────────────────┘
//!               │          │                         hibernate-expire
//!               │          │ fold panic / non-finite den       │
//!               │          ▼                                   ▼
//!               │       Faulted                            Expired
//!               │          │                                   │
//!               └──────────┴──────────── close() ──────────────┘
//!                                     (slot/arena reclaimed)
//! ```
//!
//! * **Active** — holds a pool slot; submits and ticks flow normally.
//! * **Hibernated** — the `(S, z, step)` state lives in the spill arena
//!   (or on disk); the pool slot is free for other streams. The next
//!   [`submit`](resilience::Supervisor::submit) transparently re-admits
//!   and restores, **bit-identically** — so pool capacity bounds
//!   *active* streams, not total users.
//! * **Faulted** — a poisoned fold (panic or non-finite denominator)
//!   was isolated: the slot was retired before the bad state could
//!   propagate; the stream answers [`ServeError::Faulted`] until
//!   closed. Inputs with non-finite q/k/v never get this far — they
//!   are rejected at submit with [`ServeError::NonFinite`], leaving
//!   the stream healthy ("quarantine, don't poison").
//! * **Expired** — a deadline fired (untaken output, or hibernated too
//!   long); the stream answers [`ServeError::Expired`] until closed.
//!
//! # Gateway lifecycle: readiness, drain, and crash recovery
//!
//! The process around the engine has its own small state machine,
//! reported by `GET /healthz`:
//!
//! ```text
//!    start()           recovery done        SIGTERM / POST /admin/drain
//!   ───────► starting ──────────────► ready ──────────────► draining
//!             (503)                   (200)                   (503)
//! ```
//!
//! * **starting** — the listener is already accepting (so health is
//!   observable) but the engine is still constructing or replaying the
//!   durable journal; `healthz` answers `503 {"status":"starting"}` +
//!   `Retry-After`. The `--port-file` is written only after
//!   [`net::Server::start`] returns, i.e. once recovery has finished
//!   and the gateway is genuinely ready.
//! * **ready** — normal service; `healthz` answers `200`.
//! * **draining** — entered by SIGTERM or `POST /admin/drain`. New
//!   stream opens answer a retryable `503 {"error":"draining"}` +
//!   `Retry-After`, in-flight decodes finish, the engine writes a
//!   final checkpoint (when durability is on), and the process exits
//!   with status 0.
//!
//! With `--data-dir`, a SIGKILL (or power loss) is recoverable: on
//! restart the engine loads the last good checkpoint, replays the
//! journal tail through the normal fold path, and serves every acked
//! stream bit-identically from where the crash left it. A group-commit
//! window of *delivered* decode rows may be lost from the log — never
//! bit-identity: the reconnecting client probes `GET /v1/streams/{id}`
//! for the recovered length and the deterministic fold re-derives the
//! missing rows exactly on resubmit. `serve --kill-restart --data-dir
//! DIR` is the self-contained harness proving this end to end: SIGKILL
//! mid-load at a seeded threshold, restart, resume every survivor, and
//! verify all rows bit-identical with zero 5xx.
//!
//! # Multi-node quickstart
//!
//! [`router`] scales the gateway horizontally: one router process
//! fronts N independent gateways, consistent-hashes new streams across
//! them, health-checks every node, and migrates streams off a dead
//! node onto its ring successor — transparently to clients, which keep
//! talking to one address with one stream id.
//!
//! ```text
//! # spawn 3 gateways (each on its own durable data-dir under ./fleet)
//! # plus the router fronting them:
//! macformer route --listen 127.0.0.1:8070 --spawn 3 --data-dir ./fleet \
//!   --streams 8
//!
//! # or front gateways you started yourself (pass each node's
//! # data-dir so dead-node recovery can read its durable store):
//! macformer route --listen 127.0.0.1:8070 \
//!   --backends 127.0.0.1:8077,127.0.0.1:8078 \
//!   --data-dirs ./n0,./n1
//!
//! # clients use the same wire protocol, with router-scoped ids:
//! curl -s -X POST http://127.0.0.1:8070/v1/streams   # {"stream":"r-0"}
//! curl -s http://127.0.0.1:8070/healthz              # per-backend states
//! curl -s http://127.0.0.1:8070/metrics              # router counters
//!
//! # move a stream by hand (the same path failover takes):
//! curl -s -X POST http://127.0.0.1:8070/admin/migrate -d '{"stream":"r-0"}'
//!
//! # drive load through the router exactly like a single gateway:
//! macformer serve --connect 127.0.0.1:8070 --streams 8 --verify
//! ```
//!
//! Every proxied response carries the owning backend's
//! `x-macformer-node` id, so placement stays observable without any
//! client-side awareness. `macformer route --kill-node --nodes 3
//! --data-dir DIR` runs the multi-node chaos drill: SIGKILL the
//! most-loaded backend mid-load and verify survivors bit-identical,
//! zero non-casualty 5xx, every casualty migrated and resumed.
//!
//! # Lifecycle
//!
//! ```
//! use macformer::attn::{AttentionSpec, Backend, Kernel};
//! use macformer::serve::{Scheduler, ServeConfig, StreamPool};
//!
//! let session = AttentionSpec::new(Kernel::Exp)
//!     .head_dim(2)
//!     .num_features(16)
//!     .causal(true)
//!     .backend(Backend::HostFast)
//!     .build()
//!     .unwrap();
//! let mut pool = StreamPool::new(&session, ServeConfig::new(4, 1)).unwrap();
//! let mut scheduler = Scheduler::new();
//!
//! let a = pool.admit().unwrap();
//! let b = pool.admit().unwrap();
//! pool.submit(a, &[0.1, -0.2], &[0.3, 0.0], &[1.0]).unwrap();
//! pool.submit(b, &[0.0, 0.2], &[-0.1, 0.1], &[2.0]).unwrap();
//! let stats = scheduler.tick(&mut pool).unwrap();
//! assert_eq!(stats.batch, 2);
//!
//! let mut out = [0.0f32; 1];
//! pool.take_output(a, &mut out).unwrap();
//! // the first token of a stream attends only to itself
//! assert!((out[0] - 1.0).abs() < 1e-3);
//! pool.retire(a).unwrap();
//! pool.retire(b).unwrap();
//! ```

use std::fmt;

pub mod durability;
pub mod loadgen;
pub mod net;
pub mod obs;
pub mod pool;
pub mod resilience;
pub mod router;
pub mod scheduler;
pub mod telemetry;

pub use durability::DurabilityConfig;
pub use loadgen::{Arrival, LoadConfig, LoadReport};
pub use net::{EngineSpec, NetConfig, NetLoadReport, Server};
pub use router::{BackendSpec, KillNodeReport, NodeState, Router, RouterConfig};
pub use pool::{StreamId, StreamPool};
pub use resilience::{FaultPlan, ResilienceConfig, SessionId, SpillMode, StreamStatus, Supervisor};
pub use scheduler::{Scheduler, TickStats};
pub use telemetry::Telemetry;

/// Capacity and scheduling knobs for one [`StreamPool`].
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Maximum concurrently admitted streams; [`StreamPool::admit`]
    /// beyond this is rejected with [`ServeError::PoolFull`].
    pub max_streams: usize,
    /// Bound on tokens queued for one tick across all streams;
    /// [`StreamPool::submit`] beyond this is rejected with
    /// [`ServeError::Backpressure`]. `0` means "same as `max_streams`".
    pub max_pending: usize,
    /// Batches smaller than this run the per-stream sequential decode
    /// path instead of the gathered `(g, 1, d)` step (a one-stream
    /// "batch" would only pay gather/dispatch overhead). `0` acts as 1.
    pub min_batch: usize,
    /// Value/output row length shared by every stream in the pool.
    pub dv: usize,
    /// Screen submitted q/k/v rows (and prompt row sets) for non-finite
    /// values before they can reach a fold. A rejected token is a typed
    /// [`ServeError::NonFinite`]; the stream's state is untouched.
    /// Costs one pass over `2*d + dv` floats per token — negligible
    /// next to the phi compute — and is on by default because a single
    /// NaN poisons a stream's `(S, z)` state forever.
    pub screen_inputs: bool,
}

impl ServeConfig {
    /// A config with `max_pending = max_streams`, `min_batch = 2`, and
    /// input screening on.
    pub fn new(max_streams: usize, dv: usize) -> ServeConfig {
        ServeConfig { max_streams, max_pending: 0, min_batch: 2, dv, screen_inputs: true }
    }

    /// The effective submit-queue bound (see [`ServeConfig::max_pending`]).
    pub fn pending_bound(&self) -> usize {
        if self.max_pending == 0 {
            self.max_streams
        } else {
            self.max_pending
        }
    }

    /// The effective sequential-fallback threshold (>= 1).
    pub fn batch_threshold(&self) -> usize {
        self.min_batch.max(1)
    }

    /// Reject configs that cannot admit a single stream or describe a
    /// zero-length output row. Checked at [`StreamPool::new`] and
    /// [`net::Server::start`] so a bad config is a typed
    /// [`ServeError::InvalidConfig`] at construction, not a panic (or
    /// a divide-by-zero) at first use.
    pub fn validate(&self) -> Result<(), ServeError> {
        if self.max_streams == 0 {
            return Err(ServeError::InvalidConfig { what: "max_streams must be > 0" });
        }
        if self.dv == 0 {
            return Err(ServeError::InvalidConfig { what: "dv must be > 0" });
        }
        Ok(())
    }
}

/// Why the pool rejected a request. Every admission-control,
/// stale-handle, and stream-health failure is one of these —
/// reject-with-reason, never a panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// A [`ServeConfig`] that cannot work was rejected at construction
    /// ([`ServeConfig::validate`]): `max_streams == 0` or `dv == 0`.
    InvalidConfig {
        /// Which knob was rejected and why.
        what: &'static str,
    },
    /// [`StreamPool::admit`] with every slot occupied.
    PoolFull {
        /// The pool's `max_streams`.
        capacity: usize,
    },
    /// [`StreamPool::submit`] with the tick queue at its bound, or the
    /// supervisor's overload governor shedding newest-first.
    Backpressure {
        /// The bound that was hit (the pool's effective `max_pending`,
        /// or the governor's shed threshold).
        max_pending: usize,
        /// Backoff hint: the queue drains at tick granularity, so
        /// retrying sooner than this many ticks cannot succeed.
        retry_after_ticks: u64,
    },
    /// The [`StreamId`] does not name a live stream (never admitted,
    /// already retired, or a stale generation after slot reuse).
    UnknownStream,
    /// Closed-loop violation: the stream already has a token pending or
    /// an output waiting to be taken.
    StreamBusy,
    /// [`StreamPool::take_output`] before a tick served the stream's
    /// pending token.
    NoOutput,
    /// A submitted row (or prompt row set) has the wrong length for
    /// this pool's session.
    BadRow {
        /// Which row (`"q"`, `"k"`, `"v"`, `"out"`, or `"prompt q"` /
        /// `"prompt v"` for [`Scheduler::prefill`] row sets).
        what: &'static str,
        /// Required length.
        expected: usize,
        /// Submitted length.
        got: usize,
    },
    /// A submitted row contains NaN/inf. The token was rejected before
    /// any fold, so the stream's `(S, z)` state is untouched — resubmit
    /// a finite token and the stream continues unharmed.
    NonFinite {
        /// Which row (`"q"`, `"k"`, `"v"`, or the prompt equivalents).
        what: &'static str,
    },
    /// A supervisor deadline fired (untaken output or hibernated too
    /// long); the stream's state has been reclaimed.
    Expired,
    /// The stream's fold panicked or produced a non-finite denominator;
    /// the slot was retired before the poison could spread. Terminal
    /// for the stream.
    Faulted,
    /// The underlying session rejected the stream (backend/spec error).
    Session(String),
}

impl ServeError {
    /// Whether the caller can expect the same request to succeed later
    /// without changing it: capacity/timing conditions are retryable,
    /// bad inputs and dead streams are fatal. Stable contract for the
    /// future network frontend's wire mapping.
    pub fn is_retryable(&self) -> bool {
        match self {
            ServeError::PoolFull { .. }
            | ServeError::Backpressure { .. }
            | ServeError::StreamBusy
            | ServeError::NoOutput => true,
            ServeError::InvalidConfig { .. }
            | ServeError::UnknownStream
            | ServeError::BadRow { .. }
            | ServeError::NonFinite { .. }
            | ServeError::Expired
            | ServeError::Faulted
            | ServeError::Session(_) => false,
        }
    }

    /// A stable machine-readable token per variant (wire code for the
    /// future network frontend; also the grep key in chaos logs).
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::InvalidConfig { .. } => "invalid_config",
            ServeError::PoolFull { .. } => "pool_full",
            ServeError::Backpressure { .. } => "backpressure",
            ServeError::UnknownStream => "unknown_stream",
            ServeError::StreamBusy => "stream_busy",
            ServeError::NoOutput => "no_output",
            ServeError::BadRow { .. } => "bad_row",
            ServeError::NonFinite { .. } => "non_finite",
            ServeError::Expired => "expired",
            ServeError::Faulted => "faulted",
            ServeError::Session(_) => "session",
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::InvalidConfig { what } => {
                write!(f, "invalid serve config: {what}")
            }
            ServeError::PoolFull { capacity } => {
                write!(f, "pool full: all {capacity} stream slots are admitted")
            }
            ServeError::Backpressure { max_pending, retry_after_ticks } => {
                write!(
                    f,
                    "backpressure: {max_pending} tokens already queued for this tick \
                     (retry after {retry_after_ticks} ticks)"
                )
            }
            ServeError::UnknownStream => {
                write!(f, "unknown stream: the id is not live (retired or never admitted)")
            }
            ServeError::StreamBusy => {
                write!(f, "stream busy: one token in flight per stream (take the output first)")
            }
            ServeError::NoOutput => {
                write!(f, "no output ready: the pending token has not been ticked yet")
            }
            ServeError::BadRow { what, expected, got } => {
                write!(f, "bad {what} row: expected length {expected}, got {got}")
            }
            ServeError::NonFinite { what } => {
                write!(f, "non-finite {what} row: token rejected before the fold (stream intact)")
            }
            ServeError::Expired => {
                write!(f, "stream expired: a deadline fired and the state was reclaimed")
            }
            ServeError::Faulted => {
                write!(f, "stream faulted: the fold was isolated and the slot retired")
            }
            ServeError::Session(reason) => write!(f, "session rejected the stream: {reason}"),
        }
    }
}

impl std::error::Error for ServeError {}
