//! `macformer` — the launcher.
//!
//! Subcommands:
//!   info                         backend + artifact inventory
//!   train                        one (task, variant) training run
//!   sweep                        Table-2: all variants x tasks, subprocesses
//!   microbench                   Fig-4 RMFA-vs-softmax grid (--kernel exp|inv|log|trigh|sqrt,
//!                                --backend auto|reference|host|device)
//!   fig3                         ppSBN translation ablation
//!   serve                        closed-loop multi-stream decode load run
//!                                (--streams, --tokens, --prompt n for chunked
//!                                prompt prefill at admission, --arrival
//!                                closed|staggered|bursty, --kernel, --backend,
//!                                --verify); --listen ADDR starts the HTTP/1.1
//!                                gateway instead (--port-file writes the
//!                                resolved port once the gateway is ready,
//!                                --data-dir PATH journals streams durably and
//!                                recovers them on restart, SIGTERM drains
//!                                gracefully), --connect ADDR drives a running
//!                                gateway over TCP, --kill-restart --data-dir
//!                                PATH runs the crash-restart chaos drill,
//!                                --trace-out FILE dumps the recorded stage
//!                                spans as Chrome trace JSON on exit,
//!                                --retry-budget-ms caps the wall-clock a
//!                                loadgen client spends retrying one request
//!   route                        consistent-hashing router fronting N serve
//!                                gateways (--backends a,b[,c] or --spawn N
//!                                --data-dir BASE to launch a local fleet;
//!                                probes /healthz, fails over dead nodes by
//!                                migrating their streams to ring successors);
//!                                --kill-node --nodes N --data-dir BASE runs
//!                                the SIGKILL failover chaos drill instead
//!   datagen                      dump synthetic dataset samples
//!
//! Every run prints a human summary to stdout and (with --out-json) a
//! machine-readable report for the bench harnesses / EXPERIMENTS.md.

use anyhow::{anyhow, bail, Result};

use macformer::attn::{Backend, Kernel};
use macformer::config::RunConfig;
use macformer::coordinator::{fig3, microbench, sweep, Trainer};
use macformer::runtime::{client, Registry};
use macformer::util::cli::Args;
use macformer::util::logging;

fn main() {
    logging::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(args),
        Some("train") => cmd_train(args),
        Some("sweep") => cmd_sweep(args),
        Some("microbench") => cmd_microbench(args),
        Some("fig3") => cmd_fig3(args),
        Some("serve") => cmd_serve(args),
        Some("route") => cmd_route(args),
        Some("datagen") => cmd_datagen(args),
        Some(other) => bail!(
            "unknown subcommand {other:?}; try: info, train, sweep, microbench, fig3, serve, \
             route, datagen"
        ),
        None => {
            println!(
                "macformer v{} — Random Maclaurin Feature Attention",
                macformer::VERSION
            );
            println!(
                "usage: macformer <info|train|sweep|microbench|fig3|serve|route|datagen> [flags]"
            );
            Ok(())
        }
    }
}

fn registry(args: &Args) -> Result<Registry> {
    let dir = args.str_flag("artifacts", "artifacts");
    Registry::open(std::path::Path::new(&dir))
}

fn cmd_info(args: &Args) -> Result<()> {
    let reg = registry(args)?;
    args.check_unknown().map_err(|e| anyhow!(e))?;
    println!("backend: {}", client::describe()?);
    println!("artifacts: {} modules in {:?}", reg.modules.len(), reg.dir);
    let mut by_role = std::collections::BTreeMap::new();
    for m in reg.modules.values() {
        *by_role.entry(m.role.clone()).or_insert(0usize) += 1;
    }
    for (role, count) in by_role {
        println!("  {role:<14} {count}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_args(args)?;
    args.check_unknown().map_err(|e| anyhow!(e))?;
    let reg = Registry::open(std::path::Path::new(&cfg.artifacts_dir))?;
    let out_json = cfg.out_json.clone();
    let ckpt = cfg.checkpoint.clone();
    let mut trainer = Trainer::build(cfg, &reg)?;
    let report = trainer.run()?;
    if let Some(path) = ckpt {
        macformer::coordinator::checkpoint::save(
            std::path::Path::new(&path),
            &trainer.state,
            &trainer.info,
        )?;
        log::info!("checkpoint saved to {path}");
    }
    println!(
        "{}: steps {} | loss {:.4} | eval loss {:.4} | quality {:.3} | {:.1}s train ({:.3}s/step) | peak rss {}",
        report.family,
        report.steps,
        report.final_loss,
        report.eval_loss,
        report.quality,
        report.train_seconds,
        report.step_seconds_mean,
        macformer::util::human_bytes(report.peak_rss_bytes),
    );
    if let Some(path) = out_json {
        std::fs::write(&path, report.to_json().to_string())?;
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_args(args)?;
    let tasks_flag = args.str_flag("tasks", "lra_text,lra_listops,lra_retrieval");
    let variants_flag = args.str_flag(
        "variants",
        "softmax,rfa,mac_exp,mac_inv,mac_trigh,mac_log,mac_sqrt",
    );
    args.check_unknown().map_err(|e| anyhow!(e))?;
    let variants: Vec<&str> = variants_flag.split(',').collect();
    let mut tables = Vec::new();
    for task in tasks_flag.split(',') {
        tables.push(sweep::run_task(&cfg, task, &variants)?);
    }
    println!("{}", sweep::render_table(&tables));
    if let Some(path) = cfg.out_json {
        std::fs::write(&path, sweep::to_json(&tables).to_string())?;
    }
    Ok(())
}

fn cmd_microbench(args: &Args) -> Result<()> {
    use std::str::FromStr;
    // typed parses: a typo'd --backend or --kernel is a clean CLI error,
    // never a panic
    let backend_flag = args.str_flag("backend", "host");
    let backend = Backend::from_str(&backend_flag).map_err(|e| anyhow!("--backend: {e}"))?;
    let kernel_flag = args.str_flag("kernel", "exp");
    let kernel = Kernel::from_str(&kernel_flag).map_err(|e| anyhow!("--kernel: {e}"))?;
    let repeats = args.usize_flag("repeats", 5).map_err(|e| anyhow!(e))?;
    let seed = args.u64_flag("seed", 7).map_err(|e| anyhow!(e))?;
    let groups = args.usize_flag("groups", 16 * 8).map_err(|e| anyhow!(e))?;
    let lengths_flag = args.opt_flag("lengths");
    let features_flag = args.opt_flag("features");
    let out_json = args.opt_flag("out-json");
    let artifacts_flag = args.str_flag("artifacts", "artifacts");
    args.check_unknown().map_err(|e| anyhow!(e))?;
    let parse_list = |s: String| -> Result<Vec<usize>> {
        s.split(',')
            .map(|x| x.parse::<usize>().map_err(|e| anyhow!("bad list item {x:?}: {e}")))
            .collect()
    };
    if !matches!(backend, Backend::Device) {
        // Reference, HostFast, or Auto resolving to the host tier — the
        // host grid times the requested tier per cell (plus the oracle
        // tier as the speedup baseline)
        let lengths = match lengths_flag {
            Some(s) => parse_list(s)?,
            None => vec![256, 1024, 2048],
        };
        let features = match features_flag {
            Some(s) => parse_list(s)?,
            None => vec![64, 128],
        };
        let cells = microbench::run_host_grid(
            kernel, backend, &lengths, &features, repeats, seed, groups, 64,
        )?;
        println!("{}", microbench::render_host(&cells));
        if let Some(path) = out_json {
            std::fs::write(&path, microbench::host_to_json(&cells).to_string())?;
        }
        return Ok(());
    }
    if kernel != Kernel::Exp {
        bail!(
            "the device microbench runs precompiled rmfa_exp artifacts; \
             --kernel {kernel} is host-only (drop --backend device)"
        );
    }
    let reg = Registry::open(std::path::Path::new(&artifacts_flag))?;
    let lengths = match lengths_flag {
        Some(s) => parse_list(s)?,
        None => reg.micro_lengths.clone(),
    };
    let features = match features_flag {
        Some(s) => parse_list(s)?,
        None => reg.micro_features.clone(),
    };
    let cells = microbench::run_grid(&reg, &lengths, &features, repeats, seed)?;
    println!("{}", microbench::render(&cells));
    if let Some(path) = out_json {
        std::fs::write(&path, microbench::to_json(&cells).to_string())?;
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let mut cfg = RunConfig::default();
    cfg.apply_args(args)?;
    let epochs = args.usize_flag("epochs", 8).map_err(|e| anyhow!(e))?;
    let spe = args.usize_flag("steps-per-epoch", 50).map_err(|e| anyhow!(e))?;
    args.check_unknown().map_err(|e| anyhow!(e))?;
    let reg = Registry::open(std::path::Path::new(&cfg.artifacts_dir))?;
    cfg.train_examples = cfg.train_examples.max(spe * 32);
    let out_json = cfg.out_json.clone();
    let result = fig3::run(&reg, &cfg, epochs, spe)?;
    println!("{}", fig3::render(&result));
    if let Some(path) = out_json {
        std::fs::write(&path, fig3::to_json(&result).to_string())?;
    }
    Ok(())
}

/// Flipped by the `SIGTERM` handler; polled by the `serve --listen`
/// drain loop.
static SIGTERM_SEEN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn on_sigterm(_signum: libc::c_int) {
    // async-signal-safe: a single atomic store
    SIGTERM_SEEN.store(true, std::sync::atomic::Ordering::SeqCst);
}

fn install_sigterm_handler() {
    // SAFETY: registers an async-signal-safe handler (one atomic store)
    // for SIGTERM; the previous disposition is not needed.
    unsafe {
        libc::signal(libc::SIGTERM, on_sigterm as libc::sighandler_t);
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    use macformer::serve::loadgen::{self, Arrival, LoadConfig};
    use macformer::serve::{FaultPlan, ResilienceConfig, SpillMode};
    use std::str::FromStr;
    let kernel_flag = args.str_flag("kernel", "exp");
    let kernel = Kernel::from_str(&kernel_flag).map_err(|e| anyhow!("--kernel: {e}"))?;
    let backend_flag = args.str_flag("backend", "host");
    let backend = Backend::from_str(&backend_flag).map_err(|e| anyhow!("--backend: {e}"))?;
    let arrival_flag = args.str_flag("arrival", "closed");
    let arrival = Arrival::from_str(&arrival_flag).map_err(|e| anyhow!("--arrival: {e}"))?;
    // Chaos plan: MACFORMER_FAULT_* env vars seed the defaults, flags
    // override — so CI can pin a plan in the environment and a human
    // can still tweak one knob from the command line.
    let env_plan = FaultPlan::from_env();
    let faults = FaultPlan {
        seed: args.u64_flag("fault-seed", env_plan.seed).map_err(|e| anyhow!(e))?,
        nan_every: args.u64_flag("fault-nan-every", env_plan.nan_every).map_err(|e| anyhow!(e))?,
        panics: args.u64_flag("fault-panics", env_plan.panics).map_err(|e| anyhow!(e))?,
        hibernate_every: args
            .u64_flag("fault-hibernate-every", env_plan.hibernate_every)
            .map_err(|e| anyhow!(e))?,
        delay_every: args
            .u64_flag("fault-delay-every", env_plan.delay_every)
            .map_err(|e| anyhow!(e))?,
        delay_ticks: args
            .u64_flag("fault-delay-ticks", env_plan.delay_ticks)
            .map_err(|e| anyhow!(e))?,
    };
    let spill = match args.opt_flag("spill-dir") {
        Some(dir) => SpillMode::Disk(std::path::PathBuf::from(dir)),
        None => SpillMode::Memory,
    };
    let resilience = ResilienceConfig {
        idle_hibernate_ticks: args.u64_flag("idle-hibernate-ticks", 0).map_err(|e| anyhow!(e))?,
        hibernate_expire_ticks: args
            .u64_flag("hibernate-expire-ticks", 0)
            .map_err(|e| anyhow!(e))?,
        output_deadline_ticks: args
            .u64_flag("output-deadline-ticks", 0)
            .map_err(|e| anyhow!(e))?,
        shed_pending: args.usize_flag("shed-pending", 0).map_err(|e| anyhow!(e))?,
        spill,
    };
    let cfg = LoadConfig {
        streams: args.usize_flag("streams", 64).map_err(|e| anyhow!(e))?,
        tokens: args.usize_flag("tokens", 128).map_err(|e| anyhow!(e))?,
        prompt: args.usize_flag("prompt", 0).map_err(|e| anyhow!(e))?,
        head_dim: args.usize_flag("head-dim", 32).map_err(|e| anyhow!(e))?,
        dv: args.usize_flag("dv", 32).map_err(|e| anyhow!(e))?,
        num_features: args.usize_flag("features", 64).map_err(|e| anyhow!(e))?,
        kernel,
        backend,
        arrival,
        min_batch: args.usize_flag("min-batch", 2).map_err(|e| anyhow!(e))?,
        seed: args.u64_flag("seed", 7).map_err(|e| anyhow!(e))?,
        verify: args.switch("verify"),
        faults,
        resilience,
    };
    let out_json = args.opt_flag("out-json");
    let listen = args.opt_flag("listen");
    let connect = args.opt_flag("connect");
    let port_file = args.opt_flag("port-file");
    let workers = args.usize_flag("workers", 4).map_err(|e| anyhow!(e))?;
    let queue_depth = args.usize_flag("queue-depth", 128).map_err(|e| anyhow!(e))?;
    let max_pending = args.usize_flag("max-pending", 0).map_err(|e| anyhow!(e))?;
    let data_dir = args.opt_flag("data-dir");
    let sync_every = args.u64_flag("sync-every", 32).map_err(|e| anyhow!(e))?;
    let checkpoint_every = args.u64_flag("checkpoint-every", 1024).map_err(|e| anyhow!(e))?;
    let kill_restart = args.switch("kill-restart");
    let trace_out = args.opt_flag("trace-out");
    let retry_budget_ms = args
        .u64_flag("retry-budget-ms", macformer::serve::net::DEFAULT_RETRY_BUDGET_MS)
        .map_err(|e| anyhow!(e))?;
    args.check_unknown().map_err(|e| anyhow!(e))?;
    if listen.is_some() && connect.is_some() {
        bail!("--listen and --connect are mutually exclusive");
    }
    // Wall-clock cap on a single request's retry loop (0 = attempts
    // only) — matters behind a router that answers `503 migrating`
    // while a stream's home node is being failed over.
    macformer::serve::net::set_retry_budget_ms(retry_budget_ms);
    // --trace-out: dump every recorded stage span as Chrome trace JSON
    // (chrome://tracing / Perfetto) when the run ends. Written on the
    // degraded paths too — a trace of a bad run is the useful one.
    let write_trace = || -> Result<()> {
        if let Some(path) = &trace_out {
            macformer::serve::obs::trace::write(std::path::Path::new(path))?;
            log::info!("stage trace written to {path}");
        }
        Ok(())
    };

    // --kill-restart: SIGKILL a child gateway mid-load, restart it on
    // the same data-dir, verify recovery bit-identical
    if kill_restart {
        if listen.is_some() || connect.is_some() {
            bail!("--kill-restart runs its own server; drop --listen/--connect");
        }
        let dir = data_dir
            .as_deref()
            .ok_or_else(|| anyhow!("--kill-restart needs --data-dir for the durable store"))?;
        let report = macformer::serve::net::run_kill_restart(&cfg, std::path::Path::new(dir))?;
        println!("{}", report.render());
        if let Some(path) = out_json {
            std::fs::write(&path, report.to_json().to_string())?;
        }
        if !report.verified || report.stream_errors > 0 || report.http_5xx > 0 {
            bail!(
                "kill-restart degraded: verified {}, {} stream errors, {} x 5xx",
                report.verified,
                report.stream_errors,
                report.http_5xx
            );
        }
        return Ok(());
    }

    // --listen: run the HTTP/1.1 gateway until SIGTERM / drain
    if let Some(addr) = listen {
        use macformer::serve::net::NetConfig;
        use macformer::serve::{DurabilityConfig, EngineSpec, ServeConfig, Server};
        let spec = EngineSpec {
            kernel: cfg.kernel,
            backend: cfg.backend,
            head_dim: cfg.head_dim,
            dv: cfg.dv,
            num_features: cfg.num_features,
            seed: cfg.seed,
        };
        let serve_cfg = ServeConfig {
            max_pending,
            min_batch: cfg.min_batch,
            ..ServeConfig::new(cfg.streams, cfg.dv)
        };
        let net = NetConfig { addr, workers, queue_depth, ..NetConfig::default() };
        let durability = data_dir.map(|dir| {
            let mut d = DurabilityConfig::new(dir);
            d.sync_every_ticks = sync_every.max(1);
            d.checkpoint_every_ticks = checkpoint_every.max(1);
            d
        });
        let server = Server::start(net, spec, serve_cfg, cfg.resilience.clone(), durability)?;
        let local = server.local_addr();
        // written only after Server::start returns, i.e. once the
        // gateway is accepting and the engine (recovery included)
        // reported ready — harnesses key off this file
        if let Some(path) = port_file {
            std::fs::write(&path, local.port().to_string())?;
        }
        println!(
            "serving on http://{local}  (kernel {}, d {}, dv {}, features {}, seed {}, {} streams)",
            cfg.kernel, cfg.head_dim, cfg.dv, cfg.num_features, cfg.seed, cfg.streams
        );
        // SIGTERM or POST /admin/drain flips the gateway into graceful
        // drain: stop admitting, finish in-flight decodes, write a
        // final checkpoint, exit 0
        install_sigterm_handler();
        loop {
            let term = SIGTERM_SEEN.load(std::sync::atomic::Ordering::SeqCst);
            if term || server.drain_requested() {
                eprintln!("draining: finishing in-flight work and checkpointing");
                server.drain();
                write_trace()?;
                return Ok(());
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    }

    // --connect: drive a running gateway over TCP (socket loadgen)
    if let Some(addr) = connect {
        let report = macformer::serve::net::run_socket(&cfg, &addr)?;
        println!("{}", report.render());
        if let Some(path) = out_json {
            std::fs::write(&path, report.to_json().to_string())?;
        }
        write_trace()?;
        if report.verified == Some(false)
            || report.stream_errors > 0
            || report.poisoned_streams > 0
            || report.http_5xx > 0
        {
            bail!(
                "socket serve run degraded: verified {:?}, {} stream errors, \
                 {} poisoned streams, {} x 5xx",
                report.verified,
                report.stream_errors,
                report.poisoned_streams,
                report.http_5xx
            );
        }
        return Ok(());
    }

    let report = loadgen::run(&cfg)?;
    println!("{}", report.render());
    if let Some(path) = out_json {
        std::fs::write(&path, report.to_json().to_string())?;
    }
    write_trace()?;
    // Planned chaos casualties (faulted_streams) are not a failure;
    // poison escaping isolation or any unexpected stream error is.
    if report.verified == Some(false) || report.stream_errors > 0 || report.poisoned_streams > 0 {
        bail!(
            "serve run degraded: verified {:?}, {} stream errors, {} poisoned streams",
            report.verified,
            report.stream_errors,
            report.poisoned_streams
        );
    }
    Ok(())
}

/// `macformer route` — the multi-node front door. Two server shapes
/// (`--backends` fronting already-running gateways, `--spawn N`
/// launching a local fleet of child gateways) plus the `--kill-node`
/// chaos drill, which SIGKILLs the most-loaded backend mid-decode and
/// verifies the survivors plus the migrated casualties finish
/// bit-identical to a run where nothing died.
fn cmd_route(args: &Args) -> Result<()> {
    use macformer::serve::loadgen::LoadConfig;
    use macformer::serve::router::{run_kill_node, spawn_node};
    use macformer::serve::{BackendSpec, Router, RouterConfig};
    use std::path::PathBuf;
    use std::str::FromStr;
    use std::time::Duration;

    let kernel_flag = args.str_flag("kernel", "exp");
    let kernel = Kernel::from_str(&kernel_flag).map_err(|e| anyhow!("--kernel: {e}"))?;
    let backend_flag = args.str_flag("backend", "host");
    let backend = Backend::from_str(&backend_flag).map_err(|e| anyhow!("--backend: {e}"))?;
    // The engine set every spawned gateway runs with (and the load the
    // kill-node drill drives). Must match across the fleet: a stream
    // migrates only between engines with identical specs.
    let cfg = LoadConfig {
        streams: args.usize_flag("streams", 8).map_err(|e| anyhow!(e))?,
        tokens: args.usize_flag("tokens", 64).map_err(|e| anyhow!(e))?,
        head_dim: args.usize_flag("head-dim", 32).map_err(|e| anyhow!(e))?,
        dv: args.usize_flag("dv", 32).map_err(|e| anyhow!(e))?,
        num_features: args.usize_flag("features", 64).map_err(|e| anyhow!(e))?,
        kernel,
        backend,
        min_batch: args.usize_flag("min-batch", 2).map_err(|e| anyhow!(e))?,
        seed: args.u64_flag("seed", 7).map_err(|e| anyhow!(e))?,
        ..LoadConfig::default()
    };
    let listen = args.str_flag("listen", "127.0.0.1:0");
    let port_file = args.opt_flag("port-file");
    let backends_flag = args.opt_flag("backends");
    let data_dirs_flag = args.opt_flag("data-dirs");
    let spawn = args.usize_flag("spawn", 0).map_err(|e| anyhow!(e))?;
    let data_dir = args.opt_flag("data-dir");
    let workers = args.usize_flag("workers", 16).map_err(|e| anyhow!(e))?;
    let vnodes = args.usize_flag("vnodes", 64).map_err(|e| anyhow!(e))?;
    let probe_interval_ms = args.u64_flag("probe-interval-ms", 20).map_err(|e| anyhow!(e))?;
    let probe_timeout_ms = args.u64_flag("probe-timeout-ms", 250).map_err(|e| anyhow!(e))?;
    let fail_threshold = args.u64_flag("fail-threshold", 5).map_err(|e| anyhow!(e))? as u32;
    let recover_threshold = args.u64_flag("recover-threshold", 3).map_err(|e| anyhow!(e))? as u32;
    let retry_budget_ms = args.u64_flag("retry-budget-ms", 500).map_err(|e| anyhow!(e))?;
    let kill_node = args.switch("kill-node");
    let nodes = args.usize_flag("nodes", 3).map_err(|e| anyhow!(e))?;
    let out_json = args.opt_flag("out-json");
    args.check_unknown().map_err(|e| anyhow!(e))?;

    // --kill-node: self-contained chaos drill (fleet + router + load +
    // SIGKILL + failover + bit-exact verification), then exit
    if kill_node {
        let dir = data_dir
            .as_deref()
            .ok_or_else(|| anyhow!("--kill-node needs --data-dir for the node stores"))?;
        let report = run_kill_node(&cfg, std::path::Path::new(dir), nodes)?;
        println!("{}", report.render());
        if let Some(path) = out_json {
            std::fs::write(&path, report.to_json().to_string())?;
        }
        if !report.verified
            || report.stream_errors > 0
            || report.non_casualty_5xx > 0
            || report.migration_failures > 0
        {
            bail!(
                "kill-node degraded: verified {}, {} stream errors, {} non-casualty 5xx, \
                 {} failed migrations",
                report.verified,
                report.stream_errors,
                report.non_casualty_5xx,
                report.migration_failures
            );
        }
        return Ok(());
    }

    // Assemble the backend fleet: either addresses of gateways someone
    // else runs, or children this process spawns and owns.
    if (backends_flag.is_some() as usize) + ((spawn > 0) as usize) != 1 {
        bail!("route needs exactly one of --backends a,b,... or --spawn N --data-dir BASE");
    }
    let mut children: Vec<std::process::Child> = Vec::new();
    let mut specs: Vec<BackendSpec> = Vec::new();
    if let Some(list) = backends_flag {
        let addrs: Vec<&str> = list.split(',').filter(|s| !s.is_empty()).collect();
        let dirs: Vec<String> = match &data_dirs_flag {
            Some(s) => s.split(',').map(str::to_string).collect(),
            None => Vec::new(),
        };
        if !dirs.is_empty() && dirs.len() != addrs.len() {
            bail!(
                "--data-dirs lists {} entries for {} --backends (one per address; \
                 leave an entry empty for a backend with no durable store)",
                dirs.len(),
                addrs.len()
            );
        }
        for (i, addr) in addrs.iter().enumerate() {
            let dir = dirs.get(i).filter(|d| !d.is_empty()).map(PathBuf::from);
            specs.push(BackendSpec { addr: addr.to_string(), data_dir: dir });
        }
    } else {
        let base = data_dir
            .as_deref()
            .ok_or_else(|| anyhow!("--spawn needs --data-dir BASE for the node stores"))?;
        let base = std::path::Path::new(base);
        // each gateway needs enough workers that the router's proxy
        // pool (one pooled connection per router worker) plus the
        // prober plus a migration transfer never starve
        let node_workers = workers + 8;
        for n in 0..spawn {
            let dir = base.join(format!("node{n}"));
            match spawn_node(&cfg, &dir, node_workers) {
                Ok((child, addr)) => {
                    children.push(child);
                    specs.push(BackendSpec { addr, data_dir: Some(dir) });
                }
                Err(e) => {
                    for mut c in children {
                        let _ = c.kill();
                        let _ = c.wait();
                    }
                    return Err(anyhow!("spawning node {n}: {e}"));
                }
            }
        }
    }

    let rcfg = RouterConfig {
        listen,
        workers,
        vnodes,
        seed: cfg.seed,
        probe_interval: Duration::from_millis(probe_interval_ms.max(1)),
        probe_timeout: Duration::from_millis(probe_timeout_ms.max(1)),
        fail_threshold,
        recover_threshold,
        retry_budget: Duration::from_millis(retry_budget_ms),
        backends: specs,
        ..RouterConfig::default()
    };
    let router = match Router::start(rcfg) {
        Ok(r) => r,
        Err(e) => {
            for mut c in children {
                let _ = c.kill();
                let _ = c.wait();
            }
            return Err(e);
        }
    };
    let local = router.local_addr();
    // written only once the router is accepting and the prober thread
    // is running — harnesses key off this file
    if let Some(path) = port_file {
        std::fs::write(&path, local.port().to_string())?;
    }
    println!(
        "routing on http://{local}  ({} backends, {} spawned, node {})",
        router.backend_states().len(),
        children.len(),
        router.node_id()
    );
    for (addr, state, node) in router.backend_states() {
        println!("  backend {addr}  {}  {node}", state.name());
    }

    // SIGTERM or POST /admin/drain: stop admitting at the router, pass
    // the drain down to spawned children, wait for them, exit 0 only
    // if every child drained cleanly
    install_sigterm_handler();
    loop {
        let term = SIGTERM_SEEN.load(std::sync::atomic::Ordering::SeqCst);
        if term || router.drain_requested() {
            eprintln!("draining: refusing new streams, draining {} children", children.len());
            router.begin_drain();
            for child in &children {
                // SAFETY: signals a child this process spawned and
                // still owns; SIGTERM is the gateway's drain trigger.
                unsafe {
                    libc::kill(child.id() as libc::pid_t, libc::SIGTERM);
                }
            }
            let mut failed = 0usize;
            for mut child in children {
                match child.wait() {
                    Ok(st) if st.success() => {}
                    Ok(st) => {
                        eprintln!("child gateway exited {st}");
                        failed += 1;
                    }
                    Err(e) => {
                        eprintln!("waiting on child gateway: {e}");
                        failed += 1;
                    }
                }
            }
            router.shutdown();
            if failed > 0 {
                bail!("{failed} child gateways failed to drain cleanly");
            }
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
}

fn cmd_datagen(args: &Args) -> Result<()> {
    use macformer::data;
    let task = args.str_flag("task", "lra_listops");
    let count = args.usize_flag("count", 3).map_err(|e| anyhow!(e))?;
    let seed = args.u64_flag("seed", 1).map_err(|e| anyhow!(e))?;
    let n = args.usize_flag("seq-len", 128).map_err(|e| anyhow!(e))?;
    args.check_unknown().map_err(|e| anyhow!(e))?;
    match task.as_str() {
        "lra_text" => {
            let mut rng = macformer::util::rng::Rng::new(seed);
            for e in data::text_cls::generate(&mut rng, count, n) {
                println!("[label {}] {}", e.label, e.text);
            }
        }
        "lra_listops" => {
            let mut rng = macformer::util::rng::Rng::new(seed);
            let v = data::listops::vocab();
            for e in data::listops::generate(&mut rng, count, n, 0.6) {
                let text: Vec<&str> = e
                    .tokens
                    .iter()
                    .take_while(|t| **t != data::vocab::SYM_PAD)
                    .filter_map(|t| v.symbol(*t))
                    .collect();
                println!("[label {}] {}", e.label, text.join(" "));
            }
        }
        "translation" => {
            let lex = data::translation::lexicon(0xBEEF);
            let mut rng = macformer::util::rng::Rng::new(seed);
            for _ in 0..count {
                let p = data::translation::sample_pair(&mut rng, &lex);
                println!("src {:?} -> tgt {:?}", p.src, p.tgt);
            }
        }
        other => bail!(
            "datagen for {other:?} not supported (try lra_text, lra_listops, translation)"
        ),
    }
    Ok(())
}
