//! Pure-Rust Random Maclaurin Feature map (Definition 3) — the host-side
//! mirror of the L1 Pallas kernel, used by property tests to validate the
//! unbiasedness claims (Theorem 1) independently of JAX.

use crate::attn::kernel::{degree_distribution, Kernel};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One sampled RMF map: per-feature degrees and Rademacher directions.
#[derive(Debug, Clone)]
pub struct RmfMap {
    /// degrees[i] = N_i
    pub degrees: Vec<usize>,
    /// omega[i][j] in {-1, +1}^d for j < degrees[i]
    pub omega: Vec<Vec<Vec<f32>>>,
    /// scales[i] = sqrt(a_{N_i} p^{N_i + 1})
    pub scales: Vec<f32>,
    pub dim_in: usize,
}

impl RmfMap {
    /// Draw a D-feature map for `kernel` on inputs of dimension d.
    ///
    /// Panics if `kernel` is [`Kernel::Softmax`] (no Maclaurin expansion
    /// to sample from); `attn::AttentionSpec::build` rejects that
    /// combination with a clean error before reaching here.
    pub fn sample(
        rng: &mut Rng,
        kernel: Kernel,
        num_features: usize,
        dim_in: usize,
        p: f64,
        max_degree: usize,
    ) -> RmfMap {
        assert!(
            kernel.has_maclaurin(),
            "RmfMap::sample: kernel {kernel} has no Maclaurin expansion to sample from"
        );
        assert!(
            num_features > 0,
            "RmfMap::sample: num_features must be > 0 — a zero-feature map \
             would make apply_row scale by sqrt(1/0) and emit NaNs silently"
        );
        assert!(
            dim_in > 0,
            "RmfMap::sample: dim_in must be > 0 — degree >= 1 features would \
             take empty-dot products and collapse phi to zero"
        );
        let probs = degree_distribution(p, max_degree);
        let mut degrees = Vec::with_capacity(num_features);
        let mut omega = Vec::with_capacity(num_features);
        let mut scales = Vec::with_capacity(num_features);
        for _ in 0..num_features {
            let n = rng.weighted(&probs);
            degrees.push(n);
            scales.push(kernel.feature_scale(n, p).expect("Maclaurin kernel checked above") as f32);
            let dirs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..dim_in).map(|_| rng.rademacher()).collect())
                .collect();
            omega.push(dirs);
        }
        RmfMap { degrees, omega, scales, dim_in }
    }

    pub fn num_features(&self) -> usize {
        self.degrees.len()
    }

    /// phi(x) for a single row x (length dim_in).
    pub fn apply_row(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.dim_in);
        let d = self.num_features() as f32;
        let inv = (1.0 / d).sqrt();
        self.omega
            .iter()
            .zip(&self.scales)
            .map(|(dirs, scale)| {
                let mut prod = 1.0f32;
                for w in dirs {
                    let dot: f32 = w.iter().zip(x).map(|(a, b)| a * b).sum();
                    prod *= dot;
                }
                scale * prod * inv
            })
            .collect()
    }

    /// Phi over an (n x dim_in) tensor -> (n x D).
    pub fn apply(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.shape[1], self.dim_in);
        let n = x.shape[0];
        let feat = self.num_features();
        let mut out = Tensor::zeros(&[n, feat]);
        for i in 0..n {
            let row = self.apply_row(&x.data[i * self.dim_in..(i + 1) * self.dim_in]);
            out.data[i * feat..(i + 1) * feat].copy_from_slice(&row);
        }
        out
    }
}

/// Monte-Carlo estimate of K(x.y) via phi(x).phi(y), averaged over `draws`
/// independently sampled maps — the Theorem-1 expectation check.
pub fn mc_kernel_estimate(
    rng: &mut Rng,
    kernel: Kernel,
    x: &[f32],
    y: &[f32],
    num_features: usize,
    p: f64,
    max_degree: usize,
    draws: usize,
) -> f64 {
    let mut acc = 0.0f64;
    for _ in 0..draws {
        let map = RmfMap::sample(rng, kernel, num_features, x.len(), p, max_degree);
        let fx = map.apply_row(x);
        let fy = map.apply_row(y);
        let dot: f32 = fx.iter().zip(&fy).map(|(a, b)| a * b).sum();
        acc += dot as f64;
    }
    acc / draws as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_count_and_shape() {
        let mut rng = Rng::new(1);
        let map = RmfMap::sample(&mut rng, Kernel::Exp, 32, 8, 2.0, 8);
        assert_eq!(map.num_features(), 32);
        let x = vec![0.1f32; 8];
        assert_eq!(map.apply_row(&x).len(), 32);
    }

    #[test]
    #[should_panic(expected = "num_features must be > 0")]
    fn sample_rejects_zero_features() {
        let mut rng = Rng::new(1);
        let _ = RmfMap::sample(&mut rng, Kernel::Exp, 0, 8, 2.0, 8);
    }

    #[test]
    #[should_panic(expected = "dim_in must be > 0")]
    fn sample_rejects_zero_dim() {
        let mut rng = Rng::new(1);
        let _ = RmfMap::sample(&mut rng, Kernel::Exp, 8, 0, 2.0, 8);
    }

    #[test]
    fn zero_degree_features_are_constant() {
        let mut rng = Rng::new(2);
        let map = RmfMap::sample(&mut rng, Kernel::Exp, 64, 4, 2.0, 8);
        let a = map.apply_row(&[0.5, -0.5, 0.25, 0.0]);
        let b = map.apply_row(&[0.0, 0.9, -0.1, 0.3]);
        for (i, &deg) in map.degrees.iter().enumerate() {
            if deg == 0 {
                assert_eq!(a[i], b[i], "degree-0 feature {i} must not vary");
            }
        }
    }

    #[test]
    fn unbiased_for_exp_kernel() {
        // E[phi(x).phi(y)] -> truncated exp(x.y); tolerance from MC noise.
        let mut rng = Rng::new(3);
        let x = [0.3f32, -0.2, 0.1, 0.4];
        let y = [0.2f32, 0.3, -0.1, 0.2];
        let t: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let est = mc_kernel_estimate(&mut rng, Kernel::Exp, &x, &y, 64, 2.0, 8, 3000);
        let exact = Kernel::Exp.truncated_value(t as f64, 8).unwrap();
        assert!(
            (est - exact).abs() < 0.05 * exact.abs().max(1.0),
            "est {est} vs exact {exact}"
        );
    }

    #[test]
    fn unbiased_for_inv_kernel() {
        let mut rng = Rng::new(4);
        let x = [0.3f32, -0.1, 0.2, 0.1];
        let y = [0.25f32, 0.2, -0.15, 0.1];
        let t: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let est = mc_kernel_estimate(&mut rng, Kernel::Inv, &x, &y, 64, 2.0, 8, 3000);
        let exact = Kernel::Inv.truncated_value(t as f64, 8).unwrap();
        assert!(
            (est - exact).abs() < 0.08 * exact.abs().max(1.0),
            "est {est} vs exact {exact}"
        );
    }

    #[test]
    fn variance_decreases_with_features() {
        // Theorem 2: error concentrates as D grows. Estimate variance of
        // the kernel estimate at D=8 vs D=128.
        let x = [0.4f32, -0.3, 0.2, 0.1];
        let y = [0.1f32, 0.2, 0.3, -0.2];
        let spread = |feat: usize, seed: u64| {
            let mut rng = Rng::new(seed);
            let mut vals = Vec::new();
            for _ in 0..200 {
                let map = RmfMap::sample(&mut rng, Kernel::Exp, feat, 4, 2.0, 8);
                let fx = map.apply_row(&x);
                let fy = map.apply_row(&y);
                vals.push(fx.iter().zip(&fy).map(|(a, b)| a * b).sum::<f32>() as f64);
            }
            let m = vals.iter().sum::<f64>() / vals.len() as f64;
            vals.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / vals.len() as f64
        };
        let v_small = spread(8, 7);
        let v_big = spread(128, 8);
        assert!(
            v_big < v_small / 4.0,
            "variance must shrink with D: D=8 {v_small} vs D=128 {v_big}"
        );
    }
}
