//! Pure-Rust reference implementations of the paper's math.
//!
//! Independent of JAX/XLA — these mirror `python/compile/kernels/ref.py`
//! and exist so the compiled HLO modules can be validated by a second
//! implementation (integration tests) and so property tests on the
//! paper's theorems (unbiasedness, concentration) run natively.

pub mod attention;
pub mod maclaurin;
pub mod rmf;
