//! Pure-Rust reference implementations of the paper's math.
//!
//! Independent of JAX/XLA — these mirror `python/compile/kernels/ref.py`
//! and exist so the compiled HLO modules can be validated by a second
//! implementation (integration tests) and so property tests on the
//! paper's theorems (unbiasedness, concentration) run natively.
//!
//! Kernel identities (Table-1 coefficients, closed forms, the degree
//! law) live on the typed [`crate::attn::Kernel`] enum — the old
//! stringly-typed `maclaurin` module is gone. This tier is the oracle
//! behind [`crate::attn::ReferenceBackend`]; run attention through
//! [`crate::attn::AttentionSpec`] rather than calling these free
//! functions directly.

pub mod attention;
pub mod rmf;
