//! Table-1 kernels and their Maclaurin coefficients — the Rust mirror of
//! `python/compile/maclaurin.py`. Cross-language agreement is enforced by
//! golden tests (same values both sides) and by the table1_kernels bench,
//! which regenerates Table 1 and numerically validates each expansion
//! against its closed form.

/// The five dot-product kernels of Table 1 (paper order).
pub const KERNELS: [&str; 5] = ["exp", "inv", "log", "trigh", "sqrt"];

/// Truncation degree used by the static AOT lowering (see python side).
pub const DEFAULT_MAX_DEGREE: usize = 8;

fn factorial(n: usize) -> f64 {
    (1..=n).map(|i| i as f64).product()
}

fn double_factorial(n: i64) -> f64 {
    if n <= 0 {
        return 1.0;
    }
    let mut out = 1.0;
    let mut k = n;
    while k > 1 {
        out *= k as f64;
        k -= 2;
    }
    out
}

/// a_N: the N-th Maclaurin coefficient of the named kernel.
///
/// Matches the paper's Table 1 with the two typos fixed (log: 1/max(1,N);
/// sqrt: double factorial (2N-3)!!) — see maclaurin.py for the derivation.
pub fn coefficient(kernel: &str, n: usize) -> f64 {
    match kernel {
        "exp" | "trigh" => 1.0 / factorial(n),
        "inv" => 1.0,
        "log" => {
            if n == 0 {
                1.0
            } else {
                1.0 / n as f64
            }
        }
        "sqrt" => {
            if n == 0 {
                1.0
            } else {
                double_factorial(2 * n as i64 - 3) / (2f64.powi(n as i32) * factorial(n))
            }
        }
        other => panic!("unknown kernel {other:?}"),
    }
}

/// Closed-form K as a plain function pointer, so hot loops resolve the
/// kernel name once instead of string-matching per score element.
pub fn kernel_value_fn(kernel: &str) -> fn(f64) -> f64 {
    match kernel {
        "exp" | "trigh" => f64::exp,
        "inv" => |t| 1.0 / (1.0 - t),
        "log" => |t| 1.0 - (1.0 - t).ln(),
        "sqrt" => |t| 2.0 - (1.0 - t).sqrt(),
        other => panic!("unknown kernel {other:?}"),
    }
}

/// Closed-form K(t).
pub fn kernel_value(kernel: &str, t: f64) -> f64 {
    kernel_value_fn(kernel)(t)
}

/// sum_{N=0}^{max_degree} a_N t^N.
pub fn truncated_kernel_value(kernel: &str, t: f64, max_degree: usize) -> f64 {
    let mut acc = 0.0;
    let mut tn = 1.0;
    for n in 0..=max_degree {
        acc += coefficient(kernel, n) * tn;
        tn *= t;
    }
    acc
}

/// P[N = eta] over the truncated window (renormalized geometric law).
pub fn degree_distribution(p: f64, max_degree: usize) -> Vec<f64> {
    assert!(p > 1.0, "p must be > 1");
    let raw: Vec<f64> = (0..=max_degree).map(|e| p.powi(-(e as i32 + 1))).collect();
    let z: f64 = raw.iter().sum();
    raw.into_iter().map(|x| x / z).collect()
}

/// sqrt(a_N * p^(N+1)): the phi_i prefactor from Definition 3.
pub fn feature_scale(kernel: &str, degree: usize, p: f64) -> f64 {
    (coefficient(kernel, degree) * p.powi(degree as i32 + 1)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_coefficients_are_inverse_factorials() {
        assert_eq!(coefficient("exp", 0), 1.0);
        assert_eq!(coefficient("exp", 3), 1.0 / 6.0);
        assert_eq!(coefficient("trigh", 4), 1.0 / 24.0);
    }

    #[test]
    fn all_coefficients_nonnegative() {
        for k in KERNELS {
            for n in 0..=12 {
                assert!(coefficient(k, n) >= 0.0, "{k} a_{n}");
            }
        }
    }

    #[test]
    fn expansions_match_closed_forms() {
        // On |t| <= 0.5 a degree-16 truncation must be within 1e-3 of the
        // closed form for every kernel.
        for k in KERNELS {
            for i in 0..=20 {
                let t = -0.5 + i as f64 * 0.05;
                let exact = kernel_value(k, t);
                let series = truncated_kernel_value(k, t, 16);
                assert!(
                    (exact - series).abs() < 1e-3 * exact.abs().max(1.0),
                    "{k}(t={t}): closed {exact} vs series {series}"
                );
            }
        }
    }

    #[test]
    fn sqrt_coefficient_uses_double_factorial() {
        // a_4 of 2-sqrt(1-t) is 5!!/2^4/4! = 15/384, NOT the paper's
        // max(1, 2N-3)/(2^N N!) = 5/384 — the series test above would fail
        // with the paper's literal formula.
        assert!((coefficient("sqrt", 4) - 15.0 / 384.0).abs() < 1e-12);
    }

    #[test]
    fn degree_distribution_sums_to_one() {
        for p in [1.5, 2.0, 4.0] {
            let d = degree_distribution(p, 8);
            let s: f64 = d.iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
            // monotone decreasing
            for w in d.windows(2) {
                assert!(w[0] > w[1]);
            }
        }
    }

    #[test]
    fn geometric_law_ratios() {
        let d = degree_distribution(2.0, 8);
        for w in d.windows(2) {
            assert!((w[0] / w[1] - 2.0).abs() < 1e-9);
        }
    }

    #[test]
    fn scale_squared_times_prob_recovers_coefficient() {
        // E[a_N p^{N+1} * P[N]] telescopes back to a_N (untruncated law):
        // scale^2 * p^-(N+1) == a_N.
        for k in KERNELS {
            for n in 0..=6 {
                let s = feature_scale(k, n, 2.0);
                let back = s * s * 2f64.powi(-(n as i32 + 1));
                assert!((back - coefficient(k, n)).abs() < 1e-12);
            }
        }
    }
}
