//! Pure-Rust attention oracles: exact softmax attention, kernelized
//! attention (Definition 2), and the factored linear contraction.
//!
//! These mirror `python/compile/kernels/ref.py` and exist for two jobs:
//! (1) integration tests cross-check the HLO modules' numerics against an
//! independent implementation, and (2) the Fig-4 harness computes exact
//! attention on the host when validating device outputs.
//!
//! Layout: one attention problem = q, k, v as (n x d) row-major slices.

use crate::attn::Kernel;
use crate::tensor::Tensor;

/// Exact softmax attention for a single head: out = softmax(q k^T / sqrt(d)) v.
///
/// The causal mask is defined over one shared token axis (`limit = i + 1`),
/// so `causal = true` requires `n == m` — cross-attention (m != n) is
/// non-causal by construction. This used to be silently wrong for m > n
/// and out-of-bounds for m < n; it now asserts.
pub fn softmax_attention(q: &Tensor, k: &Tensor, v: &Tensor, causal: bool) -> Tensor {
    let (n, d) = (q.shape[0], q.shape[1]);
    let m = k.shape[0];
    assert_eq!(k.shape[1], d);
    assert_eq!(v.shape[0], m);
    if causal {
        assert_eq!(n, m, "causal softmax attention needs n == m");
    }
    let dv = v.shape[1];
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = Tensor::zeros(&[n, dv]);
    let mut logits = vec![0.0f32; m];
    for i in 0..n {
        let qi = &q.data[i * d..(i + 1) * d];
        let limit = if causal { i + 1 } else { m };
        let mut maxl = f32::NEG_INFINITY;
        for j in 0..limit {
            let kj = &k.data[j * d..(j + 1) * d];
            let s: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
            logits[j] = s;
            maxl = maxl.max(s);
        }
        let mut z = 0.0f32;
        for l in logits.iter_mut().take(limit) {
            *l = (*l - maxl).exp();
            z += *l;
        }
        for j in 0..limit {
            let w = logits[j] / z;
            let vj = &v.data[j * dv..(j + 1) * dv];
            let dst = &mut out.data[i * dv..(i + 1) * dv];
            for (o, x) in dst.iter_mut().zip(vj) {
                *o += w * x;
            }
        }
    }
    out
}

/// Kernelized attention (Definition 2) with a Table-1 kernel.
///
/// Causal masking requires `n == m` (see [`softmax_attention`]).
/// Panics if `kernel` is [`Kernel::Softmax`] — the exact baseline has no
/// pointwise kernel weight; route through `attn::AttentionSession`,
/// which rejects that combination with a clean error.
pub fn kernelized_attention(
    kernel: Kernel,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (n, d) = (q.shape[0], q.shape[1]);
    let m = k.shape[0];
    assert_eq!(k.shape[1], d);
    assert_eq!(v.shape[0], m);
    if causal {
        assert_eq!(n, m, "causal kernelized attention needs n == m");
    }
    let dv = v.shape[1];
    let scale = 1.0 / (d as f32).sqrt();
    // resolve the kernel once — not per score element
    let kf = kernel
        .value_fn()
        .expect("kernelized attention requires a Table-1 Maclaurin kernel");
    let mut out = Tensor::zeros(&[n, dv]);
    for i in 0..n {
        let qi = &q.data[i * d..(i + 1) * d];
        let limit = if causal { i + 1 } else { m };
        let mut den = 0.0f32;
        let mut num = vec![0.0f32; dv];
        for j in 0..limit {
            let kj = &k.data[j * d..(j + 1) * d];
            let t: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
            let w = kf(t as f64) as f32;
            den += w;
            let vj = &v.data[j * dv..(j + 1) * dv];
            for (o, x) in num.iter_mut().zip(vj) {
                *o += w * x;
            }
        }
        for (o, x) in out.data[i * dv..(i + 1) * dv].iter_mut().zip(&num) {
            *o = x / (den + eps);
        }
    }
    out
}

/// Factored linear contraction: out_i = phi_q_i S / (phi_q_i z + eps).
pub fn linear_attention(
    phi_q: &Tensor,
    phi_k: &Tensor,
    v: &Tensor,
    causal: bool,
    eps: f32,
) -> Tensor {
    let (n, feat) = (phi_q.shape[0], phi_q.shape[1]);
    let dv = v.shape[1];
    let mut out = Tensor::zeros(&[n, dv]);
    if causal {
        let mut s = vec![0.0f32; feat * dv];
        let mut z = vec![0.0f32; feat];
        for i in 0..n {
            let pk = &phi_k.data[i * feat..(i + 1) * feat];
            let vi = &v.data[i * dv..(i + 1) * dv];
            for (f, pkf) in pk.iter().enumerate() {
                z[f] += pkf;
                let row = &mut s[f * dv..(f + 1) * dv];
                for (acc, x) in row.iter_mut().zip(vi) {
                    *acc += pkf * x;
                }
            }
            let pq = &phi_q.data[i * feat..(i + 1) * feat];
            let mut den = 0.0f32;
            let mut num = vec![0.0f32; dv];
            for (f, pqf) in pq.iter().enumerate() {
                den += pqf * z[f];
                let row = &s[f * dv..(f + 1) * dv];
                for (acc, x) in num.iter_mut().zip(row) {
                    *acc += pqf * x;
                }
            }
            for (o, x) in out.data[i * dv..(i + 1) * dv].iter_mut().zip(&num) {
                *o = x / (den + eps);
            }
        }
    } else {
        // S = phi_k^T v (feat x dv) — the scalar matmul_tn kernel reads
        // phi_k row-major and never materializes the transpose (pinned to
        // the scalar arm: the oracle must not pick up the SIMD dispatch);
        // z = sum_j phi_k_j.
        let mut s = Tensor::zeros(&[feat, dv]);
        crate::tensor::matmul_tn_scalar_into(
            &phi_k.data,
            phi_k.shape[0],
            feat,
            &v.data,
            dv,
            &mut s.data,
        );
        let mut z = vec![0.0f32; feat];
        for j in 0..phi_k.shape[0] {
            let pk = &phi_k.data[j * feat..(j + 1) * feat];
            for (zf, pkf) in z.iter_mut().zip(pk) {
                *zf += *pkf;
            }
        }
        for i in 0..n {
            let pq = &phi_q.data[i * feat..(i + 1) * feat];
            let den: f32 = pq.iter().zip(&z).map(|(a, b)| a * b).sum();
            // accumulate num_i = pq_i · S row by row: the old loop walked
            // S down its columns (stride dv) per output element; this
            // walks each S row once, contiguously.
            let num = &mut out.data[i * dv..(i + 1) * dv];
            for (f, &pqf) in pq.iter().enumerate() {
                if pqf == 0.0 {
                    continue;
                }
                let srow = &s.data[f * dv..(f + 1) * dv];
                for (o, x) in num.iter_mut().zip(srow) {
                    *o += pqf * x;
                }
            }
            let denom = den + eps;
            for o in num.iter_mut() {
                *o /= denom;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, shape: &[usize], scale: f32) -> Tensor {
        let mut t = Tensor::zeros(shape);
        for x in t.data.iter_mut() {
            *x = rng.normal() * scale;
        }
        t
    }

    #[test]
    fn softmax_rows_are_convex_combinations() {
        let mut rng = Rng::new(1);
        let q = randn(&mut rng, &[8, 4], 1.0);
        let k = randn(&mut rng, &[8, 4], 1.0);
        // v constant per column -> output must equal that constant
        let v = Tensor::filled(&[8, 3], 2.5);
        let out = softmax_attention(&q, &k, &v, false);
        for x in &out.data {
            assert!((x - 2.5).abs() < 1e-5, "{x}");
        }
    }

    #[test]
    fn causal_first_row_copies_first_value() {
        let mut rng = Rng::new(2);
        let q = randn(&mut rng, &[5, 4], 1.0);
        let k = randn(&mut rng, &[5, 4], 1.0);
        let v = randn(&mut rng, &[5, 3], 1.0);
        let out = softmax_attention(&q, &k, &v, true);
        for c in 0..3 {
            assert!((out.data[c] - v.data[c]).abs() < 1e-6);
        }
    }

    #[test]
    fn kernelized_exp_equals_softmax() {
        let mut rng = Rng::new(3);
        let q = randn(&mut rng, &[6, 4], 0.5);
        let k = randn(&mut rng, &[6, 4], 0.5);
        let v = randn(&mut rng, &[6, 4], 1.0);
        let a = softmax_attention(&q, &k, &v, false);
        let b = kernelized_attention(Kernel::Exp, &q, &k, &v, false, 0.0);
        assert!(a.max_abs_diff(&b) < 1e-4, "{}", a.max_abs_diff(&b));
    }

    #[test]
    fn non_causal_cross_attention_supports_m_ne_n() {
        // m != n is a legal cross-attention shape when non-causal; with a
        // constant v, every output row must be that constant.
        let mut rng = Rng::new(6);
        let q = randn(&mut rng, &[3, 4], 1.0);
        let k = randn(&mut rng, &[7, 4], 1.0);
        let v = Tensor::filled(&[7, 2], -1.5);
        for out in [
            softmax_attention(&q, &k, &v, false),
            kernelized_attention(Kernel::Inv, &q, &k, &v, false, 0.0),
        ] {
            assert_eq!(out.shape, vec![3, 2]);
            for x in &out.data {
                assert!((x + 1.5).abs() < 1e-4, "{x}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "causal softmax attention needs n == m")]
    fn causal_softmax_rejects_m_ne_n() {
        // Regression: limit = i + 1 assumes one shared token axis. With
        // m > n this used to silently ignore keys; with m < n it read out
        // of bounds. Both now fail fast.
        let mut rng = Rng::new(7);
        let q = randn(&mut rng, &[3, 4], 1.0);
        let k = randn(&mut rng, &[5, 4], 1.0);
        let v = randn(&mut rng, &[5, 2], 1.0);
        let _ = softmax_attention(&q, &k, &v, true);
    }

    #[test]
    #[should_panic(expected = "causal kernelized attention needs n == m")]
    fn causal_kernelized_rejects_m_ne_n() {
        let mut rng = Rng::new(8);
        let q = randn(&mut rng, &[5, 4], 1.0);
        let k = randn(&mut rng, &[3, 4], 1.0);
        let v = randn(&mut rng, &[3, 2], 1.0);
        let _ = kernelized_attention(Kernel::Exp, &q, &k, &v, true, 0.0);
    }

    #[test]
    fn linear_attention_matches_explicit_scores() {
        // With phi maps given, linear attention must equal the quadratic
        // form sum_j (phi_q.phi_k_j) v_j / sum_j (phi_q.phi_k_j).
        let mut rng = Rng::new(4);
        let n = 7;
        let feat = 5;
        let phi_q = randn(&mut rng, &[n, feat], 1.0).map(f32::abs);
        let phi_k = randn(&mut rng, &[n, feat], 1.0).map(f32::abs);
        let v = randn(&mut rng, &[n, 3], 1.0);
        let fast = linear_attention(&phi_q, &phi_k, &v, false, 0.0);
        // explicit
        let mut slow = Tensor::zeros(&[n, 3]);
        for i in 0..n {
            let mut den = 0.0;
            let mut num = [0.0f32; 3];
            for j in 0..n {
                let s: f32 = (0..feat)
                    .map(|f| phi_q.data[i * feat + f] * phi_k.data[j * feat + f])
                    .sum();
                den += s;
                for c in 0..3 {
                    num[c] += s * v.data[j * 3 + c];
                }
            }
            for c in 0..3 {
                slow.data[i * 3 + c] = num[c] / den;
            }
        }
        assert!(fast.max_abs_diff(&slow) < 1e-4);
    }

    #[test]
    fn causal_linear_matches_bidir_on_last_row() {
        let mut rng = Rng::new(5);
        let n = 6;
        let phi_q = randn(&mut rng, &[n, 4], 1.0).map(f32::abs);
        let phi_k = randn(&mut rng, &[n, 4], 1.0).map(f32::abs);
        let v = randn(&mut rng, &[n, 2], 1.0);
        let c = linear_attention(&phi_q, &phi_k, &v, true, 0.0);
        let b = linear_attention(&phi_q, &phi_k, &v, false, 0.0);
        for col in 0..2 {
            let i = (n - 1) * 2 + col;
            assert!((c.data[i] - b.data[i]).abs() < 1e-5);
        }
    }
}
