//! Experiment configuration: typed, layered (defaults < JSON file < CLI
//! flags), JSON round-trippable.
//!
//! One `RunConfig` fully describes a training run; the sweep orchestrator
//! materializes one per Table-2 cell and passes it to subprocesses as
//! JSON, so a run is reproducible from its config alone.

use anyhow::{anyhow, Result};

use crate::util::cli::Args;
use crate::util::json::{self, Value};

/// Configuration for one training/eval run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub task: String,
    pub variant: String,
    /// model-family suffix for the Fig-3 cells ("", ".base", ".ppsbn")
    pub suffix: String,
    pub seed: u64,
    pub train_examples: usize,
    pub eval_examples: usize,
    pub steps: usize,
    pub eval_every: usize,
    pub log_every: usize,
    pub artifacts_dir: String,
    pub checkpoint: Option<String>,
    pub out_json: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            task: "lra_text".into(),
            variant: "mac_exp".into(),
            suffix: String::new(),
            seed: 42,
            train_examples: 512,
            eval_examples: 128,
            steps: 200,
            eval_every: 100,
            log_every: 10,
            artifacts_dir: "artifacts".into(),
            checkpoint: None,
            out_json: None,
        }
    }
}

impl RunConfig {
    /// Artifact family prefix, e.g. "lra_text.mac_exp" or
    /// "translation.softmax.ppsbn".
    pub fn family(&self) -> String {
        format!("{}.{}{}", self.task, self.variant, self.suffix)
    }

    /// Overlay CLI flags onto this config.
    pub fn apply_args(&mut self, a: &Args) -> Result<()> {
        if let Some(f) = a.opt_flag("config") {
            let text = std::fs::read_to_string(&f)
                .map_err(|e| anyhow!("reading config {f}: {e}"))?;
            *self = RunConfig::from_json(
                &json::parse(&text).map_err(|e| anyhow!("config {f}: {e}"))?,
            )?;
        }
        self.task = a.str_flag("task", &self.task);
        self.variant = a.str_flag("variant", &self.variant);
        self.suffix = a.str_flag("suffix", &self.suffix);
        self.seed = a.u64_flag("seed", self.seed).map_err(|e| anyhow!(e))?;
        self.train_examples = a
            .usize_flag("train-examples", self.train_examples)
            .map_err(|e| anyhow!(e))?;
        self.eval_examples = a
            .usize_flag("eval-examples", self.eval_examples)
            .map_err(|e| anyhow!(e))?;
        self.steps = a.usize_flag("steps", self.steps).map_err(|e| anyhow!(e))?;
        self.eval_every = a
            .usize_flag("eval-every", self.eval_every)
            .map_err(|e| anyhow!(e))?;
        self.log_every = a
            .usize_flag("log-every", self.log_every)
            .map_err(|e| anyhow!(e))?;
        self.artifacts_dir = a.str_flag("artifacts", &self.artifacts_dir);
        self.checkpoint = a.opt_flag("checkpoint").or(self.checkpoint.take());
        self.out_json = a.opt_flag("out-json").or(self.out_json.take());
        Ok(())
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("task", Value::str(&self.task)),
            ("variant", Value::str(&self.variant)),
            ("suffix", Value::str(&self.suffix)),
            ("seed", Value::num(self.seed as f64)),
            ("train_examples", Value::num(self.train_examples as f64)),
            ("eval_examples", Value::num(self.eval_examples as f64)),
            ("steps", Value::num(self.steps as f64)),
            ("eval_every", Value::num(self.eval_every as f64)),
            ("log_every", Value::num(self.log_every as f64)),
            ("artifacts_dir", Value::str(&self.artifacts_dir)),
        ])
    }

    pub fn from_json(v: &Value) -> Result<RunConfig> {
        let d = RunConfig::default();
        Ok(RunConfig {
            task: v.get("task").as_str().unwrap_or(&d.task).to_string(),
            variant: v.get("variant").as_str().unwrap_or(&d.variant).to_string(),
            suffix: v.get("suffix").as_str().unwrap_or("").to_string(),
            seed: v.get("seed").as_i64().unwrap_or(d.seed as i64) as u64,
            train_examples: v
                .get("train_examples")
                .as_usize()
                .unwrap_or(d.train_examples),
            eval_examples: v.get("eval_examples").as_usize().unwrap_or(d.eval_examples),
            steps: v.get("steps").as_usize().unwrap_or(d.steps),
            eval_every: v.get("eval_every").as_usize().unwrap_or(d.eval_every),
            log_every: v.get("log_every").as_usize().unwrap_or(d.log_every),
            artifacts_dir: v
                .get("artifacts_dir")
                .as_str()
                .unwrap_or(&d.artifacts_dir)
                .to_string(),
            checkpoint: None,
            out_json: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_round_trip() {
        let mut c = RunConfig::default();
        c.task = "lra_listops".into();
        c.steps = 777;
        let v = c.to_json();
        let back = RunConfig::from_json(&v).unwrap();
        assert_eq!(back.task, "lra_listops");
        assert_eq!(back.steps, 777);
    }

    #[test]
    fn flags_override_defaults() {
        let toks: Vec<String> = "train --task translation --variant softmax --suffix .ppsbn --steps 5"
            .split_whitespace()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&toks).unwrap();
        let mut c = RunConfig::default();
        c.apply_args(&a).unwrap();
        assert_eq!(c.family(), "translation.softmax.ppsbn");
        assert_eq!(c.steps, 5);
    }
}
