//! Host-side tensors: the coordinator's working representation for batch
//! staging, reference math, and checkpoints.
//!
//! Deliberately minimal — the heavy math runs on the PJRT device; this
//! type exists so the data pipeline, metrics, and the pure-Rust reference
//! implementations share one shape-checked container.

mod io;

pub use io::{read_tensor, write_tensor, read_bundle, write_bundle};

/// Register-tile height/width for the blocked matmul kernels. 4x4 f32
/// accumulators fit comfortably in registers on every target we care
/// about while keeping the tail logic trivial.
const TILE: usize = 4;

/// `out = A · B^T` over raw row-major slices: A is (m x k), B is (n x k),
/// out is (m x n). Runtime-dispatched: on hosts with AVX2+FMA (and
/// `MACFORMER_NO_SIMD` unset) this runs the 8-lane
/// `fastpath::simd::x86::matmul_nt` microkernel (within `1e-5` of the
/// scalar kernel — lane-parallel accumulation reassociates addition);
/// everywhere else it is exactly [`matmul_nt_scalar_into`].
pub fn matmul_nt_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if crate::fastpath::simd::active() {
        // SAFETY: active() implies AVX2+FMA were detected on this CPU.
        unsafe { crate::fastpath::simd::x86::matmul_nt(a, m, k, b, n, out) };
        return;
    }
    matmul_nt_scalar_into(a, m, k, b, n, out);
}

/// The scalar arm of [`matmul_nt_into`]: register-blocked over TILE x
/// TILE output tiles; the k-loop stays sequential and ascending per
/// accumulator, so every output element is accumulated in exactly the
/// same order as a naive `zip(..).map(..).sum()` dot product — callers
/// (the RMF fastpath) rely on that for bit-for-bit equivalence with the
/// reference path on the scalar arm.
pub fn matmul_nt_scalar_into(a: &[f32], m: usize, k: usize, b: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul_nt_into: lhs len");
    assert_eq!(b.len(), n * k, "matmul_nt_into: rhs len");
    assert_eq!(out.len(), m * n, "matmul_nt_into: out len");
    let mut i0 = 0;
    while i0 < m {
        let ib = TILE.min(m - i0);
        let mut j0 = 0;
        while j0 < n {
            let jb = TILE.min(n - j0);
            let mut acc = [[0.0f32; TILE]; TILE];
            for p in 0..k {
                for (ii, row) in acc.iter_mut().enumerate().take(ib) {
                    let av = a[(i0 + ii) * k + p];
                    for (jj, c) in row.iter_mut().enumerate().take(jb) {
                        *c += av * b[(j0 + jj) * k + p];
                    }
                }
            }
            for (ii, row) in acc.iter().enumerate().take(ib) {
                for (jj, c) in row.iter().enumerate().take(jb) {
                    out[(i0 + ii) * n + j0 + jj] = *c;
                }
            }
            j0 += TILE;
        }
        i0 += TILE;
    }
}

/// `out = A^T · B` over raw row-major slices: A is (r x m), B is (r x n),
/// out is (m x n). Runtime-dispatched like [`matmul_nt_into`]: the
/// AVX2+FMA arm vectorizes each rank-1 update row, the fallback is
/// exactly [`matmul_tn_scalar_into`].
pub fn matmul_tn_into(a: &[f32], r: usize, m: usize, b: &[f32], n: usize, out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if crate::fastpath::simd::active() {
        // SAFETY: active() implies AVX2+FMA were detected on this CPU.
        unsafe { crate::fastpath::simd::x86::matmul_tn(a, r, m, b, n, out) };
        return;
    }
    matmul_tn_scalar_into(a, r, m, b, n, out);
}

/// The scalar arm of [`matmul_tn_into`]: rank-1 update by rank-1 update
/// so every memory stream is contiguous (the "column-major fix": no
/// transposed reads, no `transpose2` allocation). Accumulation order
/// over r matches `transpose2().matmul(..)` exactly, including its
/// zero-skip.
pub fn matmul_tn_scalar_into(a: &[f32], r: usize, m: usize, b: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(out.len(), m * n, "matmul_tn_into: out len");
    out.fill(0.0);
    matmul_tn_accum_scalar_into(a, r, m, b, n, out);
}

/// `out += A^T · B` — the accumulating form of [`matmul_tn_into`],
/// dispatched the same way. Because both arms apply the rank-1 updates
/// row by row in `r` order (vectorized only along `n`, exactly like the
/// dispatched `axpy`), accumulating a chunk of rows into a running
/// state is **bit-identical** to folding those rows in one `axpy` at a
/// time on the same arm — the property the chunked causal prefill's
/// `(S, z)` state advance relies on.
pub fn matmul_tn_accum_into(a: &[f32], r: usize, m: usize, b: &[f32], n: usize, out: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if crate::fastpath::simd::active() {
        // SAFETY: active() implies AVX2+FMA were detected on this CPU.
        unsafe { crate::fastpath::simd::x86::matmul_tn_accum(a, r, m, b, n, out) };
        return;
    }
    matmul_tn_accum_scalar_into(a, r, m, b, n, out);
}

/// Scalar arm of [`matmul_tn_accum_into`] — the exact
/// [`matmul_tn_scalar_into`] loop without the zero-fill.
pub fn matmul_tn_accum_scalar_into(
    a: &[f32],
    r: usize,
    m: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), r * m, "matmul_tn_into: lhs len");
    assert_eq!(b.len(), r * n, "matmul_tn_into: rhs len");
    assert_eq!(out.len(), m * n, "matmul_tn_into: out len");
    for p in 0..r {
        let arow = &a[p * m..(p + 1) * m];
        let brow = &b[p * n..(p + 1) * n];
        for (f, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let dst = &mut out[f * n..(f + 1) * n];
            for (d, &bv) in dst.iter_mut().zip(brow) {
                *d += av * bv;
            }
        }
    }
}

/// Dense row-major f32 tensor with explicit shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with data len {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    /// i.i.d. N(0, scale^2) entries — the one Gaussian-fill helper shared
    /// by tests and benches (drift-proof: seeding/scale semantics live
    /// here only).
    pub fn randn(rng: &mut crate::util::rng::Rng, shape: &[usize], scale: f32) -> Tensor {
        let mut t = Tensor::zeros(shape);
        for x in t.data.iter_mut() {
            *x = rng.normal() * scale;
        }
        t
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let strides = self.strides();
        let off: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.data[off]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let strides = self.strides();
        let off: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.data[off] = v;
    }

    /// 2-D matmul (self: m x k, rhs: k x n).
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(rhs.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let row = &rhs.data[p * n..(p + 1) * n];
                let dst = &mut out.data[i * n..(i + 1) * n];
                for (d, b) in dst.iter_mut().zip(row) {
                    *d += a * b;
                }
            }
        }
        out
    }

    /// `self · rhs^T` (self: m x k, rhs: n x k) via the runtime-dispatched
    /// kernel — the GEMM behind the fastpath feature maps and attention
    /// logits. On the scalar arm, accumulation order matches a naive dot
    /// product exactly; the AVX2 arm stays within `1e-5`.
    pub fn matmul_nt(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(rhs.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (n, k2) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "matmul_nt inner dims {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        matmul_nt_into(&self.data, m, k, &rhs.data, n, &mut out.data);
        out
    }

    /// `self^T · rhs` (self: r x m, rhs: r x n) without materializing the
    /// transpose — replaces the `transpose2().matmul(..)` allocation on
    /// the linear-attention path.
    pub fn matmul_tn(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(rhs.rank(), 2);
        let (r, m) = (self.shape[0], self.shape[1]);
        let (r2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(r, r2, "matmul_tn leading dims {r} vs {r2}");
        let mut out = Tensor::zeros(&[m, n]);
        matmul_tn_into(&self.data, r, m, &rhs.data, n, &mut out.data);
        out
    }

    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|x| f(*x)).collect(),
        }
    }

    pub fn add(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.shape, rhs.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn dot(&self, rhs: &Tensor) -> f32 {
        assert_eq!(self.shape, rhs.shape);
        self.data.iter().zip(&rhs.data).map(|(a, b)| a * b).sum()
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |a - b| over elements; shapes must match.
    pub fn max_abs_diff(&self, rhs: &Tensor) -> f32 {
        assert_eq!(self.shape, rhs.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Copy problem `gi` of a batched rank-3 (g, n, w) tensor out as a
    /// rank-2 (n, w) tensor — the one helper behind every per-problem
    /// fast-vs-reference comparison.
    pub fn problem2(&self, gi: usize) -> Tensor {
        assert_eq!(self.rank(), 3, "problem2 expects a (g, n, w) tensor");
        let (n, w) = (self.shape[1], self.shape[2]);
        let mut t = self.slice0(gi, 1);
        t.shape = vec![n, w];
        t
    }

    /// Slice the leading axis: rows [start, start+len). Works for any
    /// rank >= 1 (for rank-1 tensors a "row" is a single element).
    pub fn slice0(&self, start: usize, len: usize) -> Tensor {
        assert!(self.rank() >= 1, "slice0 on a rank-0 tensor");
        assert!(
            start.checked_add(len).is_some_and(|end| end <= self.shape[0]),
            "slice0 rows [{start}, {start}+{len}) out of bounds for leading axis of {}",
            self.shape[0]
        );
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = len;
        Tensor::from_vec(
            &shape,
            self.data[start * row..(start + len) * row].to_vec(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_data_agreement_enforced() {
        let r = std::panic::catch_unwind(|| Tensor::from_vec(&[2, 3], vec![0.0; 5]));
        assert!(r.is_err());
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn indexing_round_trip() {
        let mut t = Tensor::zeros(&[3, 4]);
        t.set(&[2, 1], 7.5);
        assert_eq!(t.at(&[2, 1]), 7.5);
        assert_eq!(t.data[2 * 4 + 1], 7.5);
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let eye = Tensor::from_vec(&[3, 3], vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]);
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose2().transpose2(), a);
        assert_eq!(a.transpose2().at(&[2, 1]), 6.0);
    }

    #[test]
    fn slice0_takes_rows() {
        let a = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let s = a.slice0(1, 2);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![3., 4., 5., 6.]);
    }

    #[test]
    fn slice0_works_on_rank1() {
        let a = Tensor::from_vec(&[4], vec![1., 2., 3., 4.]);
        let s = a.slice0(1, 2);
        assert_eq!(s.shape, vec![2]);
        assert_eq!(s.data, vec![2., 3.]);
    }

    #[test]
    fn slice0_bounds_checked_with_message() {
        let a = Tensor::from_vec(&[3, 2], vec![0.0; 6]);
        let r = std::panic::catch_unwind(|| a.slice0(2, 2));
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("out of bounds"), "panic message: {msg}");
        // overflow-proof: start + len wrapping must not sneak past the check
        let r = std::panic::catch_unwind(|| a.slice0(usize::MAX, 2));
        assert!(r.is_err());
    }

    #[test]
    fn matmul_nt_matches_transposed_matmul() {
        let mut rng = crate::util::rng::Rng::new(17);
        for (m, k, n) in [(1, 1, 1), (3, 5, 4), (7, 2, 9), (5, 8, 5)] {
            let a = Tensor::from_vec(
                &[m, k],
                (0..m * k).map(|_| rng.normal()).collect(),
            );
            let b = Tensor::from_vec(
                &[n, k],
                (0..n * k).map(|_| rng.normal()).collect(),
            );
            // the dispatched kernel may take the SIMD arm: 1e-5 contract
            let fast = a.matmul_nt(&b);
            let slow = a.matmul(&b.transpose2());
            assert_eq!(fast.shape, slow.shape);
            assert!(fast.max_abs_diff(&slow) < 1e-5, "({m},{k},{n})");
            // the scalar arm stays bit-for-bit
            let mut anchor = Tensor::zeros(&[m, n]);
            matmul_nt_scalar_into(&a.data, m, k, &b.data, n, &mut anchor.data);
            assert_eq!(anchor.max_abs_diff(&slow), 0.0, "scalar ({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_tn_matches_transposed_matmul() {
        let mut rng = crate::util::rng::Rng::new(18);
        for (r, m, n) in [(1, 1, 1), (4, 3, 5), (9, 2, 7), (6, 6, 1)] {
            let a = Tensor::from_vec(
                &[r, m],
                (0..r * m).map(|_| rng.normal()).collect(),
            );
            let b = Tensor::from_vec(
                &[r, n],
                (0..r * n).map(|_| rng.normal()).collect(),
            );
            // the dispatched kernel may take the SIMD arm: 1e-5 contract
            let fast = a.matmul_tn(&b);
            let slow = a.transpose2().matmul(&b);
            assert_eq!(fast.shape, slow.shape);
            assert!(fast.max_abs_diff(&slow) < 1e-5, "({r},{m},{n})");
            // the scalar arm stays bit-for-bit
            let mut anchor = Tensor::zeros(&[m, n]);
            matmul_tn_scalar_into(&a.data, r, m, &b.data, n, &mut anchor.data);
            assert_eq!(anchor.max_abs_diff(&slow), 0.0, "scalar ({r},{m},{n})");
        }
    }

    #[test]
    fn matmul_tn_accum_equals_chunked_rank1_folds() {
        // the chunked-prefill contract: accumulating a block of rows via
        // matmul_tn_accum_into is bit-identical to folding the same rows
        // one rank-1 update at a time on the same dispatch arm
        let mut rng = crate::util::rng::Rng::new(19);
        for (r, m, n) in [(1, 1, 1), (5, 3, 4), (9, 2, 17), (6, 7, 8)] {
            let a: Vec<f32> = (0..r * m).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..r * n).map(|_| rng.normal()).collect();
            let mut state: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let mut folded = state.clone();
            for p in 0..r {
                for f in 0..m {
                    let av = a[p * m + f];
                    if av == 0.0 {
                        continue;
                    }
                    crate::fastpath::simd::axpy(
                        av,
                        &b[p * n..(p + 1) * n],
                        &mut folded[f * n..(f + 1) * n],
                    );
                }
            }
            matmul_tn_accum_into(&a, r, m, &b, n, &mut state);
            for (i, (x, y)) in state.iter().zip(&folded).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "({r},{m},{n}) elem {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn norms_and_dot() {
        let a = Tensor::from_vec(&[2], vec![3.0, 4.0]);
        assert_eq!(a.l2_norm(), 5.0);
        assert_eq!(a.dot(&a), 25.0);
    }
}
