//! Host-side tensors: the coordinator's working representation for batch
//! staging, reference math, and checkpoints.
//!
//! Deliberately minimal — the heavy math runs on the PJRT device; this
//! type exists so the data pipeline, metrics, and the pure-Rust reference
//! implementations share one shape-checked container.

mod io;

pub use io::{read_tensor, write_tensor, read_bundle, write_bundle};

/// Dense row-major f32 tensor with explicit shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} incompatible with data len {}",
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn filled(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        debug_assert_eq!(idx.len(), self.shape.len());
        let strides = self.strides();
        let off: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.data[off]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let strides = self.strides();
        let off: usize = idx.iter().zip(&strides).map(|(i, s)| i * s).sum();
        self.data[off] = v;
    }

    /// 2-D matmul (self: m x k, rhs: k x n).
    pub fn matmul(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2);
        assert_eq!(rhs.rank(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (rhs.shape[0], rhs.shape[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let row = &rhs.data[p * n..(p + 1) * n];
                let dst = &mut out.data[i * n..(i + 1) * n];
                for (d, b) in dst.iter_mut().zip(row) {
                    *d += a * b;
                }
            }
        }
        out
    }

    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|x| f(*x)).collect(),
        }
    }

    pub fn add(&self, rhs: &Tensor) -> Tensor {
        assert_eq!(self.shape, rhs.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn dot(&self, rhs: &Tensor) -> f32 {
        assert_eq!(self.shape, rhs.shape);
        self.data.iter().zip(&rhs.data).map(|(a, b)| a * b).sum()
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |a - b| over elements; shapes must match.
    pub fn max_abs_diff(&self, rhs: &Tensor) -> f32 {
        assert_eq!(self.shape, rhs.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Slice the leading axis: rows [start, start+len).
    pub fn slice0(&self, start: usize, len: usize) -> Tensor {
        assert!(self.rank() >= 1);
        let row: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = len;
        Tensor::from_vec(
            &shape,
            self.data[start * row..(start + len) * row].to_vec(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_data_agreement_enforced() {
        let r = std::panic::catch_unwind(|| Tensor::from_vec(&[2, 3], vec![0.0; 5]));
        assert!(r.is_err());
    }

    #[test]
    fn strides_row_major() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.strides(), vec![12, 4, 1]);
    }

    #[test]
    fn indexing_round_trip() {
        let mut t = Tensor::zeros(&[3, 4]);
        t.set(&[2, 1], 7.5);
        assert_eq!(t.at(&[2, 1]), 7.5);
        assert_eq!(t.data[2 * 4 + 1], 7.5);
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let eye = Tensor::from_vec(&[3, 3], vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]);
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose2().transpose2(), a);
        assert_eq!(a.transpose2().at(&[2, 1]), 6.0);
    }

    #[test]
    fn slice0_takes_rows() {
        let a = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let s = a.slice0(1, 2);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![3., 4., 5., 6.]);
    }

    #[test]
    fn norms_and_dot() {
        let a = Tensor::from_vec(&[2], vec![3.0, 4.0]);
        assert_eq!(a.l2_norm(), 5.0);
        assert_eq!(a.dot(&a), 25.0);
    }
}
